"""L2: the spectral convolutional layer as a jittable JAX function, plus the
model-variant registry the AOT pipeline and the Rust coordinator share.

One compiled executable per distinct layer *shape* (T tiles, M in-channels,
N out-channels, K FFT size).  The executable covers the paper's "FPGA side":

    spatial tiles --2D FFT--> spectral --Hadamard (Pallas L1)--> spectral
                 --2D IFFT--> spatial output tiles

The "CPU side" (im2tiles, overlap-and-add, bias, ReLU, pooling, FC) lives in
the Rust coordinator, mirroring the paper's CPU-FPGA split (§6: "operations
like OaA, ReLU, Pooling, fully-connected layers are offloaded to CPU, while
FPGA is dedicated to spectral convolutional layers").

Boundary convention: all executable inputs/outputs are f32 (complex values
never cross the AOT boundary); spectral kernels arrive as re/im planes laid
out ``[N, M, K, K]`` exactly as the Rust side stores them.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

import numpy as np

from .kernels.spectral_hadamard import spectral_hadamard

KERNEL_K = 3          # spatial kernel size the paper targets (VGG 3x3)
FFT_SIZE = 8          # K — paper's chosen spectral window (§6.1)
TILE = FFT_SIZE - KERNEL_K + 1  # h' = 6


# ---------------------------------------------------------------------------
# 2D DFT as matmuls (§Perf L2). For K = 8 the dense DFT-matrix product
# (X = D x Dᵀ) beats the XLA FFT op by a wide margin on the CPU PJRT the
# artifacts run on (xla_extension 0.5.1's FFT is serial and per-plane), and
# it is also the canonical TPU mapping: small Fourier transforms are MXU
# matmuls, not butterfly networks (DESIGN.md §Hardware-Adaptation).
# ---------------------------------------------------------------------------

def _dft_mats(k: int):
    """Forward DFT matrix D (re, im) and inverse E = conj(D)/K (re, im)."""
    idx = np.arange(k)
    ang = -2.0 * np.pi * np.outer(idx, idx) / k
    dr = np.cos(ang).astype(np.float32)
    di = np.sin(ang).astype(np.float32)
    er = (dr / k).astype(np.float32)
    ei = (-di / k).astype(np.float32)
    return dr, di, er, ei


def fft2_real(x):
    """2D DFT of real tiles ``[..., K, K]`` via D x Dᵀ → (re, im)."""
    k = x.shape[-1]
    dr, di, er, ei = _dft_mats(k)
    del er, ei
    dr = jnp.asarray(dr)
    di = jnp.asarray(di)
    # rows: t = D @ x  (contract x's second-to-last axis)
    t_r = jnp.einsum("ua,...ab->...ub", dr, x)
    t_i = jnp.einsum("ua,...ab->...ub", di, x)
    # cols: X = t @ Dᵀ
    x_r = jnp.einsum("...ub,vb->...uv", t_r, dr) - jnp.einsum("...ub,vb->...uv", t_i, di)
    x_i = jnp.einsum("...ub,vb->...uv", t_r, di) + jnp.einsum("...ub,vb->...uv", t_i, dr)
    return x_r, x_i


def ifft2_real(y_r, y_i):
    """Real part of the 2D inverse DFT of ``[..., K, K]`` spectral planes."""
    k = y_r.shape[-1]
    _, _, er, ei = _dft_mats(k)
    er = jnp.asarray(er)
    ei = jnp.asarray(ei)
    t_r = jnp.einsum("ua,...ab->...ub", er, y_r) - jnp.einsum("ua,...ab->...ub", ei, y_i)
    t_i = jnp.einsum("ua,...ab->...ub", er, y_i) + jnp.einsum("ua,...ab->...ub", ei, y_r)
    return jnp.einsum("...ub,vb->...uv", t_r, er) - jnp.einsum("...ub,vb->...uv", t_i, ei)


def spectral_conv_tiles(tiles, w_re, w_im, *, mode: str = "batched"):
    """FFT → frequency-major reshape → Pallas Hadamard → IFFT.

    Args:
      tiles: ``[T, M, K, K]`` f32 zero-padded spatial input tiles.
      w_re, w_im: ``[F, M, N]`` f32 spectral kernel planes, **frequency-
        major**. Weights are static, so the host computes this layout once
        at upload time — §Perf L2 (EXPERIMENTS.md): transposing the natural
        ``[N, M, K, K]`` layout inside the graph cost ~120 ms *per request*
        at 512×512 (67 MB strided transpose), dominating the deep layers.
      mode: complex-product decomposition for the Pallas kernel.

    Returns:
      1-tuple of ``[T, N, K, K]`` f32 spatial output tiles (real part of the
      IFFT; imaginary residue is fp noise since inputs/kernels derive from
      real spatial data).
    """
    t, m, k, _ = tiles.shape
    f = k * k
    fw, mw, n = w_re.shape
    assert fw == f and mw == m, f"kernel planes {w_re.shape} vs tiles {tiles.shape}"

    xr, xi = fft2_real(tiles)  # [T, M, K, K] f32 planes (DFT-as-matmul)
    # [T, M, K, K] -> frequency-major [F, T, M]
    xr = xr.reshape(t, m, f).transpose(2, 0, 1)
    xi = xi.reshape(t, m, f).transpose(2, 0, 1)

    yr, yi = spectral_hadamard(xr, xi, w_re, w_im, mode=mode)

    # [F, T, N] -> [T, N, K, K]
    yr = yr.transpose(1, 2, 0).reshape(t, n, k, k)
    yi = yi.transpose(1, 2, 0).reshape(t, n, k, k)
    out = ifft2_real(yr, yi)
    return (out,)


def layer_fn(t: int, m: int, n: int, k: int = FFT_SIZE, mode: str = "batched"):
    """Jittable function + example args for one layer shape (for lowering)."""
    tiles = jax.ShapeDtypeStruct((t, m, k, k), jnp.float32)
    w = jax.ShapeDtypeStruct((k * k, m, n), jnp.float32)  # frequency-major

    def fn(tiles, w_re, w_im):
        return spectral_conv_tiles(tiles, w_re, w_im, mode=mode)

    return fn, (tiles, w, w)


def to_freq_major(w_planes):
    """Host-side helper: ``[N, M, K, K]`` plane → frequency-major
    ``[F, M, N]`` (the executable input layout). Mirrored by the Rust
    engine's `freq_major_planes`."""
    n, m, k, _ = w_planes.shape
    return jnp.asarray(w_planes).reshape(n, m, k * k).transpose(2, 1, 0)


def tiles_per_side(h: int, tile: int = TILE) -> int:
    return -(-h // tile)


# ---------------------------------------------------------------------------
# Model-variant registry (shared vocabulary with the Rust coordinator via
# artifacts/manifest.json)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ConvLayer:
    """One convolutional layer instance inside a variant."""
    name: str
    cin: int
    cout: int
    h: int              # spatial side at this layer's input
    pool_after: bool    # 2x2/stride-2 maxpool follows (handled in Rust)

    @property
    def tiles(self) -> int:
        s = tiles_per_side(self.h)
        return s * s

    def shape_key(self) -> Tuple[int, int, int]:
        """Executable dedup key: layers sharing (T, M, N) share an HLO."""
        return (self.tiles, self.cin, self.cout)


@dataclasses.dataclass(frozen=True)
class GraphOp:
    """One activation-DAG node (mirrors Rust ``model::GraphOp``).

    Tensor ids index the value stream: id 0 is the network input, node ``i``
    produces tensor ``i + 1``. ``op`` is one of ``conv`` (fields ``conv``,
    ``input``), ``add`` or ``concat`` (fields ``a``, ``b``).
    """
    op: str
    conv: int = 0
    input: int = 0
    a: int = 0
    b: int = 0

    def to_json(self) -> Dict[str, object]:
        if self.op == "conv":
            return {"op": "conv", "conv": self.conv, "input": self.input}
        if self.op in ("add", "concat"):
            return {"op": self.op, "a": self.a, "b": self.b}
        raise ValueError(f"unknown graph op {self.op!r}")


@dataclasses.dataclass(frozen=True)
class Variant:
    name: str
    input_hw: int
    input_c: int
    layers: Tuple[ConvLayer, ...]
    fc: Tuple[int, ...]   # FC widths after flatten (Rust-side)
    # activation DAG over `layers`; empty means the straight chain, and the
    # manifest then omits the field (pre-graph schema, exact round-trip)
    graph: Tuple[GraphOp, ...] = ()

    def unique_shapes(self) -> List[Tuple[int, int, int]]:
        seen, out = set(), []
        for l in self.layers:
            k = l.shape_key()
            if k not in seen:
                seen.add(k)
                out.append(k)
        return out


def _vgg16_convs(h0: int) -> Tuple[ConvLayer, ...]:
    """The 13 VGG16 conv layers with the 5 pool boundaries, at input side h0."""
    plan = [  # (block, n_convs, cout)
        (1, 2, 64), (2, 2, 128), (3, 3, 256), (4, 3, 512), (5, 3, 512),
    ]
    layers: List[ConvLayer] = []
    h, cin = h0, 3
    for blk, reps, cout in plan:
        for i in range(reps):
            layers.append(ConvLayer(
                name=f"conv{blk}_{i + 1}",
                cin=cin, cout=cout, h=h,
                pool_after=(i == reps - 1),
            ))
            cin = cout
        h //= 2
    return tuple(layers)


def _resnet18() -> Variant:
    """ResNet-18-shaped residual variant at CIFAR scale (mirrors Rust
    ``Network::resnet18``): widths /4, 32x32 input, pooled transition convs
    between stages (the spectral layers have no stride), 2 basic blocks
    (conv, conv, add) per stage."""
    widths = [16, 32, 64, 128]
    layers: List[ConvLayer] = []
    graph: List[GraphOp] = []
    h, cin, cur = 32, 3, 0

    def push_conv(name: str, cin: int, cout: int, h: int, pool: bool) -> None:
        nonlocal cur
        layers.append(ConvLayer(name, cin, cout, h, pool_after=pool))
        graph.append(GraphOp("conv", conv=len(layers) - 1, input=cur))
        cur = len(graph)

    push_conv("conv1", cin, widths[0], h, pool=False)
    cin = widths[0]
    for si, w in enumerate(widths):
        stage = si + 1
        if si > 0:
            push_conv(f"down{stage}", cin, w, h, pool=True)
            cin = w
            h //= 2
        for blk in (1, 2):
            shortcut = cur
            push_conv(f"conv{stage}_{blk}a", w, w, h, pool=False)
            push_conv(f"conv{stage}_{blk}b", w, w, h, pool=False)
            graph.append(GraphOp("add", a=shortcut, b=cur))
            cur = len(graph)
    return Variant(
        name="resnet18", input_hw=32, input_c=3,
        layers=tuple(layers), fc=(64, 10), graph=tuple(graph),
    )


def variants() -> Dict[str, Variant]:
    """All AOT model variants (see DESIGN.md 'Artifact variants')."""
    return {
        "demo": Variant(
            name="demo", input_hw=16, input_c=1,
            layers=(
                ConvLayer("conv1", 1, 8, 16, pool_after=True),
                ConvLayer("conv2", 8, 8, 8, pool_after=True),
            ),
            fc=(32, 10),
        ),
        "demo-residual": Variant(
            name="demo-residual", input_hw=16, input_c=1,
            layers=(
                ConvLayer("conv1", 1, 8, 16, pool_after=False),
                ConvLayer("conv2", 8, 8, 16, pool_after=False),
                ConvLayer("conv3", 8, 8, 16, pool_after=False),
                ConvLayer("conv4", 16, 8, 16, pool_after=True),
            ),
            fc=(32, 10),
            # t1 conv1 → t2 conv2 → t3 add(t1,t2) → t4 conv3
            #   → t5 concat(t3,t4) → t6 conv4+pool
            graph=(
                GraphOp("conv", conv=0, input=0),
                GraphOp("conv", conv=1, input=1),
                GraphOp("add", a=1, b=2),
                GraphOp("conv", conv=2, input=3),
                GraphOp("concat", a=3, b=4),
                GraphOp("conv", conv=3, input=5),
            ),
        ),
        "resnet18": _resnet18(),
        "vgg16-cifar": Variant(
            name="vgg16-cifar", input_hw=32, input_c=3,
            layers=_vgg16_convs(32),
            fc=(256, 10),
        ),
        "vgg16-224": Variant(
            name="vgg16-224", input_hw=224, input_c=3,
            layers=_vgg16_convs(224),
            fc=(4096, 4096, 1000),
        ),
    }
