"""Pure-jnp oracles for the spectral pipeline.

Everything here is reference-grade and deliberately naive; pytest checks the
Pallas kernel and the full AOT'd layer function against these.  The tiling /
overlap-and-add helpers are also the executable specification that the Rust
coordinator's ``fft::{im2tiles, overlap_add}`` mirrors exactly.

Conventions (match DESIGN.md and rust/src/fft/):
  * CNN convolution is cross-correlation with 'SAME' zero padding
    (pad = (k-1)/2, stride 1).
  * OaA tile size  h' = K - k + 1  (paper: K=8, k=3 → h'=6).
  * Spectral kernel  W~[n,m] = FFT2( zeropad_K( flip2(W[n,m]) ) );
    flipping turns cross-correlation into linear convolution.
  * Output tile = Re( IFFT2( FFT2(tile) ∘ W~ ) )  — the K-point circular
    convolution equals the (h'+k-1)-point linear convolution exactly.
  * Full-conv accumulation buffer has side  Hp + k - 1  (Hp = H padded up to
    a multiple of h'); the 'SAME' output is the crop starting at
    offset = k - 1 - pad.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "hadamard_ref",
    "conv2d_same_ref",
    "spectral_kernels",
    "im2tiles",
    "overlap_add",
    "spectral_conv_ref",
    "tiles_per_side",
]


def hadamard_ref(xr, xi, wr, wi):
    """Oracle for kernels.spectral_hadamard: einsum complex matmul.

    xr/xi: [F, T, M]; wr/wi: [F, M, N] → (yr, yi): [F, T, N].
    """
    x = xr + 1j * xi
    w = wr + 1j * wi
    y = jnp.einsum("ftm,fmn->ftn", x, w)
    return jnp.real(y).astype(jnp.float32), jnp.imag(y).astype(jnp.float32)


def conv2d_same_ref(x, w):
    """Spatial ground truth: 'SAME' cross-correlation.

    x: [M, H, W]; w: [N, M, k, k] → [N, H, W].
    """
    out = jax.lax.conv_general_dilated(
        x[None],  # [1, M, H, W]
        w,
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return out[0]


def spectral_kernels(w, fft_size: int):
    """Spatial [N, M, k, k] → spectral planes ([N,M,K,K], [N,M,K,K]).

    Flip (cross-correlation → convolution), zero-pad to K, FFT2.
    """
    wf = jnp.flip(w, axis=(-2, -1))
    n, m, k, _ = w.shape
    pad = fft_size - k
    wp = jnp.pad(wf, ((0, 0), (0, 0), (0, pad), (0, pad)))
    ws = jnp.fft.fft2(wp)
    return (jnp.real(ws).astype(jnp.float32),
            jnp.imag(ws).astype(jnp.float32))


def tiles_per_side(h: int, tile: int) -> int:
    """ceil(h / tile) — number of OaA tiles along one spatial dimension."""
    return -(-h // tile)


def im2tiles(x, tile: int, fft_size: int):
    """Partition [M, H, W] into zero-padded K x K tiles: [T, M, K, K].

    Tiles are laid out row-major over the (ty, tx) grid; the input is
    zero-padded up to a multiple of ``tile`` first.  T = tiles_per_side(H)
    * tiles_per_side(W).
    """
    m, h, w = x.shape
    th, tw = tiles_per_side(h, tile), tiles_per_side(w, tile)
    xp = jnp.pad(x, ((0, 0), (0, th * tile - h), (0, tw * tile - w)))
    # [M, th, tile, tw, tile] -> [th, tw, M, tile, tile]
    xt = xp.reshape(m, th, tile, tw, tile).transpose(1, 3, 0, 2, 4)
    xt = xt.reshape(th * tw, m, tile, tile)
    pad = fft_size - tile
    return jnp.pad(xt, ((0, 0), (0, 0), (0, pad), (0, pad)))


def overlap_add(tiles, h: int, w: int, tile: int, k: int, pad: int):
    """Overlap-add output tiles [T, N, K, K] back to 'SAME' output [N, H, W].

    Each tile holds the full linear convolution (length tile + k - 1 = K) of
    its input tile; tiles are added at stride ``tile`` and the result is
    cropped at offset ``k - 1 - pad``.
    """
    t, n, kk, _ = tiles.shape
    th, tw = tiles_per_side(h, tile), tiles_per_side(w, tile)
    full = np.zeros((n, th * tile + k - 1, tw * tile + k - 1), np.float32)
    tiles = np.asarray(tiles)
    for ty in range(th):
        for tx in range(tw):
            tl = tiles[ty * tw + tx]
            full[:, ty * tile:ty * tile + kk, tx * tile:tx * tile + kk] += tl
    off = k - 1 - pad
    return jnp.asarray(full[:, off:off + h, off:off + w])


def spectral_conv_ref(x, w, fft_size: int = 8):
    """End-to-end spectral 'SAME' conv oracle (pure jnp + python OaA).

    x: [M, H, W]; w: [N, M, k, k] → [N, H, W].  Must equal conv2d_same_ref
    up to fp error; pytest asserts this, proving the OaA geometry.
    """
    n, m, k, _ = w.shape
    pad = (k - 1) // 2
    tile = fft_size - k + 1
    _, h, wdt = x.shape
    tiles = im2tiles(x, tile, fft_size)
    xs = jnp.fft.fft2(tiles)  # [T, M, K, K] complex
    wr, wi = spectral_kernels(w, fft_size)
    ws = wr + 1j * wi
    ys = jnp.einsum("tmij,nmij->tnij", xs, ws)
    out_tiles = jnp.real(jnp.fft.ifft2(ys)).astype(jnp.float32)
    return overlap_add(out_tiles, h, wdt, tile, k, pad)
