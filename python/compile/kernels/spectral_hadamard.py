"""L1 Pallas kernel: frequency-batched complex Hadamard-accumulate.

The paper's compute hot spot (Eq 3) is, per spectral frequency point f:

    Y[t, n, f] = sum_m  X[t, m, f] * W[n, m, f]        (complex)

i.e. for each of the F = K*K frequency points, a dense complex matmul
[T x M] @ [M x N] over input channels M.  The FPGA realizes this as an
N' x P' array of complex MACs fed from BRAM replicas; on TPU the natural
mapping is the MXU: we grid over frequency points and issue real matmuls
per grid step (see DESIGN.md "Hardware-Adaptation").

Complex numbers cross the kernel boundary as separate real/imag f32
planes (the AOT interchange keeps all boundary buffers real-typed).

Two complex-product decompositions are provided:

  * ``mxu4``      — 4 real matmuls (xr@wr - xi@wi, xr@wi + xi@wr).
  * ``karatsuba`` — 3 real matmuls (m1 = xr@wr, m2 = xi@wi,
                    m3 = (xr+xi)@(wr+wi); yr = m1-m2, yi = m3-m1-m2).
                    Trades one MXU pass for two VPU adds; the better
                    choice is measured in the §Perf pass.

Pallas runs with ``interpret=True`` — the CPU PJRT plugin cannot execute
Mosaic custom-calls; interpret mode lowers the kernel to plain HLO so the
same artifact runs anywhere.  Block shapes are still chosen as they would
be for a real TPU lowering (one frequency slab resident in VMEM).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["spectral_hadamard", "MODES"]

MODES = ("mxu4", "karatsuba", "batched", "batched_karatsuba")


def _kernel_mxu4(xr_ref, xi_ref, wr_ref, wi_ref, yr_ref, yi_ref):
    """One grid step = one frequency point: complex [T,M] @ [M,N]."""
    xr = xr_ref[0]
    xi = xi_ref[0]
    wr = wr_ref[0]
    wi = wi_ref[0]
    dot = functools.partial(jnp.dot, preferred_element_type=jnp.float32)
    yr_ref[0] = dot(xr, wr) - dot(xi, wi)
    yi_ref[0] = dot(xr, wi) + dot(xi, wr)


def _kernel_karatsuba(xr_ref, xi_ref, wr_ref, wi_ref, yr_ref, yi_ref):
    """3-matmul complex product (Karatsuba); fewer MXU passes."""
    xr = xr_ref[0]
    xi = xi_ref[0]
    wr = wr_ref[0]
    wi = wi_ref[0]
    dot = functools.partial(jnp.dot, preferred_element_type=jnp.float32)
    m1 = dot(xr, wr)
    m2 = dot(xi, wi)
    m3 = dot(xr + xi, wr + wi)
    yr_ref[0] = m1 - m2
    yi_ref[0] = m3 - m1 - m2


_KERNELS = {"mxu4": _kernel_mxu4, "karatsuba": _kernel_karatsuba}

# Frequency-batched dot_general: contract over M with F as a batch dim.
_BATCH_DN = (((2,), (1,)), ((0,), (0,)))


def _kernel_batched(xr_ref, xi_ref, wr_ref, wi_ref, yr_ref, yi_ref):
    """Single grid step: one batched complex matmul over all F points.

    §Perf (EXPERIMENTS.md): under interpret=True on CPU-PJRT, the per-
    frequency grid loop costs ~40× more than one batched dot_general (loop
    overhead + per-step output copies dominate the tiny [T,M]@[M,N]
    matmuls). This variant is the AOT default; the grid variants above
    express the per-frequency-slab VMEM schedule a real TPU lowering would
    use and pin the numerics (tests assert all modes agree).
    """
    dot = functools.partial(
        jax.lax.dot_general,
        dimension_numbers=_BATCH_DN,
        preferred_element_type=jnp.float32,
    )
    xr = xr_ref[...]
    xi = xi_ref[...]
    wr = wr_ref[...]
    wi = wi_ref[...]
    yr_ref[...] = dot(xr, wr) - dot(xi, wi)
    yi_ref[...] = dot(xr, wi) + dot(xi, wr)


def _kernel_batched_karatsuba(xr_ref, xi_ref, wr_ref, wi_ref, yr_ref, yi_ref):
    """Batched 3-matmul complex product."""
    dot = functools.partial(
        jax.lax.dot_general,
        dimension_numbers=_BATCH_DN,
        preferred_element_type=jnp.float32,
    )
    xr = xr_ref[...]
    xi = xi_ref[...]
    wr = wr_ref[...]
    wi = wi_ref[...]
    m1 = dot(xr, wr)
    m2 = dot(xi, wi)
    m3 = dot(xr + xi, wr + wi)
    yr_ref[...] = m1 - m2
    yi_ref[...] = m3 - m1 - m2


_BATCHED_KERNELS = {
    "batched": _kernel_batched,
    "batched_karatsuba": _kernel_batched_karatsuba,
}


def spectral_hadamard(xr, xi, wr, wi, *, mode: str = "mxu4",
                      interpret: bool = True):
    """Complex Hadamard-accumulate over input channels, batched by frequency.

    Args:
      xr, xi: ``[F, T, M]`` f32 — real/imag planes of the FFT'd input tiles,
        frequency-major (F = K*K frequency points, T tiles, M input channels).
      wr, wi: ``[F, M, N]`` f32 — real/imag planes of the spectral kernels
        (N output channels).  Pruned kernels carry explicit zeros; sparsity
        *scheduling* is a coordinator concern (cycle counts), not a numerics
        one.
      mode: complex-product decomposition, one of ``MODES``.
      interpret: must remain True for CPU-PJRT execution (see module doc).

    Returns:
      ``(yr, yi)``: ``[F, T, N]`` f32 planes of the spectral output tiles.
    """
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    f, t, m = xr.shape
    fw, mw, n = wr.shape
    if xr.shape != xi.shape or wr.shape != wi.shape:
        raise ValueError("real/imag plane shapes must match")
    if fw != f or mw != m:
        raise ValueError(
            f"kernel planes [F={fw},M={mw},N={n}] incompatible with "
            f"input planes [F={f},T={t},M={m}]")

    if mode in _BATCHED_KERNELS:
        out_shape = [
            jax.ShapeDtypeStruct((f, t, n), jnp.float32),
            jax.ShapeDtypeStruct((f, t, n), jnp.float32),
        ]
        yr, yi = pl.pallas_call(
            _BATCHED_KERNELS[mode],
            out_shape=out_shape,
            interpret=interpret,
        )(xr, xi, wr, wi)
        return yr, yi

    grid = (f,)
    x_spec = pl.BlockSpec((1, t, m), lambda i: (i, 0, 0))
    w_spec = pl.BlockSpec((1, m, n), lambda i: (i, 0, 0))
    y_spec = pl.BlockSpec((1, t, n), lambda i: (i, 0, 0))
    out_shape = [
        jax.ShapeDtypeStruct((f, t, n), jnp.float32),
        jax.ShapeDtypeStruct((f, t, n), jnp.float32),
    ]
    yr, yi = pl.pallas_call(
        _KERNELS[mode],
        grid=grid,
        in_specs=[x_spec, x_spec, w_spec, w_spec],
        out_specs=[y_spec, y_spec],
        out_shape=out_shape,
        interpret=interpret,
    )(xr, xi, wr, wi)
    return yr, yi


def vmem_bytes(t: int, m: int, n: int) -> int:
    """Estimated VMEM working set of one grid step (f32 words).

    One frequency slab: 2x[T,M] inputs + 2x[M,N] weights + 2x[T,N] outputs.
    Used by the DESIGN.md §Perf roofline estimate — interpret-mode wallclock
    is *not* a TPU proxy, the structural footprint is what we optimize.
    """
    return 4 * (2 * t * m + 2 * m * n + 2 * t * n)
