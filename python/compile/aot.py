"""AOT pipeline: lower every distinct layer shape of every model variant to
XLA HLO **text** and write artifacts/manifest.json for the Rust runtime.

HLO text — NOT ``lowered.compile().serialize()`` and NOT the proto bytes —
is the interchange format: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which the image's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).  Lowered with ``return_tuple=True``
so the Rust side unwraps with ``to_tuple1()``.

Run via ``make artifacts`` (a no-op when inputs are unchanged):

    cd python && python -m compile.aot --out ../artifacts
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

import jax
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange).

    ``print_large_constants=True`` is load-bearing: the default printer
    elides big constants as ``constant({...})``, which the consuming XLA
    0.5.1 text parser silently reads back as *zeros* — the DFT matrices
    (64 floats each) vanish and every output becomes 0. Cost: ~4 KB per
    artifact.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    text = comp.as_hlo_text(print_large_constants=True)
    assert "constant({...})" not in text, "elided constant survived printing"
    return text


def shape_file(t: int, m: int, n: int, k: int) -> str:
    return f"conv_t{t}_c{m}x{n}_k{k}.hlo.txt"


def lower_shape(t: int, m: int, n: int, k: int, mode: str) -> str:
    fn, args = M.layer_fn(t, m, n, k, mode=mode)
    return to_hlo_text(jax.jit(fn).lower(*args))


def build(out_dir: str, mode: str, only=None, verbose: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "format": "hlo-text-v1",
        "fft_size": M.FFT_SIZE,
        "kernel_k": M.KERNEL_K,
        "tile": M.TILE,
        "hadamard_mode": mode,
        "word_bytes": 2,  # paper's 16-bit fixed point for the bandwidth model
        # compression ratio the artifacts are built for: the AOT graphs are
        # dense (explicit zeros), so record 1; the Rust serving CLI treats
        # this as the --alpha default (0 sentinel = "manifest default")
        "alpha": 1,
        "variants": {},
        "executables": {},
    }
    lowered_shapes = {}
    for name, var in M.variants().items():
        if only and name not in only:
            continue
        vman = {
            "input_hw": var.input_hw,
            "input_c": var.input_c,
            "fc": list(var.fc),
            "layers": [],
        }
        if var.graph:  # omitted for chain variants: pre-graph schema
            vman["graph"] = [g.to_json() for g in var.graph]
        for lyr in var.layers:
            key = lyr.shape_key()
            fname = shape_file(*key, M.FFT_SIZE)
            if key not in lowered_shapes:
                t0 = time.time()
                text = lower_shape(*key, M.FFT_SIZE, mode)
                path = os.path.join(out_dir, fname)
                with open(path, "w") as f:
                    f.write(text)
                lowered_shapes[key] = fname
                manifest["executables"][fname] = {
                    "tiles": key[0], "cin": key[1], "cout": key[2],
                    "fft_size": M.FFT_SIZE,
                    "sha256": hashlib.sha256(text.encode()).hexdigest(),
                    "bytes": len(text),
                }
                if verbose:
                    print(f"  lowered {fname:34s} "
                          f"({len(text) / 1e6:.2f} MB, {time.time() - t0:.1f}s)",
                          file=sys.stderr)
            vman["layers"].append({
                "name": lyr.name,
                "cin": lyr.cin, "cout": lyr.cout, "h": lyr.h,
                "tiles": lyr.tiles, "pool_after": lyr.pool_after,
                "file": lowered_shapes[key],
            })
        manifest["variants"][name] = vman

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--mode", default="batched",
                    choices=("mxu4", "karatsuba", "batched", "batched_karatsuba"))
    ap.add_argument("--only", nargs="*", default=None,
                    help="restrict to named variants (default: all)")
    args = ap.parse_args()
    t0 = time.time()
    man = build(args.out, args.mode, args.only)
    n = len(man["executables"])
    print(f"wrote {n} executables + manifest.json to {args.out} "
          f"in {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
