"""AOT pipeline: HLO text emission + manifest schema (demo variant only —
keeps pytest fast; the full build is exercised by ``make artifacts``)."""

import json
import os

import pytest

# optional deps: the AOT pipeline traces through JAX. Skip (not fail) when
# the environment doesn't carry them — CI installs them best-effort.
pytest.importorskip("numpy", reason="optional dep: numpy")
pytest.importorskip("jax", reason="optional dep: jax (AOT pipeline)")

from compile import aot, model as M


@pytest.fixture(scope="module")
def demo_build(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    man = aot.build(str(out), mode="mxu4", only=["demo"], verbose=False)
    return str(out), man


def test_manifest_schema(demo_build):
    out, man = demo_build
    assert man["format"] == "hlo-text-v1"
    assert man["fft_size"] == M.FFT_SIZE and man["tile"] == M.TILE
    assert "demo" in man["variants"]
    demo = man["variants"]["demo"]
    assert demo["input_hw"] == 16 and demo["fc"] == [32, 10]
    assert [l["name"] for l in demo["layers"]] == ["conv1", "conv2"]
    # every referenced file exists and is registered
    for lyr in demo["layers"]:
        assert lyr["file"] in man["executables"]
        assert os.path.exists(os.path.join(out, lyr["file"]))


def test_hlo_text_shape(demo_build):
    out, man = demo_build
    lyr = man["variants"]["demo"]["layers"][0]
    text = open(os.path.join(out, lyr["file"])).read()
    # DFT runs as DFT-matrix matmuls (§Perf L2), so the module contains dot
    # ops and no fft custom-call
    assert "ENTRY" in text and "dot(" in text
    # three f32 params: tiles [T,M,K,K]; w planes frequency-major [F,M,N]
    t, m, n, k = lyr["tiles"], lyr["cin"], lyr["cout"], man["fft_size"]
    assert f"f32[{t},{m},{k},{k}]" in text
    assert f"f32[{k * k},{m},{n}]" in text


def test_manifest_json_roundtrip(demo_build):
    out, _ = demo_build
    man = json.load(open(os.path.join(out, "manifest.json")))
    for fname, meta in man["executables"].items():
        assert meta["bytes"] > 0 and len(meta["sha256"]) == 64


def test_shape_dedup(demo_build):
    _, man = demo_build
    # demo has 2 distinct shapes → exactly 2 executables
    assert len(man["executables"]) == 2
