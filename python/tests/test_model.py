"""L2 correctness: the spectral conv layer (tiling + FFT + Pallas Hadamard +
IFFT + OaA) equals spatial 'SAME' convolution, and the variant registry is
self-consistent with the Rust coordinator's expectations.
"""

import pytest

# optional deps — skip the module (not fail) when absent
pytest.importorskip("numpy", reason="optional dep: numpy")
pytest.importorskip("hypothesis", reason="optional dep: hypothesis")
pytest.importorskip("jax", reason="optional dep: jax")

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import model as M
from compile.kernels import ref


def _rand(shape, seed):
    return np.random.default_rng(seed).standard_normal(shape, dtype=np.float32)


def _spectral_same_conv(x, w, mode="mxu4"):
    """Full pipeline as the Rust coordinator drives it: ref tiling → the
    jittable layer fn (the thing that gets AOT'd) → ref overlap-add."""
    n, m, k, _ = w.shape
    pad = (k - 1) // 2
    _, h, wdt = x.shape
    tiles = ref.im2tiles(x, M.TILE, M.FFT_SIZE)
    wr, wi = ref.spectral_kernels(w, M.FFT_SIZE)
    (out_tiles,) = M.spectral_conv_tiles(
        jnp.asarray(tiles), M.to_freq_major(wr), M.to_freq_major(wi), mode=mode)
    return ref.overlap_add(np.asarray(out_tiles), h, wdt, M.TILE, k, pad)


@pytest.mark.parametrize("mode", ("mxu4", "karatsuba"))
def test_layer_matches_spatial_conv(mode):
    x = _rand((4, 12, 12), 0)
    w = _rand((6, 4, 3, 3), 1) * 0.2
    got = _spectral_same_conv(x, w, mode)
    want = ref.conv2d_same_ref(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_ref_pipeline_matches_spatial_conv():
    """The pure-jnp spectral oracle itself is validated against lax.conv."""
    x = _rand((3, 14, 14), 2)
    w = _rand((5, 3, 3, 3), 3) * 0.2
    got = ref.spectral_conv_ref(x, w, fft_size=8)
    want = ref.conv2d_same_ref(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(
    h=st.integers(4, 20),
    m=st.integers(1, 6),
    n=st.integers(1, 6),
    seed=st.integers(0, 2**16),
)
def test_hypothesis_layer_sizes(h, m, n, seed):
    """Sweep odd sizes incl. non-multiples of the tile (edge padding path)."""
    x = _rand((m, h, h), seed)
    w = _rand((n, m, 3, 3), seed + 1) * 0.3
    got = _spectral_same_conv(x, w)
    want = ref.conv2d_same_ref(x, w)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_tile_geometry_paper_points():
    """Paper geometry: K=8, k=3 → h'=6; VGG16-224 tile counts per layer."""
    assert M.TILE == 6 and M.FFT_SIZE == 8
    sides = {224: 38, 112: 19, 56: 10, 28: 5, 14: 3}
    for h, s in sides.items():
        assert M.tiles_per_side(h) == s


def test_vgg16_variant_structure():
    v = M.variants()["vgg16-224"]
    assert len(v.layers) == 13
    assert v.layers[0].name == "conv1_1" and v.layers[0].cin == 3
    assert v.layers[-1].name == "conv5_3" and v.layers[-1].cout == 512
    assert sum(l.pool_after for l in v.layers) == 5
    # distinct executables for the 224 variant: 9 shapes
    assert len(v.unique_shapes()) == 9
    # spatial sides halve at pool boundaries
    hs = [l.h for l in v.layers]
    assert hs == [224, 224, 112, 112, 56, 56, 56, 28, 28, 28, 14, 14, 14]


def test_cifar_variant_structure():
    v = M.variants()["vgg16-cifar"]
    assert len(v.layers) == 13
    hs = [l.h for l in v.layers]
    assert hs == [32, 32, 16, 16, 8, 8, 8, 4, 4, 4, 2, 2, 2]
    # conv5 at h=2 and conv4_2/3 at h=4 share T=1,512,512 → dedup works
    assert (1, 512, 512) in v.unique_shapes()


def test_residual_variant_graphs():
    """Graph presets mirror rust/src/model/mod.rs exactly."""
    vs = M.variants()
    r = vs["resnet18"]
    assert len(r.layers) == 20
    assert len(r.graph) == 28  # 20 convs + 8 residual adds
    assert sum(1 for g in r.graph if g.op == "add") == 8
    assert [l.h for l in r.layers][:6] == [32, 32, 32, 32, 32, 32]
    d = vs["demo-residual"]
    assert any(g.op == "concat" for g in d.graph)
    assert d.layers[-1].cin == 16  # consumes the concat
    # chain variants stay graph-less so their manifests keep the old schema
    assert vs["demo"].graph == () and vs["vgg16-224"].graph == ()
    # every node's json form round-trips through the schema's field names
    for g in r.graph + d.graph:
        j = g.to_json()
        assert j["op"] in ("conv", "add", "concat")


def test_flatten_dims_consistent():
    """Post-pool flatten width feeds the Rust FC layers."""
    for name, v in M.variants().items():
        h = v.input_hw
        for l in v.layers:
            assert l.h == h
            if l.pool_after:
                h //= 2
        flat = v.layers[-1].cout * h * h
        assert flat > 0, name
