"""L1 correctness: Pallas spectral_hadamard vs the pure-jnp oracle.

Hypothesis sweeps shapes; fixed cases pin the paper's operating points
(K=8 → F=64, VGG channel widths).
"""

import pytest

# optional deps — skip the module (not fail) when absent
pytest.importorskip("numpy", reason="optional dep: numpy")
pytest.importorskip("hypothesis", reason="optional dep: hypothesis")
pytest.importorskip("jax", reason="optional dep: jax")

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.ref import hadamard_ref
from compile.kernels.spectral_hadamard import spectral_hadamard, vmem_bytes, MODES


def _rand(shape, seed):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(shape, dtype=np.float32)


def _run_case(f, t, m, n, mode, seed=0):
    xr, xi = _rand((f, t, m), seed), _rand((f, t, m), seed + 1)
    wr, wi = _rand((f, m, n), seed + 2), _rand((f, m, n), seed + 3)
    yr, yi = spectral_hadamard(xr, xi, wr, wi, mode=mode)
    er, ei = hadamard_ref(xr, xi, wr, wi)
    np.testing.assert_allclose(yr, er, rtol=1e-4, atol=1e-4 * m)
    np.testing.assert_allclose(yi, ei, rtol=1e-4, atol=1e-4 * m)
    assert yr.dtype == jnp.float32 and yi.dtype == jnp.float32


@pytest.mark.parametrize("mode", MODES)
def test_paper_operating_point(mode):
    """F=64 (K=8), a VGG-ish channel slice."""
    _run_case(64, 9, 16, 32, mode)


@pytest.mark.parametrize("mode", MODES)
def test_single_everything(mode):
    _run_case(1, 1, 1, 1, mode)


@settings(max_examples=40, deadline=None)
@given(
    f=st.sampled_from([1, 4, 16, 64]),
    t=st.integers(1, 8),
    m=st.integers(1, 24),
    n=st.integers(1, 24),
    mode=st.sampled_from(MODES),
    seed=st.integers(0, 2**16),
)
def test_hypothesis_shape_sweep(f, t, m, n, mode, seed):
    _run_case(f, t, m, n, mode, seed)


def test_pruned_kernels_zero_channels():
    """Explicit zeros in the kernel planes behave exactly as pruning."""
    f, t, m, n = 16, 3, 8, 8
    xr, xi = _rand((f, t, m), 0), _rand((f, t, m), 1)
    wr, wi = _rand((f, m, n), 2), _rand((f, m, n), 3)
    mask = (np.random.default_rng(4).random((f, m, n)) < 0.25).astype(np.float32)
    yr, yi = spectral_hadamard(xr, xi, wr * mask, wi * mask)
    er, ei = hadamard_ref(xr, xi, wr * mask, wi * mask)
    np.testing.assert_allclose(yr, er, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(yi, ei, rtol=1e-4, atol=1e-3)


def test_modes_agree():
    """mxu4 and karatsuba are algebraically identical."""
    f, t, m, n = 64, 4, 12, 12
    xr, xi = _rand((f, t, m), 10), _rand((f, t, m), 11)
    wr, wi = _rand((f, m, n), 12), _rand((f, m, n), 13)
    y1 = spectral_hadamard(xr, xi, wr, wi, mode="mxu4")
    y2 = spectral_hadamard(xr, xi, wr, wi, mode="karatsuba")
    np.testing.assert_allclose(y1[0], y2[0], rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(y1[1], y2[1], rtol=1e-4, atol=1e-3)


def test_bad_shapes_rejected():
    x = np.zeros((4, 2, 3), np.float32)
    w = np.zeros((4, 5, 2), np.float32)  # M mismatch (5 != 3)
    with pytest.raises(ValueError):
        spectral_hadamard(x, x, w, w)
    with pytest.raises(ValueError):
        spectral_hadamard(x, x, np.zeros((4, 3, 2), np.float32),
                          np.zeros((4, 3, 2), np.float32), mode="nope")


def test_linearity():
    """Hadamard is linear in X: f(aX) == a f(X)."""
    f, t, m, n = 16, 2, 4, 4
    xr, xi = _rand((f, t, m), 20), _rand((f, t, m), 21)
    wr, wi = _rand((f, m, n), 22), _rand((f, m, n), 23)
    y1 = spectral_hadamard(2.0 * xr, 2.0 * xi, wr, wi)
    y2 = spectral_hadamard(xr, xi, wr, wi)
    np.testing.assert_allclose(y1[0], 2.0 * np.asarray(y2[0]), rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(y1[1], 2.0 * np.asarray(y2[1]), rtol=1e-4, atol=1e-3)


def test_vmem_estimate_paper_point():
    """Structural VMEM footprint at the paper's conv4/5 shape fits VMEM."""
    assert vmem_bytes(t=25, m=512, n=512) < 16 * 2**20
