//! Adversarial and fuzz coverage: inputs chosen to break the invariants
//! that the happy-path tests take for granted — scheduler edge patterns,
//! FSM configuration fuzz, and parser robustness.

use spectral_flow::schedule::{Schedule, Scheduler};
use spectral_flow::sim::controller::{Controller, LoopConfig, State};
use spectral_flow::util::check::forall;
use spectral_flow::util::json::Json;
use spectral_flow::util::rng::Pcg32;

// ---------------- scheduler: adversarial patterns --------------------------

#[test]
fn scheduler_all_kernels_identical() {
    // Degenerate overlap: one index node covers everyone each cycle.
    let kernels = vec![vec![0u16, 7, 13, 42]; 64];
    for sch in [Scheduler::ExactCover, Scheduler::LowestIndexFirst] {
        let s = sch.run(&kernels, 1, 0);
        s.validate(&kernels).unwrap();
        assert_eq!(s.cycles(), 4, "{sch:?}");
        assert!((s.pe_utilization() - 1.0).abs() < 1e-12);
    }
    // The random baseline does NOT synchronize identical kernels — each
    // picks an independent random index, so with r=1 most kernels idle
    // every cycle. That asymmetry is exactly what Fig. 8 plots.
    let s = Scheduler::Random.run(&kernels, 1, 0);
    s.validate(&kernels).unwrap();
    assert!(s.cycles() > 4);
}

#[test]
fn scheduler_fully_disjoint_kernels() {
    // Zero overlap: utilization is capped by r/N' exactly.
    let n = 16usize;
    let nnz = 4usize;
    let kernels: Vec<Vec<u16>> = (0..n)
        .map(|k| (0..nnz).map(|j| (k * nnz + j) as u16).collect())
        .collect();
    for r in [1usize, 2, 4, 8] {
        let s = Scheduler::ExactCover.run(&kernels, r, 0);
        s.validate(&kernels).unwrap();
        // total edges = n·nnz; each cycle serves ≤ r kernels (disjoint ⇒
        // one kernel per distinct index)
        assert!(s.cycles() >= (n * nnz).div_ceil(r));
        assert!(s.pe_utilization() <= r as f64 / n as f64 + 1e-9);
    }
}

#[test]
fn scheduler_power_law_hub_index() {
    // One hub index shared by all kernels + unique tails: the hub must not
    // be wasted early (Alg 2's "leave high-degree nodes untouched").
    let n = 32usize;
    let mut kernels: Vec<Vec<u16>> = (0..n)
        .map(|k| {
            let mut v = vec![0u16]; // hub
            v.push((k + 1) as u16);
            v.push((k + 100) as u16);
            v.sort_unstable();
            v
        })
        .collect();
    kernels.sort();
    let s = Scheduler::ExactCover.run(&kernels, 4, 0);
    s.validate(&kernels).unwrap();
    // lower bound: 3 nnz per kernel, ≤ 4 distinct indices/cycle; tails are
    // unique so tail edges = 2n need ≥ 2n/4 cycles... but the hub cycle can
    // serve all. A good schedule stays close to 2n/3-ish; a bad one that
    // burns the hub early approaches 3n/4 cycles. Bound generously:
    assert!(
        s.cycles() <= 2 * n / 3 + 6,
        "hub wasted: {} cycles for {} kernels",
        s.cycles(),
        n
    );
}

#[test]
fn scheduler_ragged_nnz_mix() {
    forall("ragged nnz mix", 30, |rng| {
        // kernels with wildly different nnz (1..=32) — lower bound is the
        // max nnz; validation must still hold.
        let n = rng.range(2, 48);
        let kernels: Vec<Vec<u16>> = (0..n)
            .map(|_| {
                let nnz = rng.range(1, 33);
                let mut v: Vec<u16> =
                    rng.sample_indices(64, nnz).into_iter().map(|i| i as u16).collect();
                v.sort_unstable();
                v
            })
            .collect();
        let r = rng.range(1, 12);
        let s = Scheduler::ExactCover.run(&kernels, r, 0);
        s.validate(&kernels).unwrap();
        assert!(s.cycles() >= Schedule::lower_bound(&kernels, r));
    });
}

// ---------------- controller: configuration fuzz ---------------------------

#[test]
fn controller_fuzz_invariants() {
    forall("controller fuzz", 60, |rng| {
        let cfg = LoopConfig {
            n: rng.range(1, 40),
            p: rng.range(1, 40),
            m: rng.range(1, 10),
            ns: rng.range(1, 44),
            ps: rng.range(1, 44),
            p_par: rng.range(1, 8),
            n_par: rng.range(1, 8),
        };
        let mut ctl = Controller::new(cfg);
        let mut phases = Vec::new();
        while let Some(p) = ctl.next_phase() {
            phases.push(p);
            assert!(phases.len() < 2_000_000, "FSM diverged: {cfg:?}");
        }
        // every output tile (n, p) written exactly once
        let written: usize = phases
            .iter()
            .filter(|p| p.state == State::WriteOut)
            .map(|p| p.tiles * p.kernels)
            .sum();
        assert_eq!(written, cfg.n * cfg.p, "{cfg:?}");
        // ProcConv parallelism bounds respected
        for p in phases.iter().filter(|p| p.state == State::ProcConv) {
            assert!(p.kernels >= 1 && p.kernels <= cfg.n_par, "{cfg:?}");
            assert!(p.tiles >= 1 && p.tiles <= cfg.p_par, "{cfg:?}");
            assert!(p.channel < cfg.m);
        }
        // kernel transfer telescoping (Eq 13 kernel-reload factor)
        let ns_eff = cfg.ns.min(cfg.n);
        let ps_eff = cfg.ps.min(cfg.p);
        let kernel_reads: usize = phases
            .iter()
            .filter(|p| p.state == State::ReadKernel)
            .map(|p| p.kernels)
            .sum();
        assert_eq!(
            kernel_reads,
            cfg.p.div_ceil(ps_eff) * cfg.m * cfg.n,
            "{cfg:?}"
        );
        let _ = ns_eff;
    });
}

// ---------------- json: robustness fuzz -------------------------------------

#[test]
fn json_never_panics_on_garbage() {
    forall("json garbage", 300, |rng| {
        let len = rng.range(0, 64);
        let bytes: Vec<u8> = (0..len)
            .map(|_| b" {}[]\",:0123456789.eE+-truefalsenull\\x"[rng.range(0, 38)])
            .collect();
        if let Ok(s) = std::str::from_utf8(&bytes) {
            let _ = Json::parse(s); // must return, never panic
        }
    });
}

#[test]
fn json_roundtrip_fuzz() {
    fn gen(rng: &mut Pcg32, depth: usize) -> Json {
        match if depth == 0 { rng.range(0, 4) } else { rng.range(0, 6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.f32() < 0.5),
            2 => Json::Num((rng.range(0, 100_000) as f64) - 50_000.0),
            3 => Json::Str(format!("s{}", rng.next_u32())),
            4 => Json::Arr((0..rng.range(0, 4)).map(|_| gen(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.range(0, 4))
                    .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    forall("json roundtrip", 150, |rng| {
        let v = gen(rng, 3);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("{text}: {e}"));
        assert_eq!(v, back);
    });
}

// ---------------- rng: stream independence under forking --------------------

#[test]
fn rng_forked_streams_statistically_distinct() {
    forall("rng forks", 20, |rng| {
        let mut a = rng.fork(1);
        let mut b = rng.fork(2);
        let matches = (0..512).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(matches < 3, "streams collide: {matches}/512");
    });
}
