//! Adversarial and fuzz coverage: inputs chosen to break the invariants
//! that the happy-path tests take for granted — scheduler edge patterns,
//! FSM configuration fuzz, parser robustness, and hostile HTTP clients
//! against the networked serving front-end.

use spectral_flow::schedule::{Schedule, Scheduler};
use spectral_flow::sim::controller::{Controller, LoopConfig, State};
use spectral_flow::util::check::forall;
use spectral_flow::util::json::Json;
use spectral_flow::util::rng::Pcg32;

// ---------------- scheduler: adversarial patterns --------------------------

#[test]
fn scheduler_all_kernels_identical() {
    // Degenerate overlap: one index node covers everyone each cycle.
    let kernels = vec![vec![0u16, 7, 13, 42]; 64];
    for sch in [Scheduler::ExactCover, Scheduler::LowestIndexFirst] {
        let s = sch.run(&kernels, 1, 0);
        s.validate(&kernels).unwrap();
        assert_eq!(s.cycles(), 4, "{sch:?}");
        assert!((s.pe_utilization() - 1.0).abs() < 1e-12);
    }
    // The random baseline does NOT synchronize identical kernels — each
    // picks an independent random index, so with r=1 most kernels idle
    // every cycle. That asymmetry is exactly what Fig. 8 plots.
    let s = Scheduler::Random.run(&kernels, 1, 0);
    s.validate(&kernels).unwrap();
    assert!(s.cycles() > 4);
}

#[test]
fn scheduler_fully_disjoint_kernels() {
    // Zero overlap: utilization is capped by r/N' exactly.
    let n = 16usize;
    let nnz = 4usize;
    let kernels: Vec<Vec<u16>> = (0..n)
        .map(|k| (0..nnz).map(|j| (k * nnz + j) as u16).collect())
        .collect();
    for r in [1usize, 2, 4, 8] {
        let s = Scheduler::ExactCover.run(&kernels, r, 0);
        s.validate(&kernels).unwrap();
        // total edges = n·nnz; each cycle serves ≤ r kernels (disjoint ⇒
        // one kernel per distinct index)
        assert!(s.cycles() >= (n * nnz).div_ceil(r));
        assert!(s.pe_utilization() <= r as f64 / n as f64 + 1e-9);
    }
}

#[test]
fn scheduler_power_law_hub_index() {
    // One hub index shared by all kernels + unique tails: the hub must not
    // be wasted early (Alg 2's "leave high-degree nodes untouched").
    let n = 32usize;
    let mut kernels: Vec<Vec<u16>> = (0..n)
        .map(|k| {
            let mut v = vec![0u16]; // hub
            v.push((k + 1) as u16);
            v.push((k + 100) as u16);
            v.sort_unstable();
            v
        })
        .collect();
    kernels.sort();
    let s = Scheduler::ExactCover.run(&kernels, 4, 0);
    s.validate(&kernels).unwrap();
    // lower bound: 3 nnz per kernel, ≤ 4 distinct indices/cycle; tails are
    // unique so tail edges = 2n need ≥ 2n/4 cycles... but the hub cycle can
    // serve all. A good schedule stays close to 2n/3-ish; a bad one that
    // burns the hub early approaches 3n/4 cycles. Bound generously:
    assert!(
        s.cycles() <= 2 * n / 3 + 6,
        "hub wasted: {} cycles for {} kernels",
        s.cycles(),
        n
    );
}

#[test]
fn scheduler_ragged_nnz_mix() {
    forall("ragged nnz mix", 30, |rng| {
        // kernels with wildly different nnz (1..=32) — lower bound is the
        // max nnz; validation must still hold.
        let n = rng.range(2, 48);
        let kernels: Vec<Vec<u16>> = (0..n)
            .map(|_| {
                let nnz = rng.range(1, 33);
                let mut v: Vec<u16> =
                    rng.sample_indices(64, nnz).into_iter().map(|i| i as u16).collect();
                v.sort_unstable();
                v
            })
            .collect();
        let r = rng.range(1, 12);
        let s = Scheduler::ExactCover.run(&kernels, r, 0);
        s.validate(&kernels).unwrap();
        assert!(s.cycles() >= Schedule::lower_bound(&kernels, r));
    });
}

// ---------------- controller: configuration fuzz ---------------------------

#[test]
fn controller_fuzz_invariants() {
    forall("controller fuzz", 60, |rng| {
        let cfg = LoopConfig {
            n: rng.range(1, 40),
            p: rng.range(1, 40),
            m: rng.range(1, 10),
            ns: rng.range(1, 44),
            ps: rng.range(1, 44),
            p_par: rng.range(1, 8),
            n_par: rng.range(1, 8),
        };
        let mut ctl = Controller::new(cfg);
        let mut phases = Vec::new();
        while let Some(p) = ctl.next_phase() {
            phases.push(p);
            assert!(phases.len() < 2_000_000, "FSM diverged: {cfg:?}");
        }
        // every output tile (n, p) written exactly once
        let written: usize = phases
            .iter()
            .filter(|p| p.state == State::WriteOut)
            .map(|p| p.tiles * p.kernels)
            .sum();
        assert_eq!(written, cfg.n * cfg.p, "{cfg:?}");
        // ProcConv parallelism bounds respected
        for p in phases.iter().filter(|p| p.state == State::ProcConv) {
            assert!(p.kernels >= 1 && p.kernels <= cfg.n_par, "{cfg:?}");
            assert!(p.tiles >= 1 && p.tiles <= cfg.p_par, "{cfg:?}");
            assert!(p.channel < cfg.m);
        }
        // kernel transfer telescoping (Eq 13 kernel-reload factor)
        let ns_eff = cfg.ns.min(cfg.n);
        let ps_eff = cfg.ps.min(cfg.p);
        let kernel_reads: usize = phases
            .iter()
            .filter(|p| p.state == State::ReadKernel)
            .map(|p| p.kernels)
            .sum();
        assert_eq!(
            kernel_reads,
            cfg.p.div_ceil(ps_eff) * cfg.m * cfg.n,
            "{cfg:?}"
        );
        let _ = ns_eff;
    });
}

// ---------------- json: robustness fuzz -------------------------------------

#[test]
fn json_never_panics_on_garbage() {
    forall("json garbage", 300, |rng| {
        let len = rng.range(0, 64);
        let bytes: Vec<u8> = (0..len)
            .map(|_| b" {}[]\",:0123456789.eE+-truefalsenull\\x"[rng.range(0, 38)])
            .collect();
        if let Ok(s) = std::str::from_utf8(&bytes) {
            let _ = Json::parse(s); // must return, never panic
        }
    });
}

#[test]
fn json_roundtrip_fuzz() {
    fn gen(rng: &mut Pcg32, depth: usize) -> Json {
        match if depth == 0 { rng.range(0, 4) } else { rng.range(0, 6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.f32() < 0.5),
            2 => Json::Num((rng.range(0, 100_000) as f64) - 50_000.0),
            3 => Json::Str(format!("s{}", rng.next_u32())),
            4 => Json::Arr((0..rng.range(0, 4)).map(|_| gen(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.range(0, 4))
                    .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    forall("json roundtrip", 150, |rng| {
        let v = gen(rng, 3);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("{text}: {e}"));
        assert_eq!(v, back);
    });
}

// ---------------- http front-end: hostile clients ---------------------------

mod hostile_http {
    use std::io::{Read, Write};
    use std::net::{SocketAddr, TcpStream};
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    use spectral_flow::coordinator::{
        BatcherConfig, EngineOptions, ModelRegistry, ModelSpec,
    };
    use spectral_flow::net::{http, HttpConn, HttpFrontend, HttpLimits, NetConfig};
    use spectral_flow::schedule::SchedulePolicy;

    /// A short-deadline, small-body front-end over the demo variant: the
    /// attack surface with the caps tight enough to test quickly.
    fn hardened_frontend() -> HttpFrontend {
        let registry = Arc::new(
            ModelRegistry::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"), "demo")
                .with_drain_grace(Duration::from_secs(5)),
        );
        registry
            .load_blocking(
                "demo",
                ModelSpec {
                    preset: "demo".into(),
                    alpha: 1, // dense weights: no pruning artifacts needed
                    batcher: BatcherConfig { max_batch: 2, max_wait: Duration::from_millis(2) },
                    engine: EngineOptions::builder().scheduler(SchedulePolicy::Off).build(),
                    ..ModelSpec::default()
                },
            )
            .expect("demo model loads");
        HttpFrontend::start(
            registry,
            NetConfig {
                addr: "127.0.0.1:0".into(),
                limits: HttpLimits {
                    max_body: 64 << 10,
                    read_timeout: Duration::from_millis(400),
                    ..HttpLimits::default()
                },
                ..NetConfig::default()
            },
        )
        .expect("frontend binds")
    }

    /// Send raw bytes on a fresh connection, return the parsed response.
    fn send_raw(addr: SocketAddr, bytes: &[u8], read_timeout: Duration) -> (u16, Vec<u8>) {
        let stream = TcpStream::connect(addr).expect("connect");
        let mut writer = stream.try_clone().expect("clone");
        let mut conn = HttpConn::new(stream);
        writer.write_all(bytes).expect("send");
        conn.read_response(&HttpLimits { read_timeout, ..HttpLimits::default() })
            .expect("response")
    }

    /// The worker-not-wedged probe: a valid request must still succeed.
    fn assert_still_serving(addr: SocketAddr) {
        let (status, _) = send_raw(
            addr,
            &http::format_request("POST", "/infer", "t", b"{\"seed\":1}"),
            Duration::from_secs(30),
        );
        assert_eq!(status, 200, "front-end wedged by the previous attack");
    }

    #[test]
    fn malformed_request_line_gets_400() {
        let frontend = hardened_frontend();
        let addr = frontend.local_addr();
        for garbage in [
            &b"THIS IS NOT HTTP AT ALL\r\n\r\n"[..],
            b"POST\r\n\r\n",
            b"GET / SMTP/9.9\r\n\r\n",
            b"\x00\x01\x02\x03\r\n\r\n",
        ] {
            let (status, _) = send_raw(addr, garbage, Duration::from_secs(5));
            assert!(
                (400..=505).contains(&status),
                "garbage {:?} got {status}",
                String::from_utf8_lossy(garbage)
            );
        }
        assert_still_serving(addr);
        frontend.shutdown().expect("shutdown");
    }

    #[test]
    fn oversized_body_rejected_before_read() {
        let frontend = hardened_frontend();
        let addr = frontend.local_addr();
        // Content-Length far past the 64 KiB cap: 413 must come back
        // immediately, without the server waiting for (or reading) a body
        let t0 = Instant::now();
        let (status, _) = send_raw(
            addr,
            b"POST /infer HTTP/1.1\r\nHost: t\r\nContent-Length: 1073741824\r\n\r\n",
            Duration::from_secs(5),
        );
        assert_eq!(status, 413);
        assert!(t0.elapsed() < Duration::from_secs(2), "413 must not wait for the body");
        assert_still_serving(addr);
        frontend.shutdown().expect("shutdown");
    }

    #[test]
    fn truncated_json_body_gets_400() {
        let frontend = hardened_frontend();
        let addr = frontend.local_addr();
        // Content-Length matches the bytes on the wire, but the JSON
        // inside is cut off mid-value
        let body = b"{\"shape\":[1,16";
        let (status, resp) =
            send_raw(addr, &http::format_request("POST", "/infer", "t", body), Duration::from_secs(5));
        assert_eq!(status, 400, "{:?}", String::from_utf8_lossy(&resp));
        assert!(String::from_utf8_lossy(&resp).contains("json"));
        assert_still_serving(addr);
        frontend.shutdown().expect("shutdown");
    }

    #[test]
    fn slow_loris_partial_header_times_out_without_wedging() {
        let frontend = hardened_frontend();
        let addr = frontend.local_addr();
        // send a partial header and then go silent: the 400 ms request
        // deadline must close the exchange (408 or just a close) instead
        // of parking a connection thread forever
        let stream = TcpStream::connect(addr).expect("connect");
        let mut writer = stream.try_clone().expect("clone");
        writer
            .write_all(b"POST /infer HTTP/1.1\r\nHost: t\r\nContent-Ty")
            .expect("partial send");
        let t0 = Instant::now();
        let mut reader = stream;
        reader.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut buf = Vec::new();
        let outcome = reader.read_to_end(&mut buf); // server responds and/or closes
        let waited = t0.elapsed();
        assert!(
            waited < Duration::from_secs(3),
            "slow-loris held the connection for {waited:?}"
        );
        if outcome.is_ok() && !buf.is_empty() {
            let text = String::from_utf8_lossy(&buf);
            assert!(text.starts_with("HTTP/1.1 408"), "expected 408, got {text}");
        }
        // …and while that connection idled, the pool kept serving others
        assert_still_serving(addr);
        frontend.shutdown().expect("shutdown");
    }

    #[test]
    fn drip_fed_header_line_still_hits_the_deadline() {
        // sharper slow-loris: keep the socket warm with one byte per
        // 100 ms — per-read timeouts alone would never fire; the request
        // deadline must
        let frontend = hardened_frontend();
        let addr = frontend.local_addr();
        let stream = TcpStream::connect(addr).expect("connect");
        let mut writer = stream.try_clone().expect("clone");
        let t0 = Instant::now();
        let drip = b"GET /healthz HTT";
        for b in drip {
            if writer.write_all(&[*b]).is_err() {
                break; // server already gave up on us — exactly the point
            }
            std::thread::sleep(Duration::from_millis(100));
        }
        let mut reader = stream;
        reader.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut buf = Vec::new();
        let _ = reader.read_to_end(&mut buf);
        assert!(
            t0.elapsed() < Duration::from_secs(4),
            "drip-fed header held the connection for {:?}",
            t0.elapsed()
        );
        assert_still_serving(addr);
        frontend.shutdown().expect("shutdown");
    }
}

// ---------------- manifest graphs: hostile activation DAGs -------------------

mod hostile_graphs {
    use spectral_flow::runtime::Manifest;

    /// A two-conv manifest with a `{graph}` placeholder: each test splices
    /// in an adversarial node list. conv0: 8ch 16×16 → conv1: 8ch pooled.
    fn with_graph(graph: &str) -> String {
        format!(
            r#"{{
              "format": "hlo-text-v1",
              "fft_size": 8, "kernel_k": 3, "tile": 6,
              "word_bytes": 2, "hadamard_mode": "mxu4",
              "variants": {{
                "demo": {{
                  "input_hw": 16, "input_c": 8, "fc": [10],
                  "graph": [{graph}],
                  "layers": [
                    {{"name": "conv0", "cin": 8, "cout": 8, "h": 16,
                      "tiles": 9, "pool_after": false, "file": "a.hlo.txt"}},
                    {{"name": "conv1", "cin": 8, "cout": 8, "h": 16,
                      "tiles": 9, "pool_after": true, "file": "a.hlo.txt"}}
                  ]
                }}
              }},
              "executables": {{
                "a.hlo.txt": {{"tiles": 9, "cin": 8, "cout": 8,
                               "fft_size": 8, "sha256": "00", "bytes": 10}}
              }}
            }}"#
        )
    }

    const CONV0: &str = r#"{"op":"conv","conv":0,"input":0}"#;
    const CONV1: &str = r#"{"op":"conv","conv":1,"input":1}"#;

    /// Every hostile graph must come back as a clean `Err` whose message
    /// names the problem — never a panic, never a silently-accepted plan.
    #[test]
    fn malformed_graphs_error_with_clear_messages() {
        let cases: Vec<(&str, String, &str)> = vec![
            // a node reading its own output (the only way a node list can
            // express a cycle) and a forward reference
            ("self-cycle", r#"{"op":"conv","conv":0,"input":1}"#.into(), "cycle"),
            (
                "forward-ref",
                format!(r#"{{"op":"conv","conv":0,"input":2}}, {CONV1}"#),
                "cycle",
            ),
            // dangling references
            (
                "dangling-tensor",
                format!(r#"{CONV0}, {{"op":"add","a":1,"b":9}}, {CONV1}"#),
                "dangling tensor",
            ),
            ("dangling-conv", r#"{"op":"conv","conv":7,"input":0}"#.into(), "dangling conv"),
            // conv1 pools to 8×8, conv0 stays 16×16 — the add can't line up
            (
                "add-shape-mismatch",
                format!(r#"{CONV0}, {CONV1}, {{"op":"add","a":1,"b":2}}"#),
                "mismatch",
            ),
            (
                "concat-axis-mismatch",
                format!(r#"{CONV0}, {CONV1}, {{"op":"concat","a":1,"b":2}}"#),
                "concat spatial mismatch",
            ),
            // structural abuse
            ("empty-graph", String::new(), "empty"),
            (
                "conv-used-twice",
                format!(r#"{CONV0}, {{"op":"conv","conv":0,"input":1}}"#),
                "used twice",
            ),
            (
                "dead-intermediate",
                format!(r#"{CONV0}, {{"op":"conv","conv":1,"input":0}}"#),
                "never consumed",
            ),
            ("unknown-op", r#"{"op":"warp","a":0,"b":0}"#.into(), "unknown op"),
        ];
        for (tag, graph, needle) in &cases {
            let err = Manifest::parse(&with_graph(graph))
                .err()
                .unwrap_or_else(|| panic!("{tag}: hostile graph was accepted"));
            let msg = err.to_string();
            assert!(
                msg.contains(needle),
                "{tag}: error {msg:?} does not mention {needle:?}"
            );
        }
        // non-array graph field
        let bad = with_graph("").replace(r#""graph": []"#, r#""graph": "loop""#);
        let msg = Manifest::parse(&bad).err().expect("non-array graph accepted").to_string();
        assert!(msg.contains("not an array"), "{msg:?}");
    }

    /// Pre-graph manifests (no `graph` key) still parse, mean chain
    /// execution, and round-trip through to_json without growing a graph.
    #[test]
    fn legacy_layer_list_manifests_round_trip() {
        let legacy = with_graph("").replace(&format!(r#""graph": [],{}"#, "\n"), "");
        assert!(!legacy.contains("graph"), "fixture must have no graph key");
        let m = Manifest::parse(&legacy).expect("legacy manifest parses");
        let v = m.variant("demo").unwrap();
        assert!(v.graph.is_none(), "absent graph must stay None");
        assert_eq!(v.graph_ops().len(), v.layers.len(), "chain semantics");
        let text = m.to_json().to_string();
        assert!(!text.contains("\"graph\""), "to_json invented a graph key");
        let back = Manifest::parse(&text).expect("round-trip parses");
        assert!(back.variant("demo").unwrap().graph.is_none());
        assert_eq!(back.variant("demo").unwrap().layers.len(), 2);
    }
}

// ---------------- rng: stream independence under forking --------------------

#[test]
fn rng_forked_streams_statistically_distinct() {
    forall("rng forks", 20, |rng| {
        let mut a = rng.fork(1);
        let mut b = rng.fork(2);
        let matches = (0..512).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(matches < 3, "streams collide: {matches}/512");
    });
}
