//! Integration: the pure-Rust spectral pipeline (FFT → Hadamard → IFFT →
//! OaA) against the naive spatial convolution — the same equivalence the
//! Python side proves for the AOT'd path, proven here for the coordinator's
//! CPU substrate (no artifacts needed).

use spectral_flow::fft::{fft2d, ifft2d, im2tiles, overlap_add, spectral_kernels, Complex, TileGeometry};
use spectral_flow::nn::conv2d_same_ref;
use spectral_flow::tensor::Tensor;
use spectral_flow::util::check::{assert_allclose, forall};
use spectral_flow::util::rng::Pcg32;

/// Full spectral 'SAME' conv in Rust (reference-grade; the fast path runs
/// inside the XLA executables).
fn spectral_conv_rust(x: &Tensor, w: &Tensor, fft: usize) -> Tensor {
    let (m, h) = (x.shape()[0], x.shape()[1]);
    let n = w.shape()[0];
    let k = w.shape()[2];
    let geo = TileGeometry::new(h, fft, k);
    let tiles = im2tiles(x, &geo);
    let ws = spectral_kernels(w, fft);
    let t = geo.num_tiles();
    let k2 = fft * fft;
    let mut out_tiles = Tensor::zeros(&[t, n, fft, fft]);
    let mut xs_buf: Vec<Vec<Complex>> = Vec::with_capacity(m);
    for ti in 0..t {
        // FFT all input channels of this tile
        xs_buf.clear();
        for c in 0..m {
            let plane: Vec<Complex> = (0..k2)
                .map(|i| Complex::new(tiles.at(&[ti, c, i / fft, i % fft]), 0.0))
                .collect();
            xs_buf.push(fft2d(&plane, fft));
        }
        for o in 0..n {
            let mut acc = vec![Complex::ZERO; k2];
            for c in 0..m {
                for i in 0..k2 {
                    let (wr, wi) = ws.at(&[o, c, i / fft, i % fft]);
                    acc[i] = acc[i].add(xs_buf[c][i].mul(Complex::new(wr, wi)));
                }
            }
            let y = ifft2d(&acc, fft);
            for (i, v) in y.iter().enumerate() {
                out_tiles.set(&[ti, o, i / fft, i % fft], v.re);
            }
        }
    }
    overlap_add(&out_tiles, &geo, n)
}

#[test]
fn spectral_equals_spatial_small() {
    let mut rng = Pcg32::new(1);
    let x = Tensor::randn(&[3, 10, 10], &mut rng, 1.0);
    let w = Tensor::randn(&[5, 3, 3, 3], &mut rng, 0.2);
    let got = spectral_conv_rust(&x, &w, 8);
    let want = conv2d_same_ref(&x, &w);
    assert_allclose(got.data(), want.data(), 1e-3, 1e-3);
}

#[test]
fn spectral_equals_spatial_sweep() {
    forall("rust spectral == spatial", 12, |rng| {
        let h = rng.range(4, 18);
        let m = rng.range(1, 4);
        let n = rng.range(1, 4);
        let x = Tensor::randn(&[m, h, h], rng, 1.0);
        let w = Tensor::randn(&[n, m, 3, 3], rng, 0.3);
        let got = spectral_conv_rust(&x, &w, 8);
        let want = conv2d_same_ref(&x, &w);
        assert_allclose(got.data(), want.data(), 2e-3, 2e-3);
    });
}

#[test]
fn spectral_equals_spatial_k16() {
    // K=16 (Table 1 lower half geometry): tile h' = 14.
    let mut rng = Pcg32::new(2);
    let x = Tensor::randn(&[2, 20, 20], &mut rng, 1.0);
    let w = Tensor::randn(&[2, 2, 3, 3], &mut rng, 0.2);
    let got = spectral_conv_rust(&x, &w, 16);
    let want = conv2d_same_ref(&x, &w);
    assert_allclose(got.data(), want.data(), 2e-3, 2e-3);
}

#[test]
fn pruned_kernels_change_output_gracefully() {
    // α=4 pruning keeps 75%+ of kernel energy under magnitude pruning for
    // smooth kernels; the pruned spectral conv must stay correlated with
    // the dense one (sanity on the Pruned weight mode).
    use spectral_flow::sparse::prune_magnitude;
    let mut rng = Pcg32::new(3);
    let x = Tensor::randn(&[4, 12, 12], &mut rng, 1.0);
    let sparse = prune_magnitude(4, 4, 8, 4, &mut rng);
    let planes = sparse.to_dense_planes();
    // dense path: spectral conv with the pruned planes, computed tile-wise
    let geo = TileGeometry::new(12, 8, 3);
    let tiles = im2tiles(&x, &geo);
    let t = geo.num_tiles();
    let mut energy_out = 0.0f64;
    for ti in 0..t {
        for c in 0..4 {
            let plane: Vec<Complex> = (0..64)
                .map(|i| Complex::new(tiles.at(&[ti, c, i / 8, i % 8]), 0.0))
                .collect();
            let xs = fft2d(&plane, 8);
            for i in 0..64 {
                let (wr, wi) = planes.at(&[0, c, i / 8, i % 8]);
                let y = xs[i].mul(Complex::new(wr, wi));
                energy_out += (y.abs() as f64).powi(2);
            }
        }
    }
    assert!(energy_out.is_finite() && energy_out > 0.0);
}
