//! Integration: the `/v1` multi-model serving surface — registry listing,
//! the `/admin` load → serve → swap → drain lifecycle, the zero-downtime
//! weight swap under closed-loop load, and C10k-style idle keep-alive
//! connections against the fixed event-worker pool. All over real loopback
//! sockets on the offline `interp` backend (demo variant, no artifacts).

use std::io::Write;
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use spectral_flow::coordinator::{BatcherConfig, EngineOptions, ModelRegistry, ModelSpec};
use spectral_flow::net::{http, HttpConn, HttpFrontend, HttpLimits, NetConfig};
use spectral_flow::schedule::SchedulePolicy;
use spectral_flow::util::json::Json;

fn demo_spec(alpha: usize) -> ModelSpec {
    ModelSpec {
        preset: "demo".into(),
        alpha,
        batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(2) },
        engine: EngineOptions::builder().scheduler(SchedulePolicy::ExactCover).build(),
        ..ModelSpec::default()
    }
}

fn demo_registry() -> Arc<ModelRegistry> {
    let reg = Arc::new(
        ModelRegistry::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"), "demo")
            .with_drain_grace(Duration::from_secs(5)),
    );
    reg.load_blocking("demo", demo_spec(4)).expect("demo model loads");
    reg
}

fn start_frontend() -> HttpFrontend {
    HttpFrontend::start(
        demo_registry(),
        NetConfig { addr: "127.0.0.1:0".into(), ..NetConfig::default() },
    )
    .expect("frontend binds")
}

fn roundtrip(addr: SocketAddr, method: &str, path: &str, body: &[u8]) -> (u16, Vec<u8>) {
    let stream = TcpStream::connect(addr).expect("connect");
    let mut writer = stream.try_clone().expect("clone");
    let mut conn = HttpConn::new(stream);
    writer
        .write_all(&http::format_request(method, path, &addr.to_string(), body))
        .expect("send");
    conn.read_response(&HttpLimits::default()).expect("response")
}

fn parse_body(body: &[u8]) -> Json {
    Json::parse(std::str::from_utf8(body).expect("utf8 body")).expect("json body")
}

/// Poll `GET /v1/models` until `model` reports `status` (or panic after
/// `timeout`). Returns that model's status row.
fn await_status(addr: SocketAddr, model: &str, status: &str, timeout: Duration) -> Json {
    let deadline = Instant::now() + timeout;
    loop {
        let (code, body) = roundtrip(addr, "GET", "/v1/models", b"");
        assert_eq!(code, 200);
        let j = parse_body(&body);
        let row = j
            .get("models")
            .and_then(Json::as_arr)
            .and_then(|models| {
                models
                    .iter()
                    .find(|m| m.get("name").and_then(Json::as_str) == Some(model))
                    .cloned()
            });
        if let Some(row) = &row {
            if row.get("status").and_then(Json::as_str) == Some(status) {
                return row.clone();
            }
        }
        assert!(
            Instant::now() < deadline,
            "model {model:?} never reached {status:?}; last row: {row:?}"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
}

#[test]
fn admin_lifecycle_loads_swaps_and_unloads_a_second_model() {
    let frontend = start_frontend();
    let addr = frontend.local_addr();

    // load a second model under a new name (dense demo weights)
    let (status, body) =
        roundtrip(addr, "POST", "/admin/models/alt", br#"{"preset":"demo","alpha":1}"#);
    assert_eq!(status, 202, "{:?}", String::from_utf8_lossy(&body));
    let j = parse_body(&body);
    assert_eq!(j.get("status").and_then(Json::as_str), Some("loading"));
    assert_eq!(j.get("model").and_then(Json::as_str), Some("alt"));
    assert_eq!(j.get("generation").and_then(Json::as_usize), Some(1));

    // the background build lands and the model starts serving
    let row = await_status(addr, "alt", "serving", Duration::from_secs(30));
    assert_eq!(row.get("preset").and_then(Json::as_str), Some("demo"));
    assert_eq!(row.get("alpha").and_then(Json::as_usize), Some(1));
    let (status, _) = roundtrip(addr, "POST", "/v1/models/alt/infer", b"{\"seed\":4}");
    assert_eq!(status, 200, "freshly loaded model must serve");

    // both models serve from one process, each with its own metrics
    for name in ["demo", "alt"] {
        let path = format!("/v1/models/{name}/metrics");
        let (status, body) = roundtrip(addr, "GET", &path, b"");
        assert_eq!(status, 200);
        let j = parse_body(&body);
        assert_eq!(j.get("model").and_then(Json::as_str), Some(name));
        assert!(j.get("admission").is_some());
    }

    // swap alt in place (back to α=4): 202 names the next generation, the
    // old pool serves until the new one is ready, then the counter bumps
    let (status, body) =
        roundtrip(addr, "POST", "/admin/models/alt", br#"{"preset":"demo","alpha":4}"#);
    assert_eq!(status, 202, "{:?}", String::from_utf8_lossy(&body));
    assert_eq!(parse_body(&body).get("generation").and_then(Json::as_usize), Some(2));
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let (status, body) = roundtrip(addr, "GET", "/v1/models/alt/metrics", b"");
        if status == 200 && parse_body(&body).get("generation").and_then(Json::as_usize) == Some(2)
        {
            break;
        }
        assert!(Instant::now() < deadline, "generation never bumped to 2");
        std::thread::sleep(Duration::from_millis(20));
    }
    let (status, _) = roundtrip(addr, "POST", "/v1/models/alt/infer", b"{\"seed\":4}");
    assert_eq!(status, 200, "swapped model must serve");

    // drain + unload: immediate 202, then the name disappears (404)
    let (status, body) = roundtrip(addr, "DELETE", "/admin/models/alt", b"");
    assert_eq!(status, 202);
    assert_eq!(parse_body(&body).get("status").and_then(Json::as_str), Some("draining"));
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let (status, _) = roundtrip(addr, "POST", "/v1/models/alt/infer", b"{\"seed\":1}");
        if status == 404 {
            break;
        }
        assert_eq!(status, 503, "draining model must refuse, not serve");
        assert!(Instant::now() < deadline, "drained model never unloaded");
        std::thread::sleep(Duration::from_millis(20));
    }
    // …and the default model is untouched by its sibling's lifecycle
    let (status, _) = roundtrip(addr, "POST", "/v1/models/demo/infer", b"{\"seed\":1}");
    assert_eq!(status, 200);
    frontend.shutdown().expect("shutdown");
}

#[test]
fn admin_rejects_bad_specs_and_unknown_models() {
    let frontend = start_frontend();
    let addr = frontend.local_addr();

    // unknown preset: validated synchronously, 400 in the error schema
    let (status, body) =
        roundtrip(addr, "POST", "/admin/models/ghost", br#"{"preset":"no-such-variant"}"#);
    assert_eq!(status, 400);
    let err = parse_body(&body).get("error").cloned().expect("error object");
    assert_eq!(err.get("code").and_then(Json::as_str), Some("bad_request"));
    assert_eq!(err.get("model").and_then(Json::as_str), Some("ghost"));

    // unknown keys in the spec body are typos, not silently ignored
    let (status, _) =
        roundtrip(addr, "POST", "/admin/models/ghost", br#"{"bogus":1}"#);
    assert_eq!(status, 400);

    // a rejected load leaves no registry entry behind
    let (_, body) = roundtrip(addr, "GET", "/v1/models", b"");
    let models = parse_body(&body).get("models").and_then(Json::as_arr).unwrap().clone();
    assert_eq!(models.len(), 1, "failed validation must not register a model");

    // deleting a model that was never loaded is a 404
    let (status, body) = roundtrip(addr, "DELETE", "/admin/models/ghost", b"");
    assert_eq!(status, 404);
    let err = parse_body(&body).get("error").cloned().expect("error object");
    assert_eq!(err.get("code").and_then(Json::as_str), Some("not_found"));
    frontend.shutdown().expect("shutdown");
}

#[test]
fn live_swap_under_load_drops_zero_requests() {
    // The zero-downtime contract: while closed-loop clients hammer the
    // default model, an /admin rebuild swaps its pool generation 1 → 2.
    // Every request must answer 200 — none dropped, none refused — because
    // the old pool keeps serving until the new one is ready and in-flight
    // requests drain on the old engines.
    let frontend = start_frontend();
    let addr = frontend.local_addr();

    let stop = Arc::new(AtomicBool::new(false));
    let served = Arc::new(AtomicUsize::new(0));
    let clients: Vec<_> = (0..4)
        .map(|c| {
            let stop = Arc::clone(&stop);
            let served = Arc::clone(&served);
            std::thread::spawn(move || {
                let mut i = 0usize;
                while !stop.load(Ordering::SeqCst) {
                    let body = format!("{{\"seed\":{}}}", c * 1000 + i);
                    let (status, resp) =
                        roundtrip(addr, "POST", "/v1/models/demo/infer", body.as_bytes());
                    assert_eq!(
                        status,
                        200,
                        "request failed during live swap: {:?}",
                        String::from_utf8_lossy(&resp)
                    );
                    served.fetch_add(1, Ordering::SeqCst);
                    i += 1;
                }
            })
        })
        .collect();

    // let the load settle, then swap the model under it (α 4 → 1)
    while served.load(Ordering::SeqCst) < 8 {
        std::thread::sleep(Duration::from_millis(5));
    }
    let (status, body) =
        roundtrip(addr, "POST", "/admin/models/demo", br#"{"preset":"demo","alpha":1}"#);
    assert_eq!(status, 202, "{:?}", String::from_utf8_lossy(&body));

    // wait until the swap lands (generation 2 visible in /v1 metrics)
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let (status, body) = roundtrip(addr, "GET", "/v1/models/demo/metrics", b"");
        if status == 200 && parse_body(&body).get("generation").and_then(Json::as_usize) == Some(2)
        {
            break;
        }
        assert!(Instant::now() < deadline, "swap never landed under load");
        std::thread::sleep(Duration::from_millis(10));
    }

    // keep serving across the generation boundary, then stop the storm
    let after_swap = served.load(Ordering::SeqCst);
    while served.load(Ordering::SeqCst) < after_swap + 8 {
        std::thread::sleep(Duration::from_millis(5));
    }
    stop.store(true, Ordering::SeqCst);
    for c in clients {
        c.join().expect("client thread panicked (a request failed)");
    }
    assert!(served.load(Ordering::SeqCst) >= 16);
    frontend.shutdown().expect("shutdown");
}

#[test]
fn observability_surface_round_trips_over_http() {
    // The obs stack end to end on loopback: inference leaves traffic
    // accounting in /v1/metrics (JSON and Prometheus text) and a structured
    // trace behind /v1/models/<name>/trace.
    let frontend = start_frontend();
    let addr = frontend.local_addr();

    let (status, _) = roundtrip(addr, "POST", "/v1/models/demo/infer", b"{\"seed\":1}");
    assert_eq!(status, 200);
    let (status, _) = roundtrip(
        addr,
        "POST",
        "/v1/models/demo/infer",
        br#"{"batch":[{"seed":2},{"seed":3}]}"#,
    );
    assert_eq!(status, 200);

    // JSON form: one row per serving model, traffic block present with the
    // per-layer measured-vs-Eq.13 accounting
    let (status, body) = roundtrip(addr, "GET", "/v1/metrics", b"");
    assert_eq!(status, 200);
    let j = parse_body(&body);
    let models = j.get("models").and_then(Json::as_arr).expect("models array");
    let row = models
        .iter()
        .find(|m| m.get("model").and_then(Json::as_str) == Some("demo"))
        .expect("demo row");
    let traffic = row.get("traffic").expect("traffic block");
    let layers = traffic.get("layers").and_then(Json::as_arr).expect("traffic layers");
    assert_eq!(layers.len(), 2, "demo has two conv layers");
    for l in layers {
        assert!(l.get("measured_weight_bytes").and_then(Json::as_usize).unwrap_or(0) > 0);
        assert!(l.get("predicted_weight_bytes").and_then(Json::as_usize).unwrap_or(0) > 0);
        assert!(l.get("weight_ratio").is_some());
    }

    // Prometheus form: # TYPE headers, per-model labels, and every sample
    // line shaped `name{labels} value` — what a scraper would accept
    let (status, body) = roundtrip(addr, "GET", "/v1/metrics?format=prometheus", b"");
    assert_eq!(status, 200);
    let text = String::from_utf8(body).expect("utf8 exposition");
    for needle in [
        "# TYPE sf_requests_total counter",
        "# TYPE sf_traffic_bytes_total counter",
        "model=\"demo\"",
        "sf_traffic_weight_ratio",
    ] {
        assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
    }
    for line in text.lines().filter(|l| !l.starts_with('#') && !l.is_empty()) {
        let value = line.rsplit(' ').next().expect("sample value");
        assert!(value.parse::<f64>().is_ok(), "unparseable sample line {line:?}");
    }
    // unknown format is a structured 400, not a silent JSON fallback
    let (status, _) = roundtrip(addr, "GET", "/v1/metrics?format=xml", b"");
    assert_eq!(status, 400);

    // trace endpoint: the requests above left traces with the full span
    // taxonomy (wire-side parse included — these came over HTTP)
    let deadline = Instant::now() + Duration::from_secs(10);
    let traces = loop {
        let (status, body) = roundtrip(addr, "GET", "/v1/models/demo/trace?n=8", b"");
        assert_eq!(status, 200);
        let j = parse_body(&body);
        assert!(j.get("dropped").is_some() && j.get("slow_threshold_us").is_some());
        let traces = j.get("traces").and_then(Json::as_arr).cloned().expect("traces array");
        if !traces.is_empty() {
            break traces;
        }
        assert!(Instant::now() < deadline, "traces never appeared");
        std::thread::sleep(Duration::from_millis(10));
    };
    let t = &traces[0];
    assert!(t.get("request").and_then(Json::as_usize).unwrap_or(0) > 0);
    assert_eq!(t.get("model").and_then(Json::as_str), Some("demo"));
    let spans = t.get("spans").and_then(Json::as_arr).expect("spans array");
    let names: Vec<&str> =
        spans.iter().filter_map(|s| s.get("name").and_then(Json::as_str)).collect();
    assert_eq!(names.first(), Some(&"request"), "root span leads");
    for want in ["parse", "queue", "batch-close", "execute", "layer:conv1", "layer:conv2"] {
        assert!(names.contains(&want), "missing {want} span in {names:?}");
    }

    // ?slow selects the slow-retention ring (valid, likely empty here)
    let (status, body) = roundtrip(addr, "GET", "/v1/models/demo/trace?slow&n=4", b"");
    assert_eq!(status, 200);
    assert!(parse_body(&body).get("traces").and_then(Json::as_arr).is_some());

    // unknown model keeps the structured 404 schema
    let (status, body) = roundtrip(addr, "GET", "/v1/models/nope/trace", b"");
    assert_eq!(status, 404);
    let err = parse_body(&body).get("error").cloned().expect("error object");
    assert_eq!(err.get("code").and_then(Json::as_str), Some("not_found"));
    frontend.shutdown().expect("shutdown");
}

#[test]
fn a_thousand_idle_keepalive_connections_stay_cheap() {
    // C10k posture: ~1k mostly-idle keep-alive connections are multiplexed
    // over the fixed pool of event workers (4 by default) — no
    // thread-per-connection. The front-end must keep answering new
    // requests, and the idle sockets must stay serviceable (the 60 s idle
    // timeout is far beyond this test's lifetime).
    let frontend = start_frontend();
    let addr = frontend.local_addr();

    // open as many as the fd budget allows (client + server side share
    // this process's limit) — EMFILE is tolerated, but a real C10k box
    // must get well past the worker count
    let mut idle = Vec::new();
    for _ in 0..1050 {
        match TcpStream::connect(addr) {
            Ok(s) => idle.push(s),
            Err(_) => break,
        }
    }
    assert!(
        idle.len() >= 256,
        "opened only {} sockets before EMFILE — too few to exercise the event loop",
        idle.len()
    );

    // the acceptor registers them with the workers shortly after connect
    let deadline = Instant::now() + Duration::from_secs(10);
    while frontend.connections() < idle.len() && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(20));
    }
    let registered = frontend.connections();
    assert!(
        registered >= idle.len() / 2 && registered >= 256,
        "front-end registered {registered} of {} idle connections",
        idle.len()
    );

    // with every one of them idling, a fresh request still round-trips
    let (status, _) = roundtrip(addr, "POST", "/infer", b"{\"seed\":1}");
    assert_eq!(status, 200, "idle connections starved the event loop");

    // …and a long-idle keep-alive socket is still live for its next request
    let stream = idle.pop().expect("at least one idle socket");
    let mut writer = stream.try_clone().expect("clone");
    let mut conn = HttpConn::new(stream);
    writer
        .write_all(&http::format_request("POST", "/infer", &addr.to_string(), b"{\"seed\":2}"))
        .expect("send on idle keep-alive socket");
    let (status, _) = conn.read_response(&HttpLimits::default()).expect("response");
    assert_eq!(status, 200, "idle keep-alive socket went dead");

    drop(idle);
    frontend.shutdown().expect("shutdown");
}
