//! Integration: the networked serving subsystem — the event-driven HTTP
//! front-end over the model registry, wire-schema round-trips, admission
//! control, drain, and the load generator, all over real loopback sockets
//! on the offline `interp` backend (demo variant, no artifacts needed).

use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use spectral_flow::coordinator::{
    BatcherConfig, Client, EngineOptions, ModelRegistry, ModelSpec,
};
use spectral_flow::net::{http, proto, HttpConn, HttpFrontend, HttpLimits, NetConfig};
use spectral_flow::net::{loadgen, LoadGenConfig, LoadMode};
use spectral_flow::runtime::{Dtype, Plane};
use spectral_flow::schedule::SchedulePolicy;
use spectral_flow::tensor::Tensor;
use spectral_flow::util::json::Json;
use spectral_flow::util::rng::Pcg32;

const DEMO_SHAPE: [usize; 3] = [1, 16, 16];

fn demo_spec(alpha: usize, scheduler: SchedulePolicy) -> ModelSpec {
    ModelSpec {
        preset: "demo".into(),
        alpha,
        batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(2) },
        engine: EngineOptions::builder().scheduler(scheduler).build(),
        ..ModelSpec::default()
    }
}

/// A registry serving the demo variant as its (default) model "demo".
fn demo_registry(spec: ModelSpec) -> Arc<ModelRegistry> {
    let reg = Arc::new(
        ModelRegistry::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"), "demo")
            .with_drain_grace(Duration::from_secs(5)),
    );
    reg.load_blocking("demo", spec).expect("demo model loads");
    reg
}

/// In-process client handle without retaining the pool `Arc` (a held pool
/// would stall the shutdown drain).
fn demo_client(reg: &ModelRegistry) -> Client {
    reg.pool("demo").expect("demo is serving").client()
}

fn start_frontend(spec: ModelSpec, net: NetConfig) -> HttpFrontend {
    HttpFrontend::start(demo_registry(spec), net).expect("frontend binds")
}

fn demo_net() -> NetConfig {
    NetConfig { addr: "127.0.0.1:0".into(), ..NetConfig::default() }
}

/// One request over a fresh connection; returns (status, body).
fn roundtrip(addr: SocketAddr, method: &str, path: &str, body: &[u8]) -> (u16, Vec<u8>) {
    use std::io::Write;
    let stream = TcpStream::connect(addr).expect("connect");
    let mut writer = stream.try_clone().expect("clone");
    let mut conn = HttpConn::new(stream);
    writer
        .write_all(&http::format_request(method, path, &addr.to_string(), body))
        .expect("send");
    conn.read_response(&HttpLimits::default()).expect("response")
}

fn parse_body(body: &[u8]) -> Json {
    Json::parse(std::str::from_utf8(body).expect("utf8 body")).expect("json body")
}

#[test]
fn http_inference_bit_identical_to_in_process_client() {
    // The acceptance contract: the same image through the in-process
    // Client and through POST /infer yields the same logits, bit for bit,
    // across α ∈ {1, 4} and scheduler policies.
    for (alpha, policy) in [
        (1usize, SchedulePolicy::Off),
        (4, SchedulePolicy::ExactCover),
        (4, SchedulePolicy::LowestIndex),
        (4, SchedulePolicy::Off),
    ] {
        let registry = demo_registry(demo_spec(alpha, policy));
        let client = demo_client(&registry);
        let mut rng = Pcg32::new(11);
        let img = Tensor::randn(&DEMO_SHAPE, &mut rng, 1.0);
        let want = client.infer(img.clone()).expect("in-process infer").logits;

        let frontend = HttpFrontend::start(registry, demo_net()).expect("frontend binds");
        let body = proto::tensor_to_json(&img).to_string();
        let (status, resp) =
            roundtrip(frontend.local_addr(), "POST", "/infer", body.as_bytes());
        assert_eq!(status, 200, "α={alpha} {policy:?}: {resp:?}");
        let j = parse_body(&resp);
        let got = proto::logits_from_json(&j).expect("logits");
        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(
                g.to_bits(),
                w.to_bits(),
                "α={alpha} {policy:?}: logit {i} diverged over the wire ({g} vs {w})"
            );
        }
        // the reply carries the latency breakdown and pool placement
        let lat = j.get("latency_us").and_then(Json::as_f64).expect("latency_us");
        let queue = j.get("queue_us").and_then(Json::as_f64).expect("queue_us");
        let exec = j.get("execute_us").and_then(Json::as_f64).expect("execute_us");
        assert!(lat + 1.0 >= queue + exec, "latency {lat} < queue {queue} + exec {exec}");
        assert!(j.get("worker").and_then(Json::as_usize).is_some());
        if alpha > 1 && policy != SchedulePolicy::Off {
            let u = j.get("pe_utilization").and_then(Json::as_f64).expect("utilization");
            assert!(u > 0.0 && u <= 1.0 + 1e-12);
        } else {
            assert_eq!(j.get("pe_utilization"), Some(&Json::Null));
        }
        frontend.shutdown().expect("graceful shutdown");
    }
}

#[test]
fn v1_route_serves_the_same_bits_as_the_legacy_alias() {
    // /v1/models/demo/infer and the legacy /infer alias are the same model
    // — same pool, same logits, bit for bit.
    let registry = demo_registry(demo_spec(4, SchedulePolicy::ExactCover));
    let frontend = HttpFrontend::start(registry, demo_net()).expect("frontend");
    let addr = frontend.local_addr();
    let (status, legacy) = roundtrip(addr, "POST", "/infer", b"{\"seed\":3}");
    assert_eq!(status, 200);
    let (status, v1) = roundtrip(addr, "POST", "/v1/models/demo/infer", b"{\"seed\":3}");
    assert_eq!(status, 200, "{:?}", String::from_utf8_lossy(&v1));
    let want = proto::logits_from_json(&parse_body(&legacy)).expect("logits");
    let got = proto::logits_from_json(&parse_body(&v1)).expect("logits");
    assert_eq!(
        got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        want.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
    );

    // unknown model: 404 in the structured error schema
    let (status, resp) = roundtrip(addr, "POST", "/v1/models/nope/infer", b"{\"seed\":1}");
    assert_eq!(status, 404);
    let err = parse_body(&resp).get("error").cloned().expect("error object");
    assert_eq!(err.get("code").and_then(Json::as_str), Some("not_found"));
    assert_eq!(err.get("model").and_then(Json::as_str), Some("nope"));

    // the registry listing names the default model and its serving row
    let (status, resp) = roundtrip(addr, "GET", "/v1/models", b"");
    assert_eq!(status, 200);
    let j = parse_body(&resp);
    assert_eq!(j.get("default_model").and_then(Json::as_str), Some("demo"));
    let models = j.get("models").and_then(Json::as_arr).expect("models array");
    assert_eq!(models.len(), 1);
    assert_eq!(models[0].get("name").and_then(Json::as_str), Some("demo"));
    assert_eq!(models[0].get("status").and_then(Json::as_str), Some("serving"));
    assert_eq!(models[0].get("generation").and_then(Json::as_usize), Some(1));

    // per-model metrics carry the admission block and generation
    let (status, resp) = roundtrip(addr, "GET", "/v1/models/demo/metrics", b"");
    assert_eq!(status, 200);
    let j = parse_body(&resp);
    assert_eq!(j.get("model").and_then(Json::as_str), Some("demo"));
    assert_eq!(j.get("generation").and_then(Json::as_usize), Some(1));
    let adm = j.get("admission").expect("admission block");
    assert!(adm.get("admitted").and_then(Json::as_usize).unwrap() >= 2);
    assert_eq!(adm.get("rejected").and_then(Json::as_usize), Some(0));
    frontend.shutdown().expect("shutdown");
}

#[test]
fn seed_body_matches_explicit_tensor_inference() {
    // {"seed":n} asks the server to synthesize the image — same bits as
    // sending the tensor explicitly (tiny loadgen bodies, same numerics).
    let registry = demo_registry(demo_spec(4, SchedulePolicy::ExactCover));
    let client = demo_client(&registry);
    let img = Tensor::randn(&DEMO_SHAPE, &mut Pcg32::new(3), 1.0);
    let want = client.infer(img).expect("infer").logits;
    let frontend = HttpFrontend::start(registry, demo_net()).expect("frontend");
    let (status, resp) = roundtrip(frontend.local_addr(), "POST", "/infer", b"{\"seed\":3}");
    assert_eq!(status, 200);
    let got = proto::logits_from_json(&parse_body(&resp)).expect("logits");
    assert_eq!(
        got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        want.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
    );
    frontend.shutdown().expect("shutdown");
}

#[test]
fn http_batched_request_bit_identical_to_in_process_client() {
    // A {"batch":[…]} body answers {"results":[…]} in request order, each
    // image bit-identical to the in-process Client path.
    let registry = demo_registry(demo_spec(4, SchedulePolicy::ExactCover));
    let client = demo_client(&registry);
    let want: Vec<Vec<f32>> = [3u64, 9, 3]
        .iter()
        .map(|&s| {
            client.infer(Tensor::randn(&DEMO_SHAPE, &mut Pcg32::new(s), 1.0)).unwrap().logits
        })
        .collect();
    let frontend = HttpFrontend::start(registry, demo_net()).expect("frontend");
    let addr = frontend.local_addr();
    let (status, resp) =
        roundtrip(addr, "POST", "/infer", br#"{"batch":[{"seed":3},{"seed":9},{"seed":3}]}"#);
    assert_eq!(status, 200, "{:?}", String::from_utf8_lossy(&resp));
    let j = parse_body(&resp);
    let results = j.get("results").and_then(Json::as_arr).expect("results array");
    assert_eq!(results.len(), 3);
    for (i, (r, want)) in results.iter().zip(&want).enumerate() {
        let got = proto::logits_from_json(r).expect("logits");
        assert_eq!(
            got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "batch image {i} diverged over the wire"
        );
        assert!(r.get("per_image_us").and_then(Json::as_f64).is_some());
        assert!(r.get("batch_size").and_then(Json::as_usize).unwrap() >= 1);
    }

    // one bad element fails the whole batched request, naming the index
    let (status, resp) =
        roundtrip(addr, "POST", "/infer", br#"{"batch":[{"seed":1},{}]}"#);
    assert_eq!(status, 400);
    assert!(String::from_utf8_lossy(&resp).contains("batch image 1"));

    // /metrics surfaces the batch-size histogram and per-image percentiles
    let (status, resp) = roundtrip(addr, "GET", "/metrics", b"");
    assert_eq!(status, 200);
    let merged = parse_body(&resp).get("merged").cloned().expect("merged block");
    let hist = merged.get("batch_hist").and_then(Json::as_arr).expect("batch_hist");
    assert!(!hist.is_empty(), "histogram empty after served batches");
    assert!(merged.get("per_image_p50_us").and_then(Json::as_f64).is_some());
    frontend.shutdown().expect("shutdown");
}

#[test]
fn healthz_metrics_and_drain_lifecycle() {
    let frontend = start_frontend(demo_spec(4, SchedulePolicy::ExactCover), demo_net());
    let addr = frontend.local_addr();

    let (status, body) = roundtrip(addr, "GET", "/healthz", b"");
    assert_eq!(status, 200);
    assert_eq!(parse_body(&body).get("status").and_then(Json::as_str), Some("ok"));

    let (status, _) = roundtrip(addr, "POST", "/infer", b"{\"seed\":1}");
    assert_eq!(status, 200);

    let (status, body) = roundtrip(addr, "GET", "/metrics", b"");
    assert_eq!(status, 200);
    let j = parse_body(&body);
    let merged = j.get("merged").expect("merged block");
    assert!(merged.get("count").and_then(Json::as_usize).unwrap() >= 1);
    assert!(merged.get("p50_us").and_then(Json::as_f64).unwrap() > 0.0);
    // the queue/execute breakdown rides in the snapshot…
    assert!(merged.get("queue_p50_us").and_then(Json::as_f64).is_some());
    assert!(merged.get("execute_p50_us").and_then(Json::as_f64).is_some());
    // …and so does the Alg. 2 schedule-quality block (pruned + scheduled)
    let sched = merged.get("schedule").expect("schedule block");
    assert_eq!(sched.get("scheduler").and_then(Json::as_str), Some("exact-cover"));
    assert_eq!(sched.get("layers").and_then(Json::as_arr).map(<[Json]>::len), Some(2));
    assert!(!j.get("per_worker").and_then(Json::as_arr).unwrap().is_empty());

    // wrong methods and unknown paths answer, never hang
    let (status, _) = roundtrip(addr, "POST", "/healthz", b"");
    assert_eq!(status, 405);
    let (status, _) = roundtrip(addr, "GET", "/infer", b"");
    assert_eq!(status, 405);
    let (status, _) = roundtrip(addr, "GET", "/nope", b"");
    assert_eq!(status, 404);

    // drain: health flips to 503 and new inference is refused while the
    // process keeps answering (load balancers watch exactly this)
    frontend.begin_drain();
    let (status, body) = roundtrip(addr, "GET", "/healthz", b"");
    assert_eq!(status, 503);
    assert_eq!(parse_body(&body).get("status").and_then(Json::as_str), Some("draining"));
    let (status, _) = roundtrip(addr, "POST", "/infer", b"{\"seed\":2}");
    assert_eq!(status, 503);
    frontend.shutdown().expect("shutdown");
}

#[test]
fn overload_returns_429_never_hangs() {
    // max_inflight = 0: every /infer is over budget — deterministic 429
    let frontend = start_frontend(
        ModelSpec { max_inflight: 0, ..demo_spec(1, SchedulePolicy::Off) },
        demo_net(),
    );
    let addr = frontend.local_addr();
    let (status, body) = roundtrip(addr, "POST", "/infer", b"{\"seed\":1}");
    assert_eq!(status, 429, "{:?}", String::from_utf8_lossy(&body));
    // a batch draws one in-flight slot per image — over budget is 429 too
    let (status, _) = roundtrip(addr, "POST", "/infer", br#"{"batch":[{"seed":1},{"seed":2}]}"#);
    assert_eq!(status, 429);
    // health and metrics stay reachable under inference overload
    let (status, _) = roundtrip(addr, "GET", "/healthz", b"");
    assert_eq!(status, 200);
    frontend.shutdown().expect("shutdown");

    // closed-loop storm above the bound: every request completes (ok or
    // 429) — the admission gate sheds load instead of hanging
    let frontend = start_frontend(
        ModelSpec { max_inflight: 2, ..demo_spec(1, SchedulePolicy::Off) },
        demo_net(),
    );
    let report = loadgen::run(&LoadGenConfig {
        addr: frontend.local_addr().to_string(),
        mode: LoadMode::Closed { concurrency: 8 },
        requests: 24,
        timeout: Duration::from_secs(30),
        ..LoadGenConfig::default()
    })
    .expect("loadgen runs");
    assert_eq!(report.sent, 24);
    assert_eq!(report.failed, 0, "overload must surface as 429, not errors");
    assert_eq!(report.ok + report.rejected, 24);
    assert!(report.ok >= 1, "some requests fit the in-flight budget");
    assert!(report.throughput() > 0.0);
    frontend.shutdown().expect("shutdown");
}

#[test]
fn loadgen_closed_loop_over_the_pool_succeeds_fully() {
    // The CI smoke contract: a pooled server under its admission bound
    // serves a closed-loop run at 100% success with sane percentiles.
    let frontend = start_frontend(
        ModelSpec { workers: 2, ..demo_spec(4, SchedulePolicy::ExactCover) },
        demo_net(),
    );
    let report = loadgen::run(&LoadGenConfig {
        addr: frontend.local_addr().to_string(),
        mode: LoadMode::Closed { concurrency: 3 },
        requests: 12,
        timeout: Duration::from_secs(60),
        ..LoadGenConfig::default()
    })
    .expect("loadgen runs");
    assert_eq!(report.ok, 12, "100% success under the admission bound");
    assert!(report.p50().unwrap() <= report.p99().unwrap());
    assert!(report.throughput() > 0.0);
    let text = report.report();
    assert!(text.contains("p50=") && text.contains("p95=") && text.contains("p99="));
    frontend.shutdown().expect("shutdown");
}

#[test]
fn loadgen_v1_model_route_succeeds_fully() {
    // the loadgen's --model path drives /v1/models/<name>/infer
    let frontend = start_frontend(demo_spec(4, SchedulePolicy::ExactCover), demo_net());
    let report = loadgen::run(&LoadGenConfig {
        addr: frontend.local_addr().to_string(),
        mode: LoadMode::Closed { concurrency: 2 },
        requests: 8,
        models: vec!["demo".to_string()],
        timeout: Duration::from_secs(60),
        ..LoadGenConfig::default()
    })
    .expect("loadgen runs");
    assert_eq!(report.ok, 8, "every /v1 request succeeds");
    frontend.shutdown().expect("shutdown");
}

#[test]
fn open_loop_measures_from_scheduled_arrival() {
    let frontend = start_frontend(demo_spec(1, SchedulePolicy::Off), demo_net());
    let report = loadgen::run(&LoadGenConfig {
        addr: frontend.local_addr().to_string(),
        mode: LoadMode::Open { rate_hz: 50.0 },
        requests: 10,
        timeout: Duration::from_secs(30),
        ..LoadGenConfig::default()
    })
    .expect("loadgen runs");
    assert_eq!(report.sent, 10);
    assert_eq!(report.ok, 10);
    // ~10 requests at 50/s arrive over ≥180ms regardless of service time
    assert!(report.elapsed >= Duration::from_millis(150), "{:?}", report.elapsed);
    frontend.shutdown().expect("shutdown");
}

#[test]
fn numerics_modes_agree_over_the_wire() {
    // Reference leg: f64 full-plane. The reply and the metrics snapshot
    // both name the numerics mode the pool runs at.
    let spec = ModelSpec {
        engine: EngineOptions::builder()
            .scheduler(SchedulePolicy::ExactCover)
            .dtype(Some(Dtype::F64))
            .build(),
        ..demo_spec(4, SchedulePolicy::ExactCover)
    };
    let frontend = start_frontend(spec, demo_net());
    let addr = frontend.local_addr();
    let (status, resp) = roundtrip(addr, "POST", "/infer", b"{\"seed\":3}");
    assert_eq!(status, 200, "{:?}", String::from_utf8_lossy(&resp));
    let j = parse_body(&resp);
    assert_eq!(j.get("dtype").and_then(Json::as_str), Some("f64"));
    assert_eq!(j.get("plane").and_then(Json::as_str), Some("full"));
    let want = proto::logits_from_json(&j).expect("logits");
    let (status, resp) = roundtrip(addr, "GET", "/metrics", b"");
    assert_eq!(status, 200);
    let m = parse_body(&resp);
    assert_eq!(m.get("dtype").and_then(Json::as_str), Some("f64"));
    assert_eq!(m.get("plane").and_then(Json::as_str), Some("full"));
    frontend.shutdown().expect("shutdown");

    // Fast-path leg: f32 on the rfft2 half-plane — the production mode —
    // stays within the documented 2e-3 of the f64 reference over the wire.
    let spec = ModelSpec {
        engine: EngineOptions::builder()
            .scheduler(SchedulePolicy::ExactCover)
            .plane(Plane::Half)
            .build(),
        ..demo_spec(4, SchedulePolicy::ExactCover)
    };
    let frontend = start_frontend(spec, demo_net());
    let (status, resp) = roundtrip(frontend.local_addr(), "POST", "/infer", b"{\"seed\":3}");
    assert_eq!(status, 200, "{:?}", String::from_utf8_lossy(&resp));
    let j = parse_body(&resp);
    assert_eq!(j.get("dtype").and_then(Json::as_str), Some("f32"));
    assert_eq!(j.get("plane").and_then(Json::as_str), Some("half"));
    let got = proto::logits_from_json(&j).expect("logits");
    assert_eq!(got.len(), want.len());
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert!(
            (g - w).abs() < 2e-3,
            "logit {i}: f32-half {g} vs f64-full {w} diverged over the wire"
        );
    }
    frontend.shutdown().expect("shutdown");
}

#[test]
fn wrong_shape_tensor_is_a_400_not_a_crash() {
    let frontend = start_frontend(demo_spec(1, SchedulePolicy::Off), demo_net());
    let addr = frontend.local_addr();
    // structurally valid JSON, semantically wrong shape for the variant
    let img = Tensor::zeros(&[3, 16, 16]);
    let body = proto::tensor_to_json(&img).to_string();
    let (status, resp) = roundtrip(addr, "POST", "/infer", body.as_bytes());
    assert_eq!(status, 400, "{:?}", String::from_utf8_lossy(&resp));
    assert!(parse_body(&resp).get("error").is_some());
    // the pool survives and keeps serving
    let (status, _) = roundtrip(addr, "POST", "/infer", b"{\"seed\":5}");
    assert_eq!(status, 200);
    frontend.shutdown().expect("shutdown");
}
