//! Integration: the PJRT runtime + inference engine over the real AOT
//! artifacts. Requires `make artifacts`; tests skip (with a loud message)
//! when `artifacts/manifest.json` is absent so `cargo test` stays green in
//! a fresh checkout.

use spectral_flow::coordinator::{InferenceEngine, WeightMode};
use spectral_flow::runtime::Runtime;
use spectral_flow::util::check::assert_allclose;

fn artifacts_dir() -> Option<String> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
        Some(dir.to_string())
    } else {
        eprintln!("SKIP: run `make artifacts` to enable runtime e2e tests");
        None
    }
}

#[test]
fn manifest_loads_and_validates() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::open(&dir).unwrap();
    assert_eq!(rt.manifest.fft_size, 8);
    assert_eq!(rt.manifest.kernel_k, 3);
    assert_eq!(rt.manifest.tile, 6);
    for v in ["demo", "vgg16-cifar", "vgg16-224"] {
        assert!(rt.manifest.variants.contains_key(v), "missing variant {v}");
    }
    assert_eq!(rt.manifest.variant("vgg16-224").unwrap().layers.len(), 13);
}

#[test]
fn demo_executables_compile_and_cache() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::open(&dir).unwrap();
    let n = rt.warm_variant("demo").unwrap();
    assert_eq!(n, 2);
    assert_eq!(rt.cached_executables(), 2);
    // second warm hits the cache (no recompilation, count unchanged)
    rt.warm_variant("demo").unwrap();
    assert_eq!(rt.cached_executables(), 2);
}

#[test]
fn spectral_conv_via_pjrt_matches_spatial_reference() {
    // THE cross-layer correctness gate: JAX/Pallas-lowered executable
    // (FFT → Hadamard → IFFT) + Rust tiling/OaA == naive spatial conv.
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = InferenceEngine::new(&dir, "demo", WeightMode::Dense, 1234).unwrap();
    let img = engine.synthetic_image(5);
    let got = engine.conv_layer(0, &img).unwrap();
    let want = engine.conv_layer_reference(0, &img).unwrap();
    assert_allclose(got.data(), want.data(), 1e-3, 1e-3);
    // layer 2 as well (8→8 channels at 8×8 spatial)
    let x2 = spectral_flow::nn::maxpool2(&got);
    let got2 = engine.conv_layer(1, &x2).unwrap();
    let want2 = engine.conv_layer_reference(1, &x2).unwrap();
    assert_allclose(got2.data(), want2.data(), 1e-3, 1e-2);
}

#[test]
fn forward_deterministic_and_shaped() {
    let Some(dir) = artifacts_dir() else { return };
    let mut e1 = InferenceEngine::new(&dir, "demo", WeightMode::Pruned { alpha: 4 }, 7).unwrap();
    let mut e2 = InferenceEngine::new(&dir, "demo", WeightMode::Pruned { alpha: 4 }, 7).unwrap();
    let img = e1.synthetic_image(3);
    let a = e1.forward(&img).unwrap();
    let b = e2.forward(&img).unwrap();
    assert_eq!(a.len(), 10);
    assert_allclose(&a, &b, 1e-6, 1e-6);
}

#[test]
fn forward_rejects_bad_shapes() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = InferenceEngine::new(&dir, "demo", WeightMode::Dense, 7).unwrap();
    let bad = spectral_flow::tensor::Tensor::zeros(&[1, 8, 8]);
    assert!(engine.forward(&bad).is_err());
}

#[test]
fn cifar_vgg16_full_forward() {
    let Some(dir) = artifacts_dir() else { return };
    let t0 = std::time::Instant::now();
    let mut engine =
        InferenceEngine::new(&dir, "vgg16-cifar", WeightMode::Pruned { alpha: 4 }, 7).unwrap();
    let img = engine.synthetic_image(1);
    let logits = engine.forward(&img).unwrap();
    assert_eq!(logits.len(), 10);
    assert!(logits.iter().all(|v| v.is_finite()));
    eprintln!("cifar forward total {:?}", t0.elapsed());
}
