//! Integration: the runtime + inference engine end to end on the default
//! `interp` backend. Runs fully offline: when `artifacts/manifest.json` is
//! absent the runtime synthesizes the built-in manifest, so nothing here
//! needs `make artifacts` (the PJRT path reuses the same engine behind the
//! `pjrt` feature).

use spectral_flow::coordinator::{InferenceEngine, WeightMode};
use spectral_flow::runtime::Runtime;
use spectral_flow::util::check::assert_allclose;

fn artifacts_dir() -> String {
    // Real artifacts are used when present; otherwise the built-in
    // manifest kicks in and the directory never needs to exist.
    concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").to_string()
}

#[test]
fn manifest_loads_and_validates() {
    let rt = Runtime::open(artifacts_dir()).unwrap();
    assert_eq!(rt.manifest.fft_size, 8);
    assert_eq!(rt.manifest.kernel_k, 3);
    assert_eq!(rt.manifest.tile, 6);
    for v in ["demo", "vgg16-cifar", "vgg16-224"] {
        assert!(rt.manifest.variants.contains_key(v), "missing variant {v}");
    }
    assert_eq!(rt.manifest.variant("vgg16-224").unwrap().layers.len(), 13);
}

#[test]
fn demo_executables_prepare_and_cache() {
    let mut rt = Runtime::open(artifacts_dir()).unwrap();
    let n = rt.warm_variant("demo").unwrap();
    assert_eq!(n, 2);
    assert_eq!(rt.cached_executables(), 2);
    // second warm hits the cache (no re-preparation, count unchanged)
    rt.warm_variant("demo").unwrap();
    assert_eq!(rt.cached_executables(), 2);
}

#[test]
fn spectral_conv_via_backend_matches_spatial_reference() {
    // THE cross-layer correctness gate: the backend's spectral pipeline
    // (FFT → Hadamard → IFFT) + Rust tiling/OaA == naive spatial conv.
    let dir = artifacts_dir();
    let mut engine = InferenceEngine::new(&dir, "demo", WeightMode::Dense, 1234).unwrap();
    let img = engine.synthetic_image(5);
    let got = engine.conv_layer(0, &img).unwrap();
    let want = engine.conv_layer_reference(0, &img).unwrap();
    assert_allclose(got.data(), want.data(), 1e-3, 1e-3);
    // layer 2 as well (8→8 channels at 8×8 spatial)
    let x2 = spectral_flow::nn::maxpool2(&got);
    let got2 = engine.conv_layer(1, &x2).unwrap();
    let want2 = engine.conv_layer_reference(1, &x2).unwrap();
    assert_allclose(got2.data(), want2.data(), 1e-3, 1e-2);
}

#[test]
fn forward_deterministic_and_shaped() {
    let dir = artifacts_dir();
    let mut e1 = InferenceEngine::new(&dir, "demo", WeightMode::Pruned { alpha: 4 }, 7).unwrap();
    let mut e2 = InferenceEngine::new(&dir, "demo", WeightMode::Pruned { alpha: 4 }, 7).unwrap();
    let img = e1.synthetic_image(3);
    let a = e1.forward(&img).unwrap();
    let b = e2.forward(&img).unwrap();
    assert_eq!(a.len(), 10);
    assert_allclose(&a, &b, 1e-6, 1e-6);
}

#[test]
fn pruned_engine_conv_matches_dense_plane_reference() {
    // The sparse execution gate at engine level: a Pruned engine uploads
    // CSR kernels and runs the sparse MAC; pushing the *same* spectral
    // planes (pruned slots as explicit zeros) through a dense upload must
    // produce the same layer output. This pins the whole sparse path —
    // CSR build, dataflow hint, blocked MAC — against the dense semantics.
    use spectral_flow::fft::{im2tiles, overlap_add, TileGeometry};
    use spectral_flow::nn;
    use spectral_flow::runtime::{
        freq_major_planes, ExecutableEntry, InterpBackend, SpectralBackend,
    };

    let mut engine =
        InferenceEngine::new(&artifacts_dir(), "demo", WeightMode::Pruned { alpha: 4 }, 99)
            .unwrap();
    let planes = engine.weights.convs[0].spectral.clone();
    let bias = engine.weights.convs[0].bias.clone();
    let img = engine.synthetic_image(2);
    let got = engine.conv_layer(0, &img).unwrap();

    let geo = TileGeometry::new(16, 8, 3);
    let tiles = im2tiles(&img, &geo);
    let entry = ExecutableEntry {
        tiles: geo.num_tiles(),
        cin: 1,
        cout: 8,
        fft_size: 8,
        sha256: "ref".into(),
        bytes: 0,
    };
    let mut b = InterpBackend::new();
    b.prepare("ref", &entry, std::path::Path::new(".")).unwrap();
    let (re, im) = freq_major_planes(&planes);
    let wid = b.upload_weights(&re, &im, [64, 1, 8]).unwrap();
    let out_tiles = b.run_conv("ref", &tiles, wid).unwrap();
    let mut want = overlap_add(&out_tiles, &geo, 8);
    nn::add_bias(&mut want, &bias);
    nn::relu(&mut want);
    assert_allclose(got.data(), want.data(), 1e-4, 1e-4);
}

#[test]
fn scheduler_modes_bit_identical_across_threads_and_alpha() {
    // Tentpole acceptance gate: the scheduled sparse MAC (either policy)
    // must reproduce the unscheduled PR 3 walk bit for bit at the full
    // engine level, for every backend thread count, at α ∈ {1, 4} (α=1 is
    // the dense MAC — scheduling must be a no-op there too).
    use spectral_flow::runtime::BackendKind;
    use spectral_flow::schedule::SchedulePolicy;
    let dir = artifacts_dir();
    for alpha in [1usize, 4] {
        let mode = WeightMode::from_alpha(alpha);
        let forward = |policy: SchedulePolicy, threads: usize| {
            let mut e = InferenceEngine::new_with_opts(
                &dir,
                "demo",
                mode,
                7,
                BackendKind::Interp { threads },
                policy,
            )
            .unwrap();
            let img = e.synthetic_image(4);
            e.forward(&img).unwrap()
        };
        let baseline = forward(SchedulePolicy::Off, 1);
        for policy in
            [SchedulePolicy::Off, SchedulePolicy::ExactCover, SchedulePolicy::LowestIndex]
        {
            for threads in [1usize, 3] {
                let got = forward(policy, threads);
                assert_eq!(
                    got, baseline,
                    "α={alpha} {policy:?} threads={threads} diverged bit-wise"
                );
            }
        }
    }
}

#[test]
fn scheduled_pruned_engine_close_to_dense_planes() {
    // α=4 scheduled execution vs the same spectral planes (pruned slots as
    // explicit zeros) through a dense engine-level upload: ≤1e-5. This is
    // the dense-equivalence half of the acceptance gate; bit-identity to
    // the unscheduled sparse walk is checked above.
    use spectral_flow::fft::{im2tiles, overlap_add, TileGeometry};
    use spectral_flow::nn;
    use spectral_flow::runtime::{
        freq_major_planes, ExecutableEntry, InterpBackend, SpectralBackend,
    };
    let mut engine =
        InferenceEngine::new(&artifacts_dir(), "demo", WeightMode::Pruned { alpha: 4 }, 55)
            .unwrap();
    assert!(engine.schedule_metrics().is_some(), "default policy schedules pruned layers");
    let planes = engine.weights.convs[0].spectral.clone();
    let bias = engine.weights.convs[0].bias.clone();
    let img = engine.synthetic_image(6);
    let got = engine.conv_layer(0, &img).unwrap();

    let geo = TileGeometry::new(16, 8, 3);
    let tiles = im2tiles(&img, &geo);
    let entry = ExecutableEntry {
        tiles: geo.num_tiles(),
        cin: 1,
        cout: 8,
        fft_size: 8,
        sha256: "ref".into(),
        bytes: 0,
    };
    let mut b = InterpBackend::new();
    b.prepare("ref", &entry, std::path::Path::new(".")).unwrap();
    let (re, im) = freq_major_planes(&planes);
    let wid = b.upload_weights(&re, &im, [64, 1, 8]).unwrap();
    let out_tiles = b.run_conv("ref", &tiles, wid).unwrap();
    let mut want = overlap_add(&out_tiles, &geo, 8);
    nn::add_bias(&mut want, &bias);
    nn::relu(&mut want);
    assert_allclose(got.data(), want.data(), 1e-5, 1e-5);
}

#[test]
fn engine_schedule_metrics_shape() {
    use spectral_flow::runtime::BackendKind;
    use spectral_flow::schedule::SchedulePolicy;
    let dir = artifacts_dir();
    // pruned + exact-cover: one entry per conv layer, sane aggregates
    let e = InferenceEngine::new(&dir, "demo", WeightMode::Pruned { alpha: 4 }, 7).unwrap();
    let sm = e.schedule_metrics().unwrap();
    assert_eq!(sm.scheduler, "exact-cover");
    assert_eq!(sm.layers.len(), e.variant.layers.len());
    for l in &sm.layers {
        assert!(l.stats.cycles >= l.stats.lower_bound, "{}", l.layer);
        let u = l.stats.pe_utilization();
        assert!(u > 0.0 && u <= 1.0 + 1e-12, "{}: {u}", l.layer);
    }
    assert!(sm.report().contains("exact-cover"));
    // scheduler off / dense mode: no metrics
    let off = InferenceEngine::new_with_opts(
        &dir,
        "demo",
        WeightMode::Pruned { alpha: 4 },
        7,
        BackendKind::default(),
        SchedulePolicy::Off,
    )
    .unwrap();
    assert!(off.schedule_metrics().is_none());
    assert_eq!(off.scheduler(), SchedulePolicy::Off);
    let dense = InferenceEngine::new(&dir, "demo", WeightMode::Dense, 7).unwrap();
    assert!(dense.schedule_metrics().is_none());
}

#[test]
fn forward_batch_bit_identical_to_serial_forwards() {
    // Batch-major acceptance gate at engine level: `forward_batch` must
    // equal B independent `forward` calls bit for bit, across α ∈ {1, 4} ×
    // scheduler policies × backend thread counts — and across `plan_batch`
    // values, which change the dataflow blocking but never the arithmetic.
    use spectral_flow::coordinator::EngineOptions;
    use spectral_flow::runtime::BackendKind;
    use spectral_flow::schedule::SchedulePolicy;
    let dir = artifacts_dir();
    for (alpha, policy) in [
        (1usize, SchedulePolicy::Off),
        (4, SchedulePolicy::ExactCover),
        (4, SchedulePolicy::LowestIndex),
        (4, SchedulePolicy::Off),
    ] {
        for threads in [1usize, 3] {
            for plan_batch in [1usize, 4] {
                let mut e = InferenceEngine::with_options(
                    &dir,
                    "demo",
                    WeightMode::from_alpha(alpha),
                    7,
                    EngineOptions {
                        backend: BackendKind::Interp { threads },
                        scheduler: policy,
                        plan_batch,
                        ..EngineOptions::default()
                    },
                )
                .unwrap();
                let images: Vec<_> = (1u64..=4).map(|s| e.synthetic_image(s)).collect();
                let want: Vec<Vec<f32>> =
                    images.iter().map(|img| e.forward(img).unwrap()).collect();
                let got = e.forward_batch(&images).unwrap();
                assert_eq!(
                    got, want,
                    "α={alpha} {policy:?} threads={threads} plan_batch={plan_batch}: \
                     batched forward diverged from serial"
                );
            }
        }
    }
}

#[test]
fn forward_batch_rejects_any_bad_image() {
    // one mis-shaped image anywhere rejects the whole fused call (the
    // serving worker pre-screens with `check_input` for per-request errors)
    let mut e = InferenceEngine::new(&artifacts_dir(), "demo", WeightMode::Dense, 7).unwrap();
    let good = e.synthetic_image(1);
    let bad = spectral_flow::tensor::Tensor::zeros(&[1, 8, 8]);
    assert!(e.forward_batch(&[good.clone(), bad.clone()]).is_err());
    assert!(e.check_input(&bad).is_err());
    assert!(e.check_input(&good).is_ok());
    // empty batch is a no-op, not an error
    assert_eq!(e.forward_batch(&[]).unwrap(), Vec::<Vec<f32>>::new());
}

#[test]
fn forward_rejects_bad_shapes() {
    let mut engine = InferenceEngine::new(&artifacts_dir(), "demo", WeightMode::Dense, 7).unwrap();
    let bad = spectral_flow::tensor::Tensor::zeros(&[1, 8, 8]);
    assert!(engine.forward(&bad).is_err());
}

#[test]
fn unknown_variant_rejected() {
    assert!(InferenceEngine::new(&artifacts_dir(), "nope", WeightMode::Dense, 7).is_err());
}

#[test]
fn cifar_vgg16_full_forward() {
    let t0 = std::time::Instant::now();
    let mut engine =
        InferenceEngine::new(&artifacts_dir(), "vgg16-cifar", WeightMode::Pruned { alpha: 4 }, 7)
            .unwrap();
    let img = engine.synthetic_image(1);
    let logits = engine.forward(&img).unwrap();
    assert_eq!(logits.len(), 10);
    assert!(logits.iter().all(|v| v.is_finite()));
    eprintln!("cifar forward total {:?}", t0.elapsed());
}
