//! Integration: the cycle-level simulator end to end — Table 3's
//! qualitative claims on the real VGG16 workload (CIFAR scale for speed;
//! the 224 rows run in `cargo bench --bench bench_simulator`).

use spectral_flow::analysis::{transfers_flex, ArchParams, LayerParams, StreamParams};
use spectral_flow::model::Network;
use spectral_flow::schedule::Scheduler;
use spectral_flow::sim::baselines::{run_baseline, BaselineConfig, FixedStream};
use spectral_flow::sim::{estimate_resources, simulate_layer, SimConfig};
use spectral_flow::sparse::prune_magnitude;
use spectral_flow::util::rng::Pcg32;

#[test]
fn ddr_accounting_matches_eq13_on_vgg_layers() {
    // The FSM's transfer accounting must telescope to the closed form for
    // every layer and several streaming settings.
    let net = Network::vgg16_cifar();
    let arch = ArchParams { p_par: 4, n_par: 32, replicas: 8 };
    let cfg = SimConfig { sample_groups: Some(4), ..SimConfig::default() };
    let mut rng = Pcg32::new(0);
    for conv in net.optimized_convs().iter().take(6) {
        let sparse = prune_magnitude(conv.cout, conv.cin, conv.fft, 4, &mut rng);
        let p = conv.num_tiles();
        for stream in [
            StreamParams { ns: conv.cout, ps: p },
            StreamParams { ns: 32.min(conv.cout), ps: p },
            StreamParams { ns: conv.cout, ps: 4.min(p) },
        ] {
            let res = simulate_layer(conv, &sparse, &arch, &stream, &cfg);
            let l = LayerParams::from_layer(conv, 4);
            let want = transfers_flex(&l, &stream).total() * cfg.word_bytes;
            assert_eq!(res.ddr_bytes, want, "{} {stream:?}", conv.name);
        }
    }
}

#[test]
fn flexible_plan_beats_fixed_flows_in_sim() {
    let net = Network::vgg16_cifar();
    let ours = run_baseline(&BaselineConfig::this_work(), &net, Some(6), 1);
    let mut k_cfg = BaselineConfig::this_work();
    k_cfg.fixed_stream = Some(FixedStream::StreamKernels);
    let kfixed = run_baseline(&k_cfg, &net, Some(6), 1);
    assert!(ours.total_ddr_bytes() < kfixed.total_ddr_bytes());
    assert!(ours.latency_secs() <= kfixed.latency_secs() * 1.02);
}

#[test]
fn scheduler_choice_moves_latency_not_bytes() {
    let net = Network::vgg16_cifar();
    let mut li = BaselineConfig::this_work();
    li.scheduler = Scheduler::LowestIndexFirst;
    li.arch.replicas = 6;
    let mut ec = BaselineConfig::this_work();
    ec.arch.replicas = 6;
    let r_li = run_baseline(&li, &net, Some(6), 2);
    let r_ec = run_baseline(&ec, &net, Some(6), 2);
    assert_eq!(r_li.total_ddr_bytes(), r_ec.total_ddr_bytes());
    assert!(r_ec.avg_pe_utilization() > r_li.avg_pe_utilization());
    assert!(r_ec.latency_secs() <= r_li.latency_secs());
}

#[test]
fn latency_scales_with_clock() {
    let net = Network::demo();
    let mut rng = Pcg32::new(3);
    let sparse: Vec<_> = net
        .convs
        .iter()
        .map(|c| prune_magnitude(c.cout, c.cin, c.fft, 4, &mut rng))
        .collect();
    let arch = ArchParams { p_par: 2, n_par: 4, replicas: 8 };
    let layers: Vec<_> = net
        .convs
        .iter()
        .zip(&sparse)
        .map(|(c, s)| (c, s, StreamParams { ns: c.cout, ps: c.num_tiles() }))
        .collect();
    let fast = SimConfig { clock_hz: 400e6, ddr_bytes_per_sec: 1e12, sample_groups: None, ..SimConfig::default() };
    let slow = SimConfig { clock_hz: 200e6, ddr_bytes_per_sec: 1e12, sample_groups: None, ..SimConfig::default() };
    let rf = spectral_flow::sim::simulate_network(&layers, &arch, &fast);
    let rs = spectral_flow::sim::simulate_network(&layers, &arch, &slow);
    let ratio = rs.latency_secs() / rf.latency_secs();
    assert!((ratio - 2.0).abs() < 0.05, "clock scaling ratio {ratio}");
}

#[test]
fn required_bandwidth_consistent_with_compute_bound() {
    // Give the sim exactly the bandwidth it says it needs: the run must be
    // compute-bound (total ≈ compute + fill).
    let net = Network::vgg16_cifar();
    let conv = &net.convs[5];
    let mut rng = Pcg32::new(4);
    let sparse = prune_magnitude(conv.cout, conv.cin, conv.fft, 4, &mut rng);
    let arch = ArchParams::paper();
    let stream = StreamParams { ns: conv.cout, ps: conv.num_tiles() };
    let probe = SimConfig { sample_groups: Some(8), ..SimConfig::default() };
    let r0 = simulate_layer(conv, &sparse, &arch, &stream, &probe);
    let need = r0.saturating_bandwidth(probe.clock_hz);
    let tuned = SimConfig { ddr_bytes_per_sec: need * 1.01, ..probe };
    let r1 = simulate_layer(conv, &sparse, &arch, &stream, &tuned);
    assert!(r1.total_cycles <= r1.compute_cycles() + r1.fill_cycles + 1);
}

#[test]
fn resource_estimate_fits_u200_for_paper_plan() {
    use spectral_flow::dataflow::{optimize_network_at, OptimizerConfig};
    let net = Network::vgg16_224();
    let plan = optimize_network_at(&net, ArchParams::paper(), &OptimizerConfig::paper()).unwrap();
    let plans: Vec<_> = plan.layers.iter().map(|l| (l.params, l.stream)).collect();
    let r = estimate_resources(&ArchParams::paper(), &plans, 8);
    assert!(r.fits_u200(), "{}", r.utilization_report());
    assert!(r.dsp >= 2000, "PE array should dominate DSPs: {}", r.dsp);
}

#[test]
fn dense_alpha1_is_much_slower() {
    let net = Network::vgg16_cifar();
    let ours = run_baseline(&BaselineConfig::this_work(), &net, Some(6), 5);
    let dense = run_baseline(&BaselineConfig::dense_spectral_26(), &net, Some(6), 5);
    assert!(dense.latency_secs() > 2.0 * ours.latency_secs());
}
