//! Integration: exact-cover scheduler vs baselines on paper-scale kernel
//! groups — the Fig. 8/9/10 claims, plus exhaustive invariant fuzzing.

use spectral_flow::schedule::tables::compile_tables;
use spectral_flow::schedule::{Schedule, Scheduler};
use spectral_flow::sparse::{prune_magnitude, prune_random};
use spectral_flow::util::check::forall;
use spectral_flow::util::rng::Pcg32;

fn util(sch: Scheduler, kernels: &[Vec<u16>], r: usize, seed: u64) -> f64 {
    sch.run(kernels, r, seed).pe_utilization()
}

#[test]
fn invariants_hold_for_every_scheduler_everywhere() {
    forall("all schedulers valid", 60, |rng| {
        let n = rng.range(1, 65);
        let alpha = [2usize, 4, 8][rng.range(0, 3)];
        let r = rng.range(1, 21);
        let layer = if rng.f32() < 0.5 {
            prune_random(n, 1, 8, alpha, rng)
        } else {
            prune_magnitude(n, 1, 8, alpha, rng)
        };
        let kernels = layer.group_indices(0, n, 0);
        let lb = Schedule::lower_bound(&kernels, r);
        for sch in Scheduler::ALL {
            let s = sch.run(&kernels, r, rng.next_u64());
            s.validate(&kernels).unwrap_or_else(|e| panic!("{sch:?}: {e}"));
            assert!(s.cycles() >= lb, "{sch:?} below lower bound");
            assert!(s.pe_utilization() <= 1.0 + 1e-12);
        }
    });
}

#[test]
fn fig9_paper_point_exact_cover_over_80pct() {
    // Paper Fig 9 (ADMM kernels): exact-cover reaches >80% with r=10 even
    // at α=8 (indices "largely scattered"); lowest-index-first needs r≈16
    // for comparable utilization.
    let mut rng = Pcg32::new(1);
    let layer = prune_magnitude(64, 24, 8, 8, &mut rng);
    let mut ec_sum = 0.0;
    let mut li_sum = 0.0;
    let groups = 24;
    for m in 0..groups {
        let kernels = layer.group_indices(0, 64, m);
        ec_sum += util(Scheduler::ExactCover, &kernels, 10, m as u64);
        li_sum += util(Scheduler::LowestIndexFirst, &kernels, 10, m as u64);
    }
    let (ec, li) = (ec_sum / groups as f64, li_sum / groups as f64);
    assert!(ec > 0.80, "exact-cover at r=10, α=8: {ec}");
    assert!(ec > li, "exact-cover {ec} must beat lowest-index {li}");
    // and lowest-index-first catches up with more replicas (paper: r=16)
    let mut li16 = 0.0;
    for m in 0..groups {
        let kernels = layer.group_indices(0, 64, m);
        li16 += util(Scheduler::LowestIndexFirst, &kernels, 16, m as u64);
    }
    assert!(li16 / groups as f64 > li, "LI must improve with replicas");
}

#[test]
fn fig8_correlated_patterns_help_lowest_index() {
    // Paper: lowest-index-first "deeply relies on the condition that
    // indices in different kernels are close, like kernels in conv5_*".
    // ADMM-like magnitude pruning produces exactly that correlation; the
    // LI gap to exact-cover must shrink vs random patterns.
    let mut rng = Pcg32::new(2);
    let clustered = prune_magnitude(64, 4, 8, 4, &mut rng);
    let random = prune_random(64, 4, 8, 4, &mut rng);
    let gap = |layer: &spectral_flow::sparse::SparseLayer| {
        let mut ec = 0.0;
        let mut li = 0.0;
        for m in 0..4 {
            let k = layer.group_indices(0, 64, m);
            ec += util(Scheduler::ExactCover, &k, 8, m as u64);
            li += util(Scheduler::LowestIndexFirst, &k, 8, m as u64);
        }
        (ec - li) / 4.0
    };
    let g_clustered = gap(&clustered);
    let g_random = gap(&random);
    assert!(
        g_clustered < g_random + 0.02,
        "LI should be closer to EC on clustered patterns: {g_clustered} vs {g_random}"
    );
}

#[test]
fn utilization_monotone_in_replicas_for_exact_cover() {
    forall("EC monotone in r", 20, |rng| {
        let layer = prune_random(32, 1, 8, 4, rng);
        let kernels = layer.group_indices(0, 32, 0);
        let mut prev = 0.0;
        for r in [2usize, 4, 8, 16, 32] {
            let u = util(Scheduler::ExactCover, &kernels, r, 0);
            assert!(u + 1e-9 >= prev, "r={r}: {u} < {prev}");
            prev = u;
        }
        // unconstrained r ⇒ perfect utilization on equal-nnz kernels
        assert!((prev - 1.0).abs() < 1e-9);
    });
}

#[test]
fn k16_kernels_schedule_correctly() {
    let mut rng = Pcg32::new(3);
    let layer = prune_random(32, 1, 16, 4, &mut rng); // 256-point freq plane
    let kernels = layer.group_indices(0, 32, 0);
    let s = Scheduler::ExactCover.run(&kernels, 10, 0);
    s.validate(&kernels).unwrap();
    assert!(s.pe_utilization() > 0.5);
}

#[test]
fn tables_compile_for_all_schedulers() {
    let mut rng = Pcg32::new(4);
    let layer = prune_magnitude(64, 2, 8, 4, &mut rng);
    let kernels = layer.group_indices(0, 64, 1);
    for sch in Scheduler::ALL {
        let s = sch.run(&kernels, 10, 9);
        let t = compile_tables(&s, &layer, 0, 1, 64);
        assert_eq!(t.cycles(), s.cycles());
        let valid: usize = t.value.iter().flatten().filter(|v| v.valid).count();
        assert_eq!(valid as u64, layer.total_nnz() / 2 / 64 * 64); // 64 kernels × 16 nnz at channel 1
    }
}

#[test]
fn identical_rows_forced_conflict_adversarial() {
    // Adversarial case: two kernels share *every* frequency index. At r=1
    // the only conflict-free option is broadcasting one shared index per
    // cycle to both kernels — nnz cycles at 100% utilization. A schedule
    // that instead serves the two rows different indices in one cycle is a
    // forced replica conflict and `Schedule::validate` must reject it.
    use spectral_flow::schedule::{CycleSet, SchedulePolicy};
    let shared: Vec<u16> = vec![2, 7, 11, 40];
    let kernels = vec![shared.clone(), shared.clone()];
    for sch in [Scheduler::ExactCover, Scheduler::LowestIndexFirst] {
        let s = sch.run(&kernels, 1, 9);
        s.validate(&kernels).unwrap_or_else(|e| panic!("{sch:?}: {e}"));
        assert_eq!(s.cycles(), shared.len(), "{sch:?} must broadcast shared indices");
        assert!((s.pe_utilization() - 1.0).abs() < 1e-12);
    }
    // random picks indices independently, so it usually can't broadcast at
    // r=1 — it must still terminate with a valid (longer) schedule
    let s = Scheduler::Random.run(&kernels, 1, 9);
    s.validate(&kernels).unwrap();
    assert!(s.cycles() >= shared.len());
    for policy in [SchedulePolicy::ExactCover, SchedulePolicy::LowestIndex] {
        let s = policy.plan_group(&kernels, 1).unwrap();
        s.validate(&kernels).unwrap();
        assert_eq!(s.cycles(), shared.len());
    }
    // hand-built conflicting schedule: cycle 0 reads index 2 for kernel 0
    // and index 7 for kernel 1 — two distinct indices, one replica
    let bad = Schedule {
        sets: vec![
            CycleSet { reads: vec![(0, 2), (1, 7)] },
            CycleSet { reads: vec![(0, 7), (1, 2)] },
            CycleSet { reads: vec![(0, 11), (1, 11)] },
            CycleSet { reads: vec![(0, 40), (1, 40)] },
        ],
        replicas: 1,
        num_kernels: 2,
    };
    let err = bad.validate(&kernels).unwrap_err();
    assert!(err.contains("C2"), "replica conflict must be flagged: {err}");
}

#[test]
fn ragged_last_group_schedules() {
    // cout=100 with N'=64 → second group has 36 kernels.
    let mut rng = Pcg32::new(5);
    let layer = prune_random(100, 1, 8, 4, &mut rng);
    let kernels = layer.group_indices(1, 64, 0);
    assert_eq!(kernels.len(), 36);
    let s = Scheduler::ExactCover.run(&kernels, 8, 0);
    s.validate(&kernels).unwrap();
}
