//! Activation-arena property tests over randomized residual DAGs.
//!
//! A seeded generator grows random valid activation graphs (convs with
//! optional pooling, residual adds, concats), wraps each in an on-disk
//! manifest, and runs the real engine over it twice — arena slot reuse on
//! and off. Pinned properties:
//!
//! * **No read-after-reuse**: debug builds poison freed slots with NaN and
//!   generation-check every read, so a stale read either trips a
//!   debug_assert or surfaces as a non-finite logit. Every random forward
//!   must come out finite.
//! * **Peak bounds**: reuse peak ≤ the no-reuse sum of all tensors, slot
//!   count ≤ tensor count, and the plan hits the known optimum on the
//!   handmade chain (2 slots) and diamond (3 slots).
//! * **Reuse is invisible to the numbers**: arena forward bit-identical to
//!   the no-reuse forward on every random graph.

use std::fmt::Write as _;

use spectral_flow::coordinator::{ArenaPlan, EngineOptions, InferenceEngine, WeightMode};
use spectral_flow::model::{ConvShape, GraphOp};
use spectral_flow::util::rng::Pcg32;

const FFT: usize = 8;
const K: usize = 3;
const TILE: usize = FFT - K + 1;

/// One randomly grown, valid-by-construction activation graph.
struct RandomGraph {
    layers: Vec<ConvShape>,
    steps: Vec<GraphOp>,
    input_c: usize,
    input_hw: usize,
}

/// Grow a random DAG: convs consume any produced tensor (fan-out allowed),
/// adds/concats join shape-compatible pairs, then a cleanup pass folds
/// every still-unconsumed tensor into the tail so `check_graph`'s
/// every-tensor-consumed rule holds by construction. Spatial sides stay
/// powers of two, so any two loose ends can always be pooled into
/// agreement and concatenated.
fn random_graph(rng: &mut Pcg32) -> RandomGraph {
    let input_c = [1usize, 2, 4][rng.range(0, 3)];
    let input_hw = [8usize, 16][rng.range(0, 2)];
    let mut layers: Vec<ConvShape> = Vec::new();
    let mut steps: Vec<GraphOp> = Vec::new();
    // shape + consumed flag per tensor id (0 = the network input)
    let mut shapes = vec![(input_c, input_hw)];
    let mut consumed = vec![false];

    let push_conv = |layers: &mut Vec<ConvShape>,
                         steps: &mut Vec<GraphOp>,
                         shapes: &mut Vec<(usize, usize)>,
                         consumed: &mut Vec<bool>,
                         input: usize,
                         cout: usize,
                         pool: bool| {
        let (cin, h) = shapes[input];
        steps.push(GraphOp::Conv { conv: layers.len(), input });
        layers.push(ConvShape { cin, cout, h, pool_after: pool });
        consumed[input] = true;
        shapes.push((cout, if pool { h / 2 } else { h }));
        consumed.push(false);
    };

    for _ in 0..rng.range(3, 10) {
        let roll = rng.range(0, 10);
        if roll < 6 {
            // conv off any produced tensor — reading an already-consumed
            // tensor creates the fan-out the arena must keep live
            let input = rng.range(0, shapes.len());
            let cout = [1usize, 2, 4][rng.range(0, 3)];
            let pool = shapes[input].1 % 2 == 0 && shapes[input].1 > 2 && rng.range(0, 3) == 0;
            push_conv(&mut layers, &mut steps, &mut shapes, &mut consumed, input, cout, pool);
        } else if roll < 8 {
            // residual add: any two tensors with identical shapes
            let a = rng.range(0, shapes.len());
            if let Some(b) = (0..shapes.len()).find(|&b| b != a && shapes[b] == shapes[a]) {
                steps.push(GraphOp::Add { a, b });
                consumed[a] = true;
                consumed[b] = true;
                shapes.push(shapes[a]);
                consumed.push(false);
            }
        } else {
            // concat: any two tensors sharing a spatial side
            let a = rng.range(0, shapes.len());
            if let Some(b) = (0..shapes.len()).find(|&b| b != a && shapes[b].1 == shapes[a].1) {
                steps.push(GraphOp::Concat { a, b });
                consumed[a] = true;
                consumed[b] = true;
                shapes.push((shapes[a].0 + shapes[b].0, shapes[a].1));
                consumed.push(false);
            }
        }
    }
    // the random walk can degenerate to zero nodes (every roll picked a
    // join with no compatible pair); give check_graph something to chew on
    if steps.is_empty() {
        push_conv(&mut layers, &mut steps, &mut shapes, &mut consumed, 0, 4, false);
    }
    // cleanup: join every unconsumed tensor into the current tail. Pool
    // whichever side is larger down to the smaller (sides are powers of
    // two, so halving always lands on an even side), then concat. A pooled
    // copy becomes the new tail and the displaced tail becomes a loose end
    // itself, so the loop re-scans until only the final tensor is open.
    loop {
        let last = shapes.len() - 1;
        let Some(t) = (0..last).find(|&t| !consumed[t]) else { break };
        if shapes[t].1 == shapes[last].1 {
            steps.push(GraphOp::Concat { a: t, b: last });
            consumed[t] = true;
            consumed[last] = true;
            shapes.push((shapes[t].0 + shapes[last].0, shapes[t].1));
            consumed.push(false);
        } else if shapes[t].1 < shapes[last].1 {
            // shrink the tail toward the loose end
            let cout = shapes[last].0;
            push_conv(&mut layers, &mut steps, &mut shapes, &mut consumed, last, cout, true);
        } else {
            // shrink the loose end (one pooled conv per pass)
            let cout = shapes[t].0;
            push_conv(&mut layers, &mut steps, &mut shapes, &mut consumed, t, cout, true);
        }
    }
    RandomGraph { layers, steps, input_c, input_hw }
}

/// Serialize a random graph as a manifest.json the runtime can open. The
/// interp backend never reads executable files, so registering shapes is
/// enough.
fn manifest_json(g: &RandomGraph) -> String {
    let mut layers = String::new();
    let mut execs = String::new();
    for (i, l) in g.layers.iter().enumerate() {
        let side = l.h.div_ceil(TILE);
        let tiles = side * side;
        if i > 0 {
            layers.push(',');
            execs.push(',');
        }
        write!(
            layers,
            r#"{{"name":"conv{i}","cin":{},"cout":{},"h":{},"tiles":{tiles},"pool_after":{},"file":"l{i}.hlo.txt"}}"#,
            l.cin, l.cout, l.h, l.pool_after
        )
        .unwrap();
        write!(
            execs,
            r#""l{i}.hlo.txt":{{"tiles":{tiles},"cin":{},"cout":{},"fft_size":{FFT},"sha256":"synthetic","bytes":0}}"#,
            l.cin, l.cout
        )
        .unwrap();
    }
    let mut graph = String::new();
    for (i, op) in g.steps.iter().enumerate() {
        if i > 0 {
            graph.push(',');
        }
        match *op {
            GraphOp::Conv { conv, input } => {
                write!(graph, r#"{{"op":"conv","conv":{conv},"input":{input}}}"#).unwrap()
            }
            GraphOp::Add { a, b } => {
                write!(graph, r#"{{"op":"add","a":{a},"b":{b}}}"#).unwrap()
            }
            GraphOp::Concat { a, b } => {
                write!(graph, r#"{{"op":"concat","a":{a},"b":{b}}}"#).unwrap()
            }
        }
    }
    format!(
        r#"{{"format":"hlo-text-v1","fft_size":{FFT},"kernel_k":{K},"tile":{TILE},
"word_bytes":2,"hadamard_mode":"mxu4","alpha":1,
"variants":{{"random":{{"input_hw":{},"input_c":{},"fc":[4],
"layers":[{layers}],"graph":[{graph}]}}}},
"executables":{{{execs}}}}}"#,
        g.input_hw, g.input_c
    )
}

/// Write the manifest under a unique temp dir and hand back the dir.
fn write_manifest(g: &RandomGraph, tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "spectral-flow-arena-{tag}-{}",
        std::process::id()
    ));
    std::fs::create_dir_all(&dir).expect("temp dir");
    std::fs::write(dir.join("manifest.json"), manifest_json(g)).expect("manifest write");
    dir
}

fn engine_on(dir: &std::path::Path, reuse: bool, alpha: usize) -> InferenceEngine {
    InferenceEngine::with_options(
        dir.to_str().unwrap(),
        "random",
        WeightMode::from_alpha(alpha),
        9,
        EngineOptions { arena_reuse: reuse, ..EngineOptions::default() },
    )
    .expect("random-graph engine")
}

#[test]
fn random_graphs_forward_finite_and_reuse_is_bit_invisible() {
    for case in 0..12u64 {
        let mut rng = Pcg32::new(1000 + case);
        let g = random_graph(&mut rng);
        let dir = write_manifest(&g, &format!("fwd{case}"));
        let alpha = if case % 2 == 0 { 1 } else { 4 };
        let mut reuse = engine_on(&dir, true, alpha);
        let mut flat = engine_on(&dir, false, alpha);
        let am = reuse.arena_metrics().clone();
        assert!(am.slots <= am.tensors, "case {case}: more slots than tensors");
        assert!(
            am.peak_activation_bytes <= am.no_reuse_bytes,
            "case {case}: reuse peak above the flat sum"
        );
        assert_eq!(flat.arena_metrics().slots, flat.arena_metrics().tensors, "case {case}");
        let imgs: Vec<_> = (1u64..=3).map(|s| reuse.synthetic_image(s)).collect();
        let a = reuse.forward_batch(&imgs).expect("reuse forward");
        let b = flat.forward_batch(&imgs).expect("flat forward");
        for (lane, (x, y)) in a.iter().zip(&b).enumerate() {
            assert!(
                x.iter().all(|v| v.is_finite()),
                "case {case} lane {lane}: poison reached the logits"
            );
            assert_eq!(x, y, "case {case} lane {lane}: arena reuse changed the numbers");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn random_plans_never_leak_or_double_free_slots() {
    for case in 0..20u64 {
        let mut rng = Pcg32::new(7000 + case);
        let g = random_graph(&mut rng);
        let plan = ArenaPlan::build(g.steps.clone(), &g.layers, g.input_c, g.input_hw, true)
            .expect("random graph is valid by construction");
        // replay the plan: claims and frees must balance exactly, and the
        // output slot must never appear in its own step's free list — the
        // executor frees dying inputs *before* placing the output, which
        // is only safe because the planner claims first
        let mut live = vec![false; plan.n_slots];
        live[plan.slot_of[0]] = true;
        for (i, _) in plan.steps.iter().enumerate() {
            let s = plan.slot_of[i + 1];
            assert!(!live[s], "case {case} step {i}: output claimed a live slot");
            assert!(
                !plan.free_after[i].contains(&s),
                "case {case} step {i}: output slot freed by its own step"
            );
            for &f in &plan.free_after[i] {
                assert!(live[f], "case {case}: freeing a slot that is not live");
                live[f] = false;
            }
            live[s] = true;
        }
        let final_slot = plan.slot_of[plan.steps.len()];
        assert!(live[final_slot], "case {case}: final tensor's slot not live");
    }
}

#[test]
fn handmade_chain_and_diamond_hit_known_optima() {
    let layers = vec![ConvShape { cin: 4, cout: 4, h: 8, pool_after: false }; 3];
    let chain = ArenaPlan::build(GraphOp::chain(3), &layers, 4, 8, true).unwrap();
    assert_eq!(chain.n_slots, 2, "an equal-size chain ping-pongs two slots");

    // diamond: t1 fans out, both branches join in an add — 3 is optimal
    // (t1 must coexist with each branch output)
    let dlayers = vec![
        ConvShape { cin: 1, cout: 4, h: 8, pool_after: false },
        ConvShape { cin: 4, cout: 4, h: 8, pool_after: false },
        ConvShape { cin: 4, cout: 4, h: 8, pool_after: false },
    ];
    let steps = vec![
        GraphOp::Conv { conv: 0, input: 0 },
        GraphOp::Conv { conv: 1, input: 1 },
        GraphOp::Conv { conv: 2, input: 1 },
        GraphOp::Add { a: 2, b: 3 },
    ];
    let diamond = ArenaPlan::build(steps.clone(), &dlayers, 1, 8, true).unwrap();
    assert_eq!(diamond.n_slots, 3, "a diamond needs exactly three slots");
    let flat = ArenaPlan::build(steps, &dlayers, 1, 8, false).unwrap();
    assert_eq!(flat.n_slots, 5, "no-reuse keeps all five tensors resident");
    assert!(diamond.metrics.peak_activation_bytes < flat.metrics.peak_activation_bytes);
}
