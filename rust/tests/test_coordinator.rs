//! Integration: the serving coordinator — batching server over the demo
//! variant on the offline `interp` backend (no artifacts needed), plus
//! pure-logic batcher/metrics properties.

use std::time::Duration;

use spectral_flow::coordinator::{
    Batcher, BatcherConfig, EngineOptions, Metrics, Server, ServerConfig, WeightMode,
};
use spectral_flow::runtime::BackendKind;
use spectral_flow::tensor::Tensor;
use spectral_flow::util::check::forall;
use spectral_flow::util::rng::Pcg32;

fn demo_config(max_batch: usize) -> ServerConfig {
    ServerConfig {
        artifacts_dir: concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").into(),
        variant: "demo".into(),
        mode: WeightMode::Pruned { alpha: 4 },
        seed: 7,
        batcher: BatcherConfig { max_batch, max_wait: Duration::from_millis(5) },
        ..ServerConfig::default()
    }
}

fn demo_server(max_batch: usize) -> Server {
    Server::start(demo_config(max_batch)).expect("server starts")
}

#[test]
fn serves_concurrent_clients() {
    let server = demo_server(4);
    let mut rng = Pcg32::new(1);
    // submit 12 requests from 3 cloned clients via async handles
    let mut rxs = Vec::new();
    for _ in 0..3 {
        let c = server.client();
        for _ in 0..4 {
            let img = Tensor::randn(&[1, 16, 16], &mut rng, 1.0);
            rxs.push(c.infer_async(img).unwrap());
        }
    }
    for rx in rxs {
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.logits.len(), 10);
        assert!(resp.batch_size >= 1 && resp.batch_size <= 4);
    }
    let m = server.metrics().unwrap();
    assert_eq!(m.count(), 12);
    assert!(m.mean_batch_size() >= 1.0);
    server.shutdown().unwrap();
}

#[test]
fn same_image_same_logits_through_server() {
    let server = demo_server(2);
    let client = server.client();
    let mut rng = Pcg32::new(2);
    let img = Tensor::randn(&[1, 16, 16], &mut rng, 1.0);
    let a = client.infer(img.clone()).unwrap();
    let b = client.infer(img).unwrap();
    assert_eq!(a.logits, b.logits);
    server.shutdown().unwrap();
}

#[test]
fn bad_input_errors_do_not_kill_server() {
    let server = demo_server(1);
    let client = server.client();
    let bad = Tensor::zeros(&[3, 16, 16]); // wrong channel count
    assert!(client.infer(bad).is_err());
    // server still alive
    let mut rng = Pcg32::new(3);
    let good = Tensor::randn(&[1, 16, 16], &mut rng, 1.0);
    assert!(client.infer(good).is_ok());
    server.shutdown().unwrap();
}

#[test]
fn pool_matches_serial_bit_for_bit() {
    // The tentpole contract: a 4-worker pool with a tile-parallel (2-thread)
    // interp backend, hit by many concurrent clients, must produce logits
    // identical — bit for bit — to the single-worker serial path for every
    // request. Workers replicate the same deterministic weights, and the
    // tile-parallel loop reorders no arithmetic.
    let mut rng = Pcg32::new(42);
    let images: Vec<Tensor> =
        (0..12).map(|_| Tensor::randn(&[1, 16, 16], &mut rng, 1.0)).collect();

    // ground truth: serial single-worker server
    let serial = demo_server(2);
    let sc = serial.client();
    let want: Vec<Vec<f32>> =
        images.iter().map(|img| sc.infer(img.clone()).unwrap().logits).collect();
    serial.shutdown().unwrap();

    // pool: 4 workers × 2 backend threads, one blocking client thread per
    // request so batches really interleave across workers
    let pool = Server::start(ServerConfig {
        workers: 4,
        engine: EngineOptions::builder().backend(BackendKind::Interp { threads: 2 }).build(),
        ..demo_config(2)
    })
    .expect("pool starts");
    std::thread::scope(|s| {
        let handles: Vec<_> = images
            .iter()
            .map(|img| {
                let c = pool.client();
                let img = img.clone();
                s.spawn(move || c.infer(img).unwrap())
            })
            .collect();
        for (h, want) in handles.into_iter().zip(&want) {
            let resp = h.join().expect("client thread");
            assert!(resp.worker < 4);
            assert_eq!(&resp.logits, want, "pool output diverged from serial path");
        }
    });

    let pm = pool.pool_metrics().unwrap();
    assert_eq!(pm.per_worker.len(), 4);
    assert_eq!(pm.merged.count(), 12);
    assert_eq!(pm.per_worker.iter().map(|m| m.count()).sum::<usize>(), 12);
    pool.shutdown().unwrap();
}

#[test]
fn dense_alpha1_mode_serves() {
    // α threading: `WeightMode::from_alpha(1)` must select the dense MAC
    // and serve normally — the CLI's `--alpha 1` path.
    let server = Server::start(ServerConfig {
        mode: WeightMode::from_alpha(1),
        ..demo_config(2)
    })
    .expect("dense server");
    let client = server.client();
    let mut rng = Pcg32::new(17);
    let r = client.infer(Tensor::randn(&[1, 16, 16], &mut rng, 1.0)).unwrap();
    assert_eq!(r.logits.len(), 10);
    server.shutdown().unwrap();
}

#[test]
fn pool_survives_bad_inputs_and_keeps_counting() {
    let pool = Server::start(ServerConfig { workers: 2, ..demo_config(1) }).expect("pool");
    let client = pool.client();
    let mut rng = Pcg32::new(8);
    for i in 0..6 {
        if i % 3 == 0 {
            assert!(client.infer(Tensor::zeros(&[3, 16, 16])).is_err());
        } else {
            assert!(client.infer(Tensor::randn(&[1, 16, 16], &mut rng, 1.0)).is_ok());
        }
    }
    // only successful forwards are recorded; both workers stayed alive
    let pm = pool.pool_metrics().unwrap();
    assert_eq!(pm.merged.count(), 4);
    pool.shutdown().unwrap();
}

#[test]
fn deadline_closed_singleton_batch_takes_the_batched_path() {
    // A batch closed by deadline with one request rides the same fused
    // `forward_batch` path as a full batch — there is no serial fallback.
    // Its logits must match a directly-constructed engine (planned for the
    // pool's max_batch, like the worker's), and its per-image share is the
    // whole execute.
    use spectral_flow::coordinator::InferenceEngine;
    let server = demo_server(4);
    let client = server.client();
    let mut rng = Pcg32::new(31);
    let img = Tensor::randn(&[1, 16, 16], &mut rng, 1.0);
    // the sole outstanding request: the batcher can only close it by
    // deadline, at size 1
    let resp = client.infer(img.clone()).unwrap();
    assert_eq!(resp.batch_size, 1);
    assert_eq!(
        resp.per_image, resp.execute,
        "a singleton batch's per-image share is the whole execute"
    );
    let cfg = demo_config(4);
    let mut engine = InferenceEngine::with_options(
        &cfg.artifacts_dir,
        &cfg.variant,
        cfg.mode,
        cfg.seed,
        EngineOptions { plan_batch: 4, ..EngineOptions::default() },
    )
    .unwrap();
    assert_eq!(resp.logits, engine.forward(&img).unwrap(), "singleton diverged from ground truth");
    let m = server.metrics().unwrap();
    assert_eq!(m.batch_histogram().get(1), Some(&1), "one batch of size 1 recorded");
    server.shutdown().unwrap();
}

#[test]
fn batched_pool_matches_singleton_pool_bit_for_bit() {
    // Tentpole gate at pool level: logits are independent of how the
    // dispatcher fuses requests into batch forwards.
    let mut rng = Pcg32::new(77);
    let images: Vec<Tensor> =
        (0..8).map(|_| Tensor::randn(&[1, 16, 16], &mut rng, 1.0)).collect();

    // ground truth: max_batch 1 — every request is its own fused batch
    let solo = demo_server(1);
    let sc = solo.client();
    let want: Vec<Vec<f32>> =
        images.iter().map(|img| sc.infer(img.clone()).unwrap().logits).collect();
    solo.shutdown().unwrap();

    // batched pool with a generous deadline: all 8 submitted before any
    // reply, so the batcher closes full batches of 4
    let batched = Server::start(ServerConfig {
        batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(250) },
        ..demo_config(4)
    })
    .expect("batched server");
    let bc = batched.client();
    let rxs: Vec<_> =
        images.iter().map(|img| bc.infer_async(img.clone()).unwrap()).collect();
    let mut fused = false;
    for (rx, want) in rxs.into_iter().zip(&want) {
        let resp = rx.recv().unwrap().unwrap();
        fused |= resp.batch_size > 1;
        assert!(resp.per_image <= resp.execute);
        assert_eq!(&resp.logits, want, "batched pool diverged from singleton pool");
    }
    assert!(fused, "dispatcher never closed a multi-image batch");
    let m = batched.metrics().unwrap();
    assert!(
        m.batch_histogram().iter().skip(2).any(|&c| c > 0),
        "histogram records no batch of size ≥ 2: {:?}",
        m.batch_histogram()
    );
    assert!(m.per_image_percentile(0.5).is_some(), "per-image latency recorded");
    batched.shutdown().unwrap();
}

#[test]
fn pool_surfaces_schedule_metrics() {
    // Pruned serving under the default exact-cover policy: every response
    // reports the engine's PE utilization, and the merged snapshot carries
    // the per-layer schedule metrics; dense serving reports neither.
    let server = demo_server(2);
    let client = server.client();
    let mut rng = Pcg32::new(23);
    let r = client.infer(Tensor::randn(&[1, 16, 16], &mut rng, 1.0)).unwrap();
    let u = r.pe_utilization.expect("pruned + scheduled ⇒ utilization reported");
    assert!(u > 0.0 && u <= 1.0 + 1e-12, "utilization {u}");
    let pm = server.pool_metrics().unwrap();
    let sched = pm.merged.schedule.as_ref().expect("merged snapshot carries schedule");
    assert_eq!(sched.scheduler, "exact-cover");
    assert_eq!(sched.layers.len(), 2, "demo variant has 2 conv layers");
    assert!(sched.total_cycles() >= sched.total_lower_bound());
    assert!((sched.avg_pe_utilization() - u).abs() < 1e-12);
    server.shutdown().unwrap();

    let dense = Server::start(ServerConfig {
        mode: WeightMode::from_alpha(1),
        ..demo_config(2)
    })
    .expect("dense server");
    let dc = dense.client();
    let r = dc.infer(Tensor::randn(&[1, 16, 16], &mut rng, 1.0)).unwrap();
    assert!(r.pe_utilization.is_none(), "dense serving has no schedule");
    assert!(dense.pool_metrics().unwrap().merged.schedule.is_none());
    dense.shutdown().unwrap();
}

#[test]
fn scheduler_off_pool_matches_scheduled_pool_bit_for_bit() {
    // `--scheduler off` (the PR 3 storage-order walk) and the scheduled
    // default must be indistinguishable in the logits.
    use spectral_flow::schedule::SchedulePolicy;
    let mut rng = Pcg32::new(29);
    let images: Vec<Tensor> =
        (0..4).map(|_| Tensor::randn(&[1, 16, 16], &mut rng, 1.0)).collect();
    let mut runs = Vec::new();
    for policy in [SchedulePolicy::Off, SchedulePolicy::ExactCover, SchedulePolicy::LowestIndex]
    {
        let server = Server::start(ServerConfig {
            engine: EngineOptions::builder().scheduler(policy).build(),
            ..demo_config(2)
        })
        .expect("server starts");
        let client = server.client();
        let logits: Vec<Vec<f32>> =
            images.iter().map(|img| client.infer(img.clone()).unwrap().logits).collect();
        server.shutdown().unwrap();
        runs.push((policy, logits));
    }
    let (_, want) = &runs[0];
    for (policy, got) in &runs[1..] {
        assert_eq!(got, want, "{policy:?} diverged from the unscheduled pool");
    }
}

#[test]
fn unknown_variant_fails_startup_with_error() {
    let r = Server::start(ServerConfig {
        variant: "no-such-variant".into(),
        ..ServerConfig::default()
    });
    assert!(r.is_err(), "startup must surface engine construction errors");
}

// ---------- pure-logic properties (no artifacts needed) -------------------

#[test]
fn batcher_conservation_under_adversarial_timing() {
    forall("batcher conservation", 60, |rng| {
        use std::time::Instant;
        let mut b = Batcher::new(BatcherConfig {
            max_batch: rng.range(1, 6),
            max_wait: Duration::from_millis(rng.range(1, 10) as u64),
        });
        let n = rng.range(1, 60);
        let mut now = Instant::now();
        let mut out = Vec::new();
        for i in 0..n {
            now += Duration::from_millis(rng.range(0, 12) as u64);
            if let Some(batch) = b.poll(now) {
                out.extend(batch);
            }
            if let Some(batch) = b.push(i, now) {
                out.extend(batch);
            }
        }
        out.extend(b.take().unwrap_or_default());
        assert_eq!(out, (0..n).collect::<Vec<_>>());
    });
}

#[test]
fn metrics_percentiles_are_order_statistics() {
    forall("metrics percentiles", 40, |rng| {
        let mut m = Metrics::new();
        let n = rng.range(1, 200);
        let mut vals: Vec<u64> = (0..n).map(|_| rng.range(1, 100_000) as u64).collect();
        for &v in &vals {
            m.record_request(Duration::from_micros(v));
        }
        vals.sort_unstable();
        assert_eq!(m.p50().unwrap(), Duration::from_micros(vals[(n - 1) / 2 + (n - 1) % 2]));
        assert!(m.p99().unwrap() <= Duration::from_micros(*vals.last().unwrap()));
        assert!(m.p50().unwrap() <= m.p95().unwrap());
    });
}
