//! Property tests for the numeric contracts the backend refactor leans on
//! (via `util::check::forall`):
//!
//! * the radix-2 FFT agrees with a naive O(n²) DFT and round-trips
//!   (`ifft(fft(x)) ≈ x` to 1e-5) at the paper's sizes K ∈ {8, 16};
//! * `freq_major_planes` ↔ `planes_from_freq_major` is an exact transpose
//!   inverse;
//! * the full spectral pipeline through the `interp` backend
//!   (im2tiles → FFT → frequency-major MAC → IFFT → overlap-add) equals the
//!   naive spatial convolution on small random layers.

use std::path::Path;

use spectral_flow::fft::{
    fft1d, fft2d, ifft1d, ifft2d, im2tiles, overlap_add, spectral_kernels, Complex, TileGeometry,
};
use spectral_flow::nn::conv2d_same_ref;
use spectral_flow::runtime::{
    freq_major_planes, planes_from_freq_major, ExecutableEntry, InterpBackend, SpectralBackend,
};
use spectral_flow::tensor::{ComplexTensor, Tensor};
use spectral_flow::util::check::{assert_allclose, forall};
use spectral_flow::util::rng::Pcg32;

// ---------------- FFT: naive-DFT cross-check + round-trip ------------------

/// O(n²) reference DFT, accumulated in f64 with exact wrapped angles.
fn dft1d(x: &[Complex]) -> Vec<Complex> {
    let n = x.len();
    (0..n)
        .map(|k| {
            let (mut re, mut im) = (0.0f64, 0.0f64);
            for (j, c) in x.iter().enumerate() {
                let ang = -2.0 * std::f64::consts::PI * ((j * k) % n) as f64 / n as f64;
                let (s, cs) = ang.sin_cos();
                re += c.re as f64 * cs - c.im as f64 * s;
                im += c.re as f64 * s + c.im as f64 * cs;
            }
            Complex::new(re as f32, im as f32)
        })
        .collect()
}

fn randc(rng: &mut Pcg32, n: usize) -> Vec<Complex> {
    (0..n).map(|_| Complex::new(rng.normal(), rng.normal())).collect()
}

fn split(v: &[Complex]) -> (Vec<f32>, Vec<f32>) {
    (v.iter().map(|c| c.re).collect(), v.iter().map(|c| c.im).collect())
}

#[test]
fn fft_matches_naive_dft_k8_k16() {
    forall("fft == naive dft", 40, |rng| {
        for k in [8usize, 16] {
            let x = randc(rng, k);
            let (gr, gi) = split(&fft1d(&x));
            let (wr, wi) = split(&dft1d(&x));
            assert_allclose(&gr, &wr, 1e-5, 1e-4);
            assert_allclose(&gi, &wi, 1e-5, 1e-4);
        }
    });
}

#[test]
fn fft_roundtrip_1e5_k8_k16() {
    // The satellite contract: ifft(fft(x)) ≈ x to 1e-5 for K ∈ {8, 16}.
    forall("fft roundtrip 1e-5", 60, |rng| {
        for k in [8usize, 16] {
            let x = randc(rng, k);
            let y = ifft1d(&fft1d(&x));
            let (gr, gi) = split(&y);
            let (wr, wi) = split(&x);
            assert_allclose(&gr, &wr, 1e-5, 1e-5);
            assert_allclose(&gi, &wi, 1e-5, 1e-5);
        }
    });
}

#[test]
fn fft2d_roundtrip_1e5_k8_k16() {
    forall("fft2d roundtrip 1e-5", 30, |rng| {
        for k in [8usize, 16] {
            let p = randc(rng, k * k);
            let q = ifft2d(&fft2d(&p, k), k);
            let (gr, gi) = split(&q);
            let (wr, wi) = split(&p);
            assert_allclose(&gr, &wr, 1e-5, 1e-5);
            assert_allclose(&gi, &wi, 1e-5, 1e-5);
        }
    });
}

// ---------------- freq-major layout: transpose inverse ---------------------

#[test]
fn freq_major_planes_transpose_inverse() {
    forall("freq-major inverse", 30, |rng| {
        let n = rng.range(1, 7);
        let m = rng.range(1, 7);
        let fft = [4usize, 8, 16][rng.range(0, 3)];
        let mut planes = ComplexTensor::zeros(&[n, m, fft, fft]);
        for v in planes.re.data_mut() {
            *v = rng.normal();
        }
        for v in planes.im.data_mut() {
            *v = rng.normal();
        }
        let (re, im) = freq_major_planes(&planes);
        assert_eq!(re.len(), fft * fft * m * n);
        let back = planes_from_freq_major(&re, &im, n, m, fft);
        assert_eq!(planes, back, "transpose must invert exactly (bit-for-bit)");
    });
}

// ---------------- interp backend: spectral == spatial ----------------------

/// Full 'SAME' spectral conv through the interp backend (the engine's exact
/// per-layer path: im2tiles → backend → overlap_add, minus bias/ReLU).
fn spectral_conv_via_backend(x: &Tensor, w: &Tensor, fft: usize) -> Tensor {
    let (m, h) = (x.shape()[0], x.shape()[1]);
    let (n, k) = (w.shape()[0], w.shape()[2]);
    let geo = TileGeometry::new(h, fft, k);
    let tiles = im2tiles(x, &geo);
    let planes = spectral_kernels(w, fft);
    let (re, im) = freq_major_planes(&planes);
    let mut backend = InterpBackend::new();
    let meta = ExecutableEntry {
        tiles: geo.num_tiles(),
        cin: m,
        cout: n,
        fft_size: fft,
        sha256: "test".into(),
        bytes: 0,
    };
    backend.prepare("shape", &meta, Path::new(".")).unwrap();
    let wid = backend.upload_weights(&re, &im, [fft * fft, m, n]).unwrap();
    let out_tiles = backend.run_conv("shape", &tiles, wid).unwrap();
    overlap_add(&out_tiles, &geo, n)
}

#[test]
fn interp_backend_equals_spatial_conv() {
    forall("interp backend == spatial conv", 12, |rng| {
        let h = rng.range(4, 15);
        let m = rng.range(1, 4);
        let n = rng.range(1, 4);
        let x = Tensor::randn(&[m, h, h], rng, 1.0);
        let w = Tensor::randn(&[n, m, 3, 3], rng, 0.3);
        let got = spectral_conv_via_backend(&x, &w, 8);
        let want = conv2d_same_ref(&x, &w);
        assert_allclose(got.data(), want.data(), 2e-3, 2e-3);
    });
}

#[test]
fn interp_backend_equals_spatial_conv_k16() {
    // K=16 geometry (Table 1 lower half): tile h' = 14.
    let mut rng = Pcg32::new(11);
    let x = Tensor::randn(&[2, 20, 20], &mut rng, 1.0);
    let w = Tensor::randn(&[3, 2, 3, 3], &mut rng, 0.2);
    let got = spectral_conv_via_backend(&x, &w, 16);
    let want = conv2d_same_ref(&x, &w);
    assert_allclose(got.data(), want.data(), 2e-3, 2e-3);
}

#[test]
fn interp_backend_identity_kernel() {
    // Delta kernel at center → the whole pipeline is the identity.
    let mut rng = Pcg32::new(12);
    let x = Tensor::randn(&[1, 10, 10], &mut rng, 1.0);
    let mut w = Tensor::zeros(&[1, 1, 3, 3]);
    w.set(&[0, 0, 1, 1], 1.0);
    let got = spectral_conv_via_backend(&x, &w, 8);
    assert!(got.max_abs_diff(&x) < 1e-4, "err {}", got.max_abs_diff(&x));
}
