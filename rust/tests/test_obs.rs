//! Integration: the observability stack — measured data movement vs the
//! Eq. 13 prediction at the engine level, bit-invisibility of the
//! counters, and trace-span integrity through the serving pool.
//!
//! The exactness pin is the load-bearing one: at B = 1, full plane,
//! single-thread interp, the backend streams exactly the kernel words
//! Eq. 13 predicts for the executed `(Ns, Ps)` plan — dense (α = 1) and
//! sparse (α = 4), scheduled or not. Every divergence (half-plane fold,
//! batching, thread chunking) is bounded and documented below.

use std::time::Duration;

use spectral_flow::coordinator::{
    BatcherConfig, EngineOptions, InferenceEngine, Server, ServerConfig, TraceConfig, WeightMode,
};
use spectral_flow::runtime::{BackendKind, Plane};
use spectral_flow::schedule::SchedulePolicy;
use spectral_flow::tensor::Tensor;
use spectral_flow::util::rng::Pcg32;

const ARTIFACTS: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");

fn demo_engine(opts: EngineOptions, alpha: usize) -> InferenceEngine {
    InferenceEngine::with_options(ARTIFACTS, "demo", WeightMode::from_alpha(alpha), 7, opts)
        .expect("demo engine")
}

fn single_thread(scheduler: SchedulePolicy, plane: Plane) -> EngineOptions {
    EngineOptions {
        backend: BackendKind::Interp { threads: 1 },
        scheduler,
        plane,
        ..EngineOptions::default()
    }
}

#[test]
fn b1_full_plane_weight_bytes_match_eq13_exactly() {
    // B = 1, full plane, one backend thread: the measured weight stream
    // must equal the Eq. 13 kernel term to the byte, per layer, for the
    // dense MAC, the storage-order CSR walk, and the Alg. 2 schedule.
    for (alpha, policy) in [
        (1usize, SchedulePolicy::Off),
        (4, SchedulePolicy::Off),
        (4, SchedulePolicy::ExactCover),
    ] {
        let mut e = demo_engine(single_thread(policy, Plane::Full), alpha);
        assert!(e.observing(), "observation is on by default");
        let img = e.synthetic_image(1);
        let _ = e.forward(&img).expect("forward");
        let tm = e.traffic_metrics().expect("traffic metrics when observing");
        assert!(!tm.layers.is_empty());
        for l in &tm.layers {
            assert_eq!(l.forwards, 1, "{} (alpha {alpha})", l.layer);
            assert!(l.predicted_weight_bytes > 0, "{} (alpha {alpha})", l.layer);
            assert_eq!(
                l.measured.weight_bytes, l.predicted_weight_bytes,
                "layer {} alpha {alpha} policy {policy:?}: measured weight bytes \
                 must equal the Eq. 13 kernel term exactly",
                l.layer
            );
            assert!((l.weight_ratio() - 1.0).abs() < 1e-12);
            // activations cross the backend boundary as overlapping tiles
            // (a known, documented divergence from Eq. 13's h² planes) —
            // the counters must still see them move
            assert!(l.measured.input_bytes > 0, "{}", l.layer);
            assert!(l.measured.output_bytes > 0, "{}", l.layer);
            assert!(l.predicted_input_bytes > 0 && l.predicted_output_bytes > 0, "{}", l.layer);
        }
        assert_eq!(tm.measured_weight_bytes(), tm.predicted_weight_bytes());
    }
}

#[test]
fn half_plane_and_batch_ratios_stay_within_documented_bounds() {
    // Half-plane: Eq. 13 is evaluated at k2 = K(K/2+1) (the planner sees
    // the folded spectrum), while the measured stream is the folded CSR's
    // nnz — magnitude pruning keeps conjugate pairs together, so the two
    // track each other within ±50% (the dense fold ratio is 40/64 of the
    // full plane for K = 8, and the prediction folds by the same factor).
    let mut e = demo_engine(single_thread(SchedulePolicy::ExactCover, Plane::Half), 4);
    let img = e.synthetic_image(1);
    let _ = e.forward(&img).expect("half-plane forward");
    let tm = e.traffic_metrics().expect("traffic metrics");
    for l in &tm.layers {
        let r = l.weight_ratio();
        assert!(
            (0.5..=1.5).contains(&r),
            "half-plane layer {} weight ratio {r:.3} outside [0.5, 1.5]",
            l.layer
        );
    }

    // Batched: predictions are evaluated at the actual per-call batch
    // size, so the B = 4 fused forward must stay inside the same
    // [0.5, 2.0] envelope the CI traffic gate enforces.
    let mut e = demo_engine(
        EngineOptions { plan_batch: 4, ..single_thread(SchedulePolicy::ExactCover, Plane::Full) },
        4,
    );
    let images: Vec<Tensor> = (0..4u64).map(|s| e.synthetic_image(s)).collect();
    let out = e.forward_batch(&images).expect("batch forward");
    assert_eq!(out.len(), 4);
    let tm = e.traffic_metrics().expect("traffic metrics");
    for l in &tm.layers {
        let r = l.weight_ratio();
        assert!(
            (0.5..=2.0).contains(&r),
            "batched layer {} weight ratio {r:.3} outside [0.5, 2.0]",
            l.layer
        );
    }
}

#[test]
fn logits_bit_identical_with_observation_on_and_off() {
    let opts = single_thread(SchedulePolicy::ExactCover, Plane::Full);
    let mut on = demo_engine(EngineOptions { observe: true, ..opts }, 4);
    let mut off = demo_engine(EngineOptions { observe: false, ..opts }, 4);
    assert!(on.observing());
    assert!(!off.observing());
    assert!(off.traffic_metrics().is_none());
    assert!(off.layer_spans().is_empty());
    let img = on.synthetic_image(3);
    let a = on.forward(&img).expect("observed forward");
    let b = off.forward(&img).expect("unobserved forward");
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.to_bits(), y.to_bits(), "observation must be bit-invisible");
    }
    // the observed engine recorded one execute span per conv layer
    let spans = on.layer_spans();
    assert_eq!(spans.len(), 2, "demo has two conv layers");
    assert!(spans.iter().all(|s| s.end >= s.start && s.measured_bytes > 0));
}

#[test]
fn pool_traces_are_well_formed_at_four_workers() {
    let server = Server::start(ServerConfig {
        artifacts_dir: ARTIFACTS.into(),
        variant: "demo".into(),
        mode: WeightMode::Pruned { alpha: 4 },
        seed: 7,
        batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(5) },
        workers: 4,
        ..ServerConfig::default()
    })
    .expect("server");
    let client = server.client();
    let mut rng = Pcg32::new(9);
    let rxs: Vec<_> = (0..16)
        .map(|_| client.infer_async(Tensor::randn(&[1, 16, 16], &mut rng, 1.0)).unwrap())
        .collect();
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }

    let ring = server.trace();
    assert_eq!(ring.dropped(), 0, "16 requests into a 256-slot ring never contend");
    let traces = ring.recent(32);
    assert_eq!(traces.len(), 16, "every completed request leaves a trace");
    let mut seen_requests = std::collections::HashSet::new();
    for t in &traces {
        assert!(t.request > 0 && seen_requests.insert(t.request), "ids unique and 1-based");
        assert!(t.batch > 0);
        assert!(t.worker < 4);
        assert_eq!(t.model, "demo");
        assert!((1..=4).contains(&t.batch_size));

        // structure: spans[0] is the root, it covers every child, children
        // are sorted by start, and the root duration IS the latency
        let root = &t.spans[0];
        assert_eq!(root.name, "request");
        assert_eq!(root.duration_us(), t.latency_us);
        let mut prev_start = 0;
        for s in &t.spans[1..] {
            assert!(s.start_us >= root.start_us, "{} starts before root", s.name);
            assert!(s.end_us <= root.end_us, "{} ends after root", s.name);
            assert!(s.end_us >= s.start_us, "{} runs backwards", s.name);
            assert!(s.start_us >= prev_start, "children must be start-sorted");
            prev_start = s.start_us;
        }
        let names: Vec<&str> = t.spans.iter().map(|s| s.name.as_str()).collect();
        for want in ["queue", "batch-close", "execute", "respond"] {
            assert!(names.contains(&want), "missing {want} span in {names:?}");
        }
        // in-process submission has no wire, hence no parse span
        assert!(!names.contains(&"parse"));
        // one execute span per demo conv layer, carrying byte accounting
        for conv in ["layer:conv1", "layer:conv2"] {
            let s = t.spans.iter().find(|s| s.name == conv).expect("conv span present");
            assert!(s.measured_bytes > 0 && s.predicted_bytes > 0, "{conv} carries bytes");
        }
    }
    server.shutdown().unwrap();
}

#[test]
fn slow_retention_survives_wraps_on_the_server_path() {
    // threshold 0 marks every request slow: the 2-slot recent ring wraps
    // almost immediately, but the slow ring must retain what it saw
    let server = Server::start(ServerConfig {
        artifacts_dir: ARTIFACTS.into(),
        variant: "demo".into(),
        mode: WeightMode::Pruned { alpha: 4 },
        seed: 7,
        batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(1) },
        workers: 1,
        trace: TraceConfig { capacity: 2, slow_capacity: 8, slow_threshold_us: 0 },
        ..ServerConfig::default()
    })
    .expect("server");
    let client = server.client();
    let mut rng = Pcg32::new(11);
    for _ in 0..6 {
        client.infer(Tensor::randn(&[1, 16, 16], &mut rng, 1.0)).unwrap();
    }
    let ring = server.trace();
    assert_eq!(ring.slow_threshold_us(), 0);
    assert!(ring.recent(10).len() <= 2, "recent ring stays at capacity");
    let slow = ring.slow_traces(10);
    assert_eq!(slow.len(), 6, "no slow trace may be lost to fast wraps");
    assert!(slow.iter().all(|t| t.slow), "record() stamps the slow flag");
    server.shutdown().unwrap();
}
