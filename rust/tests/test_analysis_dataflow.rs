//! Integration: complexity analysis × dataflow optimizer over the real
//! VGG16 workloads — the Fig. 2 / Fig. 7 / Table 1 / Table 2 claims.

use spectral_flow::analysis::*;
use spectral_flow::dataflow::*;
use spectral_flow::model::Network;
use spectral_flow::util::check::forall;

fn layers(alpha: usize) -> Vec<LayerParams> {
    Network::vgg16_224()
        .optimized_convs()
        .iter()
        .map(|c| LayerParams::from_layer(c, alpha))
        .collect()
}

#[test]
fn fig2_flow3_never_wins_on_transfers() {
    // Paper §5.2: "streaming partial sums ... brings no advantages at all".
    let arch = ArchParams::paper();
    for l in layers(4) {
        let t3 = transfers_flow(Flow::StreamPsums, &l, &arch).total();
        let t1 = transfers_flow(Flow::ReuseKernels, &l, &arch).total();
        let t2 = transfers_flow(Flow::ReuseInputs, &l, &arch).total();
        assert!(t3 >= t1.min(t2), "psum streaming should never be best");
    }
}

#[test]
fn fig2_flow1_trades_brams_for_transfers() {
    // Flow #1 moves the least data but explodes BRAMs on large layers;
    // Flow #2 is the reverse — the tradeoff motivating the flexible flow.
    let arch = ArchParams::paper();
    let ls = layers(4);
    let early = &ls[0]; // conv1_2: 1444 tiles
    assert!(bram_flow1(early, &arch) > bram_flow2(early, &arch));
    assert!(
        transfers_flow1(early, &arch).total() < transfers_flow2(early, &arch).total()
    );
}

#[test]
fn table2_bandwidths_in_paper_band() {
    // Paper Table 2 reports 3.5–9.9 GB/s per layer at τ=20 ms. Require the
    // same order of magnitude: every layer within [1, 20] GB/s and the max
    // within [6, 16] GB/s.
    let net = Network::vgg16_224();
    let cfg = OptimizerConfig::paper();
    let plan = optimize_network_at(&net, ArchParams::paper(), &cfg).unwrap();
    for lp in &plan.layers {
        let gbps = lp.bandwidth / 1e9;
        assert!((0.5..20.0).contains(&gbps), "{}: {gbps} GB/s", lp.layer_name);
    }
    let max = plan.bw_max / 1e9;
    assert!((5.0..16.0).contains(&max), "bw_max {max} GB/s");
}

#[test]
fn table1_streaming_params_lattice() {
    // Published Table 1 has Ns multiples of 64 and Ps multiples of 9 — the
    // plan must live on the same lattice (keep-everything settings exempt).
    let net = Network::vgg16_224();
    let cfg = OptimizerConfig::paper();
    let plan = optimize_network_at(&net, ArchParams::paper(), &cfg).unwrap();
    for lp in &plan.layers {
        assert!(
            lp.stream.ns % 64 == 0 || lp.stream.ns == lp.params.n,
            "{}: Ns={}",
            lp.layer_name,
            lp.stream.ns
        );
        assert!(
            lp.stream.ps % 9 == 0 || lp.stream.ps == lp.params.p,
            "{}: Ps={}",
            lp.layer_name,
            lp.stream.ps
        );
    }
}

#[test]
fn optimizer_respects_budget_under_sweep() {
    forall("optimizer feasibility", 20, |rng| {
        let net = Network::vgg16_224();
        let alpha = [2usize, 4, 8][rng.range(0, 3)];
        let budget = 800 + rng.range(0, 1600) as u64;
        let cfg = OptimizerConfig {
            alpha,
            bram_budget: budget,
            ..OptimizerConfig::paper()
        };
        if let Some(plan) = optimize_network_at(&net, ArchParams::paper(), &cfg) {
            for lp in &plan.layers {
                assert!(lp.brams <= budget, "{} over budget", lp.layer_name);
            }
        }
    });
}

#[test]
fn tighter_budget_never_reduces_transfers() {
    // Shrinking the BRAM budget restricts the lattice ⇒ total transfers are
    // monotonically non-decreasing.
    let net = Network::vgg16_224();
    let mut prev: Option<u64> = None;
    for budget in [2160u64, 1400, 1000, 700] {
        let cfg = OptimizerConfig { bram_budget: budget, ..OptimizerConfig::paper() };
        if let Some(plan) = optimize_network_at(&net, ArchParams::paper(), &cfg) {
            if let Some(p) = prev {
                assert!(plan.total_transfers() >= p, "budget {budget}");
            }
            prev = Some(plan.total_transfers());
        }
    }
    assert!(prev.is_some());
}

#[test]
fn alpha8_reduces_kernel_traffic_vs_alpha4() {
    let net = Network::vgg16_224();
    let arch = ArchParams::paper();
    let p4 = optimize_network_at(&net, arch, &OptimizerConfig { alpha: 4, ..OptimizerConfig::paper() }).unwrap();
    let p8 = optimize_network_at(&net, arch, &OptimizerConfig { alpha: 8, ..OptimizerConfig::paper() }).unwrap();
    let k4: u64 = p4.layers.iter().map(|l| l.transfers.kernels).sum();
    let k8: u64 = p8.layers.iter().map(|l| l.transfers.kernels).sum();
    assert!(k8 < k4);
}

#[test]
fn k16_has_higher_kernel_pressure() {
    // §6.1: "the model with 16×16 spectral kernels needs 4× more storage
    // for kernels ... still causes huge communication overhead".
    let k8 = Network::vgg16_224();
    let k16 = Network::vgg16_224_k16();
    let kw8: u64 = k8.optimized_convs().iter().map(|c| LayerParams::from_layer(c, 4).sparse_kernel_words()).sum();
    let kw16: u64 = k16.optimized_convs().iter().map(|c| LayerParams::from_layer(c, 4).sparse_kernel_words()).sum();
    assert!(kw16 == 4 * kw8, "{kw16} vs 4×{kw8}");
}
