//! Integration: half-plane (rfft2) spectral storage and the `--dtype`
//! precision modes at engine level — the PR's acceptance matrix. The
//! f64 half-plane forward must match the f64 full-plane forward to
//! ≤1e-12 (the conjugate fold is algebraically exact; any residual is
//! final-rounding noise), and the f32 fast path must stay within the
//! documented 2e-3 of the f64 reference, across α × scheduler × batch.

use spectral_flow::coordinator::{EngineOptions, InferenceEngine, WeightMode};
use spectral_flow::runtime::{Dtype, Plane};
use spectral_flow::schedule::SchedulePolicy;

fn artifacts_dir() -> String {
    concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").into()
}

/// Forward `batch` synthetic images through the demo variant at the given
/// numerics mode; returns one logits vector per image.
fn forward_with(
    dtype: Option<Dtype>,
    plane: Plane,
    alpha: usize,
    policy: SchedulePolicy,
    batch: usize,
) -> Vec<Vec<f32>> {
    let mut e = InferenceEngine::with_options(
        &artifacts_dir(),
        "demo",
        WeightMode::from_alpha(alpha),
        7,
        EngineOptions { scheduler: policy, dtype, plane, ..EngineOptions::default() },
    )
    .expect("engine builds");
    let imgs: Vec<_> = (0..batch).map(|s| e.synthetic_image(s as u64 + 1)).collect();
    e.forward_batch(&imgs).expect("forward")
}

#[test]
fn f64_half_plane_matches_f64_full_plane_to_1e12() {
    // The tentpole equivalence gate: folding conjugate-symmetric non-zeros
    // into the K·(K/2+1) half-plane changes the storage and the cycle-sets
    // but not the arithmetic result, across every execution mode.
    for alpha in [1usize, 4] {
        let policies: &[SchedulePolicy] = if alpha == 1 {
            &[SchedulePolicy::Off]
        } else {
            &[SchedulePolicy::Off, SchedulePolicy::ExactCover, SchedulePolicy::LowestIndex]
        };
        for &policy in policies {
            for batch in [1usize, 8] {
                let full = forward_with(Some(Dtype::F64), Plane::Full, alpha, policy, batch);
                let half = forward_with(Some(Dtype::F64), Plane::Half, alpha, policy, batch);
                assert_eq!(full.len(), half.len());
                for (bi, (f, h)) in full.iter().zip(&half).enumerate() {
                    for (i, (a, b)) in f.iter().zip(h).enumerate() {
                        assert!(
                            (a - b).abs() <= 1e-12,
                            "α={alpha} {policy:?} batch={batch}: image {bi} logit {i} \
                             half-plane diverged ({a} vs {b})"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn f32_modes_match_f64_reference_within_tolerance() {
    // The precision gate: f32 accumulation (full or half plane) stays
    // within 2e-3 of the f64 full-plane reference, and the two f32 planes
    // agree with each other to 1e-4 — same numbers the backend-level
    // tests pin, revalidated through the whole engine stack.
    for batch in [1usize, 8] {
        let policy = SchedulePolicy::ExactCover;
        let want = forward_with(Some(Dtype::F64), Plane::Full, 4, policy, batch);
        let f32_full = forward_with(Some(Dtype::F32), Plane::Full, 4, policy, batch);
        let f32_half = forward_with(None, Plane::Half, 4, policy, batch);
        for (bi, ((w, gf), gh)) in want.iter().zip(&f32_full).zip(&f32_half).enumerate() {
            for i in 0..w.len() {
                assert!(
                    (gf[i] - w[i]).abs() < 2e-3,
                    "batch={batch} image {bi} logit {i}: f32-full {} vs f64 {}",
                    gf[i],
                    w[i]
                );
                assert!(
                    (gh[i] - w[i]).abs() < 2e-3,
                    "batch={batch} image {bi} logit {i}: f32-half {} vs f64 {}",
                    gh[i],
                    w[i]
                );
                assert!(
                    (gh[i] - gf[i]).abs() < 1e-4,
                    "batch={batch} image {bi} logit {i}: f32 half vs full ({} vs {})",
                    gh[i],
                    gf[i]
                );
            }
        }
    }
}

#[test]
fn dtype_defaults_resolve_from_manifest() {
    // `dtype: None` is the `--dtype` unset sentinel: the engine defers to
    // the manifest's recorded default (f32 for the shipped artifacts),
    // mirroring how `--alpha 0` defers to the manifest's alpha.
    let e = InferenceEngine::with_options(
        &artifacts_dir(),
        "demo",
        WeightMode::from_alpha(4),
        7,
        EngineOptions::default(),
    )
    .expect("engine builds");
    assert_eq!(e.dtype(), Dtype::F32);
    assert_eq!(e.plane(), Plane::Full);
    let e = InferenceEngine::with_options(
        &artifacts_dir(),
        "demo",
        WeightMode::from_alpha(4),
        7,
        EngineOptions { dtype: Some(Dtype::F64), plane: Plane::Half, ..EngineOptions::default() },
    )
    .expect("engine builds");
    assert_eq!(e.dtype(), Dtype::F64);
    assert_eq!(e.plane(), Plane::Half);
}
