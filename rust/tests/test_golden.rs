//! Spatial golden model: a naive direct-convolution forward (no FFT
//! anywhere) that pins the spectral engine's numerics across presets,
//! compression ratios, scheduler policies and batch sizes.
//!
//! The golden path shares only the *structural* helpers with the engine —
//! `im2tiles`, `overlap_add`, bias/ReLU/pool/FC — so the two pipelines
//! differ exactly where the paper's accelerator lives: the per-tile conv
//! core. The engine runs tile-FFT → sparse Hadamard MAC → IFFT; the golden
//! model runs the equivalent circular convolution as a direct double sum
//! in f64:
//!
//! * Dense (α = 1): the spectral planes are the FFT of the flipped spatial
//!   3×3 kernel, so the circular-conv taps are just those 9 spatial values
//!   — a direct 9-tap convolution per tile.
//! * Pruned (α > 1): the kernels exist only in the frequency domain, so
//!   the golden taps are the inverse *DFT by definition* (a literal double
//!   sum over the K²/α stored non-zeros — no butterflies) of each sparse
//!   plane. Since activations are real, only the real part of the
//!   time-domain kernel can reach the output, which is exactly what the
//!   engine's `Re(IFFT(Σ X∘W))` keeps.
//!
//! Both paths round each conv's tile outputs to f32 at the same point (the
//! backend emits f32 tiles), so at `dtype f64` the remaining divergence is
//! FFT round-off — pinned here to ≤1e-5 end to end at the logits.

use spectral_flow::coordinator::{EngineOptions, InferenceEngine, WeightMode};
use spectral_flow::fft::{im2tiles, overlap_add, TileGeometry};
use spectral_flow::model::GraphOp;
use spectral_flow::nn;
use spectral_flow::runtime::Dtype;
use spectral_flow::schedule::SchedulePolicy;
use spectral_flow::tensor::Tensor;
use spectral_flow::util::check::assert_allclose;

fn artifacts_dir() -> String {
    std::env::var("SPECTRAL_FLOW_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
}

fn f64_engine(variant: &str, mode: WeightMode, policy: SchedulePolicy) -> InferenceEngine {
    InferenceEngine::with_options(
        &artifacts_dir(),
        variant,
        mode,
        7,
        EngineOptions {
            scheduler: policy,
            dtype: Some(Dtype::F64),
            ..EngineOptions::default()
        },
    )
    .expect("engine construction")
}

/// Circular-convolution taps for one conv layer, laid out
/// `[cout][cin][side][side]` with `y[u,v] += tap[a,b] · x[(u−a)%K,(v−b)%K]`.
/// `side = k` for dense layers (9 spatial taps), `side = K` for pruned
/// layers (dense time-domain kernel from the naive inverse DFT).
struct GoldenTaps {
    taps: Vec<f64>,
    side: usize,
}

fn golden_taps(e: &InferenceEngine, idx: usize, fft: usize, k: usize) -> GoldenTaps {
    let lw = &e.weights.convs[idx];
    if let Some(sp) = &lw.spatial {
        // dense: the engine FFTs the flipped kernel, so the circular-conv
        // tap at offset (a, b) is spatial[k-1-a, k-1-b]
        let sh = sp.shape();
        let (n, m) = (sh[0], sh[1]);
        let d = sp.data();
        let mut taps = vec![0f64; n * m * k * k];
        for o in 0..n {
            for i in 0..m {
                for a in 0..k {
                    for b in 0..k {
                        taps[((o * m + i) * k + a) * k + b] =
                            d[((o * m + i) * k + (k - 1 - a)) * k + (k - 1 - b)] as f64;
                    }
                }
            }
        }
        GoldenTaps { taps, side: k }
    } else {
        // pruned: inverse DFT by definition of each sparse plane. The
        // angle e^{+2πi(up+vq)/K} only depends on (up+vq) mod K, so the
        // whole basis is a K-entry root table — no FFT, no trig in the
        // inner loop. Activations are real, so only Re(w_time) matters.
        let sl = lw.sparse.as_ref().expect("pruned weights carry sparse planes");
        let (n, m) = (sl.cout, sl.cin);
        let k2 = fft * fft;
        let roots: Vec<(f64, f64)> = (0..fft)
            .map(|r| {
                let ang = std::f64::consts::TAU * r as f64 / fft as f64;
                (ang.cos(), ang.sin())
            })
            .collect();
        let mut taps = vec![0f64; n * m * k2];
        for o in 0..n {
            for i in 0..m {
                let kern = sl.kernel(o, i);
                let base = (o * m + i) * k2;
                for (&fidx, &(re, im)) in kern.indices.iter().zip(&kern.values) {
                    let (u, v) = (fidx as usize / fft, fidx as usize % fft);
                    let (wr, wi) = (re as f64, im as f64);
                    for p in 0..fft {
                        for q in 0..fft {
                            let (c, s) = roots[(u * p + v * q) % fft];
                            taps[base + p * fft + q] += wr * c - wi * s;
                        }
                    }
                }
            }
        }
        for t in &mut taps {
            *t /= k2 as f64;
        }
        GoldenTaps { taps, side: fft }
    }
}

/// One conv layer of the golden forward: im2tiles → direct circular conv
/// in f64 (rounded to f32 tiles, the backend's emission point) →
/// overlap-add → bias → ReLU.
fn golden_conv(e: &InferenceEngine, idx: usize, x: &Tensor, fft: usize, k: usize) -> Tensor {
    let l = &e.variant.layers[idx];
    let geo = TileGeometry::new(l.h, fft, k);
    let tiles = im2tiles(x, &geo);
    let t_cnt = geo.num_tiles();
    let (cin, cout) = (l.cin, l.cout);
    let k2 = fft * fft;
    let gt = golden_taps(e, idx, fft, k);
    let side = gt.side;
    let td = tiles.data();
    let mut out = Tensor::zeros(&[t_cnt, cout, fft, fft]);
    let od = out.data_mut();
    let mut acc = vec![0f64; k2];
    for t in 0..t_cnt {
        for n in 0..cout {
            acc.iter_mut().for_each(|a| *a = 0.0);
            for m in 0..cin {
                let xoff = (t * cin + m) * k2;
                let woff = (n * cin + m) * side * side;
                for a in 0..side {
                    for b in 0..side {
                        let wv = gt.taps[woff + a * side + b];
                        if wv == 0.0 {
                            continue;
                        }
                        for u in 0..fft {
                            let xr = xoff + ((u + fft - a) % fft) * fft;
                            let yr = u * fft;
                            for v in 0..fft {
                                acc[yr + v] += wv * td[xr + (v + fft - b) % fft] as f64;
                            }
                        }
                    }
                }
            }
            let dst = (t * cout + n) * k2;
            for (o, &a) in od[dst..dst + k2].iter_mut().zip(&acc) {
                *o = a as f32;
            }
        }
    }
    let mut y = overlap_add(&out, &geo, cout);
    nn::add_bias(&mut y, &e.weights.convs[idx].bias);
    nn::relu(&mut y);
    y
}

/// Full golden forward: walk the variant's activation graph with direct
/// convs, residual adds and concats, then the shared FC head.
fn golden_forward(e: &InferenceEngine, fft: usize, k: usize, img: &Tensor) -> Vec<f32> {
    let steps = e.variant.graph_ops();
    let mut vals: Vec<Option<Tensor>> = vec![None; steps.len() + 1];
    vals[0] = Some(img.clone());
    for (i, op) in steps.iter().enumerate() {
        let out = match *op {
            GraphOp::Conv { conv, input } => {
                let x = vals[input].as_ref().expect("golden: input produced");
                let mut y = golden_conv(e, conv, x, fft, k);
                if e.variant.layers[conv].pool_after {
                    y = nn::maxpool2(&y);
                }
                y
            }
            GraphOp::Add { a, b } => {
                vals[a].as_ref().unwrap().add(vals[b].as_ref().unwrap())
            }
            GraphOp::Concat { a, b } => {
                let xa = vals[a].as_ref().unwrap();
                let xb = vals[b].as_ref().unwrap();
                let (ca, s) = (xa.shape()[0], xa.shape()[1]);
                let cb = xb.shape()[0];
                let mut data = Vec::with_capacity((ca + cb) * s * s);
                data.extend_from_slice(xa.data());
                data.extend_from_slice(xb.data());
                Tensor::from_vec(&[ca + cb, s, s], data)
            }
        };
        vals[i + 1] = Some(out);
    }
    let x = vals.pop().unwrap().expect("golden: final tensor produced");
    let n_fc = e.weights.fc.len();
    let mut v = x.into_vec();
    for (i, (w, b)) in e.weights.fc.iter().enumerate() {
        v = nn::dense(w, b, &v);
        if i + 1 < n_fc {
            for val in &mut v {
                if *val < 0.0 {
                    *val = 0.0;
                }
            }
        }
    }
    v
}

/// Pin one (variant, mode) config: golden logits per distinct seed, then
/// every (policy, batch) engine run must land within 1e-5.
fn pin_config(variant: &str, mode: WeightMode, policies: &[SchedulePolicy], seeds: &[u64]) {
    let rt = spectral_flow::runtime::Runtime::open(&artifacts_dir()).expect("runtime");
    let (fft, k) = (rt.manifest.fft_size, rt.manifest.kernel_k);
    let mut first = f64_engine(variant, mode, policies[0]);
    let images: Vec<Tensor> = seeds.iter().map(|&s| first.synthetic_image(s)).collect();
    let golden: Vec<Vec<f32>> =
        images.iter().map(|img| golden_forward(&first, fft, k, img)).collect();
    for g in &golden {
        assert!(g.iter().all(|v| v.is_finite()), "{variant}: golden produced non-finite");
    }
    for (pi, &policy) in policies.iter().enumerate() {
        // the first policy reuses the engine the golden weights came from
        let mut other = None;
        let e = if pi == 0 { &mut first } else { other.insert(f64_engine(variant, mode, policy)) };
        // batch = 1
        let logits = e.forward(&images[0]).expect("forward");
        assert_allclose(&logits, &golden[0], 1e-5, 1e-5);
        // batch = 8, cycling the distinct seeds across the lanes
        let batch: Vec<Tensor> = (0..8).map(|i| images[i % images.len()].clone()).collect();
        let out = e.forward_batch(&batch).expect("forward_batch");
        for (i, lane) in out.iter().enumerate() {
            assert_allclose(lane, &golden[i % golden.len()], 1e-5, 1e-5);
        }
    }
}

const ALL_POLICIES: [SchedulePolicy; 3] =
    [SchedulePolicy::Off, SchedulePolicy::LowestIndex, SchedulePolicy::ExactCover];

#[test]
fn demo_dense_matches_spatial_golden() {
    pin_config("demo", WeightMode::Dense, &ALL_POLICIES, &[1, 2, 3]);
}

#[test]
fn demo_pruned_alpha4_matches_spatial_golden() {
    pin_config("demo", WeightMode::Pruned { alpha: 4 }, &ALL_POLICIES, &[1, 2, 3]);
}

#[test]
fn demo_residual_dense_matches_spatial_golden() {
    pin_config("demo-residual", WeightMode::Dense, &ALL_POLICIES, &[1, 2, 3]);
}

#[test]
fn demo_residual_pruned_alpha4_matches_spatial_golden() {
    pin_config("demo-residual", WeightMode::Pruned { alpha: 4 }, &ALL_POLICIES, &[1, 2, 3]);
}

#[test]
fn resnet18_dense_matches_spatial_golden() {
    // dense golden taps are 9-wide, so two distinct images stay cheap
    let policies = [SchedulePolicy::Off, SchedulePolicy::ExactCover];
    pin_config("resnet18", WeightMode::Dense, &policies, &[1, 2]);
}

#[test]
fn resnet18_pruned_alpha4_matches_spatial_golden() {
    // pruned golden taps are K²-wide (the naive inverse DFT), so one
    // distinct image bounds the direct-conv cost; the batch-8 leg still
    // exercises the fused graph executor on every lane
    let policies = [SchedulePolicy::Off, SchedulePolicy::ExactCover];
    pin_config("resnet18", WeightMode::Pruned { alpha: 4 }, &policies, &[1]);
}

#[test]
fn vgg16_cifar_dense_matches_spatial_golden() {
    // chain preset: the golden graph walk degenerates to the layer loop
    let rt = spectral_flow::runtime::Runtime::open(&artifacts_dir()).expect("runtime");
    let (fft, k) = (rt.manifest.fft_size, rt.manifest.kernel_k);
    let mut e = f64_engine("vgg16-cifar", WeightMode::Dense, SchedulePolicy::ExactCover);
    let img = e.synthetic_image(1);
    let golden = golden_forward(&e, fft, k, &img);
    let logits = e.forward(&img).expect("forward");
    assert_allclose(&logits, &golden, 1e-5, 1e-5);
}

#[test]
#[ignore = "minutes of naive K²-tap direct conv; run with --ignored"]
fn vgg16_cifar_pruned_alpha4_matches_spatial_golden() {
    let policies = [SchedulePolicy::ExactCover];
    pin_config("vgg16-cifar", WeightMode::Pruned { alpha: 4 }, &policies, &[1]);
}
