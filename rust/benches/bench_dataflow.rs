//! Bench E2/E4 — regenerates Table 1 (optimal streaming parameters, K=8 and
//! K=16) and Table 2 (per-layer bandwidth at τ=20 ms) and times Alg. 1.
//!
//! ```bash
//! cargo bench --bench bench_dataflow [-- --quick]
//! ```

use spectral_flow::analysis::ArchParams;
use spectral_flow::dataflow::{optimize_network, optimize_network_at, OptimizerConfig};
use spectral_flow::model::Network;
use spectral_flow::report::{fmt_gbps, fmt_ms, Table};
use spectral_flow::util::bench::{quick_requested, Bench};

fn main() {
    let mut b = if quick_requested() { Bench::quick() } else { Bench::new() };
    let cfg = OptimizerConfig::paper();

    for (net, arch) in [
        (Network::vgg16_224(), ArchParams::paper()),
        (Network::vgg16_224_k16(), ArchParams { p_par: 16, n_par: 32, replicas: 10 }),
    ] {
        let plan = match optimize_network_at(&net, arch, &cfg) {
            Some(p) => p,
            None => {
                println!("({}: no feasible plan at P'={}, N'={})", net.name, arch.p_par, arch.n_par);
                continue;
            }
        };
        let mut t1 = Table::new(
            &format!("Table 1 — {} (P'={}, N'={})", net.name, arch.p_par, arch.n_par),
            &["layer", "Ps", "Ns"],
        );
        let mut t2 = Table::new(
            &format!("Table 2 — required bandwidth, {} (τ=20 ms)", net.name),
            &["layer", "τ_i", "BW"],
        );
        for lp in &plan.layers {
            t1.row(vec![lp.layer_name.clone(), lp.stream.ps.to_string(), lp.stream.ns.to_string()]);
            t2.row(vec![lp.layer_name.clone(), fmt_ms(lp.tau), fmt_gbps(lp.bandwidth)]);
        }
        println!("{}", t1.render());
        println!("{}", t2.render());
        println!("bw_max: {}\n", fmt_gbps(plan.bw_max));
        let _ = t1.save_csv(&format!("table1_{}", net.name));
        let _ = t2.save_csv(&format!("table2_{}", net.name));
    }

    println!("paper reference (Table 1, K=8): Ps 243/126/108/27/9, Ns 64/128/128/512/512");
    println!("paper reference (Table 2): 8.2/7.3/4.7/4.8/3.5/5.0/4.3/9.9 GB/s\n");

    // --- design-space exploration: Alg 1's outer loop as a table ---------
    // (the paper reports only the chosen point; this regenerates the whole
    // candidate surface so the choice is auditable)
    let net = Network::vgg16_224();
    let mut dse = Table::new(
        "DSE — bw_max (GB/s) and max BRAMs per architecture candidate (α=4, τ=20 ms)",
        &["P'", "N'", "PEs", "bw_max", "max BRAMs", "feasible"],
    );
    for arch in spectral_flow::dataflow::arch_candidates(10) {
        match optimize_network_at(&net, arch, &cfg) {
            Some(plan) => {
                let max_bram = plan.layers.iter().map(|l| l.brams).max().unwrap_or(0);
                dse.row(vec![
                    arch.p_par.to_string(),
                    arch.n_par.to_string(),
                    (arch.p_par * arch.n_par).to_string(),
                    format!("{:.1}", plan.bw_max / 1e9),
                    max_bram.to_string(),
                    "yes".into(),
                ]);
            }
            None => {
                dse.row(vec![
                    arch.p_par.to_string(),
                    arch.n_par.to_string(),
                    (arch.p_par * arch.n_par).to_string(),
                    "-".into(),
                    "-".into(),
                    "no".into(),
                ]);
            }
        }
    }
    println!("{}", dse.render());
    let _ = dse.save_csv("dse_arch");

    println!("--- timing ---");
    b.run("dataflow/alg1_fixed_arch", || {
        optimize_network_at(&net, ArchParams::paper(), &cfg).unwrap().bw_max
    });
    b.run("dataflow/alg1_full_search", || {
        optimize_network(&net, &cfg).unwrap().bw_max
    });
    let _ = b.write_csv("reports/bench_dataflow.csv");
    let _ = b.write_json("reports/BENCH_dataflow.json");
}
