//! Bench E5/E6/E7 — regenerates Fig. 8 (per-layer PE utilization), Fig. 9
//! (avg utilization vs replicas, ADMM-like kernels) and Fig. 10 (random
//! sparsity), and times the three schedulers on the paper's group shape.
//!
//! ```bash
//! cargo bench --bench bench_scheduling [-- --quick]
//! ```

use spectral_flow::model::Network;
use spectral_flow::report::{fmt_pct, Table};
use spectral_flow::schedule::{sampled_layer_utilization, Scheduler};
use spectral_flow::sparse::{prune_magnitude, prune_random, SparseLayer};
use spectral_flow::util::bench::{quick_requested, Bench};
use spectral_flow::util::rng::Pcg32;

const N_PAR: usize = 64;

/// Sampling seed: kept at the historical value so regenerated figures stay
/// comparable run over run.
const SAMPLE_SEED: u64 = 7;

fn layer_util(sparse: &SparseLayer, sch: Scheduler, r: usize, samples: usize) -> f64 {
    sampled_layer_utilization(sparse, sch, N_PAR, r, samples, SAMPLE_SEED)
}

/// Sparse layers for one (α, pattern) setting — generated once and reused
/// across every (r, scheduler) grid point (generation is ~10× the cost of
/// scheduling a sampled instance set).
fn gen_layers(net: &Network, alpha: usize, random: bool) -> Vec<(SparseLayer, f64)> {
    let mut rng = Pcg32::new(2020 + alpha as u64);
    net.optimized_convs()
        .iter()
        .map(|conv| {
            let sparse = if random {
                prune_random(conv.cout, conv.cin, conv.fft, alpha, &mut rng)
            } else {
                prune_magnitude(conv.cout, conv.cin, conv.fft, alpha, &mut rng)
            };
            (sparse, conv.spectral_macs() as f64)
        })
        .collect()
}

fn avg_util(layers: &[(SparseLayer, f64)], sch: Scheduler, r: usize, samples: usize) -> f64 {
    let (mut num, mut den) = (0.0, 0.0);
    for (sparse, w) in layers {
        num += layer_util(sparse, sch, r, samples) * w;
        den += w;
    }
    num / den
}

fn main() {
    let quick = quick_requested();
    let mut b = if quick { Bench::quick() } else { Bench::new() };
    let samples = if quick { 4 } else { 10 };
    let net = Network::vgg16_224();

    // ---- Fig 8 ------------------------------------------------------------
    let mut fig8 = Table::new(
        "Fig 8 — PE utilization per layer (r=8, N'=64, α=4, ADMM-like)",
        &["layer", "exact-cover", "lowest-index", "random"],
    );
    let mut rng = Pcg32::new(2020);
    for conv in net.optimized_convs() {
        let sparse = prune_magnitude(conv.cout, conv.cin, conv.fft, 4, &mut rng);
        fig8.row(vec![
            conv.name.clone(),
            fmt_pct(layer_util(&sparse, Scheduler::ExactCover, 8, samples)),
            fmt_pct(layer_util(&sparse, Scheduler::LowestIndexFirst, 8, samples)),
            fmt_pct(layer_util(&sparse, Scheduler::Random, 8, samples)),
        ]);
    }
    println!("{}", fig8.render());
    let _ = fig8.save_csv("fig8");

    // ---- Figs 9 & 10 -------------------------------------------------------
    let rs: &[usize] = if quick { &[4, 10, 16] } else { &[4, 6, 8, 10, 12, 16, 20] };
    for (name, random) in [("Fig 9 — ADMM-like", false), ("Fig 10 — random non-zeros", true)] {
        let mut t = Table::new(
            &format!("{name}: avg PE utilization vs replicas (N'=64)"),
            &["r", "EC α=4", "LI α=4", "RD α=4", "EC α=8", "LI α=8", "RD α=8"],
        );
        let layers4 = gen_layers(&net, 4, random);
        let layers8 = gen_layers(&net, 8, random);
        for &r in rs {
            let mut cells = vec![r.to_string()];
            for layers in [&layers4, &layers8] {
                for sch in Scheduler::ALL {
                    cells.push(fmt_pct(avg_util(layers, sch, r, samples)));
                }
            }
            t.row(cells);
        }
        println!("{}", t.render());
        let _ = t.save_csv(if random { "fig10" } else { "fig9" });
    }
    println!("paper reference: EC >80% at r=10 even for α=8; LI needs r≈16.\n");

    // ---- timing ------------------------------------------------------------
    println!("--- timing (one 64-kernel group, α=4 → 16 nnz each) ---");
    let mut rng = Pcg32::new(1);
    let layer = prune_magnitude(64, 1, 8, 4, &mut rng);
    let kernels = layer.group_indices(0, 64, 0);
    b.run("schedule/exact_cover_64x16_r10", || {
        Scheduler::ExactCover.run(&kernels, 10, 0).cycles()
    });
    b.run("schedule/lowest_index_64x16_r10", || {
        Scheduler::LowestIndexFirst.run(&kernels, 10, 0).cycles()
    });
    b.run("schedule/random_64x16_r10", || {
        Scheduler::Random.run(&kernels, 10, 0).cycles()
    });
    let rnd = prune_random(64, 1, 8, 8, &mut rng);
    let k8 = rnd.group_indices(0, 64, 0);
    b.run("schedule/exact_cover_64x8_r10_alpha8", || {
        Scheduler::ExactCover.run(&k8, 10, 0).cycles()
    });
    let _ = b.write_csv("reports/bench_scheduling.csv");
    let _ = b.write_json("reports/BENCH_scheduling.json");
}
