//! Bench — serving latency **over the wire**: closed-loop HTTP load
//! against the engine pool across the workers × backend-threads × α ×
//! scheduler grid. Where `bench_e2e` times the engine in-process, this
//! bench times the full request path (socket → event worker → admission →
//! batcher → pool → JSON response) and records p50 (median) and p99 per
//! grid point into `reports/BENCH_serve.json` — the artifact CI's
//! bench-smoke job uploads and the serve-loadgen-smoke job reproduces
//! from the CLI.
//!
//! ```bash
//! cargo bench --bench bench_serve [-- --quick]
//! ```

use std::sync::Arc;
use std::time::Duration;

use spectral_flow::coordinator::{BatcherConfig, EngineOptions, ModelRegistry, ModelSpec};
use spectral_flow::net::{loadgen, HttpFrontend, LoadGenConfig, LoadMode, NetConfig};
use spectral_flow::runtime::{BackendKind, Dtype, Plane};
use spectral_flow::schedule::SchedulePolicy;
use spectral_flow::util::bench::{quick_requested, Bench};

/// Boot a single-model registry serving the demo variant behind the
/// event-driven front-end on an ephemeral port.
fn start_frontend(spec: ModelSpec) -> HttpFrontend {
    let registry = Arc::new(ModelRegistry::new("artifacts", "demo"));
    registry.load_blocking("demo", spec).expect("demo model loads");
    HttpFrontend::start(
        registry,
        NetConfig { addr: "127.0.0.1:0".into(), ..NetConfig::default() },
    )
    .expect("frontend binds")
}

fn main() {
    let quick = quick_requested();
    let mut b = if quick { Bench::quick() } else { Bench::new() };

    // α × scheduler axis: dense, unscheduled sparse, exact-cover sparse —
    // the same execution modes bench_e2e names `_alphaN[_scheduled]`
    let modes: &[(usize, SchedulePolicy, &str)] = &[
        (1, SchedulePolicy::Off, "_alpha1"),
        (4, SchedulePolicy::Off, "_alpha4"),
        (4, SchedulePolicy::ExactCover, "_alpha4_scheduled"),
    ];
    let grid: Vec<(usize, usize)> = if quick {
        vec![(1, 1), (2, 1)] // workers × backend-threads
    } else {
        vec![(1, 1), (2, 1), (1, 2), (2, 2)]
    };
    let requests = if quick { 8 } else { 32 };
    let concurrency = 4;

    for &(workers, threads) in &grid {
        for &(alpha, policy, suffix) in modes {
            if quick && alpha == 4 && policy == SchedulePolicy::Off {
                continue; // quick mode: dense + scheduled only
            }
            let frontend = start_frontend(ModelSpec {
                preset: "demo".into(),
                alpha,
                batcher: BatcherConfig {
                    max_batch: 4,
                    max_wait: Duration::from_millis(2),
                },
                workers,
                engine: EngineOptions::builder()
                    .backend(BackendKind::Interp { threads })
                    .scheduler(policy)
                    .build(),
                ..ModelSpec::default()
            });
            let report = loadgen::run(&LoadGenConfig {
                addr: frontend.local_addr().to_string(),
                mode: LoadMode::Closed { concurrency },
                requests,
                timeout: Duration::from_secs(60),
                ..LoadGenConfig::default()
            })
            .expect("loadgen runs");
            assert_eq!(
                report.ok, report.sent,
                "serving under the admission bound must succeed 100%"
            );
            report.record_into(
                &mut b,
                &format!("serve/http_demo_c{concurrency}_w{workers}_t{threads}{suffix}"),
            );
            println!(
                "  w={workers} t={threads} α={alpha} {}: {:.1} req/s",
                policy.label(),
                report.throughput()
            );
            frontend.shutdown().expect("graceful shutdown");
        }
    }

    // ---- numerics sweep: half-plane / f64 serving over the wire ----------
    // Two extra grid points at the serving default shape (w=1 t=1 α=4
    // scheduled): the rfft2 half-plane at f32 (the production fast path —
    // compare against `_alpha4_scheduled` above for the wire-level win),
    // and the f64 half-plane reference the equivalence tests pin against.
    for &(dtype, plane, suffix) in &[
        (None, Plane::Half, "_half"),
        (Some(Dtype::F64), Plane::Half, "_f64_half"),
    ] {
        let frontend = start_frontend(ModelSpec {
            preset: "demo".into(),
            alpha: 4,
            batcher: BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(2),
            },
            engine: EngineOptions::builder().dtype(dtype).plane(plane).build(),
            ..ModelSpec::default()
        });
        let report = loadgen::run(&LoadGenConfig {
            addr: frontend.local_addr().to_string(),
            mode: LoadMode::Closed { concurrency },
            requests,
            timeout: Duration::from_secs(60),
            ..LoadGenConfig::default()
        })
        .expect("loadgen runs");
        assert_eq!(report.ok, report.sent, "numerics sweep must succeed 100%");
        report.record_into(
            &mut b,
            &format!("serve/http_demo_c{concurrency}_w1_t1_alpha4_scheduled{suffix}"),
        );
        println!(
            "  dtype={} plane={}: {:.1} req/s",
            dtype.unwrap_or_default().label(),
            plane.label(),
            report.throughput()
        );
        frontend.shutdown().expect("graceful shutdown");
    }

    // ---- max-batch sweep: fused batch serving over the wire --------------
    // One HTTP request carries a full `{"batch":[…]}` body of B seeds and
    // the pool runs it as fused batch forwards (max_batch = B) — the
    // wire-level analogue of bench_e2e's batch sweep. Each recorded sample
    // is one whole-batch round-trip, so compare like-for-like across B.
    for max_batch in [1usize, 8, 32] {
        let frontend = start_frontend(ModelSpec {
            preset: "demo".into(),
            alpha: 4,
            batcher: BatcherConfig {
                max_batch,
                max_wait: Duration::from_millis(2),
            },
            ..ModelSpec::default()
        });
        let body = format!(
            "{{\"batch\":[{}]}}",
            (0..max_batch)
                .map(|s| format!("{{\"seed\":{s}}}"))
                .collect::<Vec<_>>()
                .join(",")
        );
        let report = loadgen::run(&LoadGenConfig {
            addr: frontend.local_addr().to_string(),
            mode: LoadMode::Closed { concurrency: 1 },
            requests: if quick { 4 } else { 8 },
            body: Some(body),
            timeout: Duration::from_secs(60),
            ..LoadGenConfig::default()
        })
        .expect("loadgen runs");
        assert_eq!(report.ok, report.sent, "batched serving must succeed 100%");
        report.record_into(
            &mut b,
            &format!("serve/http_demo_batchbody{max_batch}_alpha4_scheduled"),
        );
        println!(
            "  batch body B={max_batch}: {:.1} batches/s ({:.1} img/s)",
            report.throughput(),
            report.throughput() * max_batch as f64
        );
        frontend.shutdown().expect("graceful shutdown");
    }

    let _ = b.write_csv("reports/bench_serve.csv");
    let _ = b.write_json("reports/BENCH_serve.json");
}
