//! Bench E8/E9 — regenerates Table 3 (device comparison on the simulated
//! U200) and the Fig. 11 resource estimate, and times the simulator.
//!
//! ```bash
//! cargo bench --bench bench_simulator [-- --quick]
//! ```

use spectral_flow::analysis::ArchParams;
use spectral_flow::dataflow::{optimize_network_at, OptimizerConfig};
use spectral_flow::model::Network;
use spectral_flow::report::{fmt_gbps, fmt_ms, fmt_pct, Table};
use spectral_flow::sim::baselines::{run_baseline, sparse_spatial_17_latency, BaselineConfig};
use spectral_flow::sim::{estimate_resources, SimConfig};
use spectral_flow::util::bench::{quick_requested, Bench};

fn main() {
    let quick = quick_requested();
    let mut b = if quick { Bench::quick() } else { Bench::new() };
    let samples = if quick { 8 } else { 24 };
    let net = Network::vgg16_224();

    let mut t3 = Table::new(
        "Table 3 — VGG16-224 conv stack on the simulated U200",
        &["design", "latency", "fps", "BW req", "avg PE util"],
    );
    for cfg in BaselineConfig::all() {
        let res = run_baseline(&cfg, &net, Some(samples), 2020);
        t3.row(vec![
            cfg.name.to_string(),
            fmt_ms(res.latency_secs()),
            format!("{:.0}", res.throughput_fps()),
            fmt_gbps(res.required_bandwidth()),
            fmt_pct(res.avg_pe_utilization()),
        ]);
    }
    t3.row(vec![
        "[17]-like (sparse spatial)".into(),
        fmt_ms(sparse_spatial_17_latency(&net, 4)),
        "-".into(),
        "-".into(),
        "-".into(),
    ]);
    println!("{}", t3.render());
    let _ = t3.save_csv("table3");
    println!("paper reference: this-work 9 ms / 112 fps / 12 GB/s; [16] 68 ms @ 9 GB/s;");
    println!("                 [27] 250 ms; [26] 167 ms; [17] 200 ms\n");

    let ocfg = OptimizerConfig::paper();
    let plan = optimize_network_at(&net, ArchParams::paper(), &ocfg).unwrap();
    let plans: Vec<_> = plan.layers.iter().map(|l| (l.params, l.stream)).collect();
    let r = estimate_resources(&ArchParams::paper(), &plans, SimConfig::default().fft_butterflies_per_cycle);
    println!("Fig 11 — resources: {}", r.utilization_report());
    println!("paper reference:    DSP 2680/6840, BRAM 1469/2160, LUT 230K/1.2M\n");

    // --- ablations: which design choice buys what ------------------------
    // (DESIGN.md calls these out: scheduler choice and replica count at the
    // paper's headline operating point, plus the fixed-dataflow ablation)
    use spectral_flow::schedule::Scheduler;
    use spectral_flow::sim::baselines::FixedStream;
    let mut abl = Table::new(
        "Ablations — this-work VGG16-224 with one knob changed",
        &["config", "latency", "avg PE util", "DDR MB"],
    );
    let mut add = |name: &str, cfg: &BaselineConfig| {
        let r = run_baseline(cfg, &net, Some(samples.min(12)), 2020);
        abl.row(vec![
            name.to_string(),
            fmt_ms(r.latency_secs()),
            fmt_pct(r.avg_pe_utilization()),
            format!("{:.0}", r.total_ddr_bytes() as f64 / 1e6),
        ]);
    };
    add("full (EC, r=10, flexible)", &BaselineConfig::this_work());
    for (name, sch) in [("scheduler → lowest-index", Scheduler::LowestIndexFirst),
                        ("scheduler → random", Scheduler::Random)] {
        let mut c = BaselineConfig::this_work();
        c.scheduler = sch;
        add(name, &c);
    }
    for r in [6usize, 16] {
        let mut c = BaselineConfig::this_work();
        c.arch.replicas = r;
        add(&format!("replicas → {r}"), &c);
    }
    let mut c = BaselineConfig::this_work();
    c.fixed_stream = Some(FixedStream::StreamKernels);
    add("dataflow → fixed stream-kernels", &c);
    let mut c2 = BaselineConfig::this_work();
    c2.alpha = 8;
    add("compression → α=8", &c2);
    println!("{}", abl.render());
    let _ = abl.save_csv("ablations");

    println!("--- timing ---");
    b.run("sim/this_work_vgg224_sampled", || {
        run_baseline(&BaselineConfig::this_work(), &net, Some(samples), 2020).latency_secs()
    });
    let cifar = Network::vgg16_cifar();
    b.run("sim/this_work_cifar_sampled", || {
        run_baseline(&BaselineConfig::this_work(), &cifar, Some(samples), 2020).latency_secs()
    });
    let _ = b.write_csv("reports/bench_simulator.csv");
    let _ = b.write_json("reports/BENCH_simulator.json");
}
