//! Bench E10 — the real-numerics end-to-end path through the spectral
//! backend: per-layer latency, full forward passes, and the serving loop.
//! This is the path the §Perf optimization pass iterates on (EXPERIMENTS.md
//! §Perf). Runs on the offline `interp` backend by default (no artifacts
//! needed); with `--features pjrt` + `make artifacts` the same bench times
//! the PJRT executables.
//!
//! ```bash
//! cargo bench --bench bench_e2e [-- --quick]
//! ```

use std::time::{Duration, Instant};

use spectral_flow::coordinator::{
    BatcherConfig, EngineOptions, InferenceEngine, Server, ServerConfig, WeightMode,
};
use spectral_flow::runtime::{BackendKind, Dtype, Plane};
use spectral_flow::schedule::SchedulePolicy;
use spectral_flow::tensor::Tensor;
use spectral_flow::util::bench::{quick_requested, Bench};
use spectral_flow::util::rng::Pcg32;

/// Numeric mode for the engine-level sections, from the environment: CI's
/// dtype × plane matrix sets `SF_DTYPE`/`SF_PLANE` and every engine entry
/// gets a `_f64`/`_half` name suffix so per-config artifacts stay distinct.
/// Unset = f32/full, the historical names the bench-regression baseline
/// gates on.
fn env_numerics() -> (Option<Dtype>, Plane, String) {
    let dtype = std::env::var("SF_DTYPE")
        .ok()
        .filter(|s| !s.is_empty())
        .map(|s| Dtype::parse(&s).expect("SF_DTYPE must be f32|f64"));
    let plane = std::env::var("SF_PLANE")
        .ok()
        .filter(|s| !s.is_empty())
        .map(|s| Plane::parse(&s).expect("SF_PLANE must be full|half"))
        .unwrap_or_default();
    let mut sfx = String::new();
    if dtype == Some(Dtype::F64) {
        sfx.push_str("_f64");
    }
    if plane == Plane::Half {
        sfx.push_str("_half");
    }
    (dtype, plane, sfx)
}

fn main() {
    let quick = quick_requested();
    let mut b = if quick { Bench::quick() } else { Bench::new() };
    let (env_dtype, env_plane, sfx) = env_numerics();
    let opts = |scheduler: SchedulePolicy, plan_batch: usize| EngineOptions {
        scheduler,
        plan_batch,
        dtype: env_dtype,
        plane: env_plane,
        ..EngineOptions::default()
    };

    // ---- per-layer backend latency (demo + cifar shapes) -----------------
    let mut engine = InferenceEngine::with_options(
        "artifacts",
        "demo",
        WeightMode::Dense,
        42,
        opts(SchedulePolicy::default(), 1),
    )
    .expect("demo engine");
    println!("backend: {} (dtype {}, plane {})", engine.backend_name(),
        engine.dtype().label(), engine.plane().label());
    let img = engine.synthetic_image(1);
    b.run(&format!("e2e/demo_conv_layer0{sfx}"), || engine.conv_layer(0, &img).unwrap().len());
    b.run(&format!("e2e/demo_forward{sfx}"), || engine.forward(&img).unwrap().len());

    // ---- observability overhead: traffic counters on vs off --------------
    // Two pins from the obs work: the relaxed-atomic data-movement counters
    // are bit-invisible to the logits, and their cost stays inside a 2%
    // budget on the demo forward median (asserted only in full runs — quick
    // medians are too noisy to gate on). The per-forward measured weight
    // bytes are also recorded as a pseudo-latency COUNT entry (1 ns per
    // byte, the mac_weight_nnz_* convention) so the bench-regression gate
    // pins Eq. 13 agreement per commit.
    {
        let mut on = InferenceEngine::with_options(
            "artifacts",
            "demo",
            WeightMode::Dense,
            42,
            EngineOptions { observe: true, ..opts(SchedulePolicy::default(), 1) },
        )
        .expect("demo engine (observe on)");
        let mut off = InferenceEngine::with_options(
            "artifacts",
            "demo",
            WeightMode::Dense,
            42,
            EngineOptions { observe: false, ..opts(SchedulePolicy::default(), 1) },
        )
        .expect("demo engine (observe off)");
        let lon = on.forward(&img).expect("observed forward");
        let loff = off.forward(&img).expect("unobserved forward");
        assert_eq!(lon, loff, "traffic counters must be bit-invisible to the logits");
        let mon = b
            .run(&format!("e2e/demo_forward_observe_on{sfx}"), || on.forward(&img).unwrap().len())
            .median_ns;
        let moff = b
            .run(&format!("e2e/demo_forward_observe_off{sfx}"), || {
                off.forward(&img).unwrap().len()
            })
            .median_ns;
        let ratio = mon / moff;
        println!(
            "observe overhead: {ratio:.4}× (on {mon:.0} ns vs off {moff:.0} ns median)"
        );
        if !quick {
            assert!(ratio <= 1.02, "observability overhead {ratio:.4}× exceeds the 2% budget");
        }
        if sfx.is_empty() {
            // one clean forward through a fresh default-config engine: the
            // dense demo plan streams every kernel word exactly once, so
            // measured must equal the Eq. 13 prediction to the byte
            let mut fresh = InferenceEngine::with_options(
                "artifacts",
                "demo",
                WeightMode::Dense,
                42,
                EngineOptions::default(),
            )
            .expect("demo engine (traffic count)");
            let _ = fresh.forward(&img).expect("traffic forward");
            let tm = fresh.traffic_metrics().expect("traffic metrics");
            assert_eq!(
                tm.measured_weight_bytes(),
                tm.predicted_weight_bytes(),
                "demo dense weight stream must match Eq. 13 exactly"
            );
            b.record(
                "e2e/demo_traffic_weight_bytes",
                Duration::from_nanos(tm.measured_weight_bytes()),
                1,
            );
            println!("  {}", tm.report());
        }
    }

    let t0 = Instant::now();
    let mut cifar = InferenceEngine::with_options(
        "artifacts",
        "vgg16-cifar",
        WeightMode::Pruned { alpha: 4 },
        7,
        opts(SchedulePolicy::default(), 1),
    )
    .expect("cifar engine");
    b.record(&format!("e2e/cifar_engine_startup{sfx}"), t0.elapsed(), 1);
    let cimg = cifar.synthetic_image(2);
    b.run(&format!("e2e/cifar_conv1_1{sfx}"), || cifar.conv_layer(0, &cimg).unwrap().len());
    b.run(&format!("e2e/cifar_vgg16_forward{sfx}"), || cifar.forward(&cimg).unwrap().len());

    // ---- α sweep: dense vs unscheduled-sparse vs scheduled-sparse --------
    // The compression→latency story of Table 3, now with the Alg. 2 axis:
    // α=1 runs the dense frequency-major MAC; α>1 uploads CSR kernels and
    // runs the sparse MAC either in storage order (`_alphaN`, scheduler
    // off — the PR 3 path and the historical bench name) or in exact-cover
    // schedule order (`_alphaN_scheduled`). Runs in quick mode too, so
    // CI's BENCH_QUICK=1 artifact records the full sweep per commit and
    // the bench-regression gate watches all three execution modes.
    for alpha in [1usize, 4, 8] {
        let policies: &[(SchedulePolicy, &str)] = if alpha == 1 {
            &[(SchedulePolicy::Off, "")] // dense: no sparse walk to schedule
        } else {
            &[(SchedulePolicy::Off, ""), (SchedulePolicy::ExactCover, "_scheduled")]
        };
        for &(policy, suffix) in policies {
            let mut e = InferenceEngine::with_options(
                "artifacts",
                "vgg16-cifar",
                WeightMode::from_alpha(alpha),
                7,
                opts(policy, 1),
            )
            .expect("cifar engine (alpha sweep)");
            b.run(&format!("e2e/cifar_forward_alpha{alpha}{suffix}{sfx}"), || {
                e.forward(&cimg).unwrap().len()
            });
            if let Some(sm) = e.schedule_metrics() {
                println!("  {}", sm.report());
            }
        }
    }

    // ---- resnet18: the residual graph through the activation arena -------
    // The shortcut adds are what the arena earns its keep on: 29 tensors
    // share 3 slots. `peak_activation_bytes` is recorded as a pseudo-latency
    // entry (1 ns per byte, same convention as mac_weight_nnz_*): it is a
    // deterministic COUNT the bench-regression gate pins, not a timing — it
    // only moves if the arena planner regresses.
    for alpha in [1usize, 4] {
        let mut e = InferenceEngine::with_options(
            "artifacts",
            "resnet18",
            WeightMode::from_alpha(alpha),
            7,
            opts(SchedulePolicy::ExactCover, 1),
        )
        .expect("resnet18 engine");
        let rimg = e.synthetic_image(4);
        b.run(&format!("e2e/resnet18_alpha{alpha}_scheduled{sfx}"), || {
            e.forward(&rimg).unwrap().len()
        });
        if alpha == 1 {
            let am = e.arena_metrics();
            b.record(
                "e2e/resnet18_peak_activation_bytes",
                Duration::from_nanos(am.peak_activation_bytes),
                1,
            );
            println!("  {}", am.report());
        }
    }

    // ---- numerics sweep: half-plane / f64 forwards -----------------------
    // Always-coded entries (regardless of SF_DTYPE/SF_PLANE defaults) so the
    // default-config artifact carries the half-plane and f64-reference
    // forwards next to `cifar_forward_alpha4_scheduled`. Skipped when the
    // env already selects a non-default mode — the suffixed α-sweep names
    // above would collide with these.
    if sfx.is_empty() {
        for (dtype, plane, tag) in [
            (None, Plane::Half, "_half"),
            (Some(Dtype::F64), Plane::Full, "_f64"),
            (Some(Dtype::F64), Plane::Half, "_f64_half"),
        ] {
            let mut e = InferenceEngine::with_options(
                "artifacts",
                "vgg16-cifar",
                WeightMode::Pruned { alpha: 4 },
                7,
                EngineOptions {
                    scheduler: SchedulePolicy::ExactCover,
                    dtype,
                    plane,
                    ..EngineOptions::default()
                },
            )
            .expect("cifar engine (numerics sweep)");
            b.run(&format!("e2e/cifar_forward_alpha4_scheduled{tag}"), || {
                e.forward(&cimg).unwrap().len()
            });
        }
    }

    // ---- MAC microbench: sparse vs dense on identical values -------------
    // Same layer shape, same non-zero values: the dense path multiplies the
    // explicit zeros, the sparse path skips them — §4's α× compute cut,
    // isolated from FFT/OaA overhead. Also asserts the equivalence gate
    // (sparse == dense-with-zeros within 1e-4).
    {
        use spectral_flow::runtime::{
            freq_major_planes, ExecutableEntry, InterpBackend, SparseDataflow, SpectralBackend,
        };
        use spectral_flow::sparse::prune_magnitude;
        let (t, m, n, fft, alpha) = (16usize, 128usize, 128usize, 8usize, 4usize);
        let mut rng = Pcg32::new(77);
        let layer = prune_magnitude(n, m, fft, alpha, &mut rng);
        let tiles = Tensor::randn(&[t, m, fft, fft], &mut rng, 1.0);
        let e = ExecutableEntry {
            tiles: t,
            cin: m,
            cout: n,
            fft_size: fft,
            sha256: "bench".into(),
            bytes: 0,
        };
        let dir = std::path::Path::new(".");

        let mut dense = InterpBackend::new();
        dense.prepare("x", &e, dir).expect("prepare dense");
        let (re, im) = freq_major_planes(&layer.to_dense_planes());
        let dw = dense.upload_weights(&re, &im, [fft * fft, m, n]).expect("upload dense");

        let mut sparse = InterpBackend::new();
        sparse.prepare("x", &e, dir).expect("prepare sparse");
        // all tiles resident (the deep-layer Alg. 1 optimum): each kernel
        // row streams exactly once per conv
        sparse.set_sparse_dataflow("x", SparseDataflow { tile_block: t }).unwrap();
        let sw = sparse.upload_sparse(&layer).expect("upload sparse");

        // third contender: the same CSR upload executed in Alg. 2 schedule
        // order through the banked weight store
        use spectral_flow::runtime::SparseWeightPlanes;
        use spectral_flow::schedule::{LayerSchedule, DEFAULT_WEIGHT_BANKS};
        let mut sched = InterpBackend::new();
        sched.prepare("x", &e, dir).expect("prepare scheduled");
        sched.set_sparse_dataflow("x", SparseDataflow { tile_block: t }).unwrap();
        let cw = sched.upload_sparse(&layer).expect("upload scheduled");
        let planes = SparseWeightPlanes::from_layer(&layer);
        let plan = LayerSchedule::build(
            &planes,
            64,
            10,
            DEFAULT_WEIGHT_BANKS,
            SchedulePolicy::ExactCover,
        )
        .expect("plan");
        sched.set_schedule(cw, &plan).unwrap();

        // half-plane contenders: the same CSR upload folded onto the rfft2
        // half-plane (inside `upload_sparse`), unscheduled and in Alg. 2
        // order over the folded planes — the tentpole's halved hot loop
        let mut sparse_h = InterpBackend::with_config(1, Dtype::F32, Plane::Half);
        sparse_h.prepare("x", &e, dir).expect("prepare sparse half");
        sparse_h.set_sparse_dataflow("x", SparseDataflow { tile_block: t }).unwrap();
        let swh = sparse_h.upload_sparse(&layer).expect("upload sparse half");

        let mut sched_h = InterpBackend::with_config(1, Dtype::F32, Plane::Half);
        sched_h.prepare("x", &e, dir).expect("prepare scheduled half");
        sched_h.set_sparse_dataflow("x", SparseDataflow { tile_block: t }).unwrap();
        let cwh = sched_h.upload_sparse(&layer).expect("upload scheduled half");
        let planes_h = planes.fold_half_plane(fft);
        let plan_h = LayerSchedule::build(
            &planes_h,
            64,
            10,
            DEFAULT_WEIGHT_BANKS,
            SchedulePolicy::ExactCover,
        )
        .expect("half plan");
        sched_h.set_schedule(cwh, &plan_h).unwrap();

        let want = dense.run_conv("x", &tiles, dw).unwrap();
        let got = sparse.run_conv("x", &tiles, sw).unwrap();
        let diff = got.max_abs_diff(&want);
        assert!(diff < 1e-4, "sparse MAC diverged from dense-with-zeros: {diff}");
        let got_sched = sched.run_conv("x", &tiles, cw).unwrap();
        assert_eq!(
            got_sched.data(),
            got.data(),
            "scheduled MAC must be bit-identical to the unscheduled sparse MAC"
        );
        let got_h = sparse_h.run_conv("x", &tiles, swh).unwrap();
        let diff_h = got_h.max_abs_diff(&want);
        assert!(diff_h < 1e-4, "half-plane MAC diverged from dense full-plane: {diff_h}");
        let got_sched_h = sched_h.run_conv("x", &tiles, cwh).unwrap();
        assert_eq!(
            got_sched_h.data(),
            got_h.data(),
            "scheduled half-plane MAC must be bit-identical to the unscheduled one"
        );

        // the halved weight stream, as data: non-zeros the MAC reads per
        // conv, full plane vs folded half-plane (recorded as pseudo-latency
        // entries — 1 ns per non-zero — so the artifact carries the ratio)
        let (nnz_full, nnz_half) = (planes.nnz(), planes_h.nnz());
        let fold_ratio = nnz_half as f64 / nnz_full as f64;
        assert!(
            (0.4..=0.75).contains(&fold_ratio),
            "conjugate fold should roughly halve the weight stream: \
             {nnz_half}/{nnz_full} = {fold_ratio:.3}"
        );
        b.record("e2e/mac_weight_nnz_full", Duration::from_nanos(nnz_full as u64), 1);
        b.record("e2e/mac_weight_nnz_half", Duration::from_nanos(nnz_half as u64), 1);

        let md = b
            .run("e2e/mac_dense_t16_c128", || dense.run_conv("x", &tiles, dw).unwrap().len())
            .mean_ns;
        let ms = b
            .run(&format!("e2e/mac_sparse_alpha{alpha}_t16_c128"), || {
                sparse.run_conv("x", &tiles, sw).unwrap().len()
            })
            .mean_ns;
        let mc = b
            .run(&format!("e2e/mac_scheduled_alpha{alpha}_t16_c128"), || {
                sched.run_conv("x", &tiles, cw).unwrap().len()
            })
            .mean_ns;
        let msh = b
            .run(&format!("e2e/mac_sparse_alpha{alpha}_t16_c128_half"), || {
                sparse_h.run_conv("x", &tiles, swh).unwrap().len()
            })
            .mean_ns;
        let mch = b
            .run(&format!("e2e/mac_scheduled_alpha{alpha}_t16_c128_half"), || {
                sched_h.run_conv("x", &tiles, cwh).unwrap().len()
            })
            .mean_ns;
        println!(
            "mac sparse α={alpha} vs dense: {:.2}× faster (scheduled {:.2}×, \
             half-plane {:.2}×/{:.2}×), max |err| = {diff:.2e} (half {diff_h:.2e}), \
             weight stream {nnz_half}/{nnz_full} nnz ({:.0}%), plan util {}",
            md / ms,
            md / mc,
            md / msh,
            md / mch,
            fold_ratio * 100.0,
            spectral_flow::report::fmt_pct(plan.stats.pe_utilization()),
        );
    }

    // ---- batch sweep: B as the third reuse axis --------------------------
    // The batch-major headline: per-image latency in the sparse scheduled
    // config should drop as B grows, because each sparse weight block
    // streams once per batch instead of once per image (batch-aware
    // Alg. 1). `record(…, wall, B)` stores per-image time, so the
    // B=8 / B=1 ratio reads directly off the JSON artifact.
    {
        for bsz in [1usize, 8, 32] {
            let mut e = InferenceEngine::with_options(
                "artifacts",
                "vgg16-cifar",
                WeightMode::Pruned { alpha: 4 },
                7,
                opts(SchedulePolicy::ExactCover, bsz),
            )
            .expect("cifar engine (batch sweep)");
            let images: Vec<Tensor> = (0..bsz as u64).map(|s| e.synthetic_image(s)).collect();
            let _ = e.forward_batch(&images).expect("warm batch forward");
            let t0 = Instant::now();
            let out = e.forward_batch(&images).expect("batch forward");
            let wall = t0.elapsed();
            assert_eq!(out.len(), bsz);
            b.record(&format!("e2e/cifar_forward_scheduled_batch{bsz}_per_image{sfx}"), wall, bsz);
            println!(
                "batch sweep B={bsz}: {wall:?} total, {:?} per image",
                wall / bsz as u32
            );
        }
    }

    // ---- threads sweep: tile-parallel interp backend ---------------------
    // The acceptance target is ≥2× forward throughput at 4 backend threads
    // vs 1 on a multi-core runner (tiles are the paper's P' dimension).
    for threads in [1usize, 2, 4] {
        let mut e = InferenceEngine::with_options(
            "artifacts",
            "vgg16-cifar",
            WeightMode::Pruned { alpha: 4 },
            7,
            EngineOptions {
                backend: BackendKind::Interp { threads },
                ..opts(SchedulePolicy::default(), 1)
            },
        )
        .expect("cifar engine (threads sweep)");
        b.run(&format!("e2e/cifar_forward_threads{threads}{sfx}"), || {
            e.forward(&cimg).unwrap().len()
        });
    }

    // ---- serving throughput: pool-size sweep ------------------------------
    // One engine per worker; closed batches go to the least-loaded worker.
    let worker_counts: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4] };
    for &workers in worker_counts {
        let server = Server::start(ServerConfig {
            artifacts_dir: "artifacts".into(),
            variant: "vgg16-cifar".into(),
            mode: WeightMode::Pruned { alpha: 4 },
            seed: 7,
            batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(5) },
            workers,
            engine: opts(SchedulePolicy::default(), 1),
            ..ServerConfig::default()
        })
        .expect("server");
        let client = server.client();
        let mut rng = Pcg32::new(5);
        let n = if quick { 6 } else { 16 };
        let t0 = Instant::now();
        let rxs: Vec<_> = (0..n)
            .map(|_| client.infer_async(Tensor::randn(&[3, 32, 32], &mut rng, 1.0)).unwrap())
            .collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let wall = t0.elapsed();
        b.record(&format!("e2e/serve_cifar_batched_per_request_workers{workers}{sfx}"), wall, n);
        let m = server.metrics().expect("metrics");
        println!(
            "serving[{workers}w]: {n} requests in {wall:?} → {:.2} img/s, \
             p50 {:?}, p95 {:?}, mean batch {:.1}",
            n as f64 / wall.as_secs_f64(),
            m.p50().unwrap_or_default(),
            m.p95().unwrap_or_default(),
            m.mean_batch_size()
        );
        server.shutdown().unwrap();
    }

    // ---- single-image 224 (skipped in quick mode: ~seconds per pass) -----
    if !quick {
        let t0 = Instant::now();
        let mut big = InferenceEngine::new("artifacts", "vgg16-224", WeightMode::Pruned { alpha: 4 }, 7)
            .expect("224 engine");
        println!("vgg16-224 engine up in {:?}", t0.elapsed());
        let bimg = big.synthetic_image(3);
        let _ = big.forward(&bimg).unwrap(); // warm
        let t1 = Instant::now();
        let _ = big.forward(&bimg).unwrap();
        b.record("e2e/vgg16_224_forward_single", t1.elapsed(), 1);
    }
    let _ = b.write_csv("reports/bench_e2e.csv");
    let _ = b.write_json("reports/BENCH_e2e.json");
}
