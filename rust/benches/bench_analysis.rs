//! Bench E1/E3 — regenerates Fig. 2 (fixed-flow complexity) and Fig. 7
//! (fixed vs flexible) and times the analysis kernels.
//!
//! ```bash
//! cargo bench --bench bench_analysis [-- --quick]
//! ```

use spectral_flow::analysis::{
    bram_flow, transfers_flow, transfers_flow2, ArchParams, Flow, LayerParams,
};
use spectral_flow::dataflow::{optimize_network_at, OptimizerConfig};
use spectral_flow::model::Network;
use spectral_flow::report::Table;
use spectral_flow::util::bench::{quick_requested, Bench};

fn main() {
    let mut b = if quick_requested() { Bench::quick() } else { Bench::new() };
    let net = Network::vgg16_224();
    let arch = ArchParams::paper();

    println!("\n--- Fig 2: per-layer complexity of the three fixed flows ---");
    let mut fig2 = Table::new(
        "Fig 2 — VGG16 K=8 α=4 (transfers MB / BRAMs)",
        &["layer", "xfer F1", "xfer F2", "xfer F3", "bram F1", "bram F2", "bram F3"],
    );
    for conv in net.optimized_convs() {
        let l = LayerParams::from_layer(conv, 4);
        let mut cells = vec![conv.name.clone()];
        for f in Flow::ALL {
            cells.push(format!("{:.1}", transfers_flow(f, &l, &arch).total() as f64 * 2.0 / 1e6));
        }
        for f in Flow::ALL {
            cells.push(bram_flow(f, &l, &arch).to_string());
        }
        fig2.row(cells);
    }
    println!("{}", fig2.render());
    let _ = fig2.save_csv("fig2");

    println!("--- Fig 7: flexible vs fixed transfers ---");
    let cfg = OptimizerConfig::paper();
    let plan = optimize_network_at(&net, arch, &cfg).expect("feasible");
    let mut fig7 = Table::new(
        "Fig 7 — transfers MB: Flow#1 / Flow#2 / Flow opt",
        &["layer", "Flow#1", "Flow#2", "Flow opt"],
    );
    let (mut tot1, mut tot2, mut toto) = (0u64, 0u64, 0u64);
    for lp in &plan.layers {
        let f1 = transfers_flow(Flow::ReuseKernels, &lp.params, &arch).total();
        let f2 = transfers_flow2(&lp.params, &arch).total();
        let fo = lp.transfers.total();
        tot1 += f1;
        tot2 += f2;
        toto += fo;
        fig7.row(vec![
            lp.layer_name.clone(),
            format!("{:.1}", f1 as f64 * 2.0 / 1e6),
            format!("{:.1}", f2 as f64 * 2.0 / 1e6),
            format!("{:.1}", fo as f64 * 2.0 / 1e6),
        ]);
    }
    println!("{}", fig7.render());
    println!(
        "totals: Flow#1 {:.1} MB, Flow#2 {:.1} MB, opt {:.1} MB — opt saves {:.0}% vs Flow#2\n",
        tot1 as f64 * 2.0 / 1e6,
        tot2 as f64 * 2.0 / 1e6,
        toto as f64 * 2.0 / 1e6,
        100.0 * (1.0 - toto as f64 / tot2 as f64)
    );

    println!("--- timing ---");
    let ls: Vec<LayerParams> = net
        .optimized_convs()
        .iter()
        .map(|c| LayerParams::from_layer(c, 4))
        .collect();
    b.run("analysis/fig2_all_layers_all_flows", || {
        let mut acc = 0u64;
        for l in &ls {
            for f in Flow::ALL {
                acc += transfers_flow(f, l, &arch).total() + bram_flow(f, l, &arch);
            }
        }
        acc
    });
    b.run("analysis/eq12_eq13_single_eval", || {
        use spectral_flow::analysis::{bram_flex, transfers_flex, StreamParams};
        let s = StreamParams { ns: 128, ps: 27 };
        bram_flex(&ls[5], &arch, &s) + transfers_flex(&ls[5], &s).total()
    });
    let _ = b.write_csv("reports/bench_analysis.csv");
    let _ = b.write_json("reports/BENCH_analysis.json");
}
