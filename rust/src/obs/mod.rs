//! Observability: data-movement counters, per-request trace spans, and the
//! Prometheus text renderer behind `GET /v1/metrics?format=prometheus`.
//!
//! The paper's headline claim is a *transfer* reduction (Eq. 13), so the
//! serving stack must be able to measure bytes moved, not just predict
//! them. Three pieces, deliberately decoupled:
//!
//! * [`TrafficCounters`] — relaxed-atomic byte counters the backend's hot
//!   loops bump once per resident-block walk (never per non-zero). The
//!   engine snapshots them around each conv call and compares the deltas
//!   against the Eq. 13 volume for the layer's chosen `(Ns, Ps, B)` plan.
//! * [`TraceRing`] — a fixed-capacity, never-blocking ring of structured
//!   [`RequestTrace`]s (accept → parse → queue → batch-close → per-layer
//!   execute → respond). Writers claim a slot with one `fetch_add` and
//!   publish through a per-slot `try_lock` that *drops* the trace on
//!   contention instead of waiting (the drop is counted); readers snapshot
//!   with the same `try_lock`. A second, smaller ring retains slow
//!   requests preferentially: fast traffic wrapping the main ring can
//!   never evict an over-threshold trace.
//! * [`PromWriter`] — minimal Prometheus text exposition (version 0.0.4):
//!   `# HELP`/`# TYPE` headers plus label-escaped samples.
//!
//! Everything here is observation-only: no counter or span ever feeds back
//! into the data path, so logits are bit-identical with observation on or
//! off (pinned by tests).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

// ---------------------------------------------------------------------------
// Data-movement counters
// ---------------------------------------------------------------------------

/// Monotonic byte counters at the backend boundary, bumped by the interp
/// backend's conv loops (weights per block walk, activation tiles in/out,
/// partial-sum updates) and by the engine's arena writes. All `Relaxed`:
/// the counters are statistics, not synchronization, and each increment is
/// one atomic add per *chunk* of work — cost is invisible next to the MACs
/// it measures (the `bench_e2e` observe-on/off pair pins the overhead).
#[derive(Debug, Default)]
pub struct TrafficCounters {
    /// Spectral kernel bytes streamed (CSR rows or BankedWeights
    /// cycle-sets; dense planes on the dense path).
    pub weight_bytes: AtomicU64,
    /// Activation tile bytes read into the backend (spatial f32 words).
    pub input_bytes: AtomicU64,
    /// Activation tile bytes written out of the backend.
    pub output_bytes: AtomicU64,
    /// Partial-sum accumulator traffic (complex accumulator updates).
    pub psum_bytes: AtomicU64,
    /// Activation-arena slot bytes written by the graph executor.
    pub arena_bytes: AtomicU64,
}

impl TrafficCounters {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_weights(&self, bytes: u64) {
        self.weight_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn add_inputs(&self, bytes: u64) {
        self.input_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn add_outputs(&self, bytes: u64) {
        self.output_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn add_psums(&self, bytes: u64) {
        self.psum_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn add_arena(&self, bytes: u64) {
        self.arena_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Consistent-enough snapshot for delta accounting (the engine reads
    /// before/after a conv call on the same thread that ran it).
    pub fn snapshot(&self) -> TrafficSnapshot {
        TrafficSnapshot {
            weight_bytes: self.weight_bytes.load(Ordering::Relaxed),
            input_bytes: self.input_bytes.load(Ordering::Relaxed),
            output_bytes: self.output_bytes.load(Ordering::Relaxed),
            psum_bytes: self.psum_bytes.load(Ordering::Relaxed),
            arena_bytes: self.arena_bytes.load(Ordering::Relaxed),
        }
    }
}

/// One point-in-time reading of [`TrafficCounters`], subtractable for
/// per-layer deltas and addable for accumulation.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TrafficSnapshot {
    pub weight_bytes: u64,
    pub input_bytes: u64,
    pub output_bytes: u64,
    pub psum_bytes: u64,
    pub arena_bytes: u64,
}

impl TrafficSnapshot {
    /// Bytes moved since `earlier` (saturating: counters only grow, but a
    /// racing reader should never underflow).
    pub fn since(&self, earlier: &TrafficSnapshot) -> TrafficSnapshot {
        TrafficSnapshot {
            weight_bytes: self.weight_bytes.saturating_sub(earlier.weight_bytes),
            input_bytes: self.input_bytes.saturating_sub(earlier.input_bytes),
            output_bytes: self.output_bytes.saturating_sub(earlier.output_bytes),
            psum_bytes: self.psum_bytes.saturating_sub(earlier.psum_bytes),
            arena_bytes: self.arena_bytes.saturating_sub(earlier.arena_bytes),
        }
    }

    pub fn add(&mut self, other: &TrafficSnapshot) {
        self.weight_bytes += other.weight_bytes;
        self.input_bytes += other.input_bytes;
        self.output_bytes += other.output_bytes;
        self.psum_bytes += other.psum_bytes;
        self.arena_bytes += other.arena_bytes;
    }

    pub fn total(&self) -> u64 {
        self.weight_bytes + self.input_bytes + self.output_bytes + self.psum_bytes
            + self.arena_bytes
    }
}

// ---------------------------------------------------------------------------
// Measured-vs-predicted accounting (per layer, per engine)
// ---------------------------------------------------------------------------

/// One conv layer's measured traffic next to its Eq. 13 prediction for the
/// plan the engine actually executed (`analysis::transfers_flex_batch` at
/// the chosen `(Ns, Ps)` and the real per-call batch size). Bytes on both
/// sides use the same unit convention — complex spectral words at the
/// engine dtype for kernels, spatial f32 words for activations — so the
/// B=1 full-plane kernel ratio is exactly 1.0 by construction (pinned in
/// tests; divergences: thread chunking, the tile-overlap factor on
/// activations, and the half-plane fold — see ARCHITECTURE.md
/// "Observability").
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct LayerTraffic {
    /// Manifest layer name (e.g. `conv5_3`).
    pub layer: String,
    /// Measured backend-boundary bytes, accumulated over every forward.
    pub measured: TrafficSnapshot,
    /// Eq. 13 kernel-term bytes for the executed plan, accumulated.
    pub predicted_weight_bytes: u64,
    /// Eq. 13 input-term bytes (spatial activation words × 4).
    pub predicted_input_bytes: u64,
    /// Eq. 13 output-term bytes.
    pub predicted_output_bytes: u64,
    /// Conv invocations accumulated into this row.
    pub forwards: u64,
}

impl LayerTraffic {
    /// Measured / predicted weight-stream ratio (the paper's reuse axis).
    /// 0.0 until the layer has executed at least once.
    pub fn weight_ratio(&self) -> f64 {
        if self.predicted_weight_bytes == 0 {
            return 0.0;
        }
        self.measured.weight_bytes as f64 / self.predicted_weight_bytes as f64
    }

    pub fn merge_from(&mut self, other: &LayerTraffic) {
        self.measured.add(&other.measured);
        self.predicted_weight_bytes += other.predicted_weight_bytes;
        self.predicted_input_bytes += other.predicted_input_bytes;
        self.predicted_output_bytes += other.predicted_output_bytes;
        self.forwards += other.forwards;
    }
}

/// Engine-wide traffic accounting: one [`LayerTraffic`] per conv layer plus
/// the raw counter totals (which also carry psum and arena bytes that have
/// no per-layer prediction).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct TrafficMetrics {
    pub layers: Vec<LayerTraffic>,
    /// Raw counter totals for the engine (includes psum/arena traffic).
    pub totals: TrafficSnapshot,
}

impl TrafficMetrics {
    /// Fold another engine's accounting into this one (pool merge: layer
    /// lists are identical across replicas of one config, matched by
    /// index; a foreign shape contributes totals only).
    pub fn merge_from(&mut self, other: &TrafficMetrics) {
        if self.layers.is_empty() {
            self.layers = other.layers.clone();
        } else if self.layers.len() == other.layers.len() {
            for (dst, src) in self.layers.iter_mut().zip(&other.layers) {
                dst.merge_from(src);
            }
        }
        self.totals.add(&other.totals);
    }

    pub fn measured_weight_bytes(&self) -> u64 {
        self.layers.iter().map(|l| l.measured.weight_bytes).sum()
    }

    pub fn predicted_weight_bytes(&self) -> u64 {
        self.layers.iter().map(|l| l.predicted_weight_bytes).sum()
    }

    /// One summary line (appended to the serving report).
    pub fn report(&self) -> String {
        let m = self.measured_weight_bytes();
        let p = self.predicted_weight_bytes().max(1);
        format!(
            "traffic: weights {} B (Eq.13 {} B, x{:.3}) in {} B out {} B psum {} B arena {} B",
            m,
            self.predicted_weight_bytes(),
            m as f64 / p as f64,
            self.totals.input_bytes,
            self.totals.output_bytes,
            self.totals.psum_bytes,
            self.totals.arena_bytes,
        )
    }
}

// ---------------------------------------------------------------------------
// Trace spans
// ---------------------------------------------------------------------------

/// One interval inside a request, in microseconds since the trace ring's
/// epoch. Layer spans (`layer:<name>`) additionally carry the measured
/// backend-boundary bytes and the Eq. 13 prediction for that conv call;
/// both are 0 on structural spans.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Span {
    pub name: String,
    pub start_us: u64,
    pub end_us: u64,
    pub measured_bytes: u64,
    pub predicted_bytes: u64,
}

impl Span {
    pub fn plain(name: impl Into<String>, start_us: u64, end_us: u64) -> Span {
        Span { name: name.into(), start_us, end_us, measured_bytes: 0, predicted_bytes: 0 }
    }

    pub fn duration_us(&self) -> u64 {
        self.end_us.saturating_sub(self.start_us)
    }
}

/// A completed request's spans plus its correlation ids. `spans[0]` is the
/// root (`request`): it covers every other span, children are sorted by
/// start time, and the root's duration agrees with `latency_us` (pinned by
/// the trace-integrity tests).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RequestTrace {
    pub request: u64,
    pub batch: u64,
    pub worker: usize,
    pub model: String,
    pub batch_size: usize,
    pub latency_us: u64,
    /// Latency crossed the ring's slow threshold: the trace was also
    /// retained in the slow ring, where fast wraps can't evict it.
    pub slow: bool,
    pub spans: Vec<Span>,
}

/// A conv layer's execute interval inside one engine forward, recorded with
/// raw [`Instant`]s (the engine has no ring epoch); the serving worker
/// rebases them when it assembles the [`RequestTrace`].
#[derive(Debug, Clone)]
pub struct LayerSpan {
    pub name: String,
    pub start: Instant,
    pub end: Instant,
    pub measured_bytes: u64,
    pub predicted_bytes: u64,
}

/// Wire-side stamps the HTTP front-end hands the serving pool with each
/// request: when the parsed request entered its handler and when body
/// decode finished — the `accept`/`parse` spans of the taxonomy.
#[derive(Debug, Clone, Copy)]
pub struct WireTiming {
    pub accepted: Instant,
    pub parsed: Instant,
}

/// Trace-ring sizing. Defaults suit a serving pool: 256 recent requests,
/// 64 slow ones, slow ≥ 50 ms.
#[derive(Debug, Clone, Copy)]
pub struct TraceConfig {
    pub capacity: usize,
    pub slow_capacity: usize,
    pub slow_threshold_us: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig { capacity: 256, slow_capacity: 64, slow_threshold_us: 50_000 }
    }
}

/// Fixed-capacity, never-blocking trace store (see the module docs for the
/// two-ring design). All storage is allocated at construction; recording
/// allocates nothing and never waits on a lock.
pub struct TraceRing {
    epoch: Instant,
    recent: Vec<Mutex<Option<RequestTrace>>>,
    slow: Vec<Mutex<Option<RequestTrace>>>,
    head: AtomicU64,
    slow_head: AtomicU64,
    dropped: AtomicU64,
    slow_threshold_us: u64,
    requests: AtomicU64,
    batches: AtomicU64,
}

impl std::fmt::Debug for TraceRing {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TraceRing")
            .field("capacity", &self.recent.len())
            .field("slow_capacity", &self.slow.len())
            .field("slow_threshold_us", &self.slow_threshold_us)
            .field("recorded", &self.head.load(Ordering::Relaxed))
            .field("dropped", &self.dropped.load(Ordering::Relaxed))
            .finish()
    }
}

impl TraceRing {
    pub fn new(cfg: TraceConfig) -> Self {
        let slot = |_| Mutex::new(None);
        TraceRing {
            epoch: Instant::now(),
            recent: (0..cfg.capacity.max(1)).map(slot).collect(),
            slow: (0..cfg.slow_capacity.max(1)).map(slot).collect(),
            head: AtomicU64::new(0),
            slow_head: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            slow_threshold_us: cfg.slow_threshold_us,
            requests: AtomicU64::new(0),
            batches: AtomicU64::new(0),
        }
    }

    /// Microseconds from the ring's epoch to `t` (0 for pre-epoch stamps).
    pub fn to_us(&self, t: Instant) -> u64 {
        t.checked_duration_since(self.epoch).map(|d| d.as_micros() as u64).unwrap_or(0)
    }

    pub fn now_us(&self) -> u64 {
        self.to_us(Instant::now())
    }

    /// Fresh request correlation id (1-based).
    pub fn next_request_id(&self) -> u64 {
        self.requests.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Fresh batch correlation id (1-based).
    pub fn next_batch_id(&self) -> u64 {
        self.batches.fetch_add(1, Ordering::Relaxed) + 1
    }

    pub fn capacity(&self) -> usize {
        self.recent.len()
    }

    pub fn slow_capacity(&self) -> usize {
        self.slow.len()
    }

    pub fn slow_threshold_us(&self) -> u64 {
        self.slow_threshold_us
    }

    /// Traces whose publish lost the slot race and were discarded.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Record one completed request. Wait-free for the writer: one atomic
    /// slot claim plus a `try_lock` publish; a contended slot drops the
    /// trace (counted in [`TraceRing::dropped`]) rather than blocking the
    /// serving path. Slow traces are additionally published to the slow
    /// ring, which only slow traffic can wrap.
    pub fn record(&self, mut trace: RequestTrace) {
        trace.slow = trace.latency_us >= self.slow_threshold_us;
        if trace.slow {
            Self::publish(&self.slow, &self.slow_head, &self.dropped, trace.clone());
        }
        Self::publish(&self.recent, &self.head, &self.dropped, trace);
    }

    fn publish(
        ring: &[Mutex<Option<RequestTrace>>],
        head: &AtomicU64,
        dropped: &AtomicU64,
        trace: RequestTrace,
    ) {
        let slot = (head.fetch_add(1, Ordering::Relaxed) % ring.len() as u64) as usize;
        match ring[slot].try_lock() {
            Ok(mut g) => *g = Some(trace),
            Err(_) => {
                dropped.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Most recent `n` traces, newest first. Slots a writer holds at this
    /// instant are skipped (readers never block writers either).
    pub fn recent(&self, n: usize) -> Vec<RequestTrace> {
        Self::collect(&self.recent, &self.head, n)
    }

    /// Most recent `n` slow traces, newest first.
    pub fn slow_traces(&self, n: usize) -> Vec<RequestTrace> {
        Self::collect(&self.slow, &self.slow_head, n)
    }

    fn collect(
        ring: &[Mutex<Option<RequestTrace>>],
        head: &AtomicU64,
        n: usize,
    ) -> Vec<RequestTrace> {
        let len = ring.len() as u64;
        let h = head.load(Ordering::Relaxed);
        let take = n.min(ring.len());
        let mut out = Vec::with_capacity(take);
        for i in 1..=len.min(h) {
            if out.len() >= take {
                break;
            }
            let slot = ((h - i) % len) as usize;
            if let Ok(g) = ring[slot].try_lock() {
                if let Some(t) = &*g {
                    out.push(t.clone());
                }
            }
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Prometheus text exposition
// ---------------------------------------------------------------------------

/// Minimal Prometheus text-format (0.0.4) writer: `# HELP`/`# TYPE` family
/// headers plus samples with escaped label values. The front-end drives it
/// from registry snapshots; nothing here knows about models or pools.
#[derive(Debug, Default)]
pub struct PromWriter {
    out: String,
}

impl PromWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Start a metric family: `typ` is `counter` | `gauge` | `histogram`.
    pub fn family(&mut self, name: &str, typ: &str, help: &str) {
        self.out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {typ}\n"));
    }

    /// Emit one sample. Float values print in shortest form (`2` not
    /// `2.0`); label values are escaped per the exposition format.
    pub fn sample(&mut self, name: &str, labels: &[(&str, &str)], value: f64) {
        self.out.push_str(name);
        if !labels.is_empty() {
            self.out.push('{');
            for (i, (k, v)) in labels.iter().enumerate() {
                if i > 0 {
                    self.out.push(',');
                }
                self.out.push_str(&format!("{k}=\"{}\"", escape_label(v)));
            }
            self.out.push('}');
        }
        self.out.push_str(&format!(" {value}\n"));
    }

    pub fn finish(self) -> String {
        self.out
    }
}

/// Escape a label value: backslash, double-quote, and newline.
fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            other => out.push(other),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn trace(request: u64, latency_us: u64) -> RequestTrace {
        RequestTrace {
            request,
            batch: 1,
            worker: 0,
            model: "demo".into(),
            batch_size: 1,
            latency_us,
            slow: false,
            spans: vec![Span::plain("request", 0, latency_us)],
        }
    }

    #[test]
    fn counters_snapshot_and_delta() {
        let c = TrafficCounters::new();
        c.add_weights(100);
        c.add_inputs(40);
        c.add_psums(8);
        let a = c.snapshot();
        c.add_weights(20);
        c.add_outputs(16);
        c.add_arena(4);
        let b = c.snapshot();
        let d = b.since(&a);
        assert_eq!(d.weight_bytes, 20);
        assert_eq!(d.input_bytes, 0);
        assert_eq!(d.output_bytes, 16);
        assert_eq!(d.arena_bytes, 4);
        assert_eq!(b.total(), 188);
        // since() saturates instead of underflowing
        assert_eq!(a.since(&b).weight_bytes, 0);
    }

    #[test]
    fn layer_traffic_ratio_and_merge() {
        let mut a = LayerTraffic {
            layer: "conv1".into(),
            measured: TrafficSnapshot { weight_bytes: 1024, ..Default::default() },
            predicted_weight_bytes: 1024,
            predicted_input_bytes: 64,
            predicted_output_bytes: 64,
            forwards: 1,
        };
        assert!((a.weight_ratio() - 1.0).abs() < 1e-12);
        a.merge_from(&a.clone());
        assert_eq!(a.measured.weight_bytes, 2048);
        assert_eq!(a.forwards, 2);
        assert!((a.weight_ratio() - 1.0).abs() < 1e-12);
        // unexecuted layer: defined, not a division by zero
        assert_eq!(LayerTraffic::default().weight_ratio(), 0.0);

        let mut tm = TrafficMetrics { layers: vec![a.clone()], ..Default::default() };
        tm.merge_from(&TrafficMetrics { layers: vec![a.clone()], ..Default::default() });
        assert_eq!(tm.measured_weight_bytes(), 4096);
        assert_eq!(tm.predicted_weight_bytes(), 4096);
        assert!(tm.report().contains("x1.000"), "{}", tm.report());
        // empty target adopts the other side's layers wholesale
        let mut empty = TrafficMetrics::default();
        empty.merge_from(&tm);
        assert_eq!(empty.layers.len(), 1);
    }

    #[test]
    fn ring_returns_newest_first_and_wraps() {
        let ring = TraceRing::new(TraceConfig {
            capacity: 4,
            slow_capacity: 2,
            slow_threshold_us: u64::MAX,
        });
        for i in 1..=6 {
            ring.record(trace(i, 10));
        }
        // capacity 4, 6 recorded: 3..=6 retained, newest first
        let got: Vec<u64> = ring.recent(10).iter().map(|t| t.request).collect();
        assert_eq!(got, vec![6, 5, 4, 3]);
        assert_eq!(ring.recent(2).len(), 2);
        assert_eq!(ring.recent(2)[0].request, 6);
        assert_eq!(ring.dropped(), 0);
        assert_eq!(ring.capacity(), 4);
        // nothing crossed the slow threshold
        assert!(ring.slow_traces(10).is_empty());
    }

    #[test]
    fn slow_retention_survives_fast_wraps() {
        let ring = TraceRing::new(TraceConfig {
            capacity: 4,
            slow_capacity: 2,
            slow_threshold_us: 1_000,
        });
        ring.record(trace(1, 5_000)); // slow
        for i in 2..=20 {
            ring.record(trace(i, 10)); // fast traffic wraps the recent ring
        }
        assert!(ring.recent(10).iter().all(|t| t.request != 1), "recent ring wrapped");
        let slow = ring.slow_traces(10);
        assert_eq!(slow.len(), 1);
        assert_eq!(slow[0].request, 1);
        assert!(slow[0].slow, "record() stamps the slow flag");
    }

    #[test]
    fn ring_concurrent_record_never_blocks_or_grows() {
        let ring = Arc::new(TraceRing::new(TraceConfig {
            capacity: 8,
            slow_capacity: 2,
            slow_threshold_us: 500,
        }));
        let threads: Vec<_> = (0..4)
            .map(|w| {
                let r = Arc::clone(&ring);
                std::thread::spawn(move || {
                    for i in 0..256u64 {
                        let mut t = trace(w * 1000 + i, if i % 64 == 0 { 600 } else { 10 });
                        t.worker = w as usize;
                        r.record(t);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        // storage never grew; every recorded trace either landed or was
        // counted as dropped
        assert_eq!(ring.capacity(), 8);
        assert_eq!(ring.slow_capacity(), 2);
        assert!(ring.recent(100).len() <= 8);
        let landed = ring.recent(100).len() as u64;
        assert!(landed + ring.dropped() >= 1, "some traces must be visible");
        // ids are unique across workers
        assert_eq!(ring.next_request_id(), 1);
        assert_eq!(ring.next_request_id(), 2);
        assert_eq!(ring.next_batch_id(), 1);
    }

    #[test]
    fn span_duration_and_epoch() {
        let s = Span::plain("queue", 10, 250);
        assert_eq!(s.duration_us(), 240);
        assert_eq!(Span::plain("x", 5, 3).duration_us(), 0);
        let ring = TraceRing::new(TraceConfig::default());
        let t0 = ring.now_us();
        let t1 = ring.now_us();
        assert!(t1 >= t0);
        // a pre-epoch instant clamps to 0 instead of panicking
        assert_eq!(ring.to_us(ring.epoch), 0);
    }

    #[test]
    fn prometheus_exposition_format() {
        let mut w = PromWriter::new();
        w.family("sf_requests_total", "counter", "Lifetime completed requests.");
        w.sample("sf_requests_total", &[("model", "demo")], 42.0);
        w.family("sf_latency_us", "gauge", "Latency percentile.");
        w.sample("sf_latency_us", &[("model", "a\"b\\c"), ("quantile", "0.5")], 1500.5);
        w.sample("sf_up", &[], 1.0);
        let text = w.finish();
        assert!(text.contains("# TYPE sf_requests_total counter\n"));
        assert!(text.contains("sf_requests_total{model=\"demo\"} 42\n"), "{text}");
        assert!(
            text.contains("sf_latency_us{model=\"a\\\"b\\\\c\",quantile=\"0.5\"} 1500.5\n"),
            "{text}"
        );
        assert!(text.ends_with("sf_up 1\n"));
    }
}
