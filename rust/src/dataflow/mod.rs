//! Flexible-dataflow optimization (paper §5.2, Alg. 1).
//!
//! For each architecture candidate (P', N') and each layer, search the
//! streaming-parameter space (Ps, Ns) for the setting that minimizes
//! bandwidth (Eq. 13) subject to the BRAM budget (Eq. 12). The chosen
//! architecture minimizes the *maximum* per-layer bandwidth across the
//! network (the layer that needs the most bandwidth sets the DDR
//! requirement).
//!
//! Notes vs the printed algorithm: Alg. 1's lines 5–9 evaluate the three
//! fixed-flow BRAM formulas (Eqs. 6–8) as a feasibility probe, but the
//! flexible flow's actual storage is Eq. 12 — we gate feasibility on
//! Eq. 12 (and report the fixed-flow numbers separately for Figs. 2/7).
//! Ns candidates are multiples of N' (kernel groups load whole), Ps
//! candidates are multiples of P' (tile groups likewise), both capped at
//! N/P plus the "keep everything" setting — the same lattice Table 1's
//! published optima live on.

use crate::analysis::{
    bram_flex, transfers_flex_batch, ArchParams, LayerParams, StreamParams, Transfers,
};
use crate::model::Network;

/// One layer's chosen dataflow.
#[derive(Debug, Clone)]
pub struct LayerPlan {
    pub layer_name: String,
    pub params: LayerParams,
    pub stream: StreamParams,
    pub brams: u64,
    pub transfers: Transfers,
    /// Layer latency budget τ_i (seconds) used for the bandwidth figure.
    pub tau: f64,
    /// Required bandwidth (bytes/s) at τ_i.
    pub bandwidth: f64,
}

/// A full network dataflow plan (the output of Alg. 1).
#[derive(Debug, Clone)]
pub struct DataflowPlan {
    pub arch: ArchParams,
    pub layers: Vec<LayerPlan>,
    /// max_i bandwidth_i — the DDR requirement of this plan.
    pub bw_max: f64,
}

impl DataflowPlan {
    pub fn total_transfers(&self) -> u64 {
        self.layers.iter().map(|l| l.transfers.total()).sum()
    }

    pub fn layer(&self, name: &str) -> Option<&LayerPlan> {
        self.layers.iter().find(|l| l.layer_name == name)
    }
}

/// Optimizer configuration: resource budget and latency target.
#[derive(Debug, Clone, Copy)]
pub struct OptimizerConfig {
    /// BRAM budget N_BRAM (Alveo U200: 2160).
    pub bram_budget: u64,
    /// Total conv-stack latency budget τ in seconds (paper §6.1: 20 ms).
    pub total_latency: f64,
    /// Word size in bytes (paper: 16-bit fixed point).
    pub word_bytes: u64,
    /// Compression ratio α.
    pub alpha: usize,
    /// Replicas r (input-tile copies; from the scheduling analysis).
    pub replicas: usize,
    /// Batch size B the plan is optimized for. The paper evaluates B = 1
    /// (single-image latency); serving hands the batcher's `max_batch`
    /// here so Alg. 1 sees the batch as a third reuse axis: the layer's
    /// tile population becomes `B·P` and `Ps` may grow up to it, letting
    /// each sparse kernel row stream once per *batch* (Eq. 13's `⌈B·P/Ps⌉`
    /// reload factor) instead of once per image.
    pub batch: usize,
    /// Concurrently live activation tensors the on-chip input store must
    /// hold (the activation arena's slot count). The paper's straight-line
    /// VGG keeps exactly one (the current layer's input), which Eq. 12
    /// already charges; residual graphs pin shortcut tensors alongside it,
    /// and each extra resident tensor costs roughly one more tile store at
    /// the layer's footprint.
    pub resident_tensors: usize,
}

impl OptimizerConfig {
    /// The paper's evaluation configuration (§6).
    pub fn paper() -> Self {
        OptimizerConfig {
            bram_budget: 2160,
            total_latency: 0.020,
            word_bytes: 2,
            alpha: 4,
            replicas: 10,
            batch: 1,
            resident_tensors: 1,
        }
    }
}

/// Extra BRAM18s pinned by arena residents beyond the one live input
/// Eq. 12 already accounts for. Each extra resident holds the layer's
/// per-image tile population (`P` tiles × K² words) in 18 Kib blocks;
/// `resident_tensors = 1` (every chain network) charges nothing, so all
/// pre-graph optima are preserved.
fn activation_residency_brams(l: &LayerParams, cfg: &OptimizerConfig) -> u64 {
    let extra = cfg.resident_tensors.saturating_sub(1) as u64;
    let bits_per_tensor = (l.p * l.k2) as u64 * cfg.word_bytes * 8;
    extra * bits_per_tensor.div_ceil(18 * 1024)
}

/// Streaming-parameter candidates for one layer: multiples of the group
/// sizes, plus the keep-everything extremes. The Ps axis extends to the
/// batch's whole tile population `B·P` — batch-major execution can keep
/// several images' tiles resident against one kernel stream.
fn stream_candidates(l: &LayerParams, a: &ArchParams, batch: usize) -> Vec<StreamParams> {
    let p_total = l.p * batch.max(1);
    let mut ns_opts: Vec<usize> = (1..).map(|i| i * a.n_par).take_while(|&v| v < l.n).collect();
    ns_opts.push(l.n);
    let mut ps_opts: Vec<usize> =
        (1..).map(|i| i * a.p_par).take_while(|&v| v < p_total).collect();
    ps_opts.push(p_total);
    // the per-image extreme stays a candidate even when it is not a P'
    // multiple (e.g. P = 1444, P' = 9): it is the B=1 plan's anchor point
    if batch > 1 && !ps_opts.contains(&l.p) {
        ps_opts.push(l.p);
        ps_opts.sort_unstable();
    }
    let mut out = Vec::with_capacity(ns_opts.len() * ps_opts.len());
    for &ns in &ns_opts {
        for &ps in &ps_opts {
            out.push(StreamParams { ns, ps });
        }
    }
    out
}

/// Alg. 1 inner loop: best streaming parameters for one layer under one
/// architecture, batch-aware per `cfg.batch`. Returns `None` when no
/// candidate fits the BRAM budget.
pub fn optimize_layer(
    l: &LayerParams,
    a: &ArchParams,
    cfg: &OptimizerConfig,
    tau: f64,
) -> Option<LayerPlan> {
    let mut best: Option<(f64, u64, StreamParams, Transfers)> = None;
    for s in stream_candidates(l, a, cfg.batch) {
        let brams = bram_flex(l, a, &s);
        if brams + activation_residency_brams(l, cfg) > cfg.bram_budget {
            continue;
        }
        let t = transfers_flex_batch(l, &s, cfg.batch);
        let bw = t.bandwidth(tau, cfg.word_bytes);
        let better = match &best {
            None => true,
            Some((bw0, br0, _, _)) => {
                bw < *bw0 - 1e-9 || ((bw - *bw0).abs() < 1e-9 && brams < *br0)
            }
        };
        if better {
            best = Some((bw, brams, s, t));
        }
    }
    best.map(|(bw, brams, stream, transfers)| LayerPlan {
        layer_name: String::new(),
        params: *l,
        stream,
        brams,
        transfers,
        tau,
        bandwidth: bw,
    })
}

/// Candidate architecture lattice. The paper implements (P'=9, N'=64) for
/// K=8 and reports (P'=16, N'=32) for K=16; the lattice covers both plus
/// the surrounding design space.
pub fn arch_candidates(replicas: usize) -> Vec<ArchParams> {
    let mut out = Vec::new();
    for &p_par in &[1usize, 4, 9, 16, 25] {
        for &n_par in &[16usize, 32, 64, 128] {
            // PE budget guard: N'·P' complex MACs ≈ 3 DSPs each must fit a
            // U200-class device (6840 DSPs) with room for FFT engines.
            if p_par * n_par * 3 <= 6000 {
                out.push(ArchParams { p_par, n_par, replicas });
            }
        }
    }
    out
}

/// Paper Alg. 1: joint architecture + streaming-parameter search.
///
/// Layers are weighted by their FLOP share of the latency budget
/// (τ_i = τ · CMP_i / CMP_total, §6.1); conv1_1 is skipped ("negligible
/// computations"). Returns the plan with minimum worst-layer bandwidth.
pub fn optimize_network(
    net: &Network,
    cfg: &OptimizerConfig,
) -> Option<DataflowPlan> {
    let taus = net.latency_split(cfg.total_latency);
    let mut best: Option<DataflowPlan> = None;
    for arch in arch_candidates(cfg.replicas) {
        let mut layers = Vec::new();
        let mut feasible = true;
        let mut bw_max = 0.0f64;
        for (i, conv) in net.convs.iter().enumerate() {
            if conv.name == "conv1_1" {
                continue;
            }
            let l = LayerParams::from_layer(conv, cfg.alpha);
            match optimize_layer(&l, &arch, cfg, taus[i]) {
                Some(mut plan) => {
                    plan.layer_name = conv.name.clone();
                    bw_max = bw_max.max(plan.bandwidth);
                    layers.push(plan);
                }
                None => {
                    feasible = false;
                    break;
                }
            }
        }
        if !feasible {
            continue;
        }
        let plan = DataflowPlan { arch, layers, bw_max };
        let better = match &best {
            None => true,
            Some(b) => plan.bw_max < b.bw_max,
        };
        if better {
            best = Some(plan);
        }
    }
    best
}

/// Fixed-architecture variant (reproduces Table 1/2 exactly at the paper's
/// P'=9, N'=64 point rather than whatever the search prefers).
pub fn optimize_network_at(
    net: &Network,
    arch: ArchParams,
    cfg: &OptimizerConfig,
) -> Option<DataflowPlan> {
    let taus = net.latency_split(cfg.total_latency);
    let mut layers = Vec::new();
    let mut bw_max = 0.0f64;
    for (i, conv) in net.convs.iter().enumerate() {
        if conv.name == "conv1_1" {
            continue;
        }
        let l = LayerParams::from_layer(conv, cfg.alpha);
        let mut plan = optimize_layer(&l, &arch, cfg, taus[i])?;
        plan.layer_name = conv.name.clone();
        bw_max = bw_max.max(plan.bandwidth);
        layers.push(plan);
    }
    Some(DataflowPlan { arch, layers, bw_max })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::{transfers_flow1, transfers_flow2, Flow};

    #[test]
    fn paper_arch_is_feasible() {
        let net = Network::vgg16_224();
        let cfg = OptimizerConfig::paper();
        let plan = optimize_network_at(&net, ArchParams::paper(), &cfg)
            .expect("paper arch must fit the U200 BRAM budget");
        assert_eq!(plan.layers.len(), 12); // conv1_1 skipped
        for l in &plan.layers {
            assert!(l.brams <= cfg.bram_budget);
            assert!(l.stream.ns >= 64 && l.stream.ns <= l.params.n);
            assert!(l.stream.ps >= 9 && l.stream.ps <= l.params.p);
        }
    }

    #[test]
    fn table1_shape_ns_grows_ps_shrinks_with_depth() {
        // Table 1's qualitative shape: early layers (many tiles, few
        // kernels) stream kernels rarely and tiles often (large Ps, small
        // Ns); deep layers invert (Ns → N, Ps → P).
        let net = Network::vgg16_224();
        let cfg = OptimizerConfig::paper();
        let plan = optimize_network_at(&net, ArchParams::paper(), &cfg).unwrap();
        let first = plan.layer("conv1_2").unwrap();
        let last = plan.layer("conv5_3").unwrap();
        assert!(first.stream.ps > last.stream.ps, "{:?} vs {:?}", first.stream, last.stream);
        assert!(last.stream.ns >= first.stream.ns);
        // deep layers keep everything resident (tiny tile count)
        assert_eq!(last.stream.ps, last.params.p);
        assert_eq!(last.stream.ns, last.params.n);
    }

    #[test]
    fn flex_beats_or_matches_fixed_flows_per_layer() {
        // Fig. 7's claim: Flow-opt transfers ≤ min(Flow #1, Flow #2) in
        // every layer (the flexible lattice contains both extremes when
        // they are BRAM-feasible).
        let net = Network::vgg16_224();
        let cfg = OptimizerConfig::paper();
        let arch = ArchParams::paper();
        let plan = optimize_network_at(&net, arch, &cfg).unwrap();
        for lp in &plan.layers {
            let t1 = transfers_flow1(&lp.params, &arch).total();
            let t2 = transfers_flow2(&lp.params, &arch).total();
            assert!(
                lp.transfers.total() <= t1.max(t2),
                "{}: opt {} vs flow1 {} flow2 {}",
                lp.layer_name,
                lp.transfers.total(),
                t1,
                t2
            );
        }
        let _ = Flow::ALL; // exercised by benches
    }

    #[test]
    fn headline_transfer_reduction_vs_flow2() {
        // Paper abstract: "data transfers are reduced by 42%" (vs the fixed
        // streaming-kernels dataflow a [16]-style design uses). Require a
        // comparable reduction from the optimizer.
        let net = Network::vgg16_224();
        let cfg = OptimizerConfig::paper();
        let arch = ArchParams::paper();
        let plan = optimize_network_at(&net, arch, &cfg).unwrap();
        let fixed: u64 = plan
            .layers
            .iter()
            .map(|lp| transfers_flow2(&lp.params, &arch).total())
            .sum();
        let opt = plan.total_transfers();
        let reduction = 1.0 - opt as f64 / fixed as f64;
        assert!(
            reduction > 0.30,
            "transfer reduction {reduction:.2} below the paper's band (42%)"
        );
    }

    #[test]
    fn batch_axis_extends_ps_and_amortizes_kernel_streams() {
        // Deep layer (conv5_3: 512×512, P = 9) at B = 8: the tile
        // population is 72, and Eq. 12 still fits all of it on chip at
        // Ns = 256 — so Alg. 1 keeps the whole batch resident and streams
        // the kernel store exactly once per batch.
        let net = Network::vgg16_224();
        let l = LayerParams::from_layer(&net.convs[12], 4);
        let arch = ArchParams::paper();
        let cfg = OptimizerConfig { batch: 8, ..OptimizerConfig::paper() };
        let plan = optimize_layer(&l, &arch, &cfg, 1.0).expect("batched plan feasible");
        assert_eq!(plan.stream.ps, 8 * l.p, "all B·P tiles resident");
        assert_eq!(plan.transfers.kernels, l.sparse_kernel_words(), "one kernel stream");
        assert!(plan.brams <= cfg.bram_budget);

        // versus B independent single-image forwards: 8× the kernel traffic
        let serial = optimize_layer(&l, &arch, &OptimizerConfig::paper(), 1.0).unwrap();
        assert_eq!(serial.stream.ps, l.p);
        assert_eq!(serial.transfers.kernels, l.sparse_kernel_words());
        assert!(
            plan.transfers.total() < 8 * serial.transfers.total(),
            "batched {} !< 8× serial {}",
            plan.transfers.total(),
            8 * serial.transfers.total()
        );
    }

    #[test]
    fn batch_one_plan_unchanged_by_the_batch_field() {
        // Adding the B axis must not perturb the paper's B = 1 optima.
        let net = Network::vgg16_224();
        let cfg = OptimizerConfig { batch: 1, ..OptimizerConfig::paper() };
        let a = optimize_network_at(&net, ArchParams::paper(), &OptimizerConfig::paper()).unwrap();
        let b = optimize_network_at(&net, ArchParams::paper(), &cfg).unwrap();
        for (x, y) in a.layers.iter().zip(&b.layers) {
            assert_eq!(x.stream, y.stream, "{}", x.layer_name);
            assert_eq!(x.transfers, y.transfers, "{}", x.layer_name);
        }
    }

    #[test]
    fn batched_plans_stay_within_budget_across_network() {
        // Every layer's batched plan must still clear Eq. 12 — early
        // layers (P = 1444 tiles at B = 8 ⇒ 11552) simply keep Ps at a
        // feasible prefix instead of the whole population.
        let net = Network::vgg16_224();
        let cfg = OptimizerConfig { batch: 8, ..OptimizerConfig::paper() };
        let plan = optimize_network_at(&net, ArchParams::paper(), &cfg)
            .expect("batched network plan feasible");
        for lp in &plan.layers {
            assert!(lp.brams <= cfg.bram_budget, "{} over budget", lp.layer_name);
            assert!(lp.stream.ps <= 8 * lp.params.p);
        }
    }

    #[test]
    fn search_prefers_feasible_architectures() {
        let net = Network::vgg16_224();
        let cfg = OptimizerConfig::paper();
        let plan = optimize_network(&net, &cfg).expect("some arch feasible");
        assert!(plan.bw_max > 0.0);
        // the searched optimum is at least as good as the paper's point
        let at_paper = optimize_network_at(&net, ArchParams::paper(), &cfg).unwrap();
        assert!(plan.bw_max <= at_paper.bw_max + 1.0);
    }

    #[test]
    fn infeasible_budget_returns_none() {
        let net = Network::vgg16_224();
        let mut cfg = OptimizerConfig::paper();
        cfg.bram_budget = 10; // absurd
        assert!(optimize_network_at(&net, ArchParams::paper(), &cfg).is_none());
    }

    #[test]
    fn residency_overhead_is_zero_for_chains_and_gates_feasibility() {
        // resident_tensors = 1 (the paper's straight-line case) must leave
        // every plan untouched — the overhead function returns 0.
        let net = Network::vgg16_224();
        let base = optimize_network_at(&net, ArchParams::paper(), &OptimizerConfig::paper())
            .unwrap();
        let one = optimize_network_at(
            &net,
            ArchParams::paper(),
            &OptimizerConfig { resident_tensors: 1, ..OptimizerConfig::paper() },
        )
        .unwrap();
        for (x, y) in base.layers.iter().zip(&one.layers) {
            assert_eq!(x.stream, y.stream, "{}", x.layer_name);
            assert_eq!(x.brams, y.brams, "{}", x.layer_name);
        }
        // a few pinned residents shrink the streaming budget but stay
        // feasible; an absurd count starves every candidate
        let few = OptimizerConfig { resident_tensors: 3, ..OptimizerConfig::paper() };
        let plan = optimize_network_at(&net, ArchParams::paper(), &few)
            .expect("3 residents still fit the U200 budget");
        for lp in &plan.layers {
            assert!(
                lp.brams + activation_residency_brams(&lp.params, &few) <= few.bram_budget,
                "{} over budget with residency",
                lp.layer_name
            );
        }
        let absurd = OptimizerConfig { resident_tensors: 10_000, ..OptimizerConfig::paper() };
        let l = LayerParams::from_layer(&net.convs[1], 4);
        assert!(optimize_layer(&l, &ArchParams::paper(), &absurd, 1.0).is_none());
    }

    #[test]
    fn k16_variant_runs() {
        // Table 1 lower half: K=16 needs a different arch point; just
        // verify the optimizer handles the 4x kernel storage.
        let net = Network::vgg16_224_k16();
        let cfg = OptimizerConfig::paper();
        let plan = optimize_network(&net, &cfg);
        assert!(plan.is_some());
    }
}
