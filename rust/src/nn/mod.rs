//! CPU-side neural-network ops.
//!
//! The paper's CPU-FPGA split (§6) puts ReLU, pooling, the FC layers and
//! OaA on the host CPU; these are their Rust implementations, used on the
//! coordinator's request path around the AOT'd spectral-conv executables.
//! `conv2d_same_ref` is the *spatial ground truth* used by integration tests
//! to validate the whole spectral pipeline.

use crate::tensor::Tensor;

/// In-place ReLU.
pub fn relu(x: &mut Tensor) {
    for v in x.data_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// Add a per-channel bias to `[N, H, W]` activations.
pub fn add_bias(x: &mut Tensor, bias: &[f32]) {
    let shape = x.shape().to_vec();
    assert_eq!(shape.len(), 3);
    assert_eq!(shape[0], bias.len(), "bias length != channels");
    let hw = shape[1] * shape[2];
    let d = x.data_mut();
    for (c, &b) in bias.iter().enumerate() {
        for v in &mut d[c * hw..(c + 1) * hw] {
            *v += b;
        }
    }
}

/// 2x2 stride-2 max pooling on `[C, H, W]` (H, W even — VGG guarantees it).
pub fn maxpool2(x: &Tensor) -> Tensor {
    let shape = x.shape();
    assert_eq!(shape.len(), 3);
    let (c, h, w) = (shape[0], shape[1], shape[2]);
    assert!(h % 2 == 0 && w % 2 == 0, "maxpool2 needs even H, W (got {h}x{w})");
    let (oh, ow) = (h / 2, w / 2);
    let mut out = Tensor::zeros(&[c, oh, ow]);
    let xd = x.data();
    let od = out.data_mut();
    for ch in 0..c {
        for y in 0..oh {
            let r0 = (ch * h + 2 * y) * w;
            let r1 = r0 + w;
            for xx in 0..ow {
                let m = xd[r0 + 2 * xx]
                    .max(xd[r0 + 2 * xx + 1])
                    .max(xd[r1 + 2 * xx])
                    .max(xd[r1 + 2 * xx + 1]);
                od[(ch * oh + y) * ow + xx] = m;
            }
        }
    }
    out
}

/// Dense layer: `y = W x + b` with `W: [N, M]`, `x: [M]`.
pub fn dense(w: &Tensor, bias: &[f32], x: &[f32]) -> Vec<f32> {
    let shape = w.shape();
    assert_eq!(shape.len(), 2);
    let (n, m) = (shape[0], shape[1]);
    assert_eq!(m, x.len(), "dense input width mismatch");
    assert_eq!(n, bias.len());
    let wd = w.data();
    let mut out = vec![0.0f32; n];
    for i in 0..n {
        let row = &wd[i * m..(i + 1) * m];
        let mut acc = 0.0f32;
        for (a, b) in row.iter().zip(x) {
            acc += a * b;
        }
        out[i] = acc + bias[i];
    }
    out
}

/// Numerically stable softmax.
pub fn softmax(x: &[f32]) -> Vec<f32> {
    let max = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = x.iter().map(|v| (v - max).exp()).collect();
    let sum: f32 = exps.iter().sum();
    exps.into_iter().map(|e| e / sum).collect()
}

/// Naive spatial 'SAME' cross-correlation (ground truth for tests).
///
/// `x: [M, H, W]`, `w: [N, M, k, k]` → `[N, H, W]`; pad = (k-1)/2, stride 1.
pub fn conv2d_same_ref(x: &Tensor, w: &Tensor) -> Tensor {
    let xs = x.shape();
    let ws = w.shape();
    assert_eq!(xs.len(), 3);
    assert_eq!(ws.len(), 4);
    let (m, h, wd) = (xs[0], xs[1], xs[2]);
    let (n, m2, k) = (ws[0], ws[1], ws[2]);
    assert_eq!(m, m2, "channel mismatch");
    assert_eq!(ws[3], k);
    let pad = (k - 1) / 2;
    let mut out = Tensor::zeros(&[n, h, wd]);
    for o in 0..n {
        for y in 0..h {
            for x2 in 0..wd {
                let mut acc = 0.0f32;
                for c in 0..m {
                    for u in 0..k {
                        for v in 0..k {
                            let sy = y + u;
                            let sx = x2 + v;
                            if sy < pad || sx < pad {
                                continue;
                            }
                            let (sy, sx) = (sy - pad, sx - pad);
                            if sy >= h || sx >= wd {
                                continue;
                            }
                            acc += x.at(&[c, sy, sx]) * w.at(&[o, c, u, v]);
                        }
                    }
                }
                out.set(&[o, y, x2], acc);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;
    use crate::util::rng::Pcg32;

    #[test]
    fn relu_clamps() {
        let mut t = Tensor::from_vec(&[4], vec![-1.0, 0.0, 2.0, -0.5]);
        relu(&mut t);
        assert_eq!(t.data(), &[0.0, 0.0, 2.0, 0.0]);
    }

    #[test]
    fn bias_per_channel() {
        let mut t = Tensor::zeros(&[2, 1, 2]);
        add_bias(&mut t, &[1.0, -2.0]);
        assert_eq!(t.data(), &[1.0, 1.0, -2.0, -2.0]);
    }

    #[test]
    fn maxpool_picks_max() {
        let t = Tensor::from_vec(&[1, 2, 4], vec![1.0, 2.0, 5.0, 6.0, 3.0, 4.0, 7.0, 8.0]);
        let p = maxpool2(&t);
        assert_eq!(p.shape(), &[1, 1, 2]);
        assert_eq!(p.data(), &[4.0, 8.0]);
    }

    #[test]
    fn dense_known() {
        let w = Tensor::from_vec(&[2, 3], vec![1.0, 0.0, 0.0, 0.0, 1.0, 1.0]);
        let y = dense(&w, &[0.5, -0.5], &[3.0, 4.0, 5.0]);
        assert_eq!(y, vec![3.5, 8.5]);
    }

    #[test]
    fn softmax_normalizes() {
        let p = softmax(&[1.0, 2.0, 3.0]);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(p[2] > p[1] && p[1] > p[0]);
        // stability with large values
        let p2 = softmax(&[1000.0, 1000.0]);
        assert!((p2[0] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn conv_identity_kernel() {
        let mut rng = Pcg32::new(3);
        let x = Tensor::randn(&[2, 5, 5], &mut rng, 1.0);
        let mut w = Tensor::zeros(&[2, 2, 3, 3]);
        w.set(&[0, 0, 1, 1], 1.0);
        w.set(&[1, 1, 1, 1], 1.0);
        let y = conv2d_same_ref(&x, &w);
        assert!(y.max_abs_diff(&x) < 1e-6);
    }

    #[test]
    fn conv_shift_kernel_at_border() {
        // kernel tap at (0,0) shifts the image down-right by `pad`; border
        // reads come from zero padding.
        let x = Tensor::from_vec(&[1, 2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let mut w = Tensor::zeros(&[1, 1, 3, 3]);
        w.set(&[0, 0, 0, 0], 1.0);
        let y = conv2d_same_ref(&x, &w);
        assert_eq!(y.data(), &[0.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    fn maxpool_halves_shape() {
        forall("pool shape", 20, |rng| {
            let c = rng.range(1, 4);
            let h = 2 * rng.range(1, 8);
            let x = Tensor::randn(&[c, h, h], rng, 1.0);
            let p = maxpool2(&x);
            assert_eq!(p.shape(), &[c, h / 2, h / 2]);
            // pooled max never exceeds global max
            let gmax = x.data().iter().cloned().fold(f32::MIN, f32::max);
            let pmax = p.data().iter().cloned().fold(f32::MIN, f32::max);
            assert!(pmax <= gmax + 1e-6);
        });
    }
}
