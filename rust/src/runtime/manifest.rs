//! `artifacts/manifest.json` schema (written by `python/compile/aot.py`),
//! plus the built-in synthesized manifest used when no artifacts exist
//! (the offline `interp` backend needs only shapes, not HLO files).

use std::collections::BTreeMap;

use super::Dtype;
use crate::err;
use crate::model::{check_graph, ConvShape, GraphOp, Network};
use crate::util::error::Result;
use crate::util::json::Json;

/// One executable's metadata.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ExecutableEntry {
    pub tiles: usize,
    pub cin: usize,
    pub cout: usize,
    pub fft_size: usize,
    pub sha256: String,
    pub bytes: usize,
}

/// One conv layer instance inside a variant.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerEntry {
    pub name: String,
    pub cin: usize,
    pub cout: usize,
    pub h: usize,
    pub tiles: usize,
    pub pool_after: bool,
    pub file: String,
}

/// One model variant (conv stack + FC head description).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct VariantEntry {
    pub input_hw: usize,
    pub input_c: usize,
    pub fc: Vec<usize>,
    pub layers: Vec<LayerEntry>,
    /// Activation DAG over `layers`; absent (`None`) means the historical
    /// straight chain, so pre-graph manifests keep parsing unchanged —
    /// the same optional-field pattern as `alpha`/`dtype`.
    pub graph: Option<Vec<GraphOp>>,
}

impl VariantEntry {
    /// The layers projected onto the graph checker's shape view.
    pub fn conv_shapes(&self) -> Vec<ConvShape> {
        self.layers
            .iter()
            .map(|l| ConvShape { cin: l.cin, cout: l.cout, h: l.h, pool_after: l.pool_after })
            .collect()
    }

    /// The effective execution graph: the declared DAG, or the implicit
    /// chain over `layers` for graph-less variants.
    pub fn graph_ops(&self) -> Vec<GraphOp> {
        self.graph.clone().unwrap_or_else(|| GraphOp::chain(self.layers.len()))
    }

    /// `(channels, spatial side)` of the tensor feeding the flatten.
    pub fn output_shape(&self) -> Result<(usize, usize)> {
        let shapes =
            check_graph(&self.graph_ops(), &self.conv_shapes(), self.input_c, self.input_hw)?;
        Ok(*shapes.last().expect("non-empty graph"))
    }
}

/// The whole manifest.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Manifest {
    pub fft_size: usize,
    pub kernel_k: usize,
    pub tile: usize,
    pub word_bytes: usize,
    pub hadamard_mode: String,
    /// Compression ratio α the artifacts were built for (paper §4: each
    /// K×K kernel keeps K²/α non-zeros). `1` = dense — also the default
    /// when the field is absent, so pre-α manifests keep parsing.
    pub alpha: usize,
    /// Accumulation dtype the artifacts default to (`f32` unless the
    /// manifest says otherwise). Like `alpha`, this only records a
    /// default — the CLI `--dtype` knob wins when given (see
    /// [`Manifest::resolve_dtype`]); absent in pre-dtype manifests.
    pub dtype: Dtype,
    pub variants: BTreeMap<String, VariantEntry>,
    pub executables: BTreeMap<String, ExecutableEntry>,
}

fn req_usize(j: &Json, key: &str) -> Result<usize> {
    j.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| err!("manifest: missing/invalid '{key}'"))
}

fn req_str(j: &Json, key: &str) -> Result<String> {
    j.get(key)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| err!("manifest: missing/invalid '{key}'"))
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| err!("manifest: {e}"))?;
        let format = req_str(&j, "format")?;
        if format != "hlo-text-v1" {
            return Err(err!("unsupported manifest format {format:?}"));
        }
        let mut variants = BTreeMap::new();
        for (name, v) in j
            .get("variants")
            .and_then(Json::as_obj)
            .ok_or_else(|| err!("manifest: missing 'variants'"))?
        {
            let mut layers = Vec::new();
            for l in v
                .get("layers")
                .and_then(Json::as_arr)
                .ok_or_else(|| err!("variant {name}: missing 'layers'"))?
            {
                layers.push(LayerEntry {
                    name: req_str(l, "name")?,
                    cin: req_usize(l, "cin")?,
                    cout: req_usize(l, "cout")?,
                    h: req_usize(l, "h")?,
                    tiles: req_usize(l, "tiles")?,
                    pool_after: l
                        .get("pool_after")
                        .and_then(Json::as_bool)
                        .unwrap_or(false),
                    file: req_str(l, "file")?,
                });
            }
            let fc = v
                .get("fc")
                .and_then(Json::as_arr)
                .ok_or_else(|| err!("variant {name}: missing 'fc'"))?
                .iter()
                .map(|x| x.as_usize().ok_or_else(|| err!("bad fc width")))
                .collect::<Result<Vec<_>>>()?;
            // 'graph' is optional like the top-level alpha/dtype: absent
            // means the straight chain every pre-graph manifest describes.
            let graph = match v.get("graph") {
                None => None,
                Some(g) => {
                    let nodes = g
                        .as_arr()
                        .ok_or_else(|| err!("variant {name}: 'graph' is not an array"))?;
                    let mut ops = Vec::with_capacity(nodes.len());
                    for (i, n) in nodes.iter().enumerate() {
                        let op = n
                            .get("op")
                            .and_then(Json::as_str)
                            .ok_or_else(|| err!("variant {name} graph[{i}]: missing 'op'"))?;
                        ops.push(match op {
                            "conv" => GraphOp::Conv {
                                conv: req_usize(n, "conv")?,
                                input: req_usize(n, "input")?,
                            },
                            "add" => {
                                GraphOp::Add { a: req_usize(n, "a")?, b: req_usize(n, "b")? }
                            }
                            "concat" => {
                                GraphOp::Concat { a: req_usize(n, "a")?, b: req_usize(n, "b")? }
                            }
                            other => {
                                return Err(err!(
                                    "variant {name} graph[{i}]: unknown op {other:?}"
                                ))
                            }
                        });
                    }
                    Some(ops)
                }
            };
            variants.insert(
                name.clone(),
                VariantEntry {
                    input_hw: req_usize(v, "input_hw")?,
                    input_c: req_usize(v, "input_c")?,
                    fc,
                    layers,
                    graph,
                },
            );
        }
        let mut executables = BTreeMap::new();
        for (file, e) in j
            .get("executables")
            .and_then(Json::as_obj)
            .ok_or_else(|| err!("manifest: missing 'executables'"))?
        {
            executables.insert(
                file.clone(),
                ExecutableEntry {
                    tiles: req_usize(e, "tiles")?,
                    cin: req_usize(e, "cin")?,
                    cout: req_usize(e, "cout")?,
                    fft_size: req_usize(e, "fft_size")?,
                    sha256: req_str(e, "sha256")?,
                    bytes: req_usize(e, "bytes")?,
                },
            );
        }
        // α is optional for backward compatibility: manifests written
        // before the sparsity knob existed parse as dense (α = 1).
        let alpha = match j.get("alpha") {
            None => 1,
            Some(v) => v
                .as_usize()
                .ok_or_else(|| err!("manifest: invalid 'alpha'"))?,
        };
        // dtype is optional the same way: absent means f32 (what every
        // artifact before the precision knob was built as).
        let dtype = match j.get("dtype") {
            None => Dtype::F32,
            Some(v) => Dtype::parse(
                v.as_str().ok_or_else(|| err!("manifest: invalid 'dtype'"))?,
            )?,
        };
        let m = Manifest {
            fft_size: req_usize(&j, "fft_size")?,
            kernel_k: req_usize(&j, "kernel_k")?,
            tile: req_usize(&j, "tile")?,
            word_bytes: req_usize(&j, "word_bytes")?,
            hadamard_mode: req_str(&j, "hadamard_mode")?,
            alpha,
            dtype,
            variants,
            executables,
        };
        m.validate()?;
        Ok(m)
    }

    /// Serialize back to the `manifest.json` schema — [`Manifest::parse`]'s
    /// inverse (round-trip is exact; key order is canonicalized). Lets
    /// tools rewrite a manifest at a different α and pins the schema in the
    /// round-trip test.
    pub fn to_json(&self) -> String {
        use crate::util::json::{arr, num, obj, s, Json};
        let variants = Json::Obj(
            self.variants
                .iter()
                .map(|(name, v)| {
                    let layers = arr(v
                        .layers
                        .iter()
                        .map(|l| {
                            obj(vec![
                                ("name", s(&l.name)),
                                ("cin", num(l.cin as f64)),
                                ("cout", num(l.cout as f64)),
                                ("h", num(l.h as f64)),
                                ("tiles", num(l.tiles as f64)),
                                ("pool_after", Json::Bool(l.pool_after)),
                                ("file", s(&l.file)),
                            ])
                        })
                        .collect());
                    let mut fields = vec![
                        ("input_hw", num(v.input_hw as f64)),
                        ("input_c", num(v.input_c as f64)),
                        ("fc", arr(v.fc.iter().map(|&x| num(x as f64)).collect())),
                        ("layers", layers),
                    ];
                    // emitted only when declared, so graph-less manifests
                    // round-trip to the pre-graph schema byte for byte
                    if let Some(g) = &v.graph {
                        let nodes = g
                            .iter()
                            .map(|op| match *op {
                                GraphOp::Conv { conv, input } => obj(vec![
                                    ("op", s("conv")),
                                    ("conv", num(conv as f64)),
                                    ("input", num(input as f64)),
                                ]),
                                GraphOp::Add { a, b } => obj(vec![
                                    ("op", s("add")),
                                    ("a", num(a as f64)),
                                    ("b", num(b as f64)),
                                ]),
                                GraphOp::Concat { a, b } => obj(vec![
                                    ("op", s("concat")),
                                    ("a", num(a as f64)),
                                    ("b", num(b as f64)),
                                ]),
                            })
                            .collect();
                        fields.push(("graph", arr(nodes)));
                    }
                    let body = obj(fields);
                    (name.clone(), body)
                })
                .collect(),
        );
        let executables = Json::Obj(
            self.executables
                .iter()
                .map(|(file, e)| {
                    let body = obj(vec![
                        ("tiles", num(e.tiles as f64)),
                        ("cin", num(e.cin as f64)),
                        ("cout", num(e.cout as f64)),
                        ("fft_size", num(e.fft_size as f64)),
                        ("sha256", s(&e.sha256)),
                        ("bytes", num(e.bytes as f64)),
                    ]);
                    (file.clone(), body)
                })
                .collect(),
        );
        obj(vec![
            ("format", s("hlo-text-v1")),
            ("fft_size", num(self.fft_size as f64)),
            ("kernel_k", num(self.kernel_k as f64)),
            ("tile", num(self.tile as f64)),
            ("word_bytes", num(self.word_bytes as f64)),
            ("hadamard_mode", s(&self.hadamard_mode)),
            ("alpha", num(self.alpha as f64)),
            ("dtype", s(self.dtype.label())),
            ("variants", variants),
            ("executables", executables),
        ])
        .to_string()
    }

    /// Cross-checks: every layer's file exists in `executables` with a
    /// matching shape, and tile geometry is self-consistent.
    pub fn validate(&self) -> Result<()> {
        if self.alpha == 0 {
            return Err(err!("alpha 0 is invalid (1 = dense, >1 = pruned)"));
        }
        if self.tile + self.kernel_k - 1 != self.fft_size {
            return Err(err!(
                "tile {} + k {} - 1 != K {}",
                self.tile,
                self.kernel_k,
                self.fft_size
            ));
        }
        for (name, v) in &self.variants {
            for l in &v.layers {
                let e = self
                    .executables
                    .get(&l.file)
                    .ok_or_else(|| err!("{name}/{}: file {} unregistered", l.name, l.file))?;
                if e.tiles != l.tiles || e.cin != l.cin || e.cout != l.cout {
                    return Err(err!(
                        "{name}/{}: shape mismatch with executable {}",
                        l.name,
                        l.file
                    ));
                }
                let side = l.h.div_ceil(self.tile);
                if side * side != l.tiles {
                    return Err(err!(
                        "{name}/{}: tiles {} != ceil({}/{})²",
                        l.name,
                        l.tiles,
                        l.h,
                        self.tile
                    ));
                }
            }
            if let Some(g) = &v.graph {
                check_graph(g, &v.conv_shapes(), v.input_c, v.input_hw)
                    .map_err(|e| err!("variant {name}: {e}"))?;
            }
        }
        Ok(())
    }

    /// Resolve a CLI-style α knob against this manifest: `0` means "use
    /// the manifest's recorded default", anything else wins as given.
    /// (Shared by `infer` and `serve` so the sentinel semantics can't
    /// drift between subcommands.)
    pub fn resolve_alpha(&self, cli_alpha: usize) -> usize {
        if cli_alpha == 0 {
            self.alpha
        } else {
            cli_alpha
        }
    }

    /// Resolve a CLI-style dtype knob against this manifest: `None` means
    /// "use the manifest's recorded default", `Some` wins as given — the
    /// same sentinel semantics as [`Manifest::resolve_alpha`].
    pub fn resolve_dtype(&self, cli_dtype: Option<Dtype>) -> Dtype {
        cli_dtype.unwrap_or(self.dtype)
    }

    pub fn variant(&self, name: &str) -> Result<&VariantEntry> {
        self.variants.get(name).ok_or_else(|| {
            err!(
                "variant {name:?} not in manifest (have: {:?})",
                self.variants.keys().collect::<Vec<_>>()
            )
        })
    }

    /// Dedup key for one executable shape (mirrors `aot.py`'s naming).
    pub fn shape_key(tiles: usize, cin: usize, cout: usize, fft: usize) -> String {
        format!("conv_t{tiles}_m{cin}_n{cout}_k{fft}.hlo.txt")
    }

    /// Synthesize the manifest from the built-in [`Network`] presets.
    ///
    /// Used when `artifacts/manifest.json` is absent: the `interp` backend
    /// executes shapes directly, so no HLO files are needed — only the
    /// variant/executable geometry that `aot.py` would have written. The
    /// synthesized manifest carries the same variants (`demo`,
    /// `demo-residual`, `vgg16-cifar`, `vgg16-224`, `resnet18`) at the
    /// paper's K=8/k=3/h'=6 point.
    pub fn builtin() -> Manifest {
        let (fft, k) = (8usize, 3usize);
        let tile = fft - k + 1;
        let mut variants = BTreeMap::new();
        let mut executables = BTreeMap::new();
        for net in [
            Network::demo(),
            Network::demo_residual(),
            Network::vgg16_cifar(),
            Network::vgg16_224(),
            Network::resnet18(),
        ] {
            let mut layers = Vec::new();
            for conv in &net.convs {
                debug_assert_eq!(conv.fft, fft, "builtin manifest is K=8 only");
                let tiles = conv.num_tiles();
                let file = Self::shape_key(tiles, conv.cin, conv.cout, fft);
                executables.entry(file.clone()).or_insert(ExecutableEntry {
                    tiles,
                    cin: conv.cin,
                    cout: conv.cout,
                    fft_size: fft,
                    sha256: "builtin".to_string(),
                    bytes: 0,
                });
                layers.push(LayerEntry {
                    name: conv.name.clone(),
                    cin: conv.cin,
                    cout: conv.cout,
                    h: conv.h,
                    tiles,
                    pool_after: conv.pool_after,
                    file,
                });
            }
            variants.insert(
                net.name.clone(),
                VariantEntry {
                    input_hw: net.input_hw,
                    input_c: net.input_c,
                    fc: net.fc.clone(),
                    layers,
                    graph: net.graph.clone(),
                },
            );
        }
        let m = Manifest {
            fft_size: fft,
            kernel_k: k,
            tile,
            word_bytes: 2,
            hadamard_mode: "interp".to_string(),
            // dense by default — the α knob is per engine (WeightMode), the
            // manifest field only records what artifacts were built for
            alpha: 1,
            dtype: Dtype::F32,
            variants,
            executables,
        };
        debug_assert!(m.validate().is_ok());
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> String {
        r#"{
          "format": "hlo-text-v1",
          "fft_size": 8, "kernel_k": 3, "tile": 6,
          "word_bytes": 2, "hadamard_mode": "mxu4",
          "variants": {
            "demo": {
              "input_hw": 16, "input_c": 1, "fc": [32, 10],
              "layers": [
                {"name": "conv1", "cin": 1, "cout": 8, "h": 16,
                 "tiles": 9, "pool_after": true, "file": "a.hlo.txt"}
              ]
            }
          },
          "executables": {
            "a.hlo.txt": {"tiles": 9, "cin": 1, "cout": 8,
                          "fft_size": 8, "sha256": "00", "bytes": 10}
          }
        }"#
        .to_string()
    }

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(&sample()).unwrap();
        assert_eq!(m.fft_size, 8);
        let v = m.variant("demo").unwrap();
        assert_eq!(v.layers[0].cout, 8);
        assert!(v.layers[0].pool_after);
        assert_eq!(v.fc, vec![32, 10]);
    }

    #[test]
    fn rejects_shape_mismatch() {
        let bad = sample().replace("\"tiles\": 9, \"cin\": 1", "\"tiles\": 4, \"cin\": 1");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_unknown_variant_lookup() {
        let m = Manifest::parse(&sample()).unwrap();
        assert!(m.variant("nope").is_err());
    }

    #[test]
    fn rejects_bad_format() {
        let bad = sample().replace("hlo-text-v1", "hlo-proto-v0");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn alpha_absent_defaults_to_dense() {
        // pre-α manifests (like `sample()`) must keep parsing unchanged
        let m = Manifest::parse(&sample()).unwrap();
        assert_eq!(m.alpha, 1);
    }

    #[test]
    fn alpha_parses_and_zero_rejected() {
        let with = sample().replace("\"word_bytes\": 2,", "\"word_bytes\": 2, \"alpha\": 4,");
        assert_eq!(Manifest::parse(&with).unwrap().alpha, 4);
        let zero = sample().replace("\"word_bytes\": 2,", "\"word_bytes\": 2, \"alpha\": 0,");
        assert!(Manifest::parse(&zero).is_err());
        let junk = sample().replace("\"word_bytes\": 2,", "\"word_bytes\": 2, \"alpha\": 1.5,");
        assert!(Manifest::parse(&junk).is_err());
    }

    #[test]
    fn dtype_absent_defaults_to_f32_and_parses() {
        // pre-dtype manifests (like `sample()`) keep parsing as f32
        let m = Manifest::parse(&sample()).unwrap();
        assert_eq!(m.dtype, Dtype::F32);
        let with =
            sample().replace("\"word_bytes\": 2,", "\"word_bytes\": 2, \"dtype\": \"f64\",");
        assert_eq!(Manifest::parse(&with).unwrap().dtype, Dtype::F64);
        let junk =
            sample().replace("\"word_bytes\": 2,", "\"word_bytes\": 2, \"dtype\": \"f16\",");
        assert!(Manifest::parse(&junk).is_err());
    }

    #[test]
    fn dtype_resolution_sentinel() {
        let mut m = Manifest::parse(&sample()).unwrap();
        assert_eq!(m.resolve_dtype(None), Dtype::F32);
        assert_eq!(m.resolve_dtype(Some(Dtype::F64)), Dtype::F64);
        m.dtype = Dtype::F64;
        assert_eq!(m.resolve_dtype(None), Dtype::F64);
        assert_eq!(m.resolve_dtype(Some(Dtype::F32)), Dtype::F32);
    }

    #[test]
    fn json_roundtrip_is_exact() {
        // parse(to_json(m)) == m for both a hand-written manifest with α
        // and the synthesized builtin (α = 1, three variants, dedup'd
        // executables) — pins the full schema, not just the new field.
        let mut hand = Manifest::parse(&sample()).unwrap();
        hand.alpha = 8;
        hand.dtype = Dtype::F64;
        assert_eq!(Manifest::parse(&hand.to_json()).unwrap(), hand);
        let builtin = Manifest::builtin();
        assert_eq!(Manifest::parse(&builtin.to_json()).unwrap(), builtin);
    }

    #[test]
    fn builtin_manifest_is_valid_and_complete() {
        let m = Manifest::builtin();
        m.validate().unwrap();
        assert_eq!(m.fft_size, 8);
        assert_eq!(m.kernel_k, 3);
        assert_eq!(m.tile, 6);
        for v in ["demo", "demo-residual", "vgg16-cifar", "vgg16-224", "resnet18"] {
            assert!(m.variants.contains_key(v), "missing variant {v}");
        }
        assert_eq!(m.variant("demo").unwrap().layers.len(), 2);
        assert_eq!(m.variant("vgg16-224").unwrap().layers.len(), 13);
        // graph presets carry their DAG; chain presets stay graph-less
        assert!(m.variant("vgg16-cifar").unwrap().graph.is_none());
        assert_eq!(m.variant("resnet18").unwrap().graph.as_ref().unwrap().len(), 28);
        assert_eq!(m.variant("resnet18").unwrap().output_shape().unwrap(), (128, 4));
        assert_eq!(m.variant("demo-residual").unwrap().output_shape().unwrap(), (8, 8));
        // demo has exactly two distinct executable shapes
        let demo_files: std::collections::BTreeSet<_> = m
            .variant("demo")
            .unwrap()
            .layers
            .iter()
            .map(|l| l.file.clone())
            .collect();
        assert_eq!(demo_files.len(), 2);
    }

    #[test]
    fn graph_absent_means_chain() {
        // pre-graph manifests (like `sample()`) parse to graph: None and
        // execute as the implicit chain
        let m = Manifest::parse(&sample()).unwrap();
        let v = m.variant("demo").unwrap();
        assert!(v.graph.is_none());
        assert_eq!(v.graph_ops(), GraphOp::chain(1));
        assert_eq!(v.output_shape().unwrap(), (8, 8));
    }

    #[test]
    fn graph_parses_and_roundtrips() {
        let with = sample().replace(
            "\"input_hw\": 16,",
            "\"graph\": [{\"op\": \"conv\", \"conv\": 0, \"input\": 0}], \"input_hw\": 16,",
        );
        let m = Manifest::parse(&with).unwrap();
        let v = m.variant("demo").unwrap();
        assert_eq!(v.graph.as_deref(), Some(&[GraphOp::Conv { conv: 0, input: 0 }][..]));
        assert_eq!(Manifest::parse(&m.to_json()).unwrap(), m);
    }

    #[test]
    fn graph_rejects_unknown_op_and_bad_refs() {
        let unknown = sample().replace(
            "\"input_hw\": 16,",
            "\"graph\": [{\"op\": \"stride\", \"conv\": 0, \"input\": 0}], \"input_hw\": 16,",
        );
        let e = Manifest::parse(&unknown).unwrap_err();
        assert!(format!("{e}").contains("unknown op"), "{e}");
        // dangling conv index fails validate (wrapped with the variant name)
        let dangling = sample().replace(
            "\"input_hw\": 16,",
            "\"graph\": [{\"op\": \"conv\", \"conv\": 3, \"input\": 0}], \"input_hw\": 16,",
        );
        let e = Manifest::parse(&dangling).unwrap_err();
        assert!(format!("{e}").contains("variant demo"), "{e}");
    }

    #[test]
    fn parses_real_manifest_if_built() {
        // Non-fatal integration hook: validate the real artifacts when
        // `make artifacts` has run (skip silently otherwise).
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let m = Manifest::parse(&text).unwrap();
            assert!(m.variants.contains_key("demo"));
            assert!(m.variants.contains_key("vgg16-224"));
            assert_eq!(m.variant("vgg16-224").unwrap().layers.len(), 13);
        }
    }
}
