//! Sparse weight representation + dataflow hint for the execution path.
//!
//! [`crate::sparse`] generates *pruned kernels* (index patterns + values,
//! paper §4); this module is their **runtime** form: a CSR-like layout over
//! the K² frequency plane, one row per (output-channel, input-channel)
//! kernel, that the backend's sparse MAC streams to touch only the K²/α
//! non-zeros. [`SparseDataflow`] carries the per-layer streaming decision of
//! the flexible-dataflow optimizer (paper Alg. 1 / [`crate::dataflow`]) to
//! the backend: how many input-tile spectra stay resident while the kernel
//! lists stream past — the executing analogue of the paper's
//! reuse-kernels-vs-activations choice.

use crate::analysis::StreamParams;
use crate::sparse::SparseLayer;

/// One layer's kernels in CSR-like form over the flattened K×K frequency
/// plane: row `(n, m)` (output-channel-major) holds the sorted frequency
/// indices and complex values of kernel `W[n, m]`'s non-zeros.
///
/// This is the layout the sparse MAC iterates — the sparse counterpart of
/// the dense frequency-major planes
/// ([`freq_major_planes`](super::freq_major_planes)), carrying the same
/// values at the same frequencies with the zeros elided.
#[derive(Debug, Clone, PartialEq)]
pub struct SparseWeightPlanes {
    /// `[F, M, N]` with `F = K²` — the dense-plane dims this sparsifies.
    pub dims: [usize; 3],
    /// Compression ratio α the layer was pruned at (1 = nothing pruned).
    pub alpha: usize,
    /// Row offsets, length `N·M + 1`; row `(n, m)` lives at `n·M + m`.
    row_ptr: Vec<usize>,
    /// Frequency indices (`0..F`), sorted within each row.
    idx: Vec<u32>,
    re: Vec<f32>,
    im: Vec<f32>,
}

impl SparseWeightPlanes {
    /// Build the CSR form from a pruned layer (`sparse::prune_magnitude` /
    /// `prune_random` output). Index order within a row follows the
    /// kernel's sorted index list, so iteration order is deterministic.
    pub fn from_layer(l: &SparseLayer) -> Self {
        let (n, m) = (l.cout, l.cin);
        let mut row_ptr = Vec::with_capacity(n * m + 1);
        row_ptr.push(0usize);
        let total: usize = l.total_nnz() as usize;
        let mut idx = Vec::with_capacity(total);
        let mut re = Vec::with_capacity(total);
        let mut im = Vec::with_capacity(total);
        for ni in 0..n {
            for mi in 0..m {
                let k = l.kernel(ni, mi);
                for (&fi, &(vr, vi)) in k.indices.iter().zip(&k.values) {
                    idx.push(fi as u32);
                    re.push(vr);
                    im.push(vi);
                }
                row_ptr.push(idx.len());
            }
        }
        SparseWeightPlanes { dims: [l.k2(), m, n], alpha: l.alpha, row_ptr, idx, re, im }
    }

    /// Non-zeros of kernel `(n, m)`: (frequency indices, re, im), all the
    /// same length. Indices are sorted ascending.
    pub fn row(&self, n: usize, m: usize) -> (&[u32], &[f32], &[f32]) {
        let r = n * self.dims[1] + m;
        let (lo, hi) = (self.row_ptr[r], self.row_ptr[r + 1]);
        (&self.idx[lo..hi], &self.re[lo..hi], &self.im[lo..hi])
    }

    /// Total stored non-zeros across the layer.
    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    /// Kernel groups over the output-channel axis, `⌈N / n_par⌉` of them —
    /// the scheduling granularity (paper §5.3: N' kernels in parallel).
    pub fn num_groups(&self, n_par: usize) -> usize {
        self.dims[2].div_ceil(n_par.max(1))
    }

    /// Index sets of one scheduling instance: the ≤ `n_par` CSR rows
    /// `{(n, m)}` for `n ∈ [group·n_par, ..)` at fixed input channel `m` —
    /// the [`crate::schedule`] adapter. Mirrors
    /// [`crate::sparse::SparseLayer::group_indices`] but reads the runtime
    /// CSR form, so the serving path schedules exactly the rows its MAC
    /// will stream (frequency indices fit `u16`: K ≤ 16 ⇒ K² ≤ 256).
    pub fn group_indices(&self, group: usize, n_par: usize, m: usize) -> Vec<Vec<u16>> {
        let [_, _, n] = self.dims;
        let start = group * n_par;
        let end = (start + n_par).min(n);
        (start..end)
            .map(|ni| {
                let (idx, _, _) = self.row(ni, m);
                idx.iter()
                    .map(|&fi| {
                        debug_assert!(fi <= u16::MAX as u32);
                        fi as u16
                    })
                    .collect()
            })
            .collect()
    }

    /// Fold the full-plane CSR onto the rfft2 half-plane: `[K², M, N]` →
    /// `[K·(K/2+1), M, N]`, indexed `r·(K/2+1) + c` for `c ≤ K/2`.
    ///
    /// For Hermitian input spectra `X` (any real tile's), the half-plane
    /// MAC `irfft2d(Σ_m X_half·V)` reproduces `Re(ifft2d(Σ_m X_full·W))`
    /// exactly — even for non-Hermitian `W` (e.g. `prune_random`'s
    /// asymmetric index sets) — when `W` folds to `V` as:
    ///
    /// * interior columns `1 ≤ c ≤ K/2-1`: `V[r,c] += W[r,c]/2` and the
    ///   mirror `V[r,c] += conj(W[(K-r)%K, K-c])/2` (each side carries the
    ///   1/2, so a symmetric pair merges back to full weight and a lone
    ///   entry contributes its half from both spectral copies of `X`);
    /// * columns `c ∈ {0, K/2}`: copied unchanged — their mirrors live at
    ///   other *rows inside* the half-plane, so nothing folds.
    ///
    /// Entries whose mirror is also stored merge (sum) into one slot —
    /// that merge is where the weight stream halves. Deterministic: output
    /// rows are sorted by folded index, ties merged in index order.
    pub fn fold_half_plane(&self, fft: usize) -> SparseWeightPlanes {
        let [f, m, n] = self.dims;
        assert_eq!(f, fft * fft, "dims[0] = {f} must be fft² = {}", fft * fft);
        assert!(fft.is_power_of_two(), "FFT size {fft} must be a power of two");
        let hc = fft / 2 + 1;
        let mut row_ptr = Vec::with_capacity(n * m + 1);
        row_ptr.push(0usize);
        let mut idx = Vec::with_capacity(self.nnz());
        let mut re = Vec::with_capacity(self.nnz());
        let mut im = Vec::with_capacity(self.nnz());
        let mut scratch: Vec<(u32, f32, f32)> = Vec::new();
        for ni in 0..n {
            for mi in 0..m {
                let (fidx, fre, fim) = self.row(ni, mi);
                scratch.clear();
                for ((&fi, &vr), &vi) in fidx.iter().zip(fre).zip(fim) {
                    let (r, c) = (fi as usize / fft, fi as usize % fft);
                    if c == 0 || c == fft / 2 {
                        scratch.push(((r * hc + c) as u32, vr, vi));
                    } else if c < fft / 2 {
                        scratch.push(((r * hc + c) as u32, 0.5 * vr, 0.5 * vi));
                    } else {
                        let (rr, cc) = ((fft - r) % fft, fft - c);
                        scratch.push(((rr * hc + cc) as u32, 0.5 * vr, -(0.5 * vi)));
                    }
                }
                scratch.sort_by_key(|e| e.0);
                let row_start = idx.len();
                for &(fi, vr, vi) in &scratch {
                    if idx.len() > row_start && *idx.last().unwrap() == fi {
                        let j = re.len() - 1;
                        re[j] += vr;
                        im[j] += vi;
                    } else {
                        idx.push(fi);
                        re.push(vr);
                        im.push(vi);
                    }
                }
                row_ptr.push(idx.len());
            }
        }
        SparseWeightPlanes { dims: [fft * hc, m, n], alpha: self.alpha, row_ptr, idx, re, im }
    }

    /// Densify back to the frequency-major `[F, M, N]` (re, im) layout —
    /// the verification bridge to the dense path (pruned slots are explicit
    /// zeros, exactly what [`SparseLayer::to_dense_planes`] +
    /// [`freq_major_planes`](super::freq_major_planes) produce).
    pub fn to_freq_major(&self) -> (Vec<f32>, Vec<f32>) {
        let [f, m, n] = self.dims;
        let mut re = vec![0.0f32; f * m * n];
        let mut im = vec![0.0f32; f * m * n];
        for ni in 0..n {
            for mi in 0..m {
                let (idx, wre, wim) = self.row(ni, mi);
                for ((&fi, &vr), &vi) in idx.iter().zip(wre).zip(wim) {
                    let dst = (fi as usize * m + mi) * n + ni;
                    re[dst] = vr;
                    im[dst] = vi;
                }
            }
        }
        (re, im)
    }
}

/// Per-executable streaming decision for the sparse MAC — what Alg. 1's
/// per-layer `(Ns, Ps)` optimum means *in software*.
///
/// On the FPGA, `Ps` tiles stay resident while kernel groups stream from
/// DDR; the bigger `Ps`, the fewer times each kernel is re-fetched
/// (Eq. 13's `⌈P/Ps⌉` factor). The interp backend's analogue: keep
/// `tile_block` input-tile *spectra* resident and walk every kernel's CSR
/// row once per block, so a layer's kernel lists stream from memory
/// `⌈P/tile_block⌉` times instead of `P` times. `tile_block = 1` is pure
/// tile-major execution (kernels stream per tile — Flow #2 flavor);
/// `tile_block = P` loads each kernel row exactly once (Flow #1 flavor).
/// `Ns` has no software meaning — RAM imposes no kernel-residency cap, the
/// cache-budget clamp lives in the backend (the Eq. 12 analogue).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SparseDataflow {
    /// Input-tile spectra kept resident per kernel stream (the paper's Ps).
    pub tile_block: usize,
}

impl Default for SparseDataflow {
    fn default() -> Self {
        SparseDataflow { tile_block: 1 }
    }
}

impl SparseDataflow {
    /// Adopt the streaming parameters a [`crate::dataflow::LayerPlan`]
    /// chose for this layer.
    pub fn from_stream(s: &StreamParams) -> Self {
        SparseDataflow { tile_block: s.ps.max(1) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::freq_major_planes;
    use crate::sparse::{prune_magnitude, prune_random};
    use crate::util::rng::Pcg32;

    #[test]
    fn csr_counts_and_rows_match_layer() {
        let mut rng = Pcg32::new(11);
        let l = prune_random(6, 3, 8, 4, &mut rng);
        let w = SparseWeightPlanes::from_layer(&l);
        assert_eq!(w.dims, [64, 3, 6]);
        assert_eq!(w.alpha, 4);
        assert_eq!(w.nnz() as u64, l.total_nnz());
        for n in 0..6 {
            for m in 0..3 {
                let (idx, re, im) = w.row(n, m);
                let k = l.kernel(n, m);
                assert_eq!(idx.len(), k.nnz());
                assert_eq!(re.len(), k.nnz());
                assert_eq!(im.len(), k.nnz());
                for (j, &fi) in idx.iter().enumerate() {
                    assert_eq!(fi, k.indices[j] as u32);
                    assert_eq!((re[j], im[j]), k.values[j]);
                }
            }
        }
    }

    #[test]
    fn to_freq_major_matches_dense_conversion() {
        // The CSR densification and the dense-plane transpose must agree
        // bit for bit — this is the bridge the equivalence tests stand on.
        let mut rng = Pcg32::new(12);
        let l = prune_magnitude(5, 4, 8, 4, &mut rng);
        let w = SparseWeightPlanes::from_layer(&l);
        let (sre, sim) = w.to_freq_major();
        let (dre, dim) = freq_major_planes(&l.to_dense_planes());
        assert_eq!(sre, dre);
        assert_eq!(sim, dim);
    }

    #[test]
    fn group_indices_match_layer_groups() {
        // The runtime CSR adapter must produce exactly the scheduling
        // instances the offline SparseLayer view produces — the scheduler
        // sees the same groups whichever side builds them.
        let mut rng = Pcg32::new(13);
        let l = prune_random(20, 3, 8, 4, &mut rng);
        let w = SparseWeightPlanes::from_layer(&l);
        assert_eq!(w.num_groups(8), l.num_groups(8));
        for g in 0..w.num_groups(8) {
            for m in 0..3 {
                assert_eq!(w.group_indices(g, 8, m), l.group_indices(g, 8, m));
            }
        }
        // ragged last group: 20 rows over n_par=8 ⇒ sizes 8, 8, 4
        assert_eq!(w.group_indices(2, 8, 0).len(), 4);
    }

    #[test]
    fn fold_rules_on_handmade_kernel() {
        use crate::sparse::{SparseKernel, SparseLayer};
        // one 8×8 kernel with entries covering every fold rule:
        //   (0,0) DC — copied unchanged
        //   (3,4) Nyquist column — copied unchanged
        //   (1,2) + mirror (7,6) — a symmetric pair, merges to full weight
        //   (2,3) lone interior entry — survives at half weight
        //   (5,7) lone interior mirror-side entry — folds to (3,1), conj/2
        let fft = 8usize;
        let at = |r: usize, c: usize| (r * fft + c) as u16;
        let k = SparseKernel {
            indices: vec![at(0, 0), at(1, 2), at(2, 3), at(3, 4), at(5, 7), at(7, 6)],
            values: vec![
                (1.0, 0.5),
                (2.0, -1.0),
                (4.0, 0.25),
                (3.0, 1.5),
                (6.0, -2.0),
                (2.0, 1.0),
            ],
        };
        let l = SparseLayer { cout: 1, cin: 1, fft, kernels: vec![k], alpha: 4 };
        let v = SparseWeightPlanes::from_layer(&l).fold_half_plane(fft);
        assert_eq!(v.dims, [40, 1, 1]);
        let (idx, re, im) = v.row(0, 0);
        let hc = fft / 2 + 1;
        let hat = |r: usize, c: usize| (r * hc + c) as u32;
        // folded slots, sorted: (0,0), (1,2), (2,3), (3,1)←(5,7), (3,4)
        assert_eq!(idx, &[hat(0, 0), hat(1, 2), hat(2, 3), hat(3, 1), hat(3, 4)]);
        assert_eq!((re[0], im[0]), (1.0, 0.5)); // DC unchanged
        // (1,2): own half 1.0−0.5i plus mirror conj((2.0,1.0))/2 = 1.0−0.5i
        assert_eq!((re[1], im[1]), (2.0, -1.0));
        assert_eq!((re[2], im[2]), (2.0, 0.125)); // lone interior: /2
        assert_eq!((re[3], im[3]), (3.0, 1.0)); // conj((6,-2))/2
        assert_eq!((re[4], im[4]), (3.0, 1.5)); // Nyquist column unchanged
    }

    #[test]
    fn fold_halves_the_weight_stream() {
        let mut rng = Pcg32::new(17);
        let l = prune_magnitude(8, 4, 8, 4, &mut rng);
        let w = SparseWeightPlanes::from_layer(&l);
        let v = w.fold_half_plane(8);
        // merging can at best halve, and the edge columns never merge
        assert!(v.nnz() >= w.nnz() / 2, "{} vs {}", v.nnz(), w.nnz());
        assert!(v.nnz() < w.nnz(), "{} vs {}", v.nnz(), w.nnz());
        assert_eq!(v.alpha, w.alpha);
        // schedule adapters keep working on the folded layout
        assert_eq!(v.num_groups(4), 2);
        for g in 0..2 {
            for m in 0..4 {
                for row in v.group_indices(g, 4, m) {
                    for fi in row {
                        assert!((fi as usize) < 40);
                    }
                }
            }
        }
    }

    #[test]
    fn folded_half_plane_reproduces_full_plane_conv() {
        // The identity the half-plane MAC stands on, for both a
        // Hermitian-symmetric pruning (magnitude) and an asymmetric one
        // (random): Re(ifft2d(Σ_m X·W)) == irfft2d(Σ_m X_half·V).
        use crate::fft::{fft2d, ifft2d, irfft2d, rfft2d, Complex};
        let mut rng = Pcg32::new(21);
        let layers =
            [prune_magnitude(4, 3, 8, 4, &mut rng), prune_random(4, 3, 8, 4, &mut rng)];
        for l in &layers {
            let fft = 8usize;
            let hc = fft / 2 + 1;
            let w = SparseWeightPlanes::from_layer(l);
            let v = w.fold_half_plane(fft);
            let tiles: Vec<Vec<f32>> = (0..3)
                .map(|_| (0..fft * fft).map(|_| rng.normal()).collect())
                .collect();
            let full: Vec<Vec<Complex>> = tiles
                .iter()
                .map(|t| {
                    let c: Vec<Complex> =
                        t.iter().map(|&x| Complex::new(x, 0.0)).collect();
                    fft2d(&c, fft)
                })
                .collect();
            let half: Vec<Vec<Complex>> = tiles.iter().map(|t| rfft2d(t, fft)).collect();
            for ni in 0..4 {
                let mut acc_full = vec![Complex::ZERO; fft * fft];
                let mut acc_half = vec![Complex::ZERO; fft * hc];
                for mi in 0..3 {
                    let (idx, re, im) = w.row(ni, mi);
                    for j in 0..idx.len() {
                        let f = idx[j] as usize;
                        let p = full[mi][f].mul(Complex::new(re[j], im[j]));
                        acc_full[f] = acc_full[f].add(p);
                    }
                    let (idx, re, im) = v.row(ni, mi);
                    for j in 0..idx.len() {
                        let f = idx[j] as usize;
                        let p = half[mi][f].mul(Complex::new(re[j], im[j]));
                        acc_half[f] = acc_half[f].add(p);
                    }
                }
                let out_full = ifft2d(&acc_full, fft);
                let out_half = irfft2d(&acc_half, fft);
                for (a, &b) in out_full.iter().zip(&out_half) {
                    assert!((a.re - b).abs() < 1e-4, "{} vs {}", a.re, b);
                }
            }
        }
    }

    #[test]
    fn dataflow_from_stream_clamps() {
        let d = SparseDataflow::from_stream(&StreamParams { ns: 64, ps: 9 });
        assert_eq!(d.tile_block, 9);
        let z = SparseDataflow::from_stream(&StreamParams { ns: 64, ps: 0 });
        assert_eq!(z.tile_block, 1);
        assert_eq!(SparseDataflow::default().tile_block, 1);
    }
}
