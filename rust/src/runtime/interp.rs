//! Pure-Rust spectral-conv backend (the offline default).
//!
//! Executes exactly what the AOT'd XLA executable computes, using the
//! crate's own [`fft`](crate::fft) substrate: per input tile, 2D-FFT every
//! input channel, multiply-accumulate against the frequency-major kernel
//! planes (`[K², M, N]` — the same layout
//! [`freq_major_planes`](super::freq_major_planes) feeds PJRT), then
//! 2D-IFFT each output channel and keep the real part. The engine wraps
//! this with `im2tiles` / `overlap_add`, so the end-to-end path is the
//! paper's Eq. 4 with zero external dependencies.
//!
//! Throughput note: this is the software *reference* path (the role the
//! paper's CPU/GPU baselines play); the per-tile MAC is O(K²·M·N) complex
//! ops, frequency-major so the weight row `[N]` streams contiguously.
//!
//! Tiles are independent (the paper's P'-parallel dimension), so
//! [`InterpBackend::with_threads`] fans the per-tile loop out over scoped
//! threads, each with its own scratch buffers, writing disjoint output
//! slices. The per-tile arithmetic is identical in every configuration, so
//! outputs are bit-for-bit equal for any thread count.

use std::collections::HashMap;
use std::path::Path;

use crate::err;
use crate::fft::{fft2d_inplace, ifft2d_inplace, Complex};
use crate::tensor::Tensor;
use crate::util::error::Result;

use super::{ExecutableEntry, SpectralBackend, WeightId};

#[derive(Debug, Clone, Copy)]
struct Shape {
    tiles: usize,
    cin: usize,
    cout: usize,
    fft: usize,
}

struct WeightPlanes {
    re: Vec<f32>,
    im: Vec<f32>,
    /// `[F, M, N]` with `F = K²`.
    dims: [usize; 3],
}

/// The interpreter backend: shape registry + uploaded weight planes.
pub struct InterpBackend {
    shapes: HashMap<String, Shape>,
    weights: Vec<WeightPlanes>,
    /// Worker threads for the per-tile loop (1 = serial).
    threads: usize,
}

impl Default for InterpBackend {
    fn default() -> Self {
        Self::with_threads(1)
    }
}

impl InterpBackend {
    pub fn new() -> Self {
        Self::default()
    }

    /// Backend with a tile-parallel hot loop over `threads` scoped worker
    /// threads (`0` and `1` both mean serial).
    pub fn with_threads(threads: usize) -> Self {
        InterpBackend {
            shapes: HashMap::new(),
            weights: Vec::new(),
            threads: threads.max(1),
        }
    }
}

/// One tile of the spectral conv: FFT every input channel of `in_tile`
/// (`[M, K²]` spatial), frequency-major MAC against the kernel planes,
/// IFFT each output channel into `out_tile` (`[N, K²]` spatial, real part).
/// `xs`/`acc` are caller-owned scratch (`[M, K²]` / `[N, K²]` complex) so
/// the request path does no per-tile allocation.
fn conv_tile(
    in_tile: &[f32],
    out_tile: &mut [f32],
    w: &WeightPlanes,
    s: Shape,
    xs: &mut [Complex],
    acc: &mut [Complex],
) {
    let (m, n, k) = (s.cin, s.cout, s.fft);
    let f = k * k;
    for mi in 0..m {
        let chan = &mut xs[mi * f..(mi + 1) * f];
        for (p, &v) in chan.iter_mut().zip(&in_tile[mi * f..(mi + 1) * f]) {
            *p = Complex::new(v, 0.0);
        }
        fft2d_inplace(chan, k);
    }
    for a in acc.iter_mut() {
        *a = Complex::ZERO;
    }
    // frequency-major MAC: for each (freq, cin), stream the [N] row
    for fi in 0..f {
        for mi in 0..m {
            let x = xs[mi * f + fi];
            let row = (fi * m + mi) * n;
            for ni in 0..n {
                let (wr, wi) = (w.re[row + ni], w.im[row + ni]);
                let a = &mut acc[ni * f + fi];
                a.re += x.re * wr - x.im * wi;
                a.im += x.re * wi + x.im * wr;
            }
        }
    }
    for ni in 0..n {
        let plane = &mut acc[ni * f..(ni + 1) * f];
        ifft2d_inplace(plane, k);
        for (o, c) in out_tile[ni * f..(ni + 1) * f].iter_mut().zip(plane.iter()) {
            *o = c.re;
        }
    }
}

impl SpectralBackend for InterpBackend {
    fn name(&self) -> String {
        "interp".to_string()
    }

    fn prepare(&mut self, file: &str, meta: &ExecutableEntry, _artifacts_dir: &Path)
        -> Result<()> {
        if !meta.fft_size.is_power_of_two() {
            return Err(err!("{file}: FFT size {} is not a power of two", meta.fft_size));
        }
        self.shapes.insert(
            file.to_string(),
            Shape { tiles: meta.tiles, cin: meta.cin, cout: meta.cout, fft: meta.fft_size },
        );
        Ok(())
    }

    fn upload_weights(&mut self, re: &[f32], im: &[f32], dims: [usize; 3]) -> Result<WeightId> {
        let want = dims[0] * dims[1] * dims[2];
        if re.len() != want || im.len() != want {
            return Err(err!(
                "weight planes {}x{} don't match dims {dims:?} (= {want} elements)",
                re.len(),
                im.len()
            ));
        }
        self.weights.push(WeightPlanes { re: re.to_vec(), im: im.to_vec(), dims });
        Ok(self.weights.len() - 1)
    }

    fn run_conv(&mut self, file: &str, tiles: &Tensor, wid: WeightId) -> Result<Tensor> {
        let s = *self
            .shapes
            .get(file)
            .ok_or_else(|| err!("{file} not prepared (warm the variant first)"))?;
        let (t, m, n, k) = (s.tiles, s.cin, s.cout, s.fft);
        let f = k * k;
        let want_in = [t, m, k, k];
        if tiles.shape() != want_in {
            return Err(err!(
                "input tiles shape {:?} != executable shape {:?}",
                tiles.shape(),
                want_in
            ));
        }
        let w = self
            .weights
            .get(wid)
            .ok_or_else(|| err!("weight handle {wid} unknown"))?;
        if w.dims != [f, m, n] {
            return Err(err!(
                "weight dims {:?} != executable dims {:?}",
                w.dims,
                [f, m, n]
            ));
        }

        let td = tiles.data();
        let mut out = Tensor::zeros(&[t, n, k, k]);
        let od = out.data_mut();
        let threads = self.threads.min(t).max(1);
        if threads == 1 {
            // scratch reused across tiles — no per-tile allocations on the
            // request path: FFTs run in place on these buffers
            let mut xs = vec![Complex::ZERO; m * f];
            let mut acc = vec![Complex::ZERO; n * f];
            for (ti, out_tile) in od.chunks_mut(n * f).enumerate() {
                conv_tile(&td[ti * m * f..(ti + 1) * m * f], out_tile, w, s, &mut xs, &mut acc);
            }
        } else {
            // fan tiles out over scoped threads: each thread takes a
            // contiguous chunk of tiles, owns its scratch, and writes a
            // disjoint slice of the output — no locks, no result reordering.
            // Balanced partition (sizes differ by at most one) so every
            // requested thread gets work even when `threads` ∤ `t`.
            let (base, extra) = (t / threads, t % threads);
            std::thread::scope(|scope| {
                let mut rest = od;
                let mut start = 0usize;
                for ci in 0..threads {
                    let len = base + usize::from(ci < extra);
                    let (out_chunk, tail) = rest.split_at_mut(len * n * f);
                    rest = tail;
                    let first = start;
                    start += len;
                    scope.spawn(move || {
                        let mut xs = vec![Complex::ZERO; m * f];
                        let mut acc = vec![Complex::ZERO; n * f];
                        for (j, out_tile) in out_chunk.chunks_mut(n * f).enumerate() {
                            let ti = first + j;
                            conv_tile(
                                &td[ti * m * f..(ti + 1) * m * f],
                                out_tile,
                                w,
                                s,
                                &mut xs,
                                &mut acc,
                            );
                        }
                    });
                }
            });
        }
        Ok(out)
    }

    fn prepared(&self) -> usize {
        self.shapes.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::{fft2d, ifft2d, spectral_kernels};
    use crate::runtime::freq_major_planes;
    use crate::util::check::{assert_allclose, forall};
    use crate::util::rng::Pcg32;

    fn entry(tiles: usize, cin: usize, cout: usize, fft: usize) -> ExecutableEntry {
        ExecutableEntry { tiles, cin, cout, fft_size: fft, sha256: "t".into(), bytes: 0 }
    }

    /// Reference: per-tile dense Hadamard pipeline written independently of
    /// the backend's loop structure.
    fn reference_conv(tiles: &Tensor, planes: &crate::tensor::ComplexTensor, fft: usize)
        -> Tensor {
        let (t, m) = (tiles.shape()[0], tiles.shape()[1]);
        let n = planes.shape()[0];
        let f = fft * fft;
        let mut out = Tensor::zeros(&[t, n, fft, fft]);
        for ti in 0..t {
            let xs: Vec<Vec<Complex>> = (0..m)
                .map(|mi| {
                    let p: Vec<Complex> = (0..f)
                        .map(|i| Complex::new(tiles.at(&[ti, mi, i / fft, i % fft]), 0.0))
                        .collect();
                    fft2d(&p, fft)
                })
                .collect();
            for ni in 0..n {
                let mut acc = vec![Complex::ZERO; f];
                for (mi, x) in xs.iter().enumerate() {
                    for i in 0..f {
                        let (wr, wi) = planes.at(&[ni, mi, i / fft, i % fft]);
                        acc[i] = acc[i].add(x[i].mul(Complex::new(wr, wi)));
                    }
                }
                for (i, c) in ifft2d(&acc, fft).iter().enumerate() {
                    out.set(&[ti, ni, i / fft, i % fft], c.re);
                }
            }
        }
        out
    }

    #[test]
    fn matches_dense_hadamard_reference() {
        forall("interp == dense hadamard", 10, |rng| {
            let (t, m, n, fft) = (rng.range(1, 4), rng.range(1, 4), rng.range(1, 4), 8);
            let tiles = Tensor::randn(&[t, m, fft, fft], rng, 1.0);
            let spatial = Tensor::randn(&[n, m, 3, 3], rng, 0.3);
            let planes = spectral_kernels(&spatial, fft);
            let (re, im) = freq_major_planes(&planes);

            let mut b = InterpBackend::new();
            b.prepare("x", &entry(t, m, n, fft), Path::new(".")).unwrap();
            let wid = b.upload_weights(&re, &im, [fft * fft, m, n]).unwrap();
            let got = b.run_conv("x", &tiles, wid).unwrap();
            let want = reference_conv(&tiles, &planes, fft);
            assert_allclose(got.data(), want.data(), 1e-4, 1e-4);
        });
    }

    #[test]
    fn rejects_shape_mismatches() {
        let mut rng = Pcg32::new(1);
        let mut b = InterpBackend::new();
        b.prepare("x", &entry(2, 1, 1, 8), Path::new(".")).unwrap();
        let wid = b.upload_weights(&[0.0; 64], &[0.0; 64], [64, 1, 1]).unwrap();
        // wrong tile count
        let bad = Tensor::randn(&[3, 1, 8, 8], &mut rng, 1.0);
        assert!(b.run_conv("x", &bad, wid).is_err());
        // unknown executable
        let ok = Tensor::randn(&[2, 1, 8, 8], &mut rng, 1.0);
        assert!(b.run_conv("y", &ok, wid).is_err());
        // bad weight handle
        assert!(b.run_conv("x", &ok, wid + 7).is_err());
        // bad weight dims at upload
        assert!(b.upload_weights(&[0.0; 3], &[0.0; 3], [64, 1, 1]).is_err());
    }

    #[test]
    fn threaded_matches_serial_bit_for_bit() {
        // tiles are independent and the per-tile arithmetic identical, so
        // any thread count must reproduce the serial output exactly —
        // including thread counts that don't divide the tile count and
        // counts larger than it.
        let mut rng = Pcg32::new(9);
        let (t, m, n, fft) = (7, 3, 4, 8);
        let tiles = Tensor::randn(&[t, m, fft, fft], &mut rng, 1.0);
        let spatial = Tensor::randn(&[n, m, 3, 3], &mut rng, 0.3);
        let planes = spectral_kernels(&spatial, fft);
        let (re, im) = freq_major_planes(&planes);
        let run = |threads: usize| {
            let mut b = InterpBackend::with_threads(threads);
            b.prepare("x", &entry(t, m, n, fft), Path::new(".")).unwrap();
            let wid = b.upload_weights(&re, &im, [fft * fft, m, n]).unwrap();
            b.run_conv("x", &tiles, wid).unwrap()
        };
        let serial = run(1);
        for threads in [2, 3, 4, 16] {
            let par = run(threads);
            assert_eq!(par.data(), serial.data(), "threads={threads} diverged");
        }
    }

    #[test]
    fn prepare_is_idempotent() {
        let mut b = InterpBackend::new();
        b.prepare("x", &entry(1, 1, 1, 8), Path::new(".")).unwrap();
        b.prepare("x", &entry(1, 1, 1, 8), Path::new(".")).unwrap();
        assert_eq!(b.prepared(), 1);
    }
}
