//! Pure-Rust spectral-conv backend (the offline default).
//!
//! Executes exactly what the AOT'd XLA executable computes, using the
//! crate's own [`fft`](crate::fft) substrate: per input tile, 2D-FFT every
//! input channel, multiply-accumulate against the frequency-major kernel
//! planes (`[K², M, N]` — the same layout
//! [`freq_major_planes`](super::freq_major_planes) feeds PJRT), then
//! 2D-IFFT each output channel and keep the real part. The engine wraps
//! this with `im2tiles` / `overlap_add`, so the end-to-end path is the
//! paper's Eq. 4 with zero external dependencies.
//!
//! Throughput note: this is the software *reference* path (the role the
//! paper's CPU/GPU baselines play); the per-tile MAC is O(K²·M·N) complex
//! ops, frequency-major so the weight row `[N]` streams contiguously.
//!
//! Tiles are independent (the paper's P'-parallel dimension), so
//! [`InterpBackend::with_threads`] fans the per-tile loop out over scoped
//! threads, each with its own scratch buffers, writing disjoint output
//! slices. The per-tile arithmetic is identical in every configuration, so
//! outputs are bit-for-bit equal for any thread count.
//!
//! Pruned layers take the **sparse** path: weights uploaded via
//! [`SpectralBackend::upload_sparse`] are kept as CSR rows
//! ([`SparseWeightPlanes`]) and the MAC iterates only the K²/α non-zeros —
//! the paper's §4 compute cut, executed. The sparse loop processes tiles in
//! blocks of [`SparseDataflow::tile_block`] resident spectra (Alg. 1's Ps,
//! set per executable by the engine), walking every kernel row once per
//! block so kernel data streams `⌈P/Ps⌉` times instead of `P` times — the
//! software analogue of the flexible dataflow's reuse choice.
//!
//! Execution is **batch-major**: [`SpectralBackend::run_conv_batch`]
//! concatenates the B images' tiles into one `[B·T]` tile population and
//! runs it through the same block frame, so the tile blocks (and hence the
//! kernel-stream reuse) span images — with `tile_block ≥ B·T` every CSR
//! row / `BankedWeights` cycle-set is read once per *batch* instead of
//! once per image, which is exactly the batch axis the B-aware Alg. 1
//! plans for. Because the MAC walk is outer-loop-over-weight-blocks,
//! inner-loop-over-resident-tiles, and per-tile arithmetic never depends
//! on how tiles are grouped into blocks or chunks, the batched path is
//! bit-identical to B independent [`SpectralBackend::run_conv`] calls.
//!
//! When the engine additionally attaches an Alg. 2 access plan
//! ([`SpectralBackend::set_schedule`]), the sparse MAC runs
//! **schedule-driven**: the layer's weights are compiled into a banked
//! store (`B` banks over the K² plane) and the walk follows the plan's
//! conflict-free cycle-sets instead of CSR storage order — bit-identical to
//! the unscheduled walk (see `conv_tiles_scheduled`), so scheduling is a
//! pure loop-order/metrics change, never a numerics change.
//!
//! **Numeric modes** ([`SpectralBackend::configure_numerics`]): the whole
//! pipeline is generic over [`Float`] (`f32` default, `f64` reference) and
//! over the spectral storage [`Plane`]. In [`Plane::Half`] mode the
//! Hermitian symmetry of real tiles is exploited end to end: tile spectra
//! come from [`crate::fft::rfft2d`] (half the FFT work), uploaded weights
//! are conjugate-folded onto the `K·(K/2+1)` half-plane (dense planes via
//! [`fold_freq_major_half`], CSR rows via
//! [`SparseWeightPlanes::fold_half_plane`] — so `BankedWeights` banks,
//! cycle-sets, and every scheduled MAC read halve too), and outputs come
//! back through [`crate::fft::irfft2d`]. Weights stay f32 at rest in every
//! mode and widen at MAC read time; `(f32, Full)` reproduces the
//! historical path bit for bit.
//!
//! The sparse MAC scratch is stored **SoA with the batch axis innermost**
//! (`xs_re[(m·F + f)·b + bi]`): the inner per-resident-tile loop is then a
//! unit-stride multiply-accumulate against a scalar weight, which the
//! autovectorizer turns into SIMD — without changing per-slot accumulation
//! order, so outputs stay bit-identical to the historical AoS walk.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

use crate::err;
use crate::fft::{fft2d_inplace, ifft2d_inplace, irfft2d_into, rfft2d_into, Cx, Float};
use crate::obs::TrafficCounters;
use crate::schedule::LayerSchedule;
use crate::sparse::SparseLayer;
use crate::tensor::Tensor;
use crate::util::error::Result;

use super::{
    fold_freq_major_half, Dtype, ExecutableEntry, Plane, SparseDataflow, SparseWeightPlanes,
    SpectralBackend, WeightId,
};

/// Cache budget for the sparse path's resident spectra, in complex slots
/// across the per-thread `xs`+`acc` scratch (4 Mi slots ≈ 32 MB at 8 B
/// each). The software analogue of Eq. 12's BRAM feasibility gate: the
/// planner's Ps is honored up to this cap, so a hostile manifest can't make
/// one worker thread allocate unbounded resident state.
const SPARSE_RESIDENT_SLOTS: usize = 4 << 20;

#[derive(Debug, Clone, Copy)]
struct Shape {
    tiles: usize,
    cin: usize,
    cout: usize,
    fft: usize,
}

struct WeightPlanes {
    re: Vec<f32>,
    im: Vec<f32>,
    /// `[F, M, N]` with `F = K²`.
    dims: [usize; 3],
}

/// One uploaded layer: dense frequency-major planes or sparse CSR rows.
enum WeightStore {
    Dense(WeightPlanes),
    Sparse(SparseWeightPlanes),
}

impl WeightStore {
    fn dims(&self) -> [usize; 3] {
        match self {
            WeightStore::Dense(w) => w.dims,
            WeightStore::Sparse(w) => w.dims,
        }
    }
}

/// One (kernel-group, input-channel) scheduling instance compiled to a flat
/// read stream: entries in cycle order, weights resolved to (bank, slot)
/// locations in the layer's banked store.
struct ScheduledStream {
    /// Entry offsets per cycle-set (`len = cycles + 1`): the scheduled MAC
    /// walks [`crate::schedule::Schedule`] cycles through these bounds.
    cycle_ptr: Vec<u32>,
    /// Global output channel n per entry.
    chan: Vec<u16>,
    /// Flattened frequency index per entry.
    fi: Vec<u16>,
    /// Weight location: bank id (`fi mod B`) + slot within that bank.
    bank: Vec<u16>,
    slot: Vec<u32>,
}

/// A sparse layer compiled against its [`LayerSchedule`]: the software
/// analogue of Fig. 6's INDEX/VALUE hand-off. Weights live in `B` bank
/// arrays over the K² frequency plane (`bank(f) = f mod B`); each cycle-set
/// issues its reads bank-major, so at most one read hits a bank per beat —
/// conflicts the plan counted ([`crate::schedule::ScheduleStats`]) are
/// exactly the extra beats this layout would need in hardware. Execution
/// order is channel-serial (M' = 1, §5.1) then schedule order, which keeps
/// every accumulator slot's contribution order identical to the unscheduled
/// CSR walk — see [`conv_tiles_scheduled`].
struct BankedWeights {
    cin: usize,
    num_groups: usize,
    bank_re: Vec<Vec<f32>>,
    bank_im: Vec<Vec<f32>>,
    /// `streams[g · cin + m]`.
    streams: Vec<ScheduledStream>,
}

/// Recover K from a full-plane `dims[0] = K²` (K is a power of two, so K²
/// is a power of four).
fn fft_from_k2(k2: usize) -> Result<usize> {
    if k2.is_power_of_two() && k2.trailing_zeros() % 2 == 0 {
        Ok(1 << (k2.trailing_zeros() / 2))
    } else {
        Err(err!("weight dims[0] = {k2} is not the square of a power-of-two FFT size"))
    }
}

/// Compile a layer plan + CSR rows into the banked form, validating that
/// the plan really covers these weights (the engine builds plans from the
/// same upload, but the backend must not trust that).
fn compile_schedule(plan: &LayerSchedule, w: &SparseWeightPlanes) -> Result<BankedWeights> {
    plan.validate(w)
        .map_err(|e| err!("schedule does not match sparse weights: {e}"))?;
    let banks = plan.banks.max(1);
    let cin = plan.cin;
    let num_groups = plan.num_groups();
    let mut bank_re: Vec<Vec<f32>> = vec![Vec::new(); banks];
    let mut bank_im: Vec<Vec<f32>> = vec![Vec::new(); banks];
    let mut streams = Vec::with_capacity(num_groups * cin);
    let mut total = 0usize;
    for g in 0..num_groups {
        for m in 0..cin {
            let sched = plan.group(g, m);
            let mut st = ScheduledStream {
                cycle_ptr: Vec::with_capacity(sched.cycles() + 1),
                chan: Vec::new(),
                fi: Vec::new(),
                bank: Vec::new(),
                slot: Vec::new(),
            };
            st.cycle_ptr.push(0);
            for set in &sched.sets {
                // bank-major issue order within the cycle (≤ 1 read per
                // bank per beat); numerically free — each accumulator slot
                // receives exactly one contribution per input channel
                let mut reads: Vec<(usize, u16, u16)> = set
                    .reads
                    .iter()
                    .map(|&(k, i)| (i as usize % banks, k, i))
                    .collect();
                reads.sort_unstable();
                for (b, k, i) in reads {
                    let n = g * plan.n_par + k as usize;
                    let (idx, wre, wim) = w.row(n, m);
                    let pos = idx
                        .binary_search(&(i as u32))
                        .map_err(|_| err!("scheduled index {i} not in row ({n},{m})"))?;
                    st.chan.push(n as u16);
                    st.fi.push(i);
                    st.bank.push(b as u16);
                    st.slot.push(bank_re[b].len() as u32);
                    bank_re[b].push(wre[pos]);
                    bank_im[b].push(wim[pos]);
                    total += 1;
                }
                st.cycle_ptr.push(st.chan.len() as u32);
            }
            streams.push(st);
        }
    }
    if total != w.nnz() {
        return Err(err!(
            "schedule covers {total} reads, weights hold {} non-zeros",
            w.nnz()
        ));
    }
    Ok(BankedWeights { cin, num_groups, bank_re, bank_im, streams })
}

/// The interpreter backend: shape registry + uploaded weights (dense planes
/// or sparse CSR rows) + per-executable sparse streaming hints + compiled
/// per-upload access schedules.
pub struct InterpBackend {
    shapes: HashMap<String, Shape>,
    weights: Vec<WeightStore>,
    /// Per-executable sparse streaming decision (absent ⇒ tile_block 1).
    flows: HashMap<String, SparseDataflow>,
    /// Per-upload compiled schedule (absent ⇒ unscheduled CSR walk).
    scheduled: HashMap<WeightId, BankedWeights>,
    /// Worker threads for the per-tile loop (1 = serial).
    threads: usize,
    /// Scalar precision of the FFT → MAC → IFFT core.
    dtype: Dtype,
    /// Spectral storage plane (weights fold at upload time, so this must
    /// be configured before uploads — `configure_numerics` enforces it).
    plane: Plane,
    /// Data-movement counters ([`SpectralBackend::attach_traffic`]):
    /// bumped once per weight-block walk / tile population, never per
    /// non-zero, and never read by the compute — attaching them cannot
    /// change any output bit.
    traffic: Option<Arc<TrafficCounters>>,
}

impl Default for InterpBackend {
    fn default() -> Self {
        Self::with_threads(1)
    }
}

impl InterpBackend {
    pub fn new() -> Self {
        Self::default()
    }

    /// Backend with a tile-parallel hot loop over `threads` scoped worker
    /// threads (`0` and `1` both mean serial).
    pub fn with_threads(threads: usize) -> Self {
        Self::with_config(threads, Dtype::default(), Plane::default())
    }

    /// Backend with an explicit numeric mode (threads as
    /// [`Self::with_threads`]) — the constructor-shaped twin of
    /// [`SpectralBackend::configure_numerics`].
    pub fn with_config(threads: usize, dtype: Dtype, plane: Plane) -> Self {
        InterpBackend {
            shapes: HashMap::new(),
            weights: Vec::new(),
            flows: HashMap::new(),
            scheduled: HashMap::new(),
            threads: threads.max(1),
            dtype,
            plane,
            traffic: None,
        }
    }

    /// Shared executor behind [`SpectralBackend::run_conv`] and
    /// [`SpectralBackend::run_conv_batch`]: run the spectral conv over a
    /// tile population of `t` tiles (`td` = `[t, M, K, K]` flattened, `od`
    /// = `[t, N, K, K]` flattened). For the batched entry point `t` is
    /// `B·T` — the weight walk (dense rows, CSR rows, or scheduled
    /// cycle-sets) is outermost per resident block, so blocks spanning
    /// image boundaries reuse each weight read across images.
    fn conv_tiles(
        &self,
        file: &str,
        s: Shape,
        t: usize,
        td: &[f32],
        od: &mut [f32],
        wid: WeightId,
    ) -> Result<()> {
        let (m, n, k) = (s.cin, s.cout, s.fft);
        let fs = self.plane.spectrum_len(k);
        let store = self
            .weights
            .get(wid)
            .ok_or_else(|| err!("weight handle {wid} unknown"))?;
        if store.dims() != [fs, m, n] {
            return Err(err!(
                "weight dims {:?} != executable dims {:?}",
                store.dims(),
                [fs, m, n]
            ));
        }
        // fan tiles out over scoped threads (serial when threads == 1):
        // each chunk is a contiguous tile range with its own scratch,
        // writing a disjoint output slice — no locks, no result reordering.
        let threads = self.threads.min(t).max(1);
        // resident-tile block = the planner's Ps, clamped by the scratch
        // cache budget (the Eq. 12 analogue — half-plane spectra cost half
        // the slots, so the same budget holds twice the resident tiles)
        let hinted = self.flows.get(file).map_or(1, |d| d.tile_block);
        let cap = (SPARSE_RESIDENT_SLOTS / ((m + n) * fs).max(1)).max(1);
        let block = hinted.clamp(1, cap);
        let sched = self.scheduled.get(&wid);
        // activation traffic at the backend boundary: the spatial f32 tile
        // words this call reads and writes (t·M·K² in, t·N·K² out). Note
        // this is the *tiled* population — it exceeds Eq. 13's per-pixel
        // input term by the tile-overlap factor (documented divergence).
        if let Some(c) = &self.traffic {
            let f = (k * k) as u64;
            c.add_inputs(t as u64 * m as u64 * f * 4);
            c.add_outputs(t as u64 * n as u64 * f * 4);
        }
        let traffic = self.traffic.as_deref();
        match self.dtype {
            Dtype::F32 => run_conv_typed::<f32>(
                store, sched, s, self.plane, t, td, od, threads, block, traffic,
            ),
            Dtype::F64 => run_conv_typed::<f64>(
                store, sched, s, self.plane, t, td, od, threads, block, traffic,
            ),
        }
        Ok(())
    }
}

/// Bytes of one complex spectral word at precision `T` (8 for f32, 16 for
/// f64) — the unit both the measured counters and the engine's Eq. 13
/// prediction use for kernel traffic, so the B=1 full-plane ratio is
/// exactly 1 regardless of dtype.
fn complex_bytes<T: Float>() -> u64 {
    2 * std::mem::size_of::<T>() as u64
}

/// Dispatch one tile population through the mode-specific hot loop: the
/// dtype match above monomorphizes everything below it, so the f32 path
/// carries no f64 code and vice versa.
#[allow(clippy::too_many_arguments)]
fn run_conv_typed<T: Float>(
    store: &WeightStore,
    sched: Option<&BankedWeights>,
    s: Shape,
    plane: Plane,
    t: usize,
    td: &[f32],
    od: &mut [f32],
    threads: usize,
    block: usize,
    traffic: Option<&TrafficCounters>,
) {
    let (m, n, k) = (s.cin, s.cout, s.fft);
    let f = k * k;
    let fs = plane.spectrum_len(k);
    match store {
        WeightStore::Dense(w) => {
            for_tile_chunks(od, n * f, t, threads, |first, out_chunk| {
                // scratch reused across the chunk's tiles — no per-tile
                // allocations on the request path: FFTs run in place
                let mut xs = vec![Cx::<T>::ZERO; m * fs];
                let mut acc = vec![Cx::<T>::ZERO; n * fs];
                let mut real = vec![T::ZERO; f];
                for (j, out_tile) in out_chunk.chunks_mut(n * f).enumerate() {
                    let ti = first + j;
                    conv_tile(
                        &td[ti * m * f..(ti + 1) * m * f],
                        out_tile,
                        w,
                        s,
                        plane,
                        &mut xs,
                        &mut acc,
                        &mut real,
                    );
                }
                if let Some(c) = traffic {
                    // the dense MAC re-reads the full [F', M, N] plane per
                    // tile and touches every accumulator slot once per
                    // (freq, cin) — one counter bump per chunk
                    let tiles = (out_chunk.len() / (n * f)) as u64;
                    let words = tiles * (fs * m * n) as u64;
                    c.add_weights(words * complex_bytes::<T>());
                    c.add_psums(words * complex_bytes::<T>());
                }
            });
        }
        WeightStore::Sparse(w) => match sched {
            // schedule-driven walk (Alg. 2 order, banked weights)
            Some(bw) => {
                for_tile_chunks(od, n * f, t, threads, |first, out_chunk| {
                    conv_tiles_scheduled::<T>(td, out_chunk, first, bw, s, plane, block, traffic);
                });
            }
            // unscheduled CSR storage-order walk (PR 3 path)
            None => {
                for_tile_chunks(od, n * f, t, threads, |first, out_chunk| {
                    conv_tiles_sparse::<T>(td, out_chunk, first, w, s, plane, block, traffic);
                });
            }
        },
    }
}

/// Split the output into `threads` contiguous tile chunks (sizes differ by
/// at most one) and run `body(first_tile, chunk)` on each, in a scoped
/// thread per chunk — or inline when `threads == 1`. Chunks are disjoint
/// output slices, so there are no locks and no result reordering; the
/// per-tile arithmetic is whatever `body` does, identically in both modes.
fn for_tile_chunks<F>(od: &mut [f32], tile_elems: usize, t: usize, threads: usize, body: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    if threads <= 1 {
        body(0, od);
        return;
    }
    let (base, extra) = (t / threads, t % threads);
    std::thread::scope(|scope| {
        let mut rest = od;
        let mut start = 0usize;
        for ci in 0..threads {
            let len = base + usize::from(ci < extra);
            let (out_chunk, tail) = std::mem::take(&mut rest).split_at_mut(len * tile_elems);
            rest = tail;
            let first = start;
            start += len;
            let body = &body;
            scope.spawn(move || body(first, out_chunk));
        }
    });
}

/// One tile of the spectral conv: FFT every input channel of `in_tile`
/// (`[M, K²]` spatial; rFFT in half-plane mode), frequency-major MAC
/// against the (possibly folded) kernel planes, inverse-FFT each output
/// channel into `out_tile` (`[N, K²]` spatial, real part). `xs`/`acc` are
/// caller-owned scratch (`[M, F']` / `[N, F']` complex, `F'` the plane's
/// spectrum length) and `real` a `K²` real staging buffer for the rFFT
/// ends, so the request path does no per-tile allocation.
#[allow(clippy::too_many_arguments)]
fn conv_tile<T: Float>(
    in_tile: &[f32],
    out_tile: &mut [f32],
    w: &WeightPlanes,
    s: Shape,
    plane: Plane,
    xs: &mut [Cx<T>],
    acc: &mut [Cx<T>],
    real: &mut [T],
) {
    let (m, n, k) = (s.cin, s.cout, s.fft);
    let f = k * k;
    let fs = plane.spectrum_len(k);
    for mi in 0..m {
        let chan = &mut xs[mi * fs..(mi + 1) * fs];
        let src = &in_tile[mi * f..(mi + 1) * f];
        match plane {
            Plane::Full => {
                for (p, &v) in chan.iter_mut().zip(src) {
                    *p = Cx::new(T::from_f32(v), T::ZERO);
                }
                fft2d_inplace(chan, k);
            }
            Plane::Half => {
                for (p, &v) in real.iter_mut().zip(src) {
                    *p = T::from_f32(v);
                }
                rfft2d_into(real, k, chan);
            }
        }
    }
    for a in acc.iter_mut() {
        *a = Cx::ZERO;
    }
    // frequency-major MAC: for each (freq, cin), stream the [N] row
    for fi in 0..fs {
        for mi in 0..m {
            let x = xs[mi * fs + fi];
            let row = (fi * m + mi) * n;
            for ni in 0..n {
                let (wr, wi) = (T::from_f32(w.re[row + ni]), T::from_f32(w.im[row + ni]));
                let a = &mut acc[ni * fs + fi];
                a.re += x.re * wr - x.im * wi;
                a.im += x.re * wi + x.im * wr;
            }
        }
    }
    for ni in 0..n {
        let spec = &mut acc[ni * fs..(ni + 1) * fs];
        let dst = &mut out_tile[ni * f..(ni + 1) * f];
        match plane {
            Plane::Full => {
                ifft2d_inplace(spec, k);
                for (o, c) in dst.iter_mut().zip(spec.iter()) {
                    *o = c.re.to_f32();
                }
            }
            Plane::Half => {
                irfft2d_into(spec, k, real);
                for (o, &v) in dst.iter_mut().zip(real.iter()) {
                    *o = v.to_f32();
                }
            }
        }
    }
}

/// Sparse spectral conv for one contiguous chunk of tiles (`first..first +
/// len` of the full input, `out_chunk` = that chunk's `[len, N, K²]`
/// output). Tiles are processed in blocks of up to `block` resident
/// spectra: FFT the block's input channels, walk every kernel's CSR row
/// **once** across the block (the kernel value sits in registers while the
/// `block` tiles consume it — Alg. 1's Ps-reuse, in software), then IFFT.
///
/// Accumulation order into each `(tile, n, fi)` slot is `(m ascending, nnz
/// ascending)` — the same order the dense MAC uses for its non-zero terms —
/// so results match the dense path on identical values to fp round-off of
/// the elided zero terms, and are bit-identical across `block` sizes and
/// thread counts.
#[allow(clippy::too_many_arguments)]
fn conv_tiles_sparse<T: Float>(
    in_tiles: &[f32],
    out_chunk: &mut [f32],
    first: usize,
    w: &SparseWeightPlanes,
    s: Shape,
    plane: Plane,
    block: usize,
    traffic: Option<&TrafficCounters>,
) {
    let (m, n) = (s.cin, s.cout);
    let fs = plane.spectrum_len(s.fft);
    let nnz = w.nnz() as u64;
    for_sparse_blocks::<T, _>(in_tiles, out_chunk, first, s, plane, block, |xs, acc, b| {
        if let Some(c) = traffic {
            // one kernel stream per resident block (every CSR row read
            // once), one accumulator update per non-zero per resident tile
            c.add_weights(nnz * complex_bytes::<T>());
            c.add_psums(nnz * b as u64 * complex_bytes::<T>());
        }
        // the sparse MAC: only the stored non-zeros are touched (K²/α of
        // them, ~half that again in half-plane mode). The weight sits in
        // registers while the inner loop streams the b resident tiles
        // unit-stride — a flat FMA chain the autovectorizer can widen.
        for ni in 0..n {
            for mi in 0..m {
                let (idx, wre, wim) = w.row(ni, mi);
                for ((&fi, &wr32), &wi32) in idx.iter().zip(wre).zip(wim) {
                    let fi = fi as usize;
                    let (wr, wi) = (T::from_f32(wr32), T::from_f32(wi32));
                    let x = (mi * fs + fi) * b;
                    let (xr, xi) = (&xs.re[x..x + b], &xs.im[x..x + b]);
                    let a = (ni * fs + fi) * b;
                    let (ar, ai) = (&mut acc.re[a..a + b], &mut acc.im[a..a + b]);
                    for bi in 0..b {
                        ar[bi] += xr[bi] * wr - xi[bi] * wi;
                        ai[bi] += xr[bi] * wi + xi[bi] * wr;
                    }
                }
            }
        }
    });
}

/// Schedule-driven sparse conv for one chunk of tiles: same block frame as
/// [`conv_tiles_sparse`], but the MAC walks the compiled
/// [`LayerSchedule`] cycles — input channels serial (M' = 1), then each
/// kernel group's cycle-sets in order, each cycle issuing its reads
/// bank-major from the banked weight store into per-PE partial sums.
///
/// **Bit-identity argument** (the tentpole's correctness gate): a given
/// accumulator slot `(tile, n, fi)` receives exactly one contribution per
/// input channel `m` (row indices are distinct; the schedule covers every
/// edge exactly once), and both walks process channels in ascending order —
/// the row walk in its inner `mi` loop, this walk in its outer `mi` loop.
/// Identical f32 products summed in an identical per-slot order, inside the
/// identical FFT/IFFT block frame ⇒ outputs equal the unscheduled path bit
/// for bit, for every scheduler, block size, and thread count.
#[allow(clippy::too_many_arguments)]
fn conv_tiles_scheduled<T: Float>(
    in_tiles: &[f32],
    out_chunk: &mut [f32],
    first: usize,
    bw: &BankedWeights,
    s: Shape,
    plane: Plane,
    block: usize,
    traffic: Option<&TrafficCounters>,
) {
    let fs = plane.spectrum_len(s.fft);
    // entries across every cycle-set == the layer's non-zeros
    // (compile_schedule validated the cover)
    let nnz: u64 = bw.bank_re.iter().map(|bank| bank.len() as u64).sum();
    for_sparse_blocks::<T, _>(in_tiles, out_chunk, first, s, plane, block, |xs, acc, b| {
        if let Some(c) = traffic {
            // every BankedWeights cycle-set streams once per resident block
            c.add_weights(nnz * complex_bytes::<T>());
            c.add_psums(nnz * b as u64 * complex_bytes::<T>());
        }
        for mi in 0..bw.cin {
            for g in 0..bw.num_groups {
                let st = &bw.streams[g * bw.cin + mi];
                for c in 0..st.cycle_ptr.len() - 1 {
                    for e in st.cycle_ptr[c] as usize..st.cycle_ptr[c + 1] as usize {
                        let ni = st.chan[e] as usize;
                        let fi = st.fi[e] as usize;
                        let (bk, sl) = (st.bank[e] as usize, st.slot[e] as usize);
                        let (wr, wi) =
                            (T::from_f32(bw.bank_re[bk][sl]), T::from_f32(bw.bank_im[bk][sl]));
                        let x = (mi * fs + fi) * b;
                        let (xr, xi) = (&xs.re[x..x + b], &xs.im[x..x + b]);
                        let a = (ni * fs + fi) * b;
                        let (ar, ai) = (&mut acc.re[a..a + b], &mut acc.im[a..a + b]);
                        for bi in 0..b {
                            ar[bi] += xr[bi] * wr - xi[bi] * wi;
                            ai[bi] += xr[bi] * wi + xi[bi] * wr;
                        }
                    }
                }
            }
        }
    });
}

/// Split-complex (SoA) spectra for one resident block, batch axis
/// innermost: element `(chan, fi)` of resident tile `bi` lives at
/// `(chan·F' + fi)·b + bi`. Keeping re/im in separate flat arrays makes
/// the MAC inner loop a pair of unit-stride real FMA streams.
struct SoaSpectra<T> {
    re: Vec<T>,
    im: Vec<T>,
}

/// Shared block frame of the sparse paths: process the chunk's tiles in
/// blocks of up to `block` resident spectra — (r)FFT the block's input
/// channels and transpose them into the batch-innermost SoA scratch, run
/// `mac(xs, acc, b)` to fill the block's output spectra, then inverse-FFT
/// into the chunk. Keeping the frame in one place guarantees the scheduled
/// and unscheduled MACs see byte-identical inputs and write through
/// identical drains, so the only thing that can differ between them is the
/// MAC walk itself. The SoA transposes copy values bit-for-bit, and the
/// per-slot contribution order of both MACs is unchanged from the
/// historical AoS walk — so the (f32, full-plane) outputs are too.
fn for_sparse_blocks<T: Float, F>(
    in_tiles: &[f32],
    out_chunk: &mut [f32],
    first: usize,
    s: Shape,
    plane: Plane,
    block: usize,
    mut mac: F,
) where
    F: FnMut(&SoaSpectra<T>, &mut SoaSpectra<T>, usize),
{
    let (m, n, k) = (s.cin, s.cout, s.fft);
    let f = k * k;
    let fs = plane.spectrum_len(k);
    let len = out_chunk.len() / (n * f);
    let block = block.clamp(1, len.max(1));
    let mut xs = SoaSpectra { re: vec![T::ZERO; block * m * fs], im: vec![T::ZERO; block * m * fs] };
    let mut acc =
        SoaSpectra { re: vec![T::ZERO; block * n * fs], im: vec![T::ZERO; block * n * fs] };
    let mut spec = vec![Cx::<T>::ZERO; fs];
    let mut real = vec![T::ZERO; f];
    let mut start = 0usize;
    while start < len {
        let b = block.min(len - start);
        for bi in 0..b {
            let ti = first + start + bi;
            let src = &in_tiles[ti * m * f..(ti + 1) * m * f];
            for mi in 0..m {
                let chan = &src[mi * f..(mi + 1) * f];
                match plane {
                    Plane::Full => {
                        for (p, &v) in spec.iter_mut().zip(chan) {
                            *p = Cx::new(T::from_f32(v), T::ZERO);
                        }
                        fft2d_inplace(&mut spec, k);
                    }
                    Plane::Half => {
                        for (p, &v) in real.iter_mut().zip(chan) {
                            *p = T::from_f32(v);
                        }
                        rfft2d_into(&real, k, &mut spec);
                    }
                }
                for (fi, c) in spec.iter().enumerate() {
                    xs.re[(mi * fs + fi) * b + bi] = c.re;
                    xs.im[(mi * fs + fi) * b + bi] = c.im;
                }
            }
        }
        for v in acc.re[..b * n * fs].iter_mut() {
            *v = T::ZERO;
        }
        for v in acc.im[..b * n * fs].iter_mut() {
            *v = T::ZERO;
        }
        mac(&xs, &mut acc, b);
        for bi in 0..b {
            let ti = start + bi;
            for ni in 0..n {
                for (fi, c) in spec.iter_mut().enumerate() {
                    *c = Cx::new(acc.re[(ni * fs + fi) * b + bi], acc.im[(ni * fs + fi) * b + bi]);
                }
                let dst = &mut out_chunk[(ti * n + ni) * f..(ti * n + ni + 1) * f];
                match plane {
                    Plane::Full => {
                        ifft2d_inplace(&mut spec, k);
                        for (o, c) in dst.iter_mut().zip(spec.iter()) {
                            *o = c.re.to_f32();
                        }
                    }
                    Plane::Half => {
                        irfft2d_into(&spec, k, &mut real);
                        for (o, &v) in dst.iter_mut().zip(real.iter()) {
                            *o = v.to_f32();
                        }
                    }
                }
            }
        }
        start += b;
    }
}

impl SpectralBackend for InterpBackend {
    fn name(&self) -> String {
        "interp".to_string()
    }

    fn prepare(&mut self, file: &str, meta: &ExecutableEntry, _artifacts_dir: &Path)
        -> Result<()> {
        if !meta.fft_size.is_power_of_two() {
            return Err(err!("{file}: FFT size {} is not a power of two", meta.fft_size));
        }
        self.shapes.insert(
            file.to_string(),
            Shape { tiles: meta.tiles, cin: meta.cin, cout: meta.cout, fft: meta.fft_size },
        );
        Ok(())
    }

    fn configure_numerics(&mut self, dtype: Dtype, plane: Plane) -> Result<bool> {
        // weights fold at upload time against the configured plane, so a
        // mode flip after uploads would silently mix layouts
        if !self.weights.is_empty() {
            return Err(err!("configure_numerics must precede weight uploads"));
        }
        self.dtype = dtype;
        self.plane = plane;
        Ok(true)
    }

    fn upload_weights(&mut self, re: &[f32], im: &[f32], dims: [usize; 3]) -> Result<WeightId> {
        let want = dims[0] * dims[1] * dims[2];
        if re.len() != want || im.len() != want {
            return Err(err!(
                "weight planes {}x{} don't match dims {dims:?} (= {want} elements)",
                re.len(),
                im.len()
            ));
        }
        // the upload interface always speaks full-plane [K², M, N]; in
        // half-plane mode the backend conjugate-folds at upload so the MAC
        // streams only K·(K/2+1) coefficients per (m, n) pair
        let store = match self.plane {
            Plane::Full => WeightPlanes { re: re.to_vec(), im: im.to_vec(), dims },
            Plane::Half => {
                let fft = fft_from_k2(dims[0])?;
                let (fre, fim) = fold_freq_major_half(re, im, fft, dims[1], dims[2]);
                WeightPlanes {
                    re: fre,
                    im: fim,
                    dims: [Plane::Half.spectrum_len(fft), dims[1], dims[2]],
                }
            }
        };
        self.weights.push(WeightStore::Dense(store));
        Ok(self.weights.len() - 1)
    }

    fn upload_sparse(&mut self, layer: &SparseLayer) -> Result<WeightId> {
        if !layer.fft.is_power_of_two() {
            return Err(err!("sparse layer FFT size {} is not a power of two", layer.fft));
        }
        // validate like upload_weights does: SparseLayer fields are pub, so
        // a hand-built layer can carry out-of-plane indices that would
        // otherwise read a neighboring channel's spectrum in the MAC
        let k2 = layer.k2();
        if layer.kernels.len() != layer.cout * layer.cin {
            return Err(err!(
                "sparse layer has {} kernels, expected {}×{}",
                layer.kernels.len(),
                layer.cout,
                layer.cin
            ));
        }
        for kern in &layer.kernels {
            if kern.indices.len() != kern.values.len() {
                return Err(err!("sparse kernel indices/values length mismatch"));
            }
            if let Some(&top) = kern.indices.iter().max() {
                if top as usize >= k2 {
                    return Err(err!("sparse kernel index {top} out of K²={k2}"));
                }
            }
        }
        let planes = SparseWeightPlanes::from_layer(layer);
        let planes = match self.plane {
            Plane::Full => planes,
            // same fold the engine applies when it builds the layer's
            // Alg. 2 plan, so plan validation and the MAC agree row for row
            Plane::Half => planes.fold_half_plane(layer.fft),
        };
        self.weights.push(WeightStore::Sparse(planes));
        Ok(self.weights.len() - 1)
    }

    fn set_sparse_dataflow(&mut self, file: &str, flow: SparseDataflow) -> Result<()> {
        self.flows.insert(file.to_string(), flow);
        Ok(())
    }

    fn attach_traffic(&mut self, counters: Arc<TrafficCounters>) -> bool {
        self.traffic = Some(counters);
        true
    }

    fn set_schedule(&mut self, wid: WeightId, plan: &LayerSchedule) -> Result<bool> {
        let store = self
            .weights
            .get(wid)
            .ok_or_else(|| err!("weight handle {wid} unknown"))?;
        let w = match store {
            WeightStore::Sparse(w) => w,
            WeightStore::Dense(_) => {
                return Err(err!("set_schedule needs a sparse upload (weight {wid} is dense)"))
            }
        };
        // compile eagerly: plan/weight mismatches surface at startup, and
        // the request path stays allocation- and validation-free
        let compiled = compile_schedule(plan, w)?;
        self.scheduled.insert(wid, compiled);
        Ok(true)
    }

    fn run_conv(&mut self, file: &str, tiles: &Tensor, wid: WeightId) -> Result<Tensor> {
        let s = *self
            .shapes
            .get(file)
            .ok_or_else(|| err!("{file} not prepared (warm the variant first)"))?;
        let (t, n, k) = (s.tiles, s.cout, s.fft);
        let want_in = [t, s.cin, k, k];
        if tiles.shape() != want_in {
            return Err(err!(
                "input tiles shape {:?} != executable shape {:?}",
                tiles.shape(),
                want_in
            ));
        }
        let mut out = Tensor::zeros(&[t, n, k, k]);
        self.conv_tiles(file, s, t, tiles.data(), out.data_mut(), wid)?;
        Ok(out)
    }

    fn run_conv_batch(
        &mut self,
        file: &str,
        tiles: &[Tensor],
        wid: WeightId,
    ) -> Result<Vec<Tensor>> {
        if tiles.is_empty() {
            return Ok(Vec::new());
        }
        let s = *self
            .shapes
            .get(file)
            .ok_or_else(|| err!("{file} not prepared (warm the variant first)"))?;
        let (t, m, n, k) = (s.tiles, s.cin, s.cout, s.fft);
        let f = k * k;
        let want_in = [t, m, k, k];
        for (bi, img) in tiles.iter().enumerate() {
            if img.shape() != want_in {
                return Err(err!(
                    "batch image {bi}: input tiles shape {:?} != executable shape {:?}",
                    img.shape(),
                    want_in
                ));
            }
        }
        // batch-major: concatenate the B images' tiles into one [B·T]
        // population so the resident blocks — and with them each kernel
        // row / cycle-set read — span images. Per-tile arithmetic is
        // independent of the blocking, so this is bit-identical to B
        // per-image run_conv calls.
        let b = tiles.len();
        let mut td = Vec::with_capacity(b * t * m * f);
        for img in tiles {
            td.extend_from_slice(img.data());
        }
        let mut od = vec![0.0f32; b * t * n * f];
        self.conv_tiles(file, s, b * t, &td, &mut od, wid)?;
        Ok(od
            .chunks(t * n * f)
            .map(|c| Tensor::from_vec(&[t, n, k, k], c.to_vec()))
            .collect())
    }

    fn prepared(&self) -> usize {
        self.shapes.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::{fft2d, ifft2d, spectral_kernels, Complex};
    use crate::runtime::freq_major_planes;
    use crate::util::check::{assert_allclose, forall};
    use crate::util::rng::Pcg32;

    fn entry(tiles: usize, cin: usize, cout: usize, fft: usize) -> ExecutableEntry {
        ExecutableEntry { tiles, cin, cout, fft_size: fft, sha256: "t".into(), bytes: 0 }
    }

    /// Reference: per-tile dense Hadamard pipeline written independently of
    /// the backend's loop structure.
    fn reference_conv(tiles: &Tensor, planes: &crate::tensor::ComplexTensor, fft: usize)
        -> Tensor {
        let (t, m) = (tiles.shape()[0], tiles.shape()[1]);
        let n = planes.shape()[0];
        let f = fft * fft;
        let mut out = Tensor::zeros(&[t, n, fft, fft]);
        for ti in 0..t {
            let xs: Vec<Vec<Complex>> = (0..m)
                .map(|mi| {
                    let p: Vec<Complex> = (0..f)
                        .map(|i| Complex::new(tiles.at(&[ti, mi, i / fft, i % fft]), 0.0))
                        .collect();
                    fft2d(&p, fft)
                })
                .collect();
            for ni in 0..n {
                let mut acc = vec![Complex::ZERO; f];
                for (mi, x) in xs.iter().enumerate() {
                    for i in 0..f {
                        let (wr, wi) = planes.at(&[ni, mi, i / fft, i % fft]);
                        acc[i] = acc[i].add(x[i].mul(Complex::new(wr, wi)));
                    }
                }
                for (i, c) in ifft2d(&acc, fft).iter().enumerate() {
                    out.set(&[ti, ni, i / fft, i % fft], c.re);
                }
            }
        }
        out
    }

    #[test]
    fn matches_dense_hadamard_reference() {
        forall("interp == dense hadamard", 10, |rng| {
            let (t, m, n, fft) = (rng.range(1, 4), rng.range(1, 4), rng.range(1, 4), 8);
            let tiles = Tensor::randn(&[t, m, fft, fft], rng, 1.0);
            let spatial = Tensor::randn(&[n, m, 3, 3], rng, 0.3);
            let planes = spectral_kernels(&spatial, fft);
            let (re, im) = freq_major_planes(&planes);

            let mut b = InterpBackend::new();
            b.prepare("x", &entry(t, m, n, fft), Path::new(".")).unwrap();
            let wid = b.upload_weights(&re, &im, [fft * fft, m, n]).unwrap();
            let got = b.run_conv("x", &tiles, wid).unwrap();
            let want = reference_conv(&tiles, &planes, fft);
            assert_allclose(got.data(), want.data(), 1e-4, 1e-4);
        });
    }

    #[test]
    fn rejects_shape_mismatches() {
        let mut rng = Pcg32::new(1);
        let mut b = InterpBackend::new();
        b.prepare("x", &entry(2, 1, 1, 8), Path::new(".")).unwrap();
        let wid = b.upload_weights(&[0.0; 64], &[0.0; 64], [64, 1, 1]).unwrap();
        // wrong tile count
        let bad = Tensor::randn(&[3, 1, 8, 8], &mut rng, 1.0);
        assert!(b.run_conv("x", &bad, wid).is_err());
        // unknown executable
        let ok = Tensor::randn(&[2, 1, 8, 8], &mut rng, 1.0);
        assert!(b.run_conv("y", &ok, wid).is_err());
        // bad weight handle
        assert!(b.run_conv("x", &ok, wid + 7).is_err());
        // bad weight dims at upload
        assert!(b.upload_weights(&[0.0; 3], &[0.0; 3], [64, 1, 1]).is_err());
    }

    #[test]
    fn threaded_matches_serial_bit_for_bit() {
        // tiles are independent and the per-tile arithmetic identical, so
        // any thread count must reproduce the serial output exactly —
        // including thread counts that don't divide the tile count and
        // counts larger than it.
        let mut rng = Pcg32::new(9);
        let (t, m, n, fft) = (7, 3, 4, 8);
        let tiles = Tensor::randn(&[t, m, fft, fft], &mut rng, 1.0);
        let spatial = Tensor::randn(&[n, m, 3, 3], &mut rng, 0.3);
        let planes = spectral_kernels(&spatial, fft);
        let (re, im) = freq_major_planes(&planes);
        let run = |threads: usize| {
            let mut b = InterpBackend::with_threads(threads);
            b.prepare("x", &entry(t, m, n, fft), Path::new(".")).unwrap();
            let wid = b.upload_weights(&re, &im, [fft * fft, m, n]).unwrap();
            b.run_conv("x", &tiles, wid).unwrap()
        };
        let serial = run(1);
        for threads in [2, 3, 4, 16] {
            let par = run(threads);
            assert_eq!(par.data(), serial.data(), "threads={threads} diverged");
        }
    }

    #[test]
    fn prepare_is_idempotent() {
        let mut b = InterpBackend::new();
        b.prepare("x", &entry(1, 1, 1, 8), Path::new(".")).unwrap();
        b.prepare("x", &entry(1, 1, 1, 8), Path::new(".")).unwrap();
        assert_eq!(b.prepared(), 1);
    }

    #[test]
    fn sparse_matches_dense_with_explicit_zeros() {
        // The tentpole equivalence gate: the sparse MAC (only non-zeros
        // touched) must equal the dense MAC over the same planes with the
        // pruned slots as explicit zeros, at α ∈ {1, 4} (α=1 keeps every
        // index — the degenerate all-resident pattern).
        use crate::sparse::{prune_magnitude, prune_random};
        forall("sparse MAC == dense-with-zeros", 8, |rng| {
            let (t, m, n, fft) = (rng.range(1, 6), rng.range(1, 5), rng.range(1, 5), 8);
            let alpha = [1usize, 4][rng.range(0, 2)];
            let layer = if rng.range(0, 2) == 0 {
                prune_magnitude(n, m, fft, alpha, rng)
            } else {
                prune_random(n, m, fft, alpha, rng)
            };
            let tiles = Tensor::randn(&[t, m, fft, fft], rng, 1.0);
            let e = entry(t, m, n, fft);

            let mut dense = InterpBackend::new();
            dense.prepare("x", &e, Path::new(".")).unwrap();
            let (re, im) = freq_major_planes(&layer.to_dense_planes());
            let dw = dense.upload_weights(&re, &im, [fft * fft, m, n]).unwrap();
            let want = dense.run_conv("x", &tiles, dw).unwrap();

            let mut sparse = InterpBackend::new();
            sparse.prepare("x", &e, Path::new(".")).unwrap();
            let sw = sparse.upload_sparse(&layer).unwrap();
            let got = sparse.run_conv("x", &tiles, sw).unwrap();

            assert_allclose(got.data(), want.data(), 1e-5, 1e-5);
        });
    }

    #[test]
    fn sparse_bit_identical_across_blocks_and_threads() {
        // Block size (the Ps analogue) and thread count partition work but
        // never reorder per-tile arithmetic: outputs must be bit-for-bit
        // equal in every configuration.
        use crate::sparse::prune_magnitude;
        let mut rng = Pcg32::new(21);
        let (t, m, n, fft) = (7, 3, 5, 8);
        let layer = prune_magnitude(n, m, fft, 4, &mut rng);
        let tiles = Tensor::randn(&[t, m, fft, fft], &mut rng, 1.0);
        let run = |threads: usize, block: usize| {
            let mut b = InterpBackend::with_threads(threads);
            b.prepare("x", &entry(t, m, n, fft), Path::new(".")).unwrap();
            b.set_sparse_dataflow("x", SparseDataflow { tile_block: block }).unwrap();
            let wid = b.upload_sparse(&layer).unwrap();
            b.run_conv("x", &tiles, wid).unwrap()
        };
        let baseline = run(1, 1);
        for threads in [1usize, 2, 3, 16] {
            for block in [1usize, 2, 3, 7, 100] {
                let got = run(threads, block);
                assert_eq!(
                    got.data(),
                    baseline.data(),
                    "threads={threads} block={block} diverged"
                );
            }
        }
    }

    #[test]
    fn scheduled_bit_identical_to_unscheduled_sparse() {
        // THE tentpole gate: executing in Alg. 2 schedule order (either
        // policy) must reproduce the storage-order sparse walk bit for bit,
        // across block sizes and thread counts.
        use crate::schedule::SchedulePolicy;
        use crate::sparse::{prune_magnitude, prune_random};
        forall("scheduled == unscheduled", 6, |rng| {
            let (t, m, n, fft) = (rng.range(1, 6), rng.range(1, 5), rng.range(2, 7), 8);
            let alpha = [2usize, 4][rng.range(0, 2)];
            let layer = if rng.range(0, 2) == 0 {
                prune_magnitude(n, m, fft, alpha, rng)
            } else {
                prune_random(n, m, fft, alpha, rng)
            };
            let tiles = Tensor::randn(&[t, m, fft, fft], rng, 1.0);
            let e = entry(t, m, n, fft);
            let planes = SparseWeightPlanes::from_layer(&layer);
            let run = |policy: Option<SchedulePolicy>, threads: usize, block: usize| {
                let mut b = InterpBackend::with_threads(threads);
                b.prepare("x", &e, Path::new(".")).unwrap();
                b.set_sparse_dataflow("x", SparseDataflow { tile_block: block }).unwrap();
                let wid = b.upload_sparse(&layer).unwrap();
                if let Some(p) = policy {
                    let plan =
                        crate::schedule::LayerSchedule::build(&planes, 4, 3, 8, p).unwrap();
                    b.set_schedule(wid, &plan).unwrap();
                }
                b.run_conv("x", &tiles, wid).unwrap()
            };
            let baseline = run(None, 1, 1);
            for policy in [SchedulePolicy::ExactCover, SchedulePolicy::LowestIndex] {
                for (threads, block) in [(1, 1), (2, 3), (3, 100)] {
                    let got = run(Some(policy), threads, block);
                    assert_eq!(
                        got.data(),
                        baseline.data(),
                        "{policy:?} threads={threads} block={block} diverged"
                    );
                }
            }
        });
    }

    #[test]
    fn batched_conv_bit_identical_to_per_image() {
        // The batch-major tentpole gate at the backend level: fusing B
        // images into one tile population must reproduce B independent
        // run_conv calls bit for bit — dense, sparse, and scheduled, for
        // every thread count and tile_block (including blocks that span
        // image boundaries and blocks larger than the whole batch).
        use crate::schedule::SchedulePolicy;
        use crate::sparse::prune_magnitude;
        let mut rng = Pcg32::new(33);
        let (t, m, n, fft) = (5, 3, 4, 8);
        let e = entry(t, m, n, fft);
        let batch: Vec<Tensor> =
            (0..4).map(|_| Tensor::randn(&[t, m, fft, fft], &mut rng, 1.0)).collect();
        let layer = prune_magnitude(n, m, fft, 4, &mut rng);
        let planes = SparseWeightPlanes::from_layer(&layer);
        let (re, im) = freq_major_planes(&layer.to_dense_planes());

        #[derive(Clone, Copy)]
        enum Mode {
            Dense,
            Sparse,
            Scheduled(SchedulePolicy),
        }
        let build = |mode: Mode, threads: usize, block: usize| {
            let mut b = InterpBackend::with_threads(threads);
            b.prepare("x", &e, Path::new(".")).unwrap();
            b.set_sparse_dataflow("x", SparseDataflow { tile_block: block }).unwrap();
            let wid = match mode {
                Mode::Dense => b.upload_weights(&re, &im, [fft * fft, m, n]).unwrap(),
                Mode::Sparse => b.upload_sparse(&layer).unwrap(),
                Mode::Scheduled(p) => {
                    let wid = b.upload_sparse(&layer).unwrap();
                    let plan = LayerSchedule::build(&planes, 4, 3, 8, p).unwrap();
                    b.set_schedule(wid, &plan).unwrap();
                    wid
                }
            };
            (b, wid)
        };
        for mode in [
            Mode::Dense,
            Mode::Sparse,
            Mode::Scheduled(SchedulePolicy::ExactCover),
            Mode::Scheduled(SchedulePolicy::LowestIndex),
        ] {
            for (threads, block) in [(1usize, 1usize), (2, 3), (3, 7), (1, 20), (16, 100)] {
                let (mut be, wid) = build(mode, threads, block);
                let want: Vec<Tensor> =
                    batch.iter().map(|img| be.run_conv("x", img, wid).unwrap()).collect();
                let got = be.run_conv_batch("x", &batch, wid).unwrap();
                assert_eq!(got.len(), want.len());
                for (bi, (g, w)) in got.iter().zip(&want).enumerate() {
                    assert_eq!(g.shape(), w.shape());
                    assert_eq!(
                        g.data(),
                        w.data(),
                        "image {bi} diverged (threads={threads} block={block})"
                    );
                }
            }
        }
        // empty batch is defined and empty
        let (mut be, wid) = build(Mode::Sparse, 1, 1);
        assert!(be.run_conv_batch("x", &[], wid).unwrap().is_empty());
        // a mis-shaped image anywhere in the batch rejects the whole call
        let bad = Tensor::zeros(&[t + 1, m, fft, fft]);
        let mixed = vec![batch[0].clone(), bad];
        assert!(be.run_conv_batch("x", &mixed, wid).is_err());
    }

    #[test]
    fn set_schedule_rejects_dense_and_foreign_plans() {
        use crate::schedule::{LayerSchedule, SchedulePolicy};
        use crate::sparse::prune_random;
        let mut rng = Pcg32::new(40);
        let layer = prune_random(4, 2, 8, 4, &mut rng);
        let other = prune_random(4, 2, 8, 4, &mut rng);
        let planes = SparseWeightPlanes::from_layer(&layer);
        let foreign = SparseWeightPlanes::from_layer(&other);
        let plan = LayerSchedule::build(&planes, 4, 3, 8, SchedulePolicy::ExactCover).unwrap();
        let bad = LayerSchedule::build(&foreign, 4, 3, 8, SchedulePolicy::ExactCover).unwrap();

        let mut b = InterpBackend::new();
        b.prepare("x", &entry(2, 2, 4, 8), Path::new(".")).unwrap();
        let wid = b.upload_sparse(&layer).unwrap();
        // plan built from different weights must be rejected at attach time
        assert!(b.set_schedule(wid, &bad).is_err());
        // unknown handle / dense upload rejected
        assert!(b.set_schedule(wid + 7, &plan).is_err());
        let (re, im) = freq_major_planes(&layer.to_dense_planes());
        let dense = b.upload_weights(&re, &im, [64, 2, 4]).unwrap();
        assert!(b.set_schedule(dense, &plan).is_err());
        // and the good plan attaches cleanly, reporting native execution
        assert!(b.set_schedule(wid, &plan).unwrap());
    }

    #[test]
    fn half_plane_matches_full_plane_all_paths() {
        // The half-plane equivalence gate at the backend level, for every
        // execution path (dense / sparse / both schedulers), a symmetric
        // and an asymmetric pruning, and both dtypes:
        //   * f32-half vs f32-full within FFT round-off,
        //   * f64-half vs f64-full ≤ 1e-12 (the ISSUE pin: at f64 the two
        //     plane paths differ by ~1e-15 relative before the f32 layer
        //     boundary, so they round to the same f32 with overwhelming
        //     probability — this asserts they did),
        //   * f32 vs the f64 reference to single-precision accuracy.
        use crate::schedule::SchedulePolicy;
        use crate::sparse::{prune_magnitude, prune_random};
        let mut rng = Pcg32::new(51);
        let (t, m, n, fft) = (5, 3, 4, 8);
        let e = entry(t, m, n, fft);
        let tiles = Tensor::randn(&[t, m, fft, fft], &mut rng, 1.0);
        let layers =
            [prune_magnitude(n, m, fft, 4, &mut rng), prune_random(n, m, fft, 4, &mut rng)];
        #[derive(Clone, Copy)]
        enum Mode {
            Dense,
            Sparse,
            Sched(SchedulePolicy),
        }
        for layer in &layers {
            let (re, im) = freq_major_planes(&layer.to_dense_planes());
            let planes_full = SparseWeightPlanes::from_layer(layer);
            let planes_half = planes_full.fold_half_plane(fft);
            let run = |mode: Mode, dtype: Dtype, plane: Plane, threads: usize| {
                let mut b = InterpBackend::with_config(threads, dtype, plane);
                b.prepare("x", &e, Path::new(".")).unwrap();
                b.set_sparse_dataflow("x", SparseDataflow { tile_block: 3 }).unwrap();
                let wid = match mode {
                    Mode::Dense => b.upload_weights(&re, &im, [fft * fft, m, n]).unwrap(),
                    Mode::Sparse => b.upload_sparse(layer).unwrap(),
                    Mode::Sched(p) => {
                        let wid = b.upload_sparse(layer).unwrap();
                        let src =
                            if plane == Plane::Half { &planes_half } else { &planes_full };
                        let plan = LayerSchedule::build(src, 4, 3, 8, p).unwrap();
                        b.set_schedule(wid, &plan).unwrap();
                        wid
                    }
                };
                b.run_conv("x", &tiles, wid).unwrap()
            };
            for mode in [
                Mode::Dense,
                Mode::Sparse,
                Mode::Sched(SchedulePolicy::ExactCover),
                Mode::Sched(SchedulePolicy::LowestIndex),
            ] {
                let full = run(mode, Dtype::F32, Plane::Full, 1);
                let half = run(mode, Dtype::F32, Plane::Half, 2);
                assert_allclose(half.data(), full.data(), 1e-4, 1e-4);
                let full64 = run(mode, Dtype::F64, Plane::Full, 1);
                let half64 = run(mode, Dtype::F64, Plane::Half, 2);
                for (a, b) in full64.data().iter().zip(half64.data()) {
                    assert!((a - b).abs() <= 1e-12, "f64 half diverged: {a} vs {b}");
                }
                assert_allclose(full.data(), full64.data(), 1e-3, 1e-3);
            }
            // in half-plane mode the scheduled walk must still be
            // bit-identical to the unscheduled CSR walk
            let sp = run(Mode::Sparse, Dtype::F32, Plane::Half, 1);
            for policy in [SchedulePolicy::ExactCover, SchedulePolicy::LowestIndex] {
                let sc = run(Mode::Sched(policy), Dtype::F32, Plane::Half, 3);
                assert_eq!(sp.data(), sc.data(), "{policy:?} diverged on the half-plane");
            }
        }
    }

    #[test]
    fn numerics_config_guards() {
        let mut b = InterpBackend::new();
        b.prepare("x", &entry(1, 1, 1, 8), Path::new(".")).unwrap();
        assert!(b.configure_numerics(Dtype::F64, Plane::Half).unwrap());
        let wid = b.upload_weights(&[0.0; 64], &[0.0; 64], [64, 1, 1]).unwrap();
        // mode is locked once weights exist (they folded at upload)
        assert!(b.configure_numerics(Dtype::F32, Plane::Full).is_err());
        // the folded zero planes still execute (to zero output)
        let mut rng = Pcg32::new(2);
        let tiles = Tensor::randn(&[1, 1, 8, 8], &mut rng, 1.0);
        let out = b.run_conv("x", &tiles, wid).unwrap();
        assert!(out.data().iter().all(|&v| v == 0.0));
        assert_eq!(fft_from_k2(64).unwrap(), 8);
        assert_eq!(fft_from_k2(256).unwrap(), 16);
        assert!(fft_from_k2(32).is_err());
        assert!(fft_from_k2(63).is_err());
    }

    #[test]
    fn sparse_rejects_dim_mismatch() {
        use crate::sparse::prune_random;
        let mut rng = Pcg32::new(6);
        let layer = prune_random(2, 3, 8, 4, &mut rng); // dims [64, 3, 2]
        let mut b = InterpBackend::new();
        b.prepare("x", &entry(2, 1, 1, 8), Path::new(".")).unwrap();
        let wid = b.upload_sparse(&layer).unwrap();
        let tiles = Tensor::randn(&[2, 1, 8, 8], &mut rng, 1.0);
        assert!(b.run_conv("x", &tiles, wid).is_err(), "shape mismatch must be caught");
    }

    #[test]
    fn sparse_upload_rejects_out_of_plane_indices() {
        use crate::sparse::prune_random;
        let mut rng = Pcg32::new(7);
        let mut layer = prune_random(2, 2, 8, 4, &mut rng);
        layer.kernels[1].indices[0] = 64; // K²=64 ⇒ valid indices are 0..64
        let mut b = InterpBackend::new();
        assert!(b.upload_sparse(&layer).is_err(), "index ≥ K² must be rejected at upload");
    }

    #[test]
    fn traffic_counters_measure_block_walk_and_stay_bit_invisible() {
        use crate::obs::TrafficSnapshot;
        use crate::sparse::prune_magnitude;
        use std::sync::Arc;
        let mut rng = Pcg32::new(61);
        let (t, m, n, fft) = (7usize, 3usize, 5usize, 8usize);
        let layer = prune_magnitude(n, m, fft, 4, &mut rng);
        let tiles = Tensor::randn(&[t, m, fft, fft], &mut rng, 1.0);
        let nnz = layer.nnz() as u64; // n·m·K²/α = 5·3·16 = 240
        assert_eq!(nnz, 240);

        let run = |attach: bool, block: usize| {
            let mut b = InterpBackend::new();
            b.prepare("x", &entry(t, m, n, fft), Path::new(".")).unwrap();
            b.set_sparse_dataflow("x", SparseDataflow { tile_block: block }).unwrap();
            let wid = b.upload_sparse(&layer).unwrap();
            let counters = Arc::new(TrafficCounters::new());
            if attach {
                assert!(b.attach_traffic(Arc::clone(&counters)));
            }
            (b.run_conv("x", &tiles, wid).unwrap(), counters.snapshot())
        };

        // attaching counters must not change a single output bit
        let (plain, zero) = run(false, 3);
        let (counted, snap) = run(true, 3);
        assert_eq!(plain.data(), counted.data());
        assert_eq!(zero, TrafficSnapshot::default(), "unattached counters stay zero");

        // block=3 over 7 tiles ⇒ 3 kernel-stream walks; one accumulator
        // update per non-zero per resident tile; activations at the
        // backend boundary (spatial f32 words)
        let f = (fft * fft) as u64;
        assert_eq!(snap.weight_bytes, 3 * nnz * 8);
        assert_eq!(snap.psum_bytes, nnz * t as u64 * 8);
        assert_eq!(snap.input_bytes, (t * m) as u64 * f * 4);
        assert_eq!(snap.output_bytes, (t * n) as u64 * f * 4);
        assert_eq!(snap.arena_bytes, 0, "the backend never touches arena traffic");

        // all-resident block ⇒ the kernel stream is read exactly once
        let (_, one) = run(true, 100);
        assert_eq!(one.weight_bytes, nnz * 8);

        // dense path: full [F, M, N] plane per tile
        let mut b = InterpBackend::new();
        b.prepare("x", &entry(t, m, n, fft), Path::new(".")).unwrap();
        let (re, im) = freq_major_planes(&layer.to_dense_planes());
        let wid = b.upload_weights(&re, &im, [fft * fft, m, n]).unwrap();
        let counters = Arc::new(TrafficCounters::new());
        assert!(b.attach_traffic(Arc::clone(&counters)));
        b.run_conv("x", &tiles, wid).unwrap();
        let dense = counters.snapshot();
        assert_eq!(dense.weight_bytes, (t * fft * fft * m * n) as u64 * 8);
        assert_eq!(dense.psum_bytes, dense.weight_bytes);
    }
}
