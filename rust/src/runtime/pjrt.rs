//! PJRT backend (behind the `pjrt` cargo feature): load AOT-compiled HLO
//! artifacts and execute them through the `xla` crate.
//!
//! Wraps `PjRtClient::cpu()` → `HloModuleProto::from_text_file` → `compile`
//! → `execute`. One compiled executable per layer *shape* (the manifest's
//! dedup keys); compilation happens once at engine startup and executables
//! are cached for the life of the process — Python never runs on this path.
//! Weights are uploaded once as device buffers (§Perf L3: the per-call
//! `Literal` conversion of a 512×512×8×8 kernel plane pair costs ~0.5 s).
//!
//! NOTE: the `xla` crate is not in the offline registry. Building with
//! `--features pjrt` requires adding the dependency to `rust/Cargo.toml`
//! (see README.md "Backends").

use std::collections::HashMap;
use std::path::Path;

use crate::err;
use crate::tensor::Tensor;
use crate::util::error::Result;

use super::{ExecutableEntry, SpectralBackend, WeightId};

/// A compiled spectral-conv executable for one (T, Cin, Cout, K) shape.
struct ConvExecutable {
    exe: xla::PjRtLoadedExecutable,
    tiles: usize,
    cin: usize,
    cout: usize,
    fft: usize,
}

/// The PJRT backend: client + executable cache + uploaded weight buffers.
pub struct PjrtBackend {
    client: xla::PjRtClient,
    cache: HashMap<String, ConvExecutable>,
    weights: Vec<(xla::PjRtBuffer, xla::PjRtBuffer)>,
}

impl PjrtBackend {
    pub fn new() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| err!("PjRtClient::cpu: {e:?}"))?;
        Ok(PjrtBackend { client, cache: HashMap::new(), weights: Vec::new() })
    }

    fn upload(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| err!("buffer upload: {e:?}"))
    }
}

impl SpectralBackend for PjrtBackend {
    fn name(&self) -> String {
        self.client.platform_name()
    }

    fn prepare(&mut self, file: &str, meta: &ExecutableEntry, artifacts_dir: &Path)
        -> Result<()> {
        if self.cache.contains_key(file) {
            return Ok(());
        }
        let path = artifacts_dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| err!("non-utf8 path"))?,
        )
        .map_err(|e| err!("loading {}: {e:?} — run `make artifacts` first", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| err!("compiling {file}: {e:?}"))?;
        self.cache.insert(
            file.to_string(),
            ConvExecutable {
                exe,
                tiles: meta.tiles,
                cin: meta.cin,
                cout: meta.cout,
                fft: meta.fft_size,
            },
        );
        Ok(())
    }

    fn upload_weights(&mut self, re: &[f32], im: &[f32], dims: [usize; 3]) -> Result<WeightId> {
        let w_re = self.upload(re, &dims)?;
        let w_im = self.upload(im, &dims)?;
        self.weights.push((w_re, w_im));
        Ok(self.weights.len() - 1)
    }

    fn run_conv(&mut self, file: &str, tiles: &Tensor, wid: WeightId) -> Result<Tensor> {
        let exe = self
            .cache
            .get(file)
            .ok_or_else(|| err!("{file} not prepared (warm the variant first)"))?;
        let (t, m, n, k) = (exe.tiles, exe.cin, exe.cout, exe.fft);
        let want_in = [t, m, k, k];
        if tiles.shape() != want_in {
            return Err(err!(
                "input tiles shape {:?} != executable shape {:?}",
                tiles.shape(),
                want_in
            ));
        }
        let tiles_buf = self.upload(tiles.data(), &want_in)?;
        let (w_re, w_im) = self
            .weights
            .get(wid)
            .ok_or_else(|| err!("weight handle {wid} unknown"))?;
        let result = exe
            .exe
            .execute_b::<&xla::PjRtBuffer>(&[&tiles_buf, w_re, w_im])
            .map_err(|e| err!("execute {file}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| err!("readback {file}: {e:?}"))?;
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple.
        let out = result.to_tuple1().map_err(|e| err!("untuple {file}: {e:?}"))?;
        let data = out.to_vec::<f32>().map_err(|e| err!("to_vec {file}: {e:?}"))?;
        Ok(Tensor::from_vec(&[t, n, k, k], data))
    }

    fn prepared(&self) -> usize {
        self.cache.len()
    }
}
