//! PJRT runtime: load AOT-compiled HLO artifacts and execute them.
//!
//! Wraps the `xla` crate (`PjRtClient::cpu()` → `HloModuleProto::
//! from_text_file` → `compile` → `execute`) following
//! /opt/xla-example/load_hlo. One compiled executable per layer *shape*
//! (the manifest's dedup keys); compilation happens once at engine startup
//! and executables are cached for the life of the process — Python never
//! runs on this path.

mod manifest;

pub use manifest::{LayerEntry, Manifest, VariantEntry};

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::tensor::{ComplexTensor, Tensor};

/// A compiled spectral-conv executable for one (T, Cin, Cout, K) shape.
pub struct ConvExecutable {
    exe: xla::PjRtLoadedExecutable,
    pub tiles: usize,
    pub cin: usize,
    pub cout: usize,
    pub fft: usize,
}

/// Host-side layout conversion: spectral kernel planes `[N, M, K, K]` →
/// frequency-major `[F, M, N]` (re, im) — the executable's weight layout.
/// Computed once per engine startup (§Perf L2: doing this transpose inside
/// the graph cost ~120 ms per request on 512×512 layers).
pub fn freq_major_planes(kernels: &ComplexTensor) -> (Vec<f32>, Vec<f32>) {
    let shape = kernels.shape();
    let (n, m, k) = (shape[0], shape[1], shape[2]);
    let f = k * shape[3];
    let mut re = vec![0.0f32; f * m * n];
    let mut im = vec![0.0f32; f * m * n];
    let (src_re, src_im) = (kernels.re.data(), kernels.im.data());
    for ni in 0..n {
        for mi in 0..m {
            let src = (ni * m + mi) * f;
            for fi in 0..f {
                let dst = (fi * m + mi) * n + ni;
                re[dst] = src_re[src + fi];
                im[dst] = src_im[src + fi];
            }
        }
    }
    (re, im)
}

impl ConvExecutable {
    /// One-shot execution: spatial input tiles `[T, Cin, K, K]` + spectral
    /// kernel planes `[Cout, Cin, K, K]` → spatial output tiles
    /// `[T, Cout, K, K]`. Converts the kernel layout per call; the serving
    /// hot path uses [`Self::run_buffers`] with pre-uploaded weights.
    pub fn run(&self, tiles: &Tensor, kernels: &ComplexTensor) -> Result<Tensor> {
        let k = self.fft;
        let want_in = [self.tiles, self.cin, k, k];
        let want_w = [self.cout, self.cin, k, k];
        if tiles.shape() != want_in {
            return Err(anyhow!(
                "input tiles shape {:?} != executable shape {:?}",
                tiles.shape(),
                want_in
            ));
        }
        if kernels.shape() != want_w {
            return Err(anyhow!(
                "kernel shape {:?} != executable shape {:?}",
                kernels.shape(),
                want_w
            ));
        }
        let dims: Vec<i64> = want_in.iter().map(|&d| d as i64).collect();
        let wdims = [(k * k) as i64, self.cin as i64, self.cout as i64];
        let (wre, wim) = freq_major_planes(kernels);
        let lit_tiles = xla::Literal::vec1(tiles.data()).reshape(&dims)?;
        let lit_wre = xla::Literal::vec1(&wre).reshape(&wdims)?;
        let lit_wim = xla::Literal::vec1(&wim).reshape(&wdims)?;
        let result = self
            .exe
            .execute::<xla::Literal>(&[lit_tiles, lit_wre, lit_wim])?[0][0]
            .to_literal_sync()?;
        self.unpack(result)
    }

    /// Hot-path execution with pre-uploaded device buffers (§Perf: the
    /// per-call `Literal` conversion of a 512×512×8×8 kernel plane pair
    /// costs ~0.5 s; weights are static, so the engine uploads them once
    /// and re-uses the `PjRtBuffer`s — see EXPERIMENTS.md §Perf L3).
    pub fn run_buffers(
        &self,
        tiles: &xla::PjRtBuffer,
        w_re: &xla::PjRtBuffer,
        w_im: &xla::PjRtBuffer,
    ) -> Result<Tensor> {
        let result = self
            .exe
            .execute_b::<&xla::PjRtBuffer>(&[tiles, w_re, w_im])?[0][0]
            .to_literal_sync()?;
        self.unpack(result)
    }

    fn unpack(&self, result: xla::Literal) -> Result<Tensor> {
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        let data = out.to_vec::<f32>()?;
        let k = self.fft;
        Ok(Tensor::from_vec(&[self.tiles, self.cout, k, k], data))
    }
}

/// The PJRT runtime: client + executable cache + manifest.
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    pub manifest: Manifest,
    cache: HashMap<String, ConvExecutable>,
}

impl Runtime {
    /// Open `artifacts/` (produced by `make artifacts`).
    pub fn open(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                manifest_path.display()
            )
        })?;
        let manifest = Manifest::parse(&text)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, artifacts_dir: dir, manifest, cache: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Upload an f32 host array to a device buffer (weights are uploaded
    /// once at engine startup and reused every request).
    pub fn upload(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Compile (or fetch from cache) the executable for an artifact file.
    pub fn conv_executable(&mut self, file: &str) -> Result<&ConvExecutable> {
        if !self.cache.contains_key(file) {
            let meta = self
                .manifest
                .executables
                .get(file)
                .ok_or_else(|| anyhow!("{file} not in manifest"))?;
            let path = self.artifacts_dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.cache.insert(
                file.to_string(),
                ConvExecutable {
                    exe,
                    tiles: meta.tiles,
                    cin: meta.cin,
                    cout: meta.cout,
                    fft: meta.fft_size,
                },
            );
        }
        Ok(&self.cache[file])
    }

    /// Pre-compile all executables of a variant (startup warm-up).
    pub fn warm_variant(&mut self, variant: &str) -> Result<usize> {
        let files: Vec<String> = self
            .manifest
            .variant(variant)?
            .layers
            .iter()
            .map(|l| l.file.clone())
            .collect();
        let mut compiled = 0;
        for f in files {
            self.conv_executable(&f)?;
            compiled += 1;
        }
        Ok(compiled)
    }

    pub fn cached_executables(&self) -> usize {
        self.cache.len()
    }
}
