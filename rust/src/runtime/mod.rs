//! Runtime layer: spectral-conv execution behind the [`SpectralBackend`]
//! trait.
//!
//! The coordinator drives one *executable* per layer shape (the manifest's
//! dedup keys). Two backends implement that contract:
//!
//! * `interp` ([`InterpBackend`], the default; pure Rust, zero deps) —
//!   executes the spectral
//!   pipeline directly: tile FFT → frequency-major MAC against the uploaded
//!   kernel planes → IFFT. Works with the synthesized built-in manifest, so
//!   the whole serving stack runs offline with no artifacts at all.
//! * `pjrt` (behind the off-by-default `pjrt` cargo feature) — loads
//!   AOT-compiled HLO artifacts (`make artifacts`) and executes them through
//!   the `xla` crate (`PjRtClient::cpu()` → `HloModuleProto::from_text_file`
//!   → `compile` → `execute`). Compilation happens once per shape at engine
//!   startup; weights are uploaded once as device buffers. The `xla` crate
//!   is not in the offline registry — see README.md "Backends" for how to
//!   enable it.
//!
//! Both backends consume the same host-side weight layout
//! ([`freq_major_planes`]) and the same manifest schema ([`Manifest`]),
//! so the engine, server, examples and tests are backend-agnostic.
//!
//! Pruned layers additionally have a **sparse** weight form
//! ([`SparseWeightPlanes`], CSR-like lists over the K² frequency plane):
//! [`SpectralBackend::upload_sparse`] hands a [`crate::sparse::SparseLayer`]
//! to the backend, which either executes it natively (interp's sparse MAC
//! iterates only the K²/α non-zeros) or densifies transparently (the
//! default, used by PJRT). [`SpectralBackend::set_sparse_dataflow`] threads
//! the per-layer streaming optimum of [`crate::dataflow`] (Alg. 1) into the
//! sparse hot loop — see [`SparseDataflow`].

mod interp;
mod manifest;
#[cfg(feature = "pjrt")]
mod pjrt;
mod sparse;

pub use interp::InterpBackend;
pub use manifest::{ExecutableEntry, LayerEntry, Manifest, VariantEntry};
pub use self::sparse::{SparseDataflow, SparseWeightPlanes};

use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::err;
use crate::obs::TrafficCounters;
use crate::schedule::LayerSchedule;
use crate::sparse::SparseLayer;
use crate::tensor::{ComplexTensor, Tensor};
use crate::util::error::{Context, Result};

/// Handle to one layer's uploaded weight planes (backend-owned storage).
pub type WeightId = usize;

/// Scalar precision the backend's spectral pipeline computes in.
///
/// Weights and layer boundaries (tile tensors, bias, ReLU) are f32 at rest
/// in every mode; the dtype selects the arithmetic of the FFT → MAC → IFFT
/// core. `F32` is the historical default (bit-identical to pre-dtype
/// builds); `F64` is the high-precision reference the equivalence pins
/// compare against.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Dtype {
    #[default]
    F32,
    F64,
}

impl Dtype {
    /// CLI/wire label (`"f32"` / `"f64"`).
    pub fn label(self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::F64 => "f64",
        }
    }

    /// Parse a CLI/manifest label.
    pub fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "f64" => Ok(Dtype::F64),
            _ => Err(err!("unknown dtype {s:?} (expected \"f32\" or \"f64\")")),
        }
    }
}

/// Spectral storage plane: the full K×K frequency plane, or the rfft2
/// half-plane `K×(K/2+1)` that exploits the Hermitian symmetry of real
/// tiles (see [`crate::fft::rfft2d`] and
/// [`SparseWeightPlanes::fold_half_plane`]). `Half` halves FFT work, the
/// weight store, and the scheduled MAC's bank reads.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Plane {
    #[default]
    Full,
    Half,
}

impl Plane {
    /// CLI/wire label (`"full"` / `"half"`).
    pub fn label(self) -> &'static str {
        match self {
            Plane::Full => "full",
            Plane::Half => "half",
        }
    }

    /// Parse a CLI label.
    pub fn parse(s: &str) -> Result<Plane> {
        match s {
            "full" => Ok(Plane::Full),
            "half" => Ok(Plane::Half),
            _ => Err(err!("unknown plane {s:?} (expected \"full\" or \"half\")")),
        }
    }

    /// Spectral coefficients per `fft×fft` tile in this plane.
    pub fn spectrum_len(self, fft: usize) -> usize {
        match self {
            Plane::Full => fft * fft,
            Plane::Half => crate::fft::half_plane_len(fft),
        }
    }
}

/// The spectral-conv execution contract.
///
/// An implementation owns per-shape executable state (keyed by manifest
/// file name) and per-layer weight uploads; the engine talks to it only in
/// terms of spatial tile tensors and frequency-major weight planes.
pub trait SpectralBackend {
    /// Human-readable backend/platform name (e.g. `"interp"`, `"cpu"`).
    fn name(&self) -> String;

    /// Register (and for PJRT: compile) the executable for one shape.
    /// Idempotent — re-preparing a known `file` is a no-op.
    fn prepare(&mut self, file: &str, meta: &ExecutableEntry, artifacts_dir: &Path)
        -> Result<()>;

    /// Upload frequency-major weight planes (layout of
    /// [`freq_major_planes`]: `[K², M, N]` re/im) and return a handle.
    fn upload_weights(&mut self, re: &[f32], im: &[f32], dims: [usize; 3]) -> Result<WeightId>;

    /// Upload one pruned layer's kernels in sparse form. Backends with a
    /// native sparse path (interp) keep the CSR lists and execute only the
    /// K²/α non-zeros; the default implementation densifies to explicit
    /// zeros and defers to [`Self::upload_weights`], so every backend
    /// accepts pruned layers and all of them compute the same values.
    fn upload_sparse(&mut self, layer: &SparseLayer) -> Result<WeightId> {
        let (re, im) = freq_major_planes(&layer.to_dense_planes());
        self.upload_weights(&re, &im, [layer.k2(), layer.cin, layer.cout])
    }

    /// Per-executable streaming hint for the sparse path (the Alg. 1
    /// optimum — see [`SparseDataflow`]). No-op by default: backends that
    /// densify have no kernel stream to block.
    fn set_sparse_dataflow(&mut self, _file: &str, _flow: SparseDataflow) -> Result<()> {
        Ok(())
    }

    /// Select the numeric mode for every subsequent upload/execution:
    /// scalar precision ([`Dtype`]) and spectral storage plane
    /// ([`Plane`]). Must be called before weights are uploaded. Returns
    /// whether the backend honours the request; the default accepts only
    /// the historical mode (f32 arithmetic over the full plane), so
    /// backends without a dtype axis (PJRT's AOT-compiled executables)
    /// decline non-default modes instead of silently computing something
    /// else.
    fn configure_numerics(&mut self, dtype: Dtype, plane: Plane) -> Result<bool> {
        Ok(dtype == Dtype::F32 && plane == Plane::Full)
    }

    /// Attach an Alg. 2 conflict-free access plan to a sparse weight
    /// upload: backends with a scheduled MAC (interp) compile it into their
    /// banked weight store, execute the layer in schedule order, and return
    /// `true`. Keyed by [`WeightId`] — not by executable file — because a
    /// schedule is a property of one layer's *non-zero pattern*, and
    /// shape-deduped executables are shared across layers with different
    /// patterns. Default: `Ok(false)` — densifying backends (PJRT) have no
    /// sparse walk to reorder, and the `false` tells the engine NOT to
    /// publish schedule metrics for an execution that never happens.
    fn set_schedule(&mut self, _wid: WeightId, _plan: &LayerSchedule) -> Result<bool> {
        Ok(false)
    }

    /// Attach data-movement counters ([`crate::obs::TrafficCounters`]) to
    /// the backend's hot loops. A backend that instruments its execution
    /// (interp) keeps the handle, bumps the counters once per weight-block
    /// walk / tile batch, and returns `true`; the default declines
    /// (`false`), which tells the engine NOT to publish measured-traffic
    /// metrics it would never receive. Observation must be bit-invisible:
    /// attaching counters may not change any computed value.
    fn attach_traffic(&mut self, _counters: Arc<TrafficCounters>) -> bool {
        false
    }

    /// Execute one spectral conv: spatial input tiles `[T, Cin, K, K]` →
    /// spatial output tiles `[T, Cout, K, K]`, against weights `wid`.
    fn run_conv(&mut self, file: &str, tiles: &Tensor, wid: WeightId) -> Result<Tensor>;

    /// Execute one spectral conv for a whole **batch** of images at once:
    /// `B` tile tensors (each `[T, Cin, K, K]`) → `B` output tile tensors,
    /// all against the same weights. This is the batched entry point of
    /// the batch-major forward path: backends with a streaming weight walk
    /// (interp) fuse the batch so every kernel block / `BankedWeights`
    /// cycle-set is read once per *batch* instead of once per image — and
    /// must return results bit-identical to calling [`Self::run_conv`] per
    /// image. The default implementation is exactly that per-image loop
    /// (correct for PJRT, whose compiled executables are fixed-shape).
    fn run_conv_batch(
        &mut self,
        file: &str,
        tiles: &[Tensor],
        wid: WeightId,
    ) -> Result<Vec<Tensor>> {
        tiles.iter().map(|t| self.run_conv(file, t, wid)).collect()
    }

    /// Number of distinct prepared executables (cache size).
    fn prepared(&self) -> usize;
}

/// Backend selector (serving config / CLI surface).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust interpreter (offline default). `threads` is the number of
    /// worker threads the per-tile hot loop fans out over (1 = serial; the
    /// paper's P'-parallel input tiles, in software). Results are
    /// bit-identical for every thread count — tiles are independent.
    Interp { threads: usize },
    /// AOT-compiled XLA executables via PJRT (needs the `pjrt` feature and
    /// `make artifacts`).
    #[cfg(feature = "pjrt")]
    Pjrt,
}

impl Default for BackendKind {
    fn default() -> Self {
        BackendKind::Interp { threads: 1 }
    }
}

impl BackendKind {
    fn create(self) -> Result<Box<dyn SpectralBackend>> {
        match self {
            BackendKind::Interp { threads } => Ok(Box::new(InterpBackend::with_threads(threads))),
            #[cfg(feature = "pjrt")]
            BackendKind::Pjrt => Ok(Box::new(pjrt::PjrtBackend::new()?)),
        }
    }
}

/// Host-side layout conversion: spectral kernel planes `[N, M, K, K]` →
/// frequency-major `[F, M, N]` (re, im) with `F = K²` — the backends'
/// weight layout. Computed once per engine startup (§Perf L2: doing this
/// transpose inside the graph cost ~120 ms per request on 512×512 layers).
pub fn freq_major_planes(kernels: &ComplexTensor) -> (Vec<f32>, Vec<f32>) {
    let shape = kernels.shape();
    let (n, m, k) = (shape[0], shape[1], shape[2]);
    let f = k * shape[3];
    let mut re = vec![0.0f32; f * m * n];
    let mut im = vec![0.0f32; f * m * n];
    let (src_re, src_im) = (kernels.re.data(), kernels.im.data());
    for ni in 0..n {
        for mi in 0..m {
            let src = (ni * m + mi) * f;
            for fi in 0..f {
                let dst = (fi * m + mi) * n + ni;
                re[dst] = src_re[src + fi];
                im[dst] = src_im[src + fi];
            }
        }
    }
    (re, im)
}

/// Inverse of [`freq_major_planes`]: frequency-major `[F, M, N]` re/im →
/// spectral kernel planes `[N, M, K, K]` (`F` must equal `K²`).
pub fn planes_from_freq_major(re: &[f32], im: &[f32], n: usize, m: usize, fft: usize)
    -> ComplexTensor {
    let f = fft * fft;
    assert_eq!(re.len(), f * m * n, "freq-major length mismatch");
    assert_eq!(im.len(), f * m * n, "freq-major length mismatch");
    let mut out = ComplexTensor::zeros(&[n, m, fft, fft]);
    let (or, oi) = (out.re.data_mut(), out.im.data_mut());
    for ni in 0..n {
        for mi in 0..m {
            let dst = (ni * m + mi) * f;
            for fi in 0..f {
                let src = (fi * m + mi) * n + ni;
                or[dst + fi] = re[src];
                oi[dst + fi] = im[src];
            }
        }
    }
    out
}

/// Fold dense frequency-major planes `[K², M, N]` onto the rfft2
/// half-plane `[K·(K/2+1), M, N]` — the dense-path analogue of
/// [`SparseWeightPlanes::fold_half_plane`], applying the same rules slot
/// for slot (interior columns carry 1/2 and absorb their conjugated
/// mirror; columns 0 and K/2 copy through unchanged), so the dense and
/// sparse half-plane paths see numerically identical weights.
pub fn fold_freq_major_half(
    re: &[f32],
    im: &[f32],
    fft: usize,
    m: usize,
    n: usize,
) -> (Vec<f32>, Vec<f32>) {
    let f = fft * fft;
    assert_eq!(re.len(), f * m * n, "freq-major length mismatch");
    assert_eq!(im.len(), f * m * n, "freq-major length mismatch");
    assert!(fft.is_power_of_two(), "FFT size {fft} must be a power of two");
    let hc = fft / 2 + 1;
    let mut ore = vec![0.0f32; fft * hc * m * n];
    let mut oim = vec![0.0f32; fft * hc * m * n];
    let mn = m * n;
    for r in 0..fft {
        for c in 0..fft {
            let src = (r * fft + c) * mn;
            let (dst, scale, conj) = if c == 0 || c == fft / 2 {
                ((r * hc + c) * mn, 1.0f32, false)
            } else if c < fft / 2 {
                ((r * hc + c) * mn, 0.5, false)
            } else {
                ((((fft - r) % fft) * hc + (fft - c)) * mn, 0.5, true)
            };
            for j in 0..mn {
                ore[dst + j] += scale * re[src + j];
                let v = scale * im[src + j];
                oim[dst + j] += if conj { -v } else { v };
            }
        }
    }
    (ore, oim)
}

/// The runtime: a backend + the manifest describing the model variants.
///
/// When `artifacts/manifest.json` exists it is parsed and validated;
/// otherwise the built-in synthesized manifest ([`Manifest::builtin`]) is
/// used, which is exactly what the `interp` backend needs to run offline.
pub struct Runtime {
    backend: Box<dyn SpectralBackend>,
    artifacts_dir: PathBuf,
    pub manifest: Manifest,
}

impl Runtime {
    /// Open with the default backend ([`BackendKind::Interp`]).
    pub fn open(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        Self::open_with(artifacts_dir, BackendKind::default())
    }

    /// Open `artifacts/` with an explicit backend.
    pub fn open_with(artifacts_dir: impl AsRef<Path>, kind: BackendKind) -> Result<Self> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let manifest = if manifest_path.exists() {
            let text = std::fs::read_to_string(&manifest_path)
                .with_context(|| format!("reading {}", manifest_path.display()))?;
            Manifest::parse(&text)?
        } else {
            Manifest::builtin()
        };
        let backend = kind.create()?;
        Ok(Runtime { backend, artifacts_dir: dir, manifest })
    }

    /// Backend/platform name.
    pub fn platform(&self) -> String {
        self.backend.name()
    }

    /// Prepare (compile/register) the executable for one manifest file.
    pub fn prepare(&mut self, file: &str) -> Result<()> {
        let meta = self
            .manifest
            .executables
            .get(file)
            .ok_or_else(|| err!("{file} not in manifest"))?
            .clone();
        self.backend.prepare(file, &meta, &self.artifacts_dir)
    }

    /// Pre-prepare all executables of a variant (startup warm-up); returns
    /// the number of layer entries processed.
    pub fn warm_variant(&mut self, variant: &str) -> Result<usize> {
        let files: Vec<String> = self
            .manifest
            .variant(variant)?
            .layers
            .iter()
            .map(|l| l.file.clone())
            .collect();
        let mut processed = 0;
        for f in &files {
            self.prepare(f)?;
            processed += 1;
        }
        Ok(processed)
    }

    /// Upload one layer's frequency-major weight planes.
    pub fn upload_weights(&mut self, re: &[f32], im: &[f32], dims: [usize; 3])
        -> Result<WeightId> {
        self.backend.upload_weights(re, im, dims)
    }

    /// Upload one pruned layer in sparse (CSR) form; backends without a
    /// native sparse path densify transparently.
    pub fn upload_sparse(&mut self, layer: &SparseLayer) -> Result<WeightId> {
        self.backend.upload_sparse(layer)
    }

    /// Thread one executable's streaming decision (Alg. 1's per-layer
    /// optimum) into the backend's sparse hot loop.
    pub fn set_sparse_dataflow(&mut self, file: &str, flow: SparseDataflow) -> Result<()> {
        self.backend.set_sparse_dataflow(file, flow)
    }

    /// Select the backend's numeric mode (must precede weight uploads);
    /// errors if the backend declines a non-default mode rather than
    /// silently falling back.
    pub fn configure_numerics(&mut self, dtype: Dtype, plane: Plane) -> Result<()> {
        if self.backend.configure_numerics(dtype, plane)? {
            return Ok(());
        }
        Err(err!(
            "backend {} does not support dtype={} plane={}",
            self.backend.name(),
            dtype.label(),
            plane.label()
        ))
    }

    /// Attach an Alg. 2 access plan to a sparse upload. Returns whether the
    /// backend will actually execute it (see
    /// [`SpectralBackend::set_schedule`]).
    pub fn set_schedule(&mut self, wid: WeightId, plan: &LayerSchedule) -> Result<bool> {
        self.backend.set_schedule(wid, plan)
    }

    /// Attach data-movement counters to the backend's hot loops (see
    /// [`SpectralBackend::attach_traffic`]). Returns whether the backend
    /// instruments its execution.
    pub fn attach_traffic(&mut self, counters: Arc<TrafficCounters>) -> bool {
        self.backend.attach_traffic(counters)
    }

    /// Execute one spectral conv through the backend.
    pub fn run_conv(&mut self, file: &str, tiles: &Tensor, wid: WeightId) -> Result<Tensor> {
        self.backend.run_conv(file, tiles, wid)
    }

    /// Execute one spectral conv for a batch of images (see
    /// [`SpectralBackend::run_conv_batch`]).
    pub fn run_conv_batch(
        &mut self,
        file: &str,
        tiles: &[Tensor],
        wid: WeightId,
    ) -> Result<Vec<Tensor>> {
        self.backend.run_conv_batch(file, tiles, wid)
    }

    /// Distinct prepared executables (cache size).
    pub fn cached_executables(&self) -> usize {
        self.backend.prepared()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;
    use crate::util::rng::Pcg32;

    #[test]
    fn open_without_artifacts_synthesizes_manifest() {
        let rt = Runtime::open("definitely-not-a-dir").unwrap();
        assert_eq!(rt.platform(), "interp");
        assert_eq!(rt.manifest.fft_size, 8);
        assert!(rt.manifest.variants.contains_key("demo"));
    }

    #[test]
    fn warm_variant_counts_and_caches() {
        let mut rt = Runtime::open("definitely-not-a-dir").unwrap();
        assert_eq!(rt.warm_variant("demo").unwrap(), 2);
        assert_eq!(rt.cached_executables(), 2);
        // idempotent: re-warming neither fails nor regrows the cache
        assert_eq!(rt.warm_variant("demo").unwrap(), 2);
        assert_eq!(rt.cached_executables(), 2);
    }

    #[test]
    fn unknown_file_rejected() {
        let mut rt = Runtime::open("definitely-not-a-dir").unwrap();
        assert!(rt.prepare("nope.hlo.txt").is_err());
    }

    #[test]
    fn freq_major_roundtrip() {
        forall("freq-major transpose inverse", 25, |rng| {
            let n = rng.range(1, 6);
            let m = rng.range(1, 6);
            let fft = [4usize, 8][rng.range(0, 2)];
            let mut planes = ComplexTensor::zeros(&[n, m, fft, fft]);
            for v in planes.re.data_mut() {
                *v = rng.normal();
            }
            for v in planes.im.data_mut() {
                *v = rng.normal();
            }
            let (re, im) = freq_major_planes(&planes);
            let back = planes_from_freq_major(&re, &im, n, m, fft);
            assert_eq!(planes, back);
        });
    }

    #[test]
    fn dtype_plane_labels_roundtrip() {
        for d in [Dtype::F32, Dtype::F64] {
            assert_eq!(Dtype::parse(d.label()).unwrap(), d);
        }
        for p in [Plane::Full, Plane::Half] {
            assert_eq!(Plane::parse(p.label()).unwrap(), p);
        }
        assert!(Dtype::parse("f16").is_err());
        assert!(Plane::parse("quarter").is_err());
        assert_eq!(Dtype::default(), Dtype::F32);
        assert_eq!(Plane::default(), Plane::Full);
        assert_eq!(Plane::Full.spectrum_len(8), 64);
        assert_eq!(Plane::Half.spectrum_len(8), 40);
    }

    #[test]
    fn configure_numerics_defaults_accept_only_f32_full() {
        let mut rt = Runtime::open("definitely-not-a-dir").unwrap();
        // interp honours every mode
        for d in [Dtype::F32, Dtype::F64] {
            for p in [Plane::Full, Plane::Half] {
                rt.configure_numerics(d, p).unwrap();
            }
        }
        // a backend on the trait defaults declines non-default modes
        struct Densify;
        impl SpectralBackend for Densify {
            fn name(&self) -> String {
                "densify".into()
            }
            fn prepare(&mut self, _: &str, _: &ExecutableEntry, _: &Path) -> Result<()> {
                Ok(())
            }
            fn upload_weights(&mut self, _: &[f32], _: &[f32], _: [usize; 3]) -> Result<WeightId> {
                Ok(0)
            }
            fn run_conv(&mut self, _: &str, _: &Tensor, _: WeightId) -> Result<Tensor> {
                Err(err!("unused"))
            }
            fn prepared(&self) -> usize {
                0
            }
        }
        let mut b = Densify;
        assert!(b.configure_numerics(Dtype::F32, Plane::Full).unwrap());
        assert!(!b.configure_numerics(Dtype::F64, Plane::Full).unwrap());
        assert!(!b.configure_numerics(Dtype::F32, Plane::Half).unwrap());
    }

    #[test]
    fn dense_fold_matches_sparse_fold() {
        // the dense upload path and the CSR fold must agree value for
        // value — the α=1 legs of the half-plane equivalence matrix ride
        // on this
        let mut rng = Pcg32::new(7);
        let l = crate::sparse::prune_magnitude(5, 3, 8, 4, &mut rng);
        let w = SparseWeightPlanes::from_layer(&l);
        let (re, im) = freq_major_planes(&l.to_dense_planes());
        let (fre, fim) = fold_freq_major_half(&re, &im, 8, 3, 5);
        let (sre, sim) = w.fold_half_plane(8).to_freq_major();
        assert_eq!(fre, sre);
        assert_eq!(fim, sim);
    }

    #[test]
    fn freq_major_layout_spot_check() {
        // [N=1, M=1]: freq-major must equal the flat plane itself.
        let mut rng = Pcg32::new(3);
        let mut planes = ComplexTensor::zeros(&[1, 1, 4, 4]);
        for v in planes.re.data_mut() {
            *v = rng.normal();
        }
        let (re, _) = freq_major_planes(&planes);
        assert_eq!(re, planes.re.data());
    }
}
