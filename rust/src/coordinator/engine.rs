//! The inference engine: drives one model variant through the configured
//! [`SpectralBackend`](crate::runtime::SpectralBackend) plus the CPU-side
//! head — the full spectral pipeline of paper Eq. 4.
//!
//! Per conv layer (the paper's §5.1 process, CPU side in Rust):
//!
//! ```text
//! im2tiles → [backend: FFT → Hadamard → IFFT] → overlap-add
//!          → bias → ReLU → (maxpool)
//! ```
//!
//! then flatten → FC stack → logits. The backend is `interp` by default
//! (pure Rust, runs offline with no artifacts); with the `pjrt` feature the
//! same engine drives AOT-compiled XLA executables instead.
//!
//! Thread confinement: an engine (and its backend) is owned by exactly one
//! thread for its whole life — the server pool constructs one engine
//! *inside* each executor worker. PJRT state holds raw FFI pointers that
//! must not migrate; the interp backend may itself fan out scoped threads
//! per request ([`BackendKind::Interp`]'s `threads`), which is fine because
//! those never outlive the call. Weight generation is a pure function of
//! `(variant, mode, seed)`, so pool replicas are bit-identical.

use std::sync::Arc;
use std::time::Instant;

use super::arena::ArenaPlan;
use super::metrics::{ArenaMetrics, LayerScheduleMetrics, ScheduleMetrics};
use crate::analysis::{transfers_flex_batch, ArchParams, LayerParams, StreamParams};
use crate::dataflow::{optimize_layer, OptimizerConfig};
use crate::err;
use crate::fft::{im2tiles, overlap_add, spectral_kernels, TileGeometry};
use crate::model::GraphOp;
use crate::nn;
use crate::obs::{LayerSpan, LayerTraffic, TrafficCounters, TrafficMetrics};
use crate::runtime::{
    freq_major_planes, BackendKind, Dtype, LayerEntry, Plane, Runtime, SparseDataflow,
    SparseWeightPlanes, VariantEntry, WeightId,
};
use crate::schedule::{LayerSchedule, SchedulePolicy, DEFAULT_WEIGHT_BANKS};
use crate::sparse::{prune_magnitude, SparseLayer};
use crate::tensor::{ComplexTensor, Tensor};
use crate::util::error::Result;
use crate::util::rng::Pcg32;

/// How layer weights are generated (no trained checkpoints exist for the
/// paper's pruned spectral VGG16 — DESIGN.md "Hardware substitution").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WeightMode {
    /// Dense spatial 3×3 kernels, FFT'd to spectral planes. Numerics are
    /// checkable against a spatial convolution reference.
    Dense,
    /// Magnitude-pruned ("ADMM-like") spectral kernels at ratio α,
    /// uploaded in sparse (CSR) form and executed by the backend's sparse
    /// MAC. The spectral path is then the definition of the network.
    Pruned { alpha: usize },
}

impl WeightMode {
    /// Map the `--alpha` knob to a mode: `α ≤ 1` is dense, `α > 1` prunes
    /// each K×K spectral kernel to K²/α non-zeros.
    pub fn from_alpha(alpha: usize) -> Self {
        if alpha <= 1 {
            WeightMode::Dense
        } else {
            WeightMode::Pruned { alpha }
        }
    }

    /// The compression ratio this mode runs at (1 = dense).
    pub fn alpha(&self) -> usize {
        match self {
            WeightMode::Dense => 1,
            WeightMode::Pruned { alpha } => *alpha,
        }
    }
}

/// Per-layer streaming decision for the sparse execution path: run the
/// flexible-dataflow inner loop (paper Alg. 1 / [`optimize_layer`]) on this
/// layer's geometry at the paper's architecture point, and hand the chosen
/// `Ps` to the backend as its resident-tile block ([`SparseDataflow`]).
/// This is where the planner stops being a paper artifact: the same search
/// that produces Table 1 now picks the serving loop order. `batch` is the
/// B the engine will forward at (the serving batcher's `max_batch`): the
/// planner sees the B·P tile population and may choose `Ps` spanning the
/// whole batch, so one kernel stream covers all B images' tiles. τ cancels
/// in the per-layer argmin (bandwidth = volume/τ at fixed τ), so any
/// positive value yields the same streaming optimum; infeasible-BRAM
/// layers fall back to pure tile-major execution. `resident` is the
/// activation arena's concurrent-live tensor count ([`ArenaPlan::n_slots`]):
/// residual graphs keep shortcut tensors on chip across their span, and the
/// Eq. 12 feasibility gate must budget for them (chain variants pass the
/// paper's implicit 1 and change nothing).
/// It also returns the layer's analysis geometry next to the chosen stream
/// plan — the pair the observability layer needs to evaluate Eq. 13
/// ([`transfers_flex_batch`]) for the loop order that actually executes.
/// Infeasible-BRAM layers fall back to pure tile-major streaming
/// (`Ps = 1`, `Ns = N`), which is also exactly the loop order the backend
/// then runs — so measured and predicted traffic stay comparable even off
/// the optimizer's lattice.
fn layer_plan_for(
    l: &LayerEntry,
    fft: usize,
    tile: usize,
    alpha: usize,
    batch: usize,
    resident: usize,
    plane: Plane,
) -> (LayerParams, StreamParams) {
    // Half-plane storage shrinks every per-frequency budget in the Eq. 12/13
    // feasibility/volume model: the planner sees K·(K/2+1) frequency slots
    // instead of K², so more tiles fit resident at the same BRAM point.
    let params = LayerParams {
        m: l.cin,
        n: l.cout,
        h_in: l.h,
        tile,
        k2: plane.spectrum_len(fft),
        p: l.tiles,
        alpha: alpha.max(1),
    };
    let cfg = OptimizerConfig {
        alpha: alpha.max(1),
        batch: batch.max(1),
        resident_tensors: resident.max(1),
        ..OptimizerConfig::paper()
    };
    let stream = match optimize_layer(&params, &ArchParams::paper(), &cfg, 1.0) {
        Some(plan) => plan.stream,
        None => StreamParams { ns: l.cout, ps: 1 },
    };
    (params, stream)
}

/// Engine construction knobs beyond `(artifacts, variant, mode, seed)`.
#[derive(Debug, Clone, Copy)]
pub struct EngineOptions {
    /// Backend the conv layers execute on.
    pub backend: BackendKind,
    /// Alg. 2 scheduling policy for the sparse layers.
    pub scheduler: SchedulePolicy,
    /// Batch size B the Alg. 1 streaming plan is optimized for — the
    /// serving pool passes its batcher's `max_batch`. Forwarding any
    /// batch size (including 1) stays correct for any `plan_batch`; the
    /// value only moves the kernel-reuse/residency trade-off.
    pub plan_batch: usize,
    /// Accumulation dtype for the spectral hot loop. `None` defers to the
    /// manifest's recorded default (f32 unless it says otherwise) — the
    /// same sentinel semantics as `--alpha 0`.
    pub dtype: Option<Dtype>,
    /// Spectral storage plane (full K×K vs the rfft2 half-plane).
    pub plane: Plane,
    /// Reuse dead activation-arena slots for later tensors (the default).
    /// `false` gives every tensor its own slot — the no-reuse reference
    /// mode the arena property tests compare bit-for-bit against.
    pub arena_reuse: bool,
    /// Measure data movement and per-layer execute spans (the default).
    /// Observation is bit-invisible to logits (pinned in tests) and costs
    /// a handful of relaxed atomic adds per conv call (≤ 2% median e2e,
    /// pinned by `bench_e2e`'s observe-on/off contender pair); `false`
    /// detaches the counters entirely — the overhead-reference mode.
    pub observe: bool,
}

impl Default for EngineOptions {
    fn default() -> Self {
        EngineOptions {
            backend: BackendKind::default(),
            scheduler: SchedulePolicy::default(),
            plan_batch: 1,
            dtype: None,
            plane: Plane::Full,
            arena_reuse: true,
            observe: true,
        }
    }
}

impl EngineOptions {
    /// Start a builder from validated defaults. Prefer this over struct
    /// literals when options come from user input (CLI flags, `/admin`
    /// bodies): [`EngineOptionsBuilder::build`] normalizes every knob.
    pub fn builder() -> EngineOptionsBuilder {
        EngineOptionsBuilder { opts: EngineOptions::default() }
    }
}

/// Fluent constructor for [`EngineOptions`] — one setter per knob, so call
/// sites name exactly what they override and inherit validated defaults for
/// the rest (the API-redesign replacement for positional struct sprawl).
#[derive(Debug, Clone)]
pub struct EngineOptionsBuilder {
    opts: EngineOptions,
}

impl EngineOptionsBuilder {
    /// Backend the conv layers execute on.
    pub fn backend(mut self, backend: BackendKind) -> Self {
        self.opts.backend = backend;
        self
    }

    /// Alg. 2 scheduling policy for the sparse layers.
    pub fn scheduler(mut self, scheduler: SchedulePolicy) -> Self {
        self.opts.scheduler = scheduler;
        self
    }

    /// Batch size B the Alg. 1 streaming plan is optimized for. Values are
    /// clamped to ≥ 1 at [`EngineOptionsBuilder::build`].
    pub fn plan_batch(mut self, plan_batch: usize) -> Self {
        self.opts.plan_batch = plan_batch;
        self
    }

    /// Accumulation dtype (`None` = manifest default, same sentinel as
    /// `--alpha 0`).
    pub fn dtype(mut self, dtype: Option<Dtype>) -> Self {
        self.opts.dtype = dtype;
        self
    }

    /// Spectral storage plane (full K×K vs the rfft2 half-plane).
    pub fn plane(mut self, plane: Plane) -> Self {
        self.opts.plane = plane;
        self
    }

    /// Whether dead activation-arena slots are reused (default `true`).
    pub fn arena_reuse(mut self, arena_reuse: bool) -> Self {
        self.opts.arena_reuse = arena_reuse;
        self
    }

    /// Whether data movement and per-layer spans are measured (default
    /// `true`).
    pub fn observe(mut self, observe: bool) -> Self {
        self.opts.observe = observe;
        self
    }

    /// Finalize, normalizing out-of-range knobs (`plan_batch` ≥ 1).
    pub fn build(mut self) -> EngineOptions {
        self.opts.plan_batch = self.opts.plan_batch.max(1);
        self.opts
    }
}

/// One conv layer's parameters on the engine side.
pub struct LayerWeights {
    /// Spectral kernel planes `[cout, cin, K, K]`.
    pub spectral: ComplexTensor,
    /// Spatial kernels (Dense mode only; kept for reference checking).
    pub spatial: Option<Tensor>,
    pub bias: Vec<f32>,
    /// Sparse form (Pruned mode only; drives scheduling experiments).
    pub sparse: Option<SparseLayer>,
}

/// All weights for a variant.
pub struct Weights {
    pub convs: Vec<LayerWeights>,
    /// FC stack: (weight `[out, in]`, bias).
    pub fc: Vec<(Tensor, Vec<f32>)>,
    pub mode: WeightMode,
}

impl Weights {
    /// Deterministic weight generation for a manifest variant.
    pub fn generate(variant: &VariantEntry, fft: usize, k: usize, mode: WeightMode, seed: u64) -> Self {
        let mut rng = Pcg32::new(seed);
        let mut convs = Vec::new();
        for l in &variant.layers {
            let bias: Vec<f32> = (0..l.cout).map(|_| rng.normal() * 0.01).collect();
            match mode {
                WeightMode::Dense => {
                    let scale = (2.0 / (l.cin * k * k) as f32).sqrt();
                    let spatial = Tensor::randn(&[l.cout, l.cin, k, k], &mut rng, scale);
                    let spectral = spectral_kernels(&spatial, fft);
                    convs.push(LayerWeights { spectral, spatial: Some(spatial), bias, sparse: None });
                }
                WeightMode::Pruned { alpha } => {
                    let sparse = prune_magnitude(l.cout, l.cin, fft, alpha, &mut rng);
                    let spectral = sparse.to_dense_planes();
                    convs.push(LayerWeights { spectral, spatial: None, bias, sparse: Some(sparse) });
                }
            }
        }
        // FC head: flatten width from the activation graph's final tensor
        // (for chains this is the last conv + pool walk it always was).
        let (out_c, side) = variant.output_shape().expect("variant graph validates");
        let mut in_w = out_c * side * side;
        let mut fc = Vec::new();
        for &out_w in &variant.fc {
            let scale = (2.0 / in_w as f32).sqrt();
            let w = Tensor::randn(&[out_w, in_w], &mut rng, scale);
            let b: Vec<f32> = (0..out_w).map(|_| rng.normal() * 0.01).collect();
            fc.push((w, b));
            in_w = out_w;
        }
        Weights { convs, fc, mode }
    }
}

/// Upper bound on retained per-layer spans: [`InferenceEngine::forward_batch`]
/// clears the list per forward, but direct `conv_layer_batch` callers
/// (layer microbenches) accumulate — cap so observation can never grow
/// unbounded state.
const MAX_LAYER_SPANS: usize = 4096;

/// One conv layer's observability state: the analysis geometry and stream
/// plan the layer executes under (fixed at startup) plus the measured /
/// predicted accumulation across forwards.
struct LayerTrafficState {
    params: LayerParams,
    stream: StreamParams,
    acc: LayerTraffic,
}

/// Engine-side observability (present iff `EngineOptions::observe` and the
/// backend accepted the counters — a densifying backend that can't measure
/// returns `false` from `attach_traffic` and the engine publishes nothing
/// rather than zeros that would read as "no traffic").
struct ObserveState {
    counters: Arc<TrafficCounters>,
    layers: Vec<LayerTrafficState>,
    /// Per-layer execute spans of the most recent forward.
    spans: Vec<LayerSpan>,
}

/// The engine: runtime (backend + manifest) + weights + variant description.
pub struct InferenceEngine {
    runtime: Runtime,
    pub variant_name: String,
    pub variant: VariantEntry,
    pub weights: Weights,
    /// Per-layer weight handles — uploaded once at startup (§Perf L3:
    /// avoids a ~134 MB conversion per deep-layer call on PJRT; on interp
    /// it pins the frequency-major layout the MAC loop streams).
    weight_ids: Vec<WeightId>,
    kernel_k: usize,
    fft: usize,
    /// Scheduling policy the sparse layers execute under.
    scheduler: SchedulePolicy,
    /// Accumulation dtype the spectral hot loop runs at (manifest-resolved).
    dtype: Dtype,
    /// Spectral storage plane the backend executes on.
    plane: Plane,
    /// Static per-layer scheduling quality (None when dense or `Off`).
    schedule_metrics: Option<ScheduleMetrics>,
    /// Static slot plan for the variant's activation graph (computed once
    /// at startup; the forward just indexes slots).
    arena: ArenaPlan,
    /// Traffic counters + per-layer measured-vs-predicted accounting
    /// (None when observation is off or the backend declined the counters).
    observe: Option<ObserveState>,
}

impl InferenceEngine {
    /// Build an engine for a named variant on the default (`interp`)
    /// backend, preparing all of its executables.
    pub fn new(
        artifacts_dir: &str,
        variant: &str,
        mode: WeightMode,
        seed: u64,
    ) -> Result<Self> {
        Self::new_with(artifacts_dir, variant, mode, seed, BackendKind::default())
    }

    /// Build an engine on an explicit backend with the default scheduling
    /// policy (Alg. 2 exact cover — the serving default).
    pub fn new_with(
        artifacts_dir: &str,
        variant: &str,
        mode: WeightMode,
        seed: u64,
        backend: BackendKind,
    ) -> Result<Self> {
        Self::new_with_opts(artifacts_dir, variant, mode, seed, backend, SchedulePolicy::default())
    }

    /// Build an engine with an explicit backend *and* scheduling policy
    /// (`--scheduler {exact-cover,lowest-index,off}` on the CLI), planning
    /// streaming for single-image forwards.
    pub fn new_with_opts(
        artifacts_dir: &str,
        variant: &str,
        mode: WeightMode,
        seed: u64,
        backend: BackendKind,
        scheduler: SchedulePolicy,
    ) -> Result<Self> {
        Self::with_options(
            artifacts_dir,
            variant,
            mode,
            seed,
            EngineOptions { backend, scheduler, ..EngineOptions::default() },
        )
    }

    /// Build an engine from explicit [`EngineOptions`] — the full
    /// constructor the serving pool uses (it passes the batcher's
    /// `max_batch` as `plan_batch` so Alg. 1 plans batch-major streaming).
    pub fn with_options(
        artifacts_dir: &str,
        variant: &str,
        mode: WeightMode,
        seed: u64,
        opts: EngineOptions,
    ) -> Result<Self> {
        let EngineOptions { backend, scheduler, plan_batch, dtype, plane, arena_reuse, observe } =
            opts;
        let mut runtime = Runtime::open_with(artifacts_dir, backend)?;
        let dtype = runtime.manifest.resolve_dtype(dtype);
        // Numeric mode must be pinned before any weight upload: the backend
        // folds half-plane weights at upload time, so flipping the plane
        // afterwards would desynchronize store and schedule.
        runtime.configure_numerics(dtype, plane)?;
        let v = runtime.manifest.variant(variant)?.clone();
        // Plan the activation arena up front: the slot count is the
        // concurrent-residency the dataflow optimizer must budget for.
        let arena = ArenaPlan::for_variant(&v, arena_reuse)?;
        let fft = runtime.manifest.fft_size;
        let k = runtime.manifest.kernel_k;
        runtime.warm_variant(variant)?;
        let weights = Weights::generate(&v, fft, k, mode, seed);
        let tile = runtime.manifest.tile;
        let arch = ArchParams::paper();
        // Observation: hand the backend a shared counter block; a backend
        // that can't measure (densifying PJRT) declines, and the engine
        // then publishes no traffic metrics at all.
        let mut observe = if observe {
            let counters = Arc::new(TrafficCounters::new());
            runtime.attach_traffic(Arc::clone(&counters)).then(|| ObserveState {
                counters,
                layers: Vec::new(),
                spans: Vec::new(),
            })
        } else {
            None
        };
        let mut weight_ids = Vec::with_capacity(v.layers.len());
        let mut sched_layers = Vec::new();
        for (l, w) in v.layers.iter().zip(&weights.convs) {
            // the Eq. 13 geometry + stream plan this layer executes under:
            // sparse layers run the Alg. 1 optimum; dense layers walk the
            // full plane per tile, which is exactly the `Ps = 1, Ns = N`
            // stream at α = 1 — so measured == predicted holds for both.
            let (obs_params, obs_stream) = match &w.sparse {
                Some(sp) => {
                    layer_plan_for(l, fft, tile, sp.alpha, plan_batch, arena.n_slots, plane)
                }
                None => {
                    let (params, _) =
                        layer_plan_for(l, fft, tile, 1, plan_batch, arena.n_slots, plane);
                    (params, StreamParams { ns: l.cout, ps: 1 })
                }
            };
            if let Some(obs) = observe.as_mut() {
                obs.layers.push(LayerTrafficState {
                    params: obs_params,
                    stream: obs_stream,
                    acc: LayerTraffic { layer: l.name.clone(), ..LayerTraffic::default() },
                });
            }
            let wid = match &w.sparse {
                // Pruned layers upload in CSR form, and Alg. 1's per-layer
                // streaming optimum becomes the backend's loop order. The
                // hint is keyed by the dedup'd executable (tiles/cin/cout/K):
                // same-key layers re-plan with their own h, last write wins —
                // h only nudges the optimizer's transfer totals, so a clash
                // can cost streaming efficiency, never correctness.
                Some(sp) => {
                    runtime.set_sparse_dataflow(&l.file, SparseDataflow::from_stream(&obs_stream))?;
                    let wid = runtime.upload_sparse(sp)?;
                    // Alg. 2: plan every (group, channel) instance at the
                    // paper's architecture point and execute in schedule
                    // order. Keyed by the weight handle — schedules belong
                    // to a non-zero pattern, not to the shape-deduped
                    // executable (two layers may share `l.file`).
                    //
                    // Half-plane mode schedules the *folded* planes — the
                    // fold is deterministic, so this is exactly the CSR the
                    // backend built from the same upload, and the cycle-sets
                    // cover the halved weight stream.
                    let planes = SparseWeightPlanes::from_layer(sp);
                    let planes = match plane {
                        Plane::Full => planes,
                        Plane::Half => planes.fold_half_plane(sp.fft),
                    };
                    if let Some(plan) = LayerSchedule::build(
                        &planes,
                        arch.n_par,
                        arch.replicas,
                        DEFAULT_WEIGHT_BANKS,
                        scheduler,
                    ) {
                        // only publish metrics when the backend will really
                        // execute the plan — a densifying backend (PJRT)
                        // returns false, and reporting exact-cover quality
                        // for an execution that never happens would lie to
                        // every dashboard downstream
                        if runtime.set_schedule(wid, &plan)? {
                            sched_layers.push(LayerScheduleMetrics {
                                layer: l.name.clone(),
                                stats: plan.stats,
                            });
                        }
                    }
                    wid
                }
                // Dense layers keep the frequency-major [F, M, N] planes —
                // computed once here instead of per request.
                None => {
                    let (re, im) = freq_major_planes(&w.spectral);
                    runtime.upload_weights(&re, &im, [fft * fft, l.cin, l.cout])?
                }
            };
            weight_ids.push(wid);
        }
        let schedule_metrics = if sched_layers.is_empty() {
            None
        } else {
            Some(ScheduleMetrics { scheduler: scheduler.label().to_string(), layers: sched_layers })
        };
        Ok(InferenceEngine {
            runtime,
            variant_name: variant.to_string(),
            variant: v,
            weights,
            weight_ids,
            kernel_k: k,
            fft,
            scheduler,
            dtype,
            plane,
            schedule_metrics,
            arena,
            observe,
        })
    }

    pub fn fft_size(&self) -> usize {
        self.fft
    }

    /// Backend/platform name serving this engine.
    pub fn backend_name(&self) -> String {
        self.runtime.platform()
    }

    /// The scheduling policy the sparse layers execute under.
    pub fn scheduler(&self) -> SchedulePolicy {
        self.scheduler
    }

    /// The accumulation dtype the spectral hot loop runs at.
    pub fn dtype(&self) -> Dtype {
        self.dtype
    }

    /// The spectral storage plane the backend executes on.
    pub fn plane(&self) -> Plane {
        self.plane
    }

    /// Per-layer Alg. 2 scheduling quality (PE utilization, cycles vs lower
    /// bound, simulated bank conflicts). `None` when the engine serves
    /// dense weights or was built with [`SchedulePolicy::Off`].
    pub fn schedule_metrics(&self) -> Option<&ScheduleMetrics> {
        self.schedule_metrics.as_ref()
    }

    /// The activation arena's slot plan for this variant.
    pub fn arena(&self) -> &ArenaPlan {
        &self.arena
    }

    /// Static activation-arena accounting (peak residency, slot reuse) —
    /// published to `Metrics`/`/metrics` by the serving workers.
    pub fn arena_metrics(&self) -> &ArenaMetrics {
        &self.arena.metrics
    }

    /// Whether this engine measures data movement (observation on AND the
    /// backend accepted the counters).
    pub fn observing(&self) -> bool {
        self.observe.is_some()
    }

    /// Per-layer measured traffic next to its Eq. 13 prediction, plus the
    /// raw counter totals — accumulated since engine construction. `None`
    /// when observation is off or the backend can't measure.
    pub fn traffic_metrics(&self) -> Option<TrafficMetrics> {
        self.observe.as_ref().map(|o| TrafficMetrics {
            layers: o.layers.iter().map(|s| s.acc.clone()).collect(),
            totals: o.counters.snapshot(),
        })
    }

    /// Per-layer execute spans of the most recent forward (empty when not
    /// observing).
    pub fn layer_spans(&self) -> &[LayerSpan] {
        self.observe.as_ref().map(|o| o.spans.as_slice()).unwrap_or(&[])
    }

    /// Run one conv layer through the backend (the "FPGA" side).
    pub fn conv_layer(&mut self, idx: usize, x: &Tensor) -> Result<Tensor> {
        let mut out = self.conv_layer_batch(idx, std::slice::from_ref(x))?;
        Ok(out.pop().expect("one image in, one activation out"))
    }

    /// Run one conv layer for a whole batch of images — one
    /// [`run_conv_batch`](crate::runtime::SpectralBackend::run_conv_batch)
    /// call, so the backend's kernel stream covers all B images' tiles.
    pub fn conv_layer_batch(&mut self, idx: usize, xs: &[Tensor]) -> Result<Vec<Tensor>> {
        let l = self.variant.layers[idx].clone();
        for x in xs {
            if x.shape() != [l.cin, l.h, l.h] {
                return Err(err!(
                    "layer {} expects [{}, {}, {}], got {:?}",
                    l.name,
                    l.cin,
                    l.h,
                    l.h,
                    x.shape()
                ));
            }
        }
        let geo = TileGeometry::new(l.h, self.fft, self.kernel_k);
        let tiles: Vec<Tensor> = xs.iter().map(|x| im2tiles(x, &geo)).collect();
        // snapshot the counters around the backend call: the delta is this
        // conv's measured traffic, compared against Eq. 13 evaluated at the
        // layer's executed plan and the *actual* batch size
        let before = self.observe.as_ref().map(|o| (o.counters.snapshot(), Instant::now()));
        let out_tiles = self.runtime.run_conv_batch(&l.file, &tiles, self.weight_ids[idx])?;
        if let Some((start_snap, start)) = before {
            let end = Instant::now();
            // complex word size at the engine dtype — the byte convention
            // shared with the backend's weight counters
            let cb = match self.dtype {
                Dtype::F32 => 8u64,
                Dtype::F64 => 16u64,
            };
            let obs = self.observe.as_mut().expect("observe state present before the call");
            let delta = obs.counters.snapshot().since(&start_snap);
            let tr = transfers_flex_batch(
                &obs.layers[idx].params,
                &obs.layers[idx].stream,
                xs.len(),
            );
            let acc = &mut obs.layers[idx].acc;
            acc.measured.add(&delta);
            acc.predicted_weight_bytes += tr.kernels * cb;
            acc.predicted_input_bytes += tr.inputs * 4;
            acc.predicted_output_bytes += tr.outputs * 4;
            acc.forwards += 1;
            if obs.spans.len() >= MAX_LAYER_SPANS {
                obs.spans.clear();
            }
            obs.spans.push(LayerSpan {
                name: l.name.clone(),
                start,
                end,
                measured_bytes: delta.weight_bytes
                    + delta.input_bytes
                    + delta.output_bytes
                    + delta.psum_bytes,
                predicted_bytes: tr.kernels * cb + (tr.inputs + tr.outputs) * 4,
            });
        }
        let mut outs = Vec::with_capacity(out_tiles.len());
        for ot in &out_tiles {
            let mut out = overlap_add(ot, &geo, l.cout);
            nn::add_bias(&mut out, &self.weights.convs[idx].bias);
            nn::relu(&mut out);
            outs.push(out);
        }
        Ok(outs)
    }

    /// Validate one image against the variant's input shape without running
    /// anything — the serving worker pre-screens a closed batch with this
    /// so a mis-shaped request gets its own error instead of poisoning the
    /// whole batch's fused forward.
    pub fn check_input(&self, image: &Tensor) -> Result<()> {
        let want = [self.variant.input_c, self.variant.input_hw, self.variant.input_hw];
        if image.shape() != want {
            return Err(err!("input shape {:?} != {:?}", image.shape(), want));
        }
        Ok(())
    }

    /// Full forward pass: image `[C, H, W]` → logits. Same code path as
    /// [`Self::forward_batch`] at B = 1 — there is deliberately no serial
    /// special case.
    pub fn forward(&mut self, image: &Tensor) -> Result<Vec<f32>> {
        let mut out = self.forward_batch(std::slice::from_ref(image))?;
        Ok(out.pop().expect("one image in, one logits out"))
    }

    /// Batch-major forward pass: B images `[C, H, W]` → B logit vectors.
    ///
    /// Executes the variant's activation graph over the arena's slot plan:
    /// each node reads its input slots, runs (conv via the backend, or an
    /// engine-level add/concat), writes its output slot, and frees the
    /// slots of tensors past their last use — so a residual shortcut stays
    /// in place across its whole span, never copied per layer, and peak
    /// residency is [`ArenaMetrics::peak_activation_bytes`] per image. For
    /// chain variants this degenerates to the historical layer loop (two
    /// slots ping-ponging).
    ///
    /// The loop nest is node-major, batch-inner: each conv layer executes
    /// **once** over all B images' tiles (via
    /// [`run_conv_batch`](crate::runtime::SpectralBackend::run_conv_batch)),
    /// so the backend streams each sparse weight block once per batch
    /// instead of once per image — the B reuse axis of the batch-aware
    /// Alg. 1. Outputs are bit-identical to B independent [`Self::forward`]
    /// calls (pinned by tests at backend, engine, and HTTP levels), and to
    /// the no-reuse arena mode (pinned by the arena property tests).
    pub fn forward_batch(&mut self, images: &[Tensor]) -> Result<Vec<Vec<f32>>> {
        for image in images {
            self.check_input(image)?;
        }
        // spans describe one forward: the serving worker snapshots them per
        // batch, so each forward starts a fresh list
        if let Some(obs) = self.observe.as_mut() {
            obs.spans.clear();
        }
        let plan = self.arena.clone(); // small: ~n_nodes usizes
        let mut slots: Vec<Option<Vec<Tensor>>> = vec![None; plan.n_slots];
        // generation check: which tensor id currently owns each slot
        let mut owner = vec![usize::MAX; plan.n_slots];
        slots[plan.slot_of[0]] = Some(images.to_vec());
        owner[plan.slot_of[0]] = 0;
        for (i, step) in plan.steps.iter().enumerate() {
            let out: Vec<Tensor> = match *step {
                GraphOp::Conv { conv, input } => {
                    debug_assert_eq!(
                        owner[plan.slot_of[input]], input,
                        "tensor {input} read after its arena slot was reused"
                    );
                    let mut ys = {
                        let xs =
                            slots[plan.slot_of[input]].as_ref().expect("arena: conv input freed");
                        self.conv_layer_batch(conv, xs)?
                    };
                    if self.variant.layers[conv].pool_after {
                        for y in &mut ys {
                            *y = nn::maxpool2(y);
                        }
                    }
                    ys
                }
                GraphOp::Add { a, b } => {
                    debug_assert_eq!(
                        owner[plan.slot_of[a]], a,
                        "tensor {a} read after its arena slot was reused"
                    );
                    debug_assert_eq!(
                        owner[plan.slot_of[b]], b,
                        "tensor {b} read after its arena slot was reused"
                    );
                    let xa = slots[plan.slot_of[a]].as_ref().expect("arena: add input freed");
                    let xb = slots[plan.slot_of[b]].as_ref().expect("arena: add input freed");
                    xa.iter().zip(xb).map(|(x, y)| x.add(y)).collect()
                }
                GraphOp::Concat { a, b } => {
                    debug_assert_eq!(
                        owner[plan.slot_of[a]], a,
                        "tensor {a} read after its arena slot was reused"
                    );
                    debug_assert_eq!(
                        owner[plan.slot_of[b]], b,
                        "tensor {b} read after its arena slot was reused"
                    );
                    let xa = slots[plan.slot_of[a]].as_ref().expect("arena: concat input freed");
                    let xb = slots[plan.slot_of[b]].as_ref().expect("arena: concat input freed");
                    let (c_out, side) = plan.shapes[i + 1];
                    xa.iter()
                        .zip(xb)
                        .map(|(x, y)| {
                            let mut data = Vec::with_capacity(c_out * side * side);
                            data.extend_from_slice(x.data());
                            data.extend_from_slice(y.data());
                            Tensor::from_vec(&[c_out, side, side], data)
                        })
                        .collect()
                }
            };
            // arena traffic: the slot bytes this step's output occupies
            // (per image summed over the batch)
            if let Some(obs) = self.observe.as_ref() {
                let bytes: usize = out.iter().map(|t| t.data().len() * 4).sum();
                obs.counters.add_arena(bytes as u64);
            }
            // free tensors past their last use — the plan claimed the
            // output slot from slots already free before this step, so it
            // never collides with a dying input's slot
            for &s in &plan.free_after[i] {
                if cfg!(debug_assertions) {
                    // poison-on-free: a buggy stale read turns into NaN
                    // that the property tests' finiteness check catches
                    if let Some(bufs) = &mut slots[s] {
                        for buf in bufs {
                            for v in buf.data_mut() {
                                *v = f32::NAN;
                            }
                        }
                    }
                }
                slots[s] = None;
                owner[s] = usize::MAX;
            }
            let t = i + 1;
            slots[plan.slot_of[t]] = Some(out);
            owner[plan.slot_of[t]] = t;
        }
        let final_t = plan.steps.len();
        debug_assert_eq!(owner[plan.slot_of[final_t]], final_t);
        let xs = slots[plan.slot_of[final_t]].take().expect("arena: final tensor freed");
        let n_fc = self.weights.fc.len();
        let mut all = Vec::with_capacity(xs.len());
        for x in xs {
            let mut v = x.into_vec();
            for (i, (w, b)) in self.weights.fc.iter().enumerate() {
                v = nn::dense(w, b, &v);
                if i + 1 < n_fc {
                    for e in &mut v {
                        if *e < 0.0 {
                            *e = 0.0;
                        }
                    }
                }
            }
            all.push(v);
        }
        Ok(all)
    }

    /// Pure-Rust spatial reference for one conv layer (Dense mode only):
    /// the ground truth integration tests compare [`Self::conv_layer`]
    /// against.
    pub fn conv_layer_reference(&self, idx: usize, x: &Tensor) -> Result<Tensor> {
        let w = self.weights.convs[idx]
            .spatial
            .as_ref()
            .ok_or_else(|| err!("reference path needs WeightMode::Dense"))?;
        let mut out = nn::conv2d_same_ref(x, w);
        nn::add_bias(&mut out, &self.weights.convs[idx].bias);
        nn::relu(&mut out);
        Ok(out)
    }

    /// A deterministic synthetic input image.
    pub fn synthetic_image(&self, seed: u64) -> Tensor {
        let mut rng = Pcg32::new(seed);
        Tensor::randn(
            &[self.variant.input_c, self.variant.input_hw, self.variant.input_hw],
            &mut rng,
            1.0,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alpha_mode_mapping() {
        assert_eq!(WeightMode::from_alpha(0), WeightMode::Dense);
        assert_eq!(WeightMode::from_alpha(1), WeightMode::Dense);
        assert_eq!(WeightMode::from_alpha(4), WeightMode::Pruned { alpha: 4 });
        assert_eq!(WeightMode::Dense.alpha(), 1);
        assert_eq!(WeightMode::Pruned { alpha: 8 }.alpha(), 8);
    }

    fn layer(cin: usize, cout: usize, h: usize, tiles: usize) -> LayerEntry {
        LayerEntry {
            name: "t".into(),
            cin,
            cout,
            h,
            tiles,
            pool_after: false,
            file: "t.hlo.txt".into(),
        }
    }

    /// The backend-facing projection of [`layer_plan_for`] — what
    /// `with_options` hands `set_sparse_dataflow`.
    fn sparse_dataflow_for(
        l: &LayerEntry,
        fft: usize,
        tile: usize,
        alpha: usize,
        batch: usize,
        resident: usize,
        plane: Plane,
    ) -> SparseDataflow {
        let (_, stream) = layer_plan_for(l, fft, tile, alpha, batch, resident, plane);
        SparseDataflow::from_stream(&stream)
    }

    #[test]
    fn deep_layer_keeps_all_tiles_resident() {
        // conv5_3-sized (512×512 channels, 9 tiles): Table 1's optimum is
        // Ps = P — the sparse MAC should load each kernel row exactly once.
        let d = sparse_dataflow_for(&layer(512, 512, 14, 9), 8, 6, 4, 1, 1, Plane::Full);
        assert_eq!(d.tile_block, 9);
    }

    #[test]
    fn deep_layer_batched_plan_spans_the_whole_batch() {
        // Same layer planned for B = 8: the tile population is 72, Eq. 12
        // still fits it on chip (at Ns = 256), so the plan keeps the whole
        // batch resident — each kernel row streams once per *batch* in the
        // fused forward, not once per image.
        let d = sparse_dataflow_for(&layer(512, 512, 14, 9), 8, 6, 4, 8, 1, Plane::Full);
        assert_eq!(d.tile_block, 72);
    }

    #[test]
    fn half_plane_budget_never_shrinks_residency() {
        // Eq. 12's BRAM feasibility scales with the per-tile spectrum
        // length; the half-plane stores 40 slots instead of 64, so any
        // geometry's chosen resident block can only stay or grow.
        for (cin, cout, h, tiles) in [(512, 512, 14, 9), (64, 64, 224, 1444)] {
            for batch in [1usize, 8] {
                let full =
                    sparse_dataflow_for(&layer(cin, cout, h, tiles), 8, 6, 4, batch, 1, Plane::Full);
                let half =
                    sparse_dataflow_for(&layer(cin, cout, h, tiles), 8, 6, 4, batch, 1, Plane::Half);
                assert!(
                    half.tile_block >= full.tile_block,
                    "{cin}x{cout} B={batch}: half block {} < full block {}",
                    half.tile_block,
                    full.tile_block
                );
            }
        }
    }

    #[test]
    fn early_layer_blocks_are_multiples_of_p_par() {
        // conv1_2-sized (64×64 channels, 1444 tiles): the optimizer streams
        // tile groups; whatever Ps it picks lies on the P'-lattice and is
        // at least one architecture group.
        let d = sparse_dataflow_for(&layer(64, 64, 224, 1444), 8, 6, 4, 1, 1, Plane::Full);
        assert!(d.tile_block >= 9, "got block {}", d.tile_block);
        assert!(d.tile_block == 1444 || d.tile_block % 9 == 0, "got block {}", d.tile_block);
    }

    #[test]
    fn batched_plan_never_shrinks_reuse() {
        // Growing B can only extend the Ps axis (the B=1 lattice is a
        // subset), so the chosen block never shrinks with batch size.
        for (cin, cout, h, tiles) in [(512, 512, 14, 9), (64, 64, 224, 1444)] {
            let mut prev = 0usize;
            for batch in [1usize, 2, 8, 32] {
                let d = sparse_dataflow_for(&layer(cin, cout, h, tiles), 8, 6, 4, batch, 1, Plane::Full);
                assert!(
                    d.tile_block >= prev,
                    "{cin}x{cout} B={batch}: block {} < previous {prev}",
                    d.tile_block
                );
                prev = d.tile_block;
            }
        }
    }
}
