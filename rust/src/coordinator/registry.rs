//! Multi-tenant model registry: several named engine pools in one process,
//! with zero-downtime weight swap.
//!
//! One [`ModelRegistry`] owns every model the process serves. Each model is
//! a [`ModelEntry`] whose state machine is
//! `loading → serving → (serving, swapped N times) → draining → gone`;
//! while **serving** it holds an `Arc<ModelPool>` — a [`Server`] engine
//! pool plus the per-model admission quota and resolved numerics the HTTP
//! front-end needs to route a request without touching the manifest.
//!
//! The swap protocol (`POST /admin/models/<name>` → [`ModelRegistry::begin_load`]):
//!
//! 1. Validate the spec against the manifest synchronously (cheap read, so
//!    bad requests fail with 4xx before any thread spawns); refuse
//!    concurrent builds of the same model (409).
//! 2. Build the new pool on a background thread — engines, Alg. 1 plans,
//!    Alg. 2 banked schedules; the old pool keeps serving the whole time.
//! 3. Atomically replace the entry's `Arc<ModelPool>` and bump the
//!    generation counter. New requests land on the new pool immediately.
//! 4. The build thread keeps the old `Arc` and waits for in-flight
//!    requests (admission guards hold clones) to finish before dropping it
//!    — so the blocking engine-pool join never runs on an event-loop
//!    worker, and no request is dropped.
//!
//! Unload (`DELETE /admin/models/<name>` → [`ModelRegistry::begin_remove`])
//! uses the same drain: the entry is marked draining (new requests get
//! 503), and a background thread retires the pool once it is idle.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use super::batcher::BatcherConfig;
use super::engine::{EngineOptions, WeightMode};
use super::metrics::{AdmissionMetrics, PoolMetrics};
use super::server::{Client, Server, ServerConfig};
use crate::err;
use crate::obs::{TraceConfig, TraceRing};
use crate::runtime::{Dtype, Plane, Runtime};
use crate::util::error::Result;

/// Everything needed to build one model's engine pool — the parsed form of
/// a `POST /admin/models/<name>` body (and of the CLI's boot flags).
#[derive(Debug, Clone)]
pub struct ModelSpec {
    /// Manifest variant the pool serves (e.g. `vgg16-cifar`, `resnet18`).
    pub preset: String,
    /// Compression ratio α (0 = manifest default, 1 = dense).
    pub alpha: usize,
    /// Weight-generation seed (fixed default keeps replicas bit-identical).
    pub seed: u64,
    /// Batch-closing policy for the pool's dispatcher.
    pub batcher: BatcherConfig,
    /// Executor workers in the pool (0 acts as 1).
    pub workers: usize,
    /// Engine knobs; build with [`EngineOptions::builder`].
    pub engine: EngineOptions,
    /// Per-model admission quota: in-flight requests past this get 429.
    pub max_inflight: usize,
}

impl Default for ModelSpec {
    fn default() -> Self {
        ModelSpec {
            preset: "vgg16-cifar".into(),
            alpha: 0,
            seed: 7,
            batcher: BatcherConfig::default(),
            workers: 1,
            engine: EngineOptions::default(),
            max_inflight: 64,
        }
    }
}

/// One serving pool: the engine [`Server`] plus everything the front-end
/// needs per-request without locks — resolved numerics, input shape, and
/// the admission quota counters.
pub struct ModelPool {
    pub name: String,
    /// Weight-swap generation (1 = boot build; +1 per live swap).
    pub generation: u64,
    pub spec: ModelSpec,
    /// Resolved α (after `resolve_alpha`) the pool's weights use.
    pub alpha: usize,
    /// `[c, h, w]` the model's inference inputs must have.
    pub input_shape: [usize; 3],
    /// Manifest-resolved accumulation dtype.
    pub dtype: Dtype,
    pub plane: Plane,
    pub max_inflight: usize,
    inflight: AtomicUsize,
    admitted: AtomicU64,
    rejected: AtomicU64,
    client: Client,
    /// The pool's trace-span ring (`GET /v1/models/<name>/trace`).
    trace: Arc<TraceRing>,
    /// Owns the engine pool; dropping the `ModelPool` gracefully shuts the
    /// workers down (dropped only by drain threads, never on a connection
    /// worker — see the module docs).
    _server: Server,
}

impl ModelPool {
    /// Cheap per-request handle into the engine pool.
    pub fn client(&self) -> Client {
        self.client.clone()
    }

    /// Try to reserve `slots` in-flight units (one per image). `None`
    /// means the quota is full — answer 429. The returned guard releases
    /// the slots on drop, so a connection that dies mid-request can never
    /// leak quota.
    pub fn try_admit(self: &Arc<Self>, slots: usize) -> Option<AdmitGuard> {
        if self.inflight.fetch_add(slots, Ordering::SeqCst) + slots > self.max_inflight {
            self.inflight.fetch_sub(slots, Ordering::SeqCst);
            self.rejected.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        self.admitted.fetch_add(1, Ordering::Relaxed);
        Some(AdmitGuard { pool: Arc::clone(self), slots })
    }

    /// Requests currently inside the pool.
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::SeqCst)
    }

    /// Admission/quota counters for `GET /v1/models/<name>/metrics`.
    pub fn admission(&self) -> AdmissionMetrics {
        AdmissionMetrics {
            inflight: self.inflight(),
            max_inflight: self.max_inflight,
            admitted: self.admitted.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            generation: self.generation,
        }
    }

    /// Pool latency/schedule metrics snapshot.
    pub fn pool_metrics(&self) -> Result<PoolMetrics> {
        self.client.pool_metrics()
    }

    /// The pool's per-request trace ring (shared with its workers).
    pub fn trace(&self) -> &Arc<TraceRing> {
        &self.trace
    }
}

/// RAII admission slot: holds the pool alive and releases the in-flight
/// count when dropped (response written, or connection died).
pub struct AdmitGuard {
    pool: Arc<ModelPool>,
    slots: usize,
}

impl Drop for AdmitGuard {
    fn drop(&mut self) {
        self.pool.inflight.fetch_sub(self.slots, Ordering::SeqCst);
    }
}

/// Lifecycle state of one registry entry.
enum ModelState {
    /// First build in progress; no pool yet.
    Loading,
    /// Answering traffic (swaps replace the `Arc` in place).
    Serving(Arc<ModelPool>),
    /// `DELETE` accepted: refusing new traffic while in-flight drains.
    Draining,
    /// Last (re)build failed; the error is reported on `/v1/models`.
    Failed(String),
}

/// One named model: its state machine plus swap bookkeeping.
pub struct ModelEntry {
    pub name: String,
    state: Mutex<ModelState>,
    /// Bumped on every successful build; the pool captures its value.
    generation: AtomicU64,
    /// Guards against concurrent builds of the same model (409).
    building: AtomicBool,
}

/// What a router learns when it asks for a model by name.
pub enum ModelFetch {
    /// Route the request into this pool.
    Ready(Arc<ModelPool>),
    /// First build still running — 503, retry later.
    Loading,
    /// Being unloaded — 503.
    Draining,
    /// Last build failed — 503 with the build error.
    Failed(String),
    /// No such model — 404.
    NotFound,
}

/// One row of `GET /v1/models`.
pub struct ModelStatus {
    pub name: String,
    /// `serving` | `loading` | `draining` | `failed`.
    pub status: &'static str,
    pub generation: u64,
    /// Populated while serving.
    pub preset: Option<String>,
    pub alpha: Option<usize>,
    pub workers: Option<usize>,
    pub max_inflight: Option<usize>,
    /// Build error while failed.
    pub error: Option<String>,
}

/// Errors from the `/admin` surface, pre-sorted by HTTP semantics.
#[derive(Debug)]
pub enum AdminError {
    /// Unknown model (404).
    NotFound,
    /// A build for this model is already running (409).
    Conflict(String),
    /// The spec doesn't validate against the manifest (400).
    BadRequest(String),
}

impl std::fmt::Display for AdminError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdminError::NotFound => write!(f, "model not found"),
            AdminError::Conflict(m) => write!(f, "conflict: {m}"),
            AdminError::BadRequest(m) => write!(f, "bad request: {m}"),
        }
    }
}

/// The process-wide model table. Shared as `Arc<ModelRegistry>` between the
/// HTTP front-end (lookups on every request) and admin handlers (swaps).
pub struct ModelRegistry {
    models: RwLock<BTreeMap<String, Arc<ModelEntry>>>,
    artifacts_dir: String,
    /// Model the legacy `/infer`, `/metrics`, `/healthz` aliases resolve to.
    default_model: String,
    /// How long a retired pool may wait for in-flight requests to finish
    /// before it is dropped anyway.
    drain_grace: Duration,
}

impl ModelRegistry {
    pub fn new(artifacts_dir: impl Into<String>, default_model: impl Into<String>) -> Self {
        ModelRegistry {
            models: RwLock::new(BTreeMap::new()),
            artifacts_dir: artifacts_dir.into(),
            default_model: default_model.into(),
            drain_grace: Duration::from_secs(30),
        }
    }

    /// Override the drain grace (tests use short values).
    pub fn with_drain_grace(mut self, grace: Duration) -> Self {
        self.drain_grace = grace;
        self
    }

    pub fn default_model(&self) -> &str {
        &self.default_model
    }

    pub fn artifacts_dir(&self) -> &str {
        &self.artifacts_dir
    }

    /// Look a model up for routing.
    pub fn fetch(&self, name: &str) -> ModelFetch {
        let entry = match self.models.read().unwrap().get(name) {
            Some(e) => Arc::clone(e),
            None => return ModelFetch::NotFound,
        };
        let state = entry.state.lock().unwrap();
        match &*state {
            ModelState::Serving(pool) => ModelFetch::Ready(Arc::clone(pool)),
            ModelState::Loading => ModelFetch::Loading,
            ModelState::Draining => ModelFetch::Draining,
            ModelState::Failed(e) => ModelFetch::Failed(e.clone()),
        }
    }

    /// Serving pool for `name`, if any (convenience over [`Self::fetch`]).
    pub fn pool(&self, name: &str) -> Option<Arc<ModelPool>> {
        match self.fetch(name) {
            ModelFetch::Ready(p) => Some(p),
            _ => None,
        }
    }

    /// Current weight-swap generation of `name` (0 if never built).
    pub fn generation_of(&self, name: &str) -> u64 {
        self.models
            .read()
            .unwrap()
            .get(name)
            .map(|e| e.generation.load(Ordering::SeqCst))
            .unwrap_or(0)
    }

    /// Admitted in-flight requests summed over every serving pool (drives
    /// the front-end's graceful-shutdown wait).
    pub fn total_inflight(&self) -> usize {
        let entries: Vec<Arc<ModelEntry>> =
            self.models.read().unwrap().values().cloned().collect();
        entries
            .iter()
            .filter_map(|e| match &*e.state.lock().unwrap() {
                ModelState::Serving(p) => Some(p.inflight()),
                _ => None,
            })
            .sum()
    }

    /// Status rows for `GET /v1/models` (sorted by name).
    pub fn list(&self) -> Vec<ModelStatus> {
        let entries: Vec<Arc<ModelEntry>> =
            self.models.read().unwrap().values().cloned().collect();
        entries
            .iter()
            .map(|e| {
                let state = e.state.lock().unwrap();
                let generation = e.generation.load(Ordering::SeqCst);
                match &*state {
                    ModelState::Serving(p) => ModelStatus {
                        name: e.name.clone(),
                        status: "serving",
                        generation,
                        preset: Some(p.spec.preset.clone()),
                        alpha: Some(p.alpha),
                        workers: Some(p.spec.workers.max(1)),
                        max_inflight: Some(p.max_inflight),
                        error: None,
                    },
                    ModelState::Loading => ModelStatus {
                        name: e.name.clone(),
                        status: "loading",
                        generation,
                        preset: None,
                        alpha: None,
                        workers: None,
                        max_inflight: None,
                        error: None,
                    },
                    ModelState::Draining => ModelStatus {
                        name: e.name.clone(),
                        status: "draining",
                        generation,
                        preset: None,
                        alpha: None,
                        workers: None,
                        max_inflight: None,
                        error: None,
                    },
                    ModelState::Failed(msg) => ModelStatus {
                        name: e.name.clone(),
                        status: "failed",
                        generation,
                        preset: None,
                        alpha: None,
                        workers: None,
                        max_inflight: None,
                        error: Some(msg.clone()),
                    },
                }
            })
            .collect()
    }

    /// Validate `spec` against the manifest without building anything —
    /// the synchronous half of `/admin` loads, so bad input fails with a
    /// 4xx before any thread spawns.
    pub fn validate(&self, spec: &ModelSpec) -> std::result::Result<(), AdminError> {
        let rt = Runtime::open(&self.artifacts_dir)
            .map_err(|e| AdminError::BadRequest(format!("artifacts unreadable: {e}")))?;
        rt.manifest
            .variant(&spec.preset)
            .map_err(|e| AdminError::BadRequest(e.to_string()))?;
        Ok(())
    }

    /// Build `name`'s pool synchronously and mark it serving. Used at boot
    /// (`serve` blocks until every model is up) and by tests.
    pub fn load_blocking(&self, name: &str, spec: ModelSpec) -> Result<u64> {
        let entry = self.entry_for(name);
        if entry
            .building
            .compare_exchange(false, true, Ordering::SeqCst, Ordering::SeqCst)
            .is_err()
        {
            return Err(err!("model {name:?} is already building"));
        }
        let generation = entry.generation.load(Ordering::SeqCst) + 1;
        let built = build_pool(&self.artifacts_dir, name, &spec, generation);
        let out = self.finish_build(&entry, built);
        entry.building.store(false, Ordering::SeqCst);
        out
    }

    /// Start a background (re)build of `name` — the `POST /admin` path.
    ///
    /// Synchronous part: manifest validation (4xx) and the concurrent-build
    /// check (409). Everything expensive happens on the spawned thread;
    /// while it runs, an existing pool keeps serving. On success the new
    /// pool is swapped in atomically and the old one drains in the same
    /// background thread.
    pub fn begin_load(
        self: &Arc<Self>,
        name: &str,
        spec: ModelSpec,
    ) -> std::result::Result<(), AdminError> {
        self.validate(&spec)?;
        let entry = self.entry_for(name);
        if entry
            .building
            .compare_exchange(false, true, Ordering::SeqCst, Ordering::SeqCst)
            .is_err()
        {
            return Err(AdminError::Conflict(format!("model {name:?} is already building")));
        }
        {
            // A draining or failed entry restarts from Loading; a serving
            // entry keeps serving its current pool until the swap lands.
            let mut state = entry.state.lock().unwrap();
            match &*state {
                ModelState::Serving(_) => {}
                _ => *state = ModelState::Loading,
            }
        }
        let registry = Arc::clone(self);
        let entry_bg = Arc::clone(&entry);
        let name_bg = name.to_string();
        std::thread::Builder::new()
            .name(format!("sf-load-{name}"))
            .spawn(move || {
                let generation = entry_bg.generation.load(Ordering::SeqCst) + 1;
                let built = build_pool(&registry.artifacts_dir, &name_bg, &spec, generation);
                let _ = registry.finish_build(&entry_bg, built);
                entry_bg.building.store(false, Ordering::SeqCst);
            })
            .expect("spawn model build thread");
        Ok(())
    }

    /// Start draining + unloading `name` — the `DELETE /admin` path. The
    /// entry refuses new traffic immediately; a background thread waits
    /// for in-flight requests, shuts the pool down, and removes the entry.
    pub fn begin_remove(self: &Arc<Self>, name: &str) -> std::result::Result<(), AdminError> {
        let entry = match self.models.read().unwrap().get(name) {
            Some(e) => Arc::clone(e),
            None => return Err(AdminError::NotFound),
        };
        if entry.building.load(Ordering::SeqCst) {
            return Err(AdminError::Conflict(format!("model {name:?} is building")));
        }
        let old = {
            let mut state = entry.state.lock().unwrap();
            match std::mem::replace(&mut *state, ModelState::Draining) {
                ModelState::Serving(pool) => Some(pool),
                other => {
                    // nothing to drain; keep whatever terminal state it had
                    *state = other;
                    None
                }
            }
        };
        let registry = Arc::clone(self);
        let name_bg = name.to_string();
        std::thread::Builder::new()
            .name(format!("sf-drain-{name}"))
            .spawn(move || {
                if let Some(pool) = old {
                    drain_pool(pool, registry.drain_grace);
                }
                registry.models.write().unwrap().remove(&name_bg);
            })
            .expect("spawn model drain thread");
        Ok(())
    }

    /// Drop every pool gracefully (process shutdown). Blocks while engine
    /// pools join, so call it from the main thread only.
    pub fn shutdown(&self) {
        let entries: Vec<Arc<ModelEntry>> = {
            let mut models = self.models.write().unwrap();
            let drained = models.values().cloned().collect();
            models.clear();
            drained
        };
        for entry in entries {
            let mut state = entry.state.lock().unwrap();
            if let ModelState::Serving(pool) =
                std::mem::replace(&mut *state, ModelState::Draining)
            {
                drain_pool(pool, self.drain_grace);
            }
        }
    }

    /// Existing entry for `name`, or a fresh `Loading` one.
    fn entry_for(&self, name: &str) -> Arc<ModelEntry> {
        let mut models = self.models.write().unwrap();
        Arc::clone(models.entry(name.to_string()).or_insert_with(|| {
            Arc::new(ModelEntry {
                name: name.to_string(),
                state: Mutex::new(ModelState::Loading),
                generation: AtomicU64::new(0),
                building: AtomicBool::new(false),
            })
        }))
    }

    /// Publish a finished build: swap the pool in (bumping the generation)
    /// or record the failure. Returns the new generation. The *old* pool,
    /// if any, is drained here — on the calling (background/boot) thread,
    /// never on a connection worker.
    fn finish_build(
        &self,
        entry: &Arc<ModelEntry>,
        built: Result<ModelPool>,
    ) -> Result<u64> {
        match built {
            Ok(pool) => {
                let generation = pool.generation;
                let old = {
                    let mut state = entry.state.lock().unwrap();
                    entry.generation.store(generation, Ordering::SeqCst);
                    match std::mem::replace(&mut *state, ModelState::Serving(Arc::new(pool))) {
                        ModelState::Serving(old) => Some(old),
                        _ => None,
                    }
                };
                if let Some(old) = old {
                    drain_pool(old, self.drain_grace);
                }
                Ok(generation)
            }
            Err(e) => {
                let mut state = entry.state.lock().unwrap();
                // never clobber a live pool with a failed rebuild — the
                // old weights keep serving and the error is only reported
                if !matches!(&*state, ModelState::Serving(_)) {
                    *state = ModelState::Failed(e.to_string());
                }
                Err(e)
            }
        }
    }
}

/// Wait for every admission guard on `pool` to drop (bounded by `grace`),
/// then drop it — which joins the engine pool's threads gracefully.
fn drain_pool(pool: Arc<ModelPool>, grace: Duration) {
    let deadline = Instant::now() + grace;
    while Arc::strong_count(&pool) > 1 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    drop(pool);
}

/// Build one model's engine pool (the expensive part: engines, Alg. 1
/// plans, Alg. 2 banked schedules — all inside [`Server::start`]).
fn build_pool(
    artifacts_dir: &str,
    name: &str,
    spec: &ModelSpec,
    generation: u64,
) -> Result<ModelPool> {
    let rt = Runtime::open(artifacts_dir)?;
    let vdesc = rt.manifest.variant(&spec.preset)?.clone();
    let alpha = rt.manifest.resolve_alpha(spec.alpha);
    let dtype = rt.manifest.resolve_dtype(spec.engine.dtype);
    let input_shape = [vdesc.input_c, vdesc.input_hw, vdesc.input_hw];
    drop(rt);
    let server = Server::start(ServerConfig {
        artifacts_dir: artifacts_dir.to_string(),
        variant: spec.preset.clone(),
        mode: WeightMode::from_alpha(alpha),
        seed: spec.seed,
        batcher: spec.batcher,
        workers: spec.workers,
        engine: spec.engine,
        trace: TraceConfig::default(),
    })?;
    let client = server.client();
    let trace = server.trace();
    Ok(ModelPool {
        name: name.to_string(),
        generation,
        spec: spec.clone(),
        alpha,
        input_shape,
        dtype,
        plane: spec.engine.plane,
        max_inflight: spec.max_inflight,
        inflight: AtomicUsize::new(0),
        admitted: AtomicU64::new(0),
        rejected: AtomicU64::new(0),
        client,
        trace,
        _server: server,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_model_is_not_found() {
        let reg = ModelRegistry::new("artifacts", "demo");
        assert!(matches!(reg.fetch("nope"), ModelFetch::NotFound));
        assert!(reg.pool("nope").is_none());
        assert!(reg.list().is_empty());
    }

    #[test]
    fn remove_unknown_model_errors() {
        let reg = Arc::new(ModelRegistry::new("artifacts", "demo"));
        assert!(matches!(reg.begin_remove("nope"), Err(AdminError::NotFound)));
    }

    #[test]
    fn admin_error_display() {
        assert!(AdminError::NotFound.to_string().contains("not found"));
        assert!(AdminError::Conflict("x".into()).to_string().contains("conflict"));
        assert!(AdminError::BadRequest("y".into()).to_string().contains("bad request"));
    }
}
