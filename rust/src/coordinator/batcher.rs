//! Deadline/size-bounded request batching.
//!
//! The serving loop pulls individual requests from an MPSC queue and groups
//! them into batches: a batch closes when it reaches `max_batch` requests
//! or when `max_wait` has elapsed since its first request — the standard
//! latency/throughput knob of serving systems. Pure logic (no threads), so
//! it is property-testable: no request is ever dropped, duplicated, or
//! reordered.

use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(20) }
    }
}

/// Incremental batch builder.
#[derive(Debug)]
pub struct Batcher<T> {
    cfg: BatcherConfig,
    pending: Vec<T>,
    opened_at: Option<Instant>,
}

impl<T> Batcher<T> {
    pub fn new(cfg: BatcherConfig) -> Self {
        assert!(cfg.max_batch >= 1);
        Batcher { cfg, pending: Vec::new(), opened_at: None }
    }

    /// Add a request; returns a full batch if this push closed it.
    pub fn push(&mut self, item: T, now: Instant) -> Option<Vec<T>> {
        if self.pending.is_empty() {
            self.opened_at = Some(now);
        }
        self.pending.push(item);
        if self.pending.len() >= self.cfg.max_batch {
            return self.take();
        }
        None
    }

    /// Deadline check: returns the batch if the oldest request has waited
    /// past `max_wait`.
    pub fn poll(&mut self, now: Instant) -> Option<Vec<T>> {
        match self.opened_at {
            Some(t0) if !self.pending.is_empty() && now.duration_since(t0) >= self.cfg.max_wait => {
                self.take()
            }
            _ => None,
        }
    }

    /// Flush whatever is pending (shutdown path).
    pub fn take(&mut self) -> Option<Vec<T>> {
        if self.pending.is_empty() {
            return None;
        }
        self.opened_at = None;
        Some(std::mem::take(&mut self.pending))
    }

    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// Time until the current batch's deadline (serving loop's park time).
    pub fn time_to_deadline(&self, now: Instant) -> Option<Duration> {
        self.opened_at.map(|t0| {
            let elapsed = now.duration_since(t0);
            self.cfg.max_wait.saturating_sub(elapsed)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;

    fn cfg(max_batch: usize, ms: u64) -> BatcherConfig {
        BatcherConfig { max_batch, max_wait: Duration::from_millis(ms) }
    }

    #[test]
    fn closes_on_size() {
        let mut b = Batcher::new(cfg(3, 1000));
        let t = Instant::now();
        assert!(b.push(1, t).is_none());
        assert!(b.push(2, t).is_none());
        let batch = b.push(3, t).unwrap();
        assert_eq!(batch, vec![1, 2, 3]);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn closes_on_deadline() {
        let mut b = Batcher::new(cfg(100, 10));
        let t0 = Instant::now();
        b.push("a", t0);
        assert!(b.poll(t0 + Duration::from_millis(5)).is_none());
        let batch = b.poll(t0 + Duration::from_millis(11)).unwrap();
        assert_eq!(batch, vec!["a"]);
    }

    #[test]
    fn deadline_resets_per_batch() {
        let mut b = Batcher::new(cfg(2, 10));
        let t0 = Instant::now();
        b.push(1, t0);
        b.push(2, t0); // closes by size
        b.take();
        b.push(3, t0 + Duration::from_millis(50));
        // new batch's clock starts at its own first push
        assert!(b.poll(t0 + Duration::from_millis(55)).is_none());
        assert!(b.poll(t0 + Duration::from_millis(61)).is_some());
    }

    #[test]
    fn no_loss_no_dup_no_reorder() {
        forall("batcher conservation", 100, |rng| {
            let max_batch = rng.range(1, 10);
            let mut b = Batcher::new(cfg(max_batch, 5));
            let n = rng.range(1, 50);
            let t0 = Instant::now();
            let mut out: Vec<usize> = Vec::new();
            let mut now = t0;
            for i in 0..n {
                // random time advance, sometimes past the deadline
                now += Duration::from_millis(rng.range(0, 8) as u64);
                if let Some(batch) = b.poll(now) {
                    out.extend(batch);
                }
                if let Some(batch) = b.push(i, now) {
                    out.extend(batch);
                }
            }
            if let Some(batch) = b.take() {
                out.extend(batch);
            }
            assert_eq!(out, (0..n).collect::<Vec<_>>());
        });
    }

    #[test]
    fn empty_batcher_is_inert() {
        // the dispatch loop leans on these: an empty batcher must neither
        // close batches nor report a deadline to park on
        let mut b: Batcher<u32> = Batcher::new(cfg(4, 10));
        let t = Instant::now();
        assert!(b.poll(t).is_none());
        assert!(b.poll(t + Duration::from_secs(60)).is_none());
        assert!(b.time_to_deadline(t).is_none());
        assert!(b.take().is_none());
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn closes_exactly_at_deadline() {
        // the deadline boundary is inclusive (`>=`): polling at exactly
        // t0 + max_wait closes the batch, so a worker woken by a
        // recv_timeout of `time_to_deadline` never spins on a zero wait
        let mut b = Batcher::new(cfg(10, 10));
        let t0 = Instant::now();
        b.push(1, t0);
        let deadline = t0 + Duration::from_millis(10);
        assert_eq!(b.time_to_deadline(deadline).unwrap(), Duration::ZERO);
        assert_eq!(b.poll(deadline).unwrap(), vec![1]);
    }

    #[test]
    fn reopens_cleanly_after_take() {
        let mut b = Batcher::new(cfg(10, 10));
        let t0 = Instant::now();
        b.push(1, t0);
        assert_eq!(b.take().unwrap(), vec![1]);
        // take() clears the deadline: no stale closes, no park hint
        assert!(b.time_to_deadline(t0).is_none());
        assert!(b.poll(t0 + Duration::from_secs(1)).is_none());
        // a later push reopens with a fresh clock at its own `now`
        let t1 = t0 + Duration::from_millis(100);
        assert!(b.push(2, t1).is_none());
        assert_eq!(b.time_to_deadline(t1).unwrap(), Duration::from_millis(10));
        assert!(b.poll(t1 + Duration::from_millis(9)).is_none());
        assert_eq!(b.poll(t1 + Duration::from_millis(10)).unwrap(), vec![2]);
    }

    #[test]
    fn park_time_hint() {
        let mut b = Batcher::new(cfg(10, 20));
        let t0 = Instant::now();
        assert!(b.time_to_deadline(t0).is_none());
        b.push(1, t0);
        let d = b.time_to_deadline(t0 + Duration::from_millis(5)).unwrap();
        assert_eq!(d, Duration::from_millis(15));
        // past deadline → zero
        let z = b.time_to_deadline(t0 + Duration::from_millis(30)).unwrap();
        assert_eq!(z, Duration::ZERO);
    }
}
