//! Lifetime-based activation arena for the graph executor.
//!
//! The paper's straight-line VGG forward needs exactly one live activation
//! between layers, so the engine historically allocated a fresh buffer per
//! layer and dropped the previous one implicitly. Residual graphs break
//! that: a shortcut tensor stays live across its whole block span, and a
//! naive per-layer allocator either copies it along (wasted bandwidth) or
//! keeps every tensor alive (peak = Σ all tensors). This module does what
//! reuse-aware accelerator allocators (ShortcutFusion, PAPERS.md
//! arXiv 2106.08167) do offline: compute each tensor's last use from the
//! DAG, then linear-scan tensors into slots so a tensor only occupies
//! memory across its actual lifetime. The plan is static — a property of
//! the graph, computed once at engine startup — and the executor just
//! indexes slots, so the request path pays nothing for the analysis.
//!
//! Accounting ([`ArenaMetrics`]) is per single image at f32: the batched
//! forward scales every slot by B uniformly, so the reuse ratio is
//! batch-invariant.

use crate::coordinator::metrics::ArenaMetrics;
use crate::model::{check_graph, ConvShape, GraphOp};
use crate::runtime::VariantEntry;
use crate::util::error::Result;

/// A static slot assignment for one variant's activation graph.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArenaPlan {
    /// Execution order (the declared DAG, or the implicit chain).
    pub steps: Vec<GraphOp>,
    /// `slot_of[t]` = arena slot holding tensor `t` (0 = network input).
    pub slot_of: Vec<usize>,
    /// `free_after[i]` = slots whose occupant dies after step `i` runs.
    pub free_after: Vec<Vec<usize>>,
    /// `(channels, spatial side)` per tensor id, from [`check_graph`].
    pub shapes: Vec<(usize, usize)>,
    /// Number of distinct slots (the arena's concurrent-live tensor count).
    pub n_slots: usize,
    /// Static accounting published to `Metrics`/`/metrics`.
    pub metrics: ArenaMetrics,
}

fn tensor_bytes(shape: (usize, usize)) -> u64 {
    let (c, s) = shape;
    (c * s * s) as u64 * std::mem::size_of::<f32>() as u64
}

impl ArenaPlan {
    /// Plan a manifest variant. `reuse = false` gives every tensor its own
    /// slot — the no-reuse reference the property tests compare against.
    pub fn for_variant(v: &VariantEntry, reuse: bool) -> Result<ArenaPlan> {
        Self::build(v.graph_ops(), &v.conv_shapes(), v.input_c, v.input_hw, reuse)
    }

    /// Plan an arbitrary validated graph.
    pub fn build(
        steps: Vec<GraphOp>,
        layers: &[ConvShape],
        input_c: usize,
        input_hw: usize,
        reuse: bool,
    ) -> Result<ArenaPlan> {
        let shapes = check_graph(&steps, layers, input_c, input_hw)?;
        let n_tensors = shapes.len();
        // last_use[t] = index of the last step reading t. The final tensor
        // is read by no step — it escapes to the FC head — so it never
        // frees inside the plan.
        let mut last_use = vec![usize::MAX; n_tensors];
        for (i, op) in steps.iter().enumerate() {
            for t in op.reads() {
                last_use[t] = i;
            }
        }
        // Linear scan in execution order: each produced tensor takes the
        // lowest-numbered free slot; a tensor's slot frees right after its
        // last reading step. check_graph guarantees topological order, so
        // one forward pass is the whole analysis.
        let mut slot_of = vec![usize::MAX; n_tensors];
        let mut slot_cap: Vec<u64> = Vec::new(); // max occupant bytes per slot
        let mut free: Vec<bool> = Vec::new();
        let mut free_after: Vec<Vec<usize>> = vec![Vec::new(); steps.len()];
        let mut claim = |t: usize, slot_cap: &mut Vec<u64>, free: &mut Vec<bool>| {
            let bytes = tensor_bytes(shapes[t]);
            let slot = if reuse {
                free.iter().position(|&f| f).unwrap_or(free.len())
            } else {
                free.len()
            };
            if slot == free.len() {
                free.push(false);
                slot_cap.push(bytes);
            } else {
                free[slot] = false;
                slot_cap[slot] = slot_cap[slot].max(bytes);
            }
            slot_of[t] = slot;
        };
        claim(0, &mut slot_cap, &mut free);
        for i in 0..steps.len() {
            claim(i + 1, &mut slot_cap, &mut free);
            // free inputs whose last use is this step (dedup: Add{a,b} with
            // a == b would list the slot twice)
            for t in steps[i].reads() {
                let slot = slot_of[t];
                if last_use[t] == i && !free_after[i].contains(&slot) {
                    free_after[i].push(slot);
                    free[slot] = true;
                }
            }
        }
        let n_slots = slot_cap.len();
        let metrics = ArenaMetrics {
            tensors: n_tensors,
            slots: n_slots,
            reused: n_tensors - n_slots,
            peak_activation_bytes: slot_cap.iter().sum(),
            no_reuse_bytes: shapes.iter().map(|&s| tensor_bytes(s)).sum(),
        };
        Ok(ArenaPlan { steps, slot_of, free_after, shapes, n_slots, metrics })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    fn shape(cin: usize, cout: usize, h: usize, pool: bool) -> ConvShape {
        ConvShape { cin, cout, h, pool_after: pool }
    }

    #[test]
    fn chain_ping_pongs_two_slots() {
        // equal-size chain: x → conv → conv → conv needs exactly 2 slots
        let layers = vec![shape(8, 8, 16, false); 3];
        let p = ArenaPlan::build(GraphOp::chain(3), &layers, 8, 16, true).unwrap();
        assert_eq!(p.n_slots, 2);
        assert_eq!(p.slot_of, vec![0, 1, 0, 1]);
        assert_eq!(p.metrics.peak_activation_bytes, 2 * 8 * 16 * 16 * 4);
        assert_eq!(p.metrics.no_reuse_bytes, 4 * 8 * 16 * 16 * 4);
        assert_eq!(p.metrics.reused, 2);
    }

    #[test]
    fn diamond_needs_three_slots() {
        // t1 fans out to two branches joined by an add: optimum is 3 slots
        // (t1 stays live while both branch outputs exist)
        let layers = vec![
            shape(1, 8, 16, false), // t1 = conv(t0)
            shape(8, 8, 16, false), // t2 = conv(t1)
            shape(8, 8, 16, false), // t3 = conv(t1)
        ];
        let steps = vec![
            GraphOp::Conv { conv: 0, input: 0 },
            GraphOp::Conv { conv: 1, input: 1 },
            GraphOp::Conv { conv: 2, input: 1 },
            GraphOp::Add { a: 2, b: 3 },
        ];
        let p = ArenaPlan::build(steps, &layers, 1, 16, true).unwrap();
        assert_eq!(p.n_slots, 3);
        // t0 (slot 0) dies at step 0; t1 holds its slot across both branches
        assert_eq!(p.free_after[0], vec![0]);
        assert_eq!(p.slot_of[1], 1);
        assert!(p.metrics.peak_activation_bytes < p.metrics.no_reuse_bytes);
    }

    #[test]
    fn no_reuse_mode_gives_every_tensor_a_slot() {
        let layers = vec![shape(8, 8, 16, false); 3];
        let p = ArenaPlan::build(GraphOp::chain(3), &layers, 8, 16, false).unwrap();
        assert_eq!(p.n_slots, 4);
        assert_eq!(p.metrics.reused, 0);
        assert_eq!(p.metrics.peak_activation_bytes, p.metrics.no_reuse_bytes);
    }

    #[test]
    fn builtin_residual_presets_reuse() {
        let m = Manifest::builtin();
        // demo-residual: 7 tensors in 3 slots, peak 32 KiB vs 51 KiB flat
        let p = ArenaPlan::for_variant(m.variant("demo-residual").unwrap(), true).unwrap();
        assert_eq!((p.metrics.tensors, p.n_slots), (7, 3));
        assert_eq!(p.metrics.peak_activation_bytes, 32768);
        assert_eq!(p.metrics.no_reuse_bytes, 52224);
        // resnet18: shortcuts never force a fourth slot
        let p = ArenaPlan::for_variant(m.variant("resnet18").unwrap(), true).unwrap();
        assert_eq!((p.metrics.tensors, p.n_slots), (29, 3));
        assert_eq!(p.metrics.peak_activation_bytes, 196608);
        assert_eq!(p.metrics.no_reuse_bytes, 872448);
        assert!(p.metrics.peak_activation_bytes < p.metrics.no_reuse_bytes);
        // chain presets keep the historical two-buffer footprint
        let p = ArenaPlan::for_variant(m.variant("demo").unwrap(), true).unwrap();
        assert_eq!(p.n_slots, 2);
        assert_eq!(p.metrics.peak_activation_bytes, 3072);
    }

    #[test]
    fn free_lists_cover_every_dead_tensor_once() {
        let m = Manifest::builtin();
        for name in ["demo", "demo-residual", "resnet18", "vgg16-cifar"] {
            let p = ArenaPlan::for_variant(m.variant(name).unwrap(), true).unwrap();
            let freed: usize = p.free_after.iter().map(Vec::len).sum();
            // the final tensor never frees, so at most tensors - 1 frees
            assert!(freed <= p.metrics.tensors - 1, "{name}");
            for slots in &p.free_after {
                for &s in slots {
                    assert!(s < p.n_slots, "{name}");
                }
            }
        }
    }
}
