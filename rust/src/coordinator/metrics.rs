//! Serving metrics: latency distribution + throughput.
//!
//! Each executor worker owns one [`Metrics`] (thread-confined, like its
//! engine); the server merges the per-worker accumulators into one
//! [`PoolMetrics`] snapshot on demand.

use std::time::Duration;

/// Latency/throughput accumulator (single-threaded; each executor worker
/// owns one and snapshots it on demand).
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    latencies_us: Vec<u64>,
    batches: u64,
    batch_sizes: u64,
    started: Option<std::time::Instant>,
    finished: Option<std::time::Instant>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_request(&mut self, latency: Duration) {
        let now = std::time::Instant::now();
        if self.started.is_none() {
            self.started = Some(now);
        }
        self.finished = Some(now);
        self.latencies_us.push(latency.as_micros() as u64);
    }

    pub fn record_batch(&mut self, size: usize) {
        self.batches += 1;
        self.batch_sizes += size as u64;
    }

    /// Fold another accumulator into this one (per-worker → merged
    /// snapshot): latencies concatenate, batch counters add, and the
    /// observation window spans both.
    pub fn merge_from(&mut self, other: &Metrics) {
        self.latencies_us.extend_from_slice(&other.latencies_us);
        self.batches += other.batches;
        self.batch_sizes += other.batch_sizes;
        self.started = match (self.started, other.started) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
        self.finished = match (self.finished, other.finished) {
            (Some(a), Some(b)) => Some(a.max(b)),
            (a, b) => a.or(b),
        };
    }

    pub fn count(&self) -> usize {
        self.latencies_us.len()
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batch_sizes as f64 / self.batches as f64
        }
    }

    fn percentile(&self, p: f64) -> Option<Duration> {
        if self.latencies_us.is_empty() {
            return None;
        }
        let mut v = self.latencies_us.clone();
        v.sort_unstable();
        let idx = ((v.len() - 1) as f64 * p).round() as usize;
        Some(Duration::from_micros(v[idx]))
    }

    pub fn p50(&self) -> Option<Duration> {
        self.percentile(0.50)
    }

    pub fn p95(&self) -> Option<Duration> {
        self.percentile(0.95)
    }

    pub fn p99(&self) -> Option<Duration> {
        self.percentile(0.99)
    }

    pub fn mean(&self) -> Option<Duration> {
        if self.latencies_us.is_empty() {
            return None;
        }
        let sum: u64 = self.latencies_us.iter().sum();
        Some(Duration::from_micros(sum / self.latencies_us.len() as u64))
    }

    /// Requests/second over the observation window.
    pub fn throughput(&self) -> f64 {
        match (self.started, self.finished) {
            (Some(a), Some(b)) if b > a => {
                self.count() as f64 / b.duration_since(a).as_secs_f64()
            }
            _ => 0.0,
        }
    }

    pub fn report(&self) -> String {
        format!(
            "n={} mean={:?} p50={:?} p95={:?} p99={:?} batch={:.1} thpt={:.1}/s",
            self.count(),
            self.mean().unwrap_or_default(),
            self.p50().unwrap_or_default(),
            self.p95().unwrap_or_default(),
            self.p99().unwrap_or_default(),
            self.mean_batch_size(),
            self.throughput(),
        )
    }
}

/// Pool-wide snapshot: the merged view plus one [`Metrics`] per executor
/// worker (index = worker id), so per-worker load skew is observable.
#[derive(Debug, Clone, Default)]
pub struct PoolMetrics {
    pub merged: Metrics,
    pub per_worker: Vec<Metrics>,
}

impl PoolMetrics {
    /// Merge a vector of per-worker accumulators into a snapshot.
    pub fn from_workers(per_worker: Vec<Metrics>) -> Self {
        let mut merged = Metrics::new();
        for m in &per_worker {
            merged.merge_from(m);
        }
        PoolMetrics { merged, per_worker }
    }

    /// One line per worker plus the merged line.
    pub fn report(&self) -> String {
        let mut out = String::new();
        for (i, m) in self.per_worker.iter().enumerate() {
            out.push_str(&format!("worker {i}: {}\n", m.report()));
        }
        out.push_str(&format!("merged:   {}", self.merged.report()));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut m = Metrics::new();
        for us in [100u64, 200, 300, 400, 500, 1000, 2000] {
            m.record_request(Duration::from_micros(us));
        }
        assert_eq!(m.count(), 7);
        assert!(m.p50().unwrap() <= m.p95().unwrap());
        assert!(m.p95().unwrap() <= m.p99().unwrap());
        assert_eq!(m.p50().unwrap(), Duration::from_micros(400));
    }

    #[test]
    fn empty_metrics_safe() {
        let m = Metrics::new();
        assert!(m.p50().is_none());
        assert_eq!(m.throughput(), 0.0);
        assert!(m.report().contains("n=0"));
    }

    #[test]
    fn batch_accounting() {
        let mut m = Metrics::new();
        m.record_batch(4);
        m.record_batch(8);
        assert!((m.mean_batch_size() - 6.0).abs() < 1e-12);
    }

    #[test]
    fn merge_concatenates_and_spans() {
        let mut a = Metrics::new();
        a.record_batch(2);
        a.record_request(Duration::from_micros(100));
        a.record_request(Duration::from_micros(300));
        let mut b = Metrics::new();
        b.record_batch(1);
        b.record_request(Duration::from_micros(200));
        let snap = PoolMetrics::from_workers(vec![a, b]);
        assert_eq!(snap.merged.count(), 3);
        assert!((snap.merged.mean_batch_size() - 1.5).abs() < 1e-12);
        assert_eq!(snap.merged.p50().unwrap(), Duration::from_micros(200));
        assert_eq!(snap.per_worker.len(), 2);
        assert!(snap.report().contains("worker 1"));
    }

    #[test]
    fn merge_with_empty_is_identity() {
        let mut a = Metrics::new();
        a.record_request(Duration::from_micros(50));
        let mut merged = Metrics::new();
        merged.merge_from(&Metrics::new());
        merged.merge_from(&a);
        merged.merge_from(&Metrics::new());
        assert_eq!(merged.count(), 1);
        assert_eq!(merged.p50().unwrap(), Duration::from_micros(50));
    }
}
