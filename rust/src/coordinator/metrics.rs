//! Serving metrics: latency distribution + throughput, plus the static
//! per-layer scheduling quality of the engine's Alg. 2 access plans.
//!
//! Each executor worker owns one [`Metrics`] (thread-confined, like its
//! engine); the server merges the per-worker accumulators into one
//! [`PoolMetrics`] snapshot on demand. Scheduling metrics
//! ([`ScheduleMetrics`]) are computed once at engine startup — they are a
//! property of the weights + scheduler, not of traffic — and ride along in
//! every snapshot so serving dashboards see PE utilization,
//! cycles-vs-lower-bound, and simulated bank conflicts next to latency.

use std::time::Duration;

use crate::report::fmt_pct;
use crate::schedule::ScheduleStats;

/// One conv layer's scheduling quality (static, from
/// [`crate::schedule::LayerSchedule`] at engine startup).
#[derive(Debug, Clone, PartialEq)]
pub struct LayerScheduleMetrics {
    /// Manifest layer name (e.g. `conv5_3`).
    pub layer: String,
    /// Aggregate cycles / lower bound / reads / bank conflicts.
    pub stats: ScheduleStats,
}

/// Engine-wide scheduling metrics: one entry per pruned conv layer.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ScheduleMetrics {
    /// Scheduler label ([`crate::schedule::SchedulePolicy::label`]).
    pub scheduler: String,
    pub layers: Vec<LayerScheduleMetrics>,
}

impl ScheduleMetrics {
    /// Read-weighted network PE utilization (paper Eq. 14 across layers).
    pub fn avg_pe_utilization(&self) -> f64 {
        let reads: u64 = self.layers.iter().map(|l| l.stats.reads).sum();
        let slots: u64 = self.layers.iter().map(|l| l.stats.slots).sum();
        if slots == 0 {
            return 1.0;
        }
        reads as f64 / slots as f64
    }

    pub fn total_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.stats.cycles).sum()
    }

    pub fn total_lower_bound(&self) -> u64 {
        self.layers.iter().map(|l| l.stats.lower_bound).sum()
    }

    pub fn total_bank_conflicts(&self) -> u64 {
        self.layers.iter().map(|l| l.stats.bank_conflicts).sum()
    }

    /// One summary line (appended to the latency report).
    pub fn report(&self) -> String {
        let lb = self.total_lower_bound().max(1);
        format!(
            "sched[{}]: PE util {} cycles {} (lb {}, x{:.3}) bank-conflicts {}",
            self.scheduler,
            fmt_pct(self.avg_pe_utilization()),
            self.total_cycles(),
            self.total_lower_bound(),
            self.total_cycles() as f64 / lb as f64,
            self.total_bank_conflicts(),
        )
    }

    /// Per-layer breakdown, one line per layer.
    pub fn report_layers(&self) -> String {
        let mut out = String::new();
        for l in &self.layers {
            out.push_str(&format!(
                "{}: util {} cycles {} lb {} conflicts {}\n",
                l.layer,
                fmt_pct(l.stats.pe_utilization()),
                l.stats.cycles,
                l.stats.lower_bound,
                l.stats.bank_conflicts,
            ));
        }
        out
    }
}

/// Static activation-arena accounting for one engine: how the graph
/// executor's slot allocator packed the variant's tensor lifetimes
/// (computed once at startup by [`crate::coordinator::ArenaPlan`] — a
/// property of the graph, not of traffic). All byte figures are per single
/// image at f32; the batched forward scales every slot by B identically.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct ArenaMetrics {
    /// Tensors in the variant's activation graph (input + one per node).
    pub tensors: usize,
    /// Arena slots actually allocated.
    pub slots: usize,
    /// Tensors placed into a previously-freed slot (`tensors - slots`).
    pub reused: usize,
    /// Peak resident activation bytes: Σ per-slot max occupant size.
    pub peak_activation_bytes: u64,
    /// What per-layer fresh buffers would hold: Σ all tensor sizes.
    pub no_reuse_bytes: u64,
}

impl ArenaMetrics {
    /// One summary line (appended to the latency report).
    pub fn report(&self) -> String {
        format!(
            "arena: peak {} B (no-reuse {} B, {}) slots {}/{} tensors",
            self.peak_activation_bytes,
            self.no_reuse_bytes,
            fmt_pct(self.peak_activation_bytes as f64 / self.no_reuse_bytes.max(1) as f64),
            self.slots,
            self.tensors,
        )
    }
}

/// Per-model admission accounting for one registry pool: the quota state
/// and lifetime counters the event-driven front-end updates on every
/// request, plus the weight-swap generation (bumped by each successful
/// `POST /admin/models/<name>` build). Surfaced on
/// `GET /v1/models/<name>/metrics` next to the pool's [`PoolMetrics`].
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct AdmissionMetrics {
    /// Requests currently inside the model's engine pool.
    pub inflight: usize,
    /// Admission quota: requests past this fast-fail with 429.
    pub max_inflight: usize,
    /// Lifetime requests admitted past the quota gate.
    pub admitted: u64,
    /// Lifetime requests rejected at the quota gate (the 429s).
    pub rejected: u64,
    /// Weight-swap generation: 1 for the boot build, +1 per live swap.
    pub generation: u64,
}

impl AdmissionMetrics {
    /// One summary line for logs and reports.
    pub fn report(&self) -> String {
        format!(
            "admission: inflight {}/{} admitted {} rejected {} gen {}",
            self.inflight, self.max_inflight, self.admitted, self.rejected, self.generation,
        )
    }
}

/// Cap on retained latency samples per distribution. `serve --http` runs
/// indefinitely, so sample storage must be bounded: past the cap the
/// oldest half is dropped, keeping percentiles a sliding window over the
/// most recent traffic while [`Metrics::count`]/throughput keep exact
/// lifetime totals.
pub const MAX_LATENCY_SAMPLES: usize = 1 << 16;

fn push_bounded(v: &mut Vec<u64>, sample: u64) {
    if v.len() >= MAX_LATENCY_SAMPLES {
        v.drain(..MAX_LATENCY_SAMPLES / 2);
    }
    v.push(sample);
}

/// Latency/throughput accumulator (single-threaded; each executor worker
/// owns one and snapshots it on demand).
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    latencies_us: Vec<u64>,
    /// Queue-wait portion of each latency (dispatcher + batcher + worker
    /// queue time before the forward pass starts). Only populated by
    /// [`Metrics::record_request_split`]; empty when the caller records
    /// totals only.
    queue_us: Vec<u64>,
    /// Execute portion (the engine forward itself).
    execute_us: Vec<u64>,
    /// Per-image-in-batch execute time: each batched request's share of
    /// its batch's forward (`execute / batch_size`) — the number the
    /// batch-major path improves as B grows (kernel streams amortize).
    per_image_us: Vec<u64>,
    /// Lifetime request count (exact even after sample windowing).
    completed: u64,
    batches: u64,
    batch_sizes: u64,
    /// Closed-batch size histogram: `batch_hist[s]` = number of batches
    /// executed with exactly `s` requests (index 0 unused).
    batch_hist: Vec<u64>,
    started: Option<std::time::Instant>,
    finished: Option<std::time::Instant>,
    /// Static scheduling quality of the worker's engine (None when serving
    /// dense weights or `--scheduler off`).
    pub schedule: Option<ScheduleMetrics>,
    /// Static activation-arena accounting of the worker's engine (None
    /// until an engine publishes its plan).
    pub arena: Option<ArenaMetrics>,
    /// Measured data movement vs the Eq. 13 prediction, per conv layer
    /// (None when the engine isn't observing — `observe=false` or a
    /// backend that can't measure).
    pub traffic: Option<crate::obs::TrafficMetrics>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_request(&mut self, latency: Duration) {
        let now = std::time::Instant::now();
        if self.started.is_none() {
            self.started = Some(now);
        }
        self.finished = Some(now);
        self.completed += 1;
        push_bounded(&mut self.latencies_us, latency.as_micros() as u64);
    }

    /// Record one request with its queue-wait vs execute breakdown (total
    /// latency = queue + execute). The serving loop uses this; callers
    /// without a breakdown keep using [`Metrics::record_request`].
    pub fn record_request_split(&mut self, queue: Duration, execute: Duration) {
        self.record_request(queue + execute);
        push_bounded(&mut self.queue_us, queue.as_micros() as u64);
        push_bounded(&mut self.execute_us, execute.as_micros() as u64);
    }

    pub fn record_batch(&mut self, size: usize) {
        self.batches += 1;
        self.batch_sizes += size as u64;
        if self.batch_hist.len() <= size {
            self.batch_hist.resize(size + 1, 0);
        }
        self.batch_hist[size] += 1;
    }

    /// Record one request's per-image share of its batch's execute time
    /// (`execute / batch_size` for every request in the batch).
    pub fn record_per_image(&mut self, per_image: Duration) {
        push_bounded(&mut self.per_image_us, per_image.as_micros() as u64);
    }

    /// Fold another accumulator into this one (per-worker → merged
    /// snapshot): latencies concatenate, batch counters add, and the
    /// observation window spans both.
    pub fn merge_from(&mut self, other: &Metrics) {
        self.latencies_us.extend_from_slice(&other.latencies_us);
        self.queue_us.extend_from_slice(&other.queue_us);
        self.execute_us.extend_from_slice(&other.execute_us);
        self.per_image_us.extend_from_slice(&other.per_image_us);
        self.completed += other.completed;
        self.batches += other.batches;
        self.batch_sizes += other.batch_sizes;
        if self.batch_hist.len() < other.batch_hist.len() {
            self.batch_hist.resize(other.batch_hist.len(), 0);
        }
        for (dst, &src) in self.batch_hist.iter_mut().zip(&other.batch_hist) {
            *dst += src;
        }
        // schedule/arena metrics are identical across pool replicas (same
        // weights + scheduler + graph per config), so the first snapshot wins
        if self.schedule.is_none() {
            self.schedule = other.schedule.clone();
        }
        if self.arena.is_none() {
            self.arena = other.arena.clone();
        }
        // traffic is *measured* per worker, so unlike schedule/arena it
        // merges additively (bytes across the whole pool)
        match (&mut self.traffic, &other.traffic) {
            (Some(dst), Some(src)) => dst.merge_from(src),
            (dst @ None, Some(src)) => *dst = Some(src.clone()),
            _ => {}
        }
        self.started = match (self.started, other.started) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
        self.finished = match (self.finished, other.finished) {
            (Some(a), Some(b)) => Some(a.max(b)),
            (a, b) => a.or(b),
        };
    }

    pub fn count(&self) -> usize {
        self.completed as usize
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batch_sizes as f64 / self.batches as f64
        }
    }

    /// Closed-batch size histogram: index = batch size, value = number of
    /// batches executed at that size (index 0 always 0). Empty before any
    /// batch completes.
    pub fn batch_histogram(&self) -> &[u64] {
        &self.batch_hist
    }

    /// Per-image-in-batch execute percentile (None before any batched
    /// request completes).
    pub fn per_image_percentile(&self, p: f64) -> Option<Duration> {
        Self::percentile_us(&self.per_image_us, p)
    }

    /// Nearest-rank percentile over raw microsecond samples — the one
    /// percentile definition this crate uses (the load generator reports
    /// through it too, so `/metrics` and loadgen numbers agree on the
    /// same data).
    pub fn percentile_us(v: &[u64], p: f64) -> Option<Duration> {
        if v.is_empty() {
            return None;
        }
        let mut v = v.to_vec();
        v.sort_unstable();
        let idx = ((v.len() - 1) as f64 * p).round() as usize;
        Some(Duration::from_micros(v[idx]))
    }

    fn percentile(&self, p: f64) -> Option<Duration> {
        Self::percentile_us(&self.latencies_us, p)
    }

    /// Queue-wait percentile over the split-recorded requests (None when no
    /// breakdown was recorded).
    pub fn queue_percentile(&self, p: f64) -> Option<Duration> {
        Self::percentile_us(&self.queue_us, p)
    }

    /// Execute-time percentile over the split-recorded requests.
    pub fn execute_percentile(&self, p: f64) -> Option<Duration> {
        Self::percentile_us(&self.execute_us, p)
    }

    pub fn p50(&self) -> Option<Duration> {
        self.percentile(0.50)
    }

    pub fn p95(&self) -> Option<Duration> {
        self.percentile(0.95)
    }

    pub fn p99(&self) -> Option<Duration> {
        self.percentile(0.99)
    }

    pub fn mean(&self) -> Option<Duration> {
        if self.latencies_us.is_empty() {
            return None;
        }
        let sum: u64 = self.latencies_us.iter().sum();
        Some(Duration::from_micros(sum / self.latencies_us.len() as u64))
    }

    /// Requests/second over the observation window.
    pub fn throughput(&self) -> f64 {
        match (self.started, self.finished) {
            (Some(a), Some(b)) if b > a => {
                self.count() as f64 / b.duration_since(a).as_secs_f64()
            }
            _ => 0.0,
        }
    }

    pub fn report(&self) -> String {
        let mut line = format!(
            "n={} mean={:?} p50={:?} p95={:?} p99={:?} batch={:.1} thpt={:.1}/s",
            self.count(),
            self.mean().unwrap_or_default(),
            self.p50().unwrap_or_default(),
            self.p95().unwrap_or_default(),
            self.p99().unwrap_or_default(),
            self.mean_batch_size(),
            self.throughput(),
        );
        if let (Some(q), Some(e)) = (self.queue_percentile(0.5), self.execute_percentile(0.5)) {
            line.push_str(&format!(" queue-p50={q:?} exec-p50={e:?}"));
        }
        if let Some(pi) = self.per_image_percentile(0.5) {
            line.push_str(&format!(" per-image-p50={pi:?}"));
        }
        if let Some(s) = &self.schedule {
            line.push_str(&format!(" | {}", s.report()));
        }
        if let Some(a) = &self.arena {
            line.push_str(&format!(" | {}", a.report()));
        }
        if let Some(t) = &self.traffic {
            line.push_str(&format!(" | {}", t.report()));
        }
        line
    }
}

/// Pool-wide snapshot: the merged view plus one [`Metrics`] per executor
/// worker (index = worker id), so per-worker load skew is observable.
#[derive(Debug, Clone, Default)]
pub struct PoolMetrics {
    pub merged: Metrics,
    pub per_worker: Vec<Metrics>,
}

impl PoolMetrics {
    /// Merge a vector of per-worker accumulators into a snapshot.
    pub fn from_workers(per_worker: Vec<Metrics>) -> Self {
        let mut merged = Metrics::new();
        for m in &per_worker {
            merged.merge_from(m);
        }
        PoolMetrics { merged, per_worker }
    }

    /// One line per worker plus the merged line.
    pub fn report(&self) -> String {
        let mut out = String::new();
        for (i, m) in self.per_worker.iter().enumerate() {
            out.push_str(&format!("worker {i}: {}\n", m.report()));
        }
        out.push_str(&format!("merged:   {}", self.merged.report()));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut m = Metrics::new();
        for us in [100u64, 200, 300, 400, 500, 1000, 2000] {
            m.record_request(Duration::from_micros(us));
        }
        assert_eq!(m.count(), 7);
        assert!(m.p50().unwrap() <= m.p95().unwrap());
        assert!(m.p95().unwrap() <= m.p99().unwrap());
        assert_eq!(m.p50().unwrap(), Duration::from_micros(400));
    }

    #[test]
    fn empty_metrics_safe() {
        let m = Metrics::new();
        assert!(m.p50().is_none());
        assert_eq!(m.throughput(), 0.0);
        assert!(m.report().contains("n=0"));
    }

    #[test]
    fn batch_accounting() {
        let mut m = Metrics::new();
        m.record_batch(4);
        m.record_batch(8);
        assert!((m.mean_batch_size() - 6.0).abs() < 1e-12);
    }

    #[test]
    fn batch_histogram_counts_by_size_and_merges() {
        let mut a = Metrics::new();
        a.record_batch(1);
        a.record_batch(4);
        a.record_batch(4);
        assert_eq!(a.batch_histogram(), &[0, 1, 0, 0, 2]);
        let mut b = Metrics::new();
        b.record_batch(2);
        b.record_batch(4);
        let snap = PoolMetrics::from_workers(vec![a, b]);
        assert_eq!(snap.merged.batch_histogram(), &[0, 1, 1, 0, 3]);
        // empty metrics expose an empty histogram, not a panic
        assert!(Metrics::new().batch_histogram().is_empty());
    }

    #[test]
    fn per_image_latency_tracks_batch_share() {
        let mut m = Metrics::new();
        // a batch of 4 sharing a 2 ms forward: 500 µs per image
        for _ in 0..4 {
            m.record_per_image(Duration::from_micros(500));
        }
        m.record_per_image(Duration::from_micros(2000)); // a lone request
        assert_eq!(m.per_image_percentile(0.5).unwrap(), Duration::from_micros(500));
        assert!(m.report().contains("per-image-p50"));
        // merge concatenates the distribution
        let snap = PoolMetrics::from_workers(vec![m, Metrics::new()]);
        assert_eq!(snap.merged.per_image_percentile(1.0).unwrap(), Duration::from_micros(2000));
        // absent until a batched request completes
        assert!(Metrics::new().per_image_percentile(0.5).is_none());
        assert!(!Metrics::new().report().contains("per-image-p50"));
    }

    #[test]
    fn merge_concatenates_and_spans() {
        let mut a = Metrics::new();
        a.record_batch(2);
        a.record_request(Duration::from_micros(100));
        a.record_request(Duration::from_micros(300));
        let mut b = Metrics::new();
        b.record_batch(1);
        b.record_request(Duration::from_micros(200));
        let snap = PoolMetrics::from_workers(vec![a, b]);
        assert_eq!(snap.merged.count(), 3);
        assert!((snap.merged.mean_batch_size() - 1.5).abs() < 1e-12);
        assert_eq!(snap.merged.p50().unwrap(), Duration::from_micros(200));
        assert_eq!(snap.per_worker.len(), 2);
        assert!(snap.report().contains("worker 1"));
    }

    #[test]
    fn schedule_metrics_aggregate_and_merge() {
        let sched = ScheduleMetrics {
            scheduler: "exact-cover".into(),
            layers: vec![
                LayerScheduleMetrics {
                    layer: "conv1".into(),
                    stats: ScheduleStats {
                        cycles: 20,
                        lower_bound: 16,
                        reads: 64,
                        slots: 80,
                        bank_conflicts: 3,
                    },
                },
                LayerScheduleMetrics {
                    layer: "conv2".into(),
                    stats: ScheduleStats {
                        cycles: 10,
                        lower_bound: 10,
                        reads: 40,
                        slots: 40,
                        bank_conflicts: 0,
                    },
                },
            ],
        };
        assert!((sched.avg_pe_utilization() - 104.0 / 120.0).abs() < 1e-12);
        assert_eq!(sched.total_cycles(), 30);
        assert_eq!(sched.total_lower_bound(), 26);
        assert_eq!(sched.total_bank_conflicts(), 3);
        assert!(sched.report().contains("exact-cover"));
        assert!(sched.report_layers().contains("conv2"));

        // merge: first Some wins, and the merged report carries it
        let mut a = Metrics::new();
        a.schedule = Some(sched.clone());
        a.record_request(Duration::from_micros(10));
        let mut b = Metrics::new();
        b.record_request(Duration::from_micros(20));
        let snap = PoolMetrics::from_workers(vec![b, a]);
        assert_eq!(snap.merged.schedule.as_ref().unwrap(), &sched);
        assert!(snap.report().contains("sched[exact-cover]"));
    }

    #[test]
    fn arena_metrics_report_and_merge() {
        let arena = ArenaMetrics {
            tensors: 7,
            slots: 3,
            reused: 4,
            peak_activation_bytes: 32768,
            no_reuse_bytes: 52224,
        };
        let line = arena.report();
        assert!(line.contains("peak 32768 B"), "{line}");
        assert!(line.contains("3/7 tensors"), "{line}");

        // merge: first Some wins, and the merged report carries it
        let mut a = Metrics::new();
        a.arena = Some(arena.clone());
        a.record_request(Duration::from_micros(10));
        let mut b = Metrics::new();
        b.record_request(Duration::from_micros(20));
        let snap = PoolMetrics::from_workers(vec![b, a]);
        assert_eq!(snap.merged.arena.as_ref().unwrap(), &arena);
        assert!(snap.report().contains("arena: peak"));
        // degenerate all-zero metrics report without dividing by zero
        assert!(ArenaMetrics::default().report().contains("peak 0 B"));
    }

    #[test]
    fn split_breakdown_accumulates_and_merges() {
        let mut a = Metrics::new();
        a.record_request_split(Duration::from_micros(100), Duration::from_micros(900));
        a.record_request_split(Duration::from_micros(300), Duration::from_micros(700));
        // totals land in the latency distribution…
        assert_eq!(a.count(), 2);
        assert_eq!(a.p50().unwrap(), Duration::from_micros(1000));
        // …and the breakdown has its own percentiles
        assert_eq!(a.queue_percentile(0.5).unwrap(), Duration::from_micros(300));
        assert_eq!(a.execute_percentile(0.5).unwrap(), Duration::from_micros(900));
        assert!(a.report().contains("queue-p50"));

        // merging keeps the breakdown; a breakdown-less worker contributes
        // totals only
        let mut b = Metrics::new();
        b.record_request(Duration::from_micros(500));
        let snap = PoolMetrics::from_workers(vec![a, b]);
        assert_eq!(snap.merged.count(), 3);
        assert_eq!(snap.merged.queue_percentile(0.5).unwrap(), Duration::from_micros(300));

        // no breakdown recorded → no breakdown reported
        let plain = Metrics::new();
        assert!(plain.queue_percentile(0.5).is_none());
        assert!(!plain.report().contains("queue-p50"));
    }

    #[test]
    fn sample_storage_is_bounded_but_count_is_exact() {
        // serve --http runs forever: retained samples must cap out while
        // the lifetime counters stay exact
        let mut m = Metrics::new();
        let n = MAX_LATENCY_SAMPLES + MAX_LATENCY_SAMPLES / 2;
        for i in 0..n {
            m.record_request_split(
                Duration::from_micros(i as u64),
                Duration::from_micros(1),
            );
        }
        assert_eq!(m.count(), n, "count reports lifetime total");
        assert!(m.latencies_us.len() <= MAX_LATENCY_SAMPLES);
        assert!(m.queue_us.len() <= MAX_LATENCY_SAMPLES);
        assert!(m.execute_us.len() <= MAX_LATENCY_SAMPLES);
        // the window covers recent traffic: p50 sits in the upper half of
        // the full series, not the (dropped) beginning
        assert!(m.queue_percentile(0.5).unwrap() > Duration::from_micros(n as u64 / 2));
    }

    #[test]
    fn admission_metrics_report() {
        let a = AdmissionMetrics {
            inflight: 3,
            max_inflight: 64,
            admitted: 120,
            rejected: 7,
            generation: 2,
        };
        let line = a.report();
        assert!(line.contains("inflight 3/64"), "{line}");
        assert!(line.contains("rejected 7"), "{line}");
        assert!(line.contains("gen 2"), "{line}");
        assert_eq!(AdmissionMetrics::default().generation, 0);
    }

    #[test]
    fn merge_with_empty_is_identity() {
        let mut a = Metrics::new();
        a.record_request(Duration::from_micros(50));
        let mut merged = Metrics::new();
        merged.merge_from(&Metrics::new());
        merged.merge_from(&a);
        merged.merge_from(&Metrics::new());
        assert_eq!(merged.count(), 1);
        assert_eq!(merged.p50().unwrap(), Duration::from_micros(50));
    }
}
