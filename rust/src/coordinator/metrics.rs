//! Serving metrics: latency distribution + throughput.

use std::time::Duration;

/// Latency/throughput accumulator (single-threaded; the server owns one and
/// snapshots it on demand).
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    latencies_us: Vec<u64>,
    batches: u64,
    batch_sizes: u64,
    started: Option<std::time::Instant>,
    finished: Option<std::time::Instant>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_request(&mut self, latency: Duration) {
        let now = std::time::Instant::now();
        if self.started.is_none() {
            self.started = Some(now);
        }
        self.finished = Some(now);
        self.latencies_us.push(latency.as_micros() as u64);
    }

    pub fn record_batch(&mut self, size: usize) {
        self.batches += 1;
        self.batch_sizes += size as u64;
    }

    pub fn count(&self) -> usize {
        self.latencies_us.len()
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batch_sizes as f64 / self.batches as f64
        }
    }

    fn percentile(&self, p: f64) -> Option<Duration> {
        if self.latencies_us.is_empty() {
            return None;
        }
        let mut v = self.latencies_us.clone();
        v.sort_unstable();
        let idx = ((v.len() - 1) as f64 * p).round() as usize;
        Some(Duration::from_micros(v[idx]))
    }

    pub fn p50(&self) -> Option<Duration> {
        self.percentile(0.50)
    }

    pub fn p95(&self) -> Option<Duration> {
        self.percentile(0.95)
    }

    pub fn p99(&self) -> Option<Duration> {
        self.percentile(0.99)
    }

    pub fn mean(&self) -> Option<Duration> {
        if self.latencies_us.is_empty() {
            return None;
        }
        let sum: u64 = self.latencies_us.iter().sum();
        Some(Duration::from_micros(sum / self.latencies_us.len() as u64))
    }

    /// Requests/second over the observation window.
    pub fn throughput(&self) -> f64 {
        match (self.started, self.finished) {
            (Some(a), Some(b)) if b > a => {
                self.count() as f64 / b.duration_since(a).as_secs_f64()
            }
            _ => 0.0,
        }
    }

    pub fn report(&self) -> String {
        format!(
            "n={} mean={:?} p50={:?} p95={:?} p99={:?} batch={:.1} thpt={:.1}/s",
            self.count(),
            self.mean().unwrap_or_default(),
            self.p50().unwrap_or_default(),
            self.p95().unwrap_or_default(),
            self.p99().unwrap_or_default(),
            self.mean_batch_size(),
            self.throughput(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut m = Metrics::new();
        for us in [100u64, 200, 300, 400, 500, 1000, 2000] {
            m.record_request(Duration::from_micros(us));
        }
        assert_eq!(m.count(), 7);
        assert!(m.p50().unwrap() <= m.p95().unwrap());
        assert!(m.p95().unwrap() <= m.p99().unwrap());
        assert_eq!(m.p50().unwrap(), Duration::from_micros(400));
    }

    #[test]
    fn empty_metrics_safe() {
        let m = Metrics::new();
        assert!(m.p50().is_none());
        assert_eq!(m.throughput(), 0.0);
        assert!(m.report().contains("n=0"));
    }

    #[test]
    fn batch_accounting() {
        let mut m = Metrics::new();
        m.record_batch(4);
        m.record_batch(8);
        assert!((m.mean_batch_size() - 6.0).abs() < 1e-12);
    }
}
