//! Layer-3 coordinator: the serving engine around the AOT'd executables.
//!
//! Mirrors the paper's CPU–FPGA split at system level: the "FPGA" is the
//! PJRT executable (spectral conv per tile batch), everything else —
//! tiling, OaA, bias/ReLU, pooling, the FC head, request batching and
//! metrics — runs here, in Rust, on the request path. Python exists only
//! in the build pipeline.
//!
//! * [`engine`] — [`engine::InferenceEngine`]: weights + per-layer forward.
//! * [`batcher`] — deadline/size-bounded request batching.
//! * [`server`] — worker thread + client handles (std::thread + channels;
//!   tokio is unavailable in the offline registry — DESIGN.md).
//! * [`metrics`] — latency percentiles and throughput counters.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod server;

pub use batcher::{Batcher, BatcherConfig};
pub use engine::{InferenceEngine, WeightMode, Weights};
pub use metrics::Metrics;
pub use server::{Server, ServerConfig};
