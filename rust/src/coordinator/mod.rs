//! Layer-3 coordinator: the serving engine around the spectral-conv
//! backend.
//!
//! Mirrors the paper's CPU–FPGA split at system level: the "FPGA" is the
//! [`SpectralBackend`](crate::runtime::SpectralBackend) (spectral conv per
//! tile batch — the pure-Rust `interp` interpreter by default, AOT'd PJRT
//! executables behind the `pjrt` feature), everything else — tiling, OaA,
//! bias/ReLU, pooling, the FC head, request batching and metrics — runs
//! here, in Rust, on the request path. Python exists only in the (optional)
//! artifact build pipeline.
//!
//! * [`engine`] — [`engine::InferenceEngine`]: weights + per-layer forward.
//! * [`batcher`] — deadline/size-bounded request batching.
//! * [`server`] — executor-worker pool + dispatcher + client handles
//!   (std::thread + channels; tokio is unavailable in the offline
//!   registry — DESIGN.md). Each worker owns its own engine, constructed
//!   in-thread and never moved across threads (the PJRT FFI constraint).
//! * [`metrics`] — latency percentiles and throughput counters, per worker
//!   and merged.
//! * [`arena`] — lifetime-based activation arena for the graph executor
//!   (slot reuse across dead tensors, peak-residency accounting).
//! * [`registry`] — [`registry::ModelRegistry`]: several named engine pools
//!   in one process (multi-tenant serving), each with its own admission
//!   quota, plus the zero-downtime weight-swap protocol behind
//!   `POST /admin/models/<name>`.
//!
//! Observability (measured data movement vs the paper's Eq. 13 prediction,
//! per-request trace spans, Prometheus exposition) lives in [`crate::obs`];
//! the engine hosts the counters and the server pool hosts the trace ring.

pub mod arena;
pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod registry;
pub mod server;

pub use arena::ArenaPlan;
pub use batcher::{Batcher, BatcherConfig};
pub use engine::{EngineOptions, EngineOptionsBuilder, InferenceEngine, WeightMode, Weights};
pub use metrics::{
    AdmissionMetrics, ArenaMetrics, LayerScheduleMetrics, Metrics, PoolMetrics, ScheduleMetrics,
};
pub use registry::{
    AdminError, AdmitGuard, ModelFetch, ModelPool, ModelRegistry, ModelSpec, ModelStatus,
};
pub use server::{Client, Response, Server, ServerConfig};

pub use crate::obs::{
    LayerTraffic, RequestTrace, Span, TraceConfig, TraceRing, TrafficMetrics, WireTiming,
};
