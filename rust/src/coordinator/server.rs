//! The serving loop: a pool of executor workers, each owning its own
//! [`InferenceEngine`], fed by a dispatcher thread that batches client
//! requests and routes each closed batch to the least-loaded worker.
//! A closed batch is never unbundled: the worker runs it through one
//! fused [`InferenceEngine::forward_batch`] call, so every weight block
//! streams once per batch instead of once per image (batch-major
//! kernel reuse), and its engine's dataflow plan is sized for the
//! batcher's `max_batch`.
//!
//! Thread-confinement rule: every engine is constructed *inside* its worker
//! thread and never crosses a thread boundary (PJRT objects hold raw FFI
//! pointers; the interp backend simply doesn't need to move). Clients
//! exchange plain tensors. Engines are built from the same config/seed, so
//! every worker computes bit-identical outputs — which worker serves a
//! request is invisible in the logits. (tokio is unavailable offline —
//! std::thread + channels, see DESIGN.md.)
//!
//! ```text
//! clients ──mpsc──► dispatcher (Batcher) ──per-worker mpsc──► executor 0..N-1
//!                        ▲                                      each: engine
//!                        └───── least-loaded pick (atomics) ◄── + Metrics
//! ```

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::batcher::{Batcher, BatcherConfig};
use super::engine::{EngineOptions, InferenceEngine, WeightMode};
use super::metrics::{Metrics, PoolMetrics};
use crate::err;
use crate::obs::{RequestTrace, Span, TraceConfig, TraceRing, WireTiming};
use crate::runtime::{Dtype, Plane};
use crate::tensor::Tensor;
use crate::util::error::Result;

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub artifacts_dir: String,
    pub variant: String,
    /// Weight regime every worker engine replicates: the compression knob α
    /// rides here ([`WeightMode::from_alpha`]) — `Dense` executes the dense
    /// frequency-major MAC, `Pruned { alpha }` uploads CSR kernels and runs
    /// the backend's sparse path.
    pub mode: WeightMode,
    pub seed: u64,
    pub batcher: BatcherConfig,
    /// Number of executor workers, each owning its own engine (0 acts as 1).
    pub workers: usize,
    /// Engine construction knobs (backend, scheduler, dtype, plane,
    /// arena reuse) — composed here instead of duplicated field-by-field;
    /// build with [`EngineOptions::builder`]. `engine.plan_batch` is
    /// overridden by the batcher's `max_batch` at worker startup so Alg. 1
    /// always plans for the largest batch the pool can close.
    pub engine: EngineOptions,
    /// Trace-ring sizing shared by every worker (capacity, slow retention,
    /// slow threshold). Observation-only — never alters scheduling.
    pub trace: TraceConfig,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            artifacts_dir: "artifacts".into(),
            variant: "vgg16-cifar".into(),
            mode: WeightMode::Pruned { alpha: 4 },
            seed: 7,
            batcher: BatcherConfig::default(),
            workers: 1,
            engine: EngineOptions::default(),
            trace: TraceConfig::default(),
        }
    }
}

struct Request {
    image: Tensor,
    submitted: Instant,
    /// Wire-side accept/parse stamps from the HTTP front-end; `None` for
    /// direct `Client::infer` callers (their trace starts at `submitted`).
    wire: Option<WireTiming>,
    reply: mpsc::Sender<Result<Response>>,
}

/// A completed inference.
#[derive(Debug, Clone)]
pub struct Response {
    pub logits: Vec<f32>,
    pub latency: Duration,
    /// Time spent queued before the forward pass started (dispatcher +
    /// batcher + worker queue); `latency ≈ queue_wait + execute`.
    pub queue_wait: Duration,
    /// Time the fused batch forward took (shared across every request in
    /// the closed batch — the whole batch runs as one `forward_batch`).
    pub execute: Duration,
    /// Amortized share of `execute` attributed to this request:
    /// `execute / batch_size` over the requests that actually executed.
    /// The kernel-reuse win shows up here — per-image latency shrinks as
    /// the batch grows because each weight block streams once per batch.
    pub per_image: Duration,
    pub batch_size: usize,
    /// Which pool worker executed the request.
    pub worker: usize,
    /// Network PE utilization of the engine's Alg. 2 schedules (static per
    /// engine; `None` when serving dense weights or `--scheduler off`).
    pub pe_utilization: Option<f64>,
    /// Accumulation dtype the serving engine ran this request at.
    pub dtype: Dtype,
    /// Spectral storage plane the serving engine executed on.
    pub plane: Plane,
}

enum Msg {
    Infer(Request),
    Snapshot(mpsc::Sender<PoolMetrics>),
    Shutdown,
}

enum WorkerMsg {
    Batch {
        batch: Vec<Request>,
        /// When the dispatcher closed the batch — the boundary between the
        /// `queue` and `batch-close` spans of every request riding in it.
        closed: Instant,
    },
    Snapshot(mpsc::Sender<Metrics>),
    Shutdown,
}

/// Dispatcher-side handle to one executor worker.
struct WorkerSlot {
    tx: mpsc::Sender<WorkerMsg>,
    /// Requests dispatched but not yet answered (the load-balancing key).
    load: Arc<AtomicUsize>,
}

/// Running server + client handle factory.
pub struct Server {
    tx: mpsc::Sender<Msg>,
    dispatcher: Option<std::thread::JoinHandle<Result<()>>>,
    workers: Vec<std::thread::JoinHandle<Result<()>>>,
    /// Pool-wide trace store; workers record into it, the HTTP front-end
    /// reads from it (`GET /v1/models/<name>/trace`).
    trace: Arc<TraceRing>,
}

/// Cheap cloneable client handle.
#[derive(Clone)]
pub struct Client {
    tx: mpsc::Sender<Msg>,
}

impl Client {
    /// Blocking inference call.
    pub fn infer(&self, image: Tensor) -> Result<Response> {
        let rx = self.infer_async(image)?;
        rx.recv().map_err(|_| err!("server dropped request"))?
    }

    /// Fire-and-collect: submit without waiting; returns the receiver.
    pub fn infer_async(&self, image: Tensor) -> Result<mpsc::Receiver<Result<Response>>> {
        self.submit(image, None)
    }

    /// Like [`Client::infer_async`], but carries the HTTP front-end's
    /// accept/parse stamps so the request's trace includes the wire-side
    /// `parse` span and roots at `accepted` instead of `submitted`.
    pub fn infer_async_timed(
        &self,
        image: Tensor,
        wire: WireTiming,
    ) -> Result<mpsc::Receiver<Result<Response>>> {
        self.submit(image, Some(wire))
    }

    fn submit(
        &self,
        image: Tensor,
        wire: Option<WireTiming>,
    ) -> Result<mpsc::Receiver<Result<Response>>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Infer(Request { image, submitted: Instant::now(), wire, reply }))
            .map_err(|_| err!("server stopped"))?;
        Ok(rx)
    }

    /// Per-worker + merged metrics snapshot, same as
    /// [`Server::pool_metrics`] but reachable from a cloned handle — the
    /// HTTP front-end's `/metrics` endpoint answers from connection
    /// threads that only hold a `Client`.
    pub fn pool_metrics(&self) -> Result<PoolMetrics> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Msg::Snapshot(tx)).map_err(|_| err!("server stopped"))?;
        rx.recv().map_err(|_| err!("server stopped"))
    }
}

impl Server {
    /// Start the pool; blocks until every worker's engine has loaded
    /// (compile warm-up) so the first request doesn't pay startup cost.
    /// Any engine construction error fails the whole startup.
    pub fn start(cfg: ServerConfig) -> Result<Server> {
        let n = cfg.workers.max(1);
        let trace = Arc::new(TraceRing::new(cfg.trace));
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let mut slots = Vec::with_capacity(n);
        let mut workers = Vec::with_capacity(n);
        for wi in 0..n {
            let (wtx, wrx) = mpsc::channel::<WorkerMsg>();
            let load = Arc::new(AtomicUsize::new(0));
            let wcfg = cfg.clone();
            let wready = ready_tx.clone();
            let wload = load.clone();
            let wring = Arc::clone(&trace);
            let handle = std::thread::Builder::new()
                .name(format!("sf-exec-{wi}"))
                .spawn(move || worker_loop(wi, wcfg, wrx, wready, wload, wring))
                .expect("spawn executor worker");
            slots.push(WorkerSlot { tx: wtx, load });
            workers.push(handle);
        }
        drop(ready_tx);
        // Wait for all engines; on failure, dropping `slots` disconnects the
        // surviving workers and they exit on their own.
        for _ in 0..n {
            ready_rx
                .recv()
                .map_err(|_| err!("executor worker died during startup"))??;
        }
        let (tx, rx) = mpsc::channel::<Msg>();
        let batcher_cfg = cfg.batcher;
        let dispatcher = std::thread::Builder::new()
            .name("sf-dispatch".into())
            .spawn(move || dispatcher_loop(batcher_cfg, rx, slots))
            .expect("spawn dispatcher");
        Ok(Server { tx, dispatcher: Some(dispatcher), workers, trace })
    }

    pub fn client(&self) -> Client {
        Client { tx: self.tx.clone() }
    }

    /// The pool's trace-span ring (shared handle; cheap to clone).
    pub fn trace(&self) -> Arc<TraceRing> {
        Arc::clone(&self.trace)
    }

    /// Merged metrics snapshot across the pool.
    pub fn metrics(&self) -> Result<Metrics> {
        Ok(self.pool_metrics()?.merged)
    }

    /// Per-worker + merged metrics snapshot.
    pub fn pool_metrics(&self) -> Result<PoolMetrics> {
        self.client().pool_metrics()
    }

    /// Graceful shutdown (flushes pending batches, drains every worker).
    pub fn shutdown(mut self) -> Result<()> {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(d) = self.dispatcher.take() {
            d.join().map_err(|_| err!("dispatcher panicked"))??;
        }
        for w in self.workers.drain(..) {
            w.join().map_err(|_| err!("executor worker panicked"))??;
        }
        Ok(())
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// One executor: builds its engine in-thread (thread confinement), then
/// serves dispatched batches and metric snapshots until shutdown.
fn worker_loop(
    id: usize,
    cfg: ServerConfig,
    rx: mpsc::Receiver<WorkerMsg>,
    ready: mpsc::Sender<Result<()>>,
    load: Arc<AtomicUsize>,
    ring: Arc<TraceRing>,
) -> Result<()> {
    let mut engine = match InferenceEngine::with_options(
        &cfg.artifacts_dir,
        &cfg.variant,
        cfg.mode,
        cfg.seed,
        // Plan the sparse dataflow for the largest batch the batcher can
        // close: Alg. 1 with B as the third reuse axis sizes Ps across
        // B·P tiles, so each weight block streams once per batch.
        EngineOptions { plan_batch: cfg.batcher.max_batch.max(1), ..cfg.engine },
    ) {
        Ok(e) => {
            let _ = ready.send(Ok(()));
            e
        }
        Err(e) => {
            let _ = ready.send(Err(e.clone()));
            return Err(e);
        }
    };
    // Release the ready sender now: if a sibling worker panics before its
    // send, Server::start's recv loop must observe the disconnect instead
    // of blocking on senders parked in still-alive workers.
    drop(ready);
    let mut metrics = Metrics::new();
    // static per-engine scheduling quality: snapshot once, ride along in
    // every metrics merge and response
    metrics.schedule = engine.schedule_metrics().cloned();
    metrics.arena = Some(engine.arena_metrics().clone());
    let pe_util = metrics.schedule.as_ref().map(|s| s.avg_pe_utilization());
    // manifest-resolved numeric mode, identical across the pool
    let (dtype, plane) = (engine.dtype(), engine.plane());
    while let Ok(msg) = rx.recv() {
        match msg {
            WorkerMsg::Batch { batch, closed } => {
                let size = batch.len();
                let batch_id = ring.next_batch_id();
                metrics.record_batch(size);
                // queue-wait ends (and execute begins) for the whole batch
                // here: everything before this instant was dispatcher/
                // batcher/worker-queue time. A batch of one takes exactly
                // this path too — there is no serial special case.
                let queue_waits: Vec<Duration> =
                    batch.iter().map(|r| r.submitted.elapsed()).collect();
                // Pre-screen shapes so one malformed request can't poison
                // the fused forward; the valid subset still rides together.
                let verdicts: Vec<Result<()>> =
                    batch.iter().map(|r| engine.check_input(&r.image)).collect();
                let images: Vec<Tensor> = batch
                    .iter()
                    .zip(&verdicts)
                    .filter(|(_, v)| v.is_ok())
                    .map(|(r, _)| r.image.clone())
                    .collect();
                let exec_start = Instant::now();
                let outcome = if images.is_empty() {
                    Ok(Vec::new())
                } else {
                    engine.forward_batch(&images)
                };
                let execute = exec_start.elapsed();
                let exec_end = exec_start + execute;
                let per_image = execute / images.len().max(1) as u32;
                // Per-layer execute intervals from the engine's last
                // forward, rebased onto the ring epoch. Shared by every
                // request in the closed batch — the batch ran as one fused
                // forward, so the layer timeline is genuinely common.
                let layer_spans: Vec<Span> = engine
                    .layer_spans()
                    .iter()
                    .map(|ls| Span {
                        name: format!("layer:{}", ls.name),
                        start_us: ring.to_us(ls.start),
                        end_us: ring.to_us(ls.end),
                        measured_bytes: ls.measured_bytes,
                        predicted_bytes: ls.predicted_bytes,
                    })
                    .collect();
                let mut results: std::vec::IntoIter<Result<Vec<f32>>> = match outcome {
                    Ok(v) => v.into_iter().map(Ok).collect::<Vec<_>>(),
                    // an engine-level failure fails every request that
                    // executed; shape rejections below stay per-request
                    Err(e) => (0..images.len()).map(|_| Err(e.clone())).collect(),
                }
                .into_iter();
                for ((req, queue_wait), verdict) in
                    batch.into_iter().zip(queue_waits).zip(verdicts)
                {
                    let result = match verdict {
                        Err(e) => Err(e),
                        Ok(()) => results
                            .next()
                            .expect("one result per screened request")
                            .map(|logits| {
                                let latency = req.submitted.elapsed();
                                metrics.record_request_split(queue_wait, execute);
                                metrics.record_per_image(per_image);
                                Response {
                                    logits,
                                    latency,
                                    queue_wait,
                                    execute,
                                    per_image,
                                    batch_size: size,
                                    worker: id,
                                    pe_utilization: pe_util,
                                    dtype,
                                    plane,
                                }
                            }),
                    };
                    let ok = result.is_ok();
                    let _ = req.reply.send(result);
                    load.fetch_sub(1, Ordering::Relaxed);
                    if ok {
                        // Assemble the span taxonomy: accept → parse →
                        // queue → batch-close → execute (+ per-layer) →
                        // respond. Direct Client callers have no wire
                        // stamps, so their root starts at `submitted`.
                        let respond_end = Instant::now();
                        let root_start =
                            req.wire.map(|w| w.accepted).unwrap_or(req.submitted);
                        let mut spans = Vec::with_capacity(layer_spans.len() + 6);
                        spans.push(Span::plain(
                            "request",
                            ring.to_us(root_start),
                            ring.to_us(respond_end),
                        ));
                        if let Some(w) = req.wire {
                            spans.push(Span::plain(
                                "parse",
                                ring.to_us(w.accepted),
                                ring.to_us(w.parsed),
                            ));
                        }
                        spans.push(Span::plain(
                            "queue",
                            ring.to_us(req.submitted),
                            ring.to_us(closed),
                        ));
                        spans.push(Span::plain(
                            "batch-close",
                            ring.to_us(closed),
                            ring.to_us(exec_start),
                        ));
                        spans.push(Span::plain(
                            "execute",
                            ring.to_us(exec_start),
                            ring.to_us(exec_end),
                        ));
                        spans.extend(layer_spans.iter().cloned());
                        spans.push(Span::plain(
                            "respond",
                            ring.to_us(exec_end),
                            ring.to_us(respond_end),
                        ));
                        let latency_us = spans[0].duration_us();
                        ring.record(RequestTrace {
                            request: ring.next_request_id(),
                            batch: batch_id,
                            worker: id,
                            model: cfg.variant.clone(),
                            batch_size: size,
                            latency_us,
                            slow: false, // stamped by record()
                            spans,
                        });
                    }
                }
            }
            WorkerMsg::Snapshot(tx) => {
                let mut m = metrics.clone();
                // Traffic accounting lives in the engine (it owns the
                // counters); inject the live totals into each snapshot.
                m.traffic = engine.traffic_metrics();
                let _ = tx.send(m);
            }
            WorkerMsg::Shutdown => break,
        }
    }
    Ok(())
}

/// The dispatcher: batches incoming requests against the deadline/size
/// policy and hands each closed batch to the least-loaded worker.
fn dispatcher_loop(
    cfg: BatcherConfig,
    rx: mpsc::Receiver<Msg>,
    workers: Vec<WorkerSlot>,
) -> Result<()> {
    let mut batcher: Batcher<Request> = Batcher::new(cfg);

    let dispatch = |mut batch: Vec<Request>| {
        // The batch is closed *now*; every request in it shares this
        // queue/batch-close boundary in its trace.
        let closed = Instant::now();
        loop {
            // least-loaded pick: `load` counts dispatched-but-unanswered
            // requests; Relaxed is fine — it's a heuristic, not a lock
            let slot = workers
                .iter()
                .min_by_key(|w| w.load.load(Ordering::Relaxed))
                .expect("pool has at least one worker");
            if slot.load.load(Ordering::Relaxed) == usize::MAX {
                // every worker is dead; dropping the batch drops the reply
                // senders, so clients observe "server dropped request"
                return;
            }
            slot.load.fetch_add(batch.len(), Ordering::Relaxed);
            match slot.tx.send(WorkerMsg::Batch { batch, closed }) {
                Ok(()) => return,
                Err(mpsc::SendError(msg)) => {
                    // the worker died: poison its load so it is never
                    // picked again and retry the batch on a survivor
                    slot.load.store(usize::MAX, Ordering::Relaxed);
                    match msg {
                        WorkerMsg::Batch { batch: b, .. } => batch = b,
                        _ => return,
                    }
                }
            }
        }
    };

    loop {
        // Park until the next message or the batch deadline.
        let msg = match batcher.time_to_deadline(Instant::now()) {
            Some(d) => match rx.recv_timeout(d) {
                Ok(m) => Some(m),
                Err(mpsc::RecvTimeoutError::Timeout) => None,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            },
            None => match rx.recv() {
                Ok(m) => Some(m),
                Err(_) => break,
            },
        };
        match msg {
            Some(Msg::Infer(req)) => {
                if let Some(batch) = batcher.push(req, Instant::now()) {
                    dispatch(batch);
                }
            }
            Some(Msg::Snapshot(tx)) => {
                // fan the snapshot out to every worker first, then collect:
                // the waits overlap, so the stall is one queue drain (the
                // slowest worker), not the sum over workers
                let pending: Vec<Option<mpsc::Receiver<Metrics>>> = workers
                    .iter()
                    .map(|w| {
                        let (mtx, mrx) = mpsc::channel();
                        w.tx.send(WorkerMsg::Snapshot(mtx)).ok().map(|_| mrx)
                    })
                    .collect();
                let per_worker = pending
                    .into_iter()
                    // a dead worker reports as empty
                    .map(|mrx| mrx.and_then(|rx| rx.recv().ok()).unwrap_or_default())
                    .collect();
                let _ = tx.send(PoolMetrics::from_workers(per_worker));
            }
            Some(Msg::Shutdown) => break,
            None => {}
        }
        if let Some(batch) = batcher.poll(Instant::now()) {
            dispatch(batch);
        }
    }
    // flush the open batch, then drain the pool (queued batches are
    // processed before the Shutdown message — channel FIFO order)
    if let Some(batch) = batcher.take() {
        dispatch(batch);
    }
    for w in &workers {
        let _ = w.tx.send(WorkerMsg::Shutdown);
    }
    Ok(())
}
