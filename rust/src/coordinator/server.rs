//! The serving loop: one executor thread owning the [`InferenceEngine`],
//! fed by client handles through an MPSC channel, with deadline batching.
//!
//! The engine is constructed *inside* the worker thread and never crosses a
//! thread boundary (PJRT objects hold raw FFI pointers; the interp backend
//! simply doesn't need to move); clients exchange plain tensors. (tokio is
//! unavailable offline — std::thread + channels, see DESIGN.md.)

use std::sync::mpsc;
use std::time::{Duration, Instant};

use super::batcher::{Batcher, BatcherConfig};
use super::engine::{InferenceEngine, WeightMode};
use super::metrics::Metrics;
use crate::err;
use crate::runtime::BackendKind;
use crate::tensor::Tensor;
use crate::util::error::Result;

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub artifacts_dir: String,
    pub variant: String,
    pub mode: WeightMode,
    pub seed: u64,
    pub batcher: BatcherConfig,
    /// Which spectral-conv backend the worker's engine runs on.
    pub backend: BackendKind,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            artifacts_dir: "artifacts".into(),
            variant: "vgg16-cifar".into(),
            mode: WeightMode::Pruned { alpha: 4 },
            seed: 7,
            batcher: BatcherConfig::default(),
            backend: BackendKind::default(),
        }
    }
}

struct Request {
    image: Tensor,
    submitted: Instant,
    reply: mpsc::Sender<Result<Response>>,
}

/// A completed inference.
#[derive(Debug, Clone)]
pub struct Response {
    pub logits: Vec<f32>,
    pub latency: Duration,
    pub batch_size: usize,
}

enum Msg {
    Infer(Request),
    Snapshot(mpsc::Sender<Metrics>),
    Shutdown,
}

/// Running server + client handle factory.
pub struct Server {
    tx: mpsc::Sender<Msg>,
    worker: Option<std::thread::JoinHandle<Result<()>>>,
}

/// Cheap cloneable client handle.
#[derive(Clone)]
pub struct Client {
    tx: mpsc::Sender<Msg>,
}

impl Client {
    /// Blocking inference call.
    pub fn infer(&self, image: Tensor) -> Result<Response> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Infer(Request { image, submitted: Instant::now(), reply }))
            .map_err(|_| err!("server stopped"))?;
        rx.recv().map_err(|_| err!("server dropped request"))?
    }

    /// Fire-and-collect: submit without waiting; returns the receiver.
    pub fn infer_async(&self, image: Tensor) -> Result<mpsc::Receiver<Result<Response>>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Infer(Request { image, submitted: Instant::now(), reply }))
            .map_err(|_| err!("server stopped"))?;
        Ok(rx)
    }
}

impl Server {
    /// Start the worker; blocks until the engine has loaded (compile
    /// warm-up) so the first request doesn't pay startup cost.
    pub fn start(cfg: ServerConfig) -> Result<Server> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let worker = std::thread::Builder::new()
            .name("sf-serve".into())
            .spawn(move || worker_loop(cfg, rx, ready_tx))
            .expect("spawn worker");
        ready_rx
            .recv()
            .map_err(|_| err!("server worker died during startup"))??;
        Ok(Server { tx, worker: Some(worker) })
    }

    pub fn client(&self) -> Client {
        Client { tx: self.tx.clone() }
    }

    /// Snapshot current metrics.
    pub fn metrics(&self) -> Result<Metrics> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Msg::Snapshot(tx)).map_err(|_| err!("server stopped"))?;
        rx.recv().map_err(|_| err!("server stopped"))
    }

    /// Graceful shutdown (flushes pending batches).
    pub fn shutdown(mut self) -> Result<()> {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            w.join().map_err(|_| err!("worker panicked"))??;
        }
        Ok(())
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    cfg: ServerConfig,
    rx: mpsc::Receiver<Msg>,
    ready: mpsc::Sender<Result<()>>,
) -> Result<()> {
    let mut engine = match InferenceEngine::new_with(
        &cfg.artifacts_dir,
        &cfg.variant,
        cfg.mode,
        cfg.seed,
        cfg.backend,
    ) {
        Ok(e) => {
            let _ = ready.send(Ok(()));
            e
        }
        Err(e) => {
            let _ = ready.send(Err(e.clone()));
            return Err(e);
        }
    };
    let mut batcher: Batcher<Request> = Batcher::new(cfg.batcher);
    let mut metrics = Metrics::new();

    let run_batch = |batch: Vec<Request>, engine: &mut InferenceEngine, metrics: &mut Metrics| {
        let size = batch.len();
        metrics.record_batch(size);
        for req in batch {
            let result = engine.forward(&req.image).map(|logits| {
                let latency = req.submitted.elapsed();
                metrics.record_request(latency);
                Response { logits, latency, batch_size: size }
            });
            let _ = req.reply.send(result);
        }
    };

    loop {
        // Park until the next message or the batch deadline.
        let msg = match batcher.time_to_deadline(Instant::now()) {
            Some(d) => match rx.recv_timeout(d) {
                Ok(m) => Some(m),
                Err(mpsc::RecvTimeoutError::Timeout) => None,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            },
            None => match rx.recv() {
                Ok(m) => Some(m),
                Err(_) => break,
            },
        };
        match msg {
            Some(Msg::Infer(req)) => {
                if let Some(batch) = batcher.push(req, Instant::now()) {
                    run_batch(batch, &mut engine, &mut metrics);
                }
            }
            Some(Msg::Snapshot(tx)) => {
                let _ = tx.send(metrics.clone());
            }
            Some(Msg::Shutdown) => break,
            None => {}
        }
        if let Some(batch) = batcher.poll(Instant::now()) {
            run_batch(batch, &mut engine, &mut metrics);
        }
    }
    // flush
    if let Some(batch) = batcher.take() {
        run_batch(batch, &mut engine, &mut metrics);
    }
    Ok(())
}
