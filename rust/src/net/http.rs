//! Minimal HTTP/1.1 on `std::net` (offline substitute for `hyper`).
//!
//! Exactly the slice the serving front-end needs: strict request parsing
//! with hard caps on every dimension an untrusted peer controls (request
//! line length, header count/length, body size, total read time),
//! keep-alive connection reuse, and a response writer. The parser is
//! deliberately conservative — anything outside the narrow grammar the
//! front-end speaks (`GET`/`POST`, absolute path target, `HTTP/1.0|1.1`,
//! `Content-Length`-framed bodies) is rejected with a 4xx/5xx rather than
//! guessed at. Chunked transfer encoding is not implemented (501).
//!
//! Reading is deadline-based, not just timeout-based: [`HttpConn`] re-arms
//! the socket read timeout to the *remaining* request budget before every
//! `read`, so a slow-loris peer dripping one byte per poll still hits the
//! deadline instead of resetting it ([`HttpLimits::read_timeout`] bounds
//! the whole request read, headers and body together).
//!
//! The same [`HttpConn`] type also parses *responses*
//! ([`HttpConn::read_response`]) so the load generator and the tests speak
//! the protocol through one implementation.

use std::io::{self, Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use crate::util::error::Error;

/// Parse budget for one connection (every knob caps something a hostile
/// peer controls).
#[derive(Debug, Clone, Copy)]
pub struct HttpLimits {
    /// Maximum length of the request line and of each header line (bytes,
    /// excluding CRLF).
    pub max_line: usize,
    /// Maximum number of header fields per request.
    pub max_headers: usize,
    /// Maximum `Content-Length` accepted (larger bodies get 413 before a
    /// single body byte is read).
    pub max_body: usize,
    /// Total wall-clock budget for reading one request (headers + body).
    /// Also bounds how long an idle keep-alive connection is held open.
    pub read_timeout: Duration,
    /// Requests served per connection before it is closed (keep-alive cap).
    pub max_requests_per_conn: usize,
}

impl Default for HttpLimits {
    fn default() -> Self {
        HttpLimits {
            max_line: 8 << 10,
            max_headers: 64,
            max_body: 16 << 20,
            read_timeout: Duration::from_secs(10),
            max_requests_per_conn: 1000,
        }
    }
}

/// Protocol-level error: `status` is the HTTP status to answer with
/// (408 for deadline expiry), or `0` for transport failures where no
/// response can be written (peer vanished mid-read).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HttpError {
    pub status: u16,
    pub message: String,
}

impl HttpError {
    /// Build a protocol error carrying the HTTP status to answer with.
    pub fn new(status: u16, message: impl Into<String>) -> Self {
        HttpError { status, message: message.into() }
    }

    fn transport(e: io::Error) -> Self {
        HttpError { status: 0, message: e.to_string() }
    }

    /// Deadline expiry (the slow-loris outcome).
    pub fn is_timeout(&self) -> bool {
        self.status == 408
    }
}

impl std::fmt::Display for HttpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.status == 0 {
            write!(f, "http transport error: {}", self.message)
        } else {
            write!(f, "http {}: {}", self.status, self.message)
        }
    }
}

impl From<HttpError> for Error {
    fn from(e: HttpError) -> Self {
        Error::msg(e.to_string())
    }
}

/// One parsed request. Header names are lowercased at parse time; values
/// keep their bytes (trimmed of surrounding whitespace).
#[derive(Debug, Clone)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
    keep_alive: bool,
}

impl HttpRequest {
    /// Case-insensitive header lookup (`name` must be lowercase).
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }

    /// Whether the peer asked to keep the connection open (HTTP/1.1
    /// default, overridden by `Connection:` either way).
    pub fn keep_alive(&self) -> bool {
        self.keep_alive
    }
}

/// Pull one CRLF-terminated line out of `buf` starting at `pos`.
///
/// `Ok(None)` means the line is still incomplete; the `max` cap is enforced
/// on the incomplete prefix too, so a peer cannot grow the buffer by never
/// sending the terminator.
fn take_line(
    buf: &[u8],
    pos: usize,
    max: usize,
) -> Result<Option<(String, usize)>, HttpError> {
    match buf[pos..].iter().position(|&b| b == b'\n') {
        Some(i) => {
            if i > max {
                return Err(HttpError::new(400, "header line too long"));
            }
            let mut line = buf[pos..pos + i].to_vec();
            if line.last() == Some(&b'\r') {
                line.pop();
            }
            match String::from_utf8(line) {
                Ok(s) => Ok(Some((s, pos + i + 1))),
                Err(_) => Err(HttpError::new(400, "non-utf8 bytes in header")),
            }
        }
        None => {
            if buf.len() - pos > max {
                return Err(HttpError::new(400, "header line too long"));
            }
            Ok(None)
        }
    }
}

/// Attempt to parse one complete request from `buf` without doing any I/O.
///
/// This is the incremental core shared by the blocking reader
/// ([`HttpConn::read_request`]) and the event-driven front-end, which feeds
/// it the connection's receive buffer after every poll wakeup:
///
/// * `Ok(None)` — `buf` holds only a prefix of a request; read more bytes.
/// * `Ok(Some((req, consumed)))` — one full request; drop `consumed` bytes.
/// * `Err(e)` — protocol violation. Every cap is enforced on *incomplete*
///   data (request-line/header length, header count, `Content-Length` before
///   any body byte), so a hostile peer can never grow the buffer past
///   `max_line · max_headers + max_body` or stall a decision it has already
///   lost.
pub fn try_parse_request(
    buf: &[u8],
    limits: &HttpLimits,
) -> Result<Option<(HttpRequest, usize)>, HttpError> {
    let (start_line, mut pos) = match take_line(buf, 0, limits.max_line)? {
        Some(x) => x,
        None => return Ok(None),
    };
    let parts: Vec<&str> = start_line.split(' ').collect();
    if parts.len() != 3 || parts.iter().any(|p| p.is_empty()) {
        return Err(HttpError::new(400, "malformed request line"));
    }
    let (method, target, version) = (parts[0], parts[1], parts[2]);
    if method.len() > 16 || !method.bytes().all(|b| b.is_ascii_uppercase()) {
        return Err(HttpError::new(400, "malformed method"));
    }
    if !target.starts_with('/') || target.len() > limits.max_line {
        return Err(HttpError::new(400, "target must be an absolute path"));
    }
    let mut keep_alive = match version {
        "HTTP/1.1" => true,
        "HTTP/1.0" => false,
        _ => return Err(HttpError::new(505, "unsupported HTTP version")),
    };
    let mut headers: Vec<(String, String)> = Vec::new();
    loop {
        let (line, next) = match take_line(buf, pos, limits.max_line)? {
            Some(x) => x,
            None => return Ok(None),
        };
        pos = next;
        if line.is_empty() {
            break;
        }
        if headers.len() >= limits.max_headers {
            return Err(HttpError::new(431, "too many header fields"));
        }
        let (name, value) = line
            .split_once(':')
            .ok_or_else(|| HttpError::new(400, "malformed header field"))?;
        if name.is_empty() || name.contains(' ') || name.contains('\t') {
            return Err(HttpError::new(400, "malformed header name"));
        }
        headers.push((name.to_ascii_lowercase(), value.trim().to_string()));
    }
    let header = |n: &str| headers.iter().find(|(k, _)| k == n).map(|(_, v)| v.as_str());
    if header("transfer-encoding").is_some() {
        return Err(HttpError::new(501, "transfer-encoding not supported"));
    }
    match header("connection").map(str::to_ascii_lowercase).as_deref() {
        Some("close") => keep_alive = false,
        Some("keep-alive") => keep_alive = true,
        _ => {}
    }
    let body_len = match header("content-length") {
        None => 0,
        Some(v) => {
            v.parse::<usize>().map_err(|_| HttpError::new(400, "bad content-length"))?
        }
    };
    if body_len > limits.max_body {
        return Err(HttpError::new(
            413,
            format!("body of {body_len} bytes exceeds the {} byte cap", limits.max_body),
        ));
    }
    if buf.len() - pos < body_len {
        return Ok(None);
    }
    let body = buf[pos..pos + body_len].to_vec();
    Ok(Some((
        HttpRequest {
            method: method.to_string(),
            path: target.to_string(),
            headers,
            body,
            keep_alive,
        },
        pos + body_len,
    )))
}

/// Streams that can bound an individual `read` call. [`TcpStream`] re-arms
/// its socket timeout; in-memory test readers are instantaneous and need
/// nothing.
pub trait TimeoutIo: Read {
    fn arm(&mut self, _remaining: Duration) -> io::Result<()> {
        Ok(())
    }
}

impl TimeoutIo for TcpStream {
    fn arm(&mut self, remaining: Duration) -> io::Result<()> {
        // set_read_timeout rejects a zero Duration; the deadline check in
        // `refill` already handled the expired case.
        self.set_read_timeout(Some(remaining.max(Duration::from_millis(1))))
    }
}

impl<T: AsRef<[u8]>> TimeoutIo for io::Cursor<T> {}

/// Buffered, deadline-aware reader for one connection (request side on the
/// server, response side in the load generator). Buffering lives here, not
/// in a `BufReader`, so read-ahead bytes survive across keep-alive
/// requests and every refill can re-arm the transport deadline.
pub struct HttpConn<S: TimeoutIo> {
    stream: S,
    buf: Vec<u8>,
    start: usize,
}

impl<S: TimeoutIo> HttpConn<S> {
    pub fn new(stream: S) -> Self {
        HttpConn { stream, buf: Vec::with_capacity(4096), start: 0 }
    }

    fn buffered(&self) -> &[u8] {
        &self.buf[self.start..]
    }

    fn consume(&mut self, n: usize) {
        self.start += n;
        if self.start == self.buf.len() {
            self.buf.clear();
            self.start = 0;
        }
    }

    /// Pull more bytes from the transport under the request deadline.
    /// Returns the number of new bytes (0 = EOF).
    fn refill(&mut self, deadline: Instant) -> Result<usize, HttpError> {
        let remaining = deadline.saturating_duration_since(Instant::now());
        if remaining.is_zero() {
            return Err(HttpError::new(408, "read deadline expired"));
        }
        self.stream.arm(remaining).map_err(HttpError::transport)?;
        let mut tmp = [0u8; 4096];
        match self.stream.read(&mut tmp) {
            Ok(0) => Ok(0),
            Ok(n) => {
                self.buf.extend_from_slice(&tmp[..n]);
                Ok(n)
            }
            Err(e) if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) => {
                Err(HttpError::new(408, "read deadline expired"))
            }
            Err(e) => Err(HttpError::transport(e)),
        }
    }

    /// Read one CRLF-terminated line of at most `max` bytes. `Ok(None)` on
    /// clean EOF at a line boundary.
    fn read_line(&mut self, max: usize, deadline: Instant) -> Result<Option<String>, HttpError> {
        let mut scanned = 0;
        loop {
            if let Some(i) = self.buffered()[scanned..].iter().position(|&b| b == b'\n') {
                let end = scanned + i;
                if end > max {
                    return Err(HttpError::new(400, "header line too long"));
                }
                let mut line = self.buffered()[..end].to_vec();
                self.consume(end + 1);
                if line.last() == Some(&b'\r') {
                    line.pop();
                }
                return match String::from_utf8(line) {
                    Ok(s) => Ok(Some(s)),
                    Err(_) => Err(HttpError::new(400, "non-utf8 bytes in header")),
                };
            }
            scanned = self.buffered().len();
            if scanned > max {
                return Err(HttpError::new(400, "header line too long"));
            }
            if self.refill(deadline)? == 0 {
                return if scanned == 0 {
                    Ok(None)
                } else {
                    Err(HttpError::new(400, "truncated request"))
                };
            }
        }
    }

    /// Read exactly `len` body bytes under the deadline.
    fn read_body(&mut self, len: usize, deadline: Instant) -> Result<Vec<u8>, HttpError> {
        let mut out = Vec::with_capacity(len.min(1 << 20));
        loop {
            let take = self.buffered().len().min(len - out.len());
            out.extend_from_slice(&self.buffered()[..take]);
            self.consume(take);
            if out.len() == len {
                return Ok(out);
            }
            if self.refill(deadline)? == 0 {
                return Err(HttpError::new(400, "truncated body"));
            }
        }
    }

    /// Parse one request. `Ok(None)` means the peer closed (or idled past
    /// the deadline) between requests — the clean keep-alive exit; errors
    /// carry the status to answer with before closing.
    ///
    /// This is a blocking driver around [`try_parse_request`]: refill the
    /// buffer under the deadline, re-attempt the pure parse, repeat.
    pub fn read_request(&mut self, limits: &HttpLimits) -> Result<Option<HttpRequest>, HttpError> {
        let deadline = Instant::now() + limits.read_timeout;
        loop {
            if let Some((req, consumed)) = try_parse_request(self.buffered(), limits)? {
                self.consume(consumed);
                return Ok(Some(req));
            }
            match self.refill(deadline) {
                Ok(0) => {
                    return if self.buffered().is_empty() {
                        Ok(None)
                    } else {
                        Err(HttpError::new(400, "truncated request"))
                    };
                }
                Ok(_) => {}
                // idle keep-alive: the deadline expired with zero request
                // bytes pending — that is a quiet close, not a slow peer
                // to 408
                Err(e) if e.is_timeout() && self.buffered().is_empty() => return Ok(None),
                Err(e) => return Err(e),
            }
        }
    }

    /// Parse one response (client side: the load generator and tests).
    /// Returns `(status, body)`; bodies must be `Content-Length`-framed,
    /// which is the only framing [`write_response`] emits.
    pub fn read_response(&mut self, limits: &HttpLimits) -> Result<(u16, Vec<u8>), HttpError> {
        let deadline = Instant::now() + limits.read_timeout;
        let status_line = match self.read_line(limits.max_line, deadline)? {
            Some(l) => l,
            None => return Err(HttpError::new(0, "connection closed before response")),
        };
        let mut parts = status_line.splitn(3, ' ');
        let version = parts.next().unwrap_or("");
        let status = parts
            .next()
            .and_then(|s| s.parse::<u16>().ok())
            .ok_or_else(|| HttpError::new(0, "malformed status line"))?;
        if !version.starts_with("HTTP/1.") {
            return Err(HttpError::new(0, "malformed status line"));
        }
        let mut body_len = 0usize;
        for _ in 0..limits.max_headers {
            let line = match self.read_line(limits.max_line, deadline)? {
                Some(l) => l,
                None => return Err(HttpError::new(0, "truncated response headers")),
            };
            if line.is_empty() {
                let body = if body_len > 0 {
                    self.read_body(body_len.min(limits.max_body), deadline)?
                } else {
                    Vec::new()
                };
                return Ok((status, body));
            }
            if let Some((name, value)) = line.split_once(':') {
                if name.eq_ignore_ascii_case("content-length") {
                    body_len = value
                        .trim()
                        .parse()
                        .map_err(|_| HttpError::new(0, "bad response content-length"))?;
                }
            }
        }
        Err(HttpError::new(0, "too many response headers"))
    }
}

/// Reason phrase for the statuses this server emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        505 => "HTTP Version Not Supported",
        _ => "Unknown",
    }
}

/// Write one `Content-Length`-framed response.
pub fn write_response(
    w: &mut impl Write,
    status: u16,
    content_type: &str,
    body: &[u8],
    keep_alive: bool,
) -> io::Result<()> {
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
        status,
        reason(status),
        content_type,
        body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    w.write_all(head.as_bytes())?;
    w.write_all(body)?;
    w.flush()
}

/// Render one `Content-Length`-framed request (the load generator's side).
pub fn format_request(method: &str, path: &str, host: &str, body: &[u8]) -> Vec<u8> {
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {host}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: keep-alive\r\n\r\n",
        body.len(),
    );
    let mut out = Vec::with_capacity(head.len() + body.len());
    out.extend_from_slice(head.as_bytes());
    out.extend_from_slice(body);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn conn(bytes: &[u8]) -> HttpConn<io::Cursor<Vec<u8>>> {
        HttpConn::new(io::Cursor::new(bytes.to_vec()))
    }

    fn parse(bytes: &[u8]) -> Result<Option<HttpRequest>, HttpError> {
        conn(bytes).read_request(&HttpLimits::default())
    }

    #[test]
    fn parses_get_and_post() {
        let r = parse(b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n").unwrap().unwrap();
        assert_eq!(r.method, "GET");
        assert_eq!(r.path, "/healthz");
        assert!(r.keep_alive());
        assert_eq!(r.header("host"), Some("x"));

        let r = parse(b"POST /infer HTTP/1.1\r\nContent-Length: 4\r\n\r\nabcd")
            .unwrap()
            .unwrap();
        assert_eq!(r.body, b"abcd");
    }

    #[test]
    fn keep_alive_rules() {
        let r = parse(b"GET / HTTP/1.0\r\n\r\n").unwrap().unwrap();
        assert!(!r.keep_alive(), "1.0 defaults to close");
        let r = parse(b"GET / HTTP/1.1\r\nConnection: close\r\n\r\n").unwrap().unwrap();
        assert!(!r.keep_alive());
        let r = parse(b"GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n").unwrap().unwrap();
        assert!(r.keep_alive());
    }

    #[test]
    fn two_requests_on_one_connection() {
        let mut c = conn(b"GET /a HTTP/1.1\r\n\r\nGET /b HTTP/1.1\r\n\r\n");
        let l = HttpLimits::default();
        assert_eq!(c.read_request(&l).unwrap().unwrap().path, "/a");
        assert_eq!(c.read_request(&l).unwrap().unwrap().path, "/b");
        assert!(c.read_request(&l).unwrap().is_none(), "clean EOF after the last request");
    }

    #[test]
    fn rejects_malformed_request_lines() {
        for bad in [
            &b"NOT A VALID LINE AT ALL\r\n\r\n"[..],
            b"GET /\r\n\r\n",
            b"GET  / HTTP/1.1\r\n\r\n",
            b"get / HTTP/1.1\r\n\r\n",
            b"GET x HTTP/1.1\r\n\r\n",
            b"\r\nGET / HTTP/1.1\r\n\r\n",
        ] {
            let e = parse(bad).unwrap_err();
            assert_eq!(e.status, 400, "{:?}", String::from_utf8_lossy(bad));
        }
        assert_eq!(parse(b"GET / HTTP/2.0\r\n\r\n").unwrap_err().status, 505);
    }

    #[test]
    fn rejects_bad_headers_and_bodies() {
        assert_eq!(parse(b"GET / HTTP/1.1\r\nno-colon-here\r\n\r\n").unwrap_err().status, 400);
        assert_eq!(
            parse(b"GET / HTTP/1.1\r\nContent-Length: ten\r\n\r\n").unwrap_err().status,
            400
        );
        assert_eq!(
            parse(b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n")
                .unwrap_err()
                .status,
            501
        );
        // truncated body: Content-Length promises more than the wire has
        assert_eq!(
            parse(b"POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc").unwrap_err().status,
            400
        );
    }

    #[test]
    fn caps_enforced() {
        let limits =
            HttpLimits { max_line: 32, max_headers: 2, max_body: 8, ..HttpLimits::default() };
        let long = format!("GET /{} HTTP/1.1\r\n\r\n", "a".repeat(100));
        assert_eq!(conn(long.as_bytes()).read_request(&limits).unwrap_err().status, 400);
        let many = b"GET / HTTP/1.1\r\na: 1\r\nb: 2\r\nc: 3\r\n\r\n";
        assert_eq!(conn(many).read_request(&limits).unwrap_err().status, 431);
        // oversized Content-Length is rejected before any body byte is read
        let big = b"POST / HTTP/1.1\r\nContent-Length: 999\r\n\r\n";
        assert_eq!(conn(big).read_request(&limits).unwrap_err().status, 413);
    }

    #[test]
    fn response_roundtrip() {
        let mut wire = Vec::new();
        write_response(&mut wire, 429, "application/json", b"{\"error\":\"busy\"}", true)
            .unwrap();
        let (status, body) = conn(&wire).read_response(&HttpLimits::default()).unwrap();
        assert_eq!(status, 429);
        assert_eq!(body, b"{\"error\":\"busy\"}");
        let text = String::from_utf8(wire).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"));
        assert!(text.contains("Connection: keep-alive"));
    }

    #[test]
    fn incremental_parse_waits_for_complete_request() {
        let wire = b"POST /v1/models/demo/infer HTTP/1.1\r\nContent-Length: 4\r\n\r\nabcd";
        let limits = HttpLimits::default();
        // every strict prefix is "need more bytes", never an error
        for cut in 0..wire.len() {
            let r = try_parse_request(&wire[..cut], &limits).unwrap();
            assert!(r.is_none(), "prefix of {cut} bytes must be incomplete");
        }
        let (req, consumed) = try_parse_request(wire, &limits).unwrap().unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/models/demo/infer");
        assert_eq!(req.body, b"abcd");
        assert_eq!(consumed, wire.len());
    }

    #[test]
    fn incremental_parse_consumes_only_one_request() {
        let wire = b"GET /a HTTP/1.1\r\n\r\nGET /b HTTP/1.1\r\n\r\n";
        let limits = HttpLimits::default();
        let (req, consumed) = try_parse_request(wire, &limits).unwrap().unwrap();
        assert_eq!(req.path, "/a");
        let (req2, consumed2) = try_parse_request(&wire[consumed..], &limits).unwrap().unwrap();
        assert_eq!(req2.path, "/b");
        assert_eq!(consumed + consumed2, wire.len());
    }

    #[test]
    fn incremental_parse_enforces_caps_on_prefixes() {
        let limits =
            HttpLimits { max_line: 32, max_headers: 2, max_body: 8, ..HttpLimits::default() };
        // unterminated request line past the cap fails without a newline
        let long = format!("GET /{}", "a".repeat(100));
        assert_eq!(
            try_parse_request(long.as_bytes(), &limits).unwrap_err().status,
            400
        );
        // header count violation fires before the blank line arrives
        let many = b"GET / HTTP/1.1\r\na: 1\r\nb: 2\r\nc: 3\r\n";
        assert_eq!(try_parse_request(many, &limits).unwrap_err().status, 431);
        // oversized Content-Length is rejected before any body byte exists
        let big = b"POST / HTTP/1.1\r\nContent-Length: 999\r\n\r\n";
        assert_eq!(try_parse_request(big, &limits).unwrap_err().status, 413);
        // malformed request line fails as soon as its newline lands
        assert_eq!(
            try_parse_request(b"GARBAGE\r\n", &HttpLimits::default()).unwrap_err().status,
            400
        );
    }

    #[test]
    fn request_formatting_roundtrips() {
        let wire = format_request("POST", "/infer", "h:1", b"{\"seed\":7}");
        let r = parse(&wire).unwrap().unwrap();
        assert_eq!(r.method, "POST");
        assert_eq!(r.path, "/infer");
        assert_eq!(r.body, b"{\"seed\":7}");
        assert!(r.keep_alive());
    }
}
