//! Event-driven HTTP front-end over the model registry: a fixed pool of
//! connection workers multiplexing every socket with `poll(2)`, routing
//! requests into per-model engine pools.
//!
//! ```text
//! socket ── acceptor (conns ≤ max_conns, else 503) ── least-loaded worker
//!    │
//!    ▼  (fixed pool of event_workers threads; each owns its conns)
//! poller — nonblocking reads into a per-conn buffer; `try_parse_request`
//!    │     re-attempted after every wakeup (slow-loris still hits the
//!    │     deadline: caps are enforced on incomplete prefixes)
//!    ▼
//! conn state machine — idle ⇆ reading → executing → flushing, one struct
//!    │     per connection instead of one thread: tens of thousands of
//!    │     mostly-idle keep-alive connections cost ~zero threads
//!    ▼
//! route — /v1/models/<name>/… picks the model; legacy /infer, /metrics,
//!    │     /healthz alias onto the registry's default model
//!    ▼
//! admission — per-model in-flight quota (`ModelPool::try_admit`), 429
//!    │     past the budget; an RAII guard releases slots even if the
//!    │     connection dies mid-request
//!    ▼
//! registry → pool — `Client::infer_async` receivers are polled from the
//!          event loop (never a blocking `recv`), so one worker drives
//!          many in-flight inferences concurrently
//! ```
//!
//! Admission control is per model: at most `ModelPool::max_inflight`
//! requests may be queued-or-executing in that model's pool at once. The
//! bound makes overload a *fast* failure — a 429 the moment the budget is
//! exceeded — instead of an unbounded queue whose tail latency quietly
//! explodes, which is the contract the closed-loop load generator tests.
//!
//! Shutdown is graceful and ordered: [`HttpFrontend::shutdown`] (1) flips
//! the drain flag so `/healthz` answers 503 and new inferences are
//! refused, (2) wakes and stops the acceptor, (3) waits (bounded by
//! [`NetConfig::drain_grace`]) for admitted requests to finish, (4) stops
//! the connection workers, then (5) shuts every registry pool down, which
//! flushes any open batch before the engine workers exit.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use super::http::{self, HttpLimits, HttpRequest};
use super::poll::{self, PollSpec, WakePipe, Waker};
use super::proto;
use crate::coordinator::{
    AdminError, AdmitGuard, ModelFetch, ModelRegistry, Response,
};
use crate::obs::{PromWriter, WireTiming};
use crate::util::error::{Context, Result};
use crate::util::json::{arr, num, obj, s};

/// Front-end configuration (the serving knobs the wire adds on top of the
/// registry's per-model specs).
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Bind address, e.g. `127.0.0.1:7878` (`:0` picks a free port —
    /// [`HttpFrontend::local_addr`] reports the real one).
    pub addr: String,
    /// Concurrent connections; excess connections get 503 + close. With
    /// event-driven workers this is an fd-budget guard, not a thread
    /// count — idle connections are nearly free.
    pub max_conns: usize,
    /// Fixed number of connection-worker threads multiplexing every
    /// connection (0 acts as 1). This does not bound concurrent requests —
    /// one worker drives many in-flight inferences.
    pub event_workers: usize,
    /// HTTP parse caps + per-request read deadline.
    pub limits: HttpLimits,
    /// How long an idle keep-alive connection (no partial request, nothing
    /// to write) is held open before a quiet close.
    pub idle_timeout: Duration,
    /// How long shutdown waits for admitted requests to drain.
    pub drain_grace: Duration,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            addr: "127.0.0.1:0".into(),
            max_conns: 16384,
            event_workers: 4,
            limits: HttpLimits::default(),
            idle_timeout: Duration::from_secs(60),
            drain_grace: Duration::from_secs(10),
        }
    }
}

/// Shared front-end state (acceptor + every connection worker).
struct Gate {
    /// Drain mode: `/healthz` answers 503 and new inferences are refused,
    /// but connections are still accepted and answered (load-balancer
    /// probes must see the 503, not a dead port).
    draining: AtomicBool,
    /// Shutdown: acceptor and workers exit. Implies `draining`.
    stopping: AtomicBool,
    /// Open connections across all workers.
    conns: AtomicUsize,
}

/// A running HTTP front-end over a shared [`ModelRegistry`].
pub struct HttpFrontend {
    addr: SocketAddr,
    gate: Arc<Gate>,
    registry: Arc<ModelRegistry>,
    acceptor: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    wakers: Vec<Waker>,
    drain_grace: Duration,
}

/// Acceptor-side handle to one connection worker.
struct WorkerHandle {
    tx: mpsc::Sender<NewConn>,
    waker: Waker,
    load: Arc<AtomicUsize>,
}

/// A freshly accepted connection in flight to its worker.
struct NewConn {
    stream: TcpStream,
    slot: ConnSlot,
}

impl HttpFrontend {
    /// Bind and start serving every model in `registry`. Fails fast on an
    /// unbindable address.
    pub fn start(registry: Arc<ModelRegistry>, cfg: NetConfig) -> Result<HttpFrontend> {
        let listener =
            TcpListener::bind(&cfg.addr).with_context(|| format!("binding {}", cfg.addr))?;
        let addr = listener.local_addr().context("resolving bound address")?;
        let gate = Arc::new(Gate {
            draining: AtomicBool::new(false),
            stopping: AtomicBool::new(false),
            conns: AtomicUsize::new(0),
        });
        let n = cfg.event_workers.max(1);
        let mut handles = Vec::with_capacity(n);
        let mut workers = Vec::with_capacity(n);
        let mut wakers = Vec::with_capacity(n);
        for wi in 0..n {
            let (tx, rx) = mpsc::channel::<NewConn>();
            let wake = WakePipe::new().context("creating worker wake pipe")?;
            let acceptor_waker = wake.waker().context("cloning worker waker")?;
            let frontend_waker = wake.waker().context("cloning worker waker")?;
            let load = Arc::new(AtomicUsize::new(0));
            let wgate = gate.clone();
            let wregistry = registry.clone();
            let wcfg = cfg.clone();
            let handle = std::thread::Builder::new()
                .name(format!("sf-http-ev-{wi}"))
                .spawn(move || worker_loop(rx, wake, wregistry, wgate, wcfg))
                .expect("spawn http event worker");
            handles.push(WorkerHandle { tx, waker: acceptor_waker, load });
            wakers.push(frontend_waker);
            workers.push(handle);
        }
        let agate = gate.clone();
        let acfg = cfg.clone();
        let acceptor = std::thread::Builder::new()
            .name("sf-http-accept".into())
            .spawn(move || accept_loop(listener, handles, agate, acfg))
            .expect("spawn http acceptor");
        Ok(HttpFrontend {
            addr,
            gate,
            registry,
            acceptor: Some(acceptor),
            workers,
            wakers,
            drain_grace: cfg.drain_grace,
        })
    }

    /// The actual bound address (resolves `:0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Enter drain mode without tearing anything down: `/healthz` flips to
    /// 503 and new inferences are refused while in-flight work completes.
    /// (Load balancers watch exactly this to take a replica out of
    /// rotation before it stops.)
    pub fn begin_drain(&self) {
        self.gate.draining.store(true, Ordering::SeqCst);
    }

    /// Inference requests currently admitted across every model pool.
    pub fn inflight(&self) -> usize {
        self.registry.total_inflight()
    }

    /// Open connections across the worker pool.
    pub fn connections(&self) -> usize {
        self.gate.conns.load(Ordering::SeqCst)
    }

    /// Graceful shutdown: drain, stop accepting, stop the workers, retire
    /// every registry pool.
    pub fn shutdown(mut self) -> Result<()> {
        self.finish()
    }

    fn finish(&mut self) -> Result<()> {
        self.begin_drain();
        self.gate.stopping.store(true, Ordering::SeqCst);
        // the acceptor parks in accept(): a self-connection wakes it so it
        // can observe the stop flag and exit
        let _ = TcpStream::connect(self.addr);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        let deadline = Instant::now() + self.drain_grace;
        while self.registry.total_inflight() > 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        for w in &self.wakers {
            w.wake();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // retire every pool: flushes open batches before engine workers exit
        self.registry.shutdown();
        Ok(())
    }
}

impl Drop for HttpFrontend {
    fn drop(&mut self) {
        let _ = self.finish();
    }
}

/// Releases one `Gate::conns` slot on drop (including panic unwinds), plus
/// the owning worker's load count once attached.
struct ConnSlot {
    gate: Arc<Gate>,
    load: Arc<AtomicUsize>,
}

impl Drop for ConnSlot {
    fn drop(&mut self) {
        self.gate.conns.fetch_sub(1, Ordering::SeqCst);
        self.load.fetch_sub(1, Ordering::SeqCst);
    }
}

fn accept_loop(
    listener: TcpListener,
    workers: Vec<WorkerHandle>,
    gate: Arc<Gate>,
    cfg: NetConfig,
) {
    for stream in listener.incoming() {
        if gate.stopping.load(Ordering::SeqCst) {
            break;
        }
        let mut stream = match stream {
            Ok(s) => s,
            Err(_) => continue,
        };
        // connection bound: refuse loudly instead of queueing invisibly
        if gate.conns.fetch_add(1, Ordering::SeqCst) >= cfg.max_conns {
            gate.conns.fetch_sub(1, Ordering::SeqCst);
            let body =
                proto::error_body("overloaded", "connection capacity reached", None);
            let _ = http::write_response(
                &mut stream,
                503,
                "application/json",
                body.as_bytes(),
                false,
            );
            continue;
        }
        if stream.set_nonblocking(true).is_err() {
            gate.conns.fetch_sub(1, Ordering::SeqCst);
            continue;
        }
        // least-loaded worker pick: load counts open connections
        let worker = workers
            .iter()
            .min_by_key(|w| w.load.load(Ordering::SeqCst))
            .expect("front-end has at least one worker");
        worker.load.fetch_add(1, Ordering::SeqCst);
        let slot = ConnSlot { gate: gate.clone(), load: worker.load.clone() };
        // a send can only fail during shutdown (worker gone) — the slot's
        // Drop rebalances the counters either way
        if worker.tx.send(NewConn { stream, slot }).is_ok() {
            worker.waker.wake();
        }
    }
}

/// An inference executing in a model pool, driven from the event loop:
/// one receiver per image, polled with `try_recv` so the worker thread
/// never blocks on the engine.
struct Pending {
    rxs: Vec<mpsc::Receiver<Result<Response>>>,
    resps: Vec<Response>,
    /// Single-image request (`/infer` reply shape) vs `{"batch":[…]}`.
    single: bool,
    /// Keep-alive decision made when the request was parsed.
    keep: bool,
    model: String,
    /// Releases the per-model admission slots on drop.
    _guard: AdmitGuard,
}

/// Content type of the Prometheus text exposition format 0.0.4.
const PROM_CTYPE: &str = "text/plain; version=0.0.4";

/// What handling one parsed request produced.
enum Step {
    /// Answer immediately with a JSON body.
    Respond(u16, String),
    /// Answer immediately with an explicit content type (Prometheus text).
    RespondText(u16, &'static str, String),
    /// An admitted inference: poll it to completion from the event loop.
    Execute(Box<Pending>),
}

/// One connection's state machine. Lives in a worker's table, never a
/// dedicated thread.
struct Conn {
    stream: TcpStream,
    /// Received-but-unparsed bytes (the incremental parser's input).
    buf: Vec<u8>,
    /// Rendered-but-unsent response bytes.
    out: Vec<u8>,
    out_pos: usize,
    pending: Option<Box<Pending>>,
    served: usize,
    /// When the current partial request started arriving (drives the 408
    /// deadline; `None` while idle between requests).
    read_start: Option<Instant>,
    last_activity: Instant,
    /// Peer sent EOF; no further requests can arrive.
    peer_eof: bool,
    /// Finish flushing `out`, then close.
    close_after_flush: bool,
    /// Hard close deadline once `close_after_flush` is set (a peer that
    /// never reads its error response cannot pin the connection).
    close_by: Option<Instant>,
    closed: bool,
    _slot: ConnSlot,
}

impl Conn {
    fn new(stream: TcpStream, slot: ConnSlot) -> Conn {
        Conn {
            stream,
            buf: Vec::new(),
            out: Vec::new(),
            out_pos: 0,
            pending: None,
            served: 0,
            read_start: None,
            last_activity: Instant::now(),
            peer_eof: false,
            close_after_flush: false,
            close_by: None,
            closed: false,
            _slot: slot,
        }
    }

    fn wants_read(&self) -> bool {
        !self.closed && !self.close_after_flush && self.pending.is_none() && !self.peer_eof
    }

    fn wants_write(&self) -> bool {
        !self.closed && self.out_pos < self.out.len()
    }

    /// Append a rendered JSON response to the output buffer.
    fn enqueue(&mut self, status: u16, body: &str, keep: bool, limits: &HttpLimits) {
        self.enqueue_typed(status, "application/json", body, keep, limits);
    }

    /// Append a rendered response with an explicit content type.
    fn enqueue_typed(
        &mut self,
        status: u16,
        ctype: &str,
        body: &str,
        keep: bool,
        limits: &HttpLimits,
    ) {
        let _ = http::write_response(&mut self.out, status, ctype, body.as_bytes(), keep);
        if !keep {
            self.begin_close(limits);
        }
    }

    fn begin_close(&mut self, limits: &HttpLimits) {
        self.close_after_flush = true;
        if self.close_by.is_none() {
            self.close_by = Some(Instant::now() + limits.read_timeout);
        }
    }

    /// Nonblocking read until `WouldBlock`/EOF.
    fn on_readable(&mut self) {
        let mut tmp = [0u8; 8192];
        loop {
            match (&self.stream).read(&mut tmp) {
                Ok(0) => {
                    self.peer_eof = true;
                    return;
                }
                Ok(n) => self.buf.extend_from_slice(&tmp[..n]),
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(_) => {
                    self.closed = true;
                    return;
                }
            }
        }
    }

    /// Nonblocking write of whatever is queued.
    fn flush(&mut self) {
        while self.out_pos < self.out.len() {
            match (&self.stream).write(&self.out[self.out_pos..]) {
                Ok(0) => {
                    self.closed = true;
                    return;
                }
                Ok(n) => self.out_pos += n,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(_) => {
                    self.closed = true;
                    return;
                }
            }
        }
        self.out.clear();
        self.out_pos = 0;
        self.last_activity = Instant::now();
        if self.close_after_flush {
            self.closed = true;
        }
    }

    /// Parse and handle as many complete requests as the buffer holds
    /// (stopping at one in-flight inference at a time).
    fn try_advance(&mut self, registry: &Arc<ModelRegistry>, gate: &Gate, cfg: &NetConfig) {
        while !self.closed && !self.close_after_flush && self.pending.is_none() {
            match http::try_parse_request(&self.buf, &cfg.limits) {
                Ok(Some((req, consumed))) => {
                    self.buf.drain(..consumed);
                    self.read_start = None;
                    self.last_activity = Instant::now();
                    self.served += 1;
                    // the final permitted request must advertise the close —
                    // otherwise a keep-alive client writes request N+1 into
                    // a socket we are about to shut and sees a spurious error
                    let last = self.served >= cfg.limits.max_requests_per_conn;
                    let keep = req.keep_alive()
                        && !last
                        && !gate.draining.load(Ordering::SeqCst);
                    match dispatch(&req, keep, registry, gate) {
                        Step::Respond(status, body) => {
                            self.enqueue(status, &body, keep, &cfg.limits)
                        }
                        Step::RespondText(status, ctype, body) => {
                            self.enqueue_typed(status, ctype, &body, keep, &cfg.limits)
                        }
                        Step::Execute(pending) => self.pending = Some(pending),
                    }
                }
                Ok(None) => {
                    if !self.buf.is_empty() && self.read_start.is_none() {
                        self.read_start = Some(Instant::now());
                    }
                    if self.peer_eof {
                        if self.buf.is_empty() {
                            // clean keep-alive close at a request boundary
                            self.begin_close(&cfg.limits);
                        } else {
                            self.buf.clear();
                            let body = proto::error_body(
                                "bad_request",
                                "truncated request",
                                None,
                            );
                            self.enqueue(400, &body, false, &cfg.limits);
                        }
                    }
                    return;
                }
                Err(e) => {
                    // parse errors answer once, then the connection closes —
                    // a malformed peer never wedges a worker
                    self.buf.clear();
                    let body = proto::error_body(
                        proto::code_for_status(e.status),
                        &e.message,
                        None,
                    );
                    self.enqueue(e.status, &body, false, &cfg.limits);
                    return;
                }
            }
        }
    }

    /// Drive an in-flight inference forward without blocking. Completes
    /// the request (success or error) once every receiver has answered.
    fn poll_pending(&mut self, limits: &HttpLimits) {
        let Some(pending) = &mut self.pending else { return };
        let done = loop {
            if pending.resps.len() == pending.rxs.len() {
                let body = if pending.single {
                    proto::response_to_json(&pending.resps[0]).to_string()
                } else {
                    proto::batch_response_to_json(&pending.resps).to_string()
                };
                break Some((200u16, body, pending.keep));
            }
            match pending.rxs[pending.resps.len()].try_recv() {
                Ok(Ok(resp)) => pending.resps.push(resp),
                Ok(Err(e)) => {
                    // any failed image fails the whole request — the wire
                    // reply is all results or one error, never a mix
                    let (status, body) =
                        infer_error(&e.to_string(), Some(&pending.model));
                    break Some((status, body, pending.keep));
                }
                Err(mpsc::TryRecvError::Empty) => break None,
                Err(mpsc::TryRecvError::Disconnected) => {
                    let (status, body) =
                        infer_error("server dropped request", Some(&pending.model));
                    break Some((status, body, pending.keep));
                }
            }
        };
        if let Some((status, body, keep)) = done {
            self.pending = None; // drops the admission guard
            self.enqueue(status, &body, keep, limits);
        }
    }

    /// Enforce the read deadline (slow requests → 408) and the idle
    /// timeout (quiet close), plus the post-error close deadline.
    fn sweep(&mut self, now: Instant, cfg: &NetConfig) {
        if let Some(by) = self.close_by {
            if now >= by {
                self.closed = true;
                return;
            }
        }
        if self.pending.is_some() || self.close_after_flush {
            return;
        }
        if let Some(start) = self.read_start {
            if now.saturating_duration_since(start) >= cfg.limits.read_timeout {
                self.buf.clear();
                self.read_start = None;
                let body =
                    proto::error_body("timeout", "read deadline expired", None);
                self.enqueue(408, &body, false, &cfg.limits);
                return;
            }
        }
        if self.buf.is_empty()
            && self.out.is_empty()
            && now.saturating_duration_since(self.last_activity) >= cfg.idle_timeout
        {
            self.closed = true;
        }
    }

    /// Next instant at which this connection needs attention regardless of
    /// socket readiness (deadline expiry).
    fn next_deadline(&self, cfg: &NetConfig) -> Option<Instant> {
        if let Some(by) = self.close_by {
            return Some(by);
        }
        if self.pending.is_some() {
            return None;
        }
        if let Some(start) = self.read_start {
            return Some(start + cfg.limits.read_timeout);
        }
        if self.buf.is_empty() && self.out.is_empty() {
            return Some(self.last_activity + cfg.idle_timeout);
        }
        None
    }
}

/// One connection worker: multiplex every assigned connection over
/// `poll(2)`, never blocking on any single peer or inference.
fn worker_loop(
    rx: mpsc::Receiver<NewConn>,
    wake: WakePipe,
    registry: Arc<ModelRegistry>,
    gate: Arc<Gate>,
    cfg: NetConfig,
) {
    let mut conns: Vec<Conn> = Vec::new();
    loop {
        // intake newly accepted connections
        while let Ok(new) = rx.try_recv() {
            conns.push(Conn::new(new.stream, new.slot));
        }
        if gate.stopping.load(Ordering::SeqCst) {
            // bounded farewell: one flush attempt each, then close all
            for c in &mut conns {
                c.flush();
            }
            conns.clear();
            return;
        }
        let now = Instant::now();
        let mut any_pending = false;
        for c in &mut conns {
            c.poll_pending(&cfg.limits);
            c.try_advance(&registry, &gate, &cfg);
            c.flush();
            c.sweep(now, &cfg);
            any_pending |= c.pending.is_some();
        }
        conns.retain(|c| !c.closed);
        // poll timeout: tight while inferences are in flight (their
        // receivers are polled, not blocked on); otherwise sleep until the
        // nearest deadline, capped so stop flags are observed promptly
        let timeout = if any_pending {
            Duration::from_millis(1)
        } else {
            let nearest = conns
                .iter()
                .filter_map(|c| c.next_deadline(&cfg))
                .min()
                .map(|d| d.saturating_duration_since(now))
                .unwrap_or(Duration::from_millis(500));
            nearest.clamp(Duration::from_millis(1), Duration::from_millis(500))
        };
        let mut specs = Vec::with_capacity(conns.len() + 1);
        specs.push(PollSpec { fd: wake.fd(), read: true, write: false });
        for c in &conns {
            specs.push(PollSpec {
                fd: poll::fd_of(&c.stream),
                read: c.wants_read(),
                write: c.wants_write(),
            });
        }
        let events = match poll::wait(&specs, timeout) {
            Ok(ev) => ev,
            Err(_) => continue,
        };
        if events[0].readable {
            wake.drain();
        }
        for (c, ev) in conns.iter_mut().zip(events.iter().skip(1)) {
            if ev.error {
                c.closed = true;
                continue;
            }
            if ev.readable || ev.hangup {
                c.on_readable();
                c.try_advance(&registry, &gate, &cfg);
            }
            if ev.writable {
                c.flush();
            }
        }
    }
}

/// Parsed route table for the `/v1` + `/admin` + legacy surface. Pure so
/// the unit tests cover it without sockets.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Route {
    /// `GET /healthz` (legacy alias, serves the whole process).
    Healthz,
    /// `GET /metrics` — alias for the default model's metrics.
    LegacyMetrics,
    /// `POST /infer` — alias for the default model's infer.
    LegacyInfer,
    /// `GET /v1/models`.
    ListModels,
    /// `GET /v1/metrics` — process-wide metrics; `?format=prometheus`
    /// renders the text exposition instead of JSON.
    Metrics,
    /// `POST /v1/models/<name>/infer`.
    Infer(String),
    /// `GET /v1/models/<name>/metrics`.
    ModelMetrics(String),
    /// `GET /v1/models/<name>/trace` — recent trace spans (`?n=K`,
    /// `?slow=1` for the slow-retention ring).
    ModelTrace(String),
    /// `POST /admin/models/<name>` — load or live-swap a model.
    AdminLoad(String),
    /// `DELETE /admin/models/<name>` — drain and unload.
    AdminUnload(String),
    /// Known path, wrong method; carries the allowed method.
    MethodNotAllowed(&'static str),
    NotFound,
}

/// Model names accepted in URL paths (one segment, conservative charset).
fn valid_model_name(name: &str) -> bool {
    !name.is_empty()
        && name.len() <= 64
        && name
            .bytes()
            .all(|b| b.is_ascii_alphanumeric() || b == b'-' || b == b'_' || b == b'.')
}

/// Split a request target into its path and query halves
/// (`/a/b?x=1&y=2` → `("/a/b", Some("x=1&y=2"))`).
pub fn split_query(target: &str) -> (&str, Option<&str>) {
    match target.split_once('?') {
        Some((path, query)) => (path, Some(query)),
        None => (target, None),
    }
}

/// First value of `key` in a query string; bare keys (`?slow`) yield `""`.
pub fn query_param<'a>(query: Option<&'a str>, key: &str) -> Option<&'a str> {
    query?.split('&').find_map(|pair| {
        let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
        (k == key).then_some(v)
    })
}

/// Map `(method, path)` to a [`Route`]. `path` is query-free — callers
/// split with [`split_query`] first.
pub fn route(method: &str, path: &str) -> Route {
    match (method, path) {
        ("GET", "/healthz") => return Route::Healthz,
        ("GET", "/metrics") => return Route::LegacyMetrics,
        ("POST", "/infer") => return Route::LegacyInfer,
        ("GET", "/v1/models") => return Route::ListModels,
        ("GET", "/v1/metrics") => return Route::Metrics,
        (_, "/healthz") | (_, "/metrics") | (_, "/v1/models") | (_, "/v1/metrics") => {
            return Route::MethodNotAllowed("GET")
        }
        (_, "/infer") => return Route::MethodNotAllowed("POST"),
        _ => {}
    }
    if let Some(rest) = path.strip_prefix("/v1/models/") {
        if let Some(name) = rest.strip_suffix("/infer") {
            if valid_model_name(name) {
                return match method {
                    "POST" => Route::Infer(name.to_string()),
                    _ => Route::MethodNotAllowed("POST"),
                };
            }
        } else if let Some(name) = rest.strip_suffix("/metrics") {
            if valid_model_name(name) {
                return match method {
                    "GET" => Route::ModelMetrics(name.to_string()),
                    _ => Route::MethodNotAllowed("GET"),
                };
            }
        } else if let Some(name) = rest.strip_suffix("/trace") {
            if valid_model_name(name) {
                return match method {
                    "GET" => Route::ModelTrace(name.to_string()),
                    _ => Route::MethodNotAllowed("GET"),
                };
            }
        }
        return Route::NotFound;
    }
    if let Some(name) = path.strip_prefix("/admin/models/") {
        if valid_model_name(name) {
            return match method {
                "POST" => Route::AdminLoad(name.to_string()),
                "DELETE" => Route::AdminUnload(name.to_string()),
                _ => Route::MethodNotAllowed("POST or DELETE"),
            };
        }
        return Route::NotFound;
    }
    Route::NotFound
}

/// Handle one parsed request: immediate responses for everything except an
/// admitted inference, which returns `Step::Execute` for the event loop to
/// drive.
fn dispatch(
    req: &HttpRequest,
    keep: bool,
    registry: &Arc<ModelRegistry>,
    gate: &Gate,
) -> Step {
    let (path, query) = split_query(&req.path);
    match route(&req.method, path) {
        Route::Healthz => {
            if gate.draining.load(Ordering::SeqCst) {
                Step::Respond(503, r#"{"status":"draining"}"#.to_string())
            } else {
                Step::Respond(200, r#"{"status":"ok"}"#.to_string())
            }
        }
        Route::ListModels => Step::Respond(
            200,
            proto::models_to_json(&registry.list(), registry.default_model()).to_string(),
        ),
        Route::LegacyMetrics => {
            let name = registry.default_model().to_string();
            metrics_route(&name, true, registry)
        }
        Route::ModelMetrics(name) => metrics_route(&name, false, registry),
        Route::Metrics => global_metrics_route(query, registry),
        Route::ModelTrace(name) => trace_route(&name, query, registry),
        Route::LegacyInfer => {
            let name = registry.default_model().to_string();
            infer_route(&name, req, keep, registry, gate)
        }
        Route::Infer(name) => infer_route(&name, req, keep, registry, gate),
        Route::AdminLoad(name) => admin_load_route(&name, req, registry),
        Route::AdminUnload(name) => match registry.begin_remove(&name) {
            Ok(()) => Step::Respond(
                202,
                obj(vec![("status", s("draining")), ("model", s(&name))]).to_string(),
            ),
            Err(e) => Step::Respond(admin_status(&e), admin_body(&e, &name)),
        },
        Route::MethodNotAllowed(allowed) => Step::Respond(
            405,
            proto::error_body(
                "method_not_allowed",
                &format!("method not allowed (use {allowed})"),
                None,
            ),
        ),
        Route::NotFound => Step::Respond(
            404,
            proto::error_body(
                "not_found",
                "no such endpoint (try /v1/models, /v1/models/<name>/infer, /healthz)",
                None,
            ),
        ),
    }
}

/// Resolve a model for serving, mapping registry states to wire errors.
fn resolve_model(
    name: &str,
    registry: &Arc<ModelRegistry>,
) -> std::result::Result<Arc<crate::coordinator::ModelPool>, (u16, String)> {
    match registry.fetch(name) {
        ModelFetch::Ready(pool) => Ok(pool),
        ModelFetch::Loading => Err((
            503,
            proto::error_body("loading", "model is still loading", Some(name)),
        )),
        ModelFetch::Draining => Err((
            503,
            proto::error_body("draining", "model is draining", Some(name)),
        )),
        ModelFetch::Failed(e) => Err((
            503,
            proto::error_body("unavailable", &format!("model failed to load: {e}"), Some(name)),
        )),
        ModelFetch::NotFound => Err((
            404,
            proto::error_body("not_found", "no such model", Some(name)),
        )),
    }
}

fn metrics_route(name: &str, legacy: bool, registry: &Arc<ModelRegistry>) -> Step {
    let pool = match resolve_model(name, registry) {
        Ok(p) => p,
        Err((status, body)) => return Step::Respond(status, body),
    };
    match pool.pool_metrics() {
        Ok(pm) => {
            // the legacy alias keeps its original body shape; /v1 adds the
            // model identity, generation, and admission block
            let body = if legacy {
                proto::pool_metrics_to_json(&pm, pool.dtype, pool.plane).to_string()
            } else {
                proto::model_metrics_to_json(name, &pool.admission(), &pm, pool.dtype, pool.plane)
                    .to_string()
            };
            Step::Respond(200, body)
        }
        Err(e) => Step::Respond(
            503,
            proto::error_body("unavailable", &e.to_string(), Some(name)),
        ),
    }
}

/// `GET /v1/metrics` — every serving model's metrics in one reply; with
/// `?format=prometheus`, the text exposition a scraper ingests directly.
fn global_metrics_route(query: Option<&str>, registry: &Arc<ModelRegistry>) -> Step {
    match query_param(query, "format") {
        Some("prometheus") => {
            Step::RespondText(200, PROM_CTYPE, prometheus_exposition(registry))
        }
        Some(other) => Step::Respond(
            400,
            proto::error_body(
                "bad_request",
                &format!("unknown metrics format {other:?} (use \"prometheus\" or omit)"),
                None,
            ),
        ),
        None => {
            let rows: Vec<_> = registry
                .list()
                .iter()
                .filter(|m| m.status == "serving")
                .filter_map(|m| {
                    let pool = registry.pool(&m.name)?;
                    let pm = pool.pool_metrics().ok()?;
                    Some(proto::model_metrics_to_json(
                        &m.name,
                        &pool.admission(),
                        &pm,
                        pool.dtype,
                        pool.plane,
                    ))
                })
                .collect();
            Step::Respond(200, obj(vec![("models", arr(rows))]).to_string())
        }
    }
}

/// `GET /v1/models/<name>/trace` — newest-first request traces from the
/// pool's ring (`?n=K` bounds the count, `?slow` reads the slow-retention
/// ring instead).
fn trace_route(name: &str, query: Option<&str>, registry: &Arc<ModelRegistry>) -> Step {
    let pool = match resolve_model(name, registry) {
        Ok(p) => p,
        Err((status, body)) => return Step::Respond(status, body),
    };
    let n = query_param(query, "n")
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(16);
    let ring = pool.trace();
    let traces = if query_param(query, "slow").is_some() {
        ring.slow_traces(n)
    } else {
        ring.recent(n)
    };
    Step::Respond(
        200,
        proto::traces_to_json(&traces, ring.dropped(), ring.slow_threshold_us()).to_string(),
    )
}

/// Render every serving model's counters in the Prometheus text format:
/// latency/throughput/admission gauges plus the measured-vs-Eq. 13 traffic
/// families the paper's claim is judged by.
fn prometheus_exposition(registry: &Arc<ModelRegistry>) -> String {
    struct Snap {
        name: String,
        admission: crate::coordinator::AdmissionMetrics,
        merged: crate::coordinator::Metrics,
        trace_dropped: u64,
    }
    // Snapshot first, render second: rendering never holds a pool handle
    // longer than one metrics drain.
    let mut snaps: Vec<Snap> = Vec::new();
    for row in registry.list() {
        if row.status != "serving" {
            continue;
        }
        let Some(pool) = registry.pool(&row.name) else { continue };
        let Ok(pm) = pool.pool_metrics() else { continue };
        snaps.push(Snap {
            name: row.name,
            admission: pool.admission(),
            merged: pm.merged,
            trace_dropped: pool.trace().dropped(),
        });
    }
    let mut w = PromWriter::new();
    w.family("sf_requests_total", "counter", "Completed inference requests.");
    for sn in &snaps {
        w.sample("sf_requests_total", &[("model", sn.name.as_str())], sn.merged.count() as f64);
    }
    w.family("sf_request_latency_us", "gauge", "Request latency percentiles (microseconds).");
    for sn in &snaps {
        for (q, v) in
            [("0.5", sn.merged.p50()), ("0.95", sn.merged.p95()), ("0.99", sn.merged.p99())]
        {
            if let Some(d) = v {
                w.sample(
                    "sf_request_latency_us",
                    &[("model", sn.name.as_str()), ("quantile", q)],
                    d.as_micros() as f64,
                );
            }
        }
    }
    w.family("sf_inflight", "gauge", "Admitted requests currently in the pool.");
    w.family("sf_admitted_total", "counter", "Requests admitted past the quota gate.");
    w.family("sf_rejected_total", "counter", "Requests refused by the quota gate (429).");
    w.family("sf_generation", "gauge", "Weight-swap generation of the serving pool.");
    for sn in &snaps {
        let m = &[("model", sn.name.as_str())];
        w.sample("sf_inflight", m, sn.admission.inflight as f64);
        w.sample("sf_admitted_total", m, sn.admission.admitted as f64);
        w.sample("sf_rejected_total", m, sn.admission.rejected as f64);
        w.sample("sf_generation", m, sn.admission.generation as f64);
    }
    w.family("sf_batches_total", "counter", "Closed batches by size.");
    for sn in &snaps {
        for (size, &count) in sn.merged.batch_histogram().iter().enumerate() {
            if count > 0 {
                let size = size.to_string();
                w.sample(
                    "sf_batches_total",
                    &[("model", sn.name.as_str()), ("size", size.as_str())],
                    count as f64,
                );
            }
        }
    }
    w.family("sf_pe_utilization", "gauge", "Average Alg. 2 network PE utilization.");
    w.family("sf_arena_peak_activation_bytes", "gauge", "Peak live activation-arena bytes.");
    for sn in &snaps {
        let m = &[("model", sn.name.as_str())];
        if let Some(sched) = &sn.merged.schedule {
            w.sample("sf_pe_utilization", m, sched.avg_pe_utilization());
        }
        if let Some(a) = &sn.merged.arena {
            w.sample("sf_arena_peak_activation_bytes", m, a.peak_activation_bytes as f64);
        }
    }
    w.family(
        "sf_traffic_bytes_total",
        "counter",
        "Measured backend-boundary bytes by conv layer and kind.",
    );
    w.family(
        "sf_traffic_predicted_bytes_total",
        "counter",
        "Eq. 13 predicted bytes for the executed plan, by conv layer and kind.",
    );
    w.family(
        "sf_traffic_weight_ratio",
        "gauge",
        "Measured over Eq. 13-predicted weight-stream bytes per conv layer.",
    );
    for sn in &snaps {
        let Some(t) = &sn.merged.traffic else { continue };
        for l in &t.layers {
            let base = [("model", sn.name.as_str()), ("layer", l.layer.as_str())];
            for (kind, v) in [
                ("weight", l.measured.weight_bytes),
                ("input", l.measured.input_bytes),
                ("output", l.measured.output_bytes),
                ("psum", l.measured.psum_bytes),
            ] {
                let labels = [base[0], base[1], ("kind", kind)];
                w.sample("sf_traffic_bytes_total", &labels, v as f64);
            }
            for (kind, v) in [
                ("weight", l.predicted_weight_bytes),
                ("input", l.predicted_input_bytes),
                ("output", l.predicted_output_bytes),
            ] {
                let labels = [base[0], base[1], ("kind", kind)];
                w.sample("sf_traffic_predicted_bytes_total", &labels, v as f64);
            }
            if l.predicted_weight_bytes > 0 {
                w.sample("sf_traffic_weight_ratio", &base, l.weight_ratio());
            }
        }
    }
    w.family("sf_trace_dropped_total", "counter", "Traces dropped on slot contention.");
    for sn in &snaps {
        w.sample(
            "sf_trace_dropped_total",
            &[("model", sn.name.as_str())],
            sn.trace_dropped as f64,
        );
    }
    w.finish()
}

fn infer_route(
    name: &str,
    req: &HttpRequest,
    keep: bool,
    registry: &Arc<ModelRegistry>,
    gate: &Gate,
) -> Step {
    // wire-side trace stamps: `accepted` is when the complete request
    // reached this handler, `parsed` closes the body-decode span
    let accepted = Instant::now();
    if gate.draining.load(Ordering::SeqCst) {
        return Step::Respond(
            503,
            proto::error_body("draining", "server is draining", Some(name)),
        );
    }
    let pool = match resolve_model(name, registry) {
        Ok(p) => p,
        Err((status, body)) => return Step::Respond(status, body),
    };
    // parse before admission: a batch body claims one in-flight slot per
    // image, so a batched client draws from the same budget as the
    // equivalent serial clients would
    let parsed = match proto::parse_infer_body(&req.body, pool.input_shape) {
        Ok(p) => p,
        Err(e) => {
            return Step::Respond(
                400,
                proto::error_body("bad_request", &e.to_string(), Some(name)),
            )
        }
    };
    let (images, single) = match parsed {
        proto::InferRequest::Single(t) => (vec![t], true),
        proto::InferRequest::Batch(v) => (v, false),
    };
    let wire = WireTiming { accepted, parsed: Instant::now() };
    // admission: per-model bounded in-flight budget — overload is a fast
    // 429, not a silently growing dispatcher queue
    let Some(guard) = pool.try_admit(images.len()) else {
        return Step::Respond(
            429,
            proto::error_body(
                "overloaded",
                "overloaded: in-flight request limit reached",
                Some(name),
            ),
        );
    };
    // submit every image before waiting on any reply: they land in the
    // dispatcher's window together, so the batcher can close them into
    // fused batch forwards instead of singletons
    let client = pool.client();
    let mut rxs = Vec::with_capacity(images.len());
    for image in images {
        match client.infer_async_timed(image, wire) {
            Ok(rx) => rxs.push(rx),
            Err(e) => {
                let (status, body) = infer_error(&e.to_string(), Some(name));
                return Step::Respond(status, body);
            }
        }
    }
    Step::Execute(Box::new(Pending {
        rxs,
        resps: Vec::new(),
        single,
        keep,
        model: name.to_string(),
        _guard: guard,
    }))
}

fn admin_load_route(name: &str, req: &HttpRequest, registry: &Arc<ModelRegistry>) -> Step {
    let spec = match proto::parse_model_spec(&req.body, name) {
        Ok(sp) => sp,
        Err(e) => {
            return Step::Respond(
                400,
                proto::error_body("bad_request", &e.to_string(), Some(name)),
            )
        }
    };
    match registry.begin_load(name, spec) {
        Ok(()) => Step::Respond(
            202,
            obj(vec![
                ("status", s("loading")),
                ("model", s(name)),
                ("generation", num((registry.generation_of(name) + 1) as f64)),
            ])
            .to_string(),
        ),
        Err(e) => Step::Respond(admin_status(&e), admin_body(&e, name)),
    }
}

fn admin_status(e: &AdminError) -> u16 {
    match e {
        AdminError::NotFound => 404,
        AdminError::Conflict(_) => 409,
        AdminError::BadRequest(_) => 400,
    }
}

fn admin_body(e: &AdminError, model: &str) -> String {
    let code = match e {
        AdminError::NotFound => "not_found",
        AdminError::Conflict(_) => "conflict",
        AdminError::BadRequest(_) => "bad_request",
    };
    proto::error_body(code, &e.to_string(), Some(model))
}

/// Map an inference failure to a status: engine rejections (wrong shape
/// for the variant, …) are the client's fault; a stopped/dropped pool is
/// ours.
fn infer_error(msg: &str, model: Option<&str>) -> (u16, String) {
    if msg.contains("server stopped") || msg.contains("server dropped") {
        (503, proto::error_body("unavailable", msg, model))
    } else {
        (400, proto::error_body("bad_request", msg, model))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn route_table_maps_v1_paths() {
        assert_eq!(route("GET", "/healthz"), Route::Healthz);
        assert_eq!(route("GET", "/metrics"), Route::LegacyMetrics);
        assert_eq!(route("POST", "/infer"), Route::LegacyInfer);
        assert_eq!(route("GET", "/v1/models"), Route::ListModels);
        assert_eq!(
            route("POST", "/v1/models/resnet18/infer"),
            Route::Infer("resnet18".into())
        );
        assert_eq!(
            route("GET", "/v1/models/vgg16-cifar/metrics"),
            Route::ModelMetrics("vgg16-cifar".into())
        );
        assert_eq!(
            route("POST", "/admin/models/demo"),
            Route::AdminLoad("demo".into())
        );
        assert_eq!(
            route("DELETE", "/admin/models/demo"),
            Route::AdminUnload("demo".into())
        );
        assert_eq!(route("GET", "/v1/metrics"), Route::Metrics);
        assert_eq!(
            route("GET", "/v1/models/demo/trace"),
            Route::ModelTrace("demo".into())
        );
    }

    #[test]
    fn query_split_and_params() {
        assert_eq!(split_query("/v1/metrics"), ("/v1/metrics", None));
        assert_eq!(
            split_query("/v1/metrics?format=prometheus"),
            ("/v1/metrics", Some("format=prometheus"))
        );
        let (path, query) = split_query("/v1/models/demo/trace?n=4&slow");
        assert_eq!(path, "/v1/models/demo/trace");
        assert_eq!(query_param(query, "n"), Some("4"));
        assert_eq!(query_param(query, "slow"), Some(""));
        assert_eq!(query_param(query, "format"), None);
        assert_eq!(query_param(None, "n"), None);
        // routing is query-blind once split
        assert_eq!(route("GET", path), Route::ModelTrace("demo".into()));
    }

    #[test]
    fn route_table_enforces_methods() {
        assert_eq!(route("POST", "/healthz"), Route::MethodNotAllowed("GET"));
        assert_eq!(route("GET", "/infer"), Route::MethodNotAllowed("POST"));
        assert_eq!(route("DELETE", "/metrics"), Route::MethodNotAllowed("GET"));
        assert_eq!(route("POST", "/v1/models"), Route::MethodNotAllowed("GET"));
        assert_eq!(
            route("GET", "/v1/models/demo/infer"),
            Route::MethodNotAllowed("POST")
        );
        assert_eq!(
            route("POST", "/v1/models/demo/metrics"),
            Route::MethodNotAllowed("GET")
        );
        assert_eq!(route("POST", "/v1/metrics"), Route::MethodNotAllowed("GET"));
        assert_eq!(
            route("DELETE", "/v1/models/demo/trace"),
            Route::MethodNotAllowed("GET")
        );
        assert_eq!(
            route("GET", "/admin/models/demo"),
            Route::MethodNotAllowed("POST or DELETE")
        );
    }

    #[test]
    fn route_table_rejects_unknown_and_invalid() {
        assert_eq!(route("GET", "/"), Route::NotFound);
        assert_eq!(route("GET", "/v2/models"), Route::NotFound);
        assert_eq!(route("POST", "/v1/models//infer"), Route::NotFound);
        assert_eq!(route("POST", "/v1/models/a/b/infer"), Route::NotFound);
        assert_eq!(route("POST", "/admin/models/"), Route::NotFound);
        assert_eq!(route("POST", "/admin/models/bad name"), Route::NotFound);
        assert_eq!(route("POST", "/v1/models/demo"), Route::NotFound);
        // model names are one conservative path segment
        assert!(valid_model_name("vgg16-cifar"));
        assert!(valid_model_name("resnet18.v2"));
        assert!(!valid_model_name(""));
        assert!(!valid_model_name("a/b"));
        assert!(!valid_model_name(&"x".repeat(65)));
    }
}
