//! HTTP front-end over the engine pool: socket → admission → batcher →
//! pool → response.
//!
//! [`HttpFrontend::start`] takes a running [`crate::coordinator::Server`]
//! and binds a `std::net` listener in front of it. One acceptor thread hands
//! each connection to its own handler thread (bounded by
//! [`NetConfig::max_conns`] — beyond the cap a connection gets an
//! immediate 503 and is closed, never queued invisibly). Handler threads
//! hold only a cloned [`Client`], so the engine-pool thread-confinement
//! rule is untouched: tensors cross the channel, engines never do.
//!
//! Admission control is a bounded in-flight counter in front of the
//! dispatcher: at most [`NetConfig::max_inflight`] `/infer` requests may
//! be queued-or-executing in the pool at once. The bound makes overload a
//! *fast* failure — a 429 the moment the budget is exceeded — instead of
//! an unbounded queue whose tail latency quietly explodes, which is the
//! contract the closed-loop load generator tests: concurrency above the
//! bound yields 429s, never a hang.
//!
//! Shutdown is graceful and ordered: [`HttpFrontend::shutdown`] (1) flips
//! the drain flag so `/healthz` answers 503 and new `/infer`s are refused,
//! (2) wakes and stops the acceptor, (3) waits (bounded by
//! [`NetConfig::drain_grace`]) for admitted requests to finish, then
//! (4) shuts the coordinator pool down, which flushes any open batch
//! before the workers exit.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::http::{self, HttpConn, HttpLimits, HttpRequest};
use super::proto;
use crate::coordinator::{Client, Server};
use crate::runtime::{Dtype, Plane};
use crate::util::error::{Context, Result};

/// Front-end configuration (the serving knobs the wire adds on top of
/// [`crate::coordinator::ServerConfig`]).
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Bind address, e.g. `127.0.0.1:7878` (`:0` picks a free port —
    /// [`HttpFrontend::local_addr`] reports the real one).
    pub addr: String,
    /// Concurrent connections; excess connections get 503 + close.
    pub max_conns: usize,
    /// Bounded in-flight `/infer` budget; excess requests get 429.
    pub max_inflight: usize,
    /// The served variant's input `[C, H, W]` (for `{"seed":n}` bodies).
    pub input_shape: [usize; 3],
    /// HTTP parse caps + per-request read deadline.
    pub limits: HttpLimits,
    /// How long shutdown waits for admitted requests to drain.
    pub drain_grace: Duration,
    /// Resolved accumulation dtype the pool serves at (tags `/metrics`).
    pub dtype: Dtype,
    /// Spectral storage plane the pool serves on (tags `/metrics`).
    pub plane: Plane,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            addr: "127.0.0.1:0".into(),
            max_conns: 256,
            max_inflight: 64,
            input_shape: [1, 16, 16],
            limits: HttpLimits::default(),
            drain_grace: Duration::from_secs(10),
            dtype: Dtype::F32,
            plane: Plane::Full,
        }
    }
}

/// Shared request-path state (acceptor + every connection thread).
struct Gate {
    /// Drain mode: `/healthz` answers 503 and new `/infer`s are refused,
    /// but connections are still accepted and answered (load-balancer
    /// probes must see the 503, not a dead port).
    draining: AtomicBool,
    /// Shutdown: the acceptor exits. Implies `draining`.
    stopping: AtomicBool,
    inflight: AtomicUsize,
    conns: AtomicUsize,
}

/// A running HTTP front-end. Owns the coordinator [`Server`] so the
/// shutdown order (stop accepting → drain → flush batches) has one owner.
pub struct HttpFrontend {
    addr: SocketAddr,
    gate: Arc<Gate>,
    acceptor: Option<std::thread::JoinHandle<()>>,
    server: Option<Server>,
    drain_grace: Duration,
}

impl HttpFrontend {
    /// Bind and start serving. Fails fast on an unbindable address.
    pub fn start(server: Server, cfg: NetConfig) -> Result<HttpFrontend> {
        let listener =
            TcpListener::bind(&cfg.addr).with_context(|| format!("binding {}", cfg.addr))?;
        let addr = listener.local_addr().context("resolving bound address")?;
        let gate = Arc::new(Gate {
            draining: AtomicBool::new(false),
            stopping: AtomicBool::new(false),
            inflight: AtomicUsize::new(0),
            conns: AtomicUsize::new(0),
        });
        let client = server.client();
        let agate = gate.clone();
        let acfg = cfg.clone();
        let acceptor = std::thread::Builder::new()
            .name("sf-http-accept".into())
            .spawn(move || accept_loop(listener, client, agate, acfg))
            .expect("spawn http acceptor");
        Ok(HttpFrontend {
            addr,
            gate,
            acceptor: Some(acceptor),
            server: Some(server),
            drain_grace: cfg.drain_grace,
        })
    }

    /// The actual bound address (resolves `:0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Enter drain mode without tearing anything down: `/healthz` flips to
    /// 503 and new `/infer`s are refused while in-flight work completes.
    /// (Load balancers watch exactly this to take a replica out of
    /// rotation before it stops.)
    pub fn begin_drain(&self) {
        self.gate.draining.store(true, Ordering::SeqCst);
    }

    /// `/infer` requests currently admitted (queued or executing).
    pub fn inflight(&self) -> usize {
        self.gate.inflight.load(Ordering::SeqCst)
    }

    /// Graceful shutdown: drain, stop accepting, flush the pool's batches.
    pub fn shutdown(mut self) -> Result<()> {
        self.finish()
    }

    fn finish(&mut self) -> Result<()> {
        self.begin_drain();
        self.gate.stopping.store(true, Ordering::SeqCst);
        // the acceptor parks in accept(): a self-connection wakes it so it
        // can observe the stop flag and exit
        let _ = TcpStream::connect(self.addr);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        let deadline = std::time::Instant::now() + self.drain_grace;
        while self.gate.inflight.load(Ordering::SeqCst) > 0
            && std::time::Instant::now() < deadline
        {
            std::thread::sleep(Duration::from_millis(5));
        }
        match self.server.take() {
            // Server::shutdown flushes the open batch and drains every
            // worker before joining — admitted requests get their replies
            Some(s) => s.shutdown(),
            None => Ok(()),
        }
    }
}

impl Drop for HttpFrontend {
    fn drop(&mut self) {
        let _ = self.finish();
    }
}

fn accept_loop(listener: TcpListener, client: Client, gate: Arc<Gate>, cfg: NetConfig) {
    for stream in listener.incoming() {
        if gate.stopping.load(Ordering::SeqCst) {
            break;
        }
        let mut stream = match stream {
            Ok(s) => s,
            Err(_) => continue,
        };
        // connection bound: refuse loudly instead of queueing invisibly
        if gate.conns.fetch_add(1, Ordering::SeqCst) >= cfg.max_conns {
            gate.conns.fetch_sub(1, Ordering::SeqCst);
            let body = proto::error_body("connection capacity reached");
            let _ = http::write_response(&mut stream, 503, "application/json", body.as_bytes(), false);
            continue;
        }
        let conn_client = client.clone();
        let conn_gate = gate.clone();
        let conn_cfg = cfg.clone();
        let spawned = std::thread::Builder::new().name("sf-http-conn".into()).spawn(move || {
            // drop guard: the slot is released even if the handler panics,
            // so a crashing connection can never leak capacity
            let _slot = ConnSlot(conn_gate);
            handle_conn(stream, &conn_client, &_slot.0, &conn_cfg);
        });
        if spawned.is_err() {
            gate.conns.fetch_sub(1, Ordering::SeqCst);
        }
    }
}

/// Releases one `Gate::conns` slot on drop (including panic unwinds).
struct ConnSlot(Arc<Gate>);

impl Drop for ConnSlot {
    fn drop(&mut self) {
        self.0.conns.fetch_sub(1, Ordering::SeqCst);
    }
}

/// One connection: keep-alive request loop until close/error/drain.
fn handle_conn(stream: TcpStream, client: &Client, gate: &Gate, cfg: &NetConfig) {
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut conn = HttpConn::new(stream);
    for served in 0..cfg.limits.max_requests_per_conn {
        match conn.read_request(&cfg.limits) {
            Ok(None) => break, // clean close / idle keep-alive expiry
            Ok(Some(req)) => {
                // the final permitted request must advertise the close —
                // otherwise a keep-alive client writes request N+1 into a
                // socket we are about to shut and sees a spurious error
                let last = served + 1 == cfg.limits.max_requests_per_conn;
                let keep = req.keep_alive() && !last && !gate.draining.load(Ordering::SeqCst);
                let (status, body) = route(&req, client, gate, cfg);
                if http::write_response(&mut writer, status, "application/json", body.as_bytes(), keep)
                    .is_err()
                {
                    break;
                }
                if !keep {
                    break;
                }
            }
            Err(e) => {
                // parse/deadline errors answer once (when a status exists
                // and the peer is still there), then the connection closes —
                // a malformed or slow peer never wedges this thread
                if e.status != 0 {
                    let body = proto::error_body(&e.message);
                    let _ = http::write_response(
                        &mut writer,
                        e.status,
                        "application/json",
                        body.as_bytes(),
                        false,
                    );
                }
                break;
            }
        }
    }
}

fn route(req: &HttpRequest, client: &Client, gate: &Gate, cfg: &NetConfig) -> (u16, String) {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            if gate.draining.load(Ordering::SeqCst) {
                (503, r#"{"status":"draining"}"#.to_string())
            } else {
                (200, r#"{"status":"ok"}"#.to_string())
            }
        }
        ("GET", "/metrics") => match client.pool_metrics() {
            Ok(pm) => {
                (200, proto::pool_metrics_to_json(&pm, cfg.dtype, cfg.plane).to_string())
            }
            Err(e) => (503, proto::error_body(&e.to_string())),
        },
        ("POST", "/infer") => infer_route(req, client, gate, cfg),
        (_, "/healthz") | (_, "/metrics") => {
            (405, proto::error_body("method not allowed (use GET)"))
        }
        (_, "/infer") => (405, proto::error_body("method not allowed (use POST)")),
        _ => (404, proto::error_body("no such endpoint (try /infer, /metrics, /healthz)")),
    }
}

fn infer_route(req: &HttpRequest, client: &Client, gate: &Gate, cfg: &NetConfig) -> (u16, String) {
    if gate.draining.load(Ordering::SeqCst) {
        return (503, proto::error_body("server is draining"));
    }
    // parse before admission: a batch body claims one in-flight slot per
    // image, so a batched client draws from the same budget as the
    // equivalent serial clients would
    let parsed = match proto::parse_infer_body(&req.body, cfg.input_shape) {
        Ok(p) => p,
        Err(e) => return (400, proto::error_body(&e.to_string())),
    };
    let slots = match &parsed {
        proto::InferRequest::Single(_) => 1,
        proto::InferRequest::Batch(images) => images.len(),
    };
    // admission: bounded in-flight queue — overload is a fast 429, not a
    // silently growing dispatcher queue
    if gate.inflight.fetch_add(slots, Ordering::SeqCst) + slots > cfg.max_inflight {
        gate.inflight.fetch_sub(slots, Ordering::SeqCst);
        return (429, proto::error_body("overloaded: in-flight request limit reached"));
    }
    let out = admitted_infer(parsed, client);
    gate.inflight.fetch_sub(slots, Ordering::SeqCst);
    out
}

fn admitted_infer(parsed: proto::InferRequest, client: &Client) -> (u16, String) {
    match parsed {
        proto::InferRequest::Single(image) => match client.infer(image) {
            Ok(resp) => (200, proto::response_to_json(&resp).to_string()),
            Err(e) => infer_error(&e.to_string()),
        },
        proto::InferRequest::Batch(images) => {
            // submit every image before waiting on any reply: they land in
            // the dispatcher's window together, so the batcher can close
            // them into fused batch forwards instead of singletons
            let mut rxs = Vec::with_capacity(images.len());
            for image in images {
                match client.infer_async(image) {
                    Ok(rx) => rxs.push(rx),
                    Err(e) => return infer_error(&e.to_string()),
                }
            }
            let mut resps = Vec::with_capacity(rxs.len());
            for rx in rxs {
                // any failed image fails the whole batched request — the
                // wire reply is all results or one error, never a mix
                match rx.recv() {
                    Ok(Ok(resp)) => resps.push(resp),
                    Ok(Err(e)) => return infer_error(&e.to_string()),
                    Err(_) => return infer_error("server dropped request"),
                }
            }
            (200, proto::batch_response_to_json(&resps).to_string())
        }
    }
}

/// Map an inference failure to a status: engine rejections (wrong shape
/// for the variant, …) are the client's fault; a stopped/dropped pool is
/// ours.
fn infer_error(msg: &str) -> (u16, String) {
    if msg.contains("server stopped") || msg.contains("server dropped") {
        (503, proto::error_body(msg))
    } else {
        (400, proto::error_body(msg))
    }
}
