//! Thin readiness-polling wrapper over `poll(2)` — std + raw FFI only.
//!
//! The event-driven front-end ([`crate::net::server`]) multiplexes many
//! nonblocking sockets onto a fixed pool of connection workers. Each worker
//! blocks in [`wait`] until one of its sockets is readable/writable (or a
//! deadline expires), instead of parking one OS thread per connection.
//!
//! Two deliberate restrictions keep this dependency-free:
//!
//! * On unix the syscall is declared directly (`extern "C" { fn poll(..) }`)
//!   — no libc crate. `poll(2)` is POSIX and level-triggered, which is all a
//!   keep-alive HTTP front-end needs; the fd sets are rebuilt each iteration
//!   from the worker's connection table, so there is no registration state
//!   to keep in sync (the classic epoll bug class).
//! * Cross-thread wakeups use a [`WakePipe`] built from
//!   `UnixStream::pair()` — the only portable std-only self-pipe. Writing a
//!   byte makes the read end pollable, interrupting a long `wait` when new
//!   connections or shutdown arrive.
//!
//! On non-unix targets the module degrades to a short-sleep stub that
//! reports every fd ready (correct but busy); CI only exercises unix.

use std::io;
use std::time::Duration;

/// Raw file descriptor type used by the poller.
#[cfg(unix)]
pub type Fd = std::os::unix::io::RawFd;
/// Raw file descriptor type used by the poller (stub on non-unix).
#[cfg(not(unix))]
pub type Fd = i32;

/// One fd's interest set for a [`wait`] call.
#[derive(Debug, Clone, Copy)]
pub struct PollSpec {
    /// The descriptor to watch.
    pub fd: Fd,
    /// Watch for readability (`POLLIN`).
    pub read: bool,
    /// Watch for writability (`POLLOUT`).
    pub write: bool,
}

/// One fd's readiness, aligned index-for-index with the input specs.
#[derive(Debug, Clone, Copy, Default)]
pub struct PollEvents {
    /// Data (or EOF) can be read without blocking.
    pub readable: bool,
    /// The socket's send buffer has room.
    pub writable: bool,
    /// Peer hung up (`POLLHUP`).
    pub hangup: bool,
    /// Error condition (`POLLERR` / `POLLNVAL`).
    pub error: bool,
}

impl PollEvents {
    /// True if any condition fired for this fd.
    pub fn any(&self) -> bool {
        self.readable || self.writable || self.hangup || self.error
    }
}

#[cfg(unix)]
mod sys {
    use std::os::raw::{c_int, c_ulong};

    // Matches struct pollfd from <poll.h>.
    #[repr(C)]
    pub struct PollFd {
        pub fd: c_int,
        pub events: i16,
        pub revents: i16,
    }

    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;
    pub const POLLERR: i16 = 0x008;
    pub const POLLHUP: i16 = 0x010;
    pub const POLLNVAL: i16 = 0x020;

    // nfds_t is `unsigned long` on Linux, which is where CI runs; declared
    // here so the crate needs no libc crate.
    extern "C" {
        pub fn poll(fds: *mut PollFd, nfds: c_ulong, timeout: c_int) -> c_int;
    }
}

/// Block until at least one spec'd fd is ready or `timeout` elapses.
///
/// Returns one [`PollEvents`] per input spec (same order). A timeout yields
/// all-empty events; `EINTR` is treated as a timeout (callers loop anyway).
#[cfg(unix)]
pub fn wait(specs: &[PollSpec], timeout: Duration) -> io::Result<Vec<PollEvents>> {
    let mut fds: Vec<sys::PollFd> = specs
        .iter()
        .map(|s| {
            let mut events = 0i16;
            if s.read {
                events |= sys::POLLIN;
            }
            if s.write {
                events |= sys::POLLOUT;
            }
            sys::PollFd {
                fd: s.fd,
                events,
                revents: 0,
            }
        })
        .collect();
    let timeout_ms: i32 = timeout.as_millis().min(i32::MAX as u128) as i32;
    // SAFETY: `fds` is a live, correctly-sized buffer of #[repr(C)] pollfd
    // entries for the duration of the call; poll(2) only writes `revents`.
    let rc = unsafe { sys::poll(fds.as_mut_ptr(), fds.len() as std::os::raw::c_ulong, timeout_ms) };
    if rc < 0 {
        let err = io::Error::last_os_error();
        if err.kind() == io::ErrorKind::Interrupted {
            return Ok(vec![PollEvents::default(); specs.len()]);
        }
        return Err(err);
    }
    Ok(fds
        .iter()
        .map(|f| PollEvents {
            readable: f.revents & sys::POLLIN != 0,
            writable: f.revents & sys::POLLOUT != 0,
            hangup: f.revents & sys::POLLHUP != 0,
            error: f.revents & (sys::POLLERR | sys::POLLNVAL) != 0,
        })
        .collect())
}

/// Degraded fallback for non-unix targets: sleep briefly and report every
/// fd readable + writable. Nonblocking I/O keeps this correct (reads just
/// return `WouldBlock`), only less efficient.
#[cfg(not(unix))]
pub fn wait(specs: &[PollSpec], timeout: Duration) -> io::Result<Vec<PollEvents>> {
    std::thread::sleep(timeout.min(Duration::from_millis(2)));
    Ok(specs
        .iter()
        .map(|_| PollEvents {
            readable: true,
            writable: true,
            hangup: false,
            error: false,
        })
        .collect())
}

/// Self-pipe for waking a worker blocked in [`wait`] from another thread.
///
/// Built from `UnixStream::pair()` (the std-only pipe): the worker polls the
/// read end alongside its sockets; any thread holding a clone of the write
/// end makes it readable with [`WakePipe::wake`].
#[cfg(unix)]
pub struct WakePipe {
    read: std::os::unix::net::UnixStream,
    write: std::os::unix::net::UnixStream,
}

#[cfg(unix)]
impl WakePipe {
    /// Create a nonblocking pipe pair.
    pub fn new() -> io::Result<WakePipe> {
        let (read, write) = std::os::unix::net::UnixStream::pair()?;
        read.set_nonblocking(true)?;
        write.set_nonblocking(true)?;
        Ok(WakePipe { read, write })
    }

    /// Fd of the read end, for inclusion in a [`wait`] spec set.
    pub fn fd(&self) -> Fd {
        use std::os::unix::io::AsRawFd;
        self.read.as_raw_fd()
    }

    /// Make the read end pollable. A full pipe means a wakeup is already
    /// pending, so `WouldBlock` is success.
    pub fn wake(&self) {
        use std::io::Write;
        let mut w = &self.write;
        let _ = w.write(&[1u8]);
    }

    /// Consume all pending wakeup bytes (level-triggered poll would
    /// otherwise re-fire forever).
    pub fn drain(&self) {
        use std::io::Read;
        let mut r = &self.read;
        let mut buf = [0u8; 64];
        while let Ok(n) = r.read(&mut buf) {
            if n == 0 {
                break;
            }
        }
    }

    /// Clone a handle that can only wake (for handing to other threads).
    pub fn waker(&self) -> io::Result<Waker> {
        Ok(Waker {
            write: self.write.try_clone()?,
        })
    }
}

/// Write-end handle cloned off a [`WakePipe`].
#[cfg(unix)]
pub struct Waker {
    write: std::os::unix::net::UnixStream,
}

#[cfg(unix)]
impl Waker {
    /// Make the paired read end pollable (see [`WakePipe::wake`]).
    pub fn wake(&self) {
        use std::io::Write;
        let mut w = &self.write;
        let _ = w.write(&[1u8]);
    }
}

/// Non-unix stub: no pipe exists; [`wait`] never blocks long, so wakeups
/// are unnecessary.
#[cfg(not(unix))]
pub struct WakePipe;

#[cfg(not(unix))]
impl WakePipe {
    pub fn new() -> io::Result<WakePipe> {
        Ok(WakePipe)
    }
    pub fn fd(&self) -> Fd {
        -1
    }
    pub fn wake(&self) {}
    pub fn drain(&self) {}
    pub fn waker(&self) -> io::Result<Waker> {
        Ok(Waker)
    }
}

/// Non-unix stub waker.
#[cfg(not(unix))]
pub struct Waker;

#[cfg(not(unix))]
impl Waker {
    pub fn wake(&self) {}
}

/// Raw fd of a TCP stream for polling.
#[cfg(unix)]
pub fn fd_of(stream: &std::net::TcpStream) -> Fd {
    use std::os::unix::io::AsRawFd;
    stream.as_raw_fd()
}

/// Non-unix stub: the fallback [`wait`] ignores fds entirely.
#[cfg(not(unix))]
pub fn fd_of(_stream: &std::net::TcpStream) -> Fd {
    -1
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    #[test]
    fn wake_pipe_round_trip() {
        let pipe = WakePipe::new().unwrap();
        pipe.wake();
        let specs = [PollSpec {
            fd: pipe.fd(),
            read: true,
            write: false,
        }];
        let events = wait(&specs, Duration::from_millis(500)).unwrap();
        assert!(events[0].readable, "wake() must make the pipe readable");
        pipe.drain();
    }

    #[cfg(unix)]
    #[test]
    fn timeout_returns_empty_events() {
        let pipe = WakePipe::new().unwrap();
        let specs = [PollSpec {
            fd: pipe.fd(),
            read: true,
            write: false,
        }];
        let start = Instant::now();
        let events = wait(&specs, Duration::from_millis(30)).unwrap();
        assert!(!events[0].readable, "nothing written: no readiness");
        assert!(start.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn waker_clone_wakes_from_another_thread() {
        let pipe = WakePipe::new().unwrap();
        let waker = pipe.waker().unwrap();
        let handle = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            waker.wake();
        });
        let specs = [PollSpec {
            fd: pipe.fd(),
            read: true,
            write: false,
        }];
        let start = Instant::now();
        let events = wait(&specs, Duration::from_secs(5)).unwrap();
        handle.join().unwrap();
        assert!(events[0].readable || !cfg!(unix));
        assert!(start.elapsed() < Duration::from_secs(5));
        pipe.drain();
    }

    #[test]
    fn drain_clears_pending_wakeups() {
        let pipe = WakePipe::new().unwrap();
        for _ in 0..10 {
            pipe.wake();
        }
        pipe.drain();
        let specs = [PollSpec {
            fd: pipe.fd(),
            read: true,
            write: false,
        }];
        let events = wait(&specs, Duration::from_millis(20)).unwrap();
        assert!(!events[0].readable || !cfg!(unix), "drained pipe is quiet");
    }
}
