//! Networked serving: the wire in front of the engine pool.
//!
//! Everything below `coordinator` serves requests that already live in the
//! process; this layer is how they arrive from outside it, on `std::net`
//! and `std::thread` only (the offline registry carries no async runtime
//! or HTTP crate — same constraint as the rest of `util`):
//!
//! * [`http`] — minimal HTTP/1.1: an incremental zero-copy request parser
//!   (`try_parse_request`) with hard caps enforced even on incomplete
//!   prefixes (line/header/body size — slow-loris peers hit the caps or
//!   the deadline, never unbounded memory), keep-alive, and a response
//!   writer shared with the client side.
//! * [`poll`] — `poll(2)` readiness wrapper (std + raw FFI, no libc crate)
//!   plus the self-pipe [`poll::WakePipe`] the event workers block on.
//! * [`proto`] — the JSON wire schema for the `/v1` API: model-scoped
//!   inference (tensor, `{"seed":n}`, or a `{"batch":[…]}` of them in;
//!   logits + queue/execute/per-image latency breakdown + worker + PE
//!   utilization out), the `GET /v1/models` registry listing, per-model
//!   metrics with admission counters, the `/admin` model-spec body, and
//!   the single structured error schema
//!   `{"error":{"code","message","model"}}`.
//! * [`server`] — [`server::HttpFrontend`]: acceptor + a **fixed pool of
//!   event-driven connection workers** (nonblocking sockets multiplexed
//!   over [`poll::wait`]) routing requests by URL path into a shared
//!   [`crate::coordinator::ModelRegistry`], with per-model admission
//!   control (bounded in-flight budget → 429, connection cap → 503),
//!   drain mode, and graceful shutdown that flushes every pool's batcher.
//!   Observability rides the same surface: `GET /v1/metrics`
//!   (`?format=prometheus` for the text exposition) and
//!   `GET /v1/models/<name>/trace` for per-request spans with measured
//!   vs Eq. 13-predicted data movement (see [`crate::obs`]).
//! * [`loadgen`] — open-loop (fixed arrival rate, latency from scheduled
//!   arrival) and closed-loop (fixed concurrency) drivers with percentile
//!   + histogram reporting — single-model or mixed round-robin across
//!   `/v1` model routes — writing `BENCH_serve.json` via
//!   [`crate::util::bench`].
//!
//! The request path end to end:
//!
//! ```text
//! socket ──► event worker (poll + incremental parse, caps + deadline)
//!        ──► route (/v1/models/<name>/…) ──► registry ──► admission
//!        ──► Client ──mpsc──► dispatcher (Batcher) ──► engine pool
//!        ◄── Response {logits, queue/execute breakdown, worker} as JSON
//! ```
//!
//! HTTP inference is **bit-identical** to the in-process `Client` path:
//! tensors cross the wire as f64-exact JSON numbers and the pool replicas
//! are deterministic, which `rust/tests/test_net.rs` pins across α and
//! scheduler policies. This layer is serving infrastructure around the
//! paper's reproduction, not part of the paper itself (see
//! `docs/PAPER_MAP.md`).

pub mod http;
pub mod loadgen;
pub mod poll;
pub mod proto;
pub mod server;

pub use http::{HttpConn, HttpError, HttpLimits, HttpRequest};
pub use loadgen::{LoadGenConfig, LoadMode, LoadReport};
pub use server::{HttpFrontend, NetConfig, Route};
