//! Networked serving: the wire in front of the engine pool.
//!
//! Everything below `coordinator` serves requests that already live in the
//! process; this layer is how they arrive from outside it, on `std::net`
//! and `std::thread` only (the offline registry carries no async runtime
//! or HTTP crate — same constraint as the rest of `util`):
//!
//! * [`http`] — minimal HTTP/1.1: strict request parsing with hard caps
//!   (line/header/body size, deadline-based reads that defeat slow-loris
//!   peers), keep-alive, and a response writer shared with the client
//!   side.
//! * [`proto`] — the JSON wire schema: `POST /infer` (tensor, `{"seed":n}`,
//!   or a `{"batch":[…]}` of them in; logits + queue/execute/per-image
//!   latency breakdown + worker + PE utilization out — batched bodies get
//!   `{"results":[…]}` in request order), `GET /metrics` (merged +
//!   per-worker pool snapshot with the batch-size histogram),
//!   `GET /healthz`.
//! * [`server`] — [`server::HttpFrontend`]: acceptor + per-connection
//!   threads wired to [`crate::coordinator::Server`] through cloned
//!   [`crate::coordinator::Client`] handles, with admission control
//!   (bounded in-flight budget → 429, connection cap → 503), drain mode,
//!   and graceful shutdown that flushes the batcher.
//! * [`loadgen`] — open-loop (fixed arrival rate, latency from scheduled
//!   arrival) and closed-loop (fixed concurrency) drivers with percentile
//!   + histogram reporting, writing `BENCH_serve.json` via
//!   [`crate::util::bench`].
//!
//! The request path end to end:
//!
//! ```text
//! socket ──► HttpConn (caps + deadline) ──► admission (inflight ≤ bound)
//!        ──► Client ──mpsc──► dispatcher (Batcher) ──► engine pool
//!        ◄── Response {logits, queue/execute breakdown, worker} as JSON
//! ```
//!
//! HTTP inference is **bit-identical** to the in-process `Client` path:
//! tensors cross the wire as f64-exact JSON numbers and the pool replicas
//! are deterministic, which `rust/tests/test_net.rs` pins across α and
//! scheduler policies. This layer is serving infrastructure around the
//! paper's reproduction, not part of the paper itself (see
//! `docs/PAPER_MAP.md`).

pub mod http;
pub mod loadgen;
pub mod proto;
pub mod server;

pub use http::{HttpConn, HttpError, HttpLimits, HttpRequest};
pub use loadgen::{LoadGenConfig, LoadMode, LoadReport};
pub use server::{HttpFrontend, NetConfig};
