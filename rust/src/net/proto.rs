//! JSON wire schema for the serving endpoints.
//!
//! * `POST /infer` — body is either an explicit tensor
//!   `{"shape":[c,h,w],"data":[…]}` or `{"seed":n}`, which asks the server
//!   to synthesize the deterministic test image for `n` (identical to
//!   [`crate::coordinator::InferenceEngine::synthetic_image`] — tiny
//!   request bodies for the load generator, same bits as the in-process
//!   path). Reply: logits plus the latency breakdown
//!   (`latency_us = queue_us + execute_us`), the amortized per-image share
//!   of the batch execute, the executing worker, and the engine's Alg. 2
//!   PE utilization. A `{"batch":[…]}` body carries up to
//!   [`MAX_BATCH_REQUESTS`] single-image bodies (each `{"seed":n}` or an
//!   explicit tensor) and is answered with `{"results":[…]}` — one reply
//!   object per image, in request order.
//! * `GET /metrics` — merged + per-worker
//!   [`PoolMetrics`](crate::coordinator::PoolMetrics) snapshot, including
//!   the queue/execute percentiles and the schedule-quality block.
//! * `GET /healthz` — `{"status":"ok"}` (200) or `{"status":"draining"}`
//!   (503).
//!
//! Values round-trip exactly: logits are f32, carried as f64 (exact), and
//! the serializer prints the shortest representation that re-parses to the
//! same f64 — so HTTP inference is *bit-identical* to the in-process
//! `Client`, which the integration tests pin.
//!
//! Parsing runs under tight [`JsonLimits`] (depth [`WIRE_JSON_DEPTH`], size
//! = the HTTP body cap): the wire is untrusted input.

use std::time::Duration;

use crate::coordinator::{ArenaMetrics, Metrics, PoolMetrics, Response, ScheduleMetrics};
use crate::err;
use crate::runtime::{Dtype, Plane};
use crate::tensor::Tensor;
use crate::util::error::Result;
use crate::util::json::{arr, num, obj, s, Json, JsonLimits};
use crate::util::rng::Pcg32;

/// Maximum JSON nesting accepted from the wire (the schema needs 3).
pub const WIRE_JSON_DEPTH: usize = 32;

/// Maximum tensor elements accepted in one `/infer` body (a 2048×2048 RGB
/// image; a vgg16-224 input is 150528).
pub const MAX_INFER_ELEMS: usize = 3 * 2048 * 2048;

/// Maximum images accepted in one `{"batch":[…]}` body — matches the
/// default inflight cap, so one batched request can never exceed what the
/// admission gate would grant 64 serial clients.
pub const MAX_BATCH_REQUESTS: usize = 64;

/// A parsed `POST /infer` body: one image, or an ordered batch of them.
#[derive(Debug, Clone)]
pub enum InferRequest {
    Single(Tensor),
    Batch(Vec<Tensor>),
}

/// `{"error": message}` — the body of every non-200 reply.
pub fn error_body(message: &str) -> String {
    obj(vec![("error", s(message))]).to_string()
}

/// Parse a `POST /infer` body into the input tensor. `input_shape` is the
/// served variant's `[C, H, W]`, used for `{"seed":n}` synthesis; explicit
/// `shape`/`data` tensors are validated structurally here and semantically
/// (against the variant) by the engine.
pub fn parse_infer_request(body: &[u8], input_shape: [usize; 3]) -> Result<Tensor> {
    match parse_infer_body(body, input_shape)? {
        InferRequest::Single(t) => Ok(t),
        InferRequest::Batch(_) => Err(err!("expected a single image, got a \"batch\" body")),
    }
}

/// Parse a `POST /infer` body, accepting both the single-image forms and
/// the `{"batch":[…]}` form (each element is itself a single-image body).
/// Order is preserved: `results[i]` will answer `batch[i]`.
pub fn parse_infer_body(body: &[u8], input_shape: [usize; 3]) -> Result<InferRequest> {
    let text = std::str::from_utf8(body).map_err(|_| err!("body is not utf-8"))?;
    let limits = JsonLimits { max_bytes: body.len().max(1), max_depth: WIRE_JSON_DEPTH };
    let j = Json::parse_with_limits(text, limits).map_err(|e| err!("bad json: {e}"))?;
    if let Some(batch) = j.get("batch") {
        let items = batch.as_arr().ok_or_else(|| err!("\"batch\" must be an array"))?;
        if items.is_empty() {
            return Err(err!("\"batch\" must not be empty"));
        }
        if items.len() > MAX_BATCH_REQUESTS {
            return Err(err!(
                "\"batch\" has {} images, the limit is {MAX_BATCH_REQUESTS}",
                items.len()
            ));
        }
        let images = items
            .iter()
            .enumerate()
            .map(|(i, item)| {
                tensor_from_json(item, input_shape)
                    .map_err(|e| err!("batch image {i}: {e}"))
            })
            .collect::<Result<Vec<_>>>()?;
        return Ok(InferRequest::Batch(images));
    }
    Ok(InferRequest::Single(tensor_from_json(&j, input_shape)?))
}

/// One single-image body (already parsed): `{"seed":n}` or
/// `{"shape":[c,h,w],"data":[…]}`.
fn tensor_from_json(j: &Json, input_shape: [usize; 3]) -> Result<Tensor> {
    if let Some(seed) = j.get("seed") {
        let seed = seed
            .as_usize()
            .ok_or_else(|| err!("\"seed\" must be a non-negative integer"))?;
        return Ok(Tensor::randn(&input_shape, &mut Pcg32::new(seed as u64), 1.0));
    }
    let shape: Vec<usize> = j
        .get("shape")
        .and_then(Json::as_arr)
        .ok_or_else(|| err!("body needs {{\"shape\":[c,h,w],\"data\":[…]}} or {{\"seed\":n}}"))?
        .iter()
        .map(Json::as_usize)
        .collect::<Option<_>>()
        .ok_or_else(|| err!("\"shape\" must be non-negative integers"))?;
    if shape.len() != 3 {
        return Err(err!("\"shape\" must have 3 dims [c,h,w], got {}", shape.len()));
    }
    // checked product: hostile dims must error, not overflow (a debug-build
    // panic here would kill the connection thread)
    let elems = shape
        .iter()
        .try_fold(1usize, |acc, &d| acc.checked_mul(d))
        .filter(|&e| e > 0 && e <= MAX_INFER_ELEMS)
        .ok_or_else(|| {
            err!("shape {shape:?} must have between 1 and {MAX_INFER_ELEMS} elements")
        })?;
    let data_j = j
        .get("data")
        .and_then(Json::as_arr)
        .ok_or_else(|| err!("\"data\" must be an array of numbers"))?;
    if data_j.len() != elems {
        return Err(err!("\"data\" has {} values, shape {shape:?} wants {elems}", data_j.len()));
    }
    let data: Vec<f32> = data_j
        .iter()
        .map(|v| v.as_f64().map(|f| f as f32))
        .collect::<Option<_>>()
        .ok_or_else(|| err!("\"data\" must be an array of numbers"))?;
    Ok(Tensor::from_vec(&shape, data))
}

/// Render a tensor as an explicit `/infer` body (tests, clients).
pub fn tensor_to_json(t: &Tensor) -> Json {
    obj(vec![
        ("shape", arr(t.shape().iter().map(|&d| num(d as f64)).collect())),
        ("data", arr(t.data().iter().map(|&v| num(v as f64)).collect())),
    ])
}

/// Render one completed inference as the `/infer` reply body.
pub fn response_to_json(r: &Response) -> Json {
    obj(vec![
        ("logits", arr(r.logits.iter().map(|&v| num(v as f64)).collect())),
        ("latency_us", num(r.latency.as_micros() as f64)),
        ("queue_us", num(r.queue_wait.as_micros() as f64)),
        ("execute_us", num(r.execute.as_micros() as f64)),
        ("per_image_us", num(r.per_image.as_micros() as f64)),
        ("batch_size", num(r.batch_size as f64)),
        ("worker", num(r.worker as f64)),
        ("pe_utilization", r.pe_utilization.map(num).unwrap_or(Json::Null)),
        ("dtype", s(r.dtype.label())),
        ("plane", s(r.plane.label())),
    ])
}

/// Render a batched inference's replies as `{"results":[…]}`, one object
/// per image in request order.
pub fn batch_response_to_json(rs: &[Response]) -> Json {
    obj(vec![("results", arr(rs.iter().map(response_to_json).collect()))])
}

/// Extract the logits from a parsed `/infer` reply.
pub fn logits_from_json(j: &Json) -> Result<Vec<f32>> {
    j.get("logits")
        .and_then(Json::as_arr)
        .ok_or_else(|| err!("reply has no \"logits\" array"))?
        .iter()
        .map(|v| v.as_f64().map(|f| f as f32))
        .collect::<Option<_>>()
        .ok_or_else(|| err!("\"logits\" must be numbers"))
}

fn duration_us(d: Option<Duration>) -> Json {
    d.map(|d| num(d.as_micros() as f64)).unwrap_or(Json::Null)
}

fn schedule_to_json(sm: &ScheduleMetrics) -> Json {
    obj(vec![
        ("scheduler", s(&sm.scheduler)),
        ("pe_utilization", num(sm.avg_pe_utilization())),
        ("cycles", num(sm.total_cycles() as f64)),
        ("lower_bound", num(sm.total_lower_bound() as f64)),
        ("bank_conflicts", num(sm.total_bank_conflicts() as f64)),
        (
            "layers",
            arr(sm
                .layers
                .iter()
                .map(|l| {
                    obj(vec![
                        ("layer", s(&l.layer)),
                        ("pe_utilization", num(l.stats.pe_utilization())),
                        ("cycles", num(l.stats.cycles as f64)),
                        ("lower_bound", num(l.stats.lower_bound as f64)),
                        ("bank_conflicts", num(l.stats.bank_conflicts as f64)),
                    ])
                })
                .collect()),
        ),
    ])
}

fn arena_to_json(am: &ArenaMetrics) -> Json {
    obj(vec![
        ("tensors", num(am.tensors as f64)),
        ("slots", num(am.slots as f64)),
        ("reused", num(am.reused as f64)),
        ("peak_activation_bytes", num(am.peak_activation_bytes as f64)),
        ("no_reuse_bytes", num(am.no_reuse_bytes as f64)),
    ])
}

fn metrics_to_json(m: &Metrics) -> Json {
    obj(vec![
        ("count", num(m.count() as f64)),
        ("throughput_rps", num(m.throughput())),
        ("mean_batch", num(m.mean_batch_size())),
        ("p50_us", duration_us(m.p50())),
        ("p95_us", duration_us(m.p95())),
        ("p99_us", duration_us(m.p99())),
        ("queue_p50_us", duration_us(m.queue_percentile(0.5))),
        ("queue_p95_us", duration_us(m.queue_percentile(0.95))),
        ("execute_p50_us", duration_us(m.execute_percentile(0.5))),
        ("execute_p95_us", duration_us(m.execute_percentile(0.95))),
        ("per_image_p50_us", duration_us(m.per_image_percentile(0.5))),
        ("per_image_p95_us", duration_us(m.per_image_percentile(0.95))),
        (
            "batch_hist",
            arr(m
                .batch_histogram()
                .iter()
                .enumerate()
                .filter(|&(_, &c)| c > 0)
                .map(|(size, &count)| {
                    obj(vec![
                        ("size", num(size as f64)),
                        ("count", num(count as f64)),
                    ])
                })
                .collect()),
        ),
        ("schedule", m.schedule.as_ref().map(schedule_to_json).unwrap_or(Json::Null)),
        ("arena", m.arena.as_ref().map(arena_to_json).unwrap_or(Json::Null)),
    ])
}

/// Render the `/metrics` reply: merged snapshot + one entry per worker,
/// tagged with the pool-wide numeric mode (every worker engine replicates
/// the same dtype/plane, so they sit at the top level, not per worker).
pub fn pool_metrics_to_json(pm: &PoolMetrics, dtype: Dtype, plane: Plane) -> Json {
    obj(vec![
        ("dtype", s(dtype.label())),
        ("plane", s(plane.label())),
        ("merged", metrics_to_json(&pm.merged)),
        ("per_worker", arr(pm.per_worker.iter().map(metrics_to_json).collect())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn explicit_tensor_roundtrips_bit_exactly() {
        let mut rng = Pcg32::new(9);
        let t = Tensor::randn(&[1, 4, 4], &mut rng, 1.0);
        let wire = tensor_to_json(&t).to_string();
        let back = parse_infer_request(wire.as_bytes(), [1, 4, 4]).unwrap();
        assert_eq!(back.shape(), t.shape());
        for (a, b) in back.data().iter().zip(t.data()) {
            assert_eq!(a.to_bits(), b.to_bits(), "f32 → json → f32 must be exact");
        }
    }

    #[test]
    fn seed_body_matches_synthetic_image() {
        let shape = [1usize, 16, 16];
        let t = parse_infer_request(b"{\"seed\": 3}", shape).unwrap();
        let want = Tensor::randn(&shape, &mut Pcg32::new(3), 1.0);
        assert_eq!(t, want);
    }

    #[test]
    fn rejects_malformed_bodies() {
        let shape = [1usize, 4, 4];
        for bad in [
            &b"not json"[..],
            b"{\"shape\":[1,4",                      // truncated json
            b"{}",                                   // neither seed nor tensor
            b"{\"seed\": -1}",                       // negative seed
            b"{\"shape\":[1,4,4]}",                  // missing data
            b"{\"shape\":[1,4],\"data\":[1,2]}",     // wrong rank
            b"{\"shape\":[0,4,4],\"data\":[]}",      // zero elements
            b"{\"shape\":[1,2,2],\"data\":[1,2,3]}", // count mismatch
            b"{\"shape\":[1,2,2],\"data\":[1,2,\"x\",4]}", // non-number
        ] {
            assert!(
                parse_infer_request(bad, shape).is_err(),
                "{:?}",
                String::from_utf8_lossy(bad)
            );
        }
        // oversized element count is capped independently of the body size
        let huge = br#"{"shape":[3,9999,9999],"data":[]}"#;
        assert!(parse_infer_request(huge, shape).is_err());
    }

    #[test]
    fn response_json_carries_breakdown_and_worker() {
        let r = Response {
            logits: vec![1.5, -2.25],
            latency: Duration::from_micros(1200),
            queue_wait: Duration::from_micros(200),
            execute: Duration::from_micros(1000),
            per_image: Duration::from_micros(250),
            batch_size: 4,
            worker: 2,
            pe_utilization: Some(0.875),
            dtype: Dtype::F32,
            plane: Plane::Half,
        };
        let j = response_to_json(&r);
        assert_eq!(j.get("latency_us").unwrap().as_f64(), Some(1200.0));
        assert_eq!(j.get("queue_us").unwrap().as_f64(), Some(200.0));
        assert_eq!(j.get("execute_us").unwrap().as_f64(), Some(1000.0));
        assert_eq!(j.get("per_image_us").unwrap().as_f64(), Some(250.0));
        assert_eq!(j.get("worker").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("pe_utilization").unwrap().as_f64(), Some(0.875));
        assert_eq!(j.get("dtype").unwrap().as_str(), Some("f32"));
        assert_eq!(j.get("plane").unwrap().as_str(), Some("half"));
        let back = logits_from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back, r.logits);
        // dense serving: utilization is null, not absent
        let dense = Response { pe_utilization: None, ..r };
        let j = response_to_json(&dense);
        assert_eq!(j.get("pe_utilization"), Some(&Json::Null));
    }

    #[test]
    fn metrics_json_shape() {
        let mut m = Metrics::new();
        m.record_batch(2);
        m.record_request_split(Duration::from_micros(100), Duration::from_micros(400));
        m.record_per_image(Duration::from_micros(200));
        let pm = PoolMetrics::from_workers(vec![m]);
        let j = pool_metrics_to_json(&pm, Dtype::F64, Plane::Full);
        assert_eq!(j.get("dtype").unwrap().as_str(), Some("f64"));
        assert_eq!(j.get("plane").unwrap().as_str(), Some("full"));
        let merged = j.get("merged").unwrap();
        assert_eq!(merged.get("count").unwrap().as_usize(), Some(1));
        assert_eq!(merged.get("p50_us").unwrap().as_f64(), Some(500.0));
        assert_eq!(merged.get("queue_p50_us").unwrap().as_f64(), Some(100.0));
        assert_eq!(merged.get("execute_p50_us").unwrap().as_f64(), Some(400.0));
        assert_eq!(merged.get("per_image_p50_us").unwrap().as_f64(), Some(200.0));
        let hist = merged.get("batch_hist").unwrap().as_arr().unwrap();
        assert_eq!(hist.len(), 1, "one batch size observed");
        assert_eq!(hist[0].get("size").unwrap().as_usize(), Some(2));
        assert_eq!(hist[0].get("count").unwrap().as_usize(), Some(1));
        assert_eq!(merged.get("schedule"), Some(&Json::Null));
        assert_eq!(merged.get("arena"), Some(&Json::Null));
        assert_eq!(j.get("per_worker").unwrap().as_arr().unwrap().len(), 1);
        // and it reparses (the /metrics body is valid json)
        assert!(Json::parse(&j.to_string()).is_ok());
    }

    #[test]
    fn arena_metrics_serialize_when_present() {
        let mut m = Metrics::new();
        m.arena = Some(ArenaMetrics {
            tensors: 7,
            slots: 3,
            reused: 4,
            peak_activation_bytes: 32768,
            no_reuse_bytes: 52224,
        });
        let pm = PoolMetrics::from_workers(vec![m]);
        let j = pool_metrics_to_json(&pm, Dtype::F32, Plane::Half);
        let a = j.get("merged").unwrap().get("arena").unwrap();
        assert_eq!(a.get("peak_activation_bytes").unwrap().as_usize(), Some(32768));
        assert_eq!(a.get("no_reuse_bytes").unwrap().as_usize(), Some(52224));
        assert_eq!(a.get("slots").unwrap().as_usize(), Some(3));
        assert_eq!(a.get("tensors").unwrap().as_usize(), Some(7));
        assert_eq!(a.get("reused").unwrap().as_usize(), Some(4));
    }

    #[test]
    fn batch_body_parses_in_order_and_is_bounded() {
        let shape = [1usize, 4, 4];
        // a batch of seed bodies parses to the same tensors, in order
        let body = br#"{"batch":[{"seed":3},{"seed":7},{"seed":3}]}"#;
        match parse_infer_body(body, shape).unwrap() {
            InferRequest::Batch(images) => {
                assert_eq!(images.len(), 3);
                for (img, seed) in images.iter().zip([3u64, 7, 3]) {
                    assert_eq!(*img, Tensor::randn(&shape, &mut Pcg32::new(seed), 1.0));
                }
                assert_eq!(images[0], images[2], "same seed, same image");
            }
            other => panic!("expected a batch, got {other:?}"),
        }
        // a single-image body still parses as Single through the same entry
        assert!(matches!(
            parse_infer_body(b"{\"seed\": 1}", shape).unwrap(),
            InferRequest::Single(_)
        ));
        // and parse_infer_request refuses a batch body outright
        assert!(parse_infer_request(body, shape).is_err());
        // malformed batches: not an array, empty, bad element (named by
        // index), oversized
        assert!(parse_infer_body(br#"{"batch": 3}"#, shape).is_err());
        assert!(parse_infer_body(br#"{"batch": []}"#, shape).is_err());
        let e = parse_infer_body(br#"{"batch":[{"seed":1},{}]}"#, shape).unwrap_err();
        assert!(e.to_string().contains("batch image 1"), "{e}");
        let huge = format!(
            "{{\"batch\":[{}]}}",
            vec!["{\"seed\":1}"; MAX_BATCH_REQUESTS + 1].join(",")
        );
        assert!(parse_infer_body(huge.as_bytes(), shape).is_err());
    }

    #[test]
    fn batch_reply_wraps_per_image_results_in_order() {
        let mk = |logits: Vec<f32>| Response {
            logits,
            latency: Duration::from_micros(900),
            queue_wait: Duration::from_micros(100),
            execute: Duration::from_micros(800),
            per_image: Duration::from_micros(400),
            batch_size: 2,
            worker: 0,
            pe_utilization: None,
            dtype: Dtype::F32,
            plane: Plane::Full,
        };
        let j = batch_response_to_json(&[mk(vec![1.0, 2.0]), mk(vec![-3.5])]);
        let back = Json::parse(&j.to_string()).unwrap();
        let results = back.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(logits_from_json(&results[0]).unwrap(), vec![1.0, 2.0]);
        assert_eq!(logits_from_json(&results[1]).unwrap(), vec![-3.5]);
        assert_eq!(results[0].get("per_image_us").unwrap().as_f64(), Some(400.0));
    }
}
