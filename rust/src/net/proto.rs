//! JSON wire schema for the `/v1` serving endpoints.
//!
//! * `POST /v1/models/<name>/infer` — body is either an explicit tensor
//!   `{"shape":[c,h,w],"data":[…]}` or `{"seed":n}`, which asks the server
//!   to synthesize the deterministic test image for `n` (identical to
//!   [`crate::coordinator::InferenceEngine::synthetic_image`] — tiny
//!   request bodies for the load generator, same bits as the in-process
//!   path). Reply: logits plus the latency breakdown
//!   (`latency_us = queue_us + execute_us`), the amortized per-image share
//!   of the batch execute, the executing worker, and the engine's Alg. 2
//!   PE utilization. A `{"batch":[…]}` body carries up to
//!   [`MAX_BATCH_REQUESTS`] single-image bodies (each `{"seed":n}` or an
//!   explicit tensor) and is answered with `{"results":[…]}` — one reply
//!   object per image, in request order.
//! * `GET /v1/models` — registry listing ([`models_to_json`]): one row per
//!   model with its status (`serving`/`loading`/`draining`/`failed`) and
//!   swap generation.
//! * `GET /v1/models/<name>/metrics` — per-model merged + per-worker
//!   [`PoolMetrics`](crate::coordinator::PoolMetrics) snapshot
//!   ([`model_metrics_to_json`]), including the queue/execute percentiles,
//!   the schedule-quality block, and the admission/quota counters.
//! * `GET /healthz` — `{"status":"ok"}` (200) or `{"status":"draining"}`
//!   (503). The legacy `/infer` and `/metrics` aliases answer for the
//!   default model with the same bodies as their `/v1` forms.
//!
//! Every non-200 reply carries one structured error shape
//! ([`error_body`]): `{"error":{"code":…,"message":…,"model":…}}`.
//!
//! Values round-trip exactly: logits are f32, carried as f64 (exact), and
//! the serializer prints the shortest representation that re-parses to the
//! same f64 — so HTTP inference is *bit-identical* to the in-process
//! `Client`, which the integration tests pin.
//!
//! Parsing runs under tight [`JsonLimits`] (depth [`WIRE_JSON_DEPTH`], size
//! = the HTTP body cap): the wire is untrusted input.

use std::time::Duration;

use crate::coordinator::{
    AdmissionMetrics, ArenaMetrics, EngineOptions, Metrics, ModelSpec, ModelStatus,
    PoolMetrics, Response, ScheduleMetrics,
};
use crate::err;
use crate::obs::{RequestTrace, TrafficMetrics};
use crate::runtime::{Dtype, Plane};
use crate::schedule::SchedulePolicy;
use crate::tensor::Tensor;
use crate::util::error::Result;
use crate::util::json::{arr, num, obj, s, Json, JsonLimits};
use crate::util::rng::Pcg32;

/// Maximum JSON nesting accepted from the wire (the schema needs 3).
pub const WIRE_JSON_DEPTH: usize = 32;

/// Maximum tensor elements accepted in one `/infer` body (a 2048×2048 RGB
/// image; a vgg16-224 input is 150528).
pub const MAX_INFER_ELEMS: usize = 3 * 2048 * 2048;

/// Maximum images accepted in one `{"batch":[…]}` body — matches the
/// default inflight cap, so one batched request can never exceed what the
/// admission gate would grant 64 serial clients.
pub const MAX_BATCH_REQUESTS: usize = 64;

/// A parsed `POST /infer` body: one image, or an ordered batch of them.
#[derive(Debug, Clone)]
pub enum InferRequest {
    Single(Tensor),
    Batch(Vec<Tensor>),
}

/// The single structured error schema every non-200 reply uses:
/// `{"error":{"code":…,"message":…,"model":…}}`. `code` is a stable
/// machine-readable slug (`bad_request`, `not_found`, `overloaded`,
/// `draining`, `loading`, `unavailable`, `method_not_allowed`, `conflict`,
/// `timeout`, `payload_too_large`, `internal`); `model` names the model the
/// request resolved to, or null for errors before routing.
pub fn error_body(code: &str, message: &str, model: Option<&str>) -> String {
    obj(vec![(
        "error",
        obj(vec![
            ("code", s(code)),
            ("message", s(message)),
            ("model", model.map(s).unwrap_or(Json::Null)),
        ]),
    )])
    .to_string()
}

/// Map an HTTP status to the default error-schema code slug.
pub fn code_for_status(status: u16) -> &'static str {
    match status {
        400 => "bad_request",
        404 => "not_found",
        405 => "method_not_allowed",
        408 => "timeout",
        409 => "conflict",
        413 => "payload_too_large",
        429 => "overloaded",
        431 => "bad_request",
        501 => "bad_request",
        503 => "unavailable",
        505 => "bad_request",
        _ => "internal",
    }
}

/// Parse a `POST /infer` body into the input tensor. `input_shape` is the
/// served variant's `[C, H, W]`, used for `{"seed":n}` synthesis; explicit
/// `shape`/`data` tensors are validated structurally here and semantically
/// (against the variant) by the engine.
pub fn parse_infer_request(body: &[u8], input_shape: [usize; 3]) -> Result<Tensor> {
    match parse_infer_body(body, input_shape)? {
        InferRequest::Single(t) => Ok(t),
        InferRequest::Batch(_) => Err(err!("expected a single image, got a \"batch\" body")),
    }
}

/// Parse a `POST /infer` body, accepting both the single-image forms and
/// the `{"batch":[…]}` form (each element is itself a single-image body).
/// Order is preserved: `results[i]` will answer `batch[i]`.
pub fn parse_infer_body(body: &[u8], input_shape: [usize; 3]) -> Result<InferRequest> {
    let text = std::str::from_utf8(body).map_err(|_| err!("body is not utf-8"))?;
    let limits = JsonLimits { max_bytes: body.len().max(1), max_depth: WIRE_JSON_DEPTH };
    let j = Json::parse_with_limits(text, limits).map_err(|e| err!("bad json: {e}"))?;
    if let Some(batch) = j.get("batch") {
        let items = batch.as_arr().ok_or_else(|| err!("\"batch\" must be an array"))?;
        if items.is_empty() {
            return Err(err!("\"batch\" must not be empty"));
        }
        if items.len() > MAX_BATCH_REQUESTS {
            return Err(err!(
                "\"batch\" has {} images, the limit is {MAX_BATCH_REQUESTS}",
                items.len()
            ));
        }
        let images = items
            .iter()
            .enumerate()
            .map(|(i, item)| {
                tensor_from_json(item, input_shape)
                    .map_err(|e| err!("batch image {i}: {e}"))
            })
            .collect::<Result<Vec<_>>>()?;
        return Ok(InferRequest::Batch(images));
    }
    Ok(InferRequest::Single(tensor_from_json(&j, input_shape)?))
}

/// One single-image body (already parsed): `{"seed":n}` or
/// `{"shape":[c,h,w],"data":[…]}`.
fn tensor_from_json(j: &Json, input_shape: [usize; 3]) -> Result<Tensor> {
    if let Some(seed) = j.get("seed") {
        let seed = seed
            .as_usize()
            .ok_or_else(|| err!("\"seed\" must be a non-negative integer"))?;
        return Ok(Tensor::randn(&input_shape, &mut Pcg32::new(seed as u64), 1.0));
    }
    let shape: Vec<usize> = j
        .get("shape")
        .and_then(Json::as_arr)
        .ok_or_else(|| err!("body needs {{\"shape\":[c,h,w],\"data\":[…]}} or {{\"seed\":n}}"))?
        .iter()
        .map(Json::as_usize)
        .collect::<Option<_>>()
        .ok_or_else(|| err!("\"shape\" must be non-negative integers"))?;
    if shape.len() != 3 {
        return Err(err!("\"shape\" must have 3 dims [c,h,w], got {}", shape.len()));
    }
    // checked product: hostile dims must error, not overflow (a debug-build
    // panic here would kill the connection thread)
    let elems = shape
        .iter()
        .try_fold(1usize, |acc, &d| acc.checked_mul(d))
        .filter(|&e| e > 0 && e <= MAX_INFER_ELEMS)
        .ok_or_else(|| {
            err!("shape {shape:?} must have between 1 and {MAX_INFER_ELEMS} elements")
        })?;
    let data_j = j
        .get("data")
        .and_then(Json::as_arr)
        .ok_or_else(|| err!("\"data\" must be an array of numbers"))?;
    if data_j.len() != elems {
        return Err(err!("\"data\" has {} values, shape {shape:?} wants {elems}", data_j.len()));
    }
    let data: Vec<f32> = data_j
        .iter()
        .map(|v| v.as_f64().map(|f| f as f32))
        .collect::<Option<_>>()
        .ok_or_else(|| err!("\"data\" must be an array of numbers"))?;
    Ok(Tensor::from_vec(&shape, data))
}

/// Keys a `POST /admin/models/<name>` body may carry. Anything else is a
/// hard error — an admin API that silently ignores a typo'd knob is worse
/// than one that rejects it.
const MODEL_SPEC_KEYS: [&str; 12] = [
    "preset",
    "alpha",
    "seed",
    "workers",
    "max_batch",
    "wait_ms",
    "scheduler",
    "dtype",
    "plane",
    "max_inflight",
    "arena_reuse",
    "observe",
];

/// Parse a `POST /admin/models/<name>` body into a [`ModelSpec`].
///
/// Every key is optional; an empty body loads the preset named `name` with
/// defaults. `preset` defaults to the model name, so
/// `POST /admin/models/resnet18` with `{}` serves the `resnet18` variant.
/// `"dtype":""` (like `--dtype` unset) defers to the manifest default.
pub fn parse_model_spec(body: &[u8], name: &str) -> Result<ModelSpec> {
    let mut spec = ModelSpec { preset: name.to_string(), ..ModelSpec::default() };
    if body.iter().all(|b| b.is_ascii_whitespace()) {
        return Ok(spec);
    }
    let text = std::str::from_utf8(body).map_err(|_| err!("body is not utf-8"))?;
    let limits = JsonLimits { max_bytes: body.len().max(1), max_depth: WIRE_JSON_DEPTH };
    let j = Json::parse_with_limits(text, limits).map_err(|e| err!("bad json: {e}"))?;
    let fields = j.as_obj().ok_or_else(|| err!("model spec must be a json object"))?;
    for key in fields.keys() {
        if !MODEL_SPEC_KEYS.contains(&key.as_str()) {
            return Err(err!("unknown model-spec key {key:?}"));
        }
    }
    let get_usize = |key: &str| -> Result<Option<usize>> {
        match j.get(key) {
            None => Ok(None),
            Some(v) => v
                .as_usize()
                .map(Some)
                .ok_or_else(|| err!("{key:?} must be a non-negative integer")),
        }
    };
    let get_str = |key: &str| -> Result<Option<&str>> {
        match j.get(key) {
            None => Ok(None),
            Some(v) => v
                .as_str()
                .map(Some)
                .ok_or_else(|| err!("{key:?} must be a string")),
        }
    };
    if let Some(preset) = get_str("preset")? {
        if preset.is_empty() {
            return Err(err!("\"preset\" must not be empty"));
        }
        spec.preset = preset.to_string();
    }
    if let Some(alpha) = get_usize("alpha")? {
        spec.alpha = alpha;
    }
    if let Some(seed) = get_usize("seed")? {
        spec.seed = seed as u64;
    }
    if let Some(workers) = get_usize("workers")? {
        spec.workers = workers;
    }
    if let Some(max_batch) = get_usize("max_batch")? {
        spec.batcher.max_batch = max_batch.max(1);
    }
    if let Some(wait_ms) = get_usize("wait_ms")? {
        spec.batcher.max_wait = Duration::from_millis(wait_ms as u64);
    }
    if let Some(max_inflight) = get_usize("max_inflight")? {
        spec.max_inflight = max_inflight;
    }
    let mut engine = EngineOptions::builder();
    if let Some(scheduler) = get_str("scheduler")? {
        engine = engine.scheduler(SchedulePolicy::parse(scheduler)?);
    }
    if let Some(dtype) = get_str("dtype")? {
        let parsed = if dtype.is_empty() { None } else { Some(Dtype::parse(dtype)?) };
        engine = engine.dtype(parsed);
    }
    if let Some(plane) = get_str("plane")? {
        engine = engine.plane(Plane::parse(plane)?);
    }
    if let Some(arena) = j.get("arena_reuse") {
        let arena = arena
            .as_bool()
            .ok_or_else(|| err!("\"arena_reuse\" must be a boolean"))?;
        engine = engine.arena_reuse(arena);
    }
    if let Some(observe) = j.get("observe") {
        let observe = observe
            .as_bool()
            .ok_or_else(|| err!("\"observe\" must be a boolean"))?;
        engine = engine.observe(observe);
    }
    spec.engine = engine.build();
    Ok(spec)
}

/// Render a tensor as an explicit `/infer` body (tests, clients).
pub fn tensor_to_json(t: &Tensor) -> Json {
    obj(vec![
        ("shape", arr(t.shape().iter().map(|&d| num(d as f64)).collect())),
        ("data", arr(t.data().iter().map(|&v| num(v as f64)).collect())),
    ])
}

/// Render one completed inference as the `/infer` reply body.
pub fn response_to_json(r: &Response) -> Json {
    obj(vec![
        ("logits", arr(r.logits.iter().map(|&v| num(v as f64)).collect())),
        ("latency_us", num(r.latency.as_micros() as f64)),
        ("queue_us", num(r.queue_wait.as_micros() as f64)),
        ("execute_us", num(r.execute.as_micros() as f64)),
        ("per_image_us", num(r.per_image.as_micros() as f64)),
        ("batch_size", num(r.batch_size as f64)),
        ("worker", num(r.worker as f64)),
        ("pe_utilization", r.pe_utilization.map(num).unwrap_or(Json::Null)),
        ("dtype", s(r.dtype.label())),
        ("plane", s(r.plane.label())),
    ])
}

/// Render a batched inference's replies as `{"results":[…]}`, one object
/// per image in request order.
pub fn batch_response_to_json(rs: &[Response]) -> Json {
    obj(vec![("results", arr(rs.iter().map(response_to_json).collect()))])
}

/// Extract the logits from a parsed `/infer` reply.
pub fn logits_from_json(j: &Json) -> Result<Vec<f32>> {
    j.get("logits")
        .and_then(Json::as_arr)
        .ok_or_else(|| err!("reply has no \"logits\" array"))?
        .iter()
        .map(|v| v.as_f64().map(|f| f as f32))
        .collect::<Option<_>>()
        .ok_or_else(|| err!("\"logits\" must be numbers"))
}

fn duration_us(d: Option<Duration>) -> Json {
    d.map(|d| num(d.as_micros() as f64)).unwrap_or(Json::Null)
}

fn schedule_to_json(sm: &ScheduleMetrics) -> Json {
    obj(vec![
        ("scheduler", s(&sm.scheduler)),
        ("pe_utilization", num(sm.avg_pe_utilization())),
        ("cycles", num(sm.total_cycles() as f64)),
        ("lower_bound", num(sm.total_lower_bound() as f64)),
        ("bank_conflicts", num(sm.total_bank_conflicts() as f64)),
        (
            "layers",
            arr(sm
                .layers
                .iter()
                .map(|l| {
                    obj(vec![
                        ("layer", s(&l.layer)),
                        ("pe_utilization", num(l.stats.pe_utilization())),
                        ("cycles", num(l.stats.cycles as f64)),
                        ("lower_bound", num(l.stats.lower_bound as f64)),
                        ("bank_conflicts", num(l.stats.bank_conflicts as f64)),
                    ])
                })
                .collect()),
        ),
    ])
}

fn arena_to_json(am: &ArenaMetrics) -> Json {
    obj(vec![
        ("tensors", num(am.tensors as f64)),
        ("slots", num(am.slots as f64)),
        ("reused", num(am.reused as f64)),
        ("peak_activation_bytes", num(am.peak_activation_bytes as f64)),
        ("no_reuse_bytes", num(am.no_reuse_bytes as f64)),
    ])
}

/// Measured backend-boundary traffic next to the Eq. 13 prediction for the
/// executed plan, per conv layer plus engine totals.
fn traffic_to_json(t: &TrafficMetrics) -> Json {
    obj(vec![
        (
            "layers",
            arr(t
                .layers
                .iter()
                .map(|l| {
                    obj(vec![
                        ("layer", s(&l.layer)),
                        ("measured_weight_bytes", num(l.measured.weight_bytes as f64)),
                        ("measured_input_bytes", num(l.measured.input_bytes as f64)),
                        ("measured_output_bytes", num(l.measured.output_bytes as f64)),
                        ("measured_psum_bytes", num(l.measured.psum_bytes as f64)),
                        ("predicted_weight_bytes", num(l.predicted_weight_bytes as f64)),
                        ("predicted_input_bytes", num(l.predicted_input_bytes as f64)),
                        ("predicted_output_bytes", num(l.predicted_output_bytes as f64)),
                        ("weight_ratio", num(l.weight_ratio())),
                        ("forwards", num(l.forwards as f64)),
                    ])
                })
                .collect()),
        ),
        (
            "totals",
            obj(vec![
                ("weight_bytes", num(t.totals.weight_bytes as f64)),
                ("input_bytes", num(t.totals.input_bytes as f64)),
                ("output_bytes", num(t.totals.output_bytes as f64)),
                ("psum_bytes", num(t.totals.psum_bytes as f64)),
                ("arena_bytes", num(t.totals.arena_bytes as f64)),
            ]),
        ),
    ])
}

/// Render the `GET /v1/models/<name>/trace` reply: newest-first traces with
/// their span trees, plus the ring's drop counter and slow threshold.
pub fn traces_to_json(traces: &[RequestTrace], dropped: u64, slow_threshold_us: u64) -> Json {
    obj(vec![
        (
            "traces",
            arr(traces
                .iter()
                .map(|t| {
                    obj(vec![
                        ("request", num(t.request as f64)),
                        ("batch", num(t.batch as f64)),
                        ("worker", num(t.worker as f64)),
                        ("model", s(&t.model)),
                        ("batch_size", num(t.batch_size as f64)),
                        ("latency_us", num(t.latency_us as f64)),
                        ("slow", Json::Bool(t.slow)),
                        (
                            "spans",
                            arr(t
                                .spans
                                .iter()
                                .map(|sp| {
                                    obj(vec![
                                        ("name", s(&sp.name)),
                                        ("start_us", num(sp.start_us as f64)),
                                        ("end_us", num(sp.end_us as f64)),
                                        ("measured_bytes", num(sp.measured_bytes as f64)),
                                        ("predicted_bytes", num(sp.predicted_bytes as f64)),
                                    ])
                                })
                                .collect()),
                        ),
                    ])
                })
                .collect()),
        ),
        ("dropped", num(dropped as f64)),
        ("slow_threshold_us", num(slow_threshold_us as f64)),
    ])
}

fn metrics_to_json(m: &Metrics) -> Json {
    obj(vec![
        ("count", num(m.count() as f64)),
        ("throughput_rps", num(m.throughput())),
        ("mean_batch", num(m.mean_batch_size())),
        ("p50_us", duration_us(m.p50())),
        ("p95_us", duration_us(m.p95())),
        ("p99_us", duration_us(m.p99())),
        ("queue_p50_us", duration_us(m.queue_percentile(0.5))),
        ("queue_p95_us", duration_us(m.queue_percentile(0.95))),
        ("execute_p50_us", duration_us(m.execute_percentile(0.5))),
        ("execute_p95_us", duration_us(m.execute_percentile(0.95))),
        ("per_image_p50_us", duration_us(m.per_image_percentile(0.5))),
        ("per_image_p95_us", duration_us(m.per_image_percentile(0.95))),
        (
            "batch_hist",
            arr(m
                .batch_histogram()
                .iter()
                .enumerate()
                .filter(|&(_, &c)| c > 0)
                .map(|(size, &count)| {
                    obj(vec![
                        ("size", num(size as f64)),
                        ("count", num(count as f64)),
                    ])
                })
                .collect()),
        ),
        ("schedule", m.schedule.as_ref().map(schedule_to_json).unwrap_or(Json::Null)),
        ("arena", m.arena.as_ref().map(arena_to_json).unwrap_or(Json::Null)),
        ("traffic", m.traffic.as_ref().map(traffic_to_json).unwrap_or(Json::Null)),
    ])
}

/// Render the `/metrics` reply: merged snapshot + one entry per worker,
/// tagged with the pool-wide numeric mode (every worker engine replicates
/// the same dtype/plane, so they sit at the top level, not per worker).
pub fn pool_metrics_to_json(pm: &PoolMetrics, dtype: Dtype, plane: Plane) -> Json {
    obj(vec![
        ("dtype", s(dtype.label())),
        ("plane", s(plane.label())),
        ("merged", metrics_to_json(&pm.merged)),
        ("per_worker", arr(pm.per_worker.iter().map(metrics_to_json).collect())),
    ])
}

fn admission_to_json(a: &AdmissionMetrics) -> Json {
    obj(vec![
        ("inflight", num(a.inflight as f64)),
        ("max_inflight", num(a.max_inflight as f64)),
        ("admitted", num(a.admitted as f64)),
        ("rejected", num(a.rejected as f64)),
    ])
}

/// Render the `GET /v1/models/<name>/metrics` reply: the pool snapshot
/// plus the model's identity, swap generation, and admission counters.
pub fn model_metrics_to_json(
    name: &str,
    admission: &AdmissionMetrics,
    pm: &PoolMetrics,
    dtype: Dtype,
    plane: Plane,
) -> Json {
    obj(vec![
        ("model", s(name)),
        ("generation", num(admission.generation as f64)),
        ("admission", admission_to_json(admission)),
        ("dtype", s(dtype.label())),
        ("plane", s(plane.label())),
        ("merged", metrics_to_json(&pm.merged)),
        ("per_worker", arr(pm.per_worker.iter().map(metrics_to_json).collect())),
    ])
}

fn model_status_to_json(m: &ModelStatus) -> Json {
    obj(vec![
        ("name", s(&m.name)),
        ("status", s(m.status)),
        ("generation", num(m.generation as f64)),
        ("preset", m.preset.as_deref().map(s).unwrap_or(Json::Null)),
        ("alpha", m.alpha.map(|a| num(a as f64)).unwrap_or(Json::Null)),
        ("workers", m.workers.map(|w| num(w as f64)).unwrap_or(Json::Null)),
        (
            "max_inflight",
            m.max_inflight.map(|q| num(q as f64)).unwrap_or(Json::Null),
        ),
        ("error", m.error.as_deref().map(s).unwrap_or(Json::Null)),
    ])
}

/// Render the `GET /v1/models` reply: every registered model with its
/// lifecycle status and swap generation, plus the name the legacy aliases
/// resolve to.
pub fn models_to_json(models: &[ModelStatus], default_model: &str) -> Json {
    obj(vec![
        ("default_model", s(default_model)),
        ("models", arr(models.iter().map(model_status_to_json).collect())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn explicit_tensor_roundtrips_bit_exactly() {
        let mut rng = Pcg32::new(9);
        let t = Tensor::randn(&[1, 4, 4], &mut rng, 1.0);
        let wire = tensor_to_json(&t).to_string();
        let back = parse_infer_request(wire.as_bytes(), [1, 4, 4]).unwrap();
        assert_eq!(back.shape(), t.shape());
        for (a, b) in back.data().iter().zip(t.data()) {
            assert_eq!(a.to_bits(), b.to_bits(), "f32 → json → f32 must be exact");
        }
    }

    #[test]
    fn seed_body_matches_synthetic_image() {
        let shape = [1usize, 16, 16];
        let t = parse_infer_request(b"{\"seed\": 3}", shape).unwrap();
        let want = Tensor::randn(&shape, &mut Pcg32::new(3), 1.0);
        assert_eq!(t, want);
    }

    #[test]
    fn rejects_malformed_bodies() {
        let shape = [1usize, 4, 4];
        for bad in [
            &b"not json"[..],
            b"{\"shape\":[1,4",                      // truncated json
            b"{}",                                   // neither seed nor tensor
            b"{\"seed\": -1}",                       // negative seed
            b"{\"shape\":[1,4,4]}",                  // missing data
            b"{\"shape\":[1,4],\"data\":[1,2]}",     // wrong rank
            b"{\"shape\":[0,4,4],\"data\":[]}",      // zero elements
            b"{\"shape\":[1,2,2],\"data\":[1,2,3]}", // count mismatch
            b"{\"shape\":[1,2,2],\"data\":[1,2,\"x\",4]}", // non-number
        ] {
            assert!(
                parse_infer_request(bad, shape).is_err(),
                "{:?}",
                String::from_utf8_lossy(bad)
            );
        }
        // oversized element count is capped independently of the body size
        let huge = br#"{"shape":[3,9999,9999],"data":[]}"#;
        assert!(parse_infer_request(huge, shape).is_err());
    }

    #[test]
    fn response_json_carries_breakdown_and_worker() {
        let r = Response {
            logits: vec![1.5, -2.25],
            latency: Duration::from_micros(1200),
            queue_wait: Duration::from_micros(200),
            execute: Duration::from_micros(1000),
            per_image: Duration::from_micros(250),
            batch_size: 4,
            worker: 2,
            pe_utilization: Some(0.875),
            dtype: Dtype::F32,
            plane: Plane::Half,
        };
        let j = response_to_json(&r);
        assert_eq!(j.get("latency_us").unwrap().as_f64(), Some(1200.0));
        assert_eq!(j.get("queue_us").unwrap().as_f64(), Some(200.0));
        assert_eq!(j.get("execute_us").unwrap().as_f64(), Some(1000.0));
        assert_eq!(j.get("per_image_us").unwrap().as_f64(), Some(250.0));
        assert_eq!(j.get("worker").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("pe_utilization").unwrap().as_f64(), Some(0.875));
        assert_eq!(j.get("dtype").unwrap().as_str(), Some("f32"));
        assert_eq!(j.get("plane").unwrap().as_str(), Some("half"));
        let back = logits_from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back, r.logits);
        // dense serving: utilization is null, not absent
        let dense = Response { pe_utilization: None, ..r };
        let j = response_to_json(&dense);
        assert_eq!(j.get("pe_utilization"), Some(&Json::Null));
    }

    #[test]
    fn metrics_json_shape() {
        let mut m = Metrics::new();
        m.record_batch(2);
        m.record_request_split(Duration::from_micros(100), Duration::from_micros(400));
        m.record_per_image(Duration::from_micros(200));
        let pm = PoolMetrics::from_workers(vec![m]);
        let j = pool_metrics_to_json(&pm, Dtype::F64, Plane::Full);
        assert_eq!(j.get("dtype").unwrap().as_str(), Some("f64"));
        assert_eq!(j.get("plane").unwrap().as_str(), Some("full"));
        let merged = j.get("merged").unwrap();
        assert_eq!(merged.get("count").unwrap().as_usize(), Some(1));
        assert_eq!(merged.get("p50_us").unwrap().as_f64(), Some(500.0));
        assert_eq!(merged.get("queue_p50_us").unwrap().as_f64(), Some(100.0));
        assert_eq!(merged.get("execute_p50_us").unwrap().as_f64(), Some(400.0));
        assert_eq!(merged.get("per_image_p50_us").unwrap().as_f64(), Some(200.0));
        let hist = merged.get("batch_hist").unwrap().as_arr().unwrap();
        assert_eq!(hist.len(), 1, "one batch size observed");
        assert_eq!(hist[0].get("size").unwrap().as_usize(), Some(2));
        assert_eq!(hist[0].get("count").unwrap().as_usize(), Some(1));
        assert_eq!(merged.get("schedule"), Some(&Json::Null));
        assert_eq!(merged.get("arena"), Some(&Json::Null));
        assert_eq!(j.get("per_worker").unwrap().as_arr().unwrap().len(), 1);
        // and it reparses (the /metrics body is valid json)
        assert!(Json::parse(&j.to_string()).is_ok());
    }

    #[test]
    fn arena_metrics_serialize_when_present() {
        let mut m = Metrics::new();
        m.arena = Some(ArenaMetrics {
            tensors: 7,
            slots: 3,
            reused: 4,
            peak_activation_bytes: 32768,
            no_reuse_bytes: 52224,
        });
        let pm = PoolMetrics::from_workers(vec![m]);
        let j = pool_metrics_to_json(&pm, Dtype::F32, Plane::Half);
        let a = j.get("merged").unwrap().get("arena").unwrap();
        assert_eq!(a.get("peak_activation_bytes").unwrap().as_usize(), Some(32768));
        assert_eq!(a.get("no_reuse_bytes").unwrap().as_usize(), Some(52224));
        assert_eq!(a.get("slots").unwrap().as_usize(), Some(3));
        assert_eq!(a.get("tensors").unwrap().as_usize(), Some(7));
        assert_eq!(a.get("reused").unwrap().as_usize(), Some(4));
    }

    #[test]
    fn traffic_metrics_serialize_when_present() {
        use crate::obs::{LayerTraffic, TrafficSnapshot};
        let mut m = Metrics::new();
        m.traffic = Some(TrafficMetrics {
            layers: vec![LayerTraffic {
                layer: "conv1".into(),
                measured: TrafficSnapshot { weight_bytes: 2048, ..Default::default() },
                predicted_weight_bytes: 1024,
                predicted_input_bytes: 512,
                predicted_output_bytes: 256,
                forwards: 2,
            }],
            totals: TrafficSnapshot {
                weight_bytes: 2048,
                input_bytes: 100,
                output_bytes: 200,
                psum_bytes: 300,
                arena_bytes: 400,
            },
        });
        let pm = PoolMetrics::from_workers(vec![m]);
        let j = pool_metrics_to_json(&pm, Dtype::F32, Plane::Full);
        let t = j.get("merged").unwrap().get("traffic").unwrap();
        let l = &t.get("layers").unwrap().as_arr().unwrap()[0];
        assert_eq!(l.get("layer").unwrap().as_str(), Some("conv1"));
        assert_eq!(l.get("measured_weight_bytes").unwrap().as_usize(), Some(2048));
        assert_eq!(l.get("predicted_weight_bytes").unwrap().as_usize(), Some(1024));
        assert_eq!(l.get("weight_ratio").unwrap().as_f64(), Some(2.0));
        assert_eq!(l.get("forwards").unwrap().as_usize(), Some(2));
        let tot = t.get("totals").unwrap();
        assert_eq!(tot.get("psum_bytes").unwrap().as_usize(), Some(300));
        assert_eq!(tot.get("arena_bytes").unwrap().as_usize(), Some(400));
        assert!(Json::parse(&j.to_string()).is_ok());
        // absent traffic is null, not missing (same shape as schedule/arena)
        let j = pool_metrics_to_json(
            &PoolMetrics::from_workers(vec![Metrics::new()]),
            Dtype::F32,
            Plane::Full,
        );
        assert_eq!(j.get("merged").unwrap().get("traffic"), Some(&Json::Null));
    }

    #[test]
    fn traces_serialize_with_spans_and_ring_stats() {
        use crate::obs::Span;
        let t = RequestTrace {
            request: 7,
            batch: 3,
            worker: 1,
            model: "demo".into(),
            batch_size: 2,
            latency_us: 1500,
            slow: true,
            spans: vec![
                Span::plain("request", 0, 1500),
                Span {
                    name: "layer:conv1".into(),
                    start_us: 100,
                    end_us: 900,
                    measured_bytes: 4096,
                    predicted_bytes: 4096,
                },
            ],
        };
        let j = traces_to_json(&[t], 2, 50_000);
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back.get("dropped").unwrap().as_usize(), Some(2));
        assert_eq!(back.get("slow_threshold_us").unwrap().as_usize(), Some(50_000));
        let traces = back.get("traces").unwrap().as_arr().unwrap();
        assert_eq!(traces.len(), 1);
        assert_eq!(traces[0].get("request").unwrap().as_usize(), Some(7));
        assert_eq!(traces[0].get("batch").unwrap().as_usize(), Some(3));
        assert_eq!(traces[0].get("slow").unwrap().as_bool(), Some(true));
        let spans = traces[0].get("spans").unwrap().as_arr().unwrap();
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[0].get("name").unwrap().as_str(), Some("request"));
        assert_eq!(spans[1].get("name").unwrap().as_str(), Some("layer:conv1"));
        assert_eq!(spans[1].get("measured_bytes").unwrap().as_usize(), Some(4096));
        // an empty ring renders an empty list, still valid json
        let j = traces_to_json(&[], 0, 50_000);
        assert!(j.get("traces").unwrap().as_arr().unwrap().is_empty());
    }

    #[test]
    fn batch_body_parses_in_order_and_is_bounded() {
        let shape = [1usize, 4, 4];
        // a batch of seed bodies parses to the same tensors, in order
        let body = br#"{"batch":[{"seed":3},{"seed":7},{"seed":3}]}"#;
        match parse_infer_body(body, shape).unwrap() {
            InferRequest::Batch(images) => {
                assert_eq!(images.len(), 3);
                for (img, seed) in images.iter().zip([3u64, 7, 3]) {
                    assert_eq!(*img, Tensor::randn(&shape, &mut Pcg32::new(seed), 1.0));
                }
                assert_eq!(images[0], images[2], "same seed, same image");
            }
            other => panic!("expected a batch, got {other:?}"),
        }
        // a single-image body still parses as Single through the same entry
        assert!(matches!(
            parse_infer_body(b"{\"seed\": 1}", shape).unwrap(),
            InferRequest::Single(_)
        ));
        // and parse_infer_request refuses a batch body outright
        assert!(parse_infer_request(body, shape).is_err());
        // malformed batches: not an array, empty, bad element (named by
        // index), oversized
        assert!(parse_infer_body(br#"{"batch": 3}"#, shape).is_err());
        assert!(parse_infer_body(br#"{"batch": []}"#, shape).is_err());
        let e = parse_infer_body(br#"{"batch":[{"seed":1},{}]}"#, shape).unwrap_err();
        assert!(e.to_string().contains("batch image 1"), "{e}");
        let huge = format!(
            "{{\"batch\":[{}]}}",
            vec!["{\"seed\":1}"; MAX_BATCH_REQUESTS + 1].join(",")
        );
        assert!(parse_infer_body(huge.as_bytes(), shape).is_err());
    }

    #[test]
    fn model_spec_parses_admin_bodies() {
        // empty body: serve the preset named like the model, all defaults
        let spec = parse_model_spec(b"", "resnet18").unwrap();
        assert_eq!(spec.preset, "resnet18");
        assert_eq!(spec.alpha, 0);
        assert_eq!(spec.engine.dtype, None);

        let body = br#"{"preset":"vgg16-cifar","alpha":4,"workers":2,"max_batch":8,
            "wait_ms":2,"scheduler":"lowest-index","dtype":"f64","plane":"half",
            "max_inflight":16,"seed":11,"arena_reuse":false}"#;
        let spec = parse_model_spec(body, "demo").unwrap();
        assert_eq!(spec.preset, "vgg16-cifar");
        assert_eq!(spec.alpha, 4);
        assert_eq!(spec.workers, 2);
        assert_eq!(spec.seed, 11);
        assert_eq!(spec.batcher.max_batch, 8);
        assert_eq!(spec.batcher.max_wait, Duration::from_millis(2));
        assert_eq!(spec.max_inflight, 16);
        assert_eq!(spec.engine.scheduler.label(), "lowest-index");
        assert_eq!(spec.engine.dtype, Some(Dtype::F64));
        assert_eq!(spec.engine.plane, Plane::Half);
        assert!(!spec.engine.arena_reuse);

        // "observe" rides the same builder path as the other engine knobs
        let spec = parse_model_spec(br#"{"observe":false}"#, "m").unwrap();
        assert!(!spec.engine.observe);
        assert!(parse_model_spec(br#"{"observe":1}"#, "m").is_err());
        assert!(parse_model_spec(b"", "m").unwrap().engine.observe, "observation defaults on");

        // unknown keys are rejected (typo'd admin knobs must not be ignored)
        assert!(parse_model_spec(br#"{"workrs":2}"#, "m").is_err());
        // wrong types / bad labels / non-object bodies
        assert!(parse_model_spec(br#"{"alpha":"four"}"#, "m").is_err());
        assert!(parse_model_spec(br#"{"dtype":"f16"}"#, "m").is_err());
        assert!(parse_model_spec(br#"{"scheduler":"magic"}"#, "m").is_err());
        assert!(parse_model_spec(br#"[1,2]"#, "m").is_err());
        assert!(parse_model_spec(br#"{"preset":""}"#, "m").is_err());
        // empty dtype string defers to the manifest, like --dtype unset
        let spec = parse_model_spec(br#"{"dtype":""}"#, "m").unwrap();
        assert_eq!(spec.engine.dtype, None);
    }

    #[test]
    fn error_schema_is_structured() {
        let body = error_body("not_found", "no such model", Some("resnet18"));
        let j = Json::parse(&body).unwrap();
        let e = j.get("error").unwrap();
        assert_eq!(e.get("code").unwrap().as_str(), Some("not_found"));
        assert_eq!(e.get("message").unwrap().as_str(), Some("no such model"));
        assert_eq!(e.get("model").unwrap().as_str(), Some("resnet18"));
        // pre-routing errors carry a null model, not a missing key
        let j = Json::parse(&error_body("bad_request", "bad json", None)).unwrap();
        assert_eq!(j.get("error").unwrap().get("model"), Some(&Json::Null));
        assert_eq!(code_for_status(429), "overloaded");
        assert_eq!(code_for_status(404), "not_found");
        assert_eq!(code_for_status(500), "internal");
    }

    #[test]
    fn models_listing_serializes_status_rows() {
        let rows = vec![
            ModelStatus {
                name: "vgg16-cifar".into(),
                status: "serving",
                generation: 2,
                preset: Some("vgg16-cifar".into()),
                alpha: Some(4),
                workers: Some(2),
                max_inflight: Some(64),
                error: None,
            },
            ModelStatus {
                name: "resnet18".into(),
                status: "loading",
                generation: 0,
                preset: None,
                alpha: None,
                workers: None,
                max_inflight: None,
                error: None,
            },
        ];
        let j = models_to_json(&rows, "vgg16-cifar");
        assert_eq!(j.get("default_model").unwrap().as_str(), Some("vgg16-cifar"));
        let models = j.get("models").unwrap().as_arr().unwrap();
        assert_eq!(models.len(), 2);
        assert_eq!(models[0].get("status").unwrap().as_str(), Some("serving"));
        assert_eq!(models[0].get("generation").unwrap().as_usize(), Some(2));
        assert_eq!(models[0].get("alpha").unwrap().as_usize(), Some(4));
        assert_eq!(models[1].get("status").unwrap().as_str(), Some("loading"));
        assert_eq!(models[1].get("preset"), Some(&Json::Null));
        assert!(Json::parse(&j.to_string()).is_ok());
    }

    #[test]
    fn model_metrics_carry_admission_and_generation() {
        let mut m = Metrics::new();
        m.record_request(Duration::from_micros(100));
        let pm = PoolMetrics::from_workers(vec![m]);
        let adm = AdmissionMetrics {
            inflight: 1,
            max_inflight: 32,
            admitted: 10,
            rejected: 3,
            generation: 5,
        };
        let j = model_metrics_to_json("resnet18", &adm, &pm, Dtype::F32, Plane::Half);
        assert_eq!(j.get("model").unwrap().as_str(), Some("resnet18"));
        assert_eq!(j.get("generation").unwrap().as_usize(), Some(5));
        let a = j.get("admission").unwrap();
        assert_eq!(a.get("inflight").unwrap().as_usize(), Some(1));
        assert_eq!(a.get("max_inflight").unwrap().as_usize(), Some(32));
        assert_eq!(a.get("admitted").unwrap().as_usize(), Some(10));
        assert_eq!(a.get("rejected").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("merged").unwrap().get("count").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("dtype").unwrap().as_str(), Some("f32"));
    }

    #[test]
    fn batch_reply_wraps_per_image_results_in_order() {
        let mk = |logits: Vec<f32>| Response {
            logits,
            latency: Duration::from_micros(900),
            queue_wait: Duration::from_micros(100),
            execute: Duration::from_micros(800),
            per_image: Duration::from_micros(400),
            batch_size: 2,
            worker: 0,
            pe_utilization: None,
            dtype: Dtype::F32,
            plane: Plane::Full,
        };
        let j = batch_response_to_json(&[mk(vec![1.0, 2.0]), mk(vec![-3.5])]);
        let back = Json::parse(&j.to_string()).unwrap();
        let results = back.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(logits_from_json(&results[0]).unwrap(), vec![1.0, 2.0]);
        assert_eq!(logits_from_json(&results[1]).unwrap(), vec![-3.5]);
        assert_eq!(results[0].get("per_image_us").unwrap().as_f64(), Some(400.0));
    }
}
