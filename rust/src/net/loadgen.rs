//! Open/closed-loop HTTP load generator for the serving front-end.
//!
//! Two canonical load disciplines:
//!
//! * **Closed loop** (fixed concurrency): `c` workers, each holding one
//!   keep-alive connection, issue the next request the moment the previous
//!   reply lands. Measures the server's capacity frontier — throughput at
//!   a given level of concurrency, with coordinated omission by design
//!   (the client waits, like a pool of synchronous callers would).
//! * **Open loop** (fixed arrival rate): requests launch on a fixed
//!   schedule whether or not earlier ones returned, each on its own
//!   connection, and latency is measured **from the scheduled arrival
//!   time** — so server-side queueing during overload shows up in the
//!   tail percentiles instead of being silently absorbed (the
//!   coordinated-omission correction).
//!
//! Every reply is classified as success (200), rejected (429 — the
//! admission gate working as designed), or failed (anything else,
//! including transport errors and timeouts). The report carries a latency
//! histogram with p50/p95/p99 and throughput, and can be recorded into a
//! [`Bench`] so sweeps land in `BENCH_serve.json` next to the other CI
//! bench artifacts.

use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use super::http::{self, HttpConn, HttpLimits};
use crate::coordinator::Metrics;
use crate::err;
use crate::util::bench::{Bench, Measurement};
use crate::util::error::{Context, Result};

/// Load discipline.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LoadMode {
    /// Fixed arrival rate (requests/second), one connection per request.
    Open { rate_hz: f64 },
    /// Fixed concurrency, one keep-alive connection per worker.
    Closed { concurrency: usize },
}

/// Load generator configuration.
#[derive(Debug, Clone)]
pub struct LoadGenConfig {
    /// Target `host:port` of a `serve --http` endpoint.
    pub addr: String,
    pub mode: LoadMode,
    /// Total requests to issue.
    pub requests: usize,
    /// Models to drive through `POST /v1/models/<name>/infer`. Empty hits
    /// the legacy `/infer` alias (the default model); more than one entry
    /// is the mixed-model mode — requests round-robin across the models
    /// and the report carries per-model sub-reports.
    pub models: Vec<String>,
    /// Explicit request body; `None` sends `{"seed":i}` per request —
    /// tiny on the wire, deterministic work on the server.
    pub body: Option<String>,
    /// Per-request reply deadline.
    pub timeout: Duration,
}

impl Default for LoadGenConfig {
    fn default() -> Self {
        LoadGenConfig {
            addr: "127.0.0.1:7878".into(),
            mode: LoadMode::Closed { concurrency: 4 },
            requests: 64,
            models: Vec::new(),
            body: None,
            timeout: Duration::from_secs(30),
        }
    }
}

/// Pick the model (by index) and URL path for request `seq`: round-robin
/// across `models`, or the legacy `/infer` alias when none are named.
fn path_for(models: &[String], seq: usize) -> (usize, String) {
    if models.is_empty() {
        return (0, "/infer".to_string());
    }
    let idx = seq % models.len();
    (idx, format!("/v1/models/{}/infer", models[idx]))
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Outcome {
    Success,
    Rejected,
    Failed,
}

/// Aggregated result of one load run.
#[derive(Debug, Clone, Default)]
pub struct LoadReport {
    pub sent: usize,
    /// 200 replies (latencies below cover exactly these).
    pub ok: usize,
    /// 429 replies — shed by the admission gate, not errors.
    pub rejected: usize,
    /// Transport errors, timeouts, and non-200/429 statuses.
    pub failed: usize,
    pub elapsed: Duration,
    /// Mixed-model runs: one sub-report per model (request order within a
    /// model is preserved). Empty for single-target runs.
    pub per_model: Vec<(String, LoadReport)>,
    latencies_us: Vec<u64>,
}

impl LoadReport {
    fn record(&mut self, outcome: Outcome, latency: Duration) {
        self.sent += 1;
        match outcome {
            Outcome::Success => {
                self.ok += 1;
                self.latencies_us.push(latency.as_micros() as u64);
            }
            Outcome::Rejected => self.rejected += 1,
            Outcome::Failed => self.failed += 1,
        }
    }

    /// Nearest-rank percentile (same definition as `/metrics`, via
    /// [`Metrics::percentile_us`], so the two reports agree).
    pub fn percentile(&self, p: f64) -> Option<Duration> {
        Metrics::percentile_us(&self.latencies_us, p)
    }

    pub fn p50(&self) -> Option<Duration> {
        self.percentile(0.50)
    }

    pub fn p95(&self) -> Option<Duration> {
        self.percentile(0.95)
    }

    pub fn p99(&self) -> Option<Duration> {
        self.percentile(0.99)
    }

    /// Successful replies per second over the whole run.
    pub fn throughput(&self) -> f64 {
        if self.elapsed.is_zero() {
            return 0.0;
        }
        self.ok as f64 / self.elapsed.as_secs_f64()
    }

    pub fn success_rate(&self) -> f64 {
        self.ok as f64 / self.sent.max(1) as f64
    }

    /// Doubling-width latency buckets `(upper_bound, count)` covering every
    /// successful sample (first bound 256 µs).
    pub fn histogram(&self) -> Vec<(Duration, usize)> {
        if self.latencies_us.is_empty() {
            return Vec::new();
        }
        let max = *self.latencies_us.iter().max().unwrap();
        let mut bounds = vec![256u64];
        while *bounds.last().unwrap() < max {
            let next = bounds.last().unwrap() * 2;
            bounds.push(next);
        }
        let mut counts = vec![0usize; bounds.len()];
        for &us in &self.latencies_us {
            let i = bounds.iter().position(|&b| us <= b).unwrap();
            counts[i] += 1;
        }
        bounds
            .into_iter()
            .map(Duration::from_micros)
            .zip(counts)
            .collect()
    }

    /// Human-readable summary: outcome counts, percentiles, throughput,
    /// and the histogram.
    pub fn report(&self) -> String {
        let mut out = format!(
            "loadgen: {} sent in {:?} → {} ok, {} rejected (429), {} failed | {:.1} req/s\n",
            self.sent,
            self.elapsed,
            self.ok,
            self.rejected,
            self.failed,
            self.throughput(),
        );
        if let (Some(p50), Some(p95), Some(p99)) = (self.p50(), self.p95(), self.p99()) {
            out.push_str(&format!("latency: p50={p50:?} p95={p95:?} p99={p99:?}\n"));
        }
        for (model, sub) in &self.per_model {
            out.push_str(&format!(
                "  {model}: {} ok, {} rejected, {} failed",
                sub.ok, sub.rejected, sub.failed
            ));
            if let (Some(p50), Some(p99)) = (sub.p50(), sub.p99()) {
                out.push_str(&format!(" | p50={p50:?} p99={p99:?}"));
            }
            out.push('\n');
        }
        for (bound, count) in self.histogram() {
            if count > 0 {
                out.push_str(&format!("  ≤{bound:>9?} {count:>6}  {}\n", "#".repeat(count.min(60))));
            }
        }
        out
    }

    /// Record this run into a [`Bench`] (two entries: the latency
    /// distribution with p50 as the median, and a `<name>_p99` tail entry)
    /// so sweeps serialize through the standard `BENCH_*.json` artifact.
    pub fn record_into(&self, b: &mut Bench, name: &str) {
        if self.latencies_us.is_empty() {
            return;
        }
        let to_ns = |us: u64| us as f64 * 1e3;
        let mean_us =
            self.latencies_us.iter().sum::<u64>() as f64 / self.latencies_us.len() as f64;
        let var = self
            .latencies_us
            .iter()
            .map(|&us| (us as f64 - mean_us) * (us as f64 - mean_us))
            .sum::<f64>()
            / self.latencies_us.len() as f64;
        b.push(Measurement {
            name: name.to_string(),
            iters: self.ok,
            mean_ns: mean_us * 1e3,
            stddev_ns: var.sqrt() * 1e3,
            median_ns: to_ns(self.p50().unwrap().as_micros() as u64),
            p10_ns: to_ns(self.percentile(0.10).unwrap().as_micros() as u64),
            p90_ns: to_ns(self.percentile(0.90).unwrap().as_micros() as u64),
        });
        let p99 = to_ns(self.p99().unwrap().as_micros() as u64);
        b.push(Measurement {
            name: format!("{name}_p99"),
            iters: self.ok,
            mean_ns: p99,
            stddev_ns: 0.0,
            median_ns: p99,
            p10_ns: p99,
            p90_ns: p99,
        });
    }
}

/// One worker's connection state (closed loop reuses it across requests).
type Conn = (HttpConn<TcpStream>, TcpStream);

fn connect(addr: &SocketAddr, timeout: Duration) -> Result<Conn> {
    let stream = TcpStream::connect_timeout(addr, timeout)
        .with_context(|| format!("connecting {addr}"))?;
    let _ = stream.set_nodelay(true);
    let writer = stream.try_clone().context("cloning stream")?;
    Ok((HttpConn::new(stream), writer))
}

/// Issue one request, reusing `conn` when possible. A *reused* keep-alive
/// connection may have been closed by the server between requests (its
/// per-connection request cap, or the idle deadline) — that is not a
/// server failure, so a transport error on a reused connection retries
/// exactly once on a fresh one. Timeouts never retry (the request may
/// still be executing server-side; a retry would double the work).
fn issue(
    conn: &mut Option<Conn>,
    addr: &SocketAddr,
    host: &str,
    path: &str,
    body: &[u8],
    timeout: Duration,
) -> Outcome {
    let reused = conn.is_some();
    match issue_once(conn, addr, host, path, body, timeout) {
        Some(outcome) => outcome,
        None if reused => {
            issue_once(conn, addr, host, path, body, timeout).unwrap_or(Outcome::Failed)
        }
        None => Outcome::Failed,
    }
}

/// One attempt: `Some(outcome)` is final, `None` means the transport died
/// and the caller may retry on a fresh connection.
fn issue_once(
    conn: &mut Option<Conn>,
    addr: &SocketAddr,
    host: &str,
    path: &str,
    body: &[u8],
    timeout: Duration,
) -> Option<Outcome> {
    use std::io::Write;
    if conn.is_none() {
        match connect(addr, timeout) {
            Ok(c) => *conn = Some(c),
            Err(_) => return Some(Outcome::Failed),
        }
    }
    let (reader, writer) = conn.as_mut().unwrap();
    let wire = http::format_request("POST", path, host, body);
    if writer.write_all(&wire).is_err() {
        *conn = None;
        return None;
    }
    let limits = HttpLimits { read_timeout: timeout, ..HttpLimits::default() };
    match reader.read_response(&limits) {
        Ok((200, _)) => Some(Outcome::Success),
        Ok((429, _)) => Some(Outcome::Rejected),
        Ok((_, _)) => Some(Outcome::Failed),
        Err(e) => {
            *conn = None;
            if e.is_timeout() {
                Some(Outcome::Failed)
            } else {
                None
            }
        }
    }
}

fn body_for(cfg: &LoadGenConfig, seq: usize) -> Vec<u8> {
    match &cfg.body {
        Some(b) => b.clone().into_bytes(),
        None => format!("{{\"seed\":{seq}}}").into_bytes(),
    }
}

fn resolve(addr: &str) -> Result<SocketAddr> {
    addr.to_socket_addrs()
        .with_context(|| format!("resolving {addr}"))?
        .next()
        .ok_or_else(|| err!("{addr} resolves to no address"))
}

/// Run the configured load and aggregate the report.
pub fn run(cfg: &LoadGenConfig) -> Result<LoadReport> {
    if cfg.requests == 0 {
        return Err(err!("--requests must be at least 1"));
    }
    let addr = resolve(&cfg.addr)?;
    match cfg.mode {
        LoadMode::Closed { concurrency } => run_closed(cfg, addr, concurrency.max(1)),
        LoadMode::Open { rate_hz } => run_open(cfg, addr, rate_hz),
    }
}

fn run_closed(cfg: &LoadGenConfig, addr: SocketAddr, concurrency: usize) -> Result<LoadReport> {
    let (tx, rx) = mpsc::channel::<(usize, Outcome, Duration)>();
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for w in 0..concurrency {
            let n = cfg.requests / concurrency
                + usize::from(w < cfg.requests % concurrency);
            let tx = tx.clone();
            s.spawn(move || {
                let mut conn: Option<Conn> = None;
                for i in 0..n {
                    let seq = w * cfg.requests + i;
                    let body = body_for(cfg, seq);
                    let (model, path) = path_for(&cfg.models, seq);
                    let start = Instant::now();
                    let outcome =
                        issue(&mut conn, &addr, &cfg.addr, &path, &body, cfg.timeout);
                    let _ = tx.send((model, outcome, start.elapsed()));
                }
            });
        }
    });
    drop(tx);
    Ok(collect(cfg, rx, t0))
}

/// Drain the outcome channel into the overall report plus (for mixed-model
/// runs) the per-model sub-reports.
fn collect(
    cfg: &LoadGenConfig,
    rx: mpsc::Receiver<(usize, Outcome, Duration)>,
    t0: Instant,
) -> LoadReport {
    let mut report = LoadReport::default();
    let mut per_model: Vec<LoadReport> =
        cfg.models.iter().map(|_| LoadReport::default()).collect();
    for (model, outcome, latency) in rx {
        report.record(outcome, latency);
        if let Some(sub) = per_model.get_mut(model) {
            sub.record(outcome, latency);
        }
    }
    let elapsed = t0.elapsed();
    report.elapsed = elapsed;
    if cfg.models.len() > 1 {
        for sub in &mut per_model {
            sub.elapsed = elapsed;
        }
        report.per_model = cfg.models.iter().cloned().zip(per_model).collect();
    }
    report
}

fn run_open(cfg: &LoadGenConfig, addr: SocketAddr, rate_hz: f64) -> Result<LoadReport> {
    if !rate_hz.is_finite() || rate_hz <= 0.0 {
        return Err(err!("--rate must be positive, got {rate_hz}"));
    }
    // each request is its own thread + connection; cap the fleet
    if cfg.requests > 4096 {
        return Err(err!("open-loop runs are capped at 4096 requests, got {}", cfg.requests));
    }
    let interval = Duration::from_secs_f64(1.0 / rate_hz);
    let (tx, rx) = mpsc::channel::<(usize, Outcome, Duration)>();
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for i in 0..cfg.requests {
            let scheduled = t0 + interval.mul_f64(i as f64);
            if let Some(wait) = scheduled.checked_duration_since(Instant::now()) {
                std::thread::sleep(wait);
            }
            let tx = tx.clone();
            s.spawn(move || {
                let mut conn: Option<Conn> = None;
                let body = body_for(cfg, i);
                let (model, path) = path_for(&cfg.models, i);
                let outcome = issue(&mut conn, &addr, &cfg.addr, &path, &body, cfg.timeout);
                // latency counts from the *scheduled* arrival: launch slip
                // and server queueing both land in the tail, by design
                let _ = tx.send((model, outcome, scheduled.elapsed()));
            });
        }
    });
    drop(tx);
    Ok(collect(cfg, rx, t0))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_percentiles_and_counts() {
        let mut r = LoadReport::default();
        for us in [100u64, 200, 300, 400, 1000] {
            r.record(Outcome::Success, Duration::from_micros(us));
        }
        r.record(Outcome::Rejected, Duration::ZERO);
        r.record(Outcome::Failed, Duration::ZERO);
        r.elapsed = Duration::from_secs(1);
        assert_eq!(r.sent, 7);
        assert_eq!(r.ok, 5);
        assert_eq!(r.rejected, 1);
        assert_eq!(r.failed, 1);
        assert_eq!(r.p50().unwrap(), Duration::from_micros(300));
        assert!(r.p95().unwrap() <= r.p99().unwrap());
        assert!((r.throughput() - 5.0).abs() < 1e-9);
        assert!((r.success_rate() - 5.0 / 7.0).abs() < 1e-12);
        let text = r.report();
        assert!(text.contains("p50="));
        assert!(text.contains("rejected"));
    }

    #[test]
    fn histogram_covers_all_samples() {
        let mut r = LoadReport::default();
        for us in [50u64, 300, 5000, 100_000] {
            r.record(Outcome::Success, Duration::from_micros(us));
        }
        let hist = r.histogram();
        assert_eq!(hist.iter().map(|(_, c)| c).sum::<usize>(), 4);
        // bounds double, and the last bound covers the max sample
        assert!(hist.last().unwrap().0 >= Duration::from_micros(100_000));
        for pair in hist.windows(2) {
            assert_eq!(pair[1].0, pair[0].0 * 2);
        }
    }

    #[test]
    fn record_into_bench_emits_distribution_and_tail() {
        let mut r = LoadReport::default();
        for us in 1..=100u64 {
            r.record(Outcome::Success, Duration::from_micros(us * 10));
        }
        r.elapsed = Duration::from_millis(10);
        let mut b = Bench::quick();
        r.record_into(&mut b, "serve/http_test");
        assert_eq!(b.results().len(), 2);
        assert_eq!(b.results()[0].name, "serve/http_test");
        assert_eq!(b.results()[1].name, "serve/http_test_p99");
        assert!(b.results()[0].median_ns <= b.results()[1].median_ns);
        // empty reports record nothing rather than zeros
        let empty = LoadReport::default();
        empty.record_into(&mut b, "serve/none");
        assert_eq!(b.results().len(), 2);
    }

    #[test]
    fn unreachable_target_fails_cleanly() {
        // a closed port: every request fails, nothing hangs or panics
        let cfg = LoadGenConfig {
            addr: "127.0.0.1:9".into(),
            mode: LoadMode::Closed { concurrency: 2 },
            requests: 4,
            timeout: Duration::from_millis(300),
            ..LoadGenConfig::default()
        };
        let r = run(&cfg).unwrap();
        assert_eq!(r.sent, 4);
        assert_eq!(r.ok, 0);
        assert_eq!(r.failed, 4);
    }

    #[test]
    fn path_round_robins_across_models() {
        // no models: the legacy alias
        assert_eq!(path_for(&[], 0), (0, "/infer".to_string()));
        assert_eq!(path_for(&[], 7), (0, "/infer".to_string()));
        // one model: every request pinned to its /v1 route
        let one = vec!["resnet18".to_string()];
        assert_eq!(path_for(&one, 5), (0, "/v1/models/resnet18/infer".to_string()));
        // mixed: strict round-robin by sequence number
        let two = vec!["a".to_string(), "b".to_string()];
        assert_eq!(path_for(&two, 0).1, "/v1/models/a/infer");
        assert_eq!(path_for(&two, 1).1, "/v1/models/b/infer");
        assert_eq!(path_for(&two, 2).1, "/v1/models/a/infer");
        assert_eq!(path_for(&two, 3).0, 1);
    }

    #[test]
    fn mixed_model_run_reports_per_model() {
        // unreachable target: outcomes are failures, but the per-model
        // accounting still splits the traffic
        let cfg = LoadGenConfig {
            addr: "127.0.0.1:9".into(),
            mode: LoadMode::Closed { concurrency: 2 },
            requests: 6,
            models: vec!["a".to_string(), "b".to_string()],
            timeout: Duration::from_millis(300),
            ..LoadGenConfig::default()
        };
        let r = run(&cfg).unwrap();
        assert_eq!(r.sent, 6);
        assert_eq!(r.failed, 6);
        assert_eq!(r.per_model.len(), 2);
        assert_eq!(r.per_model[0].0, "a");
        assert_eq!(r.per_model[1].0, "b");
        let split: usize = r.per_model.iter().map(|(_, s)| s.sent).sum();
        assert_eq!(split, 6, "every request lands in exactly one sub-report");
        // single-model runs don't carry redundant sub-reports
        let cfg = LoadGenConfig {
            models: vec!["a".to_string()],
            requests: 2,
            addr: "127.0.0.1:9".into(),
            timeout: Duration::from_millis(300),
            ..LoadGenConfig::default()
        };
        assert!(run(&cfg).unwrap().per_model.is_empty());
    }

    #[test]
    fn zero_requests_and_bad_rate_are_errors() {
        let mut cfg = LoadGenConfig { requests: 0, ..LoadGenConfig::default() };
        assert!(run(&cfg).is_err());
        cfg.requests = 1;
        cfg.mode = LoadMode::Open { rate_hz: 0.0 };
        assert!(run(&cfg).is_err());
    }
}
