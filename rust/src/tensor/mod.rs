//! Dense f32 tensors + complex plane pairs.
//!
//! Deliberately small: row-major contiguous storage, shape-checked views,
//! and exactly the ops the coordinator's CPU path needs (the heavy math
//! lives in the AOT'd XLA executables). Complex data is carried as separate
//! re/im planes — the same convention the AOT boundary uses.

use std::fmt;

use crate::util::rng::Pcg32;

/// Row-major dense f32 tensor.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}", self.shape)?;
        if self.data.len() <= 8 {
            write!(f, " {:?}", self.data)?;
        }
        Ok(())
    }
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} incompatible with {} elements",
            data.len()
        );
        Tensor { shape: shape.to_vec(), data }
    }

    /// Standard-normal init scaled by `scale` (weight generation).
    pub fn randn(shape: &[usize], rng: &mut Pcg32, scale: f32) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: rng.normal_vec(n, scale) }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Reinterpret with a new shape of equal element count.
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            self.data.len(),
            "reshape {:?} -> {shape:?} changes element count",
            self.shape
        );
        self.shape = shape.to_vec();
        self
    }

    #[inline]
    fn offset(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.shape.len());
        let mut off = 0;
        for (i, (&ix, &dim)) in idx.iter().zip(&self.shape).enumerate() {
            debug_assert!(ix < dim, "index {ix} out of bounds for dim {i} (size {dim})");
            off = off * dim + ix;
        }
        off
    }

    #[inline]
    pub fn at(&self, idx: &[usize]) -> f32 {
        self.data[self.offset(idx)]
    }

    #[inline]
    pub fn set(&mut self, idx: &[usize], v: f32) {
        let o = self.offset(idx);
        self.data[o] = v;
    }

    #[inline]
    pub fn add_at(&mut self, idx: &[usize], v: f32) {
        let o = self.offset(idx);
        self.data[o] += v;
    }

    pub fn map(mut self, f: impl Fn(f32) -> f32) -> Self {
        for v in &mut self.data {
            *v = f(*v);
        }
        self
    }

    pub fn scale(self, s: f32) -> Self {
        self.map(|v| v * s)
    }

    pub fn add(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape, "add shape mismatch");
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Tensor { shape: self.shape.clone(), data }
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// Complex tensor as separate re/im planes (the AOT boundary convention).
#[derive(Debug, Clone, PartialEq)]
pub struct ComplexTensor {
    pub re: Tensor,
    pub im: Tensor,
}

impl ComplexTensor {
    pub fn zeros(shape: &[usize]) -> Self {
        ComplexTensor { re: Tensor::zeros(shape), im: Tensor::zeros(shape) }
    }

    pub fn from_real(re: Tensor) -> Self {
        let im = Tensor::zeros(re.shape());
        ComplexTensor { re, im }
    }

    pub fn shape(&self) -> &[usize] {
        self.re.shape()
    }

    pub fn len(&self) -> usize {
        self.re.len()
    }

    pub fn is_empty(&self) -> bool {
        self.re.is_empty()
    }

    #[inline]
    pub fn at(&self, idx: &[usize]) -> (f32, f32) {
        (self.re.at(idx), self.im.at(idx))
    }

    #[inline]
    pub fn set(&mut self, idx: &[usize], re: f32, im: f32) {
        self.re.set(idx, re);
        self.im.set(idx, im);
    }

    /// Pointwise complex multiply: (a+bi)(c+di).
    pub fn hadamard(&self, other: &ComplexTensor) -> ComplexTensor {
        assert_eq!(self.shape(), other.shape(), "hadamard shape mismatch");
        let n = self.len();
        let mut re = vec![0.0f32; n];
        let mut im = vec![0.0f32; n];
        let (ar, ai) = (self.re.data(), self.im.data());
        let (br, bi) = (other.re.data(), other.im.data());
        for i in 0..n {
            re[i] = ar[i] * br[i] - ai[i] * bi[i];
            im[i] = ar[i] * bi[i] + ai[i] * br[i];
        }
        ComplexTensor {
            re: Tensor::from_vec(self.shape(), re),
            im: Tensor::from_vec(self.shape(), im),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_row_major() {
        let t = Tensor::from_vec(&[2, 3], (0..6).map(|i| i as f32).collect());
        assert_eq!(t.at(&[0, 0]), 0.0);
        assert_eq!(t.at(&[0, 2]), 2.0);
        assert_eq!(t.at(&[1, 0]), 3.0);
        assert_eq!(t.at(&[1, 2]), 5.0);
    }

    #[test]
    fn set_and_add_at() {
        let mut t = Tensor::zeros(&[2, 2]);
        t.set(&[1, 1], 3.0);
        t.add_at(&[1, 1], 1.5);
        assert_eq!(t.at(&[1, 1]), 4.5);
    }

    #[test]
    #[should_panic(expected = "changes element count")]
    fn reshape_guards_count() {
        Tensor::zeros(&[2, 3]).reshape(&[7]);
    }

    #[test]
    fn randn_deterministic() {
        let mut r1 = Pcg32::new(5);
        let mut r2 = Pcg32::new(5);
        let a = Tensor::randn(&[4, 4], &mut r1, 0.1);
        let b = Tensor::randn(&[4, 4], &mut r2, 0.1);
        assert_eq!(a, b);
    }

    #[test]
    fn complex_hadamard_matches_formula() {
        // (1+2i)(3+4i) = -5 + 10i
        let a = ComplexTensor {
            re: Tensor::from_vec(&[1], vec![1.0]),
            im: Tensor::from_vec(&[1], vec![2.0]),
        };
        let b = ComplexTensor {
            re: Tensor::from_vec(&[1], vec![3.0]),
            im: Tensor::from_vec(&[1], vec![4.0]),
        };
        let c = a.hadamard(&b);
        assert_eq!(c.at(&[0]), (-5.0, 10.0));
    }

    #[test]
    fn add_and_diff() {
        let a = Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0]);
        let b = Tensor::from_vec(&[3], vec![0.5, 2.0, 2.0]);
        assert_eq!(a.add(&b).data(), &[1.5, 4.0, 5.0]);
        assert_eq!(a.max_abs_diff(&b), 1.0);
    }
}
