//! `spectral-flow` CLI: the leader entrypoint.
//!
//! Subcommands map 1:1 to the paper's evaluation artifacts (DESIGN.md
//! "Per-experiment index"); the benches regenerate the same tables with
//! timing, the CLI is for interactive exploration.

use spectral_flow::analysis::{
    transfers_flow, ArchParams, Flow, LayerParams,
};
use spectral_flow::coordinator::{EngineOptions, InferenceEngine, WeightMode};
use spectral_flow::dataflow::{optimize_network_at, OptimizerConfig};
use spectral_flow::err;
use spectral_flow::model::Network;
use spectral_flow::report::{fmt_bytes, fmt_gbps, fmt_ms, fmt_pct, Table};
use spectral_flow::runtime::{BackendKind, Dtype, Plane};
use spectral_flow::schedule::{sampled_layer_utilization, SchedulePolicy, Scheduler};
use spectral_flow::util::bench::{compare_benches, read_json_artifact};
use spectral_flow::sim::baselines::{run_baseline, sparse_spatial_17_latency, BaselineConfig};
use spectral_flow::sim::{estimate_resources, SimConfig};
use spectral_flow::sparse::prune_magnitude;
use spectral_flow::util::cli::Args;
use spectral_flow::util::error::Result;
use spectral_flow::util::rng::Pcg32;

/// Parse `--backend` into a [`BackendKind`], with a clear error when the
/// binary was built without the `pjrt` feature. `threads` is the interp
/// backend's per-tile thread count (`--backend-threads`; ignored by pjrt).
fn parse_backend(name: &str, threads: usize) -> Result<BackendKind> {
    match name {
        "interp" => Ok(BackendKind::Interp { threads }),
        #[cfg(feature = "pjrt")]
        "pjrt" => Ok(BackendKind::Pjrt),
        #[cfg(not(feature = "pjrt"))]
        "pjrt" => Err(err!(
            "this binary was built without the `pjrt` feature; \
             rebuild with `cargo build --features pjrt` (see README.md)"
        )),
        other => Err(err!("unknown backend {other:?} (expected interp|pjrt)")),
    }
}

/// Parse `--dtype` with the manifest-default sentinel: the empty string
/// (the flag's default) means "use the manifest's recorded dtype", the
/// same contract as `--alpha 0`.
fn parse_dtype(name: &str) -> Result<Option<Dtype>> {
    if name.is_empty() {
        Ok(None)
    } else {
        Dtype::parse(name).map(Some)
    }
}

const ABOUT: &str = "spectral-flow — flexible-dataflow sparse spectral CNN accelerator \
(FPGA '20 reproduction)\n\n\
Usage: spectral-flow <analyze|optimize|schedule|simulate|infer|serve|loadgen|bench-check> \
[--help]";

fn main() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.positional().first().cloned().unwrap_or_default();
    match cmd.as_str() {
        "analyze" => analyze(args),
        "optimize" => optimize(args),
        "schedule" => schedule(args),
        "simulate" => simulate(args),
        "infer" => infer(args),
        "serve" => serve(args),
        "loadgen" => loadgen(args),
        "bench-check" => bench_check(args),
        _ => {
            args.maybe_help(ABOUT);
            println!("{ABOUT}");
            Ok(())
        }
    }
}

/// Fig. 2: per-layer transfer volume + BRAMs for the three fixed flows.
fn analyze(mut args: Args) -> Result<()> {
    let alpha = args.opt_usize("alpha", 4, "compression ratio");
    args.maybe_help("analyze: Fig 2 complexity (data transfers + BRAMs per flow)");
    let net = Network::vgg16_224();
    let arch = ArchParams::paper();
    let mut t = Table::new(
        &format!("Fig 2 — VGG16 K=8 α={alpha}: transfers (MB) / BRAMs per flow"),
        &["layer", "xfer#1", "xfer#2", "xfer#3", "bram#1", "bram#2", "bram#3"],
    );
    for conv in net.optimized_convs() {
        let l = LayerParams::from_layer(conv, alpha);
        let xf: Vec<String> = Flow::ALL
            .iter()
            .map(|f| format!("{:.1}", transfers_flow(*f, &l, &arch).total() as f64 * 2.0 / 1e6))
            .collect();
        let br: Vec<String> = Flow::ALL
            .iter()
            .map(|f| spectral_flow::analysis::bram_flow(*f, &l, &arch).to_string())
            .collect();
        t.row(vec![
            conv.name.clone(),
            xf[0].clone(),
            xf[1].clone(),
            xf[2].clone(),
            br[0].clone(),
            br[1].clone(),
            br[2].clone(),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

/// Tables 1 + 2: Alg. 1 optimum at the paper's architecture point.
fn optimize(mut args: Args) -> Result<()> {
    let alpha = args.opt_usize("alpha", 4, "compression ratio");
    let tau_ms = args.opt_f64("tau-ms", 20.0, "total conv latency budget");
    args.maybe_help("optimize: Alg 1 → Table 1 (streaming params) + Table 2 (bandwidth)");
    let net = Network::vgg16_224();
    let cfg = OptimizerConfig {
        alpha,
        total_latency: tau_ms / 1e3,
        ..OptimizerConfig::paper()
    };
    let plan = optimize_network_at(&net, ArchParams::paper(), &cfg)
        .ok_or_else(|| err!("no feasible plan"))?;
    let mut t = Table::new(
        &format!("Tables 1+2 — VGG16 K=8 α={alpha}, P'=9 N'=64, τ={tau_ms} ms"),
        &["layer", "Ps", "Ns", "BRAMs", "transfers", "τ_i", "BW"],
    );
    for lp in &plan.layers {
        t.row(vec![
            lp.layer_name.clone(),
            lp.stream.ps.to_string(),
            lp.stream.ns.to_string(),
            lp.brams.to_string(),
            fmt_bytes(lp.transfers.total() * 2),
            fmt_ms(lp.tau),
            fmt_gbps(lp.bandwidth),
        ]);
    }
    println!("{}", t.render());
    println!("max bandwidth: {}", fmt_gbps(plan.bw_max));
    Ok(())
}

/// Fig. 8-style: per-layer PE utilization for the three schedulers.
fn schedule(mut args: Args) -> Result<()> {
    let replicas = args.opt_usize("replicas", 8, "input-tile replicas r");
    let alpha = args.opt_usize("alpha", 4, "compression ratio");
    let samples = args.opt_usize("samples", 16, "scheduling instances per layer");
    args.maybe_help("schedule: Fig 8 PE utilization per layer and scheduler");
    let net = Network::vgg16_224();
    let n_par = 64;
    let mut t = Table::new(
        &format!("Fig 8 — PE utilization, r={replicas}, N'={n_par}, α={alpha}"),
        &["layer", "exact-cover", "lowest-index", "random"],
    );
    let mut rng = Pcg32::new(2020);
    for conv in net.optimized_convs() {
        let sparse = prune_magnitude(conv.cout, conv.cin, conv.fft, alpha, &mut rng);
        let mut cells = vec![conv.name.clone()];
        for sch in Scheduler::ALL {
            cells.push(fmt_pct(sampled_layer_utilization(
                &sparse, sch, n_par, replicas, samples, 1,
            )));
        }
        t.row(cells);
    }
    println!("{}", t.render());
    Ok(())
}

/// CI's bench-regression gate: compare a fresh `BENCH_*.json` against the
/// committed baseline by median latency and fail on regressions.
fn bench_check(mut args: Args) -> Result<()> {
    let baseline = args.opt(
        "baseline",
        "rust/benches/baseline/BENCH_e2e.json",
        "committed baseline artifact",
    );
    let current = args.opt("current", "rust/reports/BENCH_e2e.json", "freshly generated artifact");
    let threshold_pct = args.opt_f64("threshold-pct", 25.0, "max allowed median regression");
    let min_us = args.opt_f64("min-us", 50.0, "ignore benches with baseline median below this");
    let absolute = args.opt_bool(
        "absolute",
        "compare raw medians (same-host); default divides out the host-speed factor",
    );
    let strict = args.opt_bool("strict", "enforce the gate even on a desk-estimate baseline");
    let update = args.opt_bool(
        "update-baseline",
        "rewrite --baseline from --current with provenance=measured (arms the gate)",
    );
    args.maybe_help("bench-check: fail when current bench medians regress vs the baseline");
    if update {
        // refresh path: the freshly generated artifact becomes the new
        // measured baseline — run the bench twice on a quiet machine first
        // (README "Bench-regression gate")
        let cur = read_json_artifact(&current)?;
        if cur.results.is_empty() {
            return Err(err!("{current} has no measurements — run the bench first"));
        }
        spectral_flow::util::bench::write_measured_baseline(
            &baseline,
            &cur.results,
            &format!(
                "Refreshed via `spectral-flow bench-check --update-baseline` from {current}. \
                 Quick-mode medians; the regression gate is armed (README \
                 \"Bench-regression gate\")."
            ),
        )?;
        println!(
            "baseline {baseline} refreshed from {current}: {} benches, provenance=measured — \
             the bench-regression gate is now armed",
            cur.results.len()
        );
        return Ok(());
    }
    let base = read_json_artifact(&baseline)?;
    let cur = read_json_artifact(&current)?;
    let cmp = compare_benches(
        &base.results,
        &cur.results,
        threshold_pct / 100.0,
        min_us * 1e3,
        !absolute,
    );
    print!("{}", cmp.report());
    if cmp.rows.is_empty() {
        return Err(err!("no comparable benches between {baseline} and {current}"));
    }
    let regs = cmp.regressions();
    if regs.is_empty() {
        println!("bench-check OK");
        return Ok(());
    }
    if !base.is_measured() && !strict {
        // the committed baseline is a desk estimate: report, don't gate —
        // refresh it from a real run (README "Bench-regression gate") to arm
        println!(
            "bench-check: {} regression(s) vs a desk-estimate baseline — warning only; \
             refresh the baseline to arm the gate",
            regs.len()
        );
        return Ok(());
    }
    Err(err!(
        "{} bench(es) regressed more than {threshold_pct}% vs {baseline}",
        regs.len()
    ))
}

/// Table 3: device-comparison rows via the cycle simulator.
fn simulate(mut args: Args) -> Result<()> {
    let samples = args.opt_usize("samples", 24, "scheduling instances per layer");
    let resources = args.opt_bool("resources", "print the Fig 11 resource table");
    args.maybe_help("simulate: Table 3 comparison via the cycle-level simulator");
    let net = Network::vgg16_224();
    let mut t = Table::new(
        "Table 3 — simulated on the U200 model (VGG16-224 conv stack)",
        &["design", "latency", "fps", "BW req", "avg PE util"],
    );
    for cfg in BaselineConfig::all() {
        let res = run_baseline(&cfg, &net, Some(samples), 2020);
        t.row(vec![
            cfg.name.to_string(),
            fmt_ms(res.latency_secs()),
            format!("{:.0}", res.throughput_fps()),
            fmt_gbps(res.required_bandwidth()),
            fmt_pct(res.avg_pe_utilization()),
        ]);
    }
    t.row(vec![
        "[17]-like (sparse spatial)".into(),
        fmt_ms(sparse_spatial_17_latency(&net, 4)),
        "-".into(),
        "-".into(),
        "-".into(),
    ]);
    println!("{}", t.render());
    if resources {
        let cfgp = OptimizerConfig::paper();
        let plan = optimize_network_at(&net, ArchParams::paper(), &cfgp).unwrap();
        let plans: Vec<_> = plan.layers.iter().map(|l| (l.params, l.stream)).collect();
        let r = estimate_resources(&ArchParams::paper(), &plans, SimConfig::default().fft_butterflies_per_cycle);
        println!("Fig 11 resource estimate: {}", r.utilization_report());
    }
    Ok(())
}

/// Run the batching inference server — either against a synthetic
/// in-process request stream (default) or as a networked HTTP endpoint
/// (`--http <addr>`: the `/v1` multi-model API plus the legacy aliases).
fn serve(mut args: Args) -> Result<()> {
    use spectral_flow::coordinator::{BatcherConfig, ModelRegistry, ModelSpec};
    use spectral_flow::net::{HttpFrontend, NetConfig};
    use spectral_flow::tensor::Tensor;
    use std::sync::Arc;
    // `--model` is the documented knob since the graph presets landed;
    // `--variant` stays as the original alias (same mechanism as --batch:
    // the alias supplies the default, so `--model` wins when both appear)
    let legacy_variant = args.opt("variant", "vgg16-cifar", "legacy alias for --model");
    let variant = args.opt(
        "model",
        &legacy_variant,
        "model preset (demo|demo-residual|vgg16-cifar|vgg16-224|resnet18)",
    );
    let requests = args.opt_usize("requests", 16, "synthetic requests to issue (no --http)");
    // `--max-batch` is the documented knob; `--batch` stays as a legacy
    // alias (it supplies the default, so `--max-batch` wins when both are
    // given and old scripts keep working)
    let legacy_batch = args.opt_usize("batch", 4, "legacy alias for --max-batch");
    let batch = args.opt_usize(
        "max-batch",
        legacy_batch,
        "max batch size (the fused-forward reuse window; Ps is planned across it)",
    );
    let wait_ms = args.opt_usize("wait-ms", 10, "batch deadline (ms)");
    let artifacts = args.opt("artifacts", "artifacts", "artifacts directory");
    let workers = args.opt_usize("workers", 1, "executor workers (one engine each)");
    let threads = args.opt_usize("backend-threads", 1, "interp per-tile threads per engine");
    let backend_name = args.opt("backend", "interp", "spectral backend (interp|pjrt)");
    let alpha = args.opt_usize("alpha", 0, "compression ratio α (0 = manifest default, 1 = dense)");
    let scheduler_name = args.opt(
        "scheduler",
        "exact-cover",
        "sparse access scheduler (exact-cover|lowest-index|off)",
    );
    let dtype_name = args.opt("dtype", "", "accumulation dtype (f32|f64; empty = manifest default)");
    let plane_name = args.opt("plane", "full", "spectral storage plane (full|half)");
    let http_addr = args.opt("http", "", "serve over HTTP on this addr (e.g. 127.0.0.1:7878)");
    let max_inflight =
        args.opt_usize("max-inflight", 64, "per-model HTTP admission bound (excess → 429)");
    let extra_models = args.opt(
        "extra-models",
        "",
        "additional model presets to serve simultaneously (comma-separated; HTTP mode)",
    );
    let event_workers = args.opt_usize(
        "event-workers",
        4,
        "fixed event-driven connection workers multiplexing all sockets (HTTP mode)",
    );
    let duration_secs =
        args.opt_usize("duration-secs", 0, "HTTP mode: stop after this many seconds (0 = forever)");
    let backend = parse_backend(&backend_name, threads)?;
    let scheduler = SchedulePolicy::parse(&scheduler_name)?;
    let dtype = parse_dtype(&dtype_name)?;
    let plane = Plane::parse(&plane_name)?;
    args.maybe_help(
        "serve: run the batching server pool (synthetic traffic, or HTTP with --http)",
    );
    // Manifest-only read to shape the synthetic requests and resolve the α
    // default for the printout — the registry re-resolves per model.
    let m = spectral_flow::runtime::Runtime::open(&artifacts)?;
    let vdesc = m.manifest.variant(&variant)?.clone();
    let mode = WeightMode::from_alpha(m.manifest.resolve_alpha(alpha));
    let resolved_dtype = m.manifest.resolve_dtype(dtype);
    drop(m);
    println!(
        "serving {variant} at α={} ({mode:?}), scheduler {}, dtype {}, plane {}",
        mode.alpha(),
        scheduler.label(),
        resolved_dtype.label(),
        plane.label()
    );
    let spec = ModelSpec {
        preset: variant.clone(),
        alpha,
        seed: 7,
        batcher: BatcherConfig {
            max_batch: batch,
            max_wait: std::time::Duration::from_millis(wait_ms as u64),
        },
        workers,
        engine: spectral_flow::coordinator::EngineOptions::builder()
            .backend(backend)
            .scheduler(scheduler)
            .dtype(dtype)
            .plane(plane)
            .build(),
        max_inflight,
    };
    // the CLI model name doubles as the registry key; legacy aliases
    // (/infer, /metrics) resolve to it
    let registry = Arc::new(ModelRegistry::new(artifacts.clone(), variant.clone()));
    registry.load_blocking(&variant, spec.clone())?;
    if !http_addr.is_empty() {
        for name in extra_models.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            // extra models share every knob except the preset they serve
            registry.load_blocking(name, ModelSpec { preset: name.to_string(), ..spec.clone() })?;
            println!("also serving {name}");
        }
        // networked mode: hand the registry to the HTTP front-end and serve
        // until the duration elapses (0 = until the process is killed)
        let frontend = HttpFrontend::start(
            Arc::clone(&registry),
            NetConfig { addr: http_addr, event_workers, ..NetConfig::default() },
        )?;
        println!(
            "listening on http://{} — POST /v1/models/<name>/infer, GET /v1/models, \
             GET /v1/models/<name>/metrics, POST|DELETE /admin/models/<name>; \
             legacy /infer, /metrics, /healthz serve {variant}",
            frontend.local_addr()
        );
        if duration_secs > 0 {
            std::thread::sleep(std::time::Duration::from_secs(duration_secs as u64));
            println!("duration elapsed — draining and shutting down");
            return frontend.shutdown();
        }
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }
    let pool = registry
        .pool(&variant)
        .ok_or_else(|| err!("model {variant:?} is not serving"))?;
    let client = pool.client();
    let mut rng = Pcg32::new(123);
    let t0 = std::time::Instant::now();
    let rxs: Result<Vec<_>> = (0..requests)
        .map(|_| {
            client.infer_async(Tensor::randn(
                &[vdesc.input_c, vdesc.input_hw, vdesc.input_hw],
                &mut rng,
                1.0,
            ))
        })
        .collect();
    for rx in rxs? {
        rx.recv().map_err(|_| err!("server dropped request"))??;
    }
    let wall = t0.elapsed();
    let metrics = pool.pool_metrics()?;
    println!("{requests} requests in {wall:?} → {:.2} img/s", requests as f64 / wall.as_secs_f64());
    println!("{}", metrics.report());
    drop(client);
    drop(pool);
    registry.shutdown();
    Ok(())
}

/// Drive load against a `serve --http` endpoint and report latency
/// percentiles + throughput (optionally into a `BENCH_serve.json`).
fn loadgen(mut args: Args) -> Result<()> {
    use spectral_flow::net::{loadgen, LoadGenConfig, LoadMode};
    let addr = args.opt("addr", "127.0.0.1:7878", "target host:port of a serve --http endpoint");
    let mode_name = args.opt("mode", "closed", "closed (fixed concurrency) | open (fixed rate)");
    let concurrency = args.opt_usize("concurrency", 4, "closed-loop concurrent connections");
    let rate = args.opt_f64("rate", 20.0, "open-loop arrival rate (requests/second)");
    let requests = args.opt_usize("requests", 64, "total requests to issue");
    let timeout_ms = args.opt_usize("timeout-ms", 30_000, "per-request reply deadline");
    let out = args.opt(
        "out",
        "rust/reports/BENCH_serve.json",
        "bench artifact to write (\"none\" to skip)",
    );
    let model = args.opt(
        "model",
        "",
        "drive POST /v1/models/<name>/infer instead of the legacy /infer alias",
    );
    let models_flag = args.opt(
        "models",
        "",
        "comma-separated model names for mixed round-robin load (overrides --model)",
    );
    // the load generator never touches the engine's numerics (the server
    // owns those) — the flags only suffix the default artifact entry name
    // so sweeps over dtype/plane configs land in distinct bench rows
    let dtype_name = args.opt("dtype", "", "tag the bench name with a dtype suffix (f32|f64)");
    let plane_name = args.opt("plane", "full", "tag the bench name with a plane suffix (full|half)");
    let dtype_tag = parse_dtype(&dtype_name)?;
    let plane_tag = Plane::parse(&plane_name)?;
    let mut default_name = "serve/loadgen".to_string();
    if let Some(d) = dtype_tag {
        default_name.push('_');
        default_name.push_str(d.label());
    }
    if plane_tag == Plane::Half {
        default_name.push_str("_half");
    }
    let name = args.opt("name", &default_name, "bench entry name for the artifact");
    let strict = args.opt_bool("strict", "exit with an error unless every request succeeded");
    args.maybe_help("loadgen: open/closed-loop HTTP load against a serve --http endpoint");
    let mode = match mode_name.as_str() {
        "closed" => LoadMode::Closed { concurrency },
        "open" => LoadMode::Open { rate_hz: rate },
        other => return Err(err!("unknown mode {other:?} (expected closed|open)")),
    };
    let models: Vec<String> = if !models_flag.is_empty() {
        models_flag
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect()
    } else if !model.is_empty() {
        vec![model]
    } else {
        Vec::new()
    };
    let report = loadgen::run(&LoadGenConfig {
        addr,
        mode,
        requests,
        models,
        body: None,
        timeout: std::time::Duration::from_millis(timeout_ms as u64),
    })?;
    print!("{}", report.report());
    if out != "none" {
        let mut b = spectral_flow::util::bench::Bench::new();
        report.record_into(&mut b, &name);
        // mixed-model runs: one extra entry per model, so sweeps can track
        // per-model percentiles in the same artifact
        for (model, sub) in &report.per_model {
            sub.record_into(&mut b, &format!("{name}/{model}"));
        }
        b.write_json(&out)?;
        println!("wrote {out}");
    }
    if report.ok == 0 {
        return Err(err!("no successful requests — is serve --http running at the target?"));
    }
    if strict && report.ok != report.sent {
        return Err(err!(
            "{} of {} requests did not succeed ({} rejected, {} failed)",
            report.sent - report.ok,
            report.sent,
            report.rejected,
            report.failed
        ));
    }
    Ok(())
}

/// Run one forward pass through the AOT'd executables.
fn infer(mut args: Args) -> Result<()> {
    let legacy_variant = args.opt("variant", "demo", "legacy alias for --model");
    let variant = args.opt(
        "model",
        &legacy_variant,
        "model preset (demo|demo-residual|vgg16-cifar|vgg16-224|resnet18)",
    );
    let artifacts = args.opt("artifacts", "artifacts", "artifacts directory");
    let alpha = args.opt_usize("alpha", 0, "compression ratio α (0 = manifest default, 1 = dense)");
    let threads = args.opt_usize("backend-threads", 1, "interp per-tile threads");
    let backend_name = args.opt("backend", "interp", "spectral backend (interp|pjrt)");
    let scheduler_name = args.opt(
        "scheduler",
        "exact-cover",
        "sparse access scheduler (exact-cover|lowest-index|off)",
    );
    let dtype_name = args.opt("dtype", "", "accumulation dtype (f32|f64; empty = manifest default)");
    let plane_name = args.opt("plane", "full", "spectral storage plane (full|half)");
    let no_observe = args.opt_bool(
        "no-observe",
        "disable the data-movement counters (logits are identical either way)",
    );
    let trace = args.opt_bool("trace", "print the per-layer execute spans of the forward");
    let traffic_gate = args.opt(
        "traffic-gate",
        "",
        "fail unless every layer's measured/Eq.13 weight ratio is within lo,hi (e.g. 0.5,2.0)",
    );
    let backend = parse_backend(&backend_name, threads)?;
    let scheduler = SchedulePolicy::parse(&scheduler_name)?;
    let dtype = parse_dtype(&dtype_name)?;
    let plane = Plane::parse(&plane_name)?;
    let traffic_gate: Option<(f64, f64)> = if traffic_gate.is_empty() {
        None
    } else {
        let (lo, hi) = traffic_gate
            .split_once(',')
            .ok_or_else(|| err!("--traffic-gate wants two bounds, e.g. 0.5,2.0"))?;
        let parse = |v: &str| {
            v.trim()
                .parse::<f64>()
                .map_err(|_| err!("--traffic-gate bounds must be numbers, got {v:?}"))
        };
        Some((parse(lo)?, parse(hi)?))
    };
    args.maybe_help("infer: single-image forward pass through the spectral backend");
    // one extra (cheap) manifest read: the engine re-opens internally, but
    // the mode must be known before the engine can be constructed
    let mode = WeightMode::from_alpha(
        spectral_flow::runtime::Runtime::open(&artifacts)?.manifest.resolve_alpha(alpha),
    );
    let t0 = std::time::Instant::now();
    let mut engine = InferenceEngine::with_options(
        &artifacts,
        &variant,
        mode,
        7,
        EngineOptions::builder()
            .backend(backend)
            .scheduler(scheduler)
            .dtype(dtype)
            .plane(plane)
            .observe(!no_observe)
            .build(),
    )?;
    println!(
        "engine up in {:?} ({} layers, backend {}, α={}, scheduler {}, dtype {}, plane {})",
        t0.elapsed(),
        engine.variant.layers.len(),
        engine.backend_name(),
        mode.alpha(),
        engine.scheduler().label(),
        engine.dtype().label(),
        engine.plane().label(),
    );
    if let Some(sm) = engine.schedule_metrics() {
        // Alg. 2 plan quality: per-layer PE utilization, cycles vs the
        // information-theoretic lower bound, simulated bank conflicts
        let mut t = Table::new(
            &format!("Schedule quality ({})", sm.scheduler),
            &["layer", "PE util", "cycles", "lower bound", "bank conflicts"],
        );
        for l in &sm.layers {
            t.row(vec![
                l.layer.clone(),
                fmt_pct(l.stats.pe_utilization()),
                l.stats.cycles.to_string(),
                l.stats.lower_bound.to_string(),
                l.stats.bank_conflicts.to_string(),
            ]);
        }
        println!("{}", t.render());
        println!("{}", sm.report());
    }
    // static activation-arena plan: how much memory the graph's residuals
    // pin, and how far slot reuse cuts it vs one-buffer-per-tensor
    println!("{}", engine.arena_metrics().report());
    let img = engine.synthetic_image(1);
    let t1 = std::time::Instant::now();
    let logits = engine.forward(&img)?;
    println!(
        "forward({variant}) in {:?} → {} logits, argmax {}",
        t1.elapsed(),
        logits.len(),
        logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0)
    );
    if trace {
        let spans = engine.layer_spans();
        if spans.is_empty() {
            println!("trace: no layer spans recorded (is --no-observe set?)");
        } else {
            let epoch = spans.iter().map(|s| s.start).min().unwrap();
            let mut t = Table::new(
                "Layer trace (last forward)",
                &["span", "start µs", "dur µs", "measured B", "Eq.13 B"],
            );
            for sp in spans {
                t.row(vec![
                    format!("layer:{}", sp.name),
                    sp.start.duration_since(epoch).as_micros().to_string(),
                    sp.end.duration_since(sp.start).as_micros().to_string(),
                    sp.measured_bytes.to_string(),
                    sp.predicted_bytes.to_string(),
                ]);
            }
            println!("{}", t.render());
        }
    }
    match engine.traffic_metrics() {
        Some(tm) => {
            // measured on the backend boundary vs the Eq. 13 prediction;
            // exact byte counts (not fmt_bytes) so CI gates stay debuggable
            let mut t = Table::new(
                "Data movement per forward — measured vs Eq. 13 (bytes)",
                &["layer", "weights", "Eq.13 weights", "ratio", "inputs", "outputs", "psums"],
            );
            for l in &tm.layers {
                t.row(vec![
                    l.layer.clone(),
                    l.measured.weight_bytes.to_string(),
                    l.predicted_weight_bytes.to_string(),
                    format!("{:.3}", l.weight_ratio()),
                    l.measured.input_bytes.to_string(),
                    l.measured.output_bytes.to_string(),
                    l.measured.psum_bytes.to_string(),
                ]);
            }
            println!("{}", t.render());
            println!("{}", tm.report());
            if let Some((lo, hi)) = traffic_gate {
                for l in &tm.layers {
                    if l.predicted_weight_bytes == 0 {
                        continue;
                    }
                    let r = l.weight_ratio();
                    if r < lo || r > hi {
                        return Err(err!(
                            "traffic gate: layer {} measured/Eq.13 weight ratio {r:.3} \
                             outside [{lo}, {hi}]",
                            l.layer
                        ));
                    }
                }
                println!("traffic gate OK: every layer weight ratio within [{lo}, {hi}]");
            }
        }
        None => {
            if traffic_gate.is_some() {
                return Err(err!("--traffic-gate needs the counters; drop --no-observe"));
            }
        }
    }
    Ok(())
}
