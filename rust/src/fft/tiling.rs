//! Overlap-and-Add tiling geometry (paper Eq. 4) — the Rust mirror of
//! `python/compile/kernels/ref.py::{im2tiles, overlap_add, spectral_kernels}`.
//!
//! These run on the coordinator's CPU path (the paper offloads OaA to the
//! host CPU, §6) around the AOT'd spectral-conv executables.

use crate::fft::core::{fft2d, Complex};
use crate::tensor::{ComplexTensor, Tensor};

/// ceil(h / tile): number of OaA tiles along one spatial dimension.
pub fn tiles_per_side(h: usize, tile: usize) -> usize {
    h.div_ceil(tile)
}

/// Static geometry of one spectral conv layer's tiling.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TileGeometry {
    /// Input spatial side H (square activations).
    pub h: usize,
    /// OaA tile side h' = K - k + 1.
    pub tile: usize,
    /// FFT window K.
    pub fft: usize,
    /// Spatial kernel side k.
    pub k: usize,
    /// 'SAME' padding (k-1)/2.
    pub pad: usize,
}

impl TileGeometry {
    pub fn new(h: usize, fft: usize, k: usize) -> Self {
        assert!(fft >= k, "FFT window {fft} smaller than kernel {k}");
        TileGeometry { h, tile: fft - k + 1, fft, k, pad: (k - 1) / 2 }
    }

    pub fn tiles_per_side(&self) -> usize {
        tiles_per_side(self.h, self.tile)
    }

    /// Total tile count T for a square H x H activation.
    pub fn num_tiles(&self) -> usize {
        let s = self.tiles_per_side();
        s * s
    }
}

/// Partition `[M, H, H]` activations into zero-padded tiles `[T, M, K, K]`.
///
/// Tiles are row-major over the (ty, tx) grid; the activation is implicitly
/// zero-padded up to a multiple of the tile size.
pub fn im2tiles(x: &Tensor, geo: &TileGeometry) -> Tensor {
    let shape = x.shape();
    assert_eq!(shape.len(), 3, "expected [M, H, W]");
    let (m, h, w) = (shape[0], shape[1], shape[2]);
    assert_eq!(h, geo.h, "geometry H mismatch");
    assert_eq!(h, w, "square activations only");
    let side = geo.tiles_per_side();
    let (tile, fft) = (geo.tile, geo.fft);
    let mut out = Tensor::zeros(&[side * side, m, fft, fft]);
    let xd = x.data();
    let od = out.data_mut();
    for ty in 0..side {
        for tx in 0..side {
            let t = ty * side + tx;
            for c in 0..m {
                for dy in 0..tile {
                    let sy = ty * tile + dy;
                    if sy >= h {
                        break;
                    }
                    let src_row = (c * h + sy) * w + tx * tile;
                    let dst_row = ((t * m + c) * fft + dy) * fft;
                    let ncols = tile.min(w - tx * tile);
                    od[dst_row..dst_row + ncols]
                        .copy_from_slice(&xd[src_row..src_row + ncols]);
                }
            }
        }
    }
    out
}

/// Overlap-add output tiles `[T, N, K, K]` into the 'SAME' output `[N, H, H]`.
///
/// Tiles hold full linear convolutions (length tile + k - 1 = K); they are
/// accumulated at stride `tile` and cropped at offset `k - 1 - pad`.
pub fn overlap_add(tiles: &Tensor, geo: &TileGeometry, n: usize) -> Tensor {
    let shape = tiles.shape();
    assert_eq!(shape.len(), 4, "expected [T, N, K, K]");
    let side = geo.tiles_per_side();
    assert_eq!(shape[0], side * side, "tile count mismatch");
    assert_eq!(shape[1], n);
    assert_eq!(shape[2], geo.fft);
    let (h, tile, fft, k) = (geo.h, geo.tile, geo.fft, geo.k);
    let full_side = side * tile + k - 1;
    let mut full = Tensor::zeros(&[n, full_side, full_side]);
    let td = tiles.data();
    let fd = full.data_mut();
    for ty in 0..side {
        for tx in 0..side {
            let t = ty * side + tx;
            for c in 0..n {
                for dy in 0..fft {
                    let fy = ty * tile + dy;
                    let dst = (c * full_side + fy) * full_side + tx * tile;
                    let src = ((t * n + c) * fft + dy) * fft;
                    for dx in 0..fft {
                        fd[dst + dx] += td[src + dx];
                    }
                }
            }
        }
    }
    // crop: offset = k - 1 - pad, size h
    let off = k - 1 - geo.pad;
    let mut out = Tensor::zeros(&[n, h, h]);
    let odata = out.data_mut();
    let fdata = full.data();
    for c in 0..n {
        for y in 0..h {
            let src = (c * full_side + y + off) * full_side + off;
            let dst = (c * h + y) * h;
            odata[dst..dst + h].copy_from_slice(&fdata[src..src + h]);
        }
    }
    out
}

/// Spatial kernels `[N, M, k, k]` → spectral planes `[N, M, K, K]` (re, im).
///
/// Flip both spatial axes (cross-correlation → convolution), zero-pad to K,
/// 2D FFT — identical to `ref.spectral_kernels`.
pub fn spectral_kernels(w: &Tensor, fft: usize) -> ComplexTensor {
    let shape = w.shape();
    assert_eq!(shape.len(), 4, "expected [N, M, k, k]");
    let (n, m, k) = (shape[0], shape[1], shape[2]);
    assert_eq!(shape[3], k);
    let mut out = ComplexTensor::zeros(&[n, m, fft, fft]);
    let mut plane = vec![Complex::ZERO; fft * fft];
    for o in 0..n {
        for i in 0..m {
            for p in plane.iter_mut() {
                *p = Complex::ZERO;
            }
            for y in 0..k {
                for x in 0..k {
                    // flipped kernel into the top-left K x K corner
                    plane[y * fft + x] =
                        Complex::new(w.at(&[o, i, k - 1 - y, k - 1 - x]), 0.0);
                }
            }
            let spec = fft2d(&plane, fft);
            for y in 0..fft {
                for x in 0..fft {
                    let c = spec[y * fft + x];
                    out.set(&[o, i, y, x], c.re, c.im);
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;

    #[test]
    fn geometry_paper_points() {
        let g = TileGeometry::new(224, 8, 3);
        assert_eq!(g.tile, 6);
        assert_eq!(g.pad, 1);
        assert_eq!(g.tiles_per_side(), 38);
        assert_eq!(g.num_tiles(), 1444);
        assert_eq!(TileGeometry::new(14, 8, 3).num_tiles(), 9);
        assert_eq!(TileGeometry::new(112, 16, 3).num_tiles(), 64); // K=16 → h'=14
    }

    #[test]
    fn im2tiles_places_values() {
        // 1 channel, 7x7 input, tile 6 → 2x2 tiles with edge padding
        let g = TileGeometry::new(7, 8, 3);
        let x = Tensor::from_vec(&[1, 7, 7], (0..49).map(|i| i as f32).collect());
        let t = im2tiles(&x, &g);
        assert_eq!(t.shape(), &[4, 1, 8, 8]);
        // tile (0,0) holds x[0..6, 0..6] at its top-left
        assert_eq!(t.at(&[0, 0, 0, 0]), 0.0);
        assert_eq!(t.at(&[0, 0, 5, 5]), x.at(&[0, 5, 5]));
        // padding rows/cols of tile(0,0) are zero
        assert_eq!(t.at(&[0, 0, 6, 0]), 0.0);
        assert_eq!(t.at(&[0, 0, 0, 6]), 0.0);
        // tile (1,1) top-left = x[6,6]
        assert_eq!(t.at(&[3, 0, 0, 0]), x.at(&[0, 6, 6]));
        // out-of-image region of edge tile is zero
        assert_eq!(t.at(&[3, 0, 1, 1]), 0.0);
    }

    #[test]
    fn tiles_partition_preserves_mass() {
        forall("im2tiles mass", 20, |rng| {
            let h = rng.range(3, 20);
            let m = rng.range(1, 4);
            let g = TileGeometry::new(h, 8, 3);
            let x = Tensor::randn(&[m, h, h], rng, 1.0);
            let t = im2tiles(&x, &g);
            let sx: f32 = x.data().iter().sum();
            let st: f32 = t.data().iter().sum();
            assert!((sx - st).abs() < 1e-3 * x.len() as f32);
        });
    }

    #[test]
    fn identity_kernel_roundtrips_through_oaa() {
        // delta kernel at center → spectral conv is identity; this exercises
        // im2tiles + fft + hadamard + ifft + overlap_add end to end in rust.
        forall("oaa identity", 10, |rng| {
            let h = rng.range(4, 16);
            let g = TileGeometry::new(h, 8, 3);
            let x = Tensor::randn(&[1, h, h], rng, 1.0);
            let mut w = Tensor::zeros(&[1, 1, 3, 3]);
            w.set(&[0, 0, 1, 1], 1.0); // center tap
            let ws = spectral_kernels(&w, g.fft);
            let tiles = im2tiles(&x, &g);
            let t = g.num_tiles();
            let mut out_tiles = Tensor::zeros(&[t, 1, g.fft, g.fft]);
            for ti in 0..t {
                let mut plane = vec![Complex::ZERO; g.fft * g.fft];
                for y in 0..g.fft {
                    for x2 in 0..g.fft {
                        plane[y * g.fft + x2] =
                            Complex::new(tiles.at(&[ti, 0, y, x2]), 0.0);
                    }
                }
                let xs = fft2d(&plane, g.fft);
                let prod: Vec<Complex> = (0..g.fft * g.fft)
                    .map(|i| {
                        let (wr, wi) = ws.at(&[0, 0, i / g.fft, i % g.fft]);
                        xs[i].mul(Complex::new(wr, wi))
                    })
                    .collect();
                let y = crate::fft::ifft2d(&prod, g.fft);
                for (i, c) in y.iter().enumerate() {
                    out_tiles.set(&[ti, 0, i / g.fft, i % g.fft], c.re);
                }
            }
            let out = overlap_add(&out_tiles, &g, 1);
            let err = out.max_abs_diff(&x);
            assert!(err < 1e-4, "identity conv error {err}");
        });
    }

    #[test]
    #[should_panic(expected = "square")]
    fn non_square_rejected() {
        let g = TileGeometry::new(4, 8, 3);
        im2tiles(&Tensor::zeros(&[1, 4, 5]), &g);
    }
}
