//! Radix-2 iterative FFT (Cooley–Tukey, decimation in time), generic over
//! the scalar precision.
//!
//! Only power-of-two sizes are needed (the paper uses K ∈ {8, 16}); sizes
//! are asserted. `ifft` applies the 1/N normalization (matching
//! `jnp.fft.ifft`). Twiddle factors are computed per call in f64 and
//! rounded to the working precision — for `T = f32` this reproduces the
//! historical all-f32 transforms bit for bit.
//!
//! Real-input transforms ([`rfft2d`]/[`irfft2d`]) store only the
//! K × (K/2 + 1) half-plane: a real tile's spectrum is Hermitian
//! (`X[-f] = conj(X[f])`), so the reflected half of every plane is
//! redundant. The forward pass packs two real rows into one complex FFT
//! (halving the row pass) and runs column FFTs only over the kept columns;
//! the inverse reconstructs each row's reflected half explicitly before a
//! full-length row IFFT, which keeps it exact for *any* complex half-plane
//! input — including non-Hermitian-consistent accumulators produced by
//! asymmetric pruned kernels (see `SparseWeightPlanes::fold_half_plane`).

/// Scalar precision the spectral pipeline is generic over (`f32`/`f64`).
///
/// The trait is deliberately tiny: arithmetic comes from the std ops
/// bounds, conversions round-trip through the literal types, and the
/// associated consts let generic code build exact 0/1 values.
pub trait Float:
    Copy
    + Default
    + PartialEq
    + PartialOrd
    + Send
    + Sync
    + std::fmt::Debug
    + std::ops::Add<Output = Self>
    + std::ops::Sub<Output = Self>
    + std::ops::Mul<Output = Self>
    + std::ops::Neg<Output = Self>
    + std::ops::AddAssign
    + 'static
{
    const ZERO: Self;
    const ONE: Self;
    fn from_f32(x: f32) -> Self;
    fn to_f32(self) -> f32;
    /// Exact for integers and dyadic rationals in range — the only values
    /// the transforms build this way (twiddles, 1/2, 1/N for pow-2 N).
    fn from_f64(x: f64) -> Self;
    fn sqrt(self) -> Self;
}

impl Float for f32 {
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;
    #[inline]
    fn from_f32(x: f32) -> Self {
        x
    }
    #[inline]
    fn to_f32(self) -> f32 {
        self
    }
    #[inline]
    fn from_f64(x: f64) -> Self {
        x as f32
    }
    #[inline]
    fn sqrt(self) -> Self {
        f32::sqrt(self)
    }
}

impl Float for f64 {
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;
    #[inline]
    fn from_f32(x: f32) -> Self {
        x as f64
    }
    #[inline]
    fn to_f32(self) -> f32 {
        self as f32
    }
    #[inline]
    fn from_f64(x: f64) -> Self {
        x
    }
    #[inline]
    fn sqrt(self) -> Self {
        f64::sqrt(self)
    }
}

/// Minimal complex number (avoids pulling in `num-complex`), generic over
/// the scalar precision.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Cx<T> {
    pub re: T,
    pub im: T,
}

/// The historical working type: single-precision complex. Every pre-dtype
/// call site keeps compiling (and computing) unchanged through this alias.
pub type Complex = Cx<f32>;

impl<T: Float> Cx<T> {
    pub const ZERO: Cx<T> = Cx { re: T::ZERO, im: T::ZERO };

    pub fn new(re: T, im: T) -> Self {
        Cx { re, im }
    }

    #[inline]
    pub fn add(self, o: Cx<T>) -> Cx<T> {
        Cx::new(self.re + o.re, self.im + o.im)
    }

    #[inline]
    pub fn sub(self, o: Cx<T>) -> Cx<T> {
        Cx::new(self.re - o.re, self.im - o.im)
    }

    #[inline]
    pub fn mul(self, o: Cx<T>) -> Cx<T> {
        Cx::new(
            self.re * o.re - self.im * o.im,
            self.re * o.im + self.im * o.re,
        )
    }

    #[inline]
    pub fn conj(self) -> Cx<T> {
        Cx::new(self.re, -self.im)
    }

    #[inline]
    pub fn scale(self, s: T) -> Cx<T> {
        Cx::new(self.re * s, self.im * s)
    }

    pub fn abs(self) -> T {
        (self.re * self.re + self.im * self.im).sqrt()
    }
}

fn assert_pow2(n: usize) {
    assert!(n.is_power_of_two(), "FFT size {n} must be a power of two");
}

/// In-place iterative radix-2 FFT. `inverse` flips the twiddle sign;
/// normalization is the caller's concern (see [`ifft1d`]).
fn fft_inplace<T: Float>(buf: &mut [Cx<T>], inverse: bool) {
    let n = buf.len();
    assert_pow2(n);
    if n <= 1 {
        return;
    }
    // bit-reversal permutation
    let bits = n.trailing_zeros();
    for i in 0..n {
        let j = (i as u32).reverse_bits() >> (32 - bits);
        let j = j as usize;
        if i < j {
            buf.swap(i, j);
        }
    }
    let sign = if inverse { 1.0 } else { -1.0 };
    let mut len = 2;
    while len <= n {
        let ang = sign * 2.0 * std::f64::consts::PI / len as f64;
        let wlen = Cx::new(T::from_f64(ang.cos()), T::from_f64(ang.sin()));
        for chunk in buf.chunks_mut(len) {
            let mut w = Cx::new(T::ONE, T::ZERO);
            let half = len / 2;
            for i in 0..half {
                let u = chunk[i];
                let v = chunk[i + half].mul(w);
                chunk[i] = u.add(v);
                chunk[i + half] = u.sub(v);
                w = w.mul(wlen);
            }
        }
        len <<= 1;
    }
}

/// Forward 1D FFT (no normalization, like `jnp.fft.fft`).
pub fn fft1d<T: Float>(x: &[Cx<T>]) -> Vec<Cx<T>> {
    let mut buf = x.to_vec();
    fft_inplace(&mut buf, false);
    buf
}

/// Inverse 1D FFT with 1/N normalization (like `jnp.fft.ifft`).
pub fn ifft1d<T: Float>(x: &[Cx<T>]) -> Vec<Cx<T>> {
    let mut buf = x.to_vec();
    fft_inplace(&mut buf, true);
    let inv = T::from_f64(1.0 / buf.len() as f64);
    for v in &mut buf {
        *v = v.scale(inv);
    }
    buf
}

/// Forward 2D FFT on a row-major `n x n` plane.
pub fn fft2d<T: Float>(x: &[Cx<T>], n: usize) -> Vec<Cx<T>> {
    let mut out = x.to_vec();
    fft2d_inplace(&mut out, n);
    out
}

/// Inverse 2D FFT with 1/N² normalization.
pub fn ifft2d<T: Float>(x: &[Cx<T>], n: usize) -> Vec<Cx<T>> {
    let mut out = x.to_vec();
    ifft2d_inplace(&mut out, n);
    out
}

/// In-place forward 2D FFT (allocation-free except an `n`-element column
/// scratch) — the interp backend's hot path uses this on its scratch
/// buffers directly.
pub fn fft2d_inplace<T: Float>(buf: &mut [Cx<T>], n: usize) {
    fft2d_impl(buf, n, false);
}

/// In-place inverse 2D FFT with 1/N² normalization.
pub fn ifft2d_inplace<T: Float>(buf: &mut [Cx<T>], n: usize) {
    fft2d_impl(buf, n, true);
    let inv = T::from_f64(1.0 / (n * n) as f64);
    for v in buf {
        *v = v.scale(inv);
    }
}

fn fft2d_impl<T: Float>(out: &mut [Cx<T>], n: usize, inverse: bool) {
    assert_eq!(out.len(), n * n, "plane must be n x n");
    // rows
    for r in 0..n {
        fft_inplace(&mut out[r * n..(r + 1) * n], inverse);
    }
    // columns (gather/scatter through a scratch row)
    let mut col = vec![Cx::ZERO; n];
    for c in 0..n {
        for r in 0..n {
            col[r] = out[r * n + c];
        }
        fft_inplace(&mut col, inverse);
        for r in 0..n {
            out[r * n + c] = col[r];
        }
    }
}

/// Number of spectral coefficients a real `n x n` tile actually needs:
/// `n * (n/2 + 1)` — the rfft2 half-plane (full rows, columns `0..=n/2`).
pub fn half_plane_len(n: usize) -> usize {
    n * (n / 2 + 1)
}

/// Forward real-input 2D FFT storing only the `n x (n/2 + 1)` half-plane
/// (numpy `rfft2` layout: row `r`, column `c ≤ n/2` at `r * (n/2+1) + c`).
///
/// Matches `fft2d` on the kept columns (the dropped ones are the exact
/// conjugate mirrors). The row pass packs two real rows per complex FFT —
/// exact for real input — so a forward transform costs n/2 row FFTs plus
/// n/2+1 column FFTs instead of 2n.
pub fn rfft2d<T: Float>(x: &[T], n: usize) -> Vec<Cx<T>> {
    let mut out = vec![Cx::ZERO; half_plane_len(n)];
    rfft2d_into(x, n, &mut out);
    out
}

/// [`rfft2d`] into a caller-owned `n·(n/2+1)` buffer — the backend's hot
/// loop reuses one spectrum buffer across tiles instead of allocating.
pub fn rfft2d_into<T: Float>(x: &[T], n: usize, out: &mut [Cx<T>]) {
    assert_eq!(x.len(), n * n, "plane must be n x n");
    assert_eq!(out.len(), half_plane_len(n), "spectrum must be n x (n/2 + 1)");
    assert_pow2(n);
    let hc = n / 2 + 1;
    if n == 1 {
        out[0] = Cx::new(x[0], T::ZERO);
        return;
    }
    let half = T::from_f64(0.5);
    // row pass: rows 2j and 2j+1 ride one complex FFT as z = a + i·b;
    // A[k] = (Z[k] + conj(Z[-k]))/2, B[k] = -i(Z[k] - conj(Z[-k]))/2
    let mut z = vec![Cx::ZERO; n];
    for pair in 0..n / 2 {
        let (ra, rb) = (2 * pair, 2 * pair + 1);
        for c in 0..n {
            z[c] = Cx::new(x[ra * n + c], x[rb * n + c]);
        }
        fft_inplace(&mut z, false);
        for c in 0..hc {
            let zc = z[c];
            let zm = z[(n - c) % n].conj();
            out[ra * hc + c] = zc.add(zm).scale(half);
            let d = zc.sub(zm); // = 2i·B[c]
            out[rb * hc + c] = Cx::new(d.im * half, -(d.re * half));
        }
    }
    // column pass: only the kept columns
    let mut col = vec![Cx::ZERO; n];
    for c in 0..hc {
        for r in 0..n {
            col[r] = out[r * hc + c];
        }
        fft_inplace(&mut col, false);
        for r in 0..n {
            out[r * hc + c] = col[r];
        }
    }
}

/// Inverse of [`rfft2d`]: half-plane spectrum → real `n x n` tile (with the
/// 1/N² normalization, like `irfft2`).
///
/// Semantics: Hermitian-extend the half-plane across its reflected columns
/// (`Ã[r, c] = conj(A[(n-r)%n, n-c])` for `c > n/2`), run a full inverse
/// 2D FFT, keep the real part. Columns 0 and n/2 are used exactly as
/// stored (they carry their own conjugate pairs), so the transform is
/// linear and exact for arbitrary — even non-Hermitian-consistent —
/// half-plane input; the spectral MAC relies on this when pruned kernels
/// are asymmetric.
pub fn irfft2d<T: Float>(spec: &[Cx<T>], n: usize) -> Vec<T> {
    let mut out = vec![T::ZERO; n * n];
    irfft2d_into(spec, n, &mut out);
    out
}

/// [`irfft2d`] into a caller-owned `n·n` real buffer (hot-loop variant).
pub fn irfft2d_into<T: Float>(spec: &[Cx<T>], n: usize, out: &mut [T]) {
    let hc = n / 2 + 1;
    assert_eq!(spec.len(), n * hc, "spectrum must be n x (n/2 + 1)");
    assert_eq!(out.len(), n * n, "plane must be n x n");
    assert_pow2(n);
    if n == 1 {
        out[0] = spec[0].re;
        return;
    }
    // column pass: unnormalized inverse FFT down each kept column
    let mut work = spec.to_vec();
    let mut col = vec![Cx::ZERO; n];
    for c in 0..hc {
        for r in 0..n {
            col[r] = work[r * hc + c];
        }
        fft_inplace(&mut col, true);
        for r in 0..n {
            work[r * hc + c] = col[r];
        }
    }
    // row pass: after the column transforms the 2D Hermitian extension
    // collapses to a per-row one (G̃[p, c] = conj(G[p, n-c])); rebuild the
    // reflected half, full-length inverse FFT, keep the real part
    let inv = T::from_f64(1.0 / (n * n) as f64);
    let mut row = vec![Cx::ZERO; n];
    for r in 0..n {
        row[..hc].copy_from_slice(&work[r * hc..(r + 1) * hc]);
        for c in hc..n {
            row[c] = work[r * hc + (n - c)].conj();
        }
        fft_inplace(&mut row, true);
        for q in 0..n {
            out[r * n + q] = row[q].re * inv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;
    use crate::util::rng::Pcg32;

    fn randc(rng: &mut Pcg32, n: usize) -> Vec<Complex> {
        (0..n).map(|_| Complex::new(rng.normal(), rng.normal())).collect()
    }

    #[test]
    fn fft_of_impulse_is_flat() {
        let mut x = vec![Complex::ZERO; 8];
        x[0] = Complex::new(1.0, 0.0);
        for v in fft1d(&x) {
            assert!((v.re - 1.0).abs() < 1e-6 && v.im.abs() < 1e-6);
        }
    }

    #[test]
    fn fft_known_values() {
        // fft([1,2,3,4]) = [10, -2+2i, -2, -2-2i]
        let x: Vec<Complex> = [1.0, 2.0, 3.0, 4.0]
            .iter()
            .map(|&r| Complex::new(r, 0.0))
            .collect();
        let y = fft1d(&x);
        let want = [(10.0, 0.0), (-2.0, 2.0), (-2.0, 0.0), (-2.0, -2.0)];
        for (got, &(re, im)) in y.iter().zip(&want) {
            assert!((got.re - re).abs() < 1e-5, "{got:?} vs {re}");
            assert!((got.im - im).abs() < 1e-5, "{got:?} vs {im}");
        }
    }

    #[test]
    fn roundtrip_1d_2d() {
        forall("fft roundtrip", 50, |rng| {
            let n = 1 << rng.range(0, 6); // 1..32
            let x = randc(rng, n);
            let y = ifft1d(&fft1d(&x));
            for (a, b) in x.iter().zip(&y) {
                assert!((a.re - b.re).abs() < 1e-4 && (a.im - b.im).abs() < 1e-4);
            }
            let n2 = 1 << rng.range(1, 5); // 2..16
            let p = randc(rng, n2 * n2);
            let q = ifft2d(&fft2d(&p, n2), n2);
            for (a, b) in p.iter().zip(&q) {
                assert!((a.re - b.re).abs() < 1e-3 && (a.im - b.im).abs() < 1e-3);
            }
        });
    }

    #[test]
    fn parseval_energy_preserved() {
        forall("parseval", 30, |rng| {
            let n = 16;
            let x = randc(rng, n);
            let y = fft1d(&x);
            let ex: f32 = x.iter().map(|c| c.abs() * c.abs()).sum();
            let ey: f32 = y.iter().map(|c| c.abs() * c.abs()).sum::<f32>() / n as f32;
            assert!((ex - ey).abs() < 1e-2 * ex.max(1.0), "{ex} vs {ey}");
        });
    }

    #[test]
    fn linearity() {
        forall("fft linearity", 30, |rng| {
            let n = 8;
            let x = randc(rng, n);
            let y = randc(rng, n);
            let sum: Vec<Complex> = x.iter().zip(&y).map(|(a, b)| a.add(*b)).collect();
            let fs = fft1d(&sum);
            let fx = fft1d(&x);
            let fy = fft1d(&y);
            for i in 0..n {
                let e = fx[i].add(fy[i]);
                assert!((fs[i].re - e.re).abs() < 1e-3 && (fs[i].im - e.im).abs() < 1e-3);
            }
        });
    }

    #[test]
    fn convolution_theorem_circular() {
        // ifft(fft(x) ∘ fft(h)) = circular convolution of x and h
        let mut rng = Pcg32::new(77);
        let n = 8;
        let x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let h: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let mut want = vec![0.0f32; n];
        for i in 0..n {
            for j in 0..n {
                want[(i + j) % n] += x[i] * h[j];
            }
        }
        let xc: Vec<Complex> = x.iter().map(|&r| Complex::new(r, 0.0)).collect();
        let hc: Vec<Complex> = h.iter().map(|&r| Complex::new(r, 0.0)).collect();
        let prod: Vec<Complex> = fft1d(&xc)
            .iter()
            .zip(fft1d(&hc))
            .map(|(a, b)| a.mul(b))
            .collect();
        let got = ifft1d(&prod);
        for i in 0..n {
            assert!((got[i].re - want[i]).abs() < 1e-4, "{} vs {}", got[i].re, want[i]);
            assert!(got[i].im.abs() < 1e-4);
        }
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn non_pow2_rejected() {
        fft1d(&[Complex::ZERO; 6]);
    }

    #[test]
    fn f64_transforms_match_f32_shapes() {
        // the generic core at f64: same API, tighter round-trip
        let mut rng = Pcg32::new(9);
        let n = 16;
        let x: Vec<Cx<f64>> =
            (0..n).map(|_| Cx::new(rng.normal() as f64, rng.normal() as f64)).collect();
        let y = ifft1d(&fft1d(&x));
        for (a, b) in x.iter().zip(&y) {
            assert!((a.re - b.re).abs() < 1e-12 && (a.im - b.im).abs() < 1e-12);
        }
    }

    #[test]
    fn rfft2d_matches_full_fft_half_plane() {
        forall("rfft2d == fft2d half-plane", 24, |rng| {
            for n in [2usize, 4, 8, 16] {
                let x: Vec<f32> = (0..n * n).map(|_| rng.normal()).collect();
                let got = rfft2d(&x, n);
                let full =
                    fft2d(&x.iter().map(|&v| Complex::new(v, 0.0)).collect::<Vec<_>>(), n);
                let hc = n / 2 + 1;
                for r in 0..n {
                    for c in 0..hc {
                        let g = got[r * hc + c];
                        let w = full[r * n + c];
                        assert!(
                            (g.re - w.re).abs() < 1e-3 && (g.im - w.im).abs() < 1e-3,
                            "n={n} ({r},{c}): {g:?} vs {w:?}"
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn irfft2d_roundtrips_real_input() {
        forall("irfft2d ∘ rfft2d == id", 24, |rng| {
            for n in [2usize, 4, 8, 16] {
                let x: Vec<f32> = (0..n * n).map(|_| rng.normal()).collect();
                let y = irfft2d(&rfft2d(&x, n), n);
                for (a, b) in x.iter().zip(&y) {
                    assert!((a - b).abs() < 1e-5, "n={n}: {a} vs {b}");
                }
            }
        });
    }

    #[test]
    fn rfft2d_f64_roundtrip_tight() {
        let mut rng = Pcg32::new(31);
        for n in [8usize, 16] {
            let x: Vec<f64> = (0..n * n).map(|_| rng.normal() as f64).collect();
            let y = irfft2d(&rfft2d(&x, n), n);
            for (a, b) in x.iter().zip(&y) {
                assert!((a - b).abs() < 1e-12, "n={n}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn irfft2d_is_hermitian_real_part_for_arbitrary_input() {
        // the exactness contract the conjugate-folded sparse MAC leans on:
        // for ANY complex half-plane A, irfft2d(A) equals the real part of
        // the full inverse FFT of A's mirror extension (columns 0 and n/2
        // used as stored, interior columns reflected conjugated)
        let mut rng = Pcg32::new(5);
        for n in [4usize, 8] {
            let hc = n / 2 + 1;
            let a: Vec<Complex> =
                (0..n * hc).map(|_| Complex::new(rng.normal(), rng.normal())).collect();
            let got = irfft2d(&a, n);
            let mut ext = vec![Complex::ZERO; n * n];
            for r in 0..n {
                for c in 0..n {
                    ext[r * n + c] = if c < hc {
                        a[r * hc + c]
                    } else {
                        a[((n - r) % n) * hc + (n - c)].conj()
                    };
                }
            }
            let want = ifft2d(&ext, n);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w.re).abs() < 1e-5, "{g} vs {}", w.re);
            }
        }
    }

    #[test]
    fn half_plane_len_counts() {
        assert_eq!(half_plane_len(8), 40);
        assert_eq!(half_plane_len(16), 144);
    }
}
