//! Radix-2 iterative FFT (Cooley–Tukey, decimation in time).
//!
//! Only power-of-two sizes are needed (the paper uses K ∈ {8, 16}); sizes
//! are asserted. `ifft` applies the 1/N normalization (matching
//! `jnp.fft.ifft`). Twiddle factors are computed per call — the transforms
//! here run on 8/16-point tiles at build/verify time, never on the serving
//! hot path (that work is inside the AOT'd XLA executables).

/// Minimal complex number (avoids pulling in `num-complex`).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Complex {
    pub re: f32,
    pub im: f32,
}

impl Complex {
    pub const ZERO: Complex = Complex { re: 0.0, im: 0.0 };

    pub fn new(re: f32, im: f32) -> Self {
        Complex { re, im }
    }

    #[inline]
    pub fn add(self, o: Complex) -> Complex {
        Complex::new(self.re + o.re, self.im + o.im)
    }

    #[inline]
    pub fn sub(self, o: Complex) -> Complex {
        Complex::new(self.re - o.re, self.im - o.im)
    }

    #[inline]
    pub fn mul(self, o: Complex) -> Complex {
        Complex::new(
            self.re * o.re - self.im * o.im,
            self.re * o.im + self.im * o.re,
        )
    }

    #[inline]
    pub fn conj(self) -> Complex {
        Complex::new(self.re, -self.im)
    }

    pub fn abs(self) -> f32 {
        (self.re * self.re + self.im * self.im).sqrt()
    }
}

fn assert_pow2(n: usize) {
    assert!(n.is_power_of_two(), "FFT size {n} must be a power of two");
}

/// In-place iterative radix-2 FFT. `inverse` flips the twiddle sign;
/// normalization is the caller's concern (see [`ifft1d`]).
fn fft_inplace(buf: &mut [Complex], inverse: bool) {
    let n = buf.len();
    assert_pow2(n);
    if n <= 1 {
        return;
    }
    // bit-reversal permutation
    let bits = n.trailing_zeros();
    for i in 0..n {
        let j = (i as u32).reverse_bits() >> (32 - bits);
        let j = j as usize;
        if i < j {
            buf.swap(i, j);
        }
    }
    let sign = if inverse { 1.0 } else { -1.0 };
    let mut len = 2;
    while len <= n {
        let ang = sign * 2.0 * std::f64::consts::PI / len as f64;
        let wlen = Complex::new(ang.cos() as f32, ang.sin() as f32);
        for chunk in buf.chunks_mut(len) {
            let mut w = Complex::new(1.0, 0.0);
            let half = len / 2;
            for i in 0..half {
                let u = chunk[i];
                let v = chunk[i + half].mul(w);
                chunk[i] = u.add(v);
                chunk[i + half] = u.sub(v);
                w = w.mul(wlen);
            }
        }
        len <<= 1;
    }
}

/// Forward 1D FFT (no normalization, like `jnp.fft.fft`).
pub fn fft1d(x: &[Complex]) -> Vec<Complex> {
    let mut buf = x.to_vec();
    fft_inplace(&mut buf, false);
    buf
}

/// Inverse 1D FFT with 1/N normalization (like `jnp.fft.ifft`).
pub fn ifft1d(x: &[Complex]) -> Vec<Complex> {
    let mut buf = x.to_vec();
    fft_inplace(&mut buf, true);
    let inv = 1.0 / buf.len() as f32;
    for v in &mut buf {
        v.re *= inv;
        v.im *= inv;
    }
    buf
}

/// Forward 2D FFT on a row-major `n x n` plane.
pub fn fft2d(x: &[Complex], n: usize) -> Vec<Complex> {
    let mut out = x.to_vec();
    fft2d_inplace(&mut out, n);
    out
}

/// Inverse 2D FFT with 1/N² normalization.
pub fn ifft2d(x: &[Complex], n: usize) -> Vec<Complex> {
    let mut out = x.to_vec();
    ifft2d_inplace(&mut out, n);
    out
}

/// In-place forward 2D FFT (allocation-free except an `n`-element column
/// scratch) — the interp backend's hot path uses this on its scratch
/// buffers directly.
pub fn fft2d_inplace(buf: &mut [Complex], n: usize) {
    fft2d_impl(buf, n, false);
}

/// In-place inverse 2D FFT with 1/N² normalization.
pub fn ifft2d_inplace(buf: &mut [Complex], n: usize) {
    fft2d_impl(buf, n, true);
    let inv = 1.0 / (n * n) as f32;
    for v in buf {
        v.re *= inv;
        v.im *= inv;
    }
}

fn fft2d_impl(out: &mut [Complex], n: usize, inverse: bool) {
    assert_eq!(out.len(), n * n, "plane must be n x n");
    // rows
    for r in 0..n {
        fft_inplace(&mut out[r * n..(r + 1) * n], inverse);
    }
    // columns (gather/scatter through a scratch row)
    let mut col = vec![Complex::ZERO; n];
    for c in 0..n {
        for r in 0..n {
            col[r] = out[r * n + c];
        }
        fft_inplace(&mut col, inverse);
        for r in 0..n {
            out[r * n + c] = col[r];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;
    use crate::util::rng::Pcg32;

    fn randc(rng: &mut Pcg32, n: usize) -> Vec<Complex> {
        (0..n).map(|_| Complex::new(rng.normal(), rng.normal())).collect()
    }

    #[test]
    fn fft_of_impulse_is_flat() {
        let mut x = vec![Complex::ZERO; 8];
        x[0] = Complex::new(1.0, 0.0);
        for v in fft1d(&x) {
            assert!((v.re - 1.0).abs() < 1e-6 && v.im.abs() < 1e-6);
        }
    }

    #[test]
    fn fft_known_values() {
        // fft([1,2,3,4]) = [10, -2+2i, -2, -2-2i]
        let x: Vec<Complex> = [1.0, 2.0, 3.0, 4.0]
            .iter()
            .map(|&r| Complex::new(r, 0.0))
            .collect();
        let y = fft1d(&x);
        let want = [(10.0, 0.0), (-2.0, 2.0), (-2.0, 0.0), (-2.0, -2.0)];
        for (got, &(re, im)) in y.iter().zip(&want) {
            assert!((got.re - re).abs() < 1e-5, "{got:?} vs {re}");
            assert!((got.im - im).abs() < 1e-5, "{got:?} vs {im}");
        }
    }

    #[test]
    fn roundtrip_1d_2d() {
        forall("fft roundtrip", 50, |rng| {
            let n = 1 << rng.range(0, 6); // 1..32
            let x = randc(rng, n);
            let y = ifft1d(&fft1d(&x));
            for (a, b) in x.iter().zip(&y) {
                assert!((a.re - b.re).abs() < 1e-4 && (a.im - b.im).abs() < 1e-4);
            }
            let n2 = 1 << rng.range(1, 5); // 2..16
            let p = randc(rng, n2 * n2);
            let q = ifft2d(&fft2d(&p, n2), n2);
            for (a, b) in p.iter().zip(&q) {
                assert!((a.re - b.re).abs() < 1e-3 && (a.im - b.im).abs() < 1e-3);
            }
        });
    }

    #[test]
    fn parseval_energy_preserved() {
        forall("parseval", 30, |rng| {
            let n = 16;
            let x = randc(rng, n);
            let y = fft1d(&x);
            let ex: f32 = x.iter().map(|c| c.abs() * c.abs()).sum();
            let ey: f32 = y.iter().map(|c| c.abs() * c.abs()).sum::<f32>() / n as f32;
            assert!((ex - ey).abs() < 1e-2 * ex.max(1.0), "{ex} vs {ey}");
        });
    }

    #[test]
    fn linearity() {
        forall("fft linearity", 30, |rng| {
            let n = 8;
            let x = randc(rng, n);
            let y = randc(rng, n);
            let sum: Vec<Complex> = x.iter().zip(&y).map(|(a, b)| a.add(*b)).collect();
            let fs = fft1d(&sum);
            let fx = fft1d(&x);
            let fy = fft1d(&y);
            for i in 0..n {
                let e = fx[i].add(fy[i]);
                assert!((fs[i].re - e.re).abs() < 1e-3 && (fs[i].im - e.im).abs() < 1e-3);
            }
        });
    }

    #[test]
    fn convolution_theorem_circular() {
        // ifft(fft(x) ∘ fft(h)) = circular convolution of x and h
        let mut rng = Pcg32::new(77);
        let n = 8;
        let x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let h: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let mut want = vec![0.0f32; n];
        for i in 0..n {
            for j in 0..n {
                want[(i + j) % n] += x[i] * h[j];
            }
        }
        let xc: Vec<Complex> = x.iter().map(|&r| Complex::new(r, 0.0)).collect();
        let hc: Vec<Complex> = h.iter().map(|&r| Complex::new(r, 0.0)).collect();
        let prod: Vec<Complex> = fft1d(&xc)
            .iter()
            .zip(fft1d(&hc))
            .map(|(a, b)| a.mul(b))
            .collect();
        let got = ifft1d(&prod);
        for i in 0..n {
            assert!((got[i].re - want[i]).abs() < 1e-4, "{} vs {}", got[i].re, want[i]);
            assert!(got[i].im.abs() < 1e-4);
        }
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn non_pow2_rejected() {
        fft1d(&[Complex::ZERO; 6]);
    }
}
