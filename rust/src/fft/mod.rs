//! Spectral math substrate: radix-2 FFT, OaA tiling geometry.
//!
//! Mirrors `python/compile/kernels/ref.py` exactly (same conventions,
//! documented there); integration tests assert the Rust pipeline and the
//! AOT'd executables agree through this geometry.

mod core;
mod tiling;

pub use self::core::{
    fft1d, fft2d, fft2d_inplace, half_plane_len, ifft1d, ifft2d, ifft2d_inplace, irfft2d,
    irfft2d_into, rfft2d, rfft2d_into, Complex, Cx, Float,
};
pub use tiling::{im2tiles, overlap_add, spectral_kernels, tiles_per_side, TileGeometry};
