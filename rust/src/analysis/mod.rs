//! Complexity analysis of sparse spectral convolutional layers (paper §4 +
//! §5.2): on-chip BRAM requirements (Eqs. 6–8, 12) and off-chip
//! communication (Eqs. 9–11, 13) for each dataflow.
//!
//! Conventions (paper §4):
//! * `M` input channels, `N` output channels, spatial side `h_in = w_in`,
//!   tile side `h' = w'`, FFT window `K`, compression ratio `α`
//!   (each K×K kernel keeps K²/α non-zeros).
//! * Architecture parallelism: `P'` tiles, `N'` kernels, `M' = 1` input
//!   channels (serial channels avoid write conflicts, §5.1), `r` input-tile
//!   replicas for sparse-access scheduling.
//! * A BRAM holds 1024 words (36 Kb at 16+2-bit words — paper's constant).
//! * Bandwidth = data-transfer volume / layer latency τ; we expose volumes
//!   (τ-independent, Fig. 2/7's metric) and divide by τ for Tables 2/3.
//!
//! Where the printed formulas and the prose disagree we implement the
//! formulas as printed and note it inline — reproducing the paper includes
//! reproducing its model.

use crate::model::ConvLayer;

/// BRAM word depth (paper: "1024 indicates memory depth for single BRAM").
pub const BRAM_DEPTH: usize = 1024;

/// The three fixed dataflows of §4 plus the flexible flow of §5.2.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Flow {
    /// Reuse kernels + partial sums; stream input tiles (Eq. 6 / 9).
    ReuseKernels,
    /// Reuse input tiles + partial sums; stream kernels (Eq. 7 / 10).
    ReuseInputs,
    /// Reuse input tiles + kernels; stream partial sums (Eq. 8 / 11).
    StreamPsums,
}

impl Flow {
    pub const ALL: [Flow; 3] = [Flow::ReuseKernels, Flow::ReuseInputs, Flow::StreamPsums];

    pub fn label(&self) -> &'static str {
        match self {
            Flow::ReuseKernels => "Flow #1 (stream inputs)",
            Flow::ReuseInputs => "Flow #2 (stream kernels)",
            Flow::StreamPsums => "Flow #3 (stream psums)",
        }
    }
}

/// Architecture parameters (P', N', M'=1, r) shared by all layers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ArchParams {
    /// Parallel input tiles P'.
    pub p_par: usize,
    /// Parallel kernels N'.
    pub n_par: usize,
    /// Input-tile replicas r (sparse-access scheduling, §5.3).
    pub replicas: usize,
}

impl ArchParams {
    /// The paper's implemented configuration (§6.3).
    pub fn paper() -> Self {
        ArchParams { p_par: 9, n_par: 64, replicas: 10 }
    }
}

/// Per-layer quantities in paper notation, extracted from a [`ConvLayer`].
#[derive(Debug, Clone, Copy)]
pub struct LayerParams {
    pub m: usize,      // input channels
    pub n: usize,      // output channels
    pub h_in: usize,   // spatial side
    pub tile: usize,   // h' = w'
    pub k2: usize,     // K²
    pub p: usize,      // total tiles per image
    pub alpha: usize,  // compression ratio
}

impl LayerParams {
    pub fn from_layer(layer: &ConvLayer, alpha: usize) -> Self {
        let geo = layer.geometry();
        LayerParams {
            m: layer.cin,
            n: layer.cout,
            h_in: layer.h,
            tile: geo.tile,
            k2: layer.fft * layer.fft,
            p: geo.num_tiles(),
            alpha,
        }
    }

    /// Sparse kernel words for the whole layer: (1/α)·N·M·K².
    pub fn sparse_kernel_words(&self) -> u64 {
        (self.n as u64 * self.m as u64 * self.k2 as u64) / self.alpha as u64
    }

    /// Input activation words: M·h_in·w_in.
    pub fn input_words(&self) -> u64 {
        self.m as u64 * (self.h_in * self.h_in) as u64
    }

    /// Output activation words: N·h_out·w_out (same-conv ⇒ h_out = h_in).
    pub fn output_words(&self) -> u64 {
        self.n as u64 * (self.h_in * self.h_in) as u64
    }

    /// Tile area in spatial words: h'·w'.
    fn tile_words(&self) -> u64 {
        (self.tile * self.tile) as u64
    }
}

fn ceil_div(a: u64, b: u64) -> u64 {
    a.div_ceil(b)
}

// ---------------------------------------------------------------------------
// On-chip storage: Eqs. 6–8 (fixed flows) and Eq. 12 (flexible flow)
// ---------------------------------------------------------------------------

/// Eq. 6 — Flow #1 (reuse kernels + psums, stream input tiles).
///
/// `n = r·M'·P' + M'·N' + N'·P'·⌈h_in·w_in·K² / (P'·h'·w'·1024)⌉`, M' = 1.
/// The psum term keeps *all* output tiles of the image on chip.
pub fn bram_flow1(l: &LayerParams, a: &ArchParams) -> u64 {
    let inputs = (a.replicas * a.p_par) as u64;
    let kernels = a.n_par as u64;
    let depth = ceil_div(
        (self_hw(l) * l.k2 as u64) as u64,
        a.p_par as u64 * l.tile_words() * BRAM_DEPTH as u64,
    );
    let psums = (a.n_par * a.p_par) as u64 * depth;
    inputs + kernels + psums
}

fn self_hw(l: &LayerParams) -> u64 {
    (l.h_in * l.h_in) as u64
}

/// Eq. 7 — Flow #2 (reuse input tiles + psums, stream kernels).
///
/// `n = r·M'·P' + M'·N' + M'·P'·⌈N·K² / (N'·1024)⌉`, M' = 1.
pub fn bram_flow2(l: &LayerParams, a: &ArchParams) -> u64 {
    let inputs = (a.replicas * a.p_par) as u64;
    let kernels = a.n_par as u64;
    let depth = ceil_div(l.n as u64 * l.k2 as u64, a.n_par as u64 * BRAM_DEPTH as u64);
    let psums = a.p_par as u64 * depth;
    inputs + kernels + psums
}

/// Eq. 8 — Flow #3 (reuse inputs + kernels, stream psums): the min of the
/// two printed options (deep input buffer vs deep kernel buffer).
pub fn bram_flow3(l: &LayerParams, a: &ArchParams) -> u64 {
    let psums = a.p_par as u64;
    // option A: all input tiles resident
    let in_depth = ceil_div(
        self_hw(l) * l.k2 as u64,
        a.p_par as u64 * l.tile_words() * BRAM_DEPTH as u64,
    );
    let opt_a = (a.replicas * a.p_par) as u64 * in_depth + a.n_par as u64 + psums;
    // option B: all (sparse) kernels resident
    let k_depth = ceil_div(
        (l.n as u64 * l.k2 as u64) / l.alpha as u64,
        a.n_par as u64 * BRAM_DEPTH as u64,
    );
    let opt_b = (a.replicas * a.p_par) as u64 + a.n_par as u64 * k_depth + psums;
    opt_a.min(opt_b)
}

pub fn bram_flow(flow: Flow, l: &LayerParams, a: &ArchParams) -> u64 {
    match flow {
        Flow::ReuseKernels => bram_flow1(l, a),
        Flow::ReuseInputs => bram_flow2(l, a),
        Flow::StreamPsums => bram_flow3(l, a),
    }
}

/// Streaming parameters of the flexible flow (§5.2): process `ns` kernels
/// before flushing input tiles, `ps` input tiles before flushing kernels.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StreamParams {
    pub ns: usize,
    pub ps: usize,
}

/// Eq. 12 — flexible flow BRAMs.
///
/// `n = r·P' + N'·⌈(1/α)·Ns·K² / (N'·1024)⌉ + N'·P'·⌈Ns·Ps·K² / (N'·P'·1024)⌉`
pub fn bram_flex(l: &LayerParams, a: &ArchParams, s: &StreamParams) -> u64 {
    let inputs = (a.replicas * a.p_par) as u64;
    let k_depth = ceil_div(
        (s.ns as u64 * l.k2 as u64) / l.alpha as u64,
        a.n_par as u64 * BRAM_DEPTH as u64,
    );
    let kernels = a.n_par as u64 * k_depth;
    let ps_depth = ceil_div(
        s.ns as u64 * s.ps as u64 * l.k2 as u64,
        (a.n_par * a.p_par) as u64 * BRAM_DEPTH as u64,
    );
    let psums = (a.n_par * a.p_par) as u64 * ps_depth;
    inputs + kernels + psums
}

// ---------------------------------------------------------------------------
// Off-chip communication: data-transfer volumes (Eq. 9–11, 13 numerators)
// ---------------------------------------------------------------------------

/// Transfer volume (in words) decomposed as the paper's three terms.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Transfers {
    pub inputs: u64,
    pub kernels: u64,
    pub outputs: u64,
}

impl Transfers {
    pub fn total(&self) -> u64 {
        self.inputs + self.kernels + self.outputs
    }

    /// Bandwidth in bytes/s for a layer latency of `tau` seconds.
    pub fn bandwidth(&self, tau: f64, word_bytes: u64) -> f64 {
        (self.total() * word_bytes) as f64 / tau
    }
}

/// Eq. 9 — Flow #1: inputs re-loaded once per kernel group (N/N' times).
pub fn transfers_flow1(l: &LayerParams, a: &ArchParams) -> Transfers {
    Transfers {
        inputs: l.input_words() * ceil_div(l.n as u64, a.n_par as u64),
        kernels: l.sparse_kernel_words(),
        outputs: l.output_words(),
    }
}

/// Eq. 10 — Flow #2: kernels re-loaded once per tile group
/// (`h_in·w_in / (P'·h'·w')` times; we count in whole tiles, ⌈P/P'⌉, which
/// agrees exactly when h' | h_in and stays consistent with the simulator's
/// FSM accounting on padded edge tiles).
pub fn transfers_flow2(l: &LayerParams, a: &ArchParams) -> Transfers {
    let reloads = ceil_div(l.p as u64, a.p_par as u64);
    Transfers {
        inputs: l.input_words(),
        kernels: l.sparse_kernel_words() * reloads,
        outputs: l.output_words(),
    }
}

/// Eq. 11 — Flow #3: psums written+re-read once per input channel
/// (2·M/M', M'=1).
pub fn transfers_flow3(l: &LayerParams, _a: &ArchParams) -> Transfers {
    Transfers {
        inputs: l.input_words(),
        kernels: l.sparse_kernel_words(),
        outputs: l.output_words() * 2 * l.m as u64,
    }
}

pub fn transfers_flow(flow: Flow, l: &LayerParams, a: &ArchParams) -> Transfers {
    match flow {
        Flow::ReuseKernels => transfers_flow1(l, a),
        Flow::ReuseInputs => transfers_flow2(l, a),
        Flow::StreamPsums => transfers_flow3(l, a),
    }
}

/// Eq. 13 — flexible flow: inputs re-loaded N/Ns times, kernels re-loaded
/// `h_in·w_in / (Ps·h'·w')` times (counted in whole tiles, ⌈P/Ps⌉ — see
/// [`transfers_flow2`]), outputs written once.
pub fn transfers_flex(l: &LayerParams, s: &StreamParams) -> Transfers {
    transfers_flex_batch(l, s, 1)
}

/// Eq. 13 extended with the batch axis: a batch of `B` images makes the
/// tile population `B·P` while the kernel store stays a single copy, so
/// kernels are re-loaded `⌈B·P / Ps⌉` times (instead of `B·⌈P/Ps⌉` for B
/// independent forwards) and the input/output activation traffic scales
/// linearly with B. With `Ps ≥ B·P` every sparse kernel row streams from
/// memory exactly **once per batch** — the batch dimension acting as the
/// third reuse axis next to the paper's Ns/Ps choice.
pub fn transfers_flex_batch(l: &LayerParams, s: &StreamParams, batch: usize) -> Transfers {
    let b = batch.max(1) as u64;
    let in_reloads = ceil_div(l.n as u64, s.ns as u64);
    let k_reloads = ceil_div(b * l.p as u64, s.ps as u64);
    Transfers {
        inputs: b * l.input_words() * in_reloads,
        kernels: l.sparse_kernel_words() * k_reloads,
        outputs: b * l.output_words(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Network;
    use crate::util::check::forall;
    use crate::util::rng::Pcg32;

    fn conv5(alpha: usize) -> LayerParams {
        let net = Network::vgg16_224();
        LayerParams::from_layer(&net.convs[12], alpha)
    }

    fn conv1_2(alpha: usize) -> LayerParams {
        let net = Network::vgg16_224();
        LayerParams::from_layer(&net.convs[1], alpha)
    }

    #[test]
    fn paper_fig2_shape_flow1_bram_heavy_early() {
        // Fig 2 right: streaming-kernels (Flow #2) needs few BRAMs; Flow #1
        // explodes on early layers (all psum tiles resident).
        let a = ArchParams::paper();
        let l = conv1_2(4);
        assert!(
            bram_flow1(&l, &a) > 4 * bram_flow2(&l, &a),
            "flow1 {} vs flow2 {}",
            bram_flow1(&l, &a),
            bram_flow2(&l, &a)
        );
    }

    #[test]
    fn paper_fig2_shape_flow3_transfer_heavy() {
        // Fig 2 left: streaming psums transfers by far the most data (the
        // "no advantages at all" flow).
        let a = ArchParams::paper();
        for l in [conv1_2(4), conv5(4)] {
            let t3 = transfers_flow3(&l, &a).total();
            let t1 = transfers_flow1(&l, &a).total();
            let t2 = transfers_flow2(&l, &a).total();
            assert!(t3 > t1 && t3 > t2, "t1 {t1} t2 {t2} t3 {t3}");
        }
    }

    #[test]
    fn flex_with_extreme_params_matches_fixed_flows() {
        // Ns = N and Ps = P ⇒ nothing is ever flushed: transfers collapse to
        // the one-pass volumes (inputs + kernels + outputs, each once).
        let l = conv5(4);
        let s = StreamParams { ns: l.n, ps: l.p };
        let t = transfers_flex(&l, &s);
        assert_eq!(t.inputs, l.input_words());
        assert_eq!(t.kernels, l.sparse_kernel_words());
        assert_eq!(t.outputs, l.output_words());
    }

    #[test]
    fn batch_one_is_the_plain_flex_model() {
        let l = conv5(4);
        let s = StreamParams { ns: 256, ps: 9 };
        assert_eq!(transfers_flex_batch(&l, &s, 1), transfers_flex(&l, &s));
        // batch=0 is clamped to 1 (degenerate but defined)
        assert_eq!(transfers_flex_batch(&l, &s, 0), transfers_flex(&l, &s));
    }

    #[test]
    fn batching_amortizes_kernel_streams() {
        // The B-axis claim: with all B·P tiles resident, a batch of B
        // forwards streams the kernel store once, not B times — kernel
        // traffic drops by exactly B× vs B independent forwards while the
        // activation traffic stays linear in B.
        let l = conv5(4);
        let b = 8usize;
        let resident = StreamParams { ns: l.n, ps: b * l.p };
        let batched = transfers_flex_batch(&l, &resident, b);
        let serial = transfers_flex(&l, &StreamParams { ns: l.n, ps: l.p });
        assert_eq!(batched.kernels, serial.kernels, "one kernel stream per batch");
        assert_eq!(batched.inputs, b as u64 * serial.inputs);
        assert_eq!(batched.outputs, b as u64 * serial.outputs);
        // and with only P tiles resident the batch re-streams kernels B×
        let tight = transfers_flex_batch(&l, &StreamParams { ns: l.n, ps: l.p }, b);
        assert_eq!(tight.kernels, b as u64 * serial.kernels);
    }

    #[test]
    fn batch_transfers_monotone_in_ps() {
        forall("batch flex monotone", 50, |rng| {
            let l = conv5(4);
            let b = rng.range(1, 9);
            let ps1 = rng.range(1, b * l.p);
            let ps2 = rng.range(ps1, b * l.p + 1);
            let t1 = transfers_flex_batch(&l, &StreamParams { ns: l.n, ps: ps1 }, b);
            let t2 = transfers_flex_batch(&l, &StreamParams { ns: l.n, ps: ps2 }, b);
            assert!(t2.total() <= t1.total());
        });
    }

    #[test]
    fn flex_monotone_in_streaming_params() {
        // Larger Ns / Ps can only reduce (or keep) transfer volume.
        forall("flex monotone", 50, |rng| {
            let l = conv5([2, 4, 8][rng.range(0, 3)]);
            let ns1 = rng.range(1, l.n);
            let ns2 = rng.range(ns1, l.n + 1);
            let ps1 = rng.range(1, l.p);
            let ps2 = rng.range(ps1, l.p + 1);
            let t1 = transfers_flex(&l, &StreamParams { ns: ns1, ps: ps1 });
            let t2 = transfers_flex(&l, &StreamParams { ns: ns2, ps: ps2 });
            assert!(t2.total() <= t1.total());
        });
    }

    #[test]
    fn flex_bram_monotone() {
        forall("flex bram monotone", 50, |rng| {
            let l = conv5(4);
            let a = ArchParams::paper();
            let ns = rng.range(1, l.n);
            let ps = rng.range(1, l.p);
            let b1 = bram_flex(&l, &a, &StreamParams { ns, ps });
            let b2 = bram_flex(&l, &a, &StreamParams { ns: ns + 1, ps });
            let b3 = bram_flex(&l, &a, &StreamParams { ns, ps: ps + 1 });
            assert!(b2 >= b1 && b3 >= b1);
        });
    }

    #[test]
    fn alpha_scales_kernel_transfers() {
        let a = ArchParams::paper();
        let t4 = transfers_flow1(&conv5(4), &a);
        let t8 = transfers_flow1(&conv5(8), &a);
        assert_eq!(t4.kernels, 2 * t8.kernels);
        assert_eq!(t4.inputs, t8.inputs);
    }

    #[test]
    fn bandwidth_units() {
        // 1e6 words at 2 B/word over 1 ms = 2 GB/s.
        let t = Transfers { inputs: 1_000_000, kernels: 0, outputs: 0 };
        let bw = t.bandwidth(1e-3, 2);
        assert!((bw - 2e9).abs() < 1.0);
    }

    #[test]
    fn paper_kernel_words_conv5() {
        // conv5_*: 512·512·64/4 = 4,194,304 sparse kernel words at α=4.
        assert_eq!(conv5(4).sparse_kernel_words(), 4_194_304);
    }

    #[test]
    fn flow3_min_of_two_options() {
        // For a kernel-heavy layer (conv5: 512x512) option A (inputs
        // resident) wins; verify flow3 ≤ both raw options by construction.
        let a = ArchParams::paper();
        let l = conv5(4);
        let b = bram_flow3(&l, &a);
        assert!(b <= bram_flow1(&l, &a).max(bram_flow2(&l, &a)) * 2);
        // and it is strictly smaller than keeping psums resident at conv1_2
        assert!(bram_flow3(&conv1_2(4), &a) < bram_flow1(&conv1_2(4), &a));
    }

    #[test]
    fn deterministic_layer_params() {
        let _ = Pcg32::new(0); // silence unused-import lint paths
        let l = conv1_2(4);
        assert_eq!(l.p, 1444);
        assert_eq!(l.m, 64);
        assert_eq!(l.n, 64);
        assert_eq!(l.k2, 64);
        assert_eq!(l.tile, 6);
    }
}
