//! PE-array execution of compiled INDEX/VALUE tables (paper Figs. 4 & 6).
//!
//! Executes one kernel group's `AccessTables` against `P'` input tiles held
//! in [`ReplicaBank`]s, accumulating complex partial sums exactly as the
//! N'×P' PE array would: in each cycle every valid lane reads its input
//! through the replica ports (routed by `sel`), multiplies by its kernel
//! weight and accumulates at the output index. This is the *numerics*
//! ground-truth of the simulator — tests check it against the dense
//! Hadamard reference, proving the scheduler + table compiler preserve the
//! computation while the cycle counts prove legality.

use super::bram::ReplicaBank;
use crate::schedule::tables::AccessTables;

/// Result of executing one kernel group over one batch of tiles.
#[derive(Debug, Clone)]
pub struct ExecResult {
    /// Partial sums per (tile, lane): flattened `[tiles][lanes][k2]` (re, im).
    pub psums: Vec<Vec<Vec<(f32, f32)>>>,
    /// Clock cycles consumed (table depth).
    pub cycles: u64,
    /// Total MAC operations performed.
    pub macs: u64,
    /// Replica-port conflicts observed (0 for a legal schedule).
    pub conflicts: u64,
}

/// Execute `tables` against `tiles` (each a K² vector of complex values,
/// pre-FFT'd input at one channel). Each tile gets `replicas` BRAM copies.
pub fn execute_tables(
    tables: &AccessTables,
    tiles: &[Vec<(f32, f32)>],
    replicas: usize,
    k2: usize,
) -> ExecResult {
    let lanes = tables.num_lanes;
    let mut banks: Vec<ReplicaBank> = tiles
        .iter()
        .map(|t| {
            assert_eq!(t.len(), k2, "tile must hold K² spectral values");
            ReplicaBank::new(replicas, t.clone())
        })
        .collect();
    let mut psums = vec![vec![vec![(0.0f32, 0.0f32); k2]; lanes]; tiles.len()];
    let mut macs = 0u64;
    for c in 0..tables.cycles() {
        for bank in banks.iter_mut() {
            bank.begin_cycle();
        }
        for (lane, slot) in tables.value[c].iter().enumerate() {
            if !slot.valid {
                continue;
            }
            // The same (index, weight) is broadcast to all P' tile lanes
            // (paper: "s_i can be broadcast to all P' input tiles").
            for (t, bank) in banks.iter_mut().enumerate() {
                if let Some((xr, xi)) = bank.read(slot.index) {
                    let (wr, wi) = slot.weight;
                    let p = &mut psums[t][lane][slot.index as usize];
                    p.0 += xr * wr - xi * wi;
                    p.1 += xr * wi + xi * wr;
                    macs += 1;
                }
            }
        }
    }
    let conflicts = banks.iter().map(|b| b.conflicts()).sum();
    ExecResult { psums, cycles: tables.cycles() as u64, macs, conflicts }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::tables::compile_tables;
    use crate::schedule::{schedule_exact_cover, Scheduler};
    use crate::sparse::{prune_random, SparseLayer};
    use crate::util::rng::Pcg32;

    fn random_tiles(rng: &mut Pcg32, p: usize, k2: usize) -> Vec<Vec<(f32, f32)>> {
        (0..p)
            .map(|_| (0..k2).map(|_| (rng.normal(), rng.normal())).collect())
            .collect()
    }

    /// Dense reference: psum[lane][i] = x[i] * w[lane][i] for the kernel's
    /// non-zeros at one input channel.
    fn dense_ref(
        layer: &SparseLayer,
        m: usize,
        tile: &[(f32, f32)],
        lanes: usize,
    ) -> Vec<Vec<(f32, f32)>> {
        let k2 = layer.k2();
        let mut out = vec![vec![(0.0f32, 0.0f32); k2]; lanes];
        for (lane, row) in out.iter_mut().enumerate() {
            let kern = layer.kernel(lane, m);
            for (&idx, &(wr, wi)) in kern.indices.iter().zip(&kern.values) {
                let (xr, xi) = tile[idx as usize];
                row[idx as usize] =
                    (xr * wr - xi * wi, xr * wi + xi * wr);
            }
        }
        out
    }

    #[test]
    fn legal_schedule_has_no_conflicts_and_right_numbers() {
        let mut rng = Pcg32::new(31);
        let lanes = 16;
        let layer = prune_random(lanes, 2, 8, 4, &mut rng);
        let kernels = layer.group_indices(0, lanes, 0);
        let sched = schedule_exact_cover(&kernels, 6);
        let tables = compile_tables(&sched, &layer, 0, 0, lanes);
        let tiles = random_tiles(&mut rng, 3, 64);
        let res = execute_tables(&tables, &tiles, 6, 64);
        assert_eq!(res.conflicts, 0, "exact-cover schedule must be conflict-free");
        assert_eq!(res.cycles, sched.cycles() as u64);
        // every non-zero did one MAC per tile
        assert_eq!(res.macs, layer.group_indices(0, lanes, 0).iter().map(|k| k.len() as u64).sum::<u64>() * 3);
        for (t, tile) in tiles.iter().enumerate() {
            let want = dense_ref(&layer, 0, tile, lanes);
            for lane in 0..lanes {
                for i in 0..64 {
                    let (gr, gi) = res.psums[t][lane][i];
                    let (wr, wi) = want[lane][i];
                    assert!((gr - wr).abs() < 1e-5 && (gi - wi).abs() < 1e-5);
                }
            }
        }
    }

    #[test]
    fn all_schedulers_produce_identical_numerics() {
        // Scheduling reorders reads but never changes values.
        let mut rng = Pcg32::new(32);
        let lanes = 32;
        let layer = prune_random(lanes, 1, 8, 4, &mut rng);
        let kernels = layer.group_indices(0, lanes, 0);
        let tiles = random_tiles(&mut rng, 2, 64);
        let mut outs = Vec::new();
        for s in Scheduler::ALL {
            let sched = s.run(&kernels, 8, 5);
            let tables = compile_tables(&sched, &layer, 0, 0, lanes);
            let res = execute_tables(&tables, &tiles, 8, 64);
            assert_eq!(res.conflicts, 0, "{s:?}");
            outs.push(res.psums);
        }
        for other in &outs[1..] {
            for (a, b) in outs[0].iter().zip(other) {
                for (la, lb) in a.iter().zip(b) {
                    for ((ar, ai), (br, bi)) in la.iter().zip(lb) {
                        assert!((ar - br).abs() < 1e-5 && (ai - bi).abs() < 1e-5);
                    }
                }
            }
        }
    }

    #[test]
    fn under_provisioned_replicas_starve() {
        // Build a legal schedule for r=8 but execute with r=2 replicas:
        // conflicts must appear (hardware would stall / compute wrong).
        let mut rng = Pcg32::new(33);
        let lanes = 16;
        let layer = prune_random(lanes, 1, 8, 4, &mut rng);
        let kernels = layer.group_indices(0, lanes, 0);
        let sched = schedule_exact_cover(&kernels, 8);
        // only meaningful if some cycle really uses >2 indices
        if sched.sets.iter().all(|s| s.distinct_indices() <= 2) {
            return;
        }
        let tables = compile_tables(&sched, &layer, 0, 0, lanes);
        let tiles = random_tiles(&mut rng, 1, 64);
        let res = execute_tables(&tables, &tiles, 2, 64);
        assert!(res.conflicts > 0);
    }
}
