//! Cycle-level model of the paper's FPGA accelerator — the substitution for
//! the Xilinx Alveo U200 (DESIGN.md "Hardware substitution").
//!
//! The simulator has two granularities:
//!
//! * **Micro** ([`bram`], [`pe`]) — executes compiled INDEX/VALUE tables
//!   (Fig. 6) cycle by cycle against BRAM replica banks with single-port
//!   semantics, verifying the scheduler's output is hardware-legal *and*
//!   computes the right numbers (PE array accumulation is checked against
//!   the dense Hadamard reference in tests).
//! * **Phase** ([`engine`], [`controller`]) — walks the Fig. 3 streaming
//!   FSM over (kernel pass, tile pass, channel) phases, accumulating
//!   Hadamard/FFT/IFFT compute cycles and DDR transfer time with double
//!   buffering (compute/communication overlap), yielding per-layer and
//!   per-network latency — the quantities of Tables 2 and 3.
//!
//! [`resources`] maps an architecture to DSP/BRAM/LUT counts (calibrated
//! against the paper's reported utilization, constants documented there);
//! [`baselines`] configures the comparison rows of Table 3.

pub mod baselines;
pub mod bram;
pub mod controller;
pub mod engine;
pub mod pe;
pub mod resources;

pub use bram::ReplicaBank;
pub use engine::{simulate_layer, simulate_network, LayerSimResult, NetworkSimResult, SimConfig};
pub use pe::execute_tables;
pub use resources::{estimate_resources, Resources};
