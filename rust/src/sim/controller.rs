//! Streaming-controller finite state machine (paper Fig. 3).
//!
//! Loop nest, derived jointly from Fig. 3 and the Eq. 12/13 accounting:
//!
//! ```text
//! for tile-pass  (⌈P/Ps⌉):              # psums for Ps tiles stay resident
//!   for kernel-pass (⌈N/Ns⌉):           #   across the channel loop
//!     for channel m in 0..M:            # M' = 1, serial channels
//!       READ KERNEL   (Ns kernels, channel m — buffer holds one channel:
//!                      Eq. 12's (1/α)·Ns·K² kernel term)
//!       for tile-batch (⌈Ps/P'⌉):
//!         READ INPUT  (P' tiles of channel m) + tile FFT
//!         PROC CONV   (per N'-subgroup of the Ns kernels)
//!     PROC IFFT + WRITE OUT (Ns × Ps output tiles)
//! ```
//!
//! Transfer totals telescope exactly to Eq. 13: kernels are read
//! `⌈P/Ps⌉` times over the layer (`h_in·w_in/(Ps·h'·w')`), inputs
//! `⌈N/Ns⌉` times, outputs once. Fig. 3's two `!Ms` cases map to the
//! channel loop: mid-channel tile batches reuse resident kernels
//! ("kernels are already loaded"); a new channel flushes kernels and tiles.

/// FSM states (names follow Fig. 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum State {
    /// Load Ns kernels' values for the current input channel.
    ReadKernel,
    /// Load the next P' input tiles (and FFT them).
    ReadInput,
    /// Hadamard product + accumulation for one N'-subgroup.
    ProcConv,
    /// IFFT the finished Ns × Ps output tiles.
    ProcIfft,
    /// Write spatial output tiles to DDR.
    WriteOut,
    /// Layer complete.
    Done,
}

/// Layer configuration the controller sequences over.
#[derive(Debug, Clone, Copy)]
pub struct LoopConfig {
    /// Total kernels N.
    pub n: usize,
    /// Total tiles P.
    pub p: usize,
    /// Total input channels M (processed serially, M' = 1).
    pub m: usize,
    /// Streaming parameters.
    pub ns: usize,
    pub ps: usize,
    /// Parallelism.
    pub p_par: usize,
    pub n_par: usize,
}

/// One emitted phase with enough context to charge cycles against it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Phase {
    pub state: State,
    /// Global kernel-group index (kpass · ⌈Ns/N'⌉ + subgroup) for ProcConv.
    pub kernel_group: usize,
    /// Input channel (ReadKernel / ReadInput / ProcConv).
    pub channel: usize,
    /// Tiles covered by this phase.
    pub tiles: usize,
    /// Kernels covered by this phase.
    pub kernels: usize,
}

/// The streaming controller.
#[derive(Debug, Clone)]
pub struct Controller {
    cfg: LoopConfig,
    state: State,
    tpass: usize,
    kpass: usize,
    chan: usize,
    batch: usize,
    sub: usize,
    started: bool,
}

impl Controller {
    pub fn new(cfg: LoopConfig) -> Self {
        assert!(cfg.ns >= 1 && cfg.ps >= 1 && cfg.m >= 1 && cfg.n >= 1 && cfg.p >= 1);
        assert!(cfg.n_par >= 1 && cfg.p_par >= 1);
        Controller { cfg, state: State::ReadKernel, tpass: 0, kpass: 0, chan: 0, batch: 0, sub: 0, started: false }
    }

    fn ns_eff(&self) -> usize {
        (self.cfg.n - self.kpass * self.cfg.ns).min(self.cfg.ns)
    }

    fn ps_eff(&self) -> usize {
        (self.cfg.p - self.tpass * self.cfg.ps).min(self.cfg.ps)
    }

    fn subgroups(&self) -> usize {
        self.ns_eff().div_ceil(self.cfg.n_par)
    }

    fn batches(&self) -> usize {
        self.ps_eff().div_ceil(self.cfg.p_par)
    }

    fn kernels_in_sub(&self) -> usize {
        (self.ns_eff() - self.sub * self.cfg.n_par).min(self.cfg.n_par)
    }

    fn tiles_in_batch(&self) -> usize {
        (self.ps_eff() - self.batch * self.cfg.p_par).min(self.cfg.p_par)
    }

    /// Advance the FSM and return the next phase, or `None` when Done.
    pub fn next_phase(&mut self) -> Option<Phase> {
        if self.state == State::Done {
            return None;
        }
        if !self.started {
            self.started = true;
            return Some(self.emit(State::ReadKernel));
        }
        let phase = match self.state {
            State::ReadKernel => self.emit(State::ReadInput),
            State::ReadInput => self.emit(State::ProcConv),
            State::ProcConv => {
                if self.sub + 1 < self.subgroups() {
                    self.sub += 1;
                    self.emit(State::ProcConv)
                } else if self.batch + 1 < self.batches() {
                    // mid-channel: new tiles, kernels already loaded (Fig 3)
                    self.sub = 0;
                    self.batch += 1;
                    self.emit(State::ReadInput)
                } else if self.chan + 1 < self.cfg.m {
                    // new channel: flush kernels and tiles, reload both
                    self.sub = 0;
                    self.batch = 0;
                    self.chan += 1;
                    self.emit(State::ReadKernel)
                } else {
                    self.emit(State::ProcIfft)
                }
            }
            State::ProcIfft => self.emit(State::WriteOut),
            State::WriteOut => {
                self.sub = 0;
                self.batch = 0;
                self.chan = 0;
                if (self.kpass + 1) * self.cfg.ns < self.cfg.n {
                    // next kernel group against the same resident tile pass
                    self.kpass += 1;
                    self.emit(State::ReadKernel)
                } else if (self.tpass + 1) * self.cfg.ps < self.cfg.p {
                    self.tpass += 1;
                    self.kpass = 0;
                    self.emit(State::ReadKernel)
                } else {
                    self.state = State::Done;
                    return None;
                }
            }
            State::Done => return None,
        };
        Some(phase)
    }

    fn emit(&mut self, s: State) -> Phase {
        self.state = s;
        Phase {
            state: s,
            kernel_group: self.kpass * self.cfg.ns.div_ceil(self.cfg.n_par) + self.sub,
            channel: self.chan,
            tiles: match s {
                State::ReadKernel => 0,
                State::ProcIfft | State::WriteOut => self.ps_eff(),
                _ => self.tiles_in_batch(),
            },
            kernels: match s {
                State::ReadInput => 0,
                State::ProcConv => self.kernels_in_sub(),
                _ => self.ns_eff(),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(cfg: LoopConfig) -> Vec<Phase> {
        let mut c = Controller::new(cfg);
        let mut out = Vec::new();
        while let Some(p) = c.next_phase() {
            out.push(p);
            assert!(out.len() < 1_000_000, "FSM must terminate");
        }
        out
    }

    fn count(phases: &[Phase], s: State) -> usize {
        phases.iter().filter(|p| p.state == s).count()
    }

    #[test]
    fn minimal_layer_sequence() {
        let phases = run(LoopConfig { n: 4, p: 2, m: 1, ns: 4, ps: 2, p_par: 2, n_par: 4 });
        let states: Vec<State> = phases.iter().map(|p| p.state).collect();
        assert_eq!(
            states,
            vec![State::ReadKernel, State::ReadInput, State::ProcConv, State::ProcIfft, State::WriteOut]
        );
    }

    #[test]
    fn channel_loop_reloads_kernels_per_channel() {
        // Eq 12: the kernel buffer holds one channel of Ns kernels, so
        // every channel re-reads kernels.
        let phases = run(LoopConfig { n: 4, p: 2, m: 3, ns: 4, ps: 2, p_par: 2, n_par: 4 });
        assert_eq!(count(&phases, State::ReadKernel), 3);
        assert_eq!(count(&phases, State::ReadInput), 3);
        let convs: Vec<usize> = phases
            .iter()
            .filter(|p| p.state == State::ProcConv)
            .map(|p| p.channel)
            .collect();
        assert_eq!(convs, vec![0, 1, 2]);
    }

    #[test]
    fn transfer_totals_telescope_to_eq13() {
        // Kernel words loaded = ⌈P/Ps⌉ · N · M · nnz; input words = ⌈N/Ns⌉
        // · M · P · tile_area. Verify the phase counts give those factors.
        let cfg = LoopConfig { n: 8, p: 6, m: 2, ns: 4, ps: 3, p_par: 3, n_par: 4 };
        let phases = run(cfg);
        // kernel reads: tpasses(2) × kpasses(2) × channels(2) = 8 phases,
        // each ns_eff=4 kernels
        let kernel_reads: usize = phases
            .iter()
            .filter(|p| p.state == State::ReadKernel)
            .map(|p| p.kernels)
            .sum();
        assert_eq!(kernel_reads, 2 * 2 * 2 * 4); // = ⌈P/Ps⌉·⌈N/Ns⌉·M·Ns
        // input tiles read: per (tpass,kpass,chan): ps_eff tiles
        let tile_reads: usize = phases
            .iter()
            .filter(|p| p.state == State::ReadInput)
            .map(|p| p.tiles)
            .sum();
        assert_eq!(tile_reads, 2 * 2 * 2 * 3); // ⌈N/Ns⌉·M·P
        // outputs written once per (tpass, kpass): Ns×Ps tiles... summed
        // over kpasses covers all N; over tpasses covers all P.
        let written: usize = phases
            .iter()
            .filter(|p| p.state == State::WriteOut)
            .map(|p| p.tiles * p.kernels)
            .sum();
        assert_eq!(written, 8 * 6); // N × P output tiles exactly once
    }

    #[test]
    fn kernel_pass_inner_tile_pass_outer() {
        // P=4, Ps=2, N=8, Ns=4: sequence visits both kernel passes before
        // advancing the tile pass.
        let phases = run(LoopConfig { n: 8, p: 4, m: 1, ns: 4, ps: 2, p_par: 2, n_par: 4 });
        assert_eq!(count(&phases, State::WriteOut), 4); // 2 tpass × 2 kpass
        assert_eq!(count(&phases, State::ReadKernel), 4);
    }

    #[test]
    fn subgroup_and_batch_counts() {
        // Ns=8, n_par=4 → 2 subgroups per batch; Ps=4, p_par=2 → 2 batches.
        let phases = run(LoopConfig { n: 8, p: 4, m: 1, ns: 8, ps: 4, p_par: 2, n_par: 4 });
        assert_eq!(count(&phases, State::ProcConv), 4);
    }

    #[test]
    fn ragged_tails_covered() {
        let phases = run(LoopConfig { n: 10, p: 5, m: 2, ns: 4, ps: 2, p_par: 2, n_par: 4 });
        let written: usize = phases
            .iter()
            .filter(|p| p.state == State::WriteOut)
            .map(|p| p.tiles * p.kernels)
            .sum();
        assert_eq!(written, 10 * 5);
        for p in phases.iter().filter(|p| p.state == State::ProcConv) {
            assert!(p.kernels <= 4 && p.kernels >= 1);
            assert!(p.tiles <= 2 && p.tiles >= 1);
        }
    }

    #[test]
    fn kernel_group_ids_are_global_and_dense() {
        let phases = run(LoopConfig { n: 8, p: 2, m: 1, ns: 4, ps: 2, p_par: 2, n_par: 2 });
        let groups: Vec<usize> = phases
            .iter()
            .filter(|p| p.state == State::ProcConv)
            .map(|p| p.kernel_group)
            .collect();
        assert_eq!(groups, vec![0, 1, 2, 3]); // 8 kernels / n_par=2 per pass of 4
    }
}
