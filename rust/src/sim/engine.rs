//! Phase-level accelerator simulation: drives the Fig. 3 FSM and charges
//! cycles/bytes per phase, yielding per-layer and network latency — the
//! quantities behind Tables 2 and 3.
//!
//! Timing model (DESIGN.md "Simulator cycle & resource model"):
//!
//! * **ProcConv** — the scheduler's cycle count for that (kernel subgroup,
//!   channel), i.e. `|S*|`: the exact object Alg. 2 minimizes. One set per
//!   clock, broadcast to the P' tile lanes.
//! * **ReadInput FFT / ProcIfft** — streaming radix-2 2D FFT:
//!   `K²·log2(K)` butterflies per tile, `fft_butterflies_per_cycle` per
//!   engine, `p_par` engines each direction.
//! * **DDR** — phase bytes at `ddr_bytes_per_sec`, converted to cycles.
//! * **Overlap** — double buffering: layer time =
//!   `max(Σ compute, Σ ddr) + pipeline fill` (the paper sizes bandwidth so
//!   layers are compute-bound; Table 2 reports the bandwidth that makes
//!   this max flip).
//!
//! Scheduling fidelity: `sample_groups = None` schedules every (subgroup,
//! channel) instance exactly; `Some(k)` schedules k sampled instances per
//! layer and scales — benches use sampling (conv5 alone has 4096
//! instances), tests use exact mode on small layers.

use super::controller::{Controller, LoopConfig, State};
use crate::analysis::{ArchParams, StreamParams};
use crate::model::ConvLayer;
use crate::schedule::Scheduler;
use crate::sparse::SparseLayer;
use crate::util::rng::Pcg32;

/// Simulator configuration (clock + memory system + fidelity).
#[derive(Debug, Clone, Copy)]
pub struct SimConfig {
    /// FPGA clock (paper: 200 MHz).
    pub clock_hz: f64,
    /// Off-chip bandwidth in bytes/s (paper: 12 GB/s needed; DDR4 ~19.2
    /// peak — default 12.8e9).
    pub ddr_bytes_per_sec: f64,
    /// Word size (paper: 16-bit fixed point).
    pub word_bytes: u64,
    /// Streaming FFT engine throughput (butterflies/cycle/engine).
    pub fft_butterflies_per_cycle: u64,
    /// Scheduling strategy for the Hadamard phases.
    pub scheduler: Scheduler,
    /// `None` = schedule every (subgroup, channel); `Some(k)` = sample k
    /// instances per layer and scale (mean-cycles × instance count).
    pub sample_groups: Option<usize>,
    /// Seed (random scheduler + instance sampling).
    pub seed: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            clock_hz: 200e6,
            ddr_bytes_per_sec: 12.8e9,
            word_bytes: 2,
            fft_butterflies_per_cycle: 8,
            scheduler: Scheduler::ExactCover,
            sample_groups: Some(32),
            seed: 0xF1,
        }
    }
}

/// Per-layer simulation result.
#[derive(Debug, Clone)]
pub struct LayerSimResult {
    pub layer_name: String,
    /// Hadamard (ProcConv) cycles.
    pub hadamard_cycles: u64,
    /// Input-FFT cycles.
    pub fft_cycles: u64,
    /// Output-IFFT cycles.
    pub ifft_cycles: u64,
    /// Total bytes moved to/from DDR.
    pub ddr_bytes: u64,
    /// DDR time expressed in clock cycles.
    pub ddr_cycles: u64,
    /// Pipeline-fill overhead cycles.
    pub fill_cycles: u64,
    /// End-to-end layer cycles (overlap model).
    pub total_cycles: u64,
    /// FLOP-weighted PE utilization over the Hadamard phases (Eq. 14).
    pub pe_utilization: f64,
    /// Scheduling instances evaluated / total.
    pub instances_scheduled: usize,
    pub instances_total: usize,
}

impl LayerSimResult {
    pub fn latency_secs(&self, clock_hz: f64) -> f64 {
        self.total_cycles as f64 / clock_hz
    }

    /// Pipeline-bottleneck compute cycles: the datapath is three streaming
    /// stages (input FFT → Hadamard PE array → output IFFT) with double
    /// buffering between them, so steady-state cycles = the slowest stage,
    /// not the sum.
    pub fn compute_cycles(&self) -> u64 {
        self.hadamard_cycles.max(self.fft_cycles).max(self.ifft_cycles)
    }

    /// Bandwidth (bytes/s) needed for this layer to stay compute-bound
    /// (the Table 2 planning quantity).
    pub fn saturating_bandwidth(&self, clock_hz: f64) -> f64 {
        let compute_secs = self.compute_cycles() as f64 / clock_hz;
        if compute_secs <= 0.0 {
            return 0.0;
        }
        self.ddr_bytes as f64 / compute_secs
    }

    /// Bandwidth actually drawn at the achieved layer latency (the Table 3
    /// "Bandwidth" semantics: what the platform must provide).
    pub fn utilized_bandwidth(&self, clock_hz: f64) -> f64 {
        let secs = self.latency_secs(clock_hz);
        if secs <= 0.0 {
            return 0.0;
        }
        self.ddr_bytes as f64 / secs
    }
}

/// Whole-network simulation result.
#[derive(Debug, Clone)]
pub struct NetworkSimResult {
    pub layers: Vec<LayerSimResult>,
    pub clock_hz: f64,
}

impl NetworkSimResult {
    /// Single-image conv-stack latency (paper Table 3's "Latency").
    pub fn latency_secs(&self) -> f64 {
        self.layers.iter().map(|l| l.latency_secs(self.clock_hz)).sum()
    }

    /// Throughput assuming back-to-back single images (no batching).
    pub fn throughput_fps(&self) -> f64 {
        1.0 / self.latency_secs()
    }

    pub fn total_ddr_bytes(&self) -> u64 {
        self.layers.iter().map(|l| l.ddr_bytes).sum()
    }

    /// Peak per-layer bandwidth drawn (Table 3's "Bandwidth").
    pub fn required_bandwidth(&self) -> f64 {
        self.layers
            .iter()
            .map(|l| l.utilized_bandwidth(self.clock_hz))
            .fold(0.0, f64::max)
    }

    /// MAC-weighted average PE utilization.
    pub fn avg_pe_utilization(&self) -> f64 {
        let num: f64 = self
            .layers
            .iter()
            .map(|l| l.pe_utilization * l.hadamard_cycles as f64)
            .sum();
        let den: f64 = self.layers.iter().map(|l| l.hadamard_cycles as f64).sum();
        if den == 0.0 {
            1.0
        } else {
            num / den
        }
    }
}

/// Cycles to FFT `tiles` K×K tiles with `engines` streaming engines.
fn fft_cycles_for(tiles: u64, k: usize, engines: u64, butterflies_per_cycle: u64) -> u64 {
    // 2D FFT = 2K size-K FFTs = K²·log2(K) butterflies per tile.
    let log2k = (usize::BITS - 1 - k.leading_zeros()) as u64;
    let butterflies = (k * k) as u64 * log2k;
    let per_engine = butterflies.div_ceil(butterflies_per_cycle);
    tiles.div_ceil(engines) * per_engine
}

/// Schedule-cycle oracle: exact per-instance cycles, or sampled mean.
struct ScheduleCycles {
    /// cycles[(group, channel)] when exact; otherwise the sampled mean.
    exact: Option<Vec<Vec<(u32, u32)>>>, // [group][channel] -> (cycles, reads)
    mean_cycles: f64,
    mean_reads: f64,
    instances_scheduled: usize,
}

fn build_schedule_cycles(
    sparse: &SparseLayer,
    arch: &ArchParams,
    cfg: &SimConfig,
) -> ScheduleCycles {
    let groups = sparse.num_groups(arch.n_par);
    let channels = sparse.cin;
    let total = groups * channels;
    let budget = cfg.sample_groups.unwrap_or(total).min(total);
    if budget >= total {
        // exact: schedule everything
        let mut table = vec![vec![(0u32, 0u32); channels]; groups];
        for (g, row) in table.iter_mut().enumerate() {
            for (m, cell) in row.iter_mut().enumerate() {
                let kernels = sparse.group_indices(g, arch.n_par, m);
                let s = cfg.scheduler.run(&kernels, arch.replicas, cfg.seed ^ ((g * channels + m) as u64));
                *cell = (s.cycles() as u32, s.total_reads() as u32);
            }
        }
        let (mut tc, mut tr) = (0u64, 0u64);
        for row in &table {
            for &(c, r) in row {
                tc += c as u64;
                tr += r as u64;
            }
        }
        ScheduleCycles {
            exact: Some(table),
            mean_cycles: tc as f64 / total as f64,
            mean_reads: tr as f64 / total as f64,
            instances_scheduled: total,
        }
    } else {
        let mut rng = Pcg32::new(cfg.seed ^ 0xABCD);
        let picks = rng.sample_indices(total, budget);
        let (mut tc, mut tr) = (0u64, 0u64);
        for p in &picks {
            let (g, m) = (p / channels, p % channels);
            let kernels = sparse.group_indices(g, arch.n_par, m);
            let s = cfg.scheduler.run(&kernels, arch.replicas, cfg.seed ^ (*p as u64));
            tc += s.cycles() as u64;
            tr += s.total_reads() as u64;
        }
        ScheduleCycles {
            exact: None,
            mean_cycles: tc as f64 / budget as f64,
            mean_reads: tr as f64 / budget as f64,
            instances_scheduled: budget,
        }
    }
}

/// Simulate one spectral conv layer under a dataflow plan.
pub fn simulate_layer(
    layer: &ConvLayer,
    sparse: &SparseLayer,
    arch: &ArchParams,
    stream: &StreamParams,
    cfg: &SimConfig,
) -> LayerSimResult {
    assert_eq!(layer.cin, sparse.cin, "sparse layer must match conv layer");
    assert_eq!(layer.cout, sparse.cout);
    assert_eq!(layer.fft, sparse.fft);
    let geo = layer.geometry();
    let p = geo.num_tiles();
    let sched = build_schedule_cycles(sparse, arch, cfg);
    let nnz = sparse.nnz_per_kernel() as u64;

    let mut ctl = Controller::new(LoopConfig {
        n: layer.cout,
        p,
        m: layer.cin,
        ns: stream.ns.min(layer.cout),
        ps: stream.ps.min(p),
        p_par: arch.p_par,
        n_par: arch.n_par,
    });

    let mut hadamard = 0u64;
    let mut reads = 0u64; // active-PE reads (for Eq. 14, per tile lane)
    let mut read_slots = 0u64; // cycles × N' (denominator)
    let mut fftc = 0u64;
    let mut ifftc = 0u64;
    let mut kernel_bytes = 0u64;
    // Tile-unit accumulators: DDR holds exactly the h×w image (edge-tile
    // padding is generated on-chip), so a tile transfer averages h·w/P
    // spatial words — accumulated in whole-tile units and converted once so
    // the totals telescope exactly to Eq. 13.
    let mut in_tile_units = 0u64;
    let mut out_tile_units = 0u64;
    let mut first_kernel_units = 0u64;
    let mut first_tile_units = 0u64;
    let wb = cfg.word_bytes;
    let hw = (layer.h * layer.h) as u64;
    let p_total = p as u64;
    let mut phases = 0u64;

    while let Some(ph) = ctl.next_phase() {
        phases += 1;
        match ph.state {
            State::ReadKernel => {
                // Ns kernels × one channel × nnz words, values + indices
                kernel_bytes += ph.kernels as u64 * nnz * wb;
                if phases <= 2 {
                    first_kernel_units += ph.kernels as u64 * nnz;
                }
            }
            State::ReadInput => {
                // P' tiles of one channel (spatial words; padding on-chip)
                in_tile_units += ph.tiles as u64;
                if phases <= 2 {
                    first_tile_units += ph.tiles as u64;
                }
                fftc += fft_cycles_for(
                    ph.tiles as u64,
                    layer.fft,
                    arch.p_par as u64,
                    cfg.fft_butterflies_per_cycle,
                );
            }
            State::ProcConv => {
                let (cycles, rds) = match &sched.exact {
                    Some(t) => {
                        let (c, r) = t[ph.kernel_group][ph.channel];
                        (c as u64, r as u64)
                    }
                    None => (sched.mean_cycles.round() as u64, sched.mean_reads.round() as u64),
                };
                hadamard += cycles;
                reads += rds;
                read_slots += cycles * arch.n_par as u64;
            }
            State::ProcIfft => {
                let out_tiles = (ph.tiles * ph.kernels) as u64;
                ifftc += fft_cycles_for(
                    out_tiles,
                    layer.fft,
                    arch.p_par as u64,
                    cfg.fft_butterflies_per_cycle,
                );
            }
            State::WriteOut => {
                // Eq. 13 counts spatial output words (OaA on the host).
                out_tile_units += (ph.tiles * ph.kernels) as u64;
            }
            State::Done => unreachable!(),
        }
    }

    let ddr_bytes = kernel_bytes
        + in_tile_units * hw * wb / p_total
        + out_tile_units * hw * wb / p_total;
    let first_load_bytes =
        first_kernel_units * wb + first_tile_units * hw * wb / p_total;
    // three pipelined stages: FFT → Hadamard → IFFT (see compute_cycles)
    let compute = hadamard.max(fftc).max(ifftc);
    let ddr_secs = ddr_bytes as f64 / cfg.ddr_bytes_per_sec;
    let ddr_cycles = (ddr_secs * cfg.clock_hz).ceil() as u64;
    let fill_secs = first_load_bytes as f64 / cfg.ddr_bytes_per_sec;
    let fill_cycles = (fill_secs * cfg.clock_hz).ceil() as u64
        + fft_cycles_for(arch.p_par as u64, layer.fft, arch.p_par as u64, cfg.fft_butterflies_per_cycle);
    let total = compute.max(ddr_cycles) + fill_cycles;
    let pe_utilization = if read_slots == 0 { 1.0 } else { reads as f64 / read_slots as f64 };

    LayerSimResult {
        layer_name: layer.name.clone(),
        hadamard_cycles: hadamard,
        fft_cycles: fftc,
        ifft_cycles: ifftc,
        ddr_bytes,
        ddr_cycles,
        fill_cycles,
        total_cycles: total,
        pe_utilization,
        instances_scheduled: sched.instances_scheduled,
        instances_total: sparse.num_groups(arch.n_par) * sparse.cin,
    }
}

/// Simulate a network given a per-layer plan `(layer, sparse, stream)`.
pub fn simulate_network(
    layers: &[(&ConvLayer, &SparseLayer, StreamParams)],
    arch: &ArchParams,
    cfg: &SimConfig,
) -> NetworkSimResult {
    let results = layers
        .iter()
        .map(|(l, s, st)| simulate_layer(l, s, arch, st, cfg))
        .collect();
    NetworkSimResult { layers: results, clock_hz: cfg.clock_hz }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Network;
    use crate::sparse::prune_random;
    use crate::util::rng::Pcg32;

    fn small_layer() -> ConvLayer {
        ConvLayer {
            name: "test".into(),
            cin: 4,
            cout: 8,
            h: 12,
            k: 3,
            fft: 8,
            pool_after: false,
        }
    }

    fn sim_small(scheduler: Scheduler, replicas: usize) -> LayerSimResult {
        let layer = small_layer();
        let mut rng = Pcg32::new(11);
        let sparse = prune_random(layer.cout, layer.cin, 8, 4, &mut rng);
        let arch = ArchParams { p_par: 2, n_par: 4, replicas };
        let stream = StreamParams { ns: 8, ps: 4 };
        let cfg = SimConfig { scheduler, sample_groups: None, ..SimConfig::default() };
        simulate_layer(&layer, &sparse, &arch, &stream, &cfg)
    }

    #[test]
    fn ddr_bytes_match_eq13() {
        use crate::analysis::{transfers_flex, LayerParams};
        let layer = small_layer();
        let res = sim_small(Scheduler::ExactCover, 8);
        let l = LayerParams::from_layer(&layer, 4);
        let s = StreamParams { ns: 8, ps: 4 };
        let t = transfers_flex(&l, &s);
        // engine counts words × 2 bytes; Eq 13 volumes are in words.
        assert_eq!(res.ddr_bytes, t.total() * 2);
    }

    #[test]
    fn hadamard_cycles_bounded_by_workload() {
        let res = sim_small(Scheduler::ExactCover, 8);
        // Lower bound: every (kernel, nnz, channel, tile-batch) read needs
        // a cycle slot across N' lanes.
        let total_reads = 8u64 * 16 * 4; // cout × nnz × cin
        let batches = 2u64 * 2; // ⌈P(4? no: h=12 → 2x2 tiles)/p_par⌉ … P=4, p_par=2 → 2
        let min_cycles = (total_reads / 4) * 2; // /N' lanes × batches(2)
        assert!(res.hadamard_cycles >= min_cycles / 2, "{} vs {}", res.hadamard_cycles, min_cycles);
        assert!(res.pe_utilization > 0.3 && res.pe_utilization <= 1.0);
        let _ = batches;
    }

    #[test]
    fn more_replicas_never_slower() {
        let r4 = sim_small(Scheduler::ExactCover, 4);
        let r16 = sim_small(Scheduler::ExactCover, 16);
        assert!(r16.hadamard_cycles <= r4.hadamard_cycles);
        assert!(r16.pe_utilization >= r4.pe_utilization - 1e-9);
    }

    #[test]
    fn exact_cover_beats_baselines_in_sim() {
        let ec = sim_small(Scheduler::ExactCover, 6);
        let li = sim_small(Scheduler::LowestIndexFirst, 6);
        let rd = sim_small(Scheduler::Random, 6);
        assert!(ec.hadamard_cycles <= li.hadamard_cycles);
        assert!(ec.hadamard_cycles <= rd.hadamard_cycles);
    }

    #[test]
    fn sampled_mode_tracks_exact_mode() {
        let layer = ConvLayer { name: "t".into(), cin: 16, cout: 32, h: 12, k: 3, fft: 8, pool_after: false };
        let mut rng = Pcg32::new(12);
        let sparse = prune_random(layer.cout, layer.cin, 8, 4, &mut rng);
        let arch = ArchParams { p_par: 2, n_par: 8, replicas: 8 };
        let stream = StreamParams { ns: 32, ps: 4 };
        let exact = simulate_layer(&layer, &sparse, &arch, &stream,
            &SimConfig { sample_groups: None, ..SimConfig::default() });
        let sampled = simulate_layer(&layer, &sparse, &arch, &stream,
            &SimConfig { sample_groups: Some(16), ..SimConfig::default() });
        let ratio = sampled.hadamard_cycles as f64 / exact.hadamard_cycles as f64;
        assert!((0.85..1.15).contains(&ratio), "sampled/exact = {ratio}");
        assert_eq!(sampled.ddr_bytes, exact.ddr_bytes);
    }

    #[test]
    fn bandwidth_starved_sim_is_ddr_bound() {
        let layer = small_layer();
        let mut rng = Pcg32::new(13);
        let sparse = prune_random(layer.cout, layer.cin, 8, 4, &mut rng);
        let arch = ArchParams { p_par: 2, n_par: 4, replicas: 8 };
        let stream = StreamParams { ns: 8, ps: 4 };
        let starved = SimConfig { ddr_bytes_per_sec: 1e6, sample_groups: None, ..SimConfig::default() };
        let res = simulate_layer(&layer, &sparse, &arch, &stream, &starved);
        assert!(res.ddr_cycles > res.compute_cycles());
        assert!(res.total_cycles >= res.ddr_cycles);
    }

    #[test]
    fn network_aggregation() {
        let net = Network::demo();
        let mut rng = Pcg32::new(14);
        let sparse: Vec<SparseLayer> = net
            .convs
            .iter()
            .map(|c| prune_random(c.cout, c.cin, c.fft, 4, &mut rng))
            .collect();
        let plans: Vec<(&ConvLayer, &SparseLayer, StreamParams)> = net
            .convs
            .iter()
            .zip(&sparse)
            .map(|(c, s)| (c, s, StreamParams { ns: c.cout, ps: c.num_tiles() }))
            .collect();
        let arch = ArchParams { p_par: 2, n_par: 4, replicas: 8 };
        let cfg = SimConfig { sample_groups: None, ..SimConfig::default() };
        let res = simulate_network(&plans, &arch, &cfg);
        assert_eq!(res.layers.len(), 2);
        assert!(res.latency_secs() > 0.0);
        assert!(res.throughput_fps() > 0.0);
        assert!(res.avg_pe_utilization() > 0.0 && res.avg_pe_utilization() <= 1.0);
        assert_eq!(
            res.total_ddr_bytes(),
            res.layers.iter().map(|l| l.ddr_bytes).sum::<u64>()
        );
    }
}
