//! BRAM replica bank with single-port read semantics (paper §5.3, Fig. 4).
//!
//! One input tile is replicated `r` times; each replica is a BRAM that can
//! serve exactly one read address per cycle. A cycle's reads are legal iff
//! they touch ≤ r *distinct* addresses (readers of the same address share a
//! replica's output port via the `sel` mux of Fig. 6). The bank counts
//! conflicts instead of panicking so tests can probe illegal schedules.

/// A replicated single-port memory holding one K×K spectral tile.
#[derive(Debug, Clone)]
pub struct ReplicaBank {
    /// Replica count r.
    replicas: usize,
    /// Tile contents (re, im), indexed by flattened frequency index.
    data: Vec<(f32, f32)>,
    /// Distinct addresses requested in the current cycle.
    active: Vec<u16>,
    /// Total cycles processed.
    cycles: u64,
    /// Reads rejected because the cycle exceeded r distinct addresses.
    conflicts: u64,
    /// Total successful reads.
    reads: u64,
}

impl ReplicaBank {
    pub fn new(replicas: usize, data: Vec<(f32, f32)>) -> Self {
        assert!(replicas >= 1, "need at least one replica");
        ReplicaBank { replicas, data, active: Vec::new(), cycles: 0, conflicts: 0, reads: 0 }
    }

    /// Start a new clock cycle (clears the address-port assignment).
    pub fn begin_cycle(&mut self) {
        self.active.clear();
        self.cycles += 1;
    }

    /// Attempt a read this cycle. `Some(value)` if a replica port is
    /// available (or the address is already being served), `None` on a
    /// replica conflict — the requesting PE starves this cycle.
    pub fn read(&mut self, index: u16) -> Option<(f32, f32)> {
        if !self.active.contains(&index) {
            if self.active.len() >= self.replicas {
                self.conflicts += 1;
                return None;
            }
            self.active.push(index);
        }
        self.reads += 1;
        self.data.get(index as usize).copied()
    }

    pub fn replicas(&self) -> usize {
        self.replicas
    }

    pub fn cycles(&self) -> u64 {
        self.cycles
    }

    pub fn conflicts(&self) -> u64 {
        self.conflicts
    }

    pub fn total_reads(&self) -> u64 {
        self.reads
    }

    /// Ports in use this cycle (≤ r).
    pub fn ports_in_use(&self) -> usize {
        self.active.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bank(r: usize) -> ReplicaBank {
        ReplicaBank::new(r, (0..64).map(|i| (i as f32, -(i as f32))).collect())
    }

    #[test]
    fn serves_up_to_r_distinct_addresses() {
        let mut b = bank(2);
        b.begin_cycle();
        assert_eq!(b.read(3), Some((3.0, -3.0)));
        assert_eq!(b.read(7), Some((7.0, -7.0)));
        assert_eq!(b.read(9), None); // third distinct address
        assert_eq!(b.conflicts(), 1);
    }

    #[test]
    fn same_address_shares_a_port() {
        let mut b = bank(1);
        b.begin_cycle();
        assert!(b.read(5).is_some());
        assert!(b.read(5).is_some()); // broadcast through sel mux
        assert!(b.read(5).is_some());
        assert_eq!(b.ports_in_use(), 1);
        assert_eq!(b.conflicts(), 0);
        assert_eq!(b.total_reads(), 3);
    }

    #[test]
    fn cycle_boundary_resets_ports() {
        let mut b = bank(1);
        b.begin_cycle();
        assert!(b.read(1).is_some());
        assert!(b.read(2).is_none());
        b.begin_cycle();
        assert!(b.read(2).is_some());
        assert_eq!(b.cycles(), 2);
    }

    #[test]
    fn out_of_range_read_is_none_without_port_leak() {
        let mut b = bank(4);
        b.begin_cycle();
        assert_eq!(b.read(200), None);
        // port was still allocated for the address — matches hardware,
        // where the address decode happens after port assignment
        assert_eq!(b.ports_in_use(), 1);
    }
}
