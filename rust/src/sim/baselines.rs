//! Table 3 comparison rows as simulator configurations.
//!
//! The paper compares against four published designs; we cannot run their
//! bitstreams, so each row is modeled as a configuration of the same
//! simulator (DESIGN.md: baselines are "a configuration of S5"):
//!
//! * **[27] Zhang & Prasanna '17** — dense spectral CNN (α=1), fixed
//!   dataflow, small PE budget (224 DSPs → N'=8, P'=7 at 4 DSP/PE).
//! * **[26] Zeng et al. '18** — dense spectral (α=1), throughput-oriented,
//!   256 DSPs (N'=8, P'=8).
//! * **[16] SPEC2** — sparse spectral (α=4) but *fixed* streaming-kernels
//!   dataflow (Flow #2-equivalent: Ns = N, Ps = P'), lowest-index-first
//!   scheduling, 3200 DSPs (N'=64, P'=12), batch-oriented (single-image
//!   latency suffers: the paper quotes 68 ms at 9 GB/s).
//! * **[17] SparCNet** — sparse *spatial* accelerator; no spectral reuse at
//!   all. Modeled analytically: spatial MACs / (PEs · clock) at the same
//!   DSP budget scaled to the U200 (the paper does the same rescaling).
//!
//! "This work" = flexible dataflow (Alg. 1 plan) + exact-cover scheduling.

use crate::analysis::{ArchParams, StreamParams};
use crate::dataflow::{optimize_network_at, OptimizerConfig};
use crate::model::Network;
use crate::schedule::Scheduler;
use crate::sim::engine::{simulate_network, NetworkSimResult, SimConfig};
use crate::sparse::{prune_magnitude, SparseLayer};
use crate::util::rng::Pcg32;

/// A named Table 3 configuration.
#[derive(Debug, Clone)]
pub struct BaselineConfig {
    pub name: &'static str,
    pub alpha: usize,
    pub arch: ArchParams,
    pub scheduler: Scheduler,
    /// Fixed streaming parameters; `None` = run Alg. 1 (this work).
    pub fixed_stream: Option<FixedStream>,
    pub ddr_bytes_per_sec: f64,
}

/// Fixed-dataflow policies for baseline rows.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FixedStream {
    /// Stream kernels every tile pass (Flow #2): Ns = N, Ps = P'.
    StreamKernels,
    /// Stream input tiles (Flow #1): Ns = N', Ps = P.
    StreamInputs,
}

impl BaselineConfig {
    pub fn this_work() -> Self {
        BaselineConfig {
            name: "This work",
            alpha: 4,
            arch: ArchParams::paper(),
            scheduler: Scheduler::ExactCover,
            fixed_stream: None,
            ddr_bytes_per_sec: 12.8e9,
        }
    }

    /// [16] SPEC2-like: sparse, fixed dataflow, lowest-index-first.
    pub fn spec2_like() -> Self {
        BaselineConfig {
            name: "[16]-like (SPEC2)",
            alpha: 4,
            arch: ArchParams { p_par: 12, n_par: 64, replicas: 16 },
            scheduler: Scheduler::LowestIndexFirst,
            fixed_stream: Some(FixedStream::StreamKernels),
            ddr_bytes_per_sec: 9.0e9,
        }
    }

    /// [27]-like: dense spectral, small PE array.
    pub fn dense_spectral_27() -> Self {
        BaselineConfig {
            name: "[27]-like (dense spectral)",
            alpha: 1,
            arch: ArchParams { p_par: 7, n_par: 8, replicas: 1 },
            scheduler: Scheduler::LowestIndexFirst, // dense ⇒ all equal
            fixed_stream: Some(FixedStream::StreamKernels),
            ddr_bytes_per_sec: 5.0e9,
        }
    }

    /// [26]-like: dense spectral, slightly bigger array.
    pub fn dense_spectral_26() -> Self {
        BaselineConfig {
            name: "[26]-like (dense spectral)",
            alpha: 1,
            arch: ArchParams { p_par: 8, n_par: 8, replicas: 1 },
            scheduler: Scheduler::LowestIndexFirst,
            fixed_stream: Some(FixedStream::StreamKernels),
            ddr_bytes_per_sec: 9.0e9,
        }
    }

    pub fn all() -> Vec<BaselineConfig> {
        vec![
            Self::dense_spectral_27(),
            Self::dense_spectral_26(),
            Self::spec2_like(),
            Self::this_work(),
        ]
    }
}

/// Run one Table 3 row: build sparse kernels, plan the dataflow, simulate.
pub fn run_baseline(
    cfg: &BaselineConfig,
    net: &Network,
    sample_groups: Option<usize>,
    seed: u64,
) -> NetworkSimResult {
    let mut rng = Pcg32::new(seed);
    let sparse: Vec<SparseLayer> = net
        .convs
        .iter()
        .map(|c| prune_magnitude(c.cout, c.cin, c.fft, cfg.alpha, &mut rng))
        .collect();

    // Per-layer streaming parameters.
    let streams: Vec<StreamParams> = match cfg.fixed_stream {
        Some(FixedStream::StreamKernels) => net
            .convs
            .iter()
            .map(|c| StreamParams { ns: c.cout, ps: cfg.arch.p_par.min(c.num_tiles()) })
            .collect(),
        Some(FixedStream::StreamInputs) => net
            .convs
            .iter()
            .map(|c| StreamParams { ns: cfg.arch.n_par.min(c.cout), ps: c.num_tiles() })
            .collect(),
        None => {
            let ocfg = OptimizerConfig {
                alpha: cfg.alpha,
                replicas: cfg.arch.replicas,
                ..OptimizerConfig::paper()
            };
            let plan = optimize_network_at(net, cfg.arch, &ocfg)
                .expect("this-work arch must be feasible");
            net.convs
                .iter()
                .map(|c| {
                    plan.layer(&c.name)
                        .map(|lp| lp.stream)
                        // conv1_1 is unplanned (skipped by Alg. 1): keep all
                        .unwrap_or(StreamParams { ns: c.cout, ps: c.num_tiles() })
                })
                .collect()
        }
    };

    let layers: Vec<(&crate::model::ConvLayer, &SparseLayer, StreamParams)> = net
        .convs
        .iter()
        .zip(&sparse)
        .zip(&streams)
        .map(|((c, s), st)| (c, s, *st))
        .collect();

    let sim = SimConfig {
        scheduler: cfg.scheduler,
        ddr_bytes_per_sec: cfg.ddr_bytes_per_sec,
        sample_groups,
        seed,
        ..SimConfig::default()
    };
    simulate_network(&layers, &cfg.arch, &sim)
}

/// [17]-like analytical row: sparse *spatial* accelerator.
pub fn sparse_spatial_17_latency(net: &Network, _alpha: usize) -> f64 {
    // Rescaled to the U200 exactly the way the paper does it (§6.3: "we
    // also assume it can be deployed in Alveo U200, while accessing the
    // same resources"): take the published 200 ms @ 384 DSP / 100 MHz and
    // scale by DSP count and clock.
    let published_latency = 0.200; // Artix-7 XC7A200T row of Table 3
    let published_dsp = 384.0;
    let published_clock = 100e6;
    let our_dsp = 2680.0; // matched budget (paper's this-work DSPs)
    let our_clock = 200e6;
    let scaled = published_latency * (published_dsp / our_dsp)
        * (published_clock / our_clock);
    // sanity anchor: the workload must be non-trivial (guards unit slips)
    let macs: u64 = net.convs.iter().map(|c| c.spatial_macs()).sum();
    debug_assert!(macs > 1_000_000_000);
    let _ = macs;
    scaled
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_net() -> Network {
        Network::vgg16_cifar()
    }

    #[test]
    fn this_work_beats_spec2_latency() {
        // Table 3's headline: flexible dataflow + exact-cover beats the
        // fixed-dataflow SPEC2 configuration on single-image latency.
        let net = small_net();
        let ours = run_baseline(&BaselineConfig::this_work(), &net, Some(8), 1);
        let spec2 = run_baseline(&BaselineConfig::spec2_like(), &net, Some(8), 1);
        assert!(
            ours.latency_secs() < spec2.latency_secs(),
            "ours {:.4} vs spec2 {:.4}",
            ours.latency_secs(),
            spec2.latency_secs()
        );
    }

    #[test]
    fn dense_rows_are_slowest() {
        let net = small_net();
        let ours = run_baseline(&BaselineConfig::this_work(), &net, Some(8), 2);
        let dense = run_baseline(&BaselineConfig::dense_spectral_27(), &net, Some(8), 2);
        assert!(dense.latency_secs() > 3.0 * ours.latency_secs());
    }

    #[test]
    fn transfer_reduction_vs_fixed_flow_224() {
        // The paper's 42% headline holds at 224 scale, where tile counts are
        // large enough that flexibility matters (at CIFAR scale every
        // buffer fits and the flows converge — also checked).
        let net = Network::vgg16_224();
        let ours = run_baseline(&BaselineConfig::this_work(), &net, Some(2), 3);
        let mut fixed_cfg = BaselineConfig::this_work();
        fixed_cfg.fixed_stream = Some(FixedStream::StreamKernels);
        let fixed = run_baseline(&fixed_cfg, &net, Some(2), 3);
        let reduction = 1.0 - ours.total_ddr_bytes() as f64 / fixed.total_ddr_bytes() as f64;
        assert!(
            reduction > 0.30,
            "transfer reduction {reduction:.2} (ours {} vs fixed {})",
            ours.total_ddr_bytes(),
            fixed.total_ddr_bytes()
        );
        // CIFAR scale: flexible never does worse.
        let small = small_net();
        let o2 = run_baseline(&BaselineConfig::this_work(), &small, Some(4), 3);
        let mut f2 = BaselineConfig::this_work();
        f2.fixed_stream = Some(FixedStream::StreamKernels);
        let r2 = run_baseline(&f2, &small, Some(4), 3);
        assert!(o2.total_ddr_bytes() <= r2.total_ddr_bytes());
    }

    #[test]
    fn sparse_spatial_row_positive() {
        let l = sparse_spatial_17_latency(&Network::vgg16_224(), 4);
        assert!((0.010..0.020).contains(&l), "latency {l}");
    }
}
