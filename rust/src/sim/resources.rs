//! FPGA resource model (DSP / BRAM / LUT) for Table 3.
//!
//! Calibration (documented per DESIGN.md; stated again in EXPERIMENTS.md):
//!
//! * **DSP** — a 16-bit complex MAC PE = 4 DSP48 slices (3 multipliers via
//!   Karatsuba + 1 for the accumulate path). Streaming FFT/IFFT engines:
//!   one engine with `b` butterflies/cycle needs `3b` DSPs (complex
//!   multiply per butterfly); `p_par` engines per direction. At the paper's
//!   point (N'=64, P'=9, b=8): 64·9·4 + 2·9·24 = 2304 + 432 = 2736 ≈ the
//!   paper's 2680.
//! * **BRAM** — the Eq. 12 maximum across layers, plus INDEX/VALUE table
//!   storage and the I/O stream FIFOs (2 per tile lane).
//! * **LUT** — 400 LUTs per PE lane (routing + sel muxes of Fig. 6) plus a
//!   150K fixed harness (OpenCL shell + controllers); at the paper's point
//!   ≈ 230K of 1.2M.

use crate::analysis::{bram_flex, ArchParams, LayerParams, StreamParams};

/// Resource usage estimate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Resources {
    pub dsp: u64,
    pub bram: u64,
    pub lut: u64,
}

/// Device budgets for utilization reporting (Alveo U200, paper §6.3).
pub const U200_DSP: u64 = 6840;
pub const U200_BRAM: u64 = 2160;
pub const U200_LUT: u64 = 1_200_000;

/// DSPs per complex-MAC PE (Karatsuba 3 mults + accumulate).
pub const DSP_PER_PE: u64 = 4;
/// LUTs per PE lane (sel routing, valid gating).
pub const LUT_PER_PE: u64 = 400;
/// Fixed LUT harness (shell, controllers, OaA stream logic).
pub const LUT_FIXED: u64 = 150_000;

/// Estimate resources for an architecture + per-layer streaming plan.
///
/// `plans` supplies (layer params, streaming params) so the BRAM term can
/// take the worst layer (the buffers are sized once for the whole network).
pub fn estimate_resources(
    arch: &ArchParams,
    plans: &[(LayerParams, StreamParams)],
    fft_butterflies_per_cycle: u64,
) -> Resources {
    let pes = (arch.n_par * arch.p_par) as u64;
    let fft_dsp = 2 * arch.p_par as u64 * 3 * fft_butterflies_per_cycle;
    let dsp = pes * DSP_PER_PE + fft_dsp;

    let data_bram = plans
        .iter()
        .map(|(l, s)| bram_flex(l, arch, s))
        .max()
        .unwrap_or(0);
    // INDEX/VALUE tables: one VALUE word per PE lane per cycle in flight +
    // an INDEX word per replica port; stored double-buffered per group.
    let table_bram = (arch.n_par as u64 * 2).div_ceil(8) + (arch.replicas as u64).div_ceil(4);
    // Stream FIFOs: in/out per tile lane.
    let fifo_bram = 2 * arch.p_par as u64;
    let bram = data_bram + table_bram + fifo_bram;

    let lut = pes * LUT_PER_PE + LUT_FIXED;
    Resources { dsp, bram, lut }
}

impl Resources {
    /// Utilization strings against the U200 budget ("used/total").
    pub fn utilization_report(&self) -> String {
        format!(
            "DSP {}/{} ({:.0}%)  BRAM {}/{} ({:.0}%)  LUT {}K/{}K ({:.0}%)",
            self.dsp,
            U200_DSP,
            100.0 * self.dsp as f64 / U200_DSP as f64,
            self.bram,
            U200_BRAM,
            100.0 * self.bram as f64 / U200_BRAM as f64,
            self.lut / 1000,
            U200_LUT / 1000,
            100.0 * self.lut as f64 / U200_LUT as f64,
        )
    }

    pub fn fits_u200(&self) -> bool {
        self.dsp <= U200_DSP && self.bram <= U200_BRAM && self.lut <= U200_LUT
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::{optimize_network_at, OptimizerConfig};
    use crate::model::Network;

    fn paper_plan() -> Vec<(LayerParams, StreamParams)> {
        let net = Network::vgg16_224();
        let cfg = OptimizerConfig::paper();
        let plan = optimize_network_at(&net, ArchParams::paper(), &cfg).unwrap();
        plan.layers.iter().map(|l| (l.params, l.stream)).collect()
    }

    #[test]
    fn paper_point_calibration() {
        // N'=64, P'=9, b=8 → DSP ≈ 2736 vs the paper's 2680 (±5%).
        let r = estimate_resources(&ArchParams::paper(), &paper_plan(), 8);
        assert!((r.dsp as f64 - 2680.0).abs() / 2680.0 < 0.05, "dsp {}", r.dsp);
        assert!(r.fits_u200(), "{}", r.utilization_report());
    }

    #[test]
    fn bram_in_paper_band() {
        // Paper reports 1469/2160 BRAMs; require the same order (±35% —
        // the paper's count includes shell buffers we fold into constants).
        let r = estimate_resources(&ArchParams::paper(), &paper_plan(), 8);
        assert!(
            (r.bram as f64) > 900.0 && (r.bram as f64) < 2000.0,
            "bram {}",
            r.bram
        );
    }

    #[test]
    fn lut_in_paper_band() {
        // Paper: 230K / 1.2M.
        let r = estimate_resources(&ArchParams::paper(), &paper_plan(), 8);
        assert!(r.lut >= 200_000 && r.lut <= 450_000, "lut {}", r.lut);
    }

    #[test]
    fn scaling_with_parallelism() {
        let plans = paper_plan();
        let small = estimate_resources(&ArchParams { p_par: 4, n_par: 32, replicas: 8 }, &plans, 8);
        let big = estimate_resources(&ArchParams { p_par: 16, n_par: 64, replicas: 8 }, &plans, 8);
        assert!(big.dsp > small.dsp);
        assert!(big.lut > small.lut);
    }

    #[test]
    fn report_format() {
        let r = Resources { dsp: 2680, bram: 1469, lut: 230_000 };
        let s = r.utilization_report();
        assert!(s.contains("2680/6840"));
        assert!(s.contains("1469/2160"));
    }
}
