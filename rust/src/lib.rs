//! # spectral-flow
//!
//! Reproduction of *"Reuse Kernels or Activations? A Flexible Dataflow for
//! Low-latency Spectral CNN Acceleration"* (FPGA '20) as a three-layer
//! Rust + JAX + Pallas stack.
//!
//! The crate is the **Layer-3 coordinator**: it owns the dataflow optimizer
//! (paper Alg. 1), the exact-cover memory-access scheduler (paper Alg. 2),
//! a cycle-level model of the paper's FPGA accelerator, and a serving engine
//! that executes spectral VGG16 inference through a pluggable
//! [`runtime::SpectralBackend`]. The default `interp` backend is pure Rust
//! and runs fully offline with zero external dependencies; the optional
//! `pjrt` cargo feature swaps in AOT-compiled XLA executables (built once
//! by `make artifacts`; Python is never on the request path).
//!
//! Pruned models run a real **sparse execution path**: kernels upload in
//! CSR form ([`runtime::SparseWeightPlanes`]) and the backend's sparse MAC
//! touches only the K²/α stored non-zeros, with the per-layer loop order
//! chosen by the same Alg. 1 optimum that produces the paper's Table 1
//! ([`runtime::SparseDataflow`]). See `docs/ARCHITECTURE.md` for the
//! serving dataflow and `docs/PAPER_MAP.md` for the equation→code map.
//!
//! ## Quickstart
//!
//! No artifacts are needed — the runtime synthesizes its manifest from the
//! built-in model presets, so this runs anywhere the crate compiles:
//!
//! ```
//! use spectral_flow::coordinator::{InferenceEngine, WeightMode};
//!
//! // α=4: each 8×8 spectral kernel keeps 16 non-zeros; the engine uploads
//! // CSR kernels and the interp backend runs its sparse MAC. α=1
//! // (`WeightMode::from_alpha(1)` == `WeightMode::Dense`) is the dense path.
//! let mut engine = InferenceEngine::new(
//!     "artifacts",                       // absent ⇒ built-in manifest
//!     "demo",                            // demo | vgg16-cifar | vgg16-224
//!     WeightMode::from_alpha(4),
//!     7,                                 // weight seed (deterministic)
//! )
//! .unwrap();
//! let image = engine.synthetic_image(1);
//! let logits = engine.forward(&image).unwrap();
//! assert_eq!(logits.len(), 10);
//! assert!(logits.iter().all(|v| v.is_finite()));
//! ```
//!
//! Module map (see DESIGN.md for the full system inventory):
//!
//! * [`util`] — offline-environment substrates: RNG, JSON, errors, bench
//!   harness, mini property-testing.
//! * [`tensor`] — dense f32 tensors + complex planes.
//! * [`fft`] — radix-2 FFT, tiling (`im2tiles`) and overlap-and-add.
//! * [`nn`] — CPU-side ops: ReLU, maxpool, dense/FC, naive conv reference.
//! * [`model`] — layer descriptors and VGG16 presets (paper §6 workloads).
//! * [`sparse`] — sparse spectral kernels: ADMM-like and random pruning.
//! * [`analysis`] — BRAM/bandwidth complexity model (paper Eqs. 6–13).
//! * [`dataflow`] — flexible-dataflow optimizer (paper Alg. 1).
//! * [`schedule`] — exact-cover scheduler + baselines (paper Alg. 2).
//! * [`sim`] — cycle-level accelerator simulator (the U200 substitute).
//! * [`runtime`] — the [`runtime::SpectralBackend`] trait, the pure-Rust
//!   `interp` backend with dense + sparse MACs, the CSR weight form, and
//!   (feature `pjrt`) the PJRT executable loader.
//! * [`coordinator`] — batching inference server: a dispatcher over a pool
//!   of engine-owning executor workers (the e2e driver).
//! * [`net`] — networked serving on `std::net`: minimal HTTP/1.1 front-end
//!   (`POST /infer`, `GET /metrics`, `GET /healthz`) with admission
//!   control over the engine pool, plus the open/closed-loop load
//!   generator.
//! * [`obs`] — observability: backend data-movement counters compared
//!   against the Eq. 13 prediction, the per-request trace-span ring, and
//!   the Prometheus text exposition.
//! * [`report`] — ASCII/CSV emitters for every paper table and figure.

pub mod analysis;
pub mod coordinator;
pub mod dataflow;
pub mod fft;
pub mod model;
pub mod net;
pub mod nn;
pub mod obs;
pub mod report;
pub mod runtime;
pub mod schedule;
pub mod sim;
pub mod sparse;
pub mod tensor;
pub mod util;
