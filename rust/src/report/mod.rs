//! ASCII table / CSV emitters for the paper's figures and tables.
//!
//! Every bench and example funnels its rows through [`Table`] so the
//! regenerated artifacts look the same everywhere and can be diffed across
//! runs (EXPERIMENTS.md cites these outputs verbatim).

/// Simple aligned ASCII table builder.
#[derive(Debug, Clone, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// CSV form (for EXPERIMENTS.md provenance).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.header.join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }

    /// Append the CSV to `reports/<name>.csv` (creates the directory).
    pub fn save_csv(&self, name: &str) -> std::io::Result<String> {
        std::fs::create_dir_all("reports")?;
        let path = format!("reports/{name}.csv");
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }
}

/// Human-readable byte counts.
pub fn fmt_bytes(b: u64) -> String {
    if b >= 1 << 30 {
        format!("{:.2} GiB", b as f64 / (1u64 << 30) as f64)
    } else if b >= 1 << 20 {
        format!("{:.2} MiB", b as f64 / (1u64 << 20) as f64)
    } else if b >= 1 << 10 {
        format!("{:.2} KiB", b as f64 / (1u64 << 10) as f64)
    } else {
        format!("{b} B")
    }
}

/// GB/s with one decimal (Table 2/3 convention).
pub fn fmt_gbps(bytes_per_sec: f64) -> String {
    format!("{:.1} GB/s", bytes_per_sec / 1e9)
}

/// Milliseconds with one decimal.
pub fn fmt_ms(secs: f64) -> String {
    format!("{:.1} ms", secs * 1e3)
}

/// Percent with one decimal.
pub fn fmt_pct(x: f64) -> String {
    format!("{:.1}%", 100.0 * x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Demo", &["layer", "bw"]);
        t.row(vec!["conv1_2".into(), "8.2".into()]);
        t.row(vec!["conv5_1,2,3".into(), "9.9".into()]);
        let r = t.render();
        assert!(r.contains("== Demo =="));
        assert!(r.contains("conv1_2"));
        let lines: Vec<&str> = r.lines().collect();
        // all data lines same width
        assert_eq!(lines[3].len(), lines[4].len());
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn width_checked() {
        Table::new("x", &["a"]).row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn formatters() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2 * 1024 * 1024), "2.00 MiB");
        assert_eq!(fmt_gbps(12.3e9), "12.3 GB/s");
        assert_eq!(fmt_ms(0.0092), "9.2 ms");
        assert_eq!(fmt_pct(0.905), "90.5%");
    }
}
