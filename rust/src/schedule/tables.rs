//! INDEX/VALUE table encoding (paper Fig. 6).
//!
//! After scheduling, `S*` is split into two hardware tables:
//!
//! * **INDEX table** — per cycle, the ≤ r distinct frequency indices to read
//!   from the input-tile replicas (`rep_0, rep_1, ...`).
//! * **VALUE table** — per cycle, one slot per PE lane: the kernel weight,
//!   a `sel` signal routing the right replica output to the PE, and a
//!   `valid` bit ("some kernels might be inactive due to too many unique
//!   addresses in current cycle").
//!
//! The cycle-level simulator's streaming controller executes these tables
//! directly, so the scheduler → hardware hand-off is the same data structure
//! the paper describes.

use super::{Schedule, SchedulePolicy};
use crate::runtime::SparseWeightPlanes;
use crate::sparse::SparseLayer;

/// One PE lane's slot in a cycle of the VALUE table.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ValueSlot {
    /// Lane active this cycle?
    pub valid: bool,
    /// Which INDEX-table entry (replica port) feeds this lane.
    pub sel: u8,
    /// Kernel weight (re, im) consumed this cycle.
    pub weight: (f32, f32),
    /// Flattened frequency index (for writing the partial sum).
    pub index: u16,
}

impl ValueSlot {
    pub fn idle() -> Self {
        ValueSlot { valid: false, sel: 0, weight: (0.0, 0.0), index: 0 }
    }
}

/// The compiled tables for one kernel group at one input channel.
#[derive(Debug, Clone, PartialEq)]
pub struct AccessTables {
    /// `index[c]` = distinct indices read in cycle c (≤ r entries).
    pub index: Vec<Vec<u16>>,
    /// `value[c][lane]` = the lane's slot in cycle c (N' lanes wide).
    pub value: Vec<Vec<ValueSlot>>,
    pub num_lanes: usize,
}

impl AccessTables {
    pub fn cycles(&self) -> usize {
        self.index.len()
    }

    /// Words of on-chip table storage: INDEX entries + VALUE slots
    /// (weight = 2 words, sel+valid+index packed into 1).
    pub fn storage_words(&self) -> usize {
        let idx: usize = self.index.iter().map(|c| c.len()).sum();
        let val: usize = self
            .value
            .iter()
            .flat_map(|c| c.iter())
            .filter(|s| s.valid)
            .count();
        idx + 3 * val
    }
}

/// Compile a schedule into Fig. 6's INDEX/VALUE tables.
///
/// `kernel_of_lane` maps schedule-local kernel ids to lanes 1:1 (the
/// schedule's kernels *are* the lanes); weights come from the sparse layer:
/// group `group` at input channel `m`.
pub fn compile_tables(
    schedule: &Schedule,
    layer: &SparseLayer,
    group: usize,
    m: usize,
    n_par: usize,
) -> AccessTables {
    let base = group * n_par;
    let lanes = schedule.num_kernels;
    let mut index = Vec::with_capacity(schedule.cycles());
    let mut value = Vec::with_capacity(schedule.cycles());
    for set in &schedule.sets {
        let mut idxs: Vec<u16> = set.reads.iter().map(|&(_, i)| i).collect();
        idxs.sort_unstable();
        idxs.dedup();
        assert!(idxs.len() <= schedule.replicas, "C2 violated in input schedule");
        let mut slots = vec![ValueSlot::idle(); lanes];
        for &(k, i) in &set.reads {
            let sel = idxs.binary_search(&i).expect("index present") as u8;
            let kernel = layer.kernel(base + k as usize, m);
            let pos = kernel
                .indices
                .binary_search(&i)
                .expect("scheduled index must be a non-zero of the kernel");
            slots[k as usize] = ValueSlot {
                valid: true,
                sel,
                weight: kernel.values[pos],
                index: i,
            };
        }
        index.push(idxs);
        value.push(slots);
    }
    AccessTables { index, value, num_lanes: lanes }
}

/// Default weight-store bank count for the serving path's simulated bank
/// model (see [`LayerSchedule`]): 8 banks over the K² frequency plane,
/// `bank(f) = f mod 8` — one BRAM-ish bank per frequency-plane column at
/// the paper's K=8 operating point.
pub const DEFAULT_WEIGHT_BANKS: usize = 8;

/// Aggregate scheduling quality of one layer — the serving-metrics payload
/// (cycles vs lower bound, Eq. 14 utilization, simulated bank conflicts).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct ScheduleStats {
    /// Total cycles over every (group, channel) instance of the layer.
    pub cycles: u64,
    /// Sum of [`Schedule::lower_bound`] over the same instances.
    pub lower_bound: u64,
    /// Total reads issued (= the layer's nnz).
    pub reads: u64,
    /// Total PE slots (`Σ cycles · group kernels`) — utilization denominator.
    pub slots: u64,
    /// Simulated weight-bank conflicts: per cycle, distinct frequency
    /// indices mapping to the same `f mod B` bank beyond the first. The
    /// schedule is conflict-free on the paper's r-replica *input* BRAMs by
    /// construction; this counts stalls a B-banked *weight* store would add.
    pub bank_conflicts: u64,
}

impl ScheduleStats {
    /// PE utilization across the layer (paper Eq. 14).
    pub fn pe_utilization(&self) -> f64 {
        if self.slots == 0 {
            return 1.0;
        }
        self.reads as f64 / self.slots as f64
    }

    /// Scheduled cycles relative to the information-theoretic lower bound
    /// (1.0 = optimal).
    pub fn cycles_over_lower_bound(&self) -> f64 {
        if self.lower_bound == 0 {
            return 1.0;
        }
        self.cycles as f64 / self.lower_bound as f64
    }
}

/// A whole layer's compiled scheduling plan — one [`Schedule`] per
/// (kernel-group, input-channel) instance, built from the runtime CSR rows
/// ([`SparseWeightPlanes`]) so the serving path schedules exactly what its
/// MAC streams. This is what the engine hands to
/// [`crate::runtime::SpectralBackend::set_schedule`].
#[derive(Debug, Clone, PartialEq)]
pub struct LayerSchedule {
    /// Kernels scheduled in parallel per group (paper N').
    pub n_par: usize,
    /// Input-tile replica bound r the schedules honor.
    pub replicas: usize,
    /// Weight-store banks B for the simulated conflict model.
    pub banks: usize,
    /// Output channels / input channels of the layer (CSR dims).
    pub cout: usize,
    pub cin: usize,
    /// Policy the plan was built under (for labels/metrics).
    pub policy: SchedulePolicy,
    /// Schedules indexed `group · cin + m`.
    pub groups: Vec<Schedule>,
    /// Aggregate quality, computed once at build time.
    pub stats: ScheduleStats,
}

impl LayerSchedule {
    /// Plan every (group, channel) instance of a layer under `policy`.
    /// Returns `None` for [`SchedulePolicy::Off`] — the caller keeps the
    /// unscheduled CSR walk.
    pub fn build(
        planes: &SparseWeightPlanes,
        n_par: usize,
        replicas: usize,
        banks: usize,
        policy: SchedulePolicy,
    ) -> Option<LayerSchedule> {
        if policy == SchedulePolicy::Off {
            return None;
        }
        let [_, cin, cout] = planes.dims;
        let num_groups = planes.num_groups(n_par);
        let mut groups = Vec::with_capacity(num_groups * cin);
        let mut stats = ScheduleStats::default();
        for g in 0..num_groups {
            for m in 0..cin {
                let kernels = planes.group_indices(g, n_par, m);
                let s = policy
                    .plan_group(&kernels, replicas)
                    .expect("policy is not Off");
                debug_assert!(s.validate(&kernels).is_ok());
                stats.cycles += s.cycles() as u64;
                stats.lower_bound += Schedule::lower_bound(&kernels, replicas) as u64;
                stats.reads += s.total_reads() as u64;
                stats.slots += (s.cycles() * kernels.len()) as u64;
                stats.bank_conflicts += bank_conflicts(&s, banks);
                groups.push(s);
            }
        }
        Some(LayerSchedule {
            n_par,
            replicas,
            banks,
            cout,
            cin,
            policy,
            groups,
            stats,
        })
    }

    /// The schedule of group `g` at input channel `m`.
    pub fn group(&self, g: usize, m: usize) -> &Schedule {
        &self.groups[g * self.cin + m]
    }

    pub fn num_groups(&self) -> usize {
        self.cout.div_ceil(self.n_par.max(1))
    }

    /// Validate every instance against the CSR rows it must cover — the
    /// backend's defense against a plan built from different weights.
    pub fn validate(&self, planes: &SparseWeightPlanes) -> Result<(), String> {
        let [_, cin, cout] = planes.dims;
        if cin != self.cin || cout != self.cout {
            return Err(format!(
                "plan is for {}x{} channels, weights are {}x{}",
                self.cout, self.cin, cout, cin
            ));
        }
        for g in 0..self.num_groups() {
            for m in 0..cin {
                let kernels = planes.group_indices(g, self.n_par, m);
                self.group(g, m)
                    .validate(&kernels)
                    .map_err(|e| format!("group {g} channel {m}: {e}"))?;
            }
        }
        Ok(())
    }
}

/// Simulated weight-bank conflicts of one schedule: per cycle, every
/// distinct frequency index past the first that lands in the same
/// `f mod banks` bank.
pub fn bank_conflicts(s: &Schedule, banks: usize) -> u64 {
    let banks = banks.max(1);
    let mut total = 0u64;
    let mut per_bank = vec![0u32; banks];
    for set in &s.sets {
        per_bank.fill(0);
        let mut idx: Vec<u16> = set.reads.iter().map(|&(_, i)| i).collect();
        idx.sort_unstable();
        idx.dedup();
        for i in idx {
            per_bank[i as usize % banks] += 1;
        }
        total += per_bank.iter().map(|&c| c.saturating_sub(1) as u64).sum::<u64>();
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::{schedule_exact_cover, CycleSet};
    use crate::sparse::prune_random;
    use crate::util::rng::Pcg32;

    fn setup(n_par: usize, r: usize) -> (SparseLayer, Schedule, AccessTables) {
        let mut rng = Pcg32::new(21);
        let layer = prune_random(n_par, 2, 8, 4, &mut rng);
        let kernels = layer.group_indices(0, n_par, 1);
        let sched = schedule_exact_cover(&kernels, r);
        let tables = compile_tables(&sched, &layer, 0, 1, n_par);
        (layer, sched, tables)
    }

    #[test]
    fn tables_align_with_schedule() {
        let (_, sched, tables) = setup(16, 6);
        assert_eq!(tables.cycles(), sched.cycles());
        for (c, set) in sched.sets.iter().enumerate() {
            assert_eq!(tables.index[c].len(), set.distinct_indices());
            let active = tables.value[c].iter().filter(|s| s.valid).count();
            assert_eq!(active, set.active_kernels());
        }
    }

    #[test]
    fn sel_routes_to_correct_index() {
        let (_, _, tables) = setup(16, 6);
        for c in 0..tables.cycles() {
            for slot in tables.value[c].iter().filter(|s| s.valid) {
                assert_eq!(tables.index[c][slot.sel as usize], slot.index);
            }
        }
    }

    #[test]
    fn weights_match_sparse_layer() {
        let (layer, _, tables) = setup(8, 4);
        for c in 0..tables.cycles() {
            for (lane, slot) in tables.value[c].iter().enumerate() {
                if slot.valid {
                    let kernel = layer.kernel(lane, 1);
                    let pos = kernel.indices.binary_search(&slot.index).unwrap();
                    assert_eq!(slot.weight, kernel.values[pos]);
                }
            }
        }
    }

    #[test]
    fn total_valid_slots_equal_nnz() {
        let (layer, _, tables) = setup(16, 6);
        let valid: usize = tables
            .value
            .iter()
            .flat_map(|c| c.iter())
            .filter(|s| s.valid)
            .count();
        // group 0 at channel 1 covers all 16 kernels × nnz each
        let want: usize = (0..16).map(|n| layer.kernel(n, 1).nnz()).sum();
        assert_eq!(valid, want);
    }

    #[test]
    fn layer_schedule_covers_every_row() {
        let mut rng = Pcg32::new(31);
        let layer = prune_random(20, 3, 8, 4, &mut rng); // ragged: groups of 8, 8, 4
        let planes = SparseWeightPlanes::from_layer(&layer);
        for policy in [SchedulePolicy::ExactCover, SchedulePolicy::LowestIndex] {
            let plan = LayerSchedule::build(&planes, 8, 6, 8, policy).unwrap();
            assert_eq!(plan.groups.len(), 3 * 3);
            plan.validate(&planes).unwrap();
            // reads = layer nnz, utilization in (0, 1]
            assert_eq!(plan.stats.reads as usize, planes.nnz());
            let u = plan.stats.pe_utilization();
            assert!(u > 0.0 && u <= 1.0 + 1e-12, "{policy:?}: {u}");
            assert!(plan.stats.cycles >= plan.stats.lower_bound);
            assert!(plan.stats.cycles_over_lower_bound() >= 1.0);
        }
        assert!(LayerSchedule::build(&planes, 8, 6, 8, SchedulePolicy::Off).is_none());
    }

    #[test]
    fn layer_schedule_validate_rejects_foreign_weights() {
        let mut rng = Pcg32::new(32);
        let a = SparseWeightPlanes::from_layer(&prune_random(8, 2, 8, 4, &mut rng));
        let b = SparseWeightPlanes::from_layer(&prune_random(8, 2, 8, 4, &mut rng));
        let plan = LayerSchedule::build(&a, 8, 6, 8, SchedulePolicy::ExactCover).unwrap();
        plan.validate(&a).unwrap();
        assert!(plan.validate(&b).is_err(), "plan from other weights must be rejected");
        let c = SparseWeightPlanes::from_layer(&prune_random(8, 3, 8, 4, &mut rng));
        assert!(plan.validate(&c).unwrap_err().contains("channels"));
    }

    #[test]
    fn bank_conflict_counting() {
        // one cycle reading indices {0, 8, 3} with 8 banks: 0 and 8 share
        // bank 0 ⇒ 1 conflict; with 1 bank: 3 distinct ⇒ 2 conflicts.
        let s = Schedule {
            sets: vec![CycleSet { reads: vec![(0, 0), (1, 8), (2, 3)] }],
            replicas: 3,
            num_kernels: 3,
        };
        assert_eq!(bank_conflicts(&s, 8), 1);
        assert_eq!(bank_conflicts(&s, 1), 2);
        // a broadcast read (same index for every kernel) never conflicts
        let bcast = Schedule {
            sets: vec![CycleSet { reads: vec![(0, 5), (1, 5), (2, 5)] }],
            replicas: 1,
            num_kernels: 3,
        };
        assert_eq!(bank_conflicts(&bcast, 8), 0);
    }

    #[test]
    fn storage_words_positive_and_bounded() {
        let (layer, sched, tables) = setup(16, 6);
        let words = tables.storage_words();
        assert!(words > 0);
        // ≤ index entries (r per cycle) + 3 words per nnz
        let nnz: usize = (0..16).map(|n| layer.kernel(n, 1).nnz()).sum();
        assert!(words <= sched.cycles() * 6 + 3 * nnz);
    }
}
