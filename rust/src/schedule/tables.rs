//! INDEX/VALUE table encoding (paper Fig. 6).
//!
//! After scheduling, `S*` is split into two hardware tables:
//!
//! * **INDEX table** — per cycle, the ≤ r distinct frequency indices to read
//!   from the input-tile replicas (`rep_0, rep_1, ...`).
//! * **VALUE table** — per cycle, one slot per PE lane: the kernel weight,
//!   a `sel` signal routing the right replica output to the PE, and a
//!   `valid` bit ("some kernels might be inactive due to too many unique
//!   addresses in current cycle").
//!
//! The cycle-level simulator's streaming controller executes these tables
//! directly, so the scheduler → hardware hand-off is the same data structure
//! the paper describes.

use super::Schedule;
use crate::sparse::SparseLayer;

/// One PE lane's slot in a cycle of the VALUE table.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ValueSlot {
    /// Lane active this cycle?
    pub valid: bool,
    /// Which INDEX-table entry (replica port) feeds this lane.
    pub sel: u8,
    /// Kernel weight (re, im) consumed this cycle.
    pub weight: (f32, f32),
    /// Flattened frequency index (for writing the partial sum).
    pub index: u16,
}

impl ValueSlot {
    pub fn idle() -> Self {
        ValueSlot { valid: false, sel: 0, weight: (0.0, 0.0), index: 0 }
    }
}

/// The compiled tables for one kernel group at one input channel.
#[derive(Debug, Clone, PartialEq)]
pub struct AccessTables {
    /// `index[c]` = distinct indices read in cycle c (≤ r entries).
    pub index: Vec<Vec<u16>>,
    /// `value[c][lane]` = the lane's slot in cycle c (N' lanes wide).
    pub value: Vec<Vec<ValueSlot>>,
    pub num_lanes: usize,
}

impl AccessTables {
    pub fn cycles(&self) -> usize {
        self.index.len()
    }

    /// Words of on-chip table storage: INDEX entries + VALUE slots
    /// (weight = 2 words, sel+valid+index packed into 1).
    pub fn storage_words(&self) -> usize {
        let idx: usize = self.index.iter().map(|c| c.len()).sum();
        let val: usize = self
            .value
            .iter()
            .flat_map(|c| c.iter())
            .filter(|s| s.valid)
            .count();
        idx + 3 * val
    }
}

/// Compile a schedule into Fig. 6's INDEX/VALUE tables.
///
/// `kernel_of_lane` maps schedule-local kernel ids to lanes 1:1 (the
/// schedule's kernels *are* the lanes); weights come from the sparse layer:
/// group `group` at input channel `m`.
pub fn compile_tables(
    schedule: &Schedule,
    layer: &SparseLayer,
    group: usize,
    m: usize,
    n_par: usize,
) -> AccessTables {
    let base = group * n_par;
    let lanes = schedule.num_kernels;
    let mut index = Vec::with_capacity(schedule.cycles());
    let mut value = Vec::with_capacity(schedule.cycles());
    for set in &schedule.sets {
        let mut idxs: Vec<u16> = set.reads.iter().map(|&(_, i)| i).collect();
        idxs.sort_unstable();
        idxs.dedup();
        assert!(idxs.len() <= schedule.replicas, "C2 violated in input schedule");
        let mut slots = vec![ValueSlot::idle(); lanes];
        for &(k, i) in &set.reads {
            let sel = idxs.binary_search(&i).expect("index present") as u8;
            let kernel = layer.kernel(base + k as usize, m);
            let pos = kernel
                .indices
                .binary_search(&i)
                .expect("scheduled index must be a non-zero of the kernel");
            slots[k as usize] = ValueSlot {
                valid: true,
                sel,
                weight: kernel.values[pos],
                index: i,
            };
        }
        index.push(idxs);
        value.push(slots);
    }
    AccessTables { index, value, num_lanes: lanes }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::schedule_exact_cover;
    use crate::sparse::prune_random;
    use crate::util::rng::Pcg32;

    fn setup(n_par: usize, r: usize) -> (SparseLayer, Schedule, AccessTables) {
        let mut rng = Pcg32::new(21);
        let layer = prune_random(n_par, 2, 8, 4, &mut rng);
        let kernels = layer.group_indices(0, n_par, 1);
        let sched = schedule_exact_cover(&kernels, r);
        let tables = compile_tables(&sched, &layer, 0, 1, n_par);
        (layer, sched, tables)
    }

    #[test]
    fn tables_align_with_schedule() {
        let (_, sched, tables) = setup(16, 6);
        assert_eq!(tables.cycles(), sched.cycles());
        for (c, set) in sched.sets.iter().enumerate() {
            assert_eq!(tables.index[c].len(), set.distinct_indices());
            let active = tables.value[c].iter().filter(|s| s.valid).count();
            assert_eq!(active, set.active_kernels());
        }
    }

    #[test]
    fn sel_routes_to_correct_index() {
        let (_, _, tables) = setup(16, 6);
        for c in 0..tables.cycles() {
            for slot in tables.value[c].iter().filter(|s| s.valid) {
                assert_eq!(tables.index[c][slot.sel as usize], slot.index);
            }
        }
    }

    #[test]
    fn weights_match_sparse_layer() {
        let (layer, _, tables) = setup(8, 4);
        for c in 0..tables.cycles() {
            for (lane, slot) in tables.value[c].iter().enumerate() {
                if slot.valid {
                    let kernel = layer.kernel(lane, 1);
                    let pos = kernel.indices.binary_search(&slot.index).unwrap();
                    assert_eq!(slot.weight, kernel.values[pos]);
                }
            }
        }
    }

    #[test]
    fn total_valid_slots_equal_nnz() {
        let (layer, _, tables) = setup(16, 6);
        let valid: usize = tables
            .value
            .iter()
            .flat_map(|c| c.iter())
            .filter(|s| s.valid)
            .count();
        // group 0 at channel 1 covers all 16 kernels × nnz each
        let want: usize = (0..16).map(|n| layer.kernel(n, 1).nnz()).sum();
        assert_eq!(valid, want);
    }

    #[test]
    fn storage_words_positive_and_bounded() {
        let (layer, sched, tables) = setup(16, 6);
        let words = tables.storage_words();
        assert!(words > 0);
        // ≤ index entries (r per cycle) + 3 words per nnz
        let nnz: usize = (0..16).map(|n| layer.kernel(n, 1).nnz()).sum();
        assert!(words <= sched.cycles() * 6 + 3 * nnz);
    }
}
