//! Baseline schedulers the paper compares against (§6.2):
//!
//! * **random** — "randomly chooses both a kernel and a non-zero weight
//!   index in this kernel, then continues randomly choosing other kernels
//!   and indices until either all kernels are included or the number of
//!   unique indices reaches r".
//! * **lowest-index-first** ([16]) — "always picks the kernels with lowest
//!   index in the current group"; works well only when indices across
//!   kernels are correlated (paper: conv5_2/conv5_3-like patterns).
//!
//! Both share the paper's stopping condition per cycle; a kernel whose
//! proposed index cannot join (set already has r distinct indices and the
//! index is new) idles that cycle — that is exactly the utilization loss
//! Figs. 8–10 plot.

use super::{CycleSet, Schedule};
use crate::util::rng::Pcg32;

/// Random scheduling baseline. `seed` makes runs reproducible.
pub fn schedule_random(kernels: &[Vec<u16>], replicas: usize, seed: u64) -> Schedule {
    assert!(replicas >= 1);
    let mut rng = Pcg32::new(seed);
    let mut remaining: Vec<Vec<u16>> = kernels.to_vec();
    let mut sets = Vec::new();
    while remaining.iter().any(|k| !k.is_empty()) {
        let mut order: Vec<usize> = (0..remaining.len()).collect();
        rng.shuffle(&mut order);
        let mut chosen: Vec<u16> = Vec::new();
        let mut reads: Vec<(u16, u16)> = Vec::new();
        for k in order {
            if remaining[k].is_empty() {
                continue;
            }
            // random remaining index of this kernel
            let pos = rng.range(0, remaining[k].len());
            let idx = remaining[k][pos];
            if chosen.contains(&idx) {
                remaining[k].remove(pos);
                reads.push((k as u16, idx));
            } else if chosen.len() < replicas {
                chosen.push(idx);
                remaining[k].remove(pos);
                reads.push((k as u16, idx));
            }
            // else: replica budget exhausted and index is new → kernel idles
        }
        debug_assert!(!reads.is_empty());
        sets.push(CycleSet { reads });
    }
    Schedule { sets, replicas, num_kernels: kernels.len() }
}

/// Lowest-index-first baseline ([16]).
///
/// Every kernel proposes its lowest remaining index; kernels are admitted
/// in proposal order while the distinct-index budget allows.
pub fn schedule_lowest_index(kernels: &[Vec<u16>], replicas: usize) -> Schedule {
    assert!(replicas >= 1);
    // Track a cursor per kernel instead of mutating the index lists.
    let mut cursor = vec![0usize; kernels.len()];
    let mut sets = Vec::new();
    loop {
        // (kernel, lowest remaining index), sorted by index then kernel —
        // "picks the kernels with lowest index in the current group".
        let mut proposals: Vec<(u16, u16)> = kernels
            .iter()
            .enumerate()
            .filter(|(k, ks)| cursor[*k] < ks.len())
            .map(|(k, ks)| (ks[cursor[k]], k as u16))
            .map(|(i, k)| (k, i))
            .collect();
        if proposals.is_empty() {
            break;
        }
        proposals.sort_by(|a, b| a.1.cmp(&b.1).then(a.0.cmp(&b.0)));
        let mut chosen: Vec<u16> = Vec::new();
        let mut reads: Vec<(u16, u16)> = Vec::new();
        for (k, i) in proposals {
            if chosen.contains(&i) {
                reads.push((k, i));
                cursor[k as usize] += 1;
            } else if chosen.len() < replicas {
                chosen.push(i);
                reads.push((k, i));
                cursor[k as usize] += 1;
            }
            // else: kernel idles this cycle
        }
        debug_assert!(!reads.is_empty());
        sets.push(CycleSet { reads });
    }
    Schedule { sets, replicas, num_kernels: kernels.len() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::schedule_exact_cover;
    use crate::util::check::forall;
    use crate::util::rng::Pcg32;

    fn random_group(rng: &mut Pcg32, n: usize, k2: usize, nnz: usize) -> Vec<Vec<u16>> {
        (0..n)
            .map(|_| {
                let mut v: Vec<u16> =
                    rng.sample_indices(k2, nnz).into_iter().map(|i| i as u16).collect();
                v.sort_unstable();
                v
            })
            .collect()
    }

    #[test]
    fn baselines_satisfy_invariants() {
        forall("baseline invariants", 40, |rng| {
            let n = rng.range(1, 32);
            let nnz = rng.range(1, 17);
            let kernels = random_group(rng, n, 64, nnz);
            let r = rng.range(1, 16);
            for s in [
                schedule_random(&kernels, r, rng.next_u64()),
                schedule_lowest_index(&kernels, r),
            ] {
                s.validate(&kernels).unwrap();
            }
        });
    }

    #[test]
    fn lowest_index_optimal_on_identical_patterns() {
        // When all kernels share indices (the conv5-like regime the paper
        // notes), lowest-index-first is as good as exact-cover.
        let kernels = vec![vec![1u16, 5, 9, 20]; 32];
        let li = schedule_lowest_index(&kernels, 1);
        li.validate(&kernels).unwrap();
        assert_eq!(li.cycles(), 4);
        assert!((li.pe_utilization() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn exact_cover_dominates_baselines_on_random_patterns() {
        // Paper Figs. 8/10: exact-cover ≥ both baselines on scattered
        // patterns. Greedy isn't provably dominant per-instance, so check
        // in aggregate over instances.
        let mut rng = Pcg32::new(99);
        let (mut ec, mut li, mut rd) = (0usize, 0usize, 0usize);
        for t in 0..20 {
            let kernels = random_group(&mut rng, 64, 64, 16);
            ec += schedule_exact_cover(&kernels, 8).cycles();
            li += schedule_lowest_index(&kernels, 8).cycles();
            rd += schedule_random(&kernels, 8, t).cycles();
        }
        assert!(ec < li, "exact-cover {ec} vs lowest-index {li}");
        assert!(ec < rd, "exact-cover {ec} vs random {rd}");
    }

    #[test]
    fn random_seed_reproducible() {
        let kernels = vec![vec![0u16, 1, 2], vec![1, 2, 3], vec![4, 5, 6]];
        let a = schedule_random(&kernels, 2, 7);
        let b = schedule_random(&kernels, 2, 7);
        assert_eq!(a, b);
    }

    #[test]
    fn single_replica_still_completes() {
        let kernels = vec![vec![0u16], vec![1], vec![2]];
        let s = schedule_lowest_index(&kernels, 1);
        s.validate(&kernels).unwrap();
        assert_eq!(s.cycles(), 3); // one distinct index per cycle
    }
}
