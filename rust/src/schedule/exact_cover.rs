//! The paper's greedy approximate exact-cover scheduler (Alg. 2).
//!
//! Bipartite view (paper Fig. 5): kernel nodes on one side, frequency-index
//! nodes on the other; an edge (k, i) means kernel k has a non-zero at
//! index i. Each emitted set (one read cycle) takes at most one edge per
//! kernel and touches at most `r` distinct index nodes.
//!
//! Per-cycle set construction follows Alg. 2's two cases and strengthens
//! each with a cheap local search (the paper leaves the inner "find set
//! collection S" step open; a plain 1-pass greedy lands ~10 points below
//! the utilizations Fig. 9/10 report, the swap pass closes the gap —
//! measured in EXPERIMENTS.md §Perf):
//!
//! * **max-coverage greedy** over index nodes (gain = newly covered
//!   kernels, ties → lower remaining degree), then a **swap-improvement
//!   pass**: try replacing each chosen index with a better unchosen one
//!   until fixpoint.
//! * If the set covers *all* active kernels (Alg. 2 case 1), a
//!   **hub-saving pass** substitutes high-degree index nodes with the
//!   lowest-degree alternatives that keep the cover complete — "leaving
//!   high-degree nodes untouched" for future cycles.
//!
//! Kernel sets are bitmasks (`Vec<u64>` words), so coverage math is a few
//! dozen word ops per candidate; one 64-kernel × 16-nnz group schedules in
//! ~10 µs.

use super::{CycleSet, Schedule};

/// Kernel-set bitmask (supports groups larger than 64 kernels).
#[derive(Debug, Clone, PartialEq, Eq)]
struct Mask {
    words: Vec<u64>,
}

impl Mask {
    fn empty(n: usize) -> Self {
        Mask { words: vec![0; n.div_ceil(64)] }
    }

    #[inline]
    fn set(&mut self, k: usize) {
        self.words[k / 64] |= 1 << (k % 64);
    }

    #[inline]
    fn clear(&mut self, k: usize) {
        self.words[k / 64] &= !(1 << (k % 64));
    }

    #[inline]
    fn get(&self, k: usize) -> bool {
        (self.words[k / 64] >> (k % 64)) & 1 == 1
    }

    #[inline]
    fn count(&self) -> u32 {
        self.words.iter().map(|w| w.count_ones()).sum()
    }

    #[inline]
    fn or_assign(&mut self, o: &Mask) {
        for (a, b) in self.words.iter_mut().zip(&o.words) {
            *a |= b;
        }
    }

    /// |self & !other|
    #[inline]
    fn gain_over(&self, other: &Mask) -> u32 {
        self.words
            .iter()
            .zip(&other.words)
            .map(|(a, b)| (a & !b).count_ones())
            .sum()
    }

    fn is_zero(&self) -> bool {
        self.words.iter().all(|&w| w == 0)
    }

    fn or_of(masks: &[&Mask], n: usize) -> Mask {
        let mut out = Mask::empty(n);
        for m in masks {
            out.or_assign(m);
        }
        out
    }
}

/// Residual bipartite graph with per-index kernel bitmasks.
struct Residual {
    /// kernel -> remaining sorted indices.
    kernels: Vec<Vec<u16>>,
    /// dense index table: index -> kernel mask (empty mask = gone).
    masks: Vec<Mask>,
    /// live index ids (those with non-empty masks).
    live: Vec<u16>,
    n: usize,
    remaining_edges: usize,
}

impl Residual {
    fn new(kernels: &[Vec<u16>]) -> Self {
        let n = kernels.len();
        let max_idx = kernels
            .iter()
            .flat_map(|k| k.iter())
            .copied()
            .max()
            .map(|m| m as usize + 1)
            .unwrap_or(0);
        let mut masks = vec![Mask::empty(n); max_idx];
        let mut edges = 0;
        for (k, ks) in kernels.iter().enumerate() {
            for &i in ks {
                masks[i as usize].set(k);
                edges += 1;
            }
        }
        let live = (0..max_idx as u16)
            .filter(|&i| !masks[i as usize].is_zero())
            .collect();
        Residual { kernels: kernels.to_vec(), masks, live, n, remaining_edges: edges }
    }

    fn active_count(&self) -> u32 {
        let mut m = Mask::empty(self.n);
        for &i in &self.live {
            m.or_assign(&self.masks[i as usize]);
        }
        m.count()
    }

    fn degree(&self, i: u16) -> u32 {
        self.masks[i as usize].count()
    }

    fn remove_edge(&mut self, k: u16, i: u16) {
        let ks = &mut self.kernels[k as usize];
        if let Ok(pos) = ks.binary_search(&i) {
            ks.remove(pos);
            self.masks[i as usize].clear(k as usize);
            self.remaining_edges -= 1;
            if self.masks[i as usize].is_zero() {
                if let Ok(p) = self.live.binary_search(&i) {
                    self.live.remove(p);
                }
            }
        }
    }
}

/// Weighted coverage gain of index `i` over `covered`.
///
/// Kernel weights encode *criticality*: the schedule can never finish in
/// fewer cycles than the largest per-kernel remaining count, so kernels on
/// that critical path must be served every cycle — missing one extends the
/// schedule outright. Kernels with slack contribute proportionally to their
/// remaining work (serving them early keeps completion balanced and the
/// schedule tail dense).
/// Total weight of the kernels set in `m`.
fn weighted_gain_mask(m: &Mask, weights: &[u64]) -> u64 {
    let mut total = 0u64;
    for (w, &mw) in m.words.iter().enumerate() {
        let mut bits = mw;
        while bits != 0 {
            let b = bits.trailing_zeros() as usize;
            total += weights[w * 64 + b];
            bits &= bits - 1;
        }
    }
    total
}

fn weighted_gain(res: &Residual, i: u16, covered: &Mask, weights: &[u64]) -> u64 {
    let mask = &res.masks[i as usize];
    let mut total = 0u64;
    for (w, (&mw, &cw)) in mask.words.iter().zip(&covered.words).enumerate() {
        let mut bits = mw & !cw;
        while bits != 0 {
            let b = bits.trailing_zeros() as usize;
            total += weights[w * 64 + b];
            bits &= bits - 1;
        }
    }
    total
}

/// Greedy weighted-max-coverage selection of ≤ r index nodes, then swap
/// improvement, then (on full cover) hub-saving substitution.
fn select_indices(res: &Residual, r: usize) -> Vec<u16> {
    let n = res.n;
    // criticality weights (see weighted_gain)
    let max_rem = res.kernels.iter().map(|k| k.len()).max().unwrap_or(0);
    let weights: Vec<u64> = {
        let mut w = vec![0u64; res.masks.first().map(|m| m.words.len() * 64).unwrap_or(0).max(n)];
        for (k, ks) in res.kernels.iter().enumerate() {
            w[k] = if ks.is_empty() {
                0
            } else if ks.len() == max_rem {
                16_000
            } else {
                1_000 + 1_000 * ks.len() as u64
            };
        }
        w
    };
    // --- phase 1: multi-start greedy ----------------------------------------
    // Greedy from the s-th best opening pick (s = 0..STARTS); keep the
    // highest weighted coverage. The opening pick shapes the whole set, so a
    // few restarts recover most of what a one-shot greedy leaves behind.
    const STARTS: usize = 4;
    let greedy_from = |skip_rank: usize| -> Vec<u16> {
        let mut chosen: Vec<u16> = Vec::with_capacity(r);
        let mut covered = Mask::empty(n);
        let mut first = true;
        loop {
            if chosen.len() >= r {
                break;
            }
            // rank candidates by (wgain desc, degree asc, id asc)
            let mut cands: Vec<(u64, u32, u16)> = res
                .live
                .iter()
                .filter(|i| !chosen.contains(i))
                .map(|&i| (weighted_gain(res, i, &covered, &weights), res.degree(i), i))
                .filter(|&(g, _, _)| g > 0)
                .collect();
            if cands.is_empty() {
                break;
            }
            cands.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)).then(a.2.cmp(&b.2)));
            let pick = if first { skip_rank.min(cands.len() - 1) } else { 0 };
            first = false;
            let (_, _, i) = cands[pick];
            covered.or_assign(&res.masks[i as usize]);
            chosen.push(i);
        }
        chosen
    };
    let score = |chosen: &[u16]| -> u64 {
        let masks: Vec<&Mask> = chosen.iter().map(|&i| &res.masks[i as usize]).collect();
        let cov = Mask::or_of(&masks, n);
        weighted_gain_mask(&cov, &weights)
    };
    let mut chosen = greedy_from(0);
    let mut best_score = score(&chosen);
    for s in 1..STARTS {
        let cand = greedy_from(s);
        let sc = score(&cand);
        if sc > best_score {
            best_score = sc;
            chosen = cand;
        }
    }
    // --- phase 2: swap improvement -----------------------------------------
    // Replace chosen[j] with an unchosen candidate when weighted coverage
    // grows.
    let mut improved = true;
    let mut rounds = 0;
    while improved && rounds < 3 {
        improved = false;
        rounds += 1;
        for j in 0..chosen.len() {
            let others: Vec<&Mask> = chosen
                .iter()
                .enumerate()
                .filter(|&(q, _)| q != j)
                .map(|(_, &i)| &res.masks[i as usize])
                .collect();
            let without = Mask::or_of(&others, n);
            let current = weighted_gain(res, chosen[j], &without, &weights);
            let mut best: Option<(u64, u16)> = None;
            for &cand in &res.live {
                if chosen.contains(&cand) {
                    continue;
                }
                let gain = weighted_gain(res, cand, &without, &weights);
                if gain > current && best.map(|(g, _)| gain > g).unwrap_or(true) {
                    best = Some((gain, cand));
                }
            }
            if let Some((_, cand)) = best {
                chosen[j] = cand;
                improved = true;
            }
        }
    }
    // recompute coverage after swaps
    let masks: Vec<&Mask> = chosen.iter().map(|&i| &res.masks[i as usize]).collect();
    let covered = Mask::or_of(&masks, n);
    // --- phase 3: hub-saving on full cover (Alg. 2 case 1) -----------------
    if covered.count() == res.active_count() {
        let mut chosen = chosen;
        for j in 0..chosen.len() {
            let others: Vec<&Mask> = chosen
                .iter()
                .enumerate()
                .filter(|&(q, _)| q != j)
                .map(|(_, &i)| &res.masks[i as usize])
                .collect();
            let without = Mask::or_of(&others, n);
            let need = res.masks[chosen[j] as usize].gain_over(&without);
            // lowest-degree substitute that still covers the same residue
            let mut best: Option<(u32, u16)> = None;
            for &cand in &res.live {
                if chosen.contains(&cand) {
                    continue;
                }
                let deg = res.degree(cand);
                if deg >= res.degree(chosen[j]) {
                    continue;
                }
                let gain = res.masks[cand as usize].gain_over(&without);
                if gain >= need && best.map(|(d, _)| deg < d).unwrap_or(true) {
                    best = Some((deg, cand));
                }
            }
            if let Some((_, cand)) = best {
                chosen[j] = cand;
            }
        }
        return chosen;
    }
    chosen
}

/// Work estimate for scheduling one group with [`schedule_exact_cover`]:
/// every emitted cycle scans the live index nodes against the kernel masks,
/// so total cost scales like `edges × kernels` word operations. The serving
/// path compares this against a budget *before* scheduling (the software
/// stand-in for "exact cover timed out") and falls back to the
/// lowest-index-first baseline when a group would blow it.
pub fn exact_cover_work(kernels: &[Vec<u16>]) -> u64 {
    let edges: u64 = kernels.iter().map(|k| k.len() as u64).sum();
    edges * kernels.len() as u64
}

/// Budgeted front-end for [`schedule_exact_cover`]: returns `None` (caller
/// falls back to a cheaper scheduler) when [`exact_cover_work`] exceeds
/// `max_work`, instead of spending unbounded startup time on a huge group.
pub fn schedule_exact_cover_budgeted(
    kernels: &[Vec<u16>],
    replicas: usize,
    max_work: u64,
) -> Option<Schedule> {
    if exact_cover_work(kernels) > max_work {
        return None;
    }
    Some(schedule_exact_cover(kernels, replicas))
}

/// Paper Alg. 2: greedy approximate exact cover.
///
/// `kernels[k]` = sorted non-zero indices of kernel `k`. Returns a schedule
/// whose sets partition all (kernel, index) edges, each set with ≤
/// `replicas` distinct indices and ≤ 1 read per kernel.
pub fn schedule_exact_cover(kernels: &[Vec<u16>], replicas: usize) -> Schedule {
    assert!(replicas >= 1, "need at least one replica");
    let mut res = Residual::new(kernels);
    let mut sets = Vec::new();
    while res.remaining_edges > 0 {
        let chosen = select_indices(&res, replicas);
        debug_assert!(!chosen.is_empty(), "scheduler must make progress");
        // Serve each kernel once, preferring its *scarcest* chosen index
        // (lowest remaining degree) so plentiful indices stay available.
        let mut reads: Vec<(u16, u16)> = Vec::new();
        let mut served = Mask::empty(res.n);
        let mut order: Vec<u16> = chosen.clone();
        order.sort_by_key(|&i| res.degree(i));
        for &i in &order {
            let mask = res.masks[i as usize].clone();
            for k in 0..res.n {
                if mask.get(k) && !served.get(k) {
                    served.set(k);
                    reads.push((k as u16, i));
                }
            }
        }
        for &(k, i) in &reads {
            res.remove_edge(k, i);
        }
        sets.push(CycleSet { reads });
    }
    Schedule { sets, replicas, num_kernels: kernels.len() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::prune_random;
    use crate::util::check::forall;
    use crate::util::rng::Pcg32;

    fn random_group(rng: &mut Pcg32, n: usize, k2: usize, nnz: usize) -> Vec<Vec<u16>> {
        (0..n)
            .map(|_| {
                let mut v: Vec<u16> =
                    rng.sample_indices(k2, nnz).into_iter().map(|i| i as u16).collect();
                v.sort_unstable();
                v
            })
            .collect()
    }

    #[test]
    fn identical_kernels_need_nnz_cycles() {
        // All kernels share the same indices ⇒ one index serves everyone;
        // nnz cycles at 100% utilization even with r=1.
        let kernels = vec![vec![3u16, 7, 11]; 16];
        let s = schedule_exact_cover(&kernels, 1);
        s.validate(&kernels).unwrap();
        assert_eq!(s.cycles(), 3);
        assert!((s.pe_utilization() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn disjoint_kernels_bounded_by_replicas() {
        // 4 kernels with fully disjoint indices, r=2: 8 edges, ≤2 distinct
        // indices per cycle ⇒ ≥ 4 cycles; greedy should hit 4.
        let kernels = vec![vec![0u16, 1], vec![2, 3], vec![4, 5], vec![6, 7]];
        let s = schedule_exact_cover(&kernels, 2);
        s.validate(&kernels).unwrap();
        assert_eq!(s.cycles(), 4);
    }

    #[test]
    fn large_r_reaches_lower_bound() {
        forall("r=k2 optimal", 30, |rng| {
            let kernels = random_group(rng, 16, 64, 8);
            // r = 64 ⇒ no replica constraint: cycles = max nnz = 8
            let s = schedule_exact_cover(&kernels, 64);
            s.validate(&kernels).unwrap();
            assert_eq!(s.cycles(), 8);
            assert!((s.pe_utilization() - 1.0).abs() < 1e-12);
        });
    }

    #[test]
    fn exact_cover_invariants_random() {
        forall("exact-cover invariants", 40, |rng| {
            let n = rng.range(1, 40);
            let nnz = rng.range(1, 17);
            let r = rng.range(1, 21);
            let kernels = random_group(rng, n, 64, nnz);
            let s = schedule_exact_cover(&kernels, r);
            s.validate(&kernels).unwrap();
            assert!(s.cycles() >= Schedule::lower_bound(&kernels, r));
            assert!(s.pe_utilization() <= 1.0 + 1e-12);
        });
    }

    #[test]
    fn paper_operating_point_high_utilization() {
        // Paper Fig 9 (ADMM kernels, r=10, N'=64): ~90% at α=4 and >80%
        // even at α=8 ("indices largely scattered"). Fig 10 (random
        // patterns): comparable to ADMM at α=4.
        use crate::sparse::prune_magnitude;
        let mut rng = Pcg32::new(42);
        for (alpha, floor) in [(4usize, 0.85), (8, 0.80)] {
            let layer = prune_magnitude(64, 8, 8, alpha, &mut rng);
            let mut total = 0.0;
            for m in 0..8 {
                let kernels = layer.group_indices(0, 64, m);
                let s = schedule_exact_cover(&kernels, 10);
                s.validate(&kernels).unwrap();
                total += s.pe_utilization();
            }
            let avg = total / 8.0;
            assert!(avg >= floor, "α={alpha}: utilization {avg} < {floor}");
        }
        // Fig 10: random α=4 at r=10 stays within a few points of ADMM.
        let layer = prune_random(64, 8, 8, 4, &mut rng);
        let mut total = 0.0;
        for m in 0..8 {
            let kernels = layer.group_indices(0, 64, m);
            total += schedule_exact_cover(&kernels, 10).pe_utilization();
        }
        assert!(total / 8.0 >= 0.80, "random α=4: {}", total / 8.0);
    }

    #[test]
    fn empty_and_degenerate_groups() {
        let s = schedule_exact_cover(&[], 4);
        assert_eq!(s.cycles(), 0);
        let kernels = vec![vec![], vec![5u16]];
        let s = schedule_exact_cover(&kernels, 4);
        s.validate(&kernels).unwrap();
        assert_eq!(s.cycles(), 1);
    }

    #[test]
    fn deterministic() {
        let mut rng = Pcg32::new(7);
        let kernels = random_group(&mut rng, 32, 64, 16);
        let a = schedule_exact_cover(&kernels, 8);
        let b = schedule_exact_cover(&kernels, 8);
        assert_eq!(a, b);
    }

    #[test]
    fn budgeted_falls_back_only_over_budget() {
        let mut rng = Pcg32::new(11);
        let kernels = random_group(&mut rng, 16, 64, 8);
        let work = exact_cover_work(&kernels);
        assert_eq!(work, 16 * 8 * 16);
        // under budget: same schedule as the unbudgeted entry
        let s = schedule_exact_cover_budgeted(&kernels, 8, work).unwrap();
        assert_eq!(s, schedule_exact_cover(&kernels, 8));
        // over budget: signals the caller to fall back
        assert!(schedule_exact_cover_budgeted(&kernels, 8, work - 1).is_none());
    }

    #[test]
    fn groups_beyond_64_kernels() {
        // Mask spills into multiple words.
        let mut rng = Pcg32::new(8);
        let kernels = random_group(&mut rng, 130, 64, 8);
        let s = schedule_exact_cover(&kernels, 12);
        s.validate(&kernels).unwrap();
        assert!(s.pe_utilization() > 0.5);
    }
}
